#!/usr/bin/env bash
# Produce BENCH_PR6.json: the fig-11 KV-tier wall-clock benchmark —
# app-level ops/sec of the one-sided READ/WRITE data plane against the
# SEND-RPC baseline at each client count, plus per-point p99 latencies,
# server CPU and doorbell-coalescing counters. CI runs this with --quick
# and uploads the JSON plus the rendered markdown (scripts/perf_table.py
# takes any number of BENCH_*.json inputs); run it with no arguments on
# a quiet machine for the full-sweep numbers quoted in README.md.
# Measurement stays at --jobs 1 (the serial runner) so the per-point
# wall clocks are uncontended.
#
#   scripts/bench_pr6.sh [--quick] [OUT.json]
set -euo pipefail

cd "$(dirname "$0")/.."

quick=""
out="BENCH_PR6.json"
for arg in "$@"; do
    case "$arg" in
        --quick) quick="--quick" ;;
        *) out="$arg" ;;
    esac
done

cargo build --release
cargo run --quiet --release -- bench kv $quick --out "$out" >/dev/null

echo "wrote $out"
