#!/usr/bin/env bash
# Produce BENCH_PR3.json: the fig-9 wall-clock benchmark (events/sec and
# wall clock per connection count) with the raw scheduler throughput
# embedded under its "simstep" key — one self-contained perf artifact.
# CI runs this with --quick and uploads BENCH_PR3.json so every future PR
# has a perf trajectory to regress against; run it with no arguments on a
# quiet machine for the full-sweep numbers quoted in README.md.
#
#   scripts/bench_pr3.sh [--quick] [OUT.json]
set -euo pipefail

cd "$(dirname "$0")/.."

quick=""
out="BENCH_PR3.json"
for arg in "$@"; do
    case "$arg" in
        --quick) quick="--quick" ;;
        *) out="$arg" ;;
    esac
done

cargo build --release
cargo run --quiet --release -- bench fig9 $quick --out "$out" >/dev/null

echo "wrote $out"
