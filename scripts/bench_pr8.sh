#!/usr/bin/env bash
# Produce BENCH_PR8.json: the sharded-simulator benchmark — the fig-9
# scale sweep timed serial AND on the conservative-parallel executor
# (per-point speedup + the `identical_series` byte-identity bit), with
# the raw scheduler shard sweep (`bench simstep --shards`) spliced in as
# `shard_sweep`. CI runs this with --quick and uploads the JSON plus the
# rendered markdown (scripts/perf_table.py takes any number of
# BENCH_*.json inputs); run it with no arguments on a quiet machine for
# the full-sweep numbers quoted in README.md. Measurement stays at
# --jobs 1 (the serial sweep runner) so the shard speedup is the only
# parallelism being timed; --shards 0 means all cores.
#
#   scripts/bench_pr8.sh [--quick] [OUT.json]
set -euo pipefail

cd "$(dirname "$0")/.."

quick=""
out="BENCH_PR8.json"
for arg in "$@"; do
    case "$arg" in
        --quick) quick="--quick" ;;
        *) out="$arg" ;;
    esac
done

cargo build --release
cargo run --quiet --release -- bench fig9 $quick --jobs 1 --shards 0 --out "$out" >/dev/null

# splice the shard_sweep from `bench simstep --shards 0` into the same
# artifact so BENCH_PR8.json is one self-contained perf record (stdlib
# python only — no jq in the image)
cargo run --quiet --release -- bench simstep $quick --shards 0 \
    | python3 -c '
import json, sys
sweep = json.load(sys.stdin).get("shard_sweep", [])
path = sys.argv[1]
with open(path, encoding="utf-8") as f:
    doc = json.load(f)
doc["shard_sweep"] = sweep
with open(path, "w", encoding="utf-8") as f:
    json.dump(doc, f)
' "$out"

echo "wrote $out"
