#!/usr/bin/env bash
# Produce BENCH_PR10.json: the fig-14 failover-storm benchmark — steady
# Clos traffic through a scheduled spine death, measuring pre-failure /
# dip / post-recovery goodput, mouse p99 FCT and the repath / heal /
# retry-exceeded counters, with full repair (blackhole detector + ECMP
# reconvergence + daemon self-healing) against the repath-off ablation.
# With --shards N each mode is re-run on the conservative-parallel
# executor and the artifact records the speedup plus the
# identical_series byte-identity bit. CI runs this with --quick and
# uploads the JSON plus the rendered markdown (scripts/perf_table.py
# takes any number of BENCH_*.json inputs); run it with no arguments on
# a quiet machine for the full-storm numbers quoted in README.md.
#
#   scripts/bench_pr10.sh [--quick] [OUT.json]
set -euo pipefail

cd "$(dirname "$0")/.."

quick=""
out="BENCH_PR10.json"
for arg in "$@"; do
    case "$arg" in
        --quick) quick="--quick" ;;
        *) out="$arg" ;;
    esac
done

cargo build --release
cargo run --quiet --release -- bench failover $quick --shards 2 --out "$out" >/dev/null

echo "wrote $out"
