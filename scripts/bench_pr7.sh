#!/usr/bin/env bash
# Produce BENCH_PR7.json: the fig-12 elastic-control-plane benchmark —
# tenant setup rate (conns/sec) and p99 time-to-first-byte with the QP
# reuse pool + lazy batched leases, against the cold ablation (full
# handshake + eager leases per tenant), plus idle memory-per-vQPN and
# the reuse/handshake/batching counters at each tenant count. CI runs
# this with --quick and uploads the JSON plus the rendered markdown
# (scripts/perf_table.py takes any number of BENCH_*.json inputs); run
# it with no arguments on a quiet machine for the full-sweep numbers
# quoted in README.md. Measurement stays at --jobs 1 (the serial
# runner) so the per-point wall clocks are uncontended.
#
#   scripts/bench_pr7.sh [--quick] [OUT.json]
set -euo pipefail

cd "$(dirname "$0")/.."

quick=""
out="BENCH_PR7.json"
for arg in "$@"; do
    case "$arg" in
        --quick) quick="--quick" ;;
        *) out="$arg" ;;
    esac
done

cargo build --release
cargo run --quiet --release -- bench churn $quick --out "$out" >/dev/null

echo "wrote $out"
