#!/usr/bin/env bash
# Tier-1 verification + repo hygiene. Run from the repository root.
#
#   scripts/verify.sh            # full: build, test, benches, docs, dep check
#   scripts/verify.sh --quick    # shrink the simulated sweeps (CI)
set -euo pipefail

cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--quick" ]]; then
    export RDMAVISOR_BENCH_QUICK=1
fi

echo "== zero-dependency check =="
# The crate must keep compiling offline with std only: no ecosystem crate
# may be imported anywhere in the Rust tree. Match import/path forms, not
# prose (comments legitimately mention the crates we replaced).
banned='^[[:space:]]*(pub[[:space:]]+)?use[[:space:]]+(anyhow|serde|serde_json|tokio|libc|xla|rand|clap|criterion|proptest)(::|;| )|(anyhow|serde_json|tokio|libc|xla)::'
if git grep -nE "$banned" -- 'rust/src' 'rust/tests' 'rust/benches' 'examples'; then
    echo "FAIL: banned external-crate import found (see above)" >&2
    exit 1
fi
echo "ok: no external-crate imports"

echo "== manifest declares no dependencies =="
if awk '/^\[dependencies\]/{f=1;next} /^\[/{f=0} f && NF && $1 !~ /^#/' rust/Cargo.toml | grep -q .; then
    echo "FAIL: rust/Cargo.toml [dependencies] is not empty" >&2
    exit 1
fi
echo "ok: [dependencies] empty"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== bench targets compile =="
cargo build --benches

echo "== rustdoc (missing_docs surface) =="
cargo doc --no-deps

echo "== smoke: figure runner emits JSON =="
out="$(cargo run --quiet --release -- fig --id 1 --quick 2>/dev/null)"
case "$out" in
    '{"budget"'*|'{'*'"command":"fig"'*) echo "ok: fig --id 1 printed JSON" ;;
    *) echo "FAIL: unexpected fig output: ${out:0:120}" >&2; exit 1 ;;
esac

echo "ALL CHECKS PASSED"
