#!/usr/bin/env bash
# Tier-1 verification + repo hygiene. Run from the repository root.
#
#   scripts/verify.sh            # full: build, test, clippy, benches, docs
#   scripts/verify.sh --quick    # shrink the simulated sweeps (CI)
set -euo pipefail

cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--quick" ]]; then
    export RDMAVISOR_BENCH_QUICK=1
fi

echo "== zero-dependency check =="
# The crate must keep compiling offline with std only: no ecosystem crate
# may be imported anywhere in the Rust tree. Match import/path forms and
# filter out comment lines — prose legitimately mentions the crates we
# replaced (e.g. "`anyhow::Context`-style" in util/error.rs).
banned='^[[:space:]]*(pub[[:space:]]+)?use[[:space:]]+(anyhow|serde|serde_json|tokio|libc|xla|rand|clap|criterion|proptest)(::|;| )|(anyhow|serde_json|tokio|libc|xla)::'
hits="$(git grep -nE "$banned" -- 'rust/src' 'rust/tests' 'rust/benches' 'examples' \
        | grep -vE '^[^:]+:[0-9]+:[[:space:]]*(//|\*|/\*)' || true)"
if [[ -n "$hits" ]]; then
    echo "$hits"
    echo "FAIL: banned external-crate import found (see above)" >&2
    exit 1
fi
echo "ok: no external-crate imports"

echo "== manifest declares no dependencies =="
if awk '/^\[dependencies\]/{f=1;next} /^\[/{f=0} f && NF && $1 !~ /^#/' rust/Cargo.toml | grep -q .; then
    echo "FAIL: rust/Cargo.toml [dependencies] is not empty" >&2
    exit 1
fi
echo "ok: [dependencies] empty"

echo "== toolchain present =="
# Fail LOUDLY, not silently: every cargo stage below is the actual gate,
# and an environment without a toolchain must read as a failure (three
# PRs shipped on static review because this was easy to miss).
if ! command -v cargo >/dev/null 2>&1; then
    echo "FAIL: cargo not found on PATH — the tier-1 build/test/clippy stages" >&2
    echo "      CANNOT run. Install a Rust toolchain (rustup.rs) and re-run;" >&2
    echo "      do NOT treat this verify as passed." >&2
    exit 1
fi
cargo --version

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== clippy (all targets, warnings are errors) =="
# Style/complexity lint groups are allowed via rust/Cargo.toml [lints];
# this gate enforces the correctness/suspicious/perf groups plus rustc
# warnings (including missing_docs) across lib, bin, tests and benches.
if ! cargo clippy --version >/dev/null 2>&1; then
    echo "FAIL: clippy not installed (rustup component add clippy)" >&2
    exit 1
fi
cargo clippy --all-targets -- -D warnings

echo "== bench targets compile =="
cargo build --benches

echo "== rustdoc (missing_docs surface) =="
cargo doc --no-deps

echo "== smoke: figure runner emits JSON =="
out="$(cargo run --quiet --release -- fig --id 1 --quick 2>/dev/null)"
case "$out" in
    '{"budget"'*|'{'*'"command":"fig"'*) echo "ok: fig --id 1 printed JSON" ;;
    *) echo "FAIL: unexpected fig output: ${out:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: fig 9 (RC<->UD migration scale sweep) =="
out9="$(cargo run --quiet --release -- fig --id 9 --quick 2>/dev/null)"
case "$out9" in
    '{"budget"'*|'{'*'"command":"fig"'*)
        case "$out9" in
            *'"fig9_scale"'*) echo "ok: fig --id 9 printed the fig9_scale series" ;;
            *) echo "FAIL: fig 9 JSON lacks the fig9_scale series: ${out9:0:160}" >&2; exit 1 ;;
        esac ;;
    *) echo "FAIL: unexpected fig 9 output: ${out9:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: fig 9 --jobs 2 (parallel sweep harness, byte-identical) =="
# the parallel executor must not change a single output byte; only the
# top-level wall_ms field legitimately varies run to run, so strip it
out9j="$(cargo run --quiet --release -- fig --id 9 --quick --jobs 2 2>/dev/null)"
strip_wall() { printf '%s' "$1" | sed -E 's/"wall_ms":[^,}]+//g'; }
if [[ "$(strip_wall "$out9j")" != "$(strip_wall "$out9")" ]]; then
    echo "FAIL: fig 9 --jobs 2 JSON differs from the serial runner" >&2
    exit 1
fi
echo "ok: fig --id 9 --jobs 2 matches the serial series byte-for-byte"

echo "== smoke: fig 9 --shards 2 (sharded simulator, byte-identical) =="
# the conservative-parallel executor must not change a single output
# byte either — same strip_wall treatment as the --jobs smoke; the real
# gates (figs 9-13 x4, rc-only/cold/no-cc/pfc ablations, trace property)
# live in tests/determinism.rs, this is the end-to-end CLI path
out9s="$(cargo run --quiet --release -- fig --id 9 --quick --shards 2 2>/dev/null)"
if [[ "$(strip_wall "$out9s")" != "$(strip_wall "$out9")" ]]; then
    echo "FAIL: fig 9 --shards 2 JSON differs from the serial simulator" >&2
    exit 1
fi
echo "ok: fig --id 9 --shards 2 matches the serial simulator byte-for-byte"

echo "== smoke: fig 10 (fault-injection chaos sweep) =="
out10="$(cargo run --quiet --release -- fig --id 10 --quick 2>/dev/null)"
case "$out10" in
    '{"budget"'*|'{'*'"command":"fig"'*)
        case "$out10" in
            *'"fig10_chaos"'*) echo "ok: fig --id 10 printed the fig10_chaos series" ;;
            *) echo "FAIL: fig 10 JSON lacks the fig10_chaos series: ${out10:0:160}" >&2; exit 1 ;;
        esac ;;
    *) echo "FAIL: unexpected fig 10 output: ${out10:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: fig 11 (one-sided KV tier vs SEND-RPC) =="
out11="$(cargo run --quiet --release -- fig --id 11 --quick 2>/dev/null)"
case "$out11" in
    '{"budget"'*|'{'*'"command":"fig"'*)
        case "$out11" in
            *'"fig11_kv"'*) echo "ok: fig --id 11 printed the fig11_kv series" ;;
            *) echo "FAIL: fig 11 JSON lacks the fig11_kv series: ${out11:0:160}" >&2; exit 1 ;;
        esac ;;
    *) echo "FAIL: unexpected fig 11 output: ${out11:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: fig 12 (elastic control plane under tenant churn) =="
out12="$(cargo run --quiet --release -- fig --id 12 --quick 2>/dev/null)"
case "$out12" in
    '{"budget"'*|'{'*'"command":"fig"'*)
        case "$out12" in
            *'"fig12_churn"'*) echo "ok: fig --id 12 printed the fig12_churn series" ;;
            *) echo "FAIL: fig 12 JSON lacks the fig12_churn series: ${out12:0:160}" >&2; exit 1 ;;
        esac ;;
    *) echo "FAIL: unexpected fig 12 output: ${out12:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: fig 13 (Clos incast with congestion control) =="
out13="$(cargo run --quiet --release -- fig --id 13 --quick 2>/dev/null)"
case "$out13" in
    '{"budget"'*|'{'*'"command":"fig"'*)
        case "$out13" in
            *'"fig13_incast"'*) echo "ok: fig --id 13 printed the fig13_incast series" ;;
            *) echo "FAIL: fig 13 JSON lacks the fig13_incast series: ${out13:0:160}" >&2; exit 1 ;;
        esac ;;
    *) echo "FAIL: unexpected fig 13 output: ${out13:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: fig 13 --shards 2 (Clos at the coordinator barrier, byte-identical) =="
out13s="$(cargo run --quiet --release -- fig --id 13 --quick --shards 2 2>/dev/null)"
if [[ "$(strip_wall "$out13s")" != "$(strip_wall "$out13")" ]]; then
    echo "FAIL: fig 13 --shards 2 JSON differs from the serial simulator" >&2
    exit 1
fi
echo "ok: fig --id 13 --shards 2 matches the serial simulator byte-for-byte"

echo "== smoke: fig 14 (failover storm through a spine death) =="
out14="$(cargo run --quiet --release -- fig --id 14 --quick 2>/dev/null)"
case "$out14" in
    '{"budget"'*|'{'*'"command":"fig"'*)
        case "$out14" in
            *'"fig14_failover"'*) echo "ok: fig --id 14 printed the fig14_failover series" ;;
            *) echo "FAIL: fig 14 JSON lacks the fig14_failover series: ${out14:0:160}" >&2; exit 1 ;;
        esac ;;
    *) echo "FAIL: unexpected fig 14 output: ${out14:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: fig 14 --shards 2 (faults at the coordinator barrier, byte-identical) =="
# switch deaths, repath epochs and daemon heals all replay through the
# conservative barrier — same strip_wall treatment as the fig-9 smoke;
# the full gates (jobs x shards x repath-off) live in tests/determinism.rs
out14s="$(cargo run --quiet --release -- fig --id 14 --quick --shards 2 2>/dev/null)"
if [[ "$(strip_wall "$out14s")" != "$(strip_wall "$out14")" ]]; then
    echo "FAIL: fig 14 --shards 2 JSON differs from the serial simulator" >&2
    exit 1
fi
echo "ok: fig --id 14 --shards 2 matches the serial simulator byte-for-byte"

echo "== smoke: fig 14 --repath-off (survivability ablation) =="
out14a="$(cargo run --quiet --release -- fig --id 14 --quick --repath-off 2>/dev/null)"
case "$out14a" in
    *'"fig14_failover"'*) echo "ok: fig --id 14 --repath-off printed the ablation series" ;;
    *) echo "FAIL: unexpected fig 14 --repath-off output: ${out14a:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: bench incast (Clos goodput sweep -> JSON) =="
# --out to a temp file so the smoke never clobbers a tracked BENCH_PR9.json
incast_tmp="$(mktemp)"
outin="$(cargo run --quiet --release -- bench incast --quick --out "$incast_tmp" 2>/dev/null)"
rm -f "$incast_tmp"
# jsonmini sorts object keys, so "events_per_sec" precedes "mode" in the doc
case "$outin" in
    *'"events_per_sec"'*'"mode":"incast"'*) echo "ok: bench incast printed goodput JSON" ;;
    *) echo "FAIL: unexpected bench incast output: ${outin:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: bench failover (fig-14 storm + shard identity bit -> JSON) =="
# --out to a temp file so the smoke never clobbers a tracked BENCH_PR10.json
failover_tmp="$(mktemp)"
outfo="$(cargo run --quiet --release -- bench failover --quick --shards 2 --out "$failover_tmp" 2>/dev/null)"
rm -f "$failover_tmp"
# jsonmini sorts object keys, so "identical_series" precedes "mode"
case "$outfo" in
    *'"identical_series":true'*'"mode":"failover"'*) echo "ok: bench failover printed JSON with identical_series:true" ;;
    *'"identical_series":false'*)
        echo "FAIL: bench failover reports a serial/sharded series mismatch" >&2; exit 1 ;;
    *) echo "FAIL: unexpected bench failover output: ${outfo:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: bench churn (tenant setup rate -> JSON) =="
# --out to a temp file so the smoke never clobbers a tracked BENCH_PR7.json
churn_tmp="$(mktemp)"
outch="$(cargo run --quiet --release -- bench churn --quick --out "$churn_tmp" 2>/dev/null)"
rm -f "$churn_tmp"
# jsonmini sorts object keys, so "conns_per_sec" precedes "mode" in the doc
case "$outch" in
    *'"conns_per_sec"'*'"mode":"churn"'*) echo "ok: bench churn printed setup-rate JSON" ;;
    *) echo "FAIL: unexpected bench churn output: ${outch:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: bench kv (app-level KV throughput -> JSON) =="
# --out to a temp file so the smoke never clobbers a tracked BENCH_PR6.json
kv_tmp="$(mktemp)"
outkv="$(cargo run --quiet --release -- bench kv --quick --out "$kv_tmp" 2>/dev/null)"
rm -f "$kv_tmp"
case "$outkv" in
    *'"mode":"kv"'*'"ops_per_sec"'*) echo "ok: bench kv printed ops/sec JSON" ;;
    *) echo "FAIL: unexpected bench kv output: ${outkv:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: bench simstep (DES scheduler throughput) =="
outs="$(cargo run --quiet --release -- bench simstep --quick 2>/dev/null)"
case "$outs" in
    *'"mode":"simstep"'*'"events_per_sec"'*) echo "ok: bench simstep printed events/sec JSON" ;;
    *) echo "FAIL: unexpected bench simstep output: ${outs:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: bench simstep --shards 2 (shard scaling sweep) =="
outss="$(cargo run --quiet --release -- bench simstep --quick --shards 2 2>/dev/null)"
case "$outss" in
    *'"mode":"simstep"'*'"shard_sweep"'*) echo "ok: bench simstep --shards printed the shard_sweep" ;;
    *) echo "FAIL: unexpected bench simstep --shards output: ${outss:0:120}" >&2; exit 1 ;;
esac

echo "== smoke: bench pump (daemon data-plane throughput) =="
outp="$(cargo run --quiet --release -- bench pump --quick 2>/dev/null)"
case "$outp" in
    *'"mode":"pump"'*'"ops_per_sec"'*) echo "ok: bench pump printed ops/sec JSON" ;;
    *) echo "FAIL: unexpected bench pump output: ${outp:0:120}" >&2; exit 1 ;;
esac

echo "ALL CHECKS PASSED"
