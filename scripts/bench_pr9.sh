#!/usr/bin/env bash
# Produce BENCH_PR9.json: the fig-13 Clos-incast benchmark — goodput at
# the sink and mouse p99 flow-completion time per ToR oversubscription
# factor, under DCQCN, the no-CC ablation (tail-drop collapse) and the
# PFC ablation (lossless pause gating), plus the ECN-mark / switch-drop
# / pause counters at each point. CI runs this with --quick and uploads
# the JSON plus the rendered markdown (scripts/perf_table.py takes any
# number of BENCH_*.json inputs); run it with no arguments on a quiet
# machine for the full-sweep numbers quoted in README.md. Measurement
# stays at --jobs 1 (the serial runner) so the per-point wall clocks
# are uncontended.
#
#   scripts/bench_pr9.sh [--quick] [OUT.json]
set -euo pipefail

cd "$(dirname "$0")/.."

quick=""
out="BENCH_PR9.json"
for arg in "$@"; do
    case "$arg" in
        --quick) quick="--quick" ;;
        *) out="$arg" ;;
    esac
done

cargo build --release
cargo run --quiet --release -- bench incast $quick --out "$out" >/dev/null

echo "wrote $out"
