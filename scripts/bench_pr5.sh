#!/usr/bin/env bash
# Produce BENCH_PR5.json: the fig-9 wall-clock benchmark with the daemon
# data-plane throughput (`bench pump`) and the raw scheduler throughput
# (`bench simstep`) embedded — one self-contained perf artifact for the
# PR-5 daemon-densification + parallel-harness trajectory. CI runs this
# with --quick and uploads the JSON plus the rendered markdown
# (scripts/perf_table.py takes any number of BENCH_*.json inputs); run
# it with no arguments on a quiet machine for the full-sweep numbers
# quoted in README.md. Measurement stays at --jobs 1 (the serial runner)
# so the per-point wall clocks are uncontended.
#
#   scripts/bench_pr5.sh [--quick] [OUT.json]
set -euo pipefail

cd "$(dirname "$0")/.."

quick=""
out="BENCH_PR5.json"
for arg in "$@"; do
    case "$arg" in
        --quick) quick="--quick" ;;
        *) out="$arg" ;;
    esac
done

cargo build --release
cargo run --quiet --release -- bench fig9 $quick --out "$out" >/dev/null

echo "wrote $out"
