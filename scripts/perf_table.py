#!/usr/bin/env python3
"""Render one or more BENCH_*.json artifacts (from `rdmavisor bench
fig9` / `rdmavisor bench kv` / `rdmavisor bench churn` / `rdmavisor
bench incast` / `rdmavisor bench failover` / bench_pr{3,5,6,7,8,9,10}.sh)
as the markdown perf tables README.md quotes. Stdlib only.

    python3 scripts/perf_table.py BENCH_PR5.json BENCH_PR6.json \
        BENCH_PR7.json BENCH_PR8.json BENCH_PR9.json BENCH_PR10.json \
        > BENCH_PR6.md

Each input gets its own section (headed by the file name), so one
markdown artifact can carry the whole recorded perf trajectory. CI runs
this on every push; paste the tables into README.md's Performance
section when refreshing the recorded numbers.
"""
import json
import sys


def render_kv(doc: dict) -> None:
    """The `bench kv` artifact: fig-11 app-level KV throughput."""
    budget = doc.get("budget", "?")
    jobs = doc.get("jobs")
    suffix = f", jobs: {jobs:.0f}" if jobs is not None else ""
    print(f"### Fig-11 KV tier: one-sided vs SEND-RPC (budget: {budget}{suffix})\n")
    print(
        "| clients | servers | wall ms | 1-sided Mops | RPC Mops "
        "| 1-sided p99 µs | RPC p99 µs | 1-sided srv CPU | RPC srv CPU "
        "| writes coalesced |"
    )
    print("|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|")
    for p in doc.get("points", []):
        print(
            "| {clients:.0f} | {servers:.0f} | {wall_ms:.1f} | {om:.3f} | {rm:.3f} "
            "| {op99:.1f} | {rp99:.1f} | {ocpu:.2f} | {rcpu:.2f} | {wc:.0f} |".format(
                clients=p.get("clients", 0),
                servers=p.get("servers", 0),
                wall_ms=p.get("wall_ms", 0),
                om=p.get("onesided_mops", 0) or 0,
                rm=p.get("rpc_mops", 0) or 0,
                op99=p.get("onesided_p99_us", 0) or 0,
                rp99=p.get("rpc_p99_us", 0) or 0,
                ocpu=p.get("onesided_server_cpu", 0) or 0,
                rcpu=p.get("rpc_server_cpu", 0) or 0,
                wc=p.get("writes_coalesced", 0) or 0,
            )
        )
    total_ops = doc.get("total_ops", 0)
    total_wall = doc.get("total_wall_ms", 0)
    ops_s = doc.get("ops_per_sec", 0) or 0
    print(
        f"\nTotal: {total_ops:.0f} app-level KV ops in {total_wall:.0f} ms "
        f"({ops_s:.0f} sim-ops/sec of host wall clock)."
    )


def render_churn(doc: dict) -> None:
    """The `bench churn` artifact: fig-12 elastic-control-plane sweep."""
    budget = doc.get("budget", "?")
    jobs = doc.get("jobs")
    suffix = f", jobs: {jobs:.0f}" if jobs is not None else ""
    print(f"### Fig-12 tenant churn: warm (QP reuse + lazy leases) vs cold (budget: {budget}{suffix})\n")
    print(
        "| conns | hosts | wall ms | warm kcps | cold kcps "
        "| warm p99 TTFB µs | cold p99 TTFB µs | warm B/vQPN | cold B/vQPN "
        "| QPs reused | full handshakes | lease batches |"
    )
    print("|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|")
    for p in doc.get("points", []):
        print(
            "| {conns:.0f} | {hosts:.0f} | {wall_ms:.1f} | {wk:.1f} | {ck:.1f} "
            "| {wp99:.1f} | {cp99:.1f} | {wmem:.0f} | {cmem:.0f} "
            "| {reused:.0f} | {hs:.0f} | {lb:.0f} |".format(
                conns=p.get("conns", 0),
                hosts=p.get("hosts", 0),
                wall_ms=p.get("wall_ms", 0),
                wk=p.get("warm_setup_kcps", 0) or 0,
                ck=p.get("cold_setup_kcps", 0) or 0,
                wp99=p.get("warm_p99_ttfb_us", 0) or 0,
                cp99=p.get("cold_p99_ttfb_us", 0) or 0,
                wmem=p.get("warm_mem_per_vqpn", 0) or 0,
                cmem=p.get("cold_mem_per_vqpn", 0) or 0,
                reused=p.get("qp_reused", 0) or 0,
                hs=p.get("handshakes_full", 0) or 0,
                lb=p.get("lease_batches", 0) or 0,
            )
        )
    total_conns = doc.get("total_conns", 0)
    total_wall = doc.get("total_wall_ms", 0)
    cps = doc.get("conns_per_sec", 0) or 0
    print(
        f"\nTotal: {total_conns:.0f} tenant setups in {total_wall:.0f} ms "
        f"({cps:.0f} sim-conns/sec of host wall clock)."
    )


def render_incast(doc: dict) -> None:
    """The `bench incast` artifact: fig-13 Clos congestion sweep."""
    budget = doc.get("budget", "?")
    jobs = doc.get("jobs")
    suffix = f", jobs: {jobs:.0f}" if jobs is not None else ""
    print(
        f"### Fig-13 Clos incast: goodput + mouse p99 FCT vs oversubscription "
        f"(budget: {budget}{suffix})\n"
    )
    print(
        "| oversub | wall ms | dcqcn Gb/s | no-cc Gb/s | pfc Gb/s "
        "| dcqcn p99 µs | no-cc p99 µs | pfc p99 µs "
        "| ECN marks | switch drops | pauses | retransmits |"
    )
    print("|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|")
    for p in doc.get("points", []):
        print(
            "| {oversub:.0f} | {wall_ms:.1f} | {dg:.2f} | {ng:.2f} | {pg:.2f} "
            "| {dp99:.1f} | {np99:.1f} | {pp99:.1f} "
            "| {marks:.0f} | {drops:.0f} | {pauses:.0f} | {rtx:.0f} |".format(
                oversub=p.get("oversub", 0),
                wall_ms=p.get("wall_ms", 0),
                dg=p.get("dcqcn_goodput_gbps", 0) or 0,
                ng=p.get("nocc_goodput_gbps", 0) or 0,
                pg=p.get("pfc_goodput_gbps", 0) or 0,
                dp99=p.get("dcqcn_p99_fct_us", 0) or 0,
                np99=p.get("nocc_p99_fct_us", 0) or 0,
                pp99=p.get("pfc_p99_fct_us", 0) or 0,
                marks=p.get("ecn_marks", 0) or 0,
                drops=p.get("switch_drops", 0) or 0,
                pauses=p.get("pauses", 0) or 0,
                rtx=p.get("retransmits", 0) or 0,
            )
        )
    total_events = doc.get("total_events", 0)
    total_wall = doc.get("total_wall_ms", 0)
    eps = doc.get("events_per_sec", 0) or 0
    print(
        f"\nTotal: {total_events:.0f} events in {total_wall:.0f} ms "
        f"({eps:.0f} events/sec aggregate)."
    )


def render_failover(doc: dict) -> None:
    """The `bench failover` artifact: fig-14 survivable-Clos storm."""
    budget = doc.get("budget", "?")
    jobs = doc.get("jobs")
    shards = doc.get("shards")
    sharded = shards is not None and shards > 1
    suffix = f", jobs: {jobs:.0f}" if jobs is not None else ""
    if sharded:
        suffix += f", shards: {shards:.0f}"
    print(
        f"### Fig-14 failover storm: goodput through a spine death, "
        f"repair vs repath-off (budget: {budget}{suffix})\n"
    )
    head = (
        "| mode | wall ms | pre Gb/s | dip Gb/s | post Gb/s | p99 FCT µs "
        "| repaths | epochs | QPs healed | heal give-ups | retry-exceeded "
        "| retransmits | blackhole drops | flows alive |"
    )
    rule = "|---|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|"
    if sharded:
        head += " sharded ms | speedup |"
        rule += "---:|---:|"
    print(head)
    print(rule)
    for p in doc.get("points", []):
        row = (
            "| {mode} | {wall_ms:.1f} | {pre:.2f} | {dip:.2f} | {post:.2f} "
            "| {p99:.1f} | {rp:.0f} | {ep:.0f} | {heal:.0f} | {gu:.0f} "
            "| {rx:.0f} | {rtx:.0f} | {bh:.0f} | {alive:.0f} |".format(
                mode=p.get("mode", "?"),
                wall_ms=p.get("wall_ms", 0),
                pre=p.get("pre_gbps", 0) or 0,
                dip=p.get("dip_gbps", 0) or 0,
                post=p.get("post_gbps", 0) or 0,
                p99=p.get("p99_fct_us", 0) or 0,
                rp=p.get("repaths", 0) or 0,
                ep=p.get("route_epoch", 0) or 0,
                heal=p.get("qp_reestablished", 0) or 0,
                gu=p.get("heal_giveups", 0) or 0,
                rx=p.get("retry_exceeded", 0) or 0,
                rtx=p.get("retransmits", 0) or 0,
                bh=p.get("blackhole_drops", 0) or 0,
                alive=p.get("flows_alive", 0) or 0,
            )
        )
        if sharded:
            row += " {sw:.1f} | {sp:.2f}x |".format(
                sw=p.get("sharded_wall_ms", 0) or 0,
                sp=p.get("speedup", 0) or 0,
            )
        print(row)
    total_events = doc.get("total_events", 0)
    total_wall = doc.get("total_wall_ms", 0)
    eps = doc.get("events_per_sec", 0) or 0
    print(
        f"\nTotal: {total_events:.0f} events in {total_wall:.0f} ms "
        f"({eps:.0f} events/sec aggregate)."
    )
    if sharded:
        swall = doc.get("total_sharded_wall_ms", 0) or 0
        ident = doc.get("identical_series")
        verdict = (
            "byte-identical to serial"
            if ident
            else "**SERIES MISMATCH — determinism bug**"
        )
        print(
            f"\nSharded x{shards:.0f}: {swall:.0f} ms "
            f"({total_wall / swall if swall else 0:.2f}x aggregate speedup); "
            f"output series {verdict}."
        )


def render_fig9(doc: dict) -> None:
    """The `bench fig9` artifact (PR-3/PR-5/PR-8 trajectory). With
    `--shards N` (PR 8) each point carries sharded wall/speedup columns
    and the doc carries the `identical_series` byte-identity bit plus an
    optional `shard_sweep` (spliced in by bench_pr8.sh)."""
    budget = doc.get("budget", "?")
    jobs = doc.get("jobs")
    shards = doc.get("shards")
    sharded = shards is not None and shards > 1
    suffix = f", jobs: {jobs:.0f}" if jobs is not None else ""
    if sharded:
        suffix += f", shards: {shards:.0f}"
    print(f"### Fig-9 wall clock per connection count (budget: {budget}{suffix})\n")
    head = "| conns | servers | wall ms | events | events/sec | adaptive Gb/s | rc-only Gb/s |"
    rule = "|---:|---:|---:|---:|---:|---:|---:|"
    if sharded:
        head += " sharded ms | sharded ev/s | speedup |"
        rule += "---:|---:|---:|"
    print(head)
    print(rule)
    for p in doc.get("points", []):
        row = (
            "| {conns:.0f} | {servers:.0f} | {wall_ms:.1f} | {events:.0f} "
            "| {eps:.0f} | {ag:.2f} | {rg:.2f} |".format(
                conns=p.get("conns", 0),
                servers=p.get("servers", 0),
                wall_ms=p.get("wall_ms", 0),
                events=p.get("events", 0),
                eps=p.get("events_per_sec", 0) or 0,
                ag=p.get("adaptive_gbps", 0) or 0,
                rg=p.get("rc_only_gbps", 0) or 0,
            )
        )
        if sharded:
            row += " {sw:.1f} | {seps:.0f} | {sp:.2f}x |".format(
                sw=p.get("sharded_wall_ms", 0) or 0,
                seps=p.get("sharded_events_per_sec", 0) or 0,
                sp=p.get("speedup", 0) or 0,
            )
        print(row)
    total_events = doc.get("total_events", 0)
    total_wall = doc.get("total_wall_ms", 0)
    eps = doc.get("events_per_sec", 0) or 0
    print(
        f"\nTotal: {total_events:.0f} events in {total_wall:.0f} ms "
        f"({eps:.0f} events/sec aggregate)."
    )
    if sharded:
        swall = doc.get("total_sharded_wall_ms", 0) or 0
        seps = doc.get("sharded_events_per_sec", 0) or 0
        ident = doc.get("identical_series")
        verdict = (
            "byte-identical to serial"
            if ident
            else "**SERIES MISMATCH — determinism bug**"
        )
        print(
            f"\nSharded x{shards:.0f}: {swall:.0f} ms ({seps:.0f} events/sec, "
            f"{total_wall / swall if swall else 0:.2f}x aggregate speedup); "
            f"output series {verdict}."
        )
    sweep = doc.get("shard_sweep")
    if sweep:
        print(
            "\n### Scheduler events/sec vs shard count (`bench simstep --shards`)\n\n"
            "| shards | QP pairs | window | sim ms | events | best events/sec | wall ms |\n"
            "|---:|---:|---:|---:|---:|---:|---:|"
        )
        for s in sweep:
            print(
                "| {shards:.0f} | {pairs:.0f} | {window:.0f} | {sim_ms:.0f} "
                "| {events:.0f} | {eps:.0f} | {wall:.1f} |".format(
                    shards=s.get("shards", 1),
                    pairs=s.get("pairs", 0),
                    window=s.get("window", 0),
                    sim_ms=s.get("sim_ms", 0),
                    events=s.get("events", 0),
                    eps=s.get("events_per_sec", 0) or 0,
                    wall=s.get("wall_ms", 0) or 0,
                )
            )
    pump = doc.get("pump")
    if pump:
        print(
            "\n### Daemon data-plane throughput (`bench pump`)\n\n"
            "| conns | window | msg bytes | sim ms | ops | best ops/sec |\n"
            "|---:|---:|---:|---:|---:|---:|\n"
            "| {conns:.0f} | {window:.0f} | {msg:.0f} | {sim_ms:.0f} "
            "| {ops:.0f} | {ops_s:.0f} |".format(
                conns=pump.get("conns", 0),
                window=pump.get("window", 0),
                msg=pump.get("msg_bytes", 0),
                sim_ms=pump.get("sim_ms", 0),
                ops=pump.get("ops", 0),
                ops_s=pump.get("ops_per_sec", 0) or 0,
            )
        )
    ss = doc.get("simstep")
    if ss:
        print(
            "\n### Raw scheduler throughput (`bench simstep`)\n\n"
            "| QP pairs | window | msg bytes | sim ms | events | best events/sec |\n"
            "|---:|---:|---:|---:|---:|---:|\n"
            "| {pairs:.0f} | {window:.0f} | {msg:.0f} | {sim_ms:.0f} "
            "| {events:.0f} | {eps:.0f} |".format(
                pairs=ss.get("pairs", 0),
                window=ss.get("window", 0),
                msg=ss.get("msg_bytes", 0),
                sim_ms=ss.get("sim_ms", 0),
                events=ss.get("events", 0),
                eps=ss.get("events_per_sec", 0) or 0,
            )
        )


def render(path: str) -> bool:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        return False

    print(f"## {path}\n")
    mode = doc.get("mode")
    if mode == "kv":
        render_kv(doc)
    elif mode == "churn":
        render_churn(doc)
    elif mode == "incast":
        render_incast(doc)
    elif mode == "failover":
        render_failover(doc)
    else:
        render_fig9(doc)
    return True


def main() -> int:
    paths = (
        sys.argv[1:]
        if len(sys.argv) > 1
        else [
            "BENCH_PR5.json",
            "BENCH_PR6.json",
            "BENCH_PR7.json",
            "BENCH_PR8.json",
            "BENCH_PR9.json",
            "BENCH_PR10.json",
        ]
    )
    ok = True
    for i, path in enumerate(paths):
        if i:
            print()
        ok = render(path) and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
