#!/usr/bin/env python3
"""Render one or more BENCH_*.json artifacts (from `rdmavisor bench
fig9` / bench_pr3.sh / bench_pr5.sh) as the markdown perf tables
README.md quotes. Stdlib only.

    python3 scripts/perf_table.py BENCH_PR3.json BENCH_PR5.json > BENCH_PR5.md

Each input gets its own section (headed by the file name), so one
markdown artifact can carry the whole recorded perf trajectory. CI runs
this on every push; paste the tables into README.md's Performance
section when refreshing the recorded numbers.
"""
import json
import sys


def render(path: str) -> bool:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        return False

    budget = doc.get("budget", "?")
    jobs = doc.get("jobs")
    points = doc.get("points", [])
    print(f"## {path}\n")
    suffix = f", jobs: {jobs:.0f}" if jobs is not None else ""
    print(f"### Fig-9 wall clock per connection count (budget: {budget}{suffix})\n")
    print("| conns | servers | wall ms | events | events/sec | adaptive Gb/s | rc-only Gb/s |")
    print("|---:|---:|---:|---:|---:|---:|---:|")
    for p in points:
        print(
            "| {conns:.0f} | {servers:.0f} | {wall_ms:.1f} | {events:.0f} "
            "| {eps:.0f} | {ag:.2f} | {rg:.2f} |".format(
                conns=p.get("conns", 0),
                servers=p.get("servers", 0),
                wall_ms=p.get("wall_ms", 0),
                events=p.get("events", 0),
                eps=p.get("events_per_sec", 0) or 0,
                ag=p.get("adaptive_gbps", 0) or 0,
                rg=p.get("rc_only_gbps", 0) or 0,
            )
        )
    total_events = doc.get("total_events", 0)
    total_wall = doc.get("total_wall_ms", 0)
    eps = doc.get("events_per_sec", 0) or 0
    print(
        f"\nTotal: {total_events:.0f} events in {total_wall:.0f} ms "
        f"({eps:.0f} events/sec aggregate)."
    )
    pump = doc.get("pump")
    if pump:
        print(
            "\n### Daemon data-plane throughput (`bench pump`)\n\n"
            "| conns | window | msg bytes | sim ms | ops | best ops/sec |\n"
            "|---:|---:|---:|---:|---:|---:|\n"
            "| {conns:.0f} | {window:.0f} | {msg:.0f} | {sim_ms:.0f} "
            "| {ops:.0f} | {ops_s:.0f} |".format(
                conns=pump.get("conns", 0),
                window=pump.get("window", 0),
                msg=pump.get("msg_bytes", 0),
                sim_ms=pump.get("sim_ms", 0),
                ops=pump.get("ops", 0),
                ops_s=pump.get("ops_per_sec", 0) or 0,
            )
        )
    ss = doc.get("simstep")
    if ss:
        print(
            "\n### Raw scheduler throughput (`bench simstep`)\n\n"
            "| QP pairs | window | msg bytes | sim ms | events | best events/sec |\n"
            "|---:|---:|---:|---:|---:|---:|\n"
            "| {pairs:.0f} | {window:.0f} | {msg:.0f} | {sim_ms:.0f} "
            "| {events:.0f} | {eps:.0f} |".format(
                pairs=ss.get("pairs", 0),
                window=ss.get("window", 0),
                msg=ss.get("msg_bytes", 0),
                sim_ms=ss.get("sim_ms", 0),
                events=ss.get("events", 0),
                eps=ss.get("events_per_sec", 0) or 0,
            )
        )
    return True


def main() -> int:
    paths = sys.argv[1:] if len(sys.argv) > 1 else ["BENCH_PR5.json"]
    ok = True
    for i, path in enumerate(paths):
        if i:
            print()
        ok = render(path) and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
