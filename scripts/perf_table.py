#!/usr/bin/env python3
"""Render BENCH_PR3.json (from `rdmavisor bench fig9` / bench_pr3.sh) as
the markdown perf table README.md quotes. Stdlib only.

    python3 scripts/perf_table.py BENCH_PR3.json > BENCH_PR3.md

CI runs this on every push so the artifact carries both the raw JSON and
the human-readable table; paste the table into README.md's Performance
section when refreshing the recorded numbers.
"""
import json
import sys


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_PR3.json"
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        return 1

    budget = doc.get("budget", "?")
    points = doc.get("points", [])
    print(f"### Fig-9 wall clock per connection count (budget: {budget})\n")
    print("| conns | servers | wall ms | events | events/sec | adaptive Gb/s | rc-only Gb/s |")
    print("|---:|---:|---:|---:|---:|---:|---:|")
    for p in points:
        print(
            "| {conns:.0f} | {servers:.0f} | {wall_ms:.1f} | {events:.0f} "
            "| {eps:.0f} | {ag:.2f} | {rg:.2f} |".format(
                conns=p.get("conns", 0),
                servers=p.get("servers", 0),
                wall_ms=p.get("wall_ms", 0),
                events=p.get("events", 0),
                eps=p.get("events_per_sec", 0) or 0,
                ag=p.get("adaptive_gbps", 0) or 0,
                rg=p.get("rc_only_gbps", 0) or 0,
            )
        )
    total_events = doc.get("total_events", 0)
    total_wall = doc.get("total_wall_ms", 0)
    eps = doc.get("events_per_sec", 0) or 0
    print(
        f"\nTotal: {total_events:.0f} events in {total_wall:.0f} ms "
        f"({eps:.0f} events/sec aggregate)."
    )
    ss = doc.get("simstep")
    if ss:
        print(
            "\n### Raw scheduler throughput (`bench simstep`)\n\n"
            "| QP pairs | window | msg bytes | sim ms | events | best events/sec |\n"
            "|---:|---:|---:|---:|---:|---:|\n"
            "| {pairs:.0f} | {window:.0f} | {msg:.0f} | {sim_ms:.0f} "
            "| {events:.0f} | {eps:.0f} |".format(
                pairs=ss.get("pairs", 0),
                window=ss.get("window", 0),
                msg=ss.get("msg_bytes", 0),
                sim_ms=ss.get("sim_ms", 0),
                events=ss.get("events", 0),
                eps=ss.get("events_per_sec", 0) or 0,
            )
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
