//! Integration: baseline systems vs RaaS — the paper's comparative claims,
//! asserted as tests (the figure harnesses print the full sweeps).

use rdmavisor::fabric::time::Ns;
use rdmavisor::workload::scenarios::{
    locked_random_read, naive_random_read, raas_random_read, ScenarioCfg,
};

fn cfg(conns: usize, ms: u64) -> ScenarioCfg {
    let mut c = ScenarioCfg::default();
    c.conns = conns;
    c.duration = Ns::from_ms(ms);
    c.warmup_frac = 0.4;
    c
}

#[test]
fn fig5_claim_naive_drops_raas_stable() {
    let naive_low = naive_random_read(&cfg(100, 30));
    let naive_high = naive_random_read(&cfg(1000, 30));
    let raas_low = raas_random_read(&cfg(100, 30));
    let raas_high = raas_random_read(&cfg(1000, 30));

    // "the throughput of naive RDMA starts to drop when the size of
    //  connections exceeds 400"
    assert!(
        naive_high.gbps < naive_low.gbps * 0.6,
        "naive should collapse: {:.1} -> {:.1} Gb/s",
        naive_low.gbps,
        naive_high.gbps
    );
    // "RaaS shows stable performance"
    assert!(
        raas_high.gbps > raas_low.gbps * 0.9,
        "raas should be stable: {:.1} -> {:.1} Gb/s",
        raas_low.gbps,
        raas_high.gbps
    );
    // and RaaS beats naive at scale
    assert!(raas_high.gbps > naive_high.gbps * 1.5);
}

#[test]
fn fig5_mechanism_is_the_nic_cache() {
    let naive = naive_random_read(&cfg(1000, 30));
    let raas = raas_random_read(&cfg(1000, 30));
    assert!(naive.cache_hit_rate < 0.6, "naive thrashes: {}", naive.cache_hit_rate);
    assert!(raas.cache_hit_rate > 0.95, "raas stays hot: {}", raas.cache_hit_rate);
}

#[test]
fn fig6_claim_lock_contention_ordering() {
    // 512 B reads, 12 worker threads: the q=6 lock domain serializes
    let mut c = cfg(12, 10);
    c.msg_bytes = 512;
    c.window = 4;
    let raas = raas_random_read(&c);
    let q3 = locked_random_read(&c, 3);
    let q6 = locked_random_read(&c, 6);
    assert!(q6.mops < q3.mops, "q6 {:.2} !< q3 {:.2}", q6.mops, q3.mops);
    assert!(raas.mops >= q3.mops * 0.95, "raas {:.2} vs q3 {:.2}", raas.mops, q3.mops);
    assert!(q6.lock_wait_ms > 0.0);
}

#[test]
fn fig7_claim_memory_scaling() {
    let apps = |n: u32| {
        let mut c = cfg((n * 16) as usize, 8);
        c.apps = n;
        c
    };
    let n1 = naive_random_read(&apps(1));
    let n8 = naive_random_read(&apps(8));
    let r1 = raas_random_read(&apps(1));
    let r8 = raas_random_read(&apps(8));
    let naive_growth = n8.mem_bytes as f64 / n1.mem_bytes as f64;
    let raas_growth = r8.mem_bytes as f64 / r1.mem_bytes as f64;
    assert!(naive_growth > 6.0, "naive mem should ~8x: {naive_growth:.2}");
    assert!(raas_growth < naive_growth / 2.0, "raas sublinear: {raas_growth:.2}");
}

#[test]
fn fig8_claim_cpu_scaling() {
    let apps = |n: u32| {
        let mut c = cfg((n * 16) as usize, 8);
        c.apps = n;
        c
    };
    let n1 = naive_random_read(&apps(1));
    let n8 = naive_random_read(&apps(8));
    let r1 = raas_random_read(&apps(1));
    let r8 = raas_random_read(&apps(8));
    let naive_growth = n8.cpu_cores / n1.cpu_cores;
    let raas_growth = r8.cpu_cores / r1.cpu_cores;
    assert!(naive_growth > 6.0, "naive cpu ~8x: {naive_growth:.2}");
    assert!(raas_growth < 1.5, "raas cpu ~flat: {raas_growth:.2}");
}

#[test]
fn runs_are_deterministic() {
    let a = naive_random_read(&cfg(300, 8));
    let b = naive_random_read(&cfg(300, 8));
    assert_eq!(a.gbps, b.gbps);
    assert_eq!(a.ops, b.ops);
    let a = raas_random_read(&cfg(300, 8));
    let b = raas_random_read(&cfg(300, 8));
    assert_eq!(a.gbps, b.gbps);
}
