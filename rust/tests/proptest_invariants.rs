//! Property-based tests on coordinator invariants (routing, batching,
//! rings, cache, conservation) using the in-crate prop harness.

use rdmavisor::fabric::cache::{IcmCache, IcmKey};
use rdmavisor::fabric::fault::FaultConfig;
use rdmavisor::fabric::sim::{FabricConfig, Sim};
use rdmavisor::fabric::time::Ns;
use rdmavisor::fabric::types::{NodeId, QpTransport, Verb, WcStatus};
use rdmavisor::raas::api::{Flags, RaasError};
use rdmavisor::raas::daemon::{
    connect_via, disconnect_via, Daemon, DaemonConfig, Delivery, WindowToken,
};
use rdmavisor::raas::migrate::{decide, DestState, MigrationConfig, Reassembler};
use rdmavisor::raas::opslab::{unpack_op_slot, untracked_wr_id, OpSlab};
use rdmavisor::raas::shmem::SpscRing;
use rdmavisor::raas::transport::{HostLoad, Selector, SelectorConfig};
use rdmavisor::raas::vqpn::{pack_wr_id, unpack_seq, unpack_vqpn, ConnTable, Vqpn};
use rdmavisor::util::prop::{check, Gen, U64Range, UsizeRange, VecGen};
use rdmavisor::util::rng::Rng;

#[test]
fn prop_wr_id_packing_roundtrips() {
    // ∀ (vqpn, seq): unpack(pack(vqpn, seq)) == (vqpn, seq)
    check(11, 500, &U64Range(0, u64::MAX), |&x| {
        let vqpn = Vqpn(x as u32);
        let seq = (x >> 32) as u32;
        let id = pack_wr_id(vqpn, seq);
        if unpack_vqpn(id) == vqpn && unpack_seq(id) == seq {
            Ok(())
        } else {
            Err(format!("roundtrip failed for {x:#x}"))
        }
    });
}

#[test]
fn prop_op_slab_wr_ids_roundtrip_and_never_collide() {
    // Random insert/take sequences against the daemon's in-flight op
    // slab. Invariants after every step:
    //  - the wr_id minted for a live op decodes back to it (get/take
    //    resolve the payload inserted under it) and carries its vQPN in
    //    the low 32 bits;
    //  - live wr_ids are pairwise distinct AND distinct from every
    //    wr_id whose op completed (slot reuse bumps the generation, so
    //    a recycled slot's new wr_id can never collide with the old);
    //  - completed (stale) wr_ids and untracked (null-slot) wr_ids
    //    never resolve to a live op.
    let gen = VecGen { elem: U64Range(0, 999), min_len: 1, max_len: 250 };
    check(17, 60, &gen, |ops: &Vec<u64>| {
        let mut slab: OpSlab<u64> = OpSlab::new();
        let mut live: Vec<(u64, u64)> = Vec::new(); // (wr_id, payload)
        let mut dead: Vec<u64> = Vec::new();
        let mut payload = 0u64;
        for (i, &op) in ops.iter().enumerate() {
            if op < 600 || live.is_empty() {
                payload += 1;
                let vqpn = Vqpn((op % 50) as u32);
                let id = slab.insert(vqpn, payload);
                if unpack_vqpn(id) != vqpn {
                    return Err(format!("wr_id {id:#x} lost its vqpn {vqpn:?}"));
                }
                if unpack_op_slot(id).is_none() {
                    return Err(format!("live op minted the null slot: {id:#x}"));
                }
                live.push((id, payload));
            } else {
                let idx = (op as usize + i) % live.len();
                let (id, want) = live.swap_remove(idx);
                match slab.take(id) {
                    Some(got) if got == want => {}
                    other => return Err(format!("take({id:#x}) -> {other:?}, want {want}")),
                }
                dead.push(id);
            }
            if slab.len() != live.len() {
                return Err(format!("len {} != live {}", slab.len(), live.len()));
            }
            let mut seen = std::collections::HashSet::new();
            for &(id, want) in &live {
                if !seen.insert(id) {
                    return Err(format!("duplicate live wr_id {id:#x}"));
                }
                match slab.get(id) {
                    Some(&got) if got == want => {}
                    other => return Err(format!("get({id:#x}) -> {other:?}, want {want}")),
                }
            }
            for &id in &dead {
                if seen.contains(&id) {
                    return Err(format!("completed wr_id {id:#x} collides with a live op"));
                }
                if slab.get(id).is_some() || slab.take(id).is_some() {
                    return Err(format!("stale wr_id {id:#x} resolved to a live op"));
                }
            }
            if slab.get(untracked_wr_id(Vqpn(op as u32))).is_some() {
                return Err("untracked wr_id resolved to a live op".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_conn_table_routing_total() {
    // open/close sequences never mis-route: every live vqpn looks up to its
    // own entry; closed vqpns never resolve.
    let gen = VecGen { elem: U64Range(0, 99), min_len: 1, max_len: 200 };
    check(13, 100, &gen, |ops: &Vec<u64>| {
        let mut t = ConnTable::new();
        let mut live: Vec<(Vqpn, u32)> = Vec::new();
        for (i, &op) in ops.iter().enumerate() {
            if op < 60 || live.is_empty() {
                let app = (op % 7) as u32;
                let v = t.open(app, NodeId((op % 3) as u32), Vqpn(0));
                live.push((v, app));
            } else {
                let idx = (op as usize + i) % live.len();
                let (v, _) = live.swap_remove(idx);
                if !t.close(v) {
                    return Err(format!("close of live conn {v:?} failed"));
                }
            }
            // routing totality check
            for (v, app) in &live {
                match t.lookup(*v) {
                    Some(e) if e.app == *app => {}
                    other => return Err(format!("lookup {v:?} -> {other:?}")),
                }
            }
        }
        if t.active() != live.len() {
            return Err(format!("active {} != live {}", t.active(), live.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_spsc_ring_conserves_fifo() {
    // any interleaving of pushes/pops preserves FIFO and loses nothing
    let gen = VecGen { elem: U64Range(0, 1), min_len: 1, max_len: 400 };
    check(17, 60, &gen, |ops: &Vec<u64>| {
        let ring = SpscRing::new(64);
        let mut next_in = 0u64;
        let mut next_out = 0u64;
        for &op in ops {
            if op == 0 {
                if ring.push(next_in).is_ok() {
                    next_in += 1;
                } else if ring.len() != 64 {
                    return Err("push failed but ring not full".into());
                }
            } else if let Some(v) = ring.pop() {
                if v != next_out {
                    return Err(format!("FIFO violated: got {v}, want {next_out}"));
                }
                next_out += 1;
            }
        }
        // drain
        while let Some(v) = ring.pop() {
            if v != next_out {
                return Err("drain order".into());
            }
            next_out += 1;
        }
        if next_out != next_in {
            return Err(format!("lost items: in {next_in} out {next_out}"));
        }
        Ok(())
    });
}

#[test]
fn prop_lru_cache_never_exceeds_capacity_and_keeps_hot_keys() {
    let gen = VecGen { elem: U64Range(0, 600), min_len: 10, max_len: 800 };
    check(19, 60, &gen, |touches: &Vec<u64>| {
        let mut c = IcmCache::new(128);
        for &k in touches {
            c.touch(IcmKey::Qpc(k as u32));
            if c.len() > 128 {
                return Err("capacity exceeded".into());
            }
        }
        // most-recently-touched key must be resident
        if let Some(&last) = touches.last() {
            if !c.contains(&IcmKey::Qpc(last as u32)) {
                return Err("MRU key evicted".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_selector_honors_user_pins() {
    // ∀ (len, pinned transport+verb combo): a Table-1-legal pin is
    // returned verbatim; an illegal pin is rejected — the selector never
    // substitutes its own preference for the user's.
    struct PinCase;
    impl Gen<(u64, u8, u8)> for PinCase {
        fn gen(&self, rng: &mut Rng) -> (u64, u8, u8) {
            (
                U64Range(0, 2 << 20).gen(rng),
                UsizeRange(0, 2).gen(rng) as u8, // transport index
                UsizeRange(0, 2).gen(rng) as u8, // verb index
            )
        }
    }
    check(31, 400, &PinCase, |&(len, t, v)| {
        let (tf, transport) = match t {
            0 => (Flags::RC, QpTransport::Rc),
            1 => (Flags::UC, QpTransport::Uc),
            _ => (Flags::UD, QpTransport::Ud),
        };
        let (vf, verb) = match v {
            0 => (Flags::SEND, Verb::Send),
            1 => (Flags::WRITE, Verb::Write),
            _ => (Flags::READ, Verb::Read),
        };
        let legal = rdmavisor::fabric::types::supports(transport, verb);
        let mut s = Selector::new(SelectorConfig::default());
        // migration preference must NOT override an explicit pin
        let got = s.choose_adaptive(len, tf | vf, HostLoad::default(), HostLoad::default(), 4096, true);
        match (legal, got) {
            (true, Ok(c)) if c.transport == transport && c.verb == verb => Ok(()),
            (false, Err(_)) => Ok(()),
            (_, r) => Err(format!("pin ({transport},{verb}) len {len} -> {r:?}")),
        }
    });
}

#[test]
fn prop_selector_hysteresis_never_flaps_in_band() {
    // After any initial classification, message sizes inside the closed
    // hysteresis band [t(1-h), t(1+h)] never flip the size class.
    let gen = VecGen { elem: U64Range(3072, 5120), min_len: 2, max_len: 60 };
    check(37, 120, &gen, |lens: &Vec<u64>| {
        let cfg = SelectorConfig::default(); // t = 4096, h = 0.25
        let mut s = Selector::new(cfg);
        let idle = HostLoad::default();
        let first = s
            .choose(lens[0], Flags::default(), idle, idle, 4096)
            .map_err(|e| e.to_string())?
            .verb;
        for &len in &lens[1..] {
            // 3072..=5120 ⊆ [4096·0.75, 4096·1.25] — always in the band
            let got = s
                .choose(len, Flags::default(), idle, idle, 4096)
                .map_err(|e| e.to_string())?
                .verb;
            if got != first {
                return Err(format!("flapped {first:?} -> {got:?} at len {len}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_migration_decision_monotone_in_pressure() {
    // ∀ state, p1 ≤ p2: pressure only ever pushes *toward* UD — if the
    // decision at p1 already leaves RC, the decision at p2 does too, and
    // if p2 stays RC then p1 must as well. Plus: inside the hysteresis
    // band the decision is the identity.
    struct Pressures;
    impl Gen<(f64, f64, u8)> for Pressures {
        fn gen(&self, rng: &mut Rng) -> (f64, f64, u8) {
            let a = U64Range(0, 2000).gen(rng) as f64 / 1000.0;
            let b = U64Range(0, 2000).gen(rng) as f64 / 1000.0;
            (a.min(b), a.max(b), UsizeRange(0, 2).gen(rng) as u8)
        }
    }
    fn toward_ud(s: DestState) -> u8 {
        match s {
            DestState::Rc => 0,
            DestState::DrainingToUd | DestState::Ud => 1,
        }
    }
    check(41, 500, &Pressures, |&(p1, p2, st)| {
        let cfg = MigrationConfig::default();
        let state = match st {
            0 => DestState::Rc,
            1 => DestState::DrainingToUd,
            _ => DestState::Ud,
        };
        let d1 = decide(state, p1, &cfg);
        let d2 = decide(state, p2, &cfg);
        if toward_ud(d1) > toward_ud(d2) {
            return Err(format!("{state:?}: p1={p1} -> {d1:?} but p2={p2} -> {d2:?}"));
        }
        // band identity: strictly inside (exit_ud, enter_ud) nothing moves
        for &p in &[p1, p2] {
            if p > cfg.exit_ud && p < cfg.enter_ud && decide(state, p, &cfg) != state {
                return Err(format!("{state:?} moved inside the band at p={p}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_daemon_batching_conserves_ops() {
    // for any op count and batch_max, every submitted read completes
    // exactly once and every lease is returned.
    struct Cfg;
    impl Gen<(usize, usize)> for Cfg {
        fn gen(&self, rng: &mut Rng) -> (usize, usize) {
            (UsizeRange(1, 120).gen(rng), UsizeRange(1, 64).gen(rng))
        }
    }
    check(23, 25, &Cfg, |&(ops, batch_max)| {
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 2;
        fcfg.sq_depth = 4096;
        let mut sim = Sim::new(fcfg);
        let dcfg = DaemonConfig { batch_max, ..DaemonConfig::default() };
        let mut daemons = vec![
            Daemon::start(&mut sim, NodeId(0), dcfg.clone()),
            Daemon::start(&mut sim, NodeId(1), dcfg),
        ];
        let sapp = daemons[1].register_app();
        daemons[1].listen(sapp, 1);
        let app = daemons[0].register_app();
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        for i in 0..ops {
            daemons[0]
                .read(&mut sim, conn, 4096, (i * 4096) as u64 % (1 << 20), i as u64)
                .map_err(|e| format!("read {i}: {e}"))?;
        }
        for _ in 0..3_000_000 {
            for d in daemons.iter_mut() {
                d.pump(&mut sim);
            }
            if sim.step().is_none() {
                for d in daemons.iter_mut() {
                    d.pump(&mut sim);
                }
                if sim.pending_events() == 0 {
                    break;
                }
            }
        }
        let mut completions = 0;
        while let Some(d) = daemons[0].recv_zero_copy(&mut sim, app) {
            if matches!(d, Delivery::OpComplete { ok: true, .. }) {
                completions += 1;
            }
        }
        if completions != ops {
            return Err(format!("ops={ops} batch={batch_max}: {completions} completed"));
        }
        if daemons[0].pool.leased_bytes != 0 {
            return Err(format!("leaked leases: {} bytes", daemons[0].pool.leased_bytes));
        }
        Ok(())
    });
}

#[test]
fn prop_window_lease_accounting_balances() {
    // ∀ random interleavings of register / window READ / window WRITE /
    // flush / release / plain READ:
    //  - registering a window takes EXACTLY one standing lease (no
    //    double-lease, ever);
    //  - repeat READs/WRITEs through a live window never move the pool's
    //    lease ledger at submit time (the tentpole claim: per-op lease
    //    machinery is bypassed);
    //  - a released token always fails with StaleWindow, even after its
    //    slot is recycled by a later register;
    //  - after quiescing and releasing everything, the pool balance is
    //    exactly zero and every accepted op produced exactly one
    //    completion delivery.
    let gen = VecGen { elem: U64Range(0, 999), min_len: 1, max_len: 120 };
    check(61, 20, &gen, |script: &Vec<u64>| {
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 2;
        fcfg.sq_depth = 4096;
        let mut sim = Sim::new(fcfg);
        let mut daemons = vec![
            Daemon::start(&mut sim, NodeId(0), DaemonConfig::default()),
            Daemon::start(&mut sim, NodeId(1), DaemonConfig::default()),
        ];
        let sapp = daemons[1].register_app();
        daemons[1].listen(sapp, 1);
        let app = daemons[0].register_app();
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

        const SPAN: u64 = 64 << 10;
        let mut live: Vec<WindowToken> = Vec::new();
        let mut dead: Vec<WindowToken> = Vec::new();
        let mut accepted = 0u64; // ops that returned Ok (≡ owed completions)
        for (i, &op) in script.iter().enumerate() {
            let pick = |v: &Vec<WindowToken>| v[(op as usize + i) % v.len()];
            match op % 100 {
                0..=14 => {
                    if live.len() < 6 {
                        let before = daemons[0].pool.leased_bytes;
                        let w = daemons[0]
                            .register_window(&mut sim, conn, (op % 16) * SPAN, SPAN, 4096)
                            .map_err(|e| format!("register: {e}"))?;
                        let took = daemons[0].pool.leased_bytes - before;
                        if took != 4096 {
                            return Err(format!("register leased {took} bytes, want 4096"));
                        }
                        live.push(w);
                    }
                }
                15..=54 if !live.is_empty() => {
                    let len = 1 + op % 4096;
                    let off = (op * 37) % (SPAN - len + 1);
                    let before = daemons[0].pool.leased_bytes;
                    if daemons[0].window_read(&mut sim, pick(&live), len, off, op).is_ok() {
                        accepted += 1;
                    }
                    if daemons[0].pool.leased_bytes != before {
                        return Err("window READ moved the lease ledger at submit".into());
                    }
                }
                55..=79 if !live.is_empty() => {
                    let len = 1 + op % 4096;
                    let off = (op * 53) % (SPAN - len + 1);
                    let before = daemons[0].pool.leased_bytes;
                    if daemons[0].window_write(&mut sim, pick(&live), len, off, op).is_ok() {
                        accepted += 1;
                    }
                    if daemons[0].pool.leased_bytes != before {
                        return Err("window WRITE moved the lease ledger at submit".into());
                    }
                }
                80..=84 if !live.is_empty() => {
                    daemons[0]
                        .window_flush(&mut sim, pick(&live))
                        .map_err(|e| format!("flush: {e}"))?;
                }
                85..=92 if !live.is_empty() => {
                    let idx = (op as usize + i) % live.len();
                    let w = live.swap_remove(idx);
                    daemons[0]
                        .release_window(&mut sim, w)
                        .map_err(|e| format!("release: {e}"))?;
                    dead.push(w);
                }
                93..=96 => {
                    // plain READ: per-op lease machinery, interleaved with
                    // the window path to catch cross-path double accounting
                    if daemons[0].read(&mut sim, conn, 4096, (op * 4096) % (1 << 20), op).is_ok()
                    {
                        accepted += 1;
                    }
                }
                _ => {
                    // every dead token must be refused — released slots,
                    // recycled slots, all of them
                    if let Some(&w) = dead.last() {
                        let r = daemons[0].window_read(&mut sim, w, 64, 0, 0);
                        let wr = daemons[0].window_write(&mut sim, w, 64, 0, 0);
                        let f = daemons[0].window_flush(&mut sim, w);
                        if r != Err(RaasError::StaleWindow)
                            || wr != Err(RaasError::StaleWindow)
                            || f != Err(RaasError::StaleWindow)
                        {
                            return Err(format!("stale token accepted: {r:?} {wr:?} {f:?}"));
                        }
                    }
                }
            }
        }
        let live_count = live.len();
        for w in live.drain(..) {
            daemons[0]
                .release_window(&mut sim, w)
                .map_err(|e| format!("final release: {e}"))?;
        }
        if daemons[0].window_count() != 0 {
            return Err(format!("{} windows survived release", daemons[0].window_count()));
        }
        if daemons[0].stats.windows_registered != daemons[0].stats.windows_released {
            return Err(format!(
                "register/release imbalance: {} vs {} (live was {live_count})",
                daemons[0].stats.windows_registered, daemons[0].stats.windows_released
            ));
        }
        for _ in 0..3_000_000 {
            for d in daemons.iter_mut() {
                d.pump(&mut sim);
            }
            if sim.step().is_none() {
                for d in daemons.iter_mut() {
                    d.pump(&mut sim);
                }
                if sim.pending_events() == 0 {
                    break;
                }
            }
        }
        if daemons[0].pool.leased_bytes != 0 {
            return Err(format!(
                "pool balance nonzero after quiesce: {} bytes leased",
                daemons[0].pool.leased_bytes
            ));
        }
        let mut delivered = 0u64;
        while let Some(d) = daemons[0].recv_zero_copy(&mut sim, app) {
            match d {
                Delivery::OpComplete { .. } => delivered += 1,
                Delivery::Message { .. } => return Err("unexpected two-sided message".into()),
            }
        }
        if delivered != accepted {
            return Err(format!("{delivered} completions for {accepted} accepted ops"));
        }
        Ok(())
    });
}

#[test]
fn prop_qp_reuse_never_aliases_tenants() {
    // ∀ random connect/read/disconnect/drain interleavings over a tiny
    // pool (qp_pool_max = 2, lazy + batched leases) that parks, revives
    // and evicts shared RC QPs constantly:
    //  - the reuse pool never exceeds its configured bound, on any host;
    //  - a completion is only ever attributed to the tenant that issued
    //    the op — a recycled vQPN slot or a revived RC QP never surfaces
    //    a prior tenant's frame, CQE or lease (the §12 epoch gate);
    //  - after tearing every tenant down and quiescing, all ledgers are
    //    zero: no live conns, no quarantined slots, no leased bytes, no
    //    in-flight ops, no deferred lease offers.
    // per-slot op ledger: `budget[v]` = completions the slot's CURRENT
    // tenant is still owed. vQPN slots recycle verbatim (bare indices),
    // so a prior tenant's frame surfacing on a recycled slot shows up as
    // a completion the new tenant never paid for — budget underflow.
    type Budget = std::collections::HashMap<u32, u64>;

    fn pop_and_check(
        sim: &mut Sim,
        daemons: &mut [Daemon],
        app: u32,
        budget: &mut Budget,
    ) -> Result<(), String> {
        while let Some(d) = daemons[0].recv_zero_copy(sim, app) {
            if let Delivery::OpComplete { conn, .. } = d {
                // unowned slot = the issuer already departed (its own
                // fail-fast or late completion) — harmless. An OWNED slot
                // must be owed: zero budget means a prior tenant's CQE or
                // frame leaked through the epoch gate.
                if let Some(b) = budget.get_mut(&conn.0) {
                    if *b == 0 {
                        return Err(format!(
                            "completion on {conn:?} its current tenant never \
                             issued — prior-tenant leak through a recycled \
                             vQPN or revived QP"
                        ));
                    }
                    *b -= 1;
                }
            }
        }
        Ok(())
    }

    fn quiesce(sim: &mut Sim, daemons: &mut [Daemon]) {
        for _ in 0..200_000 {
            for d in daemons.iter_mut() {
                d.pump(sim);
            }
            if sim.step().is_none() {
                for d in daemons.iter_mut() {
                    d.pump(sim);
                }
                if sim.pending_events() == 0 {
                    return;
                }
            }
        }
        panic!("cluster did not quiesce");
    }

    let gen = VecGen { elem: U64Range(0, 999), min_len: 20, max_len: 160 };
    check(67, 20, &gen, |script: &Vec<u64>| {
        const SERVERS: usize = 3;
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 1 + SERVERS as u32;
        fcfg.sq_depth = 1024;
        let mut sim = Sim::new(fcfg);
        let mut dcfg = DaemonConfig::default();
        dcfg.migration.enabled = false;
        dcfg.qp_pool_max = 2; // tiny: force LRU eviction under churn
        dcfg.lazy_leases = true;
        dcfg.lease_batch_max = 4;
        let mut daemons: Vec<Daemon> = (0..=SERVERS)
            .map(|i| Daemon::start(&mut sim, NodeId(i as u32), dcfg.clone()))
            .collect();
        for s in 1..=SERVERS {
            let sapp = daemons[s].register_app();
            daemons[s].listen(sapp, 7);
        }
        let app = daemons[0].register_app();

        let mut live: Vec<Vqpn> = Vec::new();
        let mut budget: Budget = Budget::new();

        for (i, &op) in script.iter().enumerate() {
            match op % 100 {
                0..=29 if live.len() < 12 => {
                    let server = 1 + (op as usize % SERVERS);
                    let conn = connect_via(&mut sim, &mut daemons, 0, app, server, 7)
                        .map_err(|e| format!("connect: {e}"))?;
                    budget.insert(conn.0, 0);
                    live.push(conn);
                }
                30..=64 if !live.is_empty() => {
                    let conn = live[(op as usize + i) % live.len()];
                    // Err (pool pressure) is fine; an accepted op is owed
                    // exactly one completion to exactly this tenant
                    if daemons[0]
                        .read(&mut sim, conn, 2048, (op * 4096) % (1 << 20), op)
                        .is_ok()
                    {
                        *budget.get_mut(&conn.0).expect("live conn has a ledger") += 1;
                    }
                }
                65..=84 if !live.is_empty() => {
                    let idx = (op as usize + i) % live.len();
                    let conn = live.swap_remove(idx);
                    // flush deliveries already attributed to live slots,
                    // THEN retire the ledger — the disconnect's fail-fast
                    // completions land on a now-unowned slot
                    pop_and_check(&mut sim, &mut daemons, app, &mut budget)?;
                    budget.remove(&conn.0);
                    disconnect_via(&mut sim, &mut daemons, 0, conn)
                        .map_err(|e| format!("disconnect: {e}"))?;
                    pop_and_check(&mut sim, &mut daemons, app, &mut budget)?;
                }
                _ => {
                    quiesce(&mut sim, &mut daemons);
                    pop_and_check(&mut sim, &mut daemons, app, &mut budget)?;
                }
            }
            for d in daemons.iter() {
                if d.pooled_qp_count() > 2 {
                    return Err(format!(
                        "reuse pool over bound: {} parked QPs",
                        d.pooled_qp_count()
                    ));
                }
            }
        }

        // full teardown: every tenant departs, then the fabric quiesces
        pop_and_check(&mut sim, &mut daemons, app, &mut budget)?;
        for conn in live.drain(..) {
            budget.remove(&conn.0);
            disconnect_via(&mut sim, &mut daemons, 0, conn)
                .map_err(|e| format!("final disconnect: {e}"))?;
        }
        quiesce(&mut sim, &mut daemons);
        pop_and_check(&mut sim, &mut daemons, app, &mut budget)?;
        for (h, d) in daemons.iter().enumerate() {
            if d.conns.active() != 0 {
                return Err(format!("host {h}: {} conns survived teardown", d.conns.active()));
            }
            if d.conns.quarantined() != 0 {
                return Err(format!(
                    "host {h}: {} vQPN slots stuck in quarantine",
                    d.conns.quarantined()
                ));
            }
            if d.pool.leased_bytes != 0 {
                return Err(format!("host {h}: {} leased bytes leaked", d.pool.leased_bytes));
            }
            if d.inflight_ops() != 0 {
                return Err(format!("host {h}: {} ops stuck in flight", d.inflight_ops()));
            }
            if d.deferred_lease_count() != 0 {
                return Err(format!(
                    "host {h}: {} lease offers still deferred",
                    d.deferred_lease_count()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rc_exactly_once_under_random_drop_plans() {
    use rdmavisor::fabric::verbs;
    use rdmavisor::fabric::wqe::SendWr;

    // ∀ (fault seed, loss rate ≤ 12%, message count): every RC message
    // completes at the requester EXACTLY once (success or RetryExceeded),
    // the responder delivers every message AT MOST once, and a success
    // implies a delivery. Loss, bursts and jitter reordering included.
    struct Case;
    impl Gen<(u64, u64, usize)> for Case {
        fn gen(&self, rng: &mut Rng) -> (u64, u64, usize) {
            (
                rng.next_u64(),                 // fault stream seed
                U64Range(0, 120).gen(rng),      // loss in millis
                UsizeRange(1, 24).gen(rng),     // messages
            )
        }
    }
    check(53, 25, &Case, |&(fseed, loss_m, n)| {
        let mut sim = Sim::new(FabricConfig::default());
        sim.install_faults(FaultConfig {
            seed: fseed,
            drop_p: loss_m as f64 / 1000.0,
            burst_p: 0.2,
            burst_len: (2, 6),
            jitter_p: 0.05,
            jitter_ns: (200, 3000),
            ..FaultConfig::default()
        });
        let cq0 = sim.create_cq(NodeId(0), 8192);
        let cq1 = sim.create_cq(NodeId(1), 8192);
        let pair = verbs::create_connected_pair(
            &mut sim, QpTransport::Rc, NodeId(0), NodeId(1), cq0, cq0, cq1, cq1,
        );
        let local = sim.reg_mr(NodeId(0), 32 << 20, rdmavisor::fabric::mr::Access::REMOTE_RW, true);
        let remote =
            sim.reg_mr(NodeId(1), 32 << 20, rdmavisor::fabric::mr::Access::REMOTE_RW, true);
        let mut next_recv = 0u64;
        verbs::replenish_rq(&mut sim, NodeId(1), pair.b.1, &remote, 8192, 200, &mut next_recv);
        for i in 0..n {
            let len = 1 + (i as u64 * 977) % 8000;
            sim.post_send(
                NodeId(0),
                pair.a.1,
                SendWr::send(i as u64, len, local.key, local.addr, i as u32),
            )
            .map_err(|e| format!("post {i}: {e}"))?;
        }
        let mut guard = 0u64;
        while sim.step().is_some() {
            guard += 1;
            if guard > 10_000_000 {
                return Err("did not quiesce (retransmission livelock?)".into());
            }
        }
        let reqs = sim.poll_cq(NodeId(0), cq0, 100_000);
        if reqs.len() != n {
            return Err(format!("{} of {n} requester completions", reqs.len()));
        }
        let mut seen = std::collections::HashSet::new();
        let mut success = std::collections::HashSet::new();
        for c in &reqs {
            if !seen.insert(c.wr_id) {
                return Err(format!("wr {} completed twice", c.wr_id));
            }
            match c.status {
                WcStatus::Success => {
                    success.insert(c.wr_id as u32);
                }
                WcStatus::RetryExceeded => {}
                other => return Err(format!("unexpected status {other:?}")),
            }
        }
        let mut delivered = std::collections::HashSet::new();
        for c in sim.poll_cq(NodeId(1), cq1, 100_000) {
            let imm = c.imm_data.ok_or("recv CQE without imm")?;
            if !delivered.insert(imm) {
                return Err(format!("message {imm} delivered twice (exactly-once broken)"));
            }
        }
        for s in &success {
            if !delivered.contains(s) {
                return Err(format!("message {s} succeeded but was never delivered"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_reassembler_completes_exactly_the_undamaged_messages() {
    use rdmavisor::raas::vqpn::Vqpn;

    // ∀ (fragment-count vector, drop plan): feeding the surviving
    // fragments in order (single path, distinct mod-64 tags), a message
    // reassembles iff NO fragment of it was dropped, and the reported
    // total is the sum of its fragment lengths. Orphans/drops never
    // produce a completion.
    struct Plan;
    impl Gen<(Vec<u64>, u64, u64)> for Plan {
        fn gen(&self, rng: &mut Rng) -> (Vec<u64>, u64, u64) {
            let counts = VecGen { elem: U64Range(1, 6), min_len: 1, max_len: 12 }.gen(rng);
            (counts, rng.next_u64(), U64Range(0, 400).gen(rng))
        }
    }
    check(59, 150, &Plan, |(counts, drop_seed, p_millis)| {
        let p = *p_millis as f64 / 1000.0;
        let mut drop_rng = Rng::new(*drop_seed);
        let mut r = Reassembler::new();
        let v = Vqpn(3);
        let mut t = 0u64;
        let mut expected_completed = 0u64;
        for (m, &frags) in counts.iter().enumerate() {
            let sizes: Vec<u64> = (0..frags).map(|k| 1000 + (m as u64 * 7 + k)).collect();
            let survived: Vec<bool> = (0..frags).map(|_| !drop_rng.chance(p)).collect();
            let intact = survived.iter().all(|&s| s);
            if intact {
                expected_completed += 1;
            }
            let mut got = None;
            for (k, &ok) in survived.iter().enumerate() {
                if !ok {
                    continue;
                }
                t += 1;
                got = r.accept(
                    v,
                    (m % 64) as u8,
                    k as u16,
                    k as u64 + 1 == frags,
                    sizes[k],
                    Ns(t),
                );
            }
            if intact {
                let total: u64 = sizes.iter().sum();
                if got != Some(total) {
                    return Err(format!("msg {m}: expected Some({total}), got {got:?}"));
                }
            } else if got.is_some() {
                return Err(format!("msg {m} lost a fragment yet completed: {got:?}"));
            }
        }
        if r.completed != expected_completed {
            return Err(format!(
                "completed {} != undamaged {}",
                r.completed, expected_completed
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_sim_time_monotonic_under_random_traffic() {
    use rdmavisor::fabric::mr::Access;
    use rdmavisor::fabric::types::QpTransport;
    use rdmavisor::fabric::verbs;
    use rdmavisor::fabric::wqe::SendWr;

    let gen = VecGen { elem: U64Range(1, 64 << 10), min_len: 1, max_len: 60 };
    check(29, 30, &gen, |sizes: &Vec<u64>| {
        let mut sim = Sim::new(FabricConfig::default());
        let cq0 = sim.create_cq(NodeId(0), 8192);
        let cq1 = sim.create_cq(NodeId(1), 8192);
        let pair = verbs::create_connected_pair(
            &mut sim, QpTransport::Rc, NodeId(0), NodeId(1), cq0, cq0, cq1, cq1,
        );
        let local = sim.reg_mr(NodeId(0), 32 << 20, Access::REMOTE_RW, true);
        let remote = sim.reg_mr(NodeId(1), 32 << 20, Access::REMOTE_RW, true);
        for (i, &len) in sizes.iter().enumerate() {
            sim.post_send(
                NodeId(0),
                pair.a.1,
                SendWr::write(i as u64, len, local.key, local.addr, remote.key, remote.addr),
            )
            .map_err(|e| format!("post {i}: {e}"))?;
        }
        let mut last = Ns::ZERO;
        while sim.step().is_some() {
            if sim.now() < last {
                return Err("time went backwards".into());
            }
            last = sim.now();
        }
        let cqes = sim.poll_cq(NodeId(0), cq0, 10_000);
        if cqes.len() != sizes.len() {
            return Err(format!("{} of {} completed", cqes.len(), sizes.len()));
        }
        Ok(())
    });
}
