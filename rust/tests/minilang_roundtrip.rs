//! Round-trip coverage for the hand-rolled config/result mini-languages
//! (`util::jsonmini`, `util::tomlmini`), which sit on the CLI output and
//! config input paths: parse → write → parse must be the identity, for
//! hand-written documents and for randomized values.

use rdmavisor::util::jsonmini::{self, Json};
use rdmavisor::util::rng::Rng;
use rdmavisor::util::tomlmini::{self, Value};

// ------------------------------------------------------------------- JSON

/// Random JSON value with bounded depth/width.
fn random_json(rng: &mut Rng, depth: u32) -> Json {
    let kind = if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) };
    match kind {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => {
            // mix integers and fractions; Display for f64 is
            // shortest-roundtrip so any finite value survives
            if rng.chance(0.5) {
                Json::Num(rng.gen_range(2_000_000) as f64 - 1_000_000.0)
            } else {
                Json::Num((rng.f64() - 0.5) * 1e6)
            }
        }
        3 => Json::Str(random_string(rng)),
        4 => {
            let n = rng.gen_range(5) as usize;
            Json::Arr((0..n).map(|_| random_json(rng, depth - 1)).collect())
        }
        _ => {
            let n = rng.gen_range(5) as usize;
            Json::Obj(
                (0..n)
                    .map(|i| (format!("k{i}_{}", rng.gen_range(100)), random_json(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}

fn random_string(rng: &mut Rng) -> String {
    let alphabet: Vec<char> =
        "abz09 _-.\"\\\n\t\r/€λ\u{1}".chars().collect();
    let n = rng.gen_range(12) as usize;
    (0..n).map(|_| alphabet[rng.gen_range(alphabet.len() as u64) as usize]).collect()
}

#[test]
fn json_random_values_roundtrip() {
    let mut rng = Rng::new(0xD1CE);
    for case in 0..500 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = jsonmini::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: reparse failed: {e}\ndoc: {text}"));
        assert_eq!(back, v, "case {case} not identity; doc: {text}");
        // writing the reparsed value is stable (fixed point)
        assert_eq!(back.to_string(), text, "case {case} writer not stable");
    }
}

#[test]
fn json_handwritten_documents_roundtrip() {
    let docs = [
        r#"{"seed":42,"variants":[{"name":"b1","batch":1}],"empty":[],"obj":{}}"#,
        r#"[1,-2.5,3e2,true,false,null,"esc\"\n\t\\",{"€":"λ"}]"#,
        r#"{"nested":{"a":[{"b":[[]]}]}}"#,
    ];
    for doc in docs {
        let v = jsonmini::parse(doc).unwrap();
        let again = jsonmini::parse(&v.to_string()).unwrap();
        assert_eq!(v, again, "doc: {doc}");
    }
}

#[test]
fn json_figure_output_shape_roundtrips() {
    // the exact object shape `rdmavisor fig` emits
    let doc = jsonmini::obj(vec![
        ("command", Json::Str("fig".into())),
        (
            "figures",
            Json::Arr(vec![jsonmini::obj(vec![
                ("id", Json::Num(5.0)),
                ("x", Json::Str("conns".into())),
                (
                    "rows",
                    Json::Arr(vec![Json::Arr(vec![
                        Json::Num(100.0),
                        Json::Num(36.125),
                        Json::Null, // NaN series points degrade to null
                    ])]),
                ),
            ])]),
        ),
    ]);
    let text = doc.to_string();
    assert_eq!(jsonmini::parse(&text).unwrap(), doc);
}

// ------------------------------------------------------------------- TOML

fn random_toml_value(rng: &mut Rng, allow_array: bool) -> Value {
    match rng.gen_range(if allow_array { 5 } else { 4 }) {
        0 => Value::Int(rng.gen_range(2_000_000) as i64 - 1_000_000),
        1 => Value::Float((rng.f64() - 0.5) * 1e4),
        2 => Value::Bool(rng.chance(0.5)),
        3 => {
            // strings: no quotes/escapes/newlines in the subset grammar
            let n = rng.gen_range(10) as usize;
            let alphabet: Vec<char> = "abcXYZ012 _-./".chars().collect();
            Value::Str(
                (0..n)
                    .map(|_| alphabet[rng.gen_range(alphabet.len() as u64) as usize])
                    .collect(),
            )
        }
        _ => {
            let n = rng.gen_range(4) as usize;
            Value::Array((0..n).map(|_| random_toml_value(rng, false)).collect())
        }
    }
}

#[test]
fn toml_random_tables_roundtrip() {
    let mut rng = Rng::new(0x7011);
    for case in 0..300 {
        let mut t = tomlmini::Table::default();
        let entries = rng.gen_range(12) + 1;
        for i in 0..entries {
            let key = match rng.gen_range(3) {
                0 => format!("top{i}"),
                1 => format!("sec{}.k{i}", rng.gen_range(3)),
                _ => format!("sec{}.sub{}.k{i}", rng.gen_range(2), rng.gen_range(2)),
            };
            t.set(&key, random_toml_value(&mut rng, true));
        }
        let doc = tomlmini::write(&t);
        let back = tomlmini::parse(&doc)
            .unwrap_or_else(|e| panic!("case {case}: reparse failed: {e}\ndoc:\n{doc}"));
        assert_eq!(back, t, "case {case} not identity; doc:\n{doc}");
        // write is a fixed point after one round
        assert_eq!(tomlmini::write(&back), doc, "case {case} writer not stable");
    }
}

#[test]
fn toml_sample_config_roundtrips_through_writer() {
    let t = tomlmini::parse(rdmavisor::config::SAMPLE).unwrap();
    let doc = tomlmini::write(&t);
    let back = tomlmini::parse(&doc).unwrap();
    assert_eq!(t, back);
    // and the typed config layer agrees on the rewritten document
    let cfg_a = rdmavisor::config::from_str(rdmavisor::config::SAMPLE).unwrap();
    let cfg_b = rdmavisor::config::from_str(&doc).unwrap();
    assert_eq!(cfg_a.fabric.nodes, cfg_b.fabric.nodes);
    assert_eq!(cfg_a.fabric.link_gbps, cfg_b.fabric.link_gbps);
    assert_eq!(cfg_a.scenario.conns, cfg_b.scenario.conns);
    assert_eq!(cfg_a.daemon.batch_max, cfg_b.daemon.batch_max);
}
