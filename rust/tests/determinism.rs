//! Determinism: every scenario driver and every `fig --id N` harness must
//! replay byte-identically from the same seed. Catches hidden
//! HashMap-iteration order leaking into the event timeline, wall-clock
//! time sneaking into results, and any other nondeterministic state.
//!
//! Figures run at `Budget::Quick`; scenario drivers run tiny dedicated
//! configs. Comparison is on serialized bytes (`Series::to_json` + the
//! rendered table for figures, `{:?}` for raw stat rows), so even a
//! single bit of f64 drift fails the test.

use rdmavisor::fabric::time::Ns;
use rdmavisor::fabric::topo::CcMode;
use rdmavisor::figures::{self, Budget};
use rdmavisor::workload::scenarios::{
    chaos_send, churn_storm, failover_storm, incast_storm, kv_storm, locked_random_read,
    naive_random_read, raas_random_read, scale_send, verbs_sweep_point, ChaosCfg, ChurnCfg,
    IncastCfg, KvCfg, ScaleCfg, ScenarioCfg,
};

/// Run one figure id end-to-end on `jobs` threads and serialize
/// everything it produces.
fn fig_bytes_jobs(id: u64, jobs: usize) -> String {
    let mut cache = None;
    let (series, table) =
        figures::run_fig(id, Budget::Quick, &mut cache, jobs).expect("known figure id");
    format!("{}\n{}", series.to_json().to_string(), table)
}

/// The serial runner (`--jobs 1` — the exact old code path).
fn fig_bytes(id: u64) -> String {
    fig_bytes_jobs(id, 1)
}

fn assert_fig_deterministic(id: u64) {
    let a = fig_bytes(id);
    let b = fig_bytes(id);
    assert_eq!(a, b, "fig --id {id} differed between two identical runs");
}

#[test]
fn fig1_replays_byte_identically() {
    assert_fig_deterministic(1);
}

#[test]
fn fig5_replays_byte_identically() {
    assert_fig_deterministic(5);
}

#[test]
fn fig6_replays_byte_identically() {
    assert_fig_deterministic(6);
}

#[test]
fn fig7_replays_byte_identically() {
    assert_fig_deterministic(7);
}

#[test]
fn fig8_replays_byte_identically() {
    assert_fig_deterministic(8);
}

#[test]
fn fig9_replays_byte_identically() {
    assert_fig_deterministic(9);
}

#[test]
fn fig10_replays_byte_identically() {
    // the whole fault machinery — drop/jitter RNG stream, burst episodes,
    // flap windows, RC retransmission timers, reassembly discards —
    // under the determinism gate: same seed ⇒ byte-identical JSON
    assert_fig_deterministic(10);
}

#[test]
fn fig10_rc_only_replays_byte_identically() {
    let run = || {
        let rows = figures::fig10_rc_only(Budget::Quick, 1);
        format!(
            "{}\n{}",
            figures::fig10_series(&rows).to_json().to_string(),
            figures::print_fig10(&rows)
        )
    };
    assert_eq!(run(), run(), "fig --id 10 --rc-only differed between runs");
}

#[test]
fn fig10_chaos_point_exercises_both_failure_families() {
    // the acceptance gate: at the lossy quick point, the adaptive run's
    // UD traffic must tear reassemblies and the rc-only run must exhaust
    // RC retry budgets inside the flap windows — both nonzero, on top of
    // the byte-identity the tests above pin
    let adaptive = chaos_send(&figures::fig10_cfg(0.05, Budget::Quick, false));
    assert!(adaptive.frames_dropped > 0, "{adaptive:?}");
    assert!(
        adaptive.ud_dropped + adaptive.ud_orphans + adaptive.ud_expired > 0,
        "UD reassembly-discard counters must be nonzero: {adaptive:?}"
    );
    let rc_only = chaos_send(&figures::fig10_cfg(0.05, Budget::Quick, true));
    assert!(rc_only.retransmits > 0, "{rc_only:?}");
    assert!(
        rc_only.retry_exceeded > 0,
        "RC retry-exceeded counter must be nonzero: {rc_only:?}"
    );
}

#[test]
fn fig11_replays_byte_identically() {
    // the KV tier end-to-end: window registration order, Zipf key streams,
    // doorbell flush grouping and the RPC baseline all under one seed
    assert_fig_deterministic(11);
}

#[test]
fn fig11_rc_only_replays_byte_identically() {
    // the `fig --id 11 --rc-only` CLI path (SEND-RPC ablation alone)
    let run = || {
        let rows = figures::fig11_rpc_only(Budget::Quick, 1);
        format!(
            "{}\n{}",
            figures::fig11_series(&rows).to_json().to_string(),
            figures::print_fig11(&rows)
        )
    };
    assert_eq!(run(), run(), "fig --id 11 --rc-only differed between runs");
}

#[test]
fn fig11_one_sided_beats_rpc_at_scale() {
    // the PR-6 acceptance gate: at the biggest quick point (1024 clients)
    // the one-sided data plane must beat SEND-RPC on app-level ops/sec
    let rows = figures::fig11(Budget::Quick, 1);
    let row = rows
        .iter()
        .find(|r| r.clients >= 1024)
        .expect("quick sweep must include a >=1024-client point");
    let os = row.os_read.as_ref().expect("one-sided column present");
    assert!(
        os.mops > row.rpc_read.mops,
        "{} clients: one-sided {:.3} Mops must beat SEND-RPC {:.3} Mops",
        row.clients,
        os.mops,
        row.rpc_read.mops
    );
    // and it must do so while leaving the server's service loop idle
    assert_eq!(os.server_gets_served + os.server_puts_applied, 0);
    assert!(row.rpc_read.server_gets_served > 0);
}

#[test]
fn fig12_replays_byte_identically() {
    // the elastic control plane end-to-end: seeded arrival/departure
    // tape, QP park/revive bookkeeping, lazy lease batching, epoch
    // stamps — all under one seed, warm and cold interleaved
    assert_fig_deterministic(12);
}

#[test]
fn fig12_cold_only_replays_byte_identically() {
    // the `fig --id 12 --cold` CLI path (no-pool/eager-lease ablation)
    let run = || {
        let rows = figures::fig12_cold_only(Budget::Quick, 1);
        format!(
            "{}\n{}",
            figures::fig12_series(&rows).to_json().to_string(),
            figures::print_fig12(&rows)
        )
    };
    assert_eq!(run(), run(), "fig --id 12 --cold differed between runs");
}

#[test]
fn fig12_warm_beats_cold_at_scale() {
    // the PR-7 acceptance gate: at the biggest quick point, QP reuse +
    // lazy batched leases must beat the cold path on setup rate, and an
    // idle registered vQPN must cost far less than any full connection
    // (the fig-7 naive footprint is a QP ring pair — tens of KB)
    let rows = figures::fig12(Budget::Quick, 1);
    let row = rows.last().expect("non-empty sweep");
    let warm = row.warm.as_ref().expect("warm column present");
    assert!(
        warm.setup_kcps > row.cold.setup_kcps,
        "{} conns: warm {:.1} kcps must beat cold {:.1} kcps",
        row.conns,
        warm.setup_kcps,
        row.cold.setup_kcps
    );
    assert!(warm.qp_reused > 0, "the pool must serve reconnects: {warm:?}");
    assert_eq!(row.cold.qp_reused, 0, "cold mode must never revive: {:?}", row.cold);
    assert!(
        warm.table_bytes_per_vqpn > 0.0 && warm.table_bytes_per_vqpn < 1024.0,
        "idle tenant must cost ~one table entry: {warm:?}"
    );
    assert!(
        warm.mem_per_vqpn < 16_384.0,
        "per-vQPN footprint must stay below a full connection's: {warm:?}"
    );
}

#[test]
fn fig9_rc_only_replays_byte_identically() {
    // the `fig --id 9 --rc-only` CLI path (ablation series alone), at the
    // same quick budget the CI smoke uses
    let run = || {
        let rows = figures::fig9_rc_only(Budget::Quick, 1);
        format!(
            "{}\n{}",
            figures::fig9_series(&rows).to_json().to_string(),
            figures::print_fig9(&rows)
        )
    };
    assert_eq!(run(), run(), "fig --id 9 --rc-only differed between runs");
}

// ------------------------------------------------- parallel sweep harness

/// The PR-5 acceptance gate: the parallel sweep executor must merge
/// per-point results in index order with NOTHING shared between the
/// per-point Sims, so `--jobs 4` output is byte-for-byte the serial
/// runner's. Figures 1, 9 and 10 cover the three sweep shapes (raw
/// verbs points, the daemon-scale sweep, the fault-injection sweep).
#[test]
fn fig1_parallel_matches_serial() {
    assert_eq!(fig_bytes_jobs(1, 1), fig_bytes_jobs(1, 4), "fig 1: --jobs 4 != --jobs 1");
}

#[test]
fn fig9_parallel_matches_serial() {
    assert_eq!(fig_bytes_jobs(9, 1), fig_bytes_jobs(9, 4), "fig 9: --jobs 4 != --jobs 1");
}

#[test]
fn fig9_rc_only_parallel_matches_serial() {
    let run = |jobs| {
        let rows = figures::fig9_rc_only(Budget::Quick, jobs);
        format!(
            "{}\n{}",
            figures::fig9_series(&rows).to_json().to_string(),
            figures::print_fig9(&rows)
        )
    };
    assert_eq!(run(1), run(4), "fig 9 --rc-only: --jobs 4 != --jobs 1");
}

#[test]
fn fig10_parallel_matches_serial() {
    assert_eq!(fig_bytes_jobs(10, 1), fig_bytes_jobs(10, 4), "fig 10: --jobs 4 != --jobs 1");
}

#[test]
fn fig10_rc_only_parallel_matches_serial() {
    let run = |jobs| {
        let rows = figures::fig10_rc_only(Budget::Quick, jobs);
        format!(
            "{}\n{}",
            figures::fig10_series(&rows).to_json().to_string(),
            figures::print_fig10(&rows)
        )
    };
    assert_eq!(run(1), run(4), "fig 10 --rc-only: --jobs 4 != --jobs 1");
}

#[test]
fn fig11_parallel_matches_serial() {
    assert_eq!(fig_bytes_jobs(11, 1), fig_bytes_jobs(11, 4), "fig 11: --jobs 4 != --jobs 1");
}

#[test]
fn fig11_rc_only_parallel_matches_serial() {
    let run = |jobs| {
        let rows = figures::fig11_rpc_only(Budget::Quick, jobs);
        format!(
            "{}\n{}",
            figures::fig11_series(&rows).to_json().to_string(),
            figures::print_fig11(&rows)
        )
    };
    assert_eq!(run(1), run(4), "fig 11 --rc-only: --jobs 4 != --jobs 1");
}

#[test]
fn fig12_parallel_matches_serial() {
    assert_eq!(fig_bytes_jobs(12, 1), fig_bytes_jobs(12, 4), "fig 12: --jobs 4 != --jobs 1");
}

// ------------------------------------------------- sharded simulator

/// Run one figure id with every sweep point's `Sim` split into `shards`
/// conservatively-synchronized partitions, and serialize everything it
/// produces. `--jobs` stays at 1 so the only variable is the sharded
/// executor inside each `Sim`.
fn fig_bytes_sharded(id: u64, shards: usize) -> String {
    let mut cache = None;
    let (series, table) = figures::run_fig_sharded(id, Budget::Quick, &mut cache, 1, shards)
        .expect("known figure id");
    format!("{}\n{}", series.to_json().to_string(), table)
}

/// The PR-8 acceptance gate: splitting a `Sim` into shards must not move
/// a single output byte. Figures 9–12 cover the four daemon-scale
/// workload shapes (RC↔UD migration, fault injection with per-node
/// forked fault RNG streams, the one-sided KV window plane, and the
/// control-plane churn storm).
#[test]
fn fig9_sharded_matches_serial() {
    assert_eq!(fig_bytes(9), fig_bytes_sharded(9, 4), "fig 9: --shards 4 != --shards 1");
}

#[test]
fn fig10_sharded_matches_serial() {
    assert_eq!(fig_bytes(10), fig_bytes_sharded(10, 4), "fig 10: --shards 4 != --shards 1");
}

#[test]
fn fig11_sharded_matches_serial() {
    assert_eq!(fig_bytes(11), fig_bytes_sharded(11, 4), "fig 11: --shards 4 != --shards 1");
}

#[test]
fn fig12_sharded_matches_serial() {
    assert_eq!(fig_bytes(12), fig_bytes_sharded(12, 4), "fig 12: --shards 4 != --shards 1");
}

#[test]
fn fig9_rc_only_sharded_matches_serial() {
    let run = |shards| {
        let rows = figures::fig9_rc_only_sharded(Budget::Quick, 1, shards);
        format!(
            "{}\n{}",
            figures::fig9_series(&rows).to_json().to_string(),
            figures::print_fig9(&rows)
        )
    };
    assert_eq!(run(1), run(4), "fig 9 --rc-only: --shards 4 != --shards 1");
}

#[test]
fn fig10_rc_only_sharded_matches_serial() {
    let run = |shards| {
        let rows = figures::fig10_rc_only_sharded(Budget::Quick, 1, shards);
        format!(
            "{}\n{}",
            figures::fig10_series(&rows).to_json().to_string(),
            figures::print_fig10(&rows)
        )
    };
    assert_eq!(run(1), run(4), "fig 10 --rc-only: --shards 4 != --shards 1");
}

#[test]
fn fig11_rpc_only_sharded_matches_serial() {
    let run = |shards| {
        let rows = figures::fig11_rpc_only_sharded(Budget::Quick, 1, shards);
        format!(
            "{}\n{}",
            figures::fig11_series(&rows).to_json().to_string(),
            figures::print_fig11(&rows)
        )
    };
    assert_eq!(run(1), run(4), "fig 11 --rc-only: --shards 4 != --shards 1");
}

#[test]
fn fig12_cold_only_sharded_matches_serial() {
    let run = |shards| {
        let rows = figures::fig12_cold_only_sharded(Budget::Quick, 1, shards);
        format!(
            "{}\n{}",
            figures::fig12_series(&rows).to_json().to_string(),
            figures::print_fig12(&rows)
        )
    };
    assert_eq!(run(1), run(4), "fig 12 --cold: --shards 4 != --shards 1");
}

/// One seeded random WRITE storm on a 6-node fabric with the event trace
/// recorder on: random directed QP pairs, random burst sizes, random
/// payloads and offsets — everything drawn from one `Rng` before the
/// clock starts, so every shard count replays the same workload. Returns
/// `(events, rx_bytes, trace)`; the trace is the full per-event `(time,
/// node, kind)` pop order.
fn random_write_storm(seed: u64, shards: usize) -> (u64, u64, Vec<(u64, u32, u8)>) {
    use rdmavisor::fabric::mr::Access;
    use rdmavisor::fabric::sim::{FabricConfig, Sim};
    use rdmavisor::fabric::types::{NodeId, QpTransport};
    use rdmavisor::fabric::verbs as fv;
    use rdmavisor::fabric::wqe::SendWr;
    use rdmavisor::util::rng::Rng;

    const NODES: u64 = 6;
    let mut fabric = FabricConfig::default();
    fabric.nodes = NODES as usize;
    fabric.sq_depth = 256;
    fabric.shards = shards;
    let mut sim = Sim::new(fabric);
    sim.set_trace(true);
    let mut rng = Rng::new(seed);

    let cqs: Vec<_> = (0..NODES).map(|n| sim.create_cq(NodeId(n as u32), 4096)).collect();
    let mrs: Vec<_> = (0..NODES)
        .map(|n| sim.reg_mr(NodeId(n as u32), 8 << 20, Access::REMOTE_RW, true))
        .collect();
    let mut qps = Vec::new();
    for _ in 0..12 {
        let s = rng.gen_range(NODES) as u32;
        let d = (s + 1 + rng.gen_range(NODES - 1) as u32) % NODES as u32;
        let pair = fv::create_connected_pair(
            &mut sim,
            QpTransport::Rc,
            NodeId(s),
            NodeId(d),
            cqs[s as usize],
            cqs[s as usize],
            cqs[d as usize],
            cqs[d as usize],
        );
        qps.push((s as usize, d as usize, pair.a.1));
    }
    let mut wr_id = 0u64;
    for &(s, d, qpn) in &qps {
        for _ in 0..1 + rng.gen_range(6) {
            let len = 64 + rng.gen_range(4000);
            let off = rng.gen_range((4 << 20) - 4096);
            wr_id += 1;
            let wr = SendWr::write(
                wr_id,
                len,
                mrs[s].key,
                mrs[s].addr + off,
                mrs[d].key,
                mrs[d].addr + off,
            );
            fv::must_post(&mut sim, NodeId(s as u32), qpn, wr);
        }
    }
    sim.run_to_quiescence();
    (sim.steps_processed(), sim.total_rx_data_bytes(), sim.take_trace())
}

#[test]
fn random_storm_trace_is_invariant_across_shard_counts() {
    // the strongest form of the gate: not just the aggregate counters but
    // the exact per-event pop order (time, node, kind) must match the
    // serial executor for every shard count, across several seeds
    for seed in [1u64, 42, 0xDEAD_BEEF] {
        let serial = random_write_storm(seed, 1);
        assert!(serial.0 > 0 && serial.1 > 0, "storm must move traffic: {serial:?}");
        for shards in [2usize, 3, 4, 6] {
            let sharded = random_write_storm(seed, shards);
            assert_eq!(
                serial.0, sharded.0,
                "seed {seed}: event count differs at {shards} shards"
            );
            assert_eq!(
                serial.1, sharded.1,
                "seed {seed}: rx bytes differ at {shards} shards"
            );
            assert_eq!(
                serial.2, sharded.2,
                "seed {seed}: event pop order differs at {shards} shards"
            );
        }
    }
}

#[test]
fn event_storm_events_invariant_across_shard_counts() {
    // the `bench simstep --shards` workload itself: same deterministic
    // event count at every shard count (the wall clock is the only thing
    // allowed to move)
    use rdmavisor::workload::scenarios::{event_storm, event_storm_sharded};
    let serial = event_storm(32, 4, 4096, Ns::from_ms(1));
    assert!(serial > 0);
    for shards in [2usize, 4] {
        assert_eq!(serial, event_storm_sharded(32, 4, 4096, Ns::from_ms(1), shards));
    }
}

// --------------------------------------------- Clos fabric + fig 13 (PR 9)

/// A small fig-13-shaped incast (8 nodes on 2 ToRs) for the shard-count
/// invariance sweeps — small enough to run at several shard counts per
/// CC mode.
fn small_incast(mode: CcMode, shards: usize) -> IncastCfg {
    let mut cfg = IncastCfg::default();
    cfg.writers = 6;
    cfg.hosts_per_tor = 4;
    cfg.tors = 2;
    cfg.oversub = 4;
    cfg.mode = mode;
    cfg.elephants = 2;
    cfg.mice = 2;
    cfg.window = 8;
    cfg.duration = Ns::from_ms(2);
    cfg.shards = shards;
    cfg
}

#[test]
fn fig13_replays_byte_identically() {
    // the Clos fabric end-to-end: ECMP path choice, per-port queue and
    // buffer state, ECN marks, DCQCN rate state, GBN recovery of
    // tail-dropped frames — all under one seed, three CC modes
    assert_fig_deterministic(13);
}

#[test]
fn fig13_parallel_matches_serial() {
    assert_eq!(fig_bytes_jobs(13, 1), fig_bytes_jobs(13, 4), "fig 13: --jobs 4 != --jobs 1");
}

#[test]
fn fig13_sharded_matches_serial() {
    // cross-switch hops are resolved at the coordinator barrier, so the
    // Clos port state must be invariant to how nodes are partitioned
    assert_eq!(fig_bytes(13), fig_bytes_sharded(13, 4), "fig 13: --shards 4 != --shards 1");
}

#[test]
fn fig13_no_cc_matches_serial_under_jobs_and_shards() {
    let run = |jobs, shards| {
        let rows = figures::fig13_no_cc_sharded(Budget::Quick, jobs, shards);
        format!(
            "{}\n{}",
            figures::fig13_series(&rows).to_json().to_string(),
            figures::print_fig13(&rows)
        )
    };
    let serial = run(1, 1);
    assert_eq!(serial, run(4, 1), "fig 13 --no-cc: --jobs 4 != --jobs 1");
    assert_eq!(serial, run(1, 4), "fig 13 --no-cc: --shards 4 != --shards 1");
}

#[test]
fn fig13_pfc_matches_serial_under_jobs_and_shards() {
    // PFC is the delicate sharded case: the pause gate reads the
    // barrier snapshot of uplink horizons, never live remote state
    let run = |jobs, shards| {
        let rows = figures::fig13_pfc_sharded(Budget::Quick, jobs, shards);
        format!(
            "{}\n{}",
            figures::fig13_series(&rows).to_json().to_string(),
            figures::print_fig13(&rows)
        )
    };
    let serial = run(1, 1);
    assert_eq!(serial, run(4, 1), "fig 13 --pfc: --jobs 4 != --jobs 1");
    assert_eq!(serial, run(1, 4), "fig 13 --pfc: --shards 4 != --shards 1");
}

#[test]
fn incast_storm_invariant_across_shard_counts() {
    // every CC mode, every counter — 12 shards > the 8 nodes pins the
    // shard-clamp edge case on the Clos path too
    for mode in [CcMode::Dcqcn, CcMode::NoCc, CcMode::Pfc] {
        let serial = format!("{:?}", incast_storm(&small_incast(mode, 1)));
        for shards in [2usize, 4, 12] {
            assert_eq!(
                serial,
                format!("{:?}", incast_storm(&small_incast(mode, shards))),
                "mode {mode:?}: {shards} shards differ from serial"
            );
        }
    }
}

#[test]
fn incast_spine_flap_replays_across_shard_counts() {
    // PR-4 fault streams riding the Clos fabric: a spine-link flap window
    // must drop the same frames and trigger the same GBN recoveries for
    // every shard count
    let run = |shards| {
        let mut cfg = small_incast(CcMode::Dcqcn, shards);
        cfg.spine_flap = Some((500_000, 900_000));
        incast_storm(&cfg)
    };
    let serial = run(1);
    assert!(serial.ops > 0, "flapped incast must still complete traffic: {serial:?}");
    assert_eq!(format!("{serial:?}"), format!("{:?}", run(4)), "4 shards differ");
}

#[test]
fn fig13_dcqcn_beats_no_cc_at_deepest_incast() {
    // the PR-9 acceptance gate: at the most oversubscribed quick point
    // the rate limiter must pay for itself — no-CC blasts the full
    // closed-loop inventory into the finite switch buffers and burns the
    // bottleneck on go-back-N duplicates, DCQCN paces to capacity
    let deepest = *figures::fig13_oversubs(Budget::Quick).last().expect("non-empty sweep");
    let dcqcn = incast_storm(&figures::fig13_cfg(deepest, Budget::Quick, CcMode::Dcqcn));
    let no_cc = incast_storm(&figures::fig13_cfg(deepest, Budget::Quick, CcMode::NoCc));
    assert!(
        dcqcn.goodput_gbps > no_cc.goodput_gbps,
        "oversub {deepest}: DCQCN {:.3} Gb/s must beat no-CC {:.3} Gb/s",
        dcqcn.goodput_gbps,
        no_cc.goodput_gbps
    );
    assert!(dcqcn.ecn_marks > 0, "congested DCQCN run must mark frames: {dcqcn:?}");
    assert!(no_cc.switch_drops > 0, "uncontrolled incast must overflow buffers: {no_cc:?}");
    assert!(no_cc.retransmits > 0, "dropped frames must force GBN recovery: {no_cc:?}");
}

#[test]
fn fig13_no_cc_goodput_degrades_with_oversubscription() {
    // with CC off, halving the uplinks at every step must never help:
    // monotone (small slack for ECMP hash luck) and strictly worse at
    // the deep end
    let goodput: Vec<f64> = figures::FIG13_OVERSUBS
        .iter()
        .map(|&o| incast_storm(&figures::fig13_cfg(o, Budget::Quick, CcMode::NoCc)).goodput_gbps)
        .collect();
    for pair in goodput.windows(2) {
        assert!(
            pair[1] <= pair[0] * 1.05,
            "no-CC goodput must not rise with oversubscription: {goodput:?}"
        );
    }
    assert!(
        *goodput.last().unwrap() < goodput[0],
        "deepest oversubscription must cost goodput: {goodput:?}"
    );
}

// -------------------------------------------- survivable Clos + fig 14 (PR 10)

#[test]
fn fig14_replays_byte_identically() {
    // the whole failover machinery — switch-fault events at the barrier,
    // ECMP route epochs, blackhole salt bumps, daemon park/replay — under
    // the determinism gate: same tape ⇒ byte-identical JSON
    assert_fig_deterministic(14);
}

#[test]
fn fig14_parallel_matches_serial() {
    assert_eq!(fig_bytes_jobs(14, 1), fig_bytes_jobs(14, 4), "fig 14: --jobs 4 != --jobs 1");
}

#[test]
fn fig14_sharded_matches_serial() {
    // switch faults apply at the conservative barrier before absorption,
    // so the post-failure timeline must be invariant to the partitioning
    // — at 2 shards and 4
    let serial = fig_bytes(14);
    assert_eq!(serial, fig_bytes_sharded(14, 2), "fig 14: --shards 2 != --shards 1");
    assert_eq!(serial, fig_bytes_sharded(14, 4), "fig 14: --shards 4 != --shards 1");
}

#[test]
fn fig14_repath_off_matches_serial_under_jobs_and_shards() {
    // the `fig --id 14 --repath-off` CLI path (frozen-routing ablation)
    let run = |jobs, shards| {
        let rows = figures::fig14_repath_off_sharded(Budget::Quick, jobs, shards);
        format!(
            "{}\n{}",
            figures::fig14_series(&rows).to_json().to_string(),
            figures::print_fig14(&rows)
        )
    };
    let serial = run(1, 1);
    assert_eq!(serial, run(4, 1), "fig 14 --repath-off: --jobs 4 != --jobs 1");
    assert_eq!(serial, run(1, 4), "fig 14 --repath-off: --shards 4 != --shards 1");
}

#[test]
fn repath_epochs_replay_across_shard_counts() {
    // the repath-epoch gate: the route-epoch counter, the detector's salt
    // bumps and the daemon's heal ledger are all coordinator-side state —
    // a shard split must not move a single recovery event
    let run = |shards| {
        let mut cfg = figures::fig14_cfg(Budget::Quick, true);
        cfg.shards = shards;
        let r = failover_storm(&cfg);
        (
            r.route_epoch,
            r.repaths,
            r.qp_reestablished,
            r.heal_giveups,
            r.retry_exceeded,
            r.blackhole_drops,
            format!("{r:?}"),
        )
    };
    let serial = run(1);
    assert!(serial.0 > 0, "the failure tape must bump the route epoch: {serial:?}");
    for shards in [2usize, 4] {
        assert_eq!(serial, run(shards), "{shards} shards replay different recovery events");
    }
}

#[test]
fn fig14_repath_recovers_goodput_and_ablation_does_not() {
    // the PR-10 acceptance gate, both halves on the quick tape:
    // with repath + healing on, post-failure goodput returns to ≥90% of
    // pre-failure and both recovery mechanisms demonstrably fired; with
    // them off, flows die (retry_exceeded) and the fabric ends the run
    // strictly worse
    let on = failover_storm(&figures::fig14_cfg(Budget::Quick, true));
    assert!(
        on.post_gbps >= 0.9 * on.pre_gbps,
        "repath-on must recover ≥90% of pre-failure goodput: pre {:.2} post {:.2}",
        on.pre_gbps,
        on.post_gbps
    );
    assert!(on.repaths > 0, "the blackhole detector must fire: {on:?}");
    assert!(on.qp_reestablished > 0, "daemon healing must revive a QP: {on:?}");
    assert!(on.route_epoch > 0, "reconvergence must bump the epoch: {on:?}");

    let off = failover_storm(&figures::fig14_cfg(Budget::Quick, false));
    assert!(off.retry_exceeded > 0, "frozen routing must kill flows: {off:?}");
    assert!(
        off.post_gbps < on.post_gbps,
        "the ablation must end strictly worse: off {:.2} vs on {:.2} Gb/s",
        off.post_gbps,
        on.post_gbps
    );
    assert!(
        off.flows_alive < on.flows_alive,
        "dead flows must show in the survivor count: off {} vs on {}",
        off.flows_alive,
        on.flows_alive
    );
}

// ------------------------------------- event-queue horizon + shard clamps
// (the PR-9 verification-debt sweep: regression-pins for the fig 9–12
// full-budget hints — timing-wheel overflow past ~1.07 s, merged-counter
// drift, shard counts above the node count)

#[test]
fn event_queue_orders_across_the_long_horizon() {
    // timestamps straddling 2^30 (the ~1.07 s wheel horizon), 2^32 and
    // 2^40, pushed scrambled, must pop in time order
    use rdmavisor::fabric::event::EventQueue;
    let times: [u64; 10] = [
        0,
        999,
        1 << 20,
        (1 << 30) - 1,
        1 << 30,
        (1 << 30) + 1,
        (1u64 << 32) + 7,
        3_000_000_000,
        1u64 << 40,
        (1u64 << 40) + 1,
    ];
    let scramble = [5usize, 0, 8, 3, 9, 1, 7, 2, 6, 4];
    let mut q = EventQueue::new();
    for &i in &scramble {
        q.push(Ns(times[i]), i);
    }
    let mut popped = Vec::new();
    while let Some((at, i)) = q.pop() {
        assert_eq!(at.0, times[i], "payload must ride with its timestamp");
        popped.push(at.0);
    }
    let mut sorted = popped.clone();
    sorted.sort_unstable();
    assert_eq!(popped, sorted, "pops must come out time-ordered across the horizon");
    assert_eq!(popped.len(), times.len());
}

#[test]
fn rc_timers_cross_the_wheel_horizon_identically_at_any_shard_count() {
    // black-hole the wire so only retransmission timers advance the
    // clock: three 1.5 s timeouts march the Sim far past the 2^30 ns
    // wheel horizon on a handful of events, at 1, 2 and 5 (> nodes)
    // shards — clock, step count and fault counters must all agree
    use rdmavisor::fabric::fault::FaultConfig;
    use rdmavisor::fabric::mr::Access;
    use rdmavisor::fabric::sim::{FabricConfig, Sim};
    use rdmavisor::fabric::types::{NodeId, QpTransport};
    use rdmavisor::fabric::verbs as fv;
    use rdmavisor::fabric::wqe::SendWr;

    let run = |shards: usize| {
        let mut fabric = FabricConfig::default();
        fabric.nodes = 2;
        fabric.shards = shards;
        fabric.nic.retransmit_timeout_ns = 1_500_000_000;
        fabric.nic.retry_cnt = 2;
        let mut sim = Sim::new(fabric);
        let mut faults = FaultConfig::default();
        faults.drop_p = 1.0;
        sim.install_faults(faults);
        let cq_a = sim.create_cq(NodeId(0), 64);
        let cq_b = sim.create_cq(NodeId(1), 64);
        let mr_a = sim.reg_mr(NodeId(0), 1 << 20, Access::REMOTE_RW, true);
        let mr_b = sim.reg_mr(NodeId(1), 1 << 20, Access::REMOTE_RW, true);
        let pair = fv::create_connected_pair(
            &mut sim,
            QpTransport::Rc,
            NodeId(0),
            NodeId(1),
            cq_a,
            cq_a,
            cq_b,
            cq_b,
        );
        fv::must_post(
            &mut sim,
            NodeId(0),
            pair.a.1,
            SendWr::write(1, 4096, mr_a.key, mr_a.addr, mr_b.key, mr_b.addr),
        );
        sim.run_to_quiescence();
        (sim.now().0, sim.steps_processed(), format!("{:?}", sim.fault_stats()))
    };
    let serial = run(1);
    assert!(
        serial.0 > (1u64 << 30),
        "the run must outlive the 2^30 ns wheel horizon: {serial:?}"
    );
    for shards in [2usize, 5] {
        assert_eq!(serial, run(shards), "{shards} shards differ from serial");
    }
}

// ------------------------------------------------------ scenario drivers

fn tiny_scenario(conns: usize) -> ScenarioCfg {
    let mut cfg = ScenarioCfg::default();
    cfg.conns = conns;
    cfg.duration = Ns::from_ms(3);
    cfg.seed = 7;
    cfg
}

#[test]
fn naive_scenario_replays_byte_identically() {
    let cfg = tiny_scenario(64);
    let a = format!("{:?}", naive_random_read(&cfg));
    let b = format!("{:?}", naive_random_read(&cfg));
    assert_eq!(a, b);
}

#[test]
fn raas_scenario_replays_byte_identically() {
    // multiple remotes: this is the path where HashMap-ordered batch
    // flushing used to leak the hasher seed into the timeline
    let cfg = tiny_scenario(96);
    let a = format!("{:?}", raas_random_read(&cfg));
    let b = format!("{:?}", raas_random_read(&cfg));
    assert_eq!(a, b);
}

#[test]
fn locked_scenario_replays_byte_identically() {
    let mut cfg = tiny_scenario(12);
    cfg.msg_bytes = 512;
    cfg.window = 4;
    let a = format!("{:?}", locked_random_read(&cfg, 3));
    let b = format!("{:?}", locked_random_read(&cfg, 3));
    assert_eq!(a, b);
}

#[test]
fn verbs_sweep_replays_byte_identically() {
    use rdmavisor::fabric::types::{QpTransport, Verb};
    let run = || {
        verbs_sweep_point(QpTransport::Rc, Verb::Write, 16 << 10, 8, Ns::from_ms(2))
    };
    assert_eq!(format!("{:?}", run()), format!("{:?}", run()));
}

#[test]
fn chaos_scenario_replays_byte_identically() {
    // lossy + flapping + restarting: the hardest determinism case — the
    // fault RNG stream, retransmission timers and restart events must
    // all replay bit-identically from the seed
    let mut cfg = ChaosCfg::default();
    cfg.conns = 64;
    cfg.duration = Ns::from_ms(2);
    cfg.loss = 0.03;
    cfg.flaps = 2;
    cfg.server_restarts = 1;
    let a = format!("{:?}", chaos_send(&cfg));
    let b = format!("{:?}", chaos_send(&cfg));
    assert_eq!(a, b);

    // the rc-only ablation too
    cfg.rc_only = true;
    let a = format!("{:?}", chaos_send(&cfg));
    let b = format!("{:?}", chaos_send(&cfg));
    assert_eq!(a, b);

    // and the loss-0 null plan (the lossless-identity clause): zero fault
    // counters, still deterministic
    cfg.rc_only = false;
    cfg.loss = 0.0;
    cfg.flaps = 0;
    cfg.server_restarts = 0;
    let r = chaos_send(&cfg);
    assert_eq!(format!("{r:?}"), format!("{:?}", chaos_send(&cfg)));
    assert_eq!(r.frames_dropped + r.frames_delayed + r.retransmits + r.restarts, 0);
}

#[test]
fn kv_scenario_replays_byte_identically() {
    // the KV storm driver on its own (outside the figure harness): Zipf
    // key streams, per-client windows, doorbell flushes and the stalled
    // retry list must all replay from the seed — both modes
    let mut cfg = KvCfg::default();
    cfg.clients = 96;
    cfg.max_servers = 4;
    cfg.duration = Ns::from_ms(2);
    let a = format!("{:?}", kv_storm(&cfg));
    let b = format!("{:?}", kv_storm(&cfg));
    assert_eq!(a, b);

    // the SEND-RPC ablation too
    cfg.rpc = true;
    let a = format!("{:?}", kv_storm(&cfg));
    let b = format!("{:?}", kv_storm(&cfg));
    assert_eq!(a, b);
}

#[test]
fn churn_scenario_replays_byte_identically() {
    // the churn driver on its own (outside the figure harness): arrival
    // RNG, departure buckets, park/revive order, lease backlog order and
    // the TTFB histogram must all replay from the seed — both modes
    let mut cfg = ChurnCfg::default();
    cfg.conns = 1_500;
    let a = format!("{:?}", churn_storm(&cfg));
    let b = format!("{:?}", churn_storm(&cfg));
    assert_eq!(a, b);

    // the cold ablation too
    cfg.cold = true;
    let a = format!("{:?}", churn_storm(&cfg));
    let b = format!("{:?}", churn_storm(&cfg));
    assert_eq!(a, b);
}

#[test]
fn scale_scenario_replays_byte_identically() {
    // 300 destinations > the 200-dest RC budget: the adaptive run
    // migrates its working set to UD (exercising the whole migration
    // machinery), the rc-only run below covers the connected path
    let mut cfg = ScaleCfg::default();
    cfg.conns = 300;
    cfg.duration = Ns::from_ms(2);
    let a = format!("{:?}", scale_send(&cfg));
    let b = format!("{:?}", scale_send(&cfg));
    assert_eq!(a, b);

    // the rc-only ablation too
    cfg.rc_only = true;
    let a = format!("{:?}", scale_send(&cfg));
    let b = format!("{:?}", scale_send(&cfg));
    assert_eq!(a, b);
}
