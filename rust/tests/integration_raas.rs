//! Integration: RDMAvisor daemon over the fabric — multi-node, multi-app.

use rdmavisor::fabric::sim::{FabricConfig, Sim};
use rdmavisor::fabric::types::{NodeId, Verb};
use rdmavisor::raas::api::{Flags, RaasError, Target};
use rdmavisor::raas::daemon::{connect_target, connect_via, Daemon, DaemonConfig, Delivery};
use rdmavisor::raas::transport::HostLoad;

fn cluster(n: usize) -> (Sim, Vec<Daemon>) {
    let mut cfg = FabricConfig::default();
    cfg.nodes = n;
    cfg.sq_depth = 8192;
    let mut sim = Sim::new(cfg);
    let daemons = (0..n)
        .map(|i| Daemon::start(&mut sim, NodeId(i as u32), DaemonConfig::default()))
        .collect();
    (sim, daemons)
}

fn settle(sim: &mut Sim, daemons: &mut [Daemon]) {
    for _ in 0..2_000_000 {
        for d in daemons.iter_mut() {
            d.pump(sim);
        }
        if sim.step().is_none() {
            for d in daemons.iter_mut() {
                d.pump(sim);
            }
            if sim.pending_events() == 0 {
                return;
            }
        }
    }
    panic!("no quiescence");
}

#[test]
fn thousand_connections_three_shared_qps() {
    let (mut sim, mut daemons) = cluster(4);
    for i in 1..4 {
        let app = daemons[i].register_app();
        daemons[i].listen(app, 1);
    }
    let app = daemons[0].register_app();
    let mut conns = Vec::new();
    for i in 0..1000usize {
        let server = 1 + i % 3;
        conns.push(connect_via(&mut sim, &mut daemons, 0, app, server, 1).unwrap());
    }
    assert_eq!(daemons[0].conns.active(), 1000);
    assert_eq!(daemons[0].shared_qp_count(), 3, "1000 conns, 3 QPs");
    // 3 shared RC QPs + the daemon's host-wide UD QP
    assert_eq!(sim.node(NodeId(0)).qps.len(), 4);

    // every connection can actually move data
    for (i, c) in conns.iter().enumerate().take(50) {
        daemons[0].read(&mut sim, *c, 4096, (i * 4096) as u64, i as u64).unwrap();
    }
    settle(&mut sim, &mut daemons);
    let mut ok = 0;
    while let Some(d) = daemons[0].recv_zero_copy(&mut sim, app) {
        if matches!(d, Delivery::OpComplete { ok: true, .. }) {
            ok += 1;
        }
    }
    assert_eq!(ok, 50);
}

#[test]
fn connect_via_target_address_forms() {
    let (mut sim, mut daemons) = cluster(3);
    let sapp = daemons[2].register_app();
    daemons[2].listen(sapp, 9);
    let app = daemons[0].register_app();
    // IPv4 host byte routes to node 2
    let c = connect_target(&mut sim, &mut daemons, 0, app, Target::Ipv4([10, 0, 0, 2], 9), 9)
        .unwrap();
    assert_eq!(daemons[0].conns.lookup(c).unwrap().remote, NodeId(2));
    // LID form
    let c2 = connect_target(&mut sim, &mut daemons, 0, app, Target::Lid(2), 9).unwrap();
    assert_eq!(daemons[0].conns.lookup(c2).unwrap().remote, NodeId(2));
    // both reuse ONE shared QP
    assert_eq!(daemons[0].shared_qp_count(), 1);
}

#[test]
fn flags_pin_rejected_combinations() {
    let (mut sim, mut daemons) = cluster(2);
    let sapp = daemons[1].register_app();
    daemons[1].listen(sapp, 1);
    let app = daemons[0].register_app();
    let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
    let err = daemons[0]
        .send(&mut sim, conn, 64, Flags::UC | Flags::READ, 0, HostLoad::default())
        .unwrap_err();
    assert!(matches!(err, RaasError::UnsupportedCombination(..)));
}

#[test]
fn bidirectional_traffic_same_shared_qp() {
    let (mut sim, mut daemons) = cluster(2);
    let sapp = daemons[1].register_app();
    daemons[1].listen(sapp, 1);
    let capp = daemons[0].register_app();
    let conn = connect_via(&mut sim, &mut daemons, 0, capp, 1, 1).unwrap();
    let sconn = daemons[1].accept(sapp, 1).unwrap();

    daemons[0]
        .send(&mut sim, conn, 1024, Flags::default(), 1, HostLoad::default())
        .unwrap();
    daemons[1]
        .send(&mut sim, sconn, 2048, Flags::default(), 2, HostLoad::default())
        .unwrap();
    settle(&mut sim, &mut daemons);

    let to_server = daemons[1].recv_zero_copy(&mut sim, sapp);
    assert!(matches!(to_server, Some(Delivery::Message { len: 1024, .. })), "{to_server:?}");
    // drain client inbox: should contain its own OpComplete AND the reply
    let mut got_msg = false;
    while let Some(d) = daemons[0].recv(&mut sim, capp) {
        if matches!(d, Delivery::Message { len: 2048, .. }) {
            got_msg = true;
        }
    }
    assert!(got_msg, "server->client message must arrive");
    assert_eq!(daemons[0].shared_qp_count(), 1);
    assert_eq!(daemons[1].shared_qp_count(), 1);
}

#[test]
fn many_apps_share_daemon_resources() {
    let (mut sim, mut daemons) = cluster(2);
    let sapp = daemons[1].register_app();
    daemons[1].listen(sapp, 1);
    let mut apps = Vec::new();
    for _ in 0..16 {
        let a = daemons[0].register_app();
        let c = connect_via(&mut sim, &mut daemons, 0, a, 1, 1).unwrap();
        apps.push((a, c));
    }
    let snap = daemons[0].snapshot(&sim);
    assert_eq!(snap.apps, 16);
    assert_eq!(snap.shared_qps, 1, "16 apps, still one QP to the peer");

    for (i, (_, c)) in apps.iter().enumerate() {
        daemons[0].read(&mut sim, *c, 8192, (i * 8192) as u64, i as u64).unwrap();
    }
    settle(&mut sim, &mut daemons);
    for (a, _) in &apps {
        let d = daemons[0].recv_zero_copy(&mut sim, *a);
        assert!(
            matches!(d, Some(Delivery::OpComplete { ok: true, .. })),
            "app {a} delivery: {d:?}"
        );
    }
}

#[test]
fn adaptive_selection_end_to_end() {
    let (mut sim, mut daemons) = cluster(2);
    let sapp = daemons[1].register_app();
    daemons[1].listen(sapp, 1);
    let app = daemons[0].register_app();
    let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

    let v_small = daemons[0]
        .send(&mut sim, conn, 256, Flags::default(), 1, HostLoad::default())
        .unwrap();
    let v_large = daemons[0]
        .send(&mut sim, conn, 512 << 10, Flags::default(), 2, HostLoad::default())
        .unwrap();
    assert_eq!(v_small, Verb::Send);
    assert_eq!(v_large, Verb::Write);
    assert_eq!(daemons[0].selector.chose_send, 1);
    assert_eq!(daemons[0].selector.chose_write, 1);
    settle(&mut sim, &mut daemons);
    let mut lens = Vec::new();
    while let Some(Delivery::Message { len, .. }) = daemons[1].recv_zero_copy(&mut sim, sapp) {
        lens.push(len);
    }
    lens.sort_unstable();
    assert_eq!(lens, vec![256, 512 << 10]);
}

#[test]
fn srq_driven_below_watermark_refills_and_pool_exhaustion_backpressures() {
    // Receiver with a small SRQ: a burst of sends drives the posted WQE
    // count below the watermark; the next pump must refill to capacity.
    // Sender with a tiny pool: once every slab slot is leased, send()
    // must return PoolExhausted — an error, not a drop or a deadlock.
    let mut fcfg = FabricConfig::default();
    fcfg.nodes = 2;
    fcfg.sq_depth = 8192;
    let mut sim = Sim::new(fcfg);

    let mut sender_cfg = DaemonConfig::default();
    // 8 × 4 KB slots and nothing else; SRQ recv leases are recycled in
    // place, so all 8 slots are available to stage outgoing sends
    sender_cfg.pool_layout = vec![(4096, 8)];
    sender_cfg.recv_slot_bytes = 4096;
    sender_cfg.srq_capacity = 4;
    let mut receiver_cfg = DaemonConfig::default();
    receiver_cfg.srq_capacity = 8;
    receiver_cfg.srq_watermark = 4;

    let mut daemons = vec![
        Daemon::start(&mut sim, NodeId(0), sender_cfg),
        Daemon::start(&mut sim, NodeId(1), receiver_cfg),
    ];
    let sapp = daemons[1].register_app();
    daemons[1].listen(sapp, 1);
    let app = daemons[0].register_app();
    let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

    let srqn = sim.node(NodeId(1)).srqs.iter().next().unwrap().srqn.0;
    assert_eq!(sim.node(NodeId(1)).srqs[srqn].posted(), 8, "pre-filled");

    // burst of 6 sends: consumes 6 receiver WQEs => below the watermark
    for i in 0..6 {
        daemons[0]
            .send(&mut sim, conn, 1024, Flags::default(), i, HostLoad::default())
            .unwrap();
    }
    daemons[0].pump(&mut sim);
    while sim.step().is_some() {}
    let srq = &sim.node(NodeId(1)).srqs[srqn];
    assert!(srq.consumed >= 6, "consumed={}", srq.consumed);
    assert!(srq.starved_events > 0, "burst must dip below the watermark");
    assert!(srq.posted() < 4, "drained before the Poller refills");

    // receiver pump refills the SRQ back to capacity from the pool
    daemons[1].pump(&mut sim);
    assert_eq!(sim.node(NodeId(1)).srqs[srqn].posted(), 8, "refilled");
    assert!(!sim.node(NodeId(1)).srqs[srqn].is_starving());

    // drain the sender's completions so the first burst's leases return
    settle(&mut sim, &mut daemons);
    assert_eq!(daemons[0].pool.leased_bytes, 0, "burst leases released");

    // sender-side exhaustion: 8 slots, keep sends in flight without
    // pumping so leases accumulate; the 9th must error out cleanly
    let mut sent = 0;
    let mut exhausted = false;
    for i in 0..16 {
        match daemons[0].send(&mut sim, conn, 1024, Flags::default(), i, HostLoad::default()) {
            Ok(_) => sent += 1,
            Err(RaasError::PoolExhausted) => {
                exhausted = true;
                break;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(sent, 8, "exactly the slab capacity");
    assert!(exhausted, "9th lease must fail with PoolExhausted");
    assert_eq!(daemons[0].pool.exhausted, 1);

    // backpressure recovers: complete the in-flight sends, then send again
    settle(&mut sim, &mut daemons);
    assert_eq!(daemons[0].pool.leased_bytes, 0, "all leases released");
    daemons[0]
        .send(&mut sim, conn, 1024, Flags::default(), 99, HostLoad::default())
        .expect("pool recovered after completions");
    settle(&mut sim, &mut daemons);
}

#[test]
fn srq_shared_across_all_apps_on_host() {
    // the §1.2 observation: SRQs shared among applications on one machine
    let (mut sim, mut daemons) = cluster(2);
    let s1 = daemons[1].register_app();
    daemons[1].listen(s1, 1);
    let s2 = daemons[1].register_app();
    daemons[1].listen(s2, 2);

    let a = daemons[0].register_app();
    let c1 = connect_via(&mut sim, &mut daemons, 0, a, 1, 1).unwrap();
    let c2 = connect_via(&mut sim, &mut daemons, 0, a, 1, 2).unwrap();

    daemons[0].send(&mut sim, c1, 100, Flags::default(), 1, HostLoad::default()).unwrap();
    daemons[0].send(&mut sim, c2, 200, Flags::default(), 2, HostLoad::default()).unwrap();
    settle(&mut sim, &mut daemons);

    // both apps' messages consumed WQEs from the ONE host-wide SRQ
    assert_eq!(sim.node(NodeId(1)).srqs.len(), 1);
    assert!(sim.node(NodeId(1)).srqs.iter().next().unwrap().consumed >= 2);
    assert!(matches!(
        daemons[1].recv_zero_copy(&mut sim, s1),
        Some(Delivery::Message { len: 100, .. })
    ));
    assert!(matches!(
        daemons[1].recv_zero_copy(&mut sim, s2),
        Some(Delivery::Message { len: 200, .. })
    ));
}
