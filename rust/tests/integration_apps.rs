//! Integration: applications (KV store, RPC) over RaaS across nodes,
//! plus the live inference engine round-trip when artifacts exist.

use rdmavisor::apps::kv::{KvClient, KvLayout, KvServer};
use rdmavisor::apps::rpc::{RpcClient, RpcServer};
use rdmavisor::fabric::sim::{FabricConfig, Sim};
use rdmavisor::fabric::types::NodeId;
use rdmavisor::raas::daemon::{connect_via, Daemon, DaemonConfig};

fn cluster(n: usize) -> (Sim, Vec<Daemon>) {
    let mut cfg = FabricConfig::default();
    cfg.nodes = n;
    cfg.sq_depth = 8192;
    let mut sim = Sim::new(cfg);
    let daemons = (0..n)
        .map(|i| Daemon::start(&mut sim, NodeId(i as u32), DaemonConfig::default()))
        .collect();
    (sim, daemons)
}

fn drive(sim: &mut Sim, daemons: &mut [Daemon], iters: usize) {
    for _ in 0..iters {
        for d in daemons.iter_mut() {
            d.pump(sim);
        }
        if sim.step().is_none() {
            for d in daemons.iter_mut() {
                d.pump(sim);
            }
            if sim.pending_events() == 0 {
                return;
            }
        }
    }
}

#[test]
fn kv_multiclient_gets_and_puts() {
    let (mut sim, mut daemons) = cluster(4);
    let layout = KvLayout { slots: 4096, slot_bytes: 1024 };
    let mut server = KvServer::new(&mut daemons[0], 6000, layout);

    let mut clients = Vec::new();
    for node in 1..4usize {
        let app = daemons[node].register_app();
        let conn = connect_via(&mut sim, &mut daemons, node, app, 0, 6000).unwrap();
        clients.push((node, KvClient::new(app, conn, layout, node as u64, 0.99)));
    }
    for (node, c) in clients.iter_mut() {
        for _ in 0..10 {
            c.get(&mut sim, &mut daemons[*node]).unwrap();
        }
        c.put(&mut sim, &mut daemons[*node], 512).unwrap();
    }
    drive(&mut sim, &mut daemons, 3_000_000);
    server.service(&mut sim, &mut daemons[0]);
    let mut total_done = 0;
    for (node, c) in clients.iter_mut() {
        total_done += c.drain(&mut sim, &mut daemons[*node]);
    }
    assert_eq!(total_done, 3 * 11, "10 gets + 1 put per client");
    assert_eq!(server.puts_applied, 3);
    // GETs are one-sided: server daemon never saw them as messages
    assert_eq!(daemons[0].stats.msgs_delivered, 3);
}

#[test]
fn rpc_many_clients_one_server() {
    let (mut sim, mut daemons) = cluster(3);
    let mut server = RpcServer::new(&mut daemons[0], 5000, 128);
    let mut clients = Vec::new();
    for node in 1..3usize {
        for _ in 0..4 {
            let app = daemons[node].register_app();
            let conn = connect_via(&mut sim, &mut daemons, node, app, 0, 5000).unwrap();
            clients.push((node, RpcClient::new(app, conn, 64)));
        }
    }
    for (node, c) in clients.iter_mut() {
        for _ in 0..5 {
            c.call(&mut sim, &mut daemons[*node]).unwrap();
        }
    }
    // drive with server servicing inline
    for _ in 0..3_000_000 {
        for d in daemons.iter_mut() {
            d.pump(&mut sim);
        }
        server.service(&mut sim, &mut daemons[0]).unwrap();
        if sim.step().is_none() {
            for d in daemons.iter_mut() {
                d.pump(&mut sim);
            }
            server.service(&mut sim, &mut daemons[0]).unwrap();
            if sim.pending_events() == 0 {
                break;
            }
        }
    }
    let mut responses = 0;
    for (node, c) in clients.iter_mut() {
        responses += c.drain(&mut sim, &mut daemons[*node]);
    }
    assert_eq!(server.served, 40);
    assert_eq!(responses, 40, "every rpc answered");
    // 8 logical connections, but the server holds only 2 shared QPs
    assert_eq!(daemons[0].shared_qp_count(), 2);
}

#[test]
fn live_inference_engine_round_trip() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    use rdmavisor::apps::inference::InferenceEngine;
    let engine = InferenceEngine::new("artifacts", 2, 64);
    let server = {
        let e = engine.clone();
        std::thread::spawn(move || e.serve_loop())
    };
    for tag in 0..6u64 {
        assert!(engine.submit((tag % 2) as usize, tag));
    }
    let t0 = std::time::Instant::now();
    let mut got = std::collections::BTreeSet::new();
    while got.len() < 6 {
        for c in 0..2 {
            for t in engine.reap(c) {
                got.insert(t);
            }
        }
        assert!(t0.elapsed().as_secs() < 300, "serving timed out; got {got:?}");
        std::thread::yield_now();
    }
    engine.stop();
    engine.channels[0].submit_bell.ring();
    let _ = server.join();
    assert_eq!(got, (0..6).collect());
    let st = engine.stats.lock().unwrap();
    assert_eq!(st.requests, 6);
    assert!(st.batches >= 1);
}
