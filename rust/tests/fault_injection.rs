//! Fault-injection integration: lossy links, RC go-back-N retransmission,
//! retry exhaustion under link flaps, exactly-once delivery when ACKs are
//! lost, jitter-induced reordering, node restarts, UD fragment loss and
//! the daemon's stale-lease reclaim.

use rdmavisor::fabric::fault::{FaultConfig, Flap};
use rdmavisor::fabric::mr::Access;
use rdmavisor::fabric::sim::{FabricConfig, Sim};
use rdmavisor::fabric::time::Ns;
use rdmavisor::fabric::types::{NodeId, QpTransport, WcStatus};
use rdmavisor::fabric::verbs;
use rdmavisor::fabric::wqe::SendWr;
use rdmavisor::raas::api::{Flags, RaasError};
use rdmavisor::raas::daemon::{connect_via, disconnect_via, Daemon, DaemonConfig, Delivery};
use rdmavisor::raas::transport::HostLoad;

/// Two-node RC harness: (cq0, cq1, qpn0, qpn1, local mr, remote mr).
struct RcPair {
    cq0: rdmavisor::fabric::types::Cqn,
    cq1: rdmavisor::fabric::types::Cqn,
    q0: rdmavisor::fabric::types::Qpn,
    q1: rdmavisor::fabric::types::Qpn,
    local: rdmavisor::fabric::mr::MemoryRegion,
    remote: rdmavisor::fabric::mr::MemoryRegion,
}

fn rc_pair(sim: &mut Sim) -> RcPair {
    let cq0 = sim.create_cq(NodeId(0), 1 << 14);
    let cq1 = sim.create_cq(NodeId(1), 1 << 14);
    let pair = verbs::create_connected_pair(
        sim,
        QpTransport::Rc,
        NodeId(0),
        NodeId(1),
        cq0,
        cq0,
        cq1,
        cq1,
    );
    let local = sim.reg_mr(NodeId(0), 64 << 20, Access::REMOTE_RW, true);
    let remote = sim.reg_mr(NodeId(1), 64 << 20, Access::REMOTE_RW, true);
    RcPair { cq0, cq1, q0: pair.a.1, q1: pair.b.1, local, remote }
}

fn drain(sim: &mut Sim) {
    let mut guard = 0u64;
    while sim.step().is_some() {
        guard += 1;
        assert!(guard < 20_000_000, "simulation did not quiesce");
    }
}

#[test]
fn drops_are_recovered_by_retransmission() {
    let mut sim = Sim::new(FabricConfig::default());
    sim.install_faults(FaultConfig { seed: 11, drop_p: 0.08, ..FaultConfig::default() });
    let h = rc_pair(&mut sim);
    let n = 40u64;
    for i in 0..n {
        sim.post_send(
            NodeId(0),
            h.q0,
            SendWr::write(i, 8 << 10, h.local.key, h.local.addr, h.remote.key, h.remote.addr),
        )
        .unwrap();
    }
    drain(&mut sim);
    let cqes = sim.poll_cq(NodeId(0), h.cq0, 10_000);
    assert_eq!(cqes.len() as u64, n, "every message completes exactly once");
    let mut seen = std::collections::HashSet::new();
    let mut ok = 0;
    for c in &cqes {
        assert!(seen.insert(c.wr_id), "wr {} completed twice", c.wr_id);
        if c.status == WcStatus::Success {
            ok += 1;
        } else {
            assert_eq!(c.status, WcStatus::RetryExceeded);
        }
    }
    assert!(ok >= n - 2, "8% loss should rarely exhaust 7 retries: {ok}/{n} ok");
    assert!(sim.node(NodeId(0)).retransmits > 0, "loss must force retransmissions");
    let fs = sim.fault_stats().expect("plan installed");
    assert!(fs.frames_dropped > 0);
}

#[test]
fn permanent_flap_exhausts_the_retry_budget() {
    let mut sim = Sim::new(FabricConfig::default());
    sim.install_faults(FaultConfig {
        seed: 1,
        flaps: vec![Flap {
            src: NodeId(0),
            dst: NodeId(1),
            from: Ns(0),
            until: Ns(1_000_000_000),
        }],
        ..FaultConfig::default()
    });
    let h = rc_pair(&mut sim);
    let n = 5u64;
    for i in 0..n {
        sim.post_send(
            NodeId(0),
            h.q0,
            SendWr::write(i, 4096, h.local.key, h.local.addr, h.remote.key, h.remote.addr),
        )
        .unwrap();
    }
    drain(&mut sim);
    let cqes = sim.poll_cq(NodeId(0), h.cq0, 100);
    assert_eq!(cqes.len() as u64, n, "RetryExceeded must complete the window, not hang it");
    for c in &cqes {
        assert_eq!(c.status, WcStatus::RetryExceeded, "{c:?}");
        assert_eq!(c.len, 0);
    }
    assert_eq!(sim.node(NodeId(0)).retry_exceeded, n);
    // the first exhaustion error-flushes the whole QP (real RC flushes
    // outstanding WRs when the QP faults), so the trigger message burned
    // its full budget and the rest burned most of theirs
    let retransmits = sim.node(NodeId(0)).retransmits;
    let retry_cnt = sim.cfg.nic.retry_cnt as u64;
    assert!(
        retransmits >= retry_cnt && retransmits <= n * retry_cnt,
        "retransmits={retransmits}"
    );
    assert_eq!(sim.node(NodeId(0)).qps[h.q0.0].outstanding, 0, "window fully released");
}

#[test]
fn lost_acks_are_reacked_without_redelivery() {
    // flap the ACK direction only, shorter than the retry budget: data
    // arrives once, duplicates get re-ACKed, the requester completes,
    // and the responder never delivers twice
    let mut sim = Sim::new(FabricConfig::default());
    sim.install_faults(FaultConfig {
        seed: 3,
        flaps: vec![Flap { src: NodeId(1), dst: NodeId(0), from: Ns(0), until: Ns(200_000) }],
        ..FaultConfig::default()
    });
    let h = rc_pair(&mut sim);
    // receive WQEs for the SENDs
    let mut next = 0u64;
    verbs::replenish_rq(&mut sim, NodeId(1), h.q1, &h.remote, 8192, 64, &mut next);
    let n = 8u64;
    for i in 0..n {
        sim.post_send(
            NodeId(0),
            h.q0,
            SendWr::send(i, 2048, h.local.key, h.local.addr, i as u32),
        )
        .unwrap();
    }
    drain(&mut sim);
    let reqs = sim.poll_cq(NodeId(0), h.cq0, 100);
    assert_eq!(reqs.len() as u64, n);
    for c in &reqs {
        assert_eq!(c.status, WcStatus::Success, "{c:?}");
    }
    // exactly-once delivery at the responder
    let recvs = sim.poll_cq(NodeId(1), h.cq1, 100);
    assert_eq!(recvs.len() as u64, n, "each message delivered exactly once");
    let imms: std::collections::HashSet<u32> =
        recvs.iter().map(|c| c.imm_data.expect("send carries imm")).collect();
    assert_eq!(imms.len() as u64, n, "no duplicate deliveries");
    assert!(sim.node(NodeId(1)).gbn_dup_acks > 0, "retransmits must have been re-ACKed");
    assert!(sim.node(NodeId(0)).retransmits > 0);
}

#[test]
fn jitter_reordering_is_recovered_in_order() {
    let mut sim = Sim::new(FabricConfig::default());
    sim.install_faults(FaultConfig {
        seed: 7,
        jitter_p: 0.3,
        jitter_ns: (500, 20_000),
        ..FaultConfig::default()
    });
    let h = rc_pair(&mut sim);
    let mut next = 0u64;
    verbs::replenish_rq(&mut sim, NodeId(1), h.q1, &h.remote, 16 << 10, 128, &mut next);
    let n = 30u64;
    for i in 0..n {
        sim.post_send(
            NodeId(0),
            h.q0,
            SendWr::send(i, 12 << 10, h.local.key, h.local.addr, i as u32),
        )
        .unwrap();
    }
    drain(&mut sim);
    let reqs = sim.poll_cq(NodeId(0), h.cq0, 1000);
    assert_eq!(reqs.len() as u64, n);
    for c in &reqs {
        assert_eq!(c.status, WcStatus::Success, "{c:?}");
    }
    let recvs = sim.poll_cq(NodeId(1), h.cq1, 1000);
    assert_eq!(recvs.len() as u64, n, "reordering must not lose or duplicate messages");
    let fs = sim.fault_stats().unwrap();
    assert!(fs.frames_delayed > 0, "jitter plan must actually delay frames");
}

#[test]
fn read_responses_survive_loss() {
    let mut sim = Sim::new(FabricConfig::default());
    sim.install_faults(FaultConfig { seed: 23, drop_p: 0.1, ..FaultConfig::default() });
    let h = rc_pair(&mut sim);
    let n = 10u64;
    for i in 0..n {
        sim.post_send(
            NodeId(0),
            h.q0,
            SendWr::read(i, 16 << 10, h.local.key, h.local.addr, h.remote.key, h.remote.addr),
        )
        .unwrap();
    }
    drain(&mut sim);
    let cqes = sim.poll_cq(NodeId(0), h.cq0, 100);
    assert_eq!(cqes.len() as u64, n, "every READ completes exactly once");
    let ok = cqes.iter().filter(|c| c.status == WcStatus::Success).count() as u64;
    assert!(ok >= n - 1, "10% loss should rarely exhaust the budget: {ok}/{n}");
    assert!(sim.node(NodeId(0)).retransmits > 0);
}

#[test]
fn node_restart_clears_queued_work_and_quiesces() {
    let mut sim = Sim::new(FabricConfig::default());
    sim.install_faults(FaultConfig {
        seed: 5,
        restarts: vec![(0, 5_000)],
        ..FaultConfig::default()
    });
    let h = rc_pair(&mut sim);
    let n = 50u64;
    for i in 0..n {
        sim.post_send(
            NodeId(0),
            h.q0,
            SendWr::write(i, 8 << 10, h.local.key, h.local.addr, h.remote.key, h.remote.addr),
        )
        .unwrap();
    }
    drain(&mut sim);
    assert_eq!(sim.node(NodeId(0)).restarts, 1);
    assert_eq!(sim.fault_stats().unwrap().restarts, 1);
    // messages queued or in flight at the restart never complete; the
    // rest completed before it — either way the timeline drains and the
    // window is not wedged
    let cqes = sim.poll_cq(NodeId(0), h.cq0, 1000);
    assert!((cqes.len() as u64) < n, "the restart must have killed queued work");
    assert_eq!(sim.node(NodeId(0)).qps[h.q0.0].outstanding, 0);
    assert!(sim.node(NodeId(0)).engine_queue_len() == 0);
}

// ------------------------------------------------------- daemon layer

fn lossy_cluster(fault: FaultConfig, client: DaemonConfig, server: DaemonConfig) -> (Sim, Vec<Daemon>) {
    let mut fcfg = FabricConfig::default();
    fcfg.nodes = 2;
    fcfg.sq_depth = 8192;
    let mut sim = Sim::new(fcfg);
    sim.install_faults(fault);
    let daemons = vec![
        Daemon::start(&mut sim, NodeId(0), client),
        Daemon::start(&mut sim, NodeId(1), server),
    ];
    (sim, daemons)
}

fn pump_to_quiescence(sim: &mut Sim, daemons: &mut [Daemon]) {
    for _ in 0..200_000 {
        for d in daemons.iter_mut() {
            d.pump(sim);
        }
        if sim.step().is_none() {
            for d in daemons.iter_mut() {
                d.pump(sim);
            }
            if sim.pending_events() == 0 {
                return;
            }
        }
    }
    panic!("daemon cluster did not quiesce");
}

#[test]
fn ud_fragment_loss_discards_partials_and_balances_leases() {
    let mut server_cfg = DaemonConfig::default();
    server_cfg.reassembly_timeout_ns = 500_000;
    let (mut sim, mut daemons) = lossy_cluster(
        FaultConfig { seed: 19, drop_p: 0.15, ..FaultConfig::default() },
        DaemonConfig::default(),
        server_cfg,
    );
    let c_app = daemons[0].register_app();
    let s_app = daemons[1].register_app();
    daemons[1].listen(s_app, 1);
    let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();

    // 30 × 64 KB pinned-UD messages = 480 fragments at 15% loss: many
    // messages lose a fragment and must be discarded by reassembly
    let n = 30u64;
    for i in 0..n {
        daemons[0]
            .send(&mut sim, conn, 64 << 10, Flags::UD, i, HostLoad::default())
            .unwrap();
    }
    pump_to_quiescence(&mut sim, &mut daemons);

    // the sender's completions are LOCAL — UD loss never hangs them, so
    // every staging lease comes back through the normal path
    assert_eq!(daemons[0].stats.ops_completed, n);
    assert_eq!(daemons[0].pool.leased_bytes, 0, "no lease leaked");
    // delivered + torn = sent
    let delivered = daemons[1].stats.msgs_delivered;
    let torn = daemons[1].reassembly.dropped
        + daemons[1].reassembly.expired
        + daemons[1].reassembly.in_progress() as u64;
    assert!(delivered < n, "15% fragment loss must tear some messages");
    assert!(
        daemons[1].reassembly.dropped + daemons[1].reassembly.orphan_fragments > 0,
        "losses must surface in the reassembly counters: {:?}",
        daemons[1].reassembly
    );
    assert!(delivered + torn <= n, "a message is delivered at most once");
}

#[test]
fn client_restart_reclaims_stale_leases_and_fails_the_ops() {
    let mut client_cfg = DaemonConfig::default();
    client_cfg.lease_timeout_ns = 200_000;
    let (mut sim, mut daemons) = lossy_cluster(
        FaultConfig { seed: 2, restarts: vec![(0, 5_000)], ..FaultConfig::default() },
        client_cfg,
        DaemonConfig::default(),
    );
    let c_app = daemons[0].register_app();
    let s_app = daemons[1].register_app();
    daemons[1].listen(s_app, 1);
    let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();

    // 200 small RC sends: far more than can complete before the 5 µs
    // restart clears the SQ and CQs under them
    let n = 200u64;
    for i in 0..n {
        match daemons[0].send(&mut sim, conn, 1024, Flags::default(), i, HostLoad::default()) {
            Ok(_) | Err(RaasError::PoolExhausted) => {}
            Err(e) => panic!("send {i}: {e}"),
        }
    }
    daemons[0].pump(&mut sim);
    pump_to_quiescence(&mut sim, &mut daemons);
    // advance virtual time past the lease deadline, then pump to reclaim
    sim.schedule(Ns(1_000_000), 1);
    while sim.step().is_some() {}
    daemons[0].pump(&mut sim);

    assert_eq!(daemons[0].pool.leased_bytes, 0, "all leases back");
    assert!(daemons[0].stats.leases_reclaimed > 0, "restart must strand some leases");
    assert_eq!(sim.node(NodeId(0)).restarts, 1);
    // every reclaimed op surfaced to the app as a failed completion
    let mut failed = 0;
    while let Some(d) = daemons[0].recv(&mut sim, c_app) {
        if matches!(d, Delivery::OpComplete { ok: false, .. }) {
            failed += 1;
        }
    }
    assert_eq!(failed, daemons[0].stats.leases_reclaimed, "failure deliveries match reclaims");
}

// ------------------------------------------------- window data plane

#[test]
fn window_reads_survive_loss_exactly_once() {
    // one-sided READs through a registered window on a 10%-lossy fabric:
    // the RC layer retransmits underneath, every op completes exactly
    // once, and — the window contract — no per-op lease is ever taken,
    // so loss cannot leak pool bytes
    let (mut sim, mut daemons) = lossy_cluster(
        FaultConfig { seed: 29, drop_p: 0.08, ..FaultConfig::default() },
        DaemonConfig::default(),
        DaemonConfig::default(),
    );
    let c_app = daemons[0].register_app();
    let s_app = daemons[1].register_app();
    daemons[1].listen(s_app, 1);
    let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();

    let win = daemons[0]
        .register_window(&mut sim, conn, 0, 1 << 20, 16 << 10)
        .unwrap();
    let standing = daemons[0].pool.leased_bytes;
    assert!(standing > 0, "registration holds one standing lease");

    let n = 40u64;
    for i in 0..n {
        daemons[0]
            .window_read(&mut sim, win, 4096, (i % 256) * 4096, i)
            .unwrap();
    }
    pump_to_quiescence(&mut sim, &mut daemons);

    assert_eq!(daemons[0].stats.ops_completed, n, "every READ completes exactly once");
    assert_eq!(daemons[0].stats.window_ops, n);
    assert!(sim.node(NodeId(0)).retransmits > 0, "8% loss must force retransmissions");
    let mut delivered = 0u64;
    let mut ok = 0u64;
    while let Some(d) = daemons[0].recv_zero_copy(&mut sim, c_app) {
        let Delivery::OpComplete { ok: o, .. } = d else { panic!("{d:?}") };
        delivered += 1;
        if o {
            ok += 1;
        }
    }
    assert_eq!(delivered, n, "one delivery per READ — no duplicates, no losses");
    assert!(ok >= n - 2, "8% loss should rarely exhaust the retry budget: {ok}/{n}");
    // repeat READs took no per-op leases, lossy or not
    assert_eq!(daemons[0].pool.leased_bytes, standing);
    daemons[0].release_window(&mut sim, win).unwrap();
    assert_eq!(daemons[0].pool.leased_bytes, 0, "release returns the standing lease");
}

#[test]
fn window_write_bursts_survive_a_link_flap() {
    // doorbell-coalesced WRITE groups across a link that is dark for the
    // first 100 µs: the group's single signaled tail either completes or
    // retry-fails, and the daemon fans exactly one completion out to each
    // coalesced WRITE's tag — exactly-once per logical op, under faults
    let (mut sim, mut daemons) = lossy_cluster(
        FaultConfig {
            seed: 31,
            flaps: vec![Flap { src: NodeId(0), dst: NodeId(1), from: Ns(0), until: Ns(100_000) }],
            ..FaultConfig::default()
        },
        DaemonConfig::default(),
        DaemonConfig::default(),
    );
    let c_app = daemons[0].register_app();
    let s_app = daemons[1].register_app();
    daemons[1].listen(s_app, 1);
    let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();

    let win = daemons[0].register_window(&mut sim, conn, 0, 1 << 20, 4096).unwrap();
    let bursts = 20u64;
    let per_burst = 4u64;
    for b in 0..bursts {
        for j in 0..per_burst {
            let tag = b * per_burst + j;
            daemons[0].window_write(&mut sim, win, 4096, tag * 4096, tag).unwrap();
        }
        daemons[0].window_flush(&mut sim, win).unwrap();
    }
    pump_to_quiescence(&mut sim, &mut daemons);

    let n = bursts * per_burst;
    assert_eq!(daemons[0].stats.window_flushes, bursts);
    assert_eq!(daemons[0].stats.writes_coalesced, bursts * (per_burst - 1));
    assert_eq!(daemons[0].stats.ops_completed, n, "every WRITE resolves exactly once");
    assert!(sim.node(NodeId(0)).retransmits > 0, "the flap must force retransmissions");
    // the group fan-out carries each user tag exactly once, ok or not
    let mut seen = std::collections::HashSet::new();
    while let Some(d) = daemons[0].recv_zero_copy(&mut sim, c_app) {
        let Delivery::OpComplete { tag, .. } = d else { panic!("{d:?}") };
        assert!(seen.insert(tag), "tag {tag} completed twice");
    }
    assert_eq!(seen.len() as u64, n, "one completion per coalesced WRITE");
    daemons[0].release_window(&mut sim, win).unwrap();
    assert_eq!(daemons[0].pool.leased_bytes, 0);
}

#[test]
fn client_restart_reclaims_abandoned_windows() {
    // the client restarts 5 µs in, stranding a registered window and its
    // in-flight one-sided ops. The stale-lease sweep fails the in-flight
    // ops (no lease released — the window owns it), then the idle-window
    // sweep reclaims the slot and the standing lease, and the dead token
    // is refused cleanly ever after
    let mut client_cfg = DaemonConfig::default();
    client_cfg.lease_timeout_ns = 200_000;
    let (mut sim, mut daemons) = lossy_cluster(
        FaultConfig { seed: 37, restarts: vec![(0, 5_000)], ..FaultConfig::default() },
        client_cfg,
        DaemonConfig::default(),
    );
    let c_app = daemons[0].register_app();
    let s_app = daemons[1].register_app();
    daemons[1].listen(s_app, 1);
    let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();

    let win = daemons[0].register_window(&mut sim, conn, 0, 1 << 20, 16 << 10).unwrap();
    assert_eq!(daemons[0].window_count(), 1);
    // in-flight READs plus a flushed WRITE group — all killed by the restart
    for i in 0..16u64 {
        daemons[0]
            .window_read(&mut sim, win, 16 << 10, i * (16 << 10), i)
            .unwrap();
    }
    for j in 0..4u64 {
        daemons[0].window_write(&mut sim, win, 4096, j * 4096, 100 + j).unwrap();
    }
    daemons[0].window_flush(&mut sim, win).unwrap();
    daemons[0].pump(&mut sim);
    pump_to_quiescence(&mut sim, &mut daemons);
    // advance virtual time past lease + window deadlines, then sweep
    sim.schedule(Ns(1_000_000), 1);
    while sim.step().is_some() {}
    daemons[0].pump(&mut sim);

    assert_eq!(sim.node(NodeId(0)).restarts, 1);
    assert_eq!(daemons[0].window_count(), 0, "the abandoned window is swept");
    assert!(daemons[0].stats.windows_reclaimed > 0, "{:?}", daemons[0].stats);
    assert_eq!(daemons[0].pool.leased_bytes, 0, "standing lease back in the pool");
    // window-op failures do NOT masquerade as pool-lease reclaims
    assert!(daemons[0].stats.ops_failed > 0, "stranded window ops surface as failures");
    // the dead token is refused, not misrouted to a recycled slot
    assert_eq!(
        daemons[0].window_read(&mut sim, win, 4096, 0, 0),
        Err(RaasError::StaleWindow)
    );
    assert_eq!(
        daemons[0].window_write(&mut sim, win, 4096, 0, 0),
        Err(RaasError::StaleWindow)
    );
    assert_eq!(daemons[0].window_flush(&mut sim, win), Err(RaasError::StaleWindow));
    // every stranded op surfaced to the app as a failed completion
    let mut failed = 0u64;
    while let Some(d) = daemons[0].recv_zero_copy(&mut sim, c_app) {
        if matches!(d, Delivery::OpComplete { ok: false, .. }) {
            failed += 1;
        }
    }
    assert_eq!(failed, daemons[0].stats.ops_failed, "failure deliveries match the ledger");
}

#[test]
fn server_restart_recovers_and_client_completes_everything() {
    // server soft-restarts mid-run; its daemon refills the SRQ on later
    // pumps and the client's RC machinery (RNR + go-back-N retransmit)
    // either delivers or fails each op — nothing hangs, nothing leaks
    let (mut sim, mut daemons) = lossy_cluster(
        FaultConfig { seed: 4, restarts: vec![(1, 40_000)], ..FaultConfig::default() },
        DaemonConfig::default(),
        DaemonConfig::default(),
    );
    let c_app = daemons[0].register_app();
    let s_app = daemons[1].register_app();
    daemons[1].listen(s_app, 1);
    let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();

    let n = 100u64;
    for i in 0..n {
        daemons[0]
            .send(&mut sim, conn, 512, Flags::default(), i, HostLoad::default())
            .unwrap();
    }
    pump_to_quiescence(&mut sim, &mut daemons);
    assert_eq!(sim.node(NodeId(1)).restarts, 1);
    assert_eq!(
        daemons[0].stats.ops_completed,
        n,
        "every op completes (ok or failed), none hangs"
    );
    assert_eq!(daemons[0].pool.leased_bytes, 0);
}

// ------------------------------------- elastic control plane × faults

#[test]
fn client_restart_mid_establishment_leaves_no_orphaned_qp_or_lease() {
    // the client restarts 5 µs in — while the lazily-deferred lease batch
    // and the first RC ops are still in flight. The stale-lease sweep
    // fails the stranded ops, disconnect parks the drained QP, and the
    // reuse pool must hold no orphan: a reconnect to the same remote
    // revives the parked QP and completes new work on it
    let mut cfg = DaemonConfig::default();
    cfg.migration.enabled = false;
    cfg.lazy_leases = true;
    cfg.qp_pool_max = 4;
    cfg.lease_timeout_ns = 200_000;
    let (mut sim, mut daemons) = lossy_cluster(
        FaultConfig { seed: 41, restarts: vec![(0, 5_000)], ..FaultConfig::default() },
        cfg.clone(),
        cfg,
    );
    let c_app = daemons[0].register_app();
    let s_app = daemons[1].register_app();
    daemons[1].listen(s_app, 1);
    let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();
    assert!(!daemons[0].creds_established(1), "lazy: connect must not establish");

    // first read triggers the batched establishment; the 5 µs restart
    // lands under this burst
    for i in 0..32u64 {
        daemons[0].read(&mut sim, conn, 2048, i * 4096, i).unwrap();
    }
    daemons[0].pump(&mut sim);
    pump_to_quiescence(&mut sim, &mut daemons);
    // advance past the lease deadline so the sweep reclaims strays
    sim.schedule(Ns(1_000_000), 1);
    while sim.step().is_some() {}
    daemons[0].pump(&mut sim);
    assert_eq!(sim.node(NodeId(0)).restarts, 1);
    assert_eq!(daemons[0].pool.leased_bytes, 0, "no lease survives the reclaim");
    assert_eq!(daemons[0].inflight_ops(), 0, "no op stuck in the slab");

    // teardown parks the drained QP on both sides…
    disconnect_via(&mut sim, &mut daemons, 0, conn).unwrap();
    pump_to_quiescence(&mut sim, &mut daemons);
    for d in &daemons {
        assert!(d.pooled_qp_count() <= 4, "pool over bound: {}", d.pooled_qp_count());
        assert_eq!(d.conns.active(), 0);
        assert_eq!(d.conns.quarantined(), 0, "quarantine must drain after parting");
    }
    assert!(daemons[0].stats.qp_parked > 0, "the drained QP must be parked, not lost");

    // …and the parked half is revivable, not an orphan: reconnect rides
    // it and fresh work completes. Flush the stranded-op deliveries first
    // so the post-reconnect inbox holds exactly the fresh op's completion
    while daemons[0].recv_zero_copy(&mut sim, c_app).is_some() {}
    let conn2 = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();
    assert!(daemons[0].stats.qp_reused >= 1, "reconnect must revive the parked QP");
    daemons[0].read(&mut sim, conn2, 2048, 0, 1_000).unwrap();
    pump_to_quiescence(&mut sim, &mut daemons);
    let mut fresh = Vec::new();
    while let Some(d) = daemons[0].recv_zero_copy(&mut sim, c_app) {
        fresh.push(d);
    }
    assert!(
        matches!(fresh[..], [Delivery::OpComplete { ok: true, .. }]),
        "work on the revived QP must complete exactly once: {fresh:?}"
    );
    disconnect_via(&mut sim, &mut daemons, 0, conn2).unwrap();
    pump_to_quiescence(&mut sim, &mut daemons);
    assert_eq!(daemons[0].pool.leased_bytes, 0);
}

#[test]
fn link_flap_during_batched_lease_establishment_is_all_or_nothing() {
    // the client↔server-1 link is dark while the deferred lease batch and
    // the first ops go out. Whatever the fabric does, the credential
    // ledger is never partial: the first use drains the whole backlog in
    // ONE coalesced control message, both remotes end fully established
    // (creds_established cross-checks both ledger halves internally), and
    // every accepted op completes exactly once through the flap
    let mut cfg = DaemonConfig::default();
    cfg.migration.enabled = false;
    cfg.lazy_leases = true;
    cfg.lease_batch_max = 8;
    let mut fcfg = FabricConfig::default();
    fcfg.nodes = 3;
    fcfg.sq_depth = 8192;
    let mut sim = Sim::new(fcfg);
    sim.install_faults(FaultConfig {
        seed: 43,
        flaps: vec![Flap { src: NodeId(0), dst: NodeId(1), from: Ns(0), until: Ns(300_000) }],
        ..FaultConfig::default()
    });
    let mut daemons: Vec<Daemon> = (0..3)
        .map(|i| Daemon::start(&mut sim, NodeId(i), cfg.clone()))
        .collect();
    let c_app = daemons[0].register_app();
    for s in 1..3 {
        let sapp = daemons[s].register_app();
        daemons[s].listen(sapp, 1);
    }
    // two tenants per remote, all creds deferred at connect
    let c1a = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();
    let _c1b = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();
    let _c2a = connect_via(&mut sim, &mut daemons, 0, c_app, 2, 1).unwrap();
    assert_eq!(daemons[0].deferred_lease_count(), 2, "both remotes deferred");
    assert!(!daemons[0].creds_established(1));
    assert!(!daemons[0].creds_established(2));

    // first use of remote 1 mid-flap: establishment + 12 reads
    let n = 12u64;
    for i in 0..n {
        daemons[0].read(&mut sim, c1a, 2048, i * 4096, i).unwrap();
    }
    pump_to_quiescence(&mut sim, &mut daemons);

    // all-or-nothing, batch-wide: one control message established BOTH
    // backlogged remotes — no remote is ever left half-installed
    assert!(daemons[0].creds_established(1), "touched remote must be fully established");
    assert!(daemons[0].creds_established(2), "backlogged remote rides the same batch");
    assert_eq!(daemons[0].deferred_lease_count(), 0, "the batch drains the backlog");
    assert_eq!(daemons[0].stats.lease_batches, 1, "exactly one coalesced control message");
    assert_eq!(daemons[0].stats.leases_established, 2);

    // exactly-once through the flap: one completion per accepted op
    // (ops_completed counts every CQE-resolved op, ok or retry-exhausted)
    assert!(sim.node(NodeId(0)).retransmits > 0, "the flap must force retransmissions");
    assert_eq!(daemons[0].stats.ops_completed, n);
    let mut seen = std::collections::HashSet::new();
    while let Some(d) = daemons[0].recv_zero_copy(&mut sim, c_app) {
        let Delivery::OpComplete { tag, .. } = d else { panic!("{d:?}") };
        assert!(seen.insert(tag), "tag {tag} completed twice");
    }
    assert_eq!(seen.len() as u64, n);
    assert_eq!(daemons[0].pool.leased_bytes, 0);
}

#[test]
fn churn_under_loss_keeps_exactly_once_completions() {
    // connect → read burst → disconnect cycles on a 5%-lossy fabric, with
    // the reuse pool reviving the parked QP every round: RC retransmission
    // under the epoch-stamped QP must deliver exactly one completion per
    // op — never a duplicate, never a prior tenant's — and park/revive
    // must not strand a single lease
    let mut cfg = DaemonConfig::default();
    cfg.migration.enabled = false;
    cfg.lazy_leases = true;
    cfg.qp_pool_max = 2;
    let (mut sim, mut daemons) = lossy_cluster(
        FaultConfig { seed: 47, drop_p: 0.05, ..FaultConfig::default() },
        cfg.clone(),
        cfg,
    );
    let c_app = daemons[0].register_app();
    let s_app = daemons[1].register_app();
    daemons[1].listen(s_app, 1);

    let rounds = 8u64;
    let per_round = 6u64;
    let mut seen = std::collections::HashSet::new();
    for r in 0..rounds {
        let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();
        for i in 0..per_round {
            daemons[0].read(&mut sim, conn, 2048, i * 4096, r * 100 + i).unwrap();
        }
        pump_to_quiescence(&mut sim, &mut daemons);
        while let Some(d) = daemons[0].recv_zero_copy(&mut sim, c_app) {
            let Delivery::OpComplete { tag, .. } = d else { panic!("{d:?}") };
            assert!(seen.insert(tag), "tag {tag} delivered twice (round {r})");
        }
        disconnect_via(&mut sim, &mut daemons, 0, conn).unwrap();
        pump_to_quiescence(&mut sim, &mut daemons);
    }

    assert_eq!(seen.len() as u64, rounds * per_round, "one completion per op, none lost");
    assert_eq!(daemons[0].stats.ops_completed, rounds * per_round);
    assert!(daemons[0].stats.qp_reused >= rounds - 1, "each round must revive the parked QP");
    assert!(sim.node(NodeId(0)).retransmits > 0, "5% loss must force retransmissions");
    for d in &daemons {
        assert_eq!(d.pool.leased_bytes, 0, "park/revive churn must not strand leases");
        assert_eq!(d.conns.active(), 0);
        assert_eq!(d.conns.quarantined(), 0);
        assert!(d.pooled_qp_count() <= 2);
    }
}

// --------------------------------------------- survivable Clos (PR 10)

/// A 3-ToR Clos (4 hosts per ToR, oversub 1 → 4 uplinks/spines) with the
/// retransmit clock tightened so detector/retry ordering is exercised in
/// microseconds, not milliseconds.
fn clos_sim(repath: bool, reroute_lag_ns: u64, retry_cnt: u32) -> Sim {
    use rdmavisor::fabric::topo::TopoConfig;
    let mut topo = TopoConfig::default();
    topo.hosts_per_tor = 4;
    topo.oversub = 1;
    topo.repath = repath;
    topo.reroute_lag_ns = reroute_lag_ns;
    let mut fcfg = FabricConfig::default();
    fcfg.nodes = 12;
    fcfg.sq_depth = 8192;
    fcfg.nic.retransmit_timeout_ns = 50_000;
    fcfg.nic.retry_cnt = retry_cnt;
    fcfg.topo = Some(topo);
    Sim::new(fcfg)
}

/// Draw RC pairs between `src` and `dst` until ECMP hashes one onto
/// `spine` (each pair gets fresh QPNs, so each draw re-rolls the hash) —
/// makes the spine-death tests deterministic instead of hoping some flow
/// of a big population crossed the dead switch.
fn pair_via_spine(
    sim: &mut Sim,
    cq_src: rdmavisor::fabric::types::Cqn,
    cq_dst: rdmavisor::fabric::types::Cqn,
    src: NodeId,
    dst: NodeId,
    spine: usize,
) -> rdmavisor::fabric::types::Qpn {
    for _ in 0..64 {
        let pair = verbs::create_connected_pair(
            sim,
            QpTransport::Rc,
            src,
            dst,
            cq_src,
            cq_src,
            cq_dst,
            cq_dst,
        );
        if sim.clos().expect("topology installed").path_of(src, dst, pair.a.1, pair.b.1) == spine
        {
            return pair.a.1;
        }
    }
    panic!("no QP pair hashed onto spine {spine} in 64 draws");
}

#[test]
fn spine_window_death_recovers_exactly_once() {
    // spine 0 dies at 50 µs and revives at 2 ms, under a transfer pinned
    // to it. Between the per-QP blackhole escape (3 timeouts ≈ 150 µs)
    // and the 200 µs reconvergence backstop, every WRITE must complete
    // exactly once — GBN retransmission repaths, never duplicates
    let mut sim = clos_sim(true, 200_000, 7);
    sim.install_faults(FaultConfig {
        spine_windows: vec![(0, 50_000, 2_000_000)],
        ..FaultConfig::default()
    });
    let (src, dst) = (NodeId(4), NodeId(8)); // ToR 1 host → ToR 2 host
    let cq_src = sim.create_cq(src, 1 << 14);
    let cq_dst = sim.create_cq(dst, 1 << 14);
    let qpn = pair_via_spine(&mut sim, cq_src, cq_dst, src, dst, 0);
    let local = sim.reg_mr(src, 64 << 20, Access::REMOTE_RW, true);
    let remote = sim.reg_mr(dst, 64 << 20, Access::REMOTE_RW, true);
    let n = 40u64;
    for i in 0..n {
        sim.post_send(
            src,
            qpn,
            SendWr::write(i, 64 << 10, local.key, local.addr, remote.key, remote.addr),
        )
        .unwrap();
    }
    drain(&mut sim);
    let cqes = sim.poll_cq(src, cq_src, 1000);
    assert_eq!(cqes.len() as u64, n, "every WRITE completes");
    let mut seen = std::collections::HashSet::new();
    for c in &cqes {
        assert_eq!(c.status, WcStatus::Success, "{c:?}");
        assert!(seen.insert(c.wr_id), "wr {} completed twice", c.wr_id);
    }
    assert!(sim.node(src).retransmits > 0, "the dead spine must force retransmissions");
    assert!(sim.clos_stats().blackhole_drops > 0, "frames must have hit the dead port");
    assert!(
        sim.repaths() > 0 || sim.route_epoch() > 0,
        "recovery must come from the repath machinery, not luck: repaths={} epoch={}",
        sim.repaths(),
        sim.route_epoch()
    );
    assert_eq!(sim.node(src).retry_exceeded, 0, "no flow may die inside the budget");
}

#[test]
fn blackhole_detector_fires_before_retry_exhaustion() {
    // reconvergence lagged to 600 µs, retry budget stretched to 12: the
    // detector's three-timeout fuse (~150 µs of stall) is the first
    // recovery to fire, and between it and the late mask update the flow
    // must survive with the budget untouched
    let mut sim = clos_sim(true, 600_000, 12);
    sim.install_faults(FaultConfig {
        spine_windows: vec![(0, 50_000, 100_000_000)],
        ..FaultConfig::default()
    });
    let (src, dst) = (NodeId(4), NodeId(8));
    let cq_src = sim.create_cq(src, 1 << 14);
    let cq_dst = sim.create_cq(dst, 1 << 14);
    let qpn = pair_via_spine(&mut sim, cq_src, cq_dst, src, dst, 0);
    let local = sim.reg_mr(src, 64 << 20, Access::REMOTE_RW, true);
    let remote = sim.reg_mr(dst, 64 << 20, Access::REMOTE_RW, true);
    let n = 20u64;
    for i in 0..n {
        sim.post_send(
            src,
            qpn,
            SendWr::write(i, 32 << 10, local.key, local.addr, remote.key, remote.addr),
        )
        .unwrap();
    }
    drain(&mut sim);
    let cqes = sim.poll_cq(src, cq_src, 1000);
    assert_eq!(cqes.len() as u64, n);
    for c in &cqes {
        assert_eq!(c.status, WcStatus::Success, "{c:?}");
    }
    assert!(sim.node(src).repaths >= 1, "the blackhole detector must fire");
    assert_eq!(
        sim.node(src).retry_exceeded,
        0,
        "the detector + remask must beat the 12-retry budget"
    );

    // the ablation: repath off freezes the mask AND disarms the detector,
    // so the same pinned flow burns its whole budget and dies
    let mut sim = clos_sim(false, 600_000, 7);
    sim.install_faults(FaultConfig {
        spine_windows: vec![(0, 50_000, 100_000_000)],
        ..FaultConfig::default()
    });
    let cq_src = sim.create_cq(src, 1 << 14);
    let cq_dst = sim.create_cq(dst, 1 << 14);
    let qpn = pair_via_spine(&mut sim, cq_src, cq_dst, src, dst, 0);
    let local = sim.reg_mr(src, 64 << 20, Access::REMOTE_RW, true);
    let remote = sim.reg_mr(dst, 64 << 20, Access::REMOTE_RW, true);
    for i in 0..n {
        sim.post_send(
            src,
            qpn,
            SendWr::write(i, 32 << 10, local.key, local.addr, remote.key, remote.addr),
        )
        .unwrap();
    }
    drain(&mut sim);
    assert!(sim.node(src).retry_exceeded > 0, "without repath the pinned flow must die");
    assert_eq!(sim.repaths(), 0, "the detector is disarmed when repath is off");
    assert_eq!(sim.route_epoch(), 0, "the mask never reconverges when repath is off");
}

#[test]
fn daemon_reestablishes_qp_after_retry_exhaustion() {
    // a 2.3 ms link blackout outlasts the ~1.3 ms retry budget: the
    // shared QP retry-fails, the daemon parks it (no ok:false yet),
    // re-establishes after the 500 µs backoff, replays the stashed WRs,
    // and once the link returns every op completes ok — exactly once,
    // with the lease ledger balanced
    let mut fcfg = FabricConfig::default();
    fcfg.nodes = 2;
    fcfg.sq_depth = 8192;
    fcfg.nic.retransmit_timeout_ns = 50_000;
    fcfg.nic.retry_cnt = 5;
    let mut sim = Sim::new(fcfg);
    sim.install_faults(FaultConfig {
        seed: 53,
        flaps: vec![Flap {
            src: NodeId(0),
            dst: NodeId(1),
            from: Ns(200_000),
            until: Ns(2_500_000),
        }],
        ..FaultConfig::default()
    });
    let mut cfg = DaemonConfig::default();
    cfg.migration.enabled = false;
    cfg.heal_max_attempts = 6;
    cfg.heal_backoff_ns = 500_000;
    cfg.heal_backoff_cap_ns = 800_000;
    let mut daemons = vec![
        Daemon::start(&mut sim, NodeId(0), cfg.clone()),
        Daemon::start(&mut sim, NodeId(1), cfg),
    ];
    let c_app = daemons[0].register_app();
    let s_app = daemons[1].register_app();
    daemons[1].listen(s_app, 1);
    // connect (and eagerly establish creds) before the link goes dark
    let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();
    pump_to_quiescence(&mut sim, &mut daemons);

    // step into the blackout, then issue the reads that must exhaust
    sim.schedule(Ns(250_000), 1);
    while sim.step().is_some() {}
    let n = 8u64;
    for i in 0..n {
        daemons[0].read(&mut sim, conn, 2048, i * 4096, i).unwrap();
    }
    // drive idle ticks so retry timers and the heal backoff keep maturing
    // even while every QP of the fabric is parked
    let deadline = Ns::from_ms(10);
    let mut saw_parked = false;
    let mut guard = 0u32;
    loop {
        guard += 1;
        assert!(guard < 500_000, "heal did not converge");
        for d in daemons.iter_mut() {
            d.pump(&mut sim);
        }
        saw_parked |= daemons[0].heals_active() > 0;
        if sim.step().is_none() {
            if sim.now() >= deadline {
                break;
            }
            let t = sim.now() + Ns(50_000);
            sim.schedule(t, 1);
        }
    }
    for d in daemons.iter_mut() {
        d.pump(&mut sim);
    }

    assert!(sim.node(NodeId(0)).retry_exceeded > 0, "the blackout must exhaust the budget");
    assert!(saw_parked, "the daemon must park the dead QP in a heal cycle");
    let ds = &daemons[0].stats;
    assert!(ds.qp_reestablished >= 1, "heal must revive the QP: {ds:?}");
    assert_eq!(ds.heal_giveups, 0, "the blackout ends inside the backoff budget");
    assert_eq!(ds.ops_failed, 0, "no op surfaces as failed — the replay completed them");
    assert!(ds.backoff_ns > 0, "parked time must be accounted");
    assert_eq!(daemons[0].heals_active(), 0, "a concluded heal leaves no residue");
    // exactly-once: one delivery per op, all ok, ledger balanced
    let mut ok = 0u64;
    let mut total = 0u64;
    while let Some(d) = daemons[0].recv_zero_copy(&mut sim, c_app) {
        let Delivery::OpComplete { ok: o, .. } = d else { panic!("{d:?}") };
        total += 1;
        if o {
            ok += 1;
        }
    }
    assert_eq!(total, n, "one delivery per op — no duplicates from the replay");
    assert_eq!(ok, n, "every replayed op completes ok");
    assert_eq!(daemons[0].pool.leased_bytes, 0, "lease balance intact through park/replay");
}

#[test]
fn null_plan_is_not_installed() {
    let mut sim = Sim::new(FabricConfig::default());
    sim.install_faults(FaultConfig::default());
    assert!(!sim.faults_active(), "null plan must leave the lossless simulator untouched");
    assert!(sim.fault_stats().is_none());

    // and a lossless run on it behaves exactly like one that never heard
    // of the fault layer: no retransmits, no discards, no timers
    let h = rc_pair(&mut sim);
    for i in 0..10u64 {
        sim.post_send(
            NodeId(0),
            h.q0,
            SendWr::write(i, 8 << 10, h.local.key, h.local.addr, h.remote.key, h.remote.addr),
        )
        .unwrap();
    }
    drain(&mut sim);
    assert_eq!(sim.poll_cq(NodeId(0), h.cq0, 100).len(), 10);
    let n0 = sim.node(NodeId(0));
    assert_eq!(n0.retransmits + n0.retry_exceeded + n0.gbn_discards + n0.gbn_dup_acks, 0);
}
