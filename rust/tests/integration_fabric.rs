//! Integration tests: end-to-end verb flows over the simulated fabric.

use rdmavisor::fabric::mr::Access;
use rdmavisor::fabric::sim::{FabricConfig, Notification, Sim};
use rdmavisor::fabric::time::{gbps, Ns};
use rdmavisor::fabric::types::{NodeId, QpTransport, Verb, WcStatus};
use rdmavisor::fabric::verbs;
use rdmavisor::fabric::wqe::{CqeKind, RecvWr, SendWr};

fn two_node_rc() -> (
    Sim,
    rdmavisor::fabric::verbs::QpPair,
    rdmavisor::fabric::types::Cqn,
    rdmavisor::fabric::types::Cqn,
) {
    let mut sim = Sim::new(FabricConfig::default());
    let cq0 = sim.create_cq(NodeId(0), 4096);
    let cq1 = sim.create_cq(NodeId(1), 4096);
    let pair = verbs::create_connected_pair(
        &mut sim, QpTransport::Rc, NodeId(0), NodeId(1), cq0, cq0, cq1, cq1,
    );
    (sim, pair, cq0, cq1)
}

#[test]
fn rc_write_completes_with_ack() {
    let (mut sim, pair, cq0, _cq1) = two_node_rc();
    let local = sim.reg_mr(NodeId(0), 1 << 20, Access::REMOTE_RW, true);
    let remote = sim.reg_mr(NodeId(1), 1 << 20, Access::REMOTE_RW, true);

    sim.post_send(
        NodeId(0),
        pair.a.1,
        SendWr::write(7, 64 << 10, local.key, local.addr, remote.key, remote.addr),
    )
    .unwrap();

    let notes = sim.run_to_quiescence();
    assert!(notes.contains(&Notification::CqeReady { node: NodeId(0), cqn: cq0 }));
    let cqes = sim.poll_cq(NodeId(0), cq0, 16);
    assert_eq!(cqes.len(), 1);
    assert_eq!(cqes[0].wr_id, 7);
    assert_eq!(cqes[0].kind, CqeKind::SendDone(Verb::Write));
    assert_eq!(cqes[0].status, WcStatus::Success);
    assert_eq!(sim.completed_bytes, 64 << 10);
}

#[test]
fn rc_read_round_trip() {
    let (mut sim, pair, cq0, _cq1) = two_node_rc();
    let local = sim.reg_mr(NodeId(0), 1 << 20, Access::REMOTE_RW, true);
    let remote = sim.reg_mr(NodeId(1), 1 << 20, Access::REMOTE_RW, true);

    sim.post_send(
        NodeId(0),
        pair.a.1,
        SendWr::read(42, 64 << 10, local.key, local.addr, remote.key, remote.addr),
    )
    .unwrap();
    sim.run_to_quiescence();

    let cqes = sim.poll_cq(NodeId(0), cq0, 16);
    assert_eq!(cqes.len(), 1);
    assert_eq!(cqes[0].kind, CqeKind::SendDone(Verb::Read));
    assert_eq!(cqes[0].len, 64 << 10);
    // read took at least the wire time of 64 KB at 40 Gb/s (~13 µs)
    assert!(sim.now() > Ns(13_000), "completed too fast: {}", sim.now());
}

#[test]
fn rc_send_recv_delivers_imm_vqpn() {
    let (mut sim, pair, cq0, cq1) = two_node_rc();
    let local = sim.reg_mr(NodeId(0), 1 << 20, Access::REMOTE_RW, true);
    let rbuf = sim.reg_mr(NodeId(1), 1 << 20, Access::REMOTE_RW, true);
    let mut next_id = 100;
    verbs::replenish_rq(&mut sim, NodeId(1), pair.b.1, &rbuf, 8192, 16, &mut next_id);

    // vQPN 0xBEEF rides in imm_data (the paper's two-sided demux, Fig 4)
    sim.post_send(NodeId(0), pair.a.1, SendWr::send(1, 4096, local.key, local.addr, 0xBEEF))
        .unwrap();
    sim.run_to_quiescence();

    let recv = sim.poll_cq(NodeId(1), cq1, 16);
    assert_eq!(recv.len(), 1);
    assert_eq!(recv[0].kind, CqeKind::Recv);
    assert_eq!(recv[0].imm_data, Some(0xBEEF));
    assert_eq!(recv[0].len, 4096);
    assert_eq!(recv[0].src, Some((NodeId(0), pair.a.1)));
    // sender got its ack-completion too
    let sent = sim.poll_cq(NodeId(0), cq0, 16);
    assert_eq!(sent.len(), 1);
}

#[test]
fn send_without_recv_wqe_rnr_retries_rc() {
    let (mut sim, pair, cq0, cq1) = two_node_rc();
    let local = sim.reg_mr(NodeId(0), 1 << 20, Access::REMOTE_RW, true);
    let rbuf = sim.reg_mr(NodeId(1), 1 << 20, Access::REMOTE_RW, true);

    sim.post_send(NodeId(0), pair.a.1, SendWr::send(1, 4096, local.key, local.addr, 1))
        .unwrap();
    // no recv posted yet: the message RNR-NAKs; post the recv during backoff
    for _ in 0..2000 {
        if sim.step().is_none() {
            break;
        }
        if sim.node(NodeId(1)).rnr_naks_sent > 0 {
            break;
        }
    }
    assert!(sim.node(NodeId(1)).rnr_naks_sent > 0, "expected an RNR NAK");
    sim.post_recv(
        NodeId(1),
        pair.b.1,
        RecvWr { wr_id: 9, lkey: rbuf.key, laddr: rbuf.addr, len: 8192 },
    )
    .unwrap();
    sim.run_to_quiescence();
    let recv = sim.poll_cq(NodeId(1), cq1, 16);
    assert_eq!(recv.len(), 1, "retried send must be delivered");
    assert_eq!(recv[0].wr_id, 9);
    let sent = sim.poll_cq(NodeId(0), cq0, 16);
    assert_eq!(sent.len(), 1);
}

#[test]
fn read_from_unreadable_region_errors() {
    let (mut sim, pair, cq0, _cq1) = two_node_rc();
    let local = sim.reg_mr(NodeId(0), 1 << 20, Access::REMOTE_RW, true);
    // remote region deliberately NOT remote-readable
    let remote = sim.reg_mr(NodeId(1), 1 << 20, Access::LOCAL_ONLY, true);

    sim.post_send(
        NodeId(0),
        pair.a.1,
        SendWr::read(1, 4096, local.key, local.addr, remote.key, remote.addr),
    )
    .unwrap();
    sim.run_to_quiescence();
    let cqes = sim.poll_cq(NodeId(0), cq0, 16);
    assert_eq!(cqes.len(), 1);
    assert_eq!(cqes[0].status, WcStatus::RemoteAccessError);
    assert_eq!(sim.node(NodeId(1)).protection_errors, 1);
}

#[test]
fn large_write_saturates_line_rate() {
    let (mut sim, pair, cq0, _cq1) = two_node_rc();
    let local = sim.reg_mr(NodeId(0), 64 << 20, Access::REMOTE_RW, true);
    let remote = sim.reg_mr(NodeId(1), 64 << 20, Access::REMOTE_RW, true);

    // pipeline 64 × 1 MB writes
    let n = 64u64;
    let len = 1 << 20;
    for i in 0..n {
        sim.post_send(
            NodeId(0),
            pair.a.1,
            SendWr::write(i, len, local.key, local.addr, remote.key, remote.addr),
        )
        .unwrap();
    }
    sim.run_to_quiescence();
    let cqes = sim.poll_cq(NodeId(0), cq0, 4096);
    assert_eq!(cqes.len() as u64, n);
    let g = gbps(n * len, sim.now());
    assert!(g > 34.0 && g <= 40.0, "throughput {g} Gb/s not near 40G line rate");
}

#[test]
fn uc_write_no_ack_local_completion() {
    let mut sim = Sim::new(FabricConfig::default());
    let cq0 = sim.create_cq(NodeId(0), 256);
    let cq1 = sim.create_cq(NodeId(1), 256);
    let pair = verbs::create_connected_pair(
        &mut sim, QpTransport::Uc, NodeId(0), NodeId(1), cq0, cq0, cq1, cq1,
    );
    let local = sim.reg_mr(NodeId(0), 1 << 20, Access::REMOTE_RW, true);
    let remote = sim.reg_mr(NodeId(1), 1 << 20, Access::REMOTE_RW, true);
    sim.post_send(
        NodeId(0),
        pair.a.1,
        SendWr::write(5, 64 << 10, local.key, local.addr, remote.key, remote.addr),
    )
    .unwrap();
    sim.run_to_quiescence();
    let cqes = sim.poll_cq(NodeId(0), cq0, 16);
    assert_eq!(cqes.len(), 1, "UC write completes locally without ACK");
    assert_eq!(cqes[0].kind, CqeKind::SendDone(Verb::Write));
}

#[test]
fn ud_send_one_qp_to_many_peers() {
    let mut sim = Sim::new(FabricConfig::default());
    let cq0 = sim.create_cq(NodeId(0), 256);
    let ud0 = verbs::create_ud(&mut sim, NodeId(0), cq0, cq0);
    let local = sim.reg_mr(NodeId(0), 1 << 20, Access::REMOTE_RW, true);

    // one UD QP on node 0 talks to UD QPs on nodes 1..3 (connectionless)
    let mut peer_cqs = Vec::new();
    let mut peers = Vec::new();
    for n in 1..4u32 {
        let cq = sim.create_cq(NodeId(n), 256);
        let ud = verbs::create_ud(&mut sim, NodeId(n), cq, cq);
        let buf = sim.reg_mr(NodeId(n), 1 << 20, Access::REMOTE_RW, true);
        let mut id = 0;
        verbs::replenish_rq(&mut sim, NodeId(n), ud, &buf, 4096, 8, &mut id);
        peer_cqs.push(cq);
        peers.push(ud);
    }
    for (i, n) in (1..4u32).enumerate() {
        sim.post_send(
            NodeId(0),
            ud0,
            SendWr::send(i as u64, 2048, local.key, local.addr, i as u32)
                .to_ud(NodeId(n), peers[i]),
        )
        .unwrap();
    }
    sim.run_to_quiescence();
    for (i, n) in (1..4u32).enumerate() {
        let cqes = sim.poll_cq(NodeId(n), peer_cqs[i], 16);
        assert_eq!(cqes.len(), 1, "peer {n} should receive one datagram");
        assert_eq!(cqes[0].src, Some((NodeId(0), ud0)));
    }
}

#[test]
fn srq_shared_across_qps() {
    let mut sim = Sim::new(FabricConfig::default());
    let cq0 = sim.create_cq(NodeId(0), 256);
    let cq1 = sim.create_cq(NodeId(1), 256);
    let srq = sim.create_srq(NodeId(1), 128, 4);
    let rbuf = sim.reg_mr(NodeId(1), 1 << 20, Access::REMOTE_RW, true);
    let mut id = 0;
    verbs::replenish_srq(&mut sim, NodeId(1), srq, &rbuf, 8192, 16, &mut id);

    // two QPs on node1 share the SRQ
    let p1 = verbs::create_connected_pair(
        &mut sim, QpTransport::Rc, NodeId(0), NodeId(1), cq0, cq0, cq1, cq1,
    );
    let p2 = verbs::create_connected_pair(
        &mut sim, QpTransport::Rc, NodeId(0), NodeId(1), cq0, cq0, cq1, cq1,
    );
    sim.attach_srq(NodeId(1), p1.b.1, srq);
    sim.attach_srq(NodeId(1), p2.b.1, srq);

    let local = sim.reg_mr(NodeId(0), 1 << 20, Access::REMOTE_RW, true);
    sim.post_send(NodeId(0), p1.a.1, SendWr::send(1, 1024, local.key, local.addr, 11))
        .unwrap();
    sim.post_send(NodeId(0), p2.a.1, SendWr::send(2, 1024, local.key, local.addr, 22))
        .unwrap();
    sim.run_to_quiescence();

    let recv = sim.poll_cq(NodeId(1), cq1, 16);
    assert_eq!(recv.len(), 2);
    assert_eq!(sim.node(NodeId(1)).srqs[srq.0].consumed, 2);
    let imms: Vec<_> = recv.iter().filter_map(|c| c.imm_data).collect();
    assert!(imms.contains(&11) && imms.contains(&22));
}

#[test]
fn deterministic_replay() {
    let run = || {
        let (mut sim, pair, cq0, _cq1) = two_node_rc();
        let local = sim.reg_mr(NodeId(0), 1 << 20, Access::REMOTE_RW, true);
        let remote = sim.reg_mr(NodeId(1), 1 << 20, Access::REMOTE_RW, true);
        for i in 0..50 {
            sim.post_send(
                NodeId(0),
                pair.a.1,
                SendWr::write(i, 16 << 10, local.key, local.addr, remote.key, remote.addr),
            )
            .unwrap();
        }
        sim.run_to_quiescence();
        let polled = sim.poll_cq(NodeId(0), cq0, 1024).len();
        (sim.now(), sim.completed_bytes, polled)
    };
    assert_eq!(run(), run());
}

#[test]
fn window_limits_outstanding_reads() {
    let mut cfg = FabricConfig::default();
    cfg.max_outstanding = 2;
    let mut sim = Sim::new(cfg);
    let cq0 = sim.create_cq(NodeId(0), 4096);
    let cq1 = sim.create_cq(NodeId(1), 4096);
    let pair = verbs::create_connected_pair(
        &mut sim, QpTransport::Rc, NodeId(0), NodeId(1), cq0, cq0, cq1, cq1,
    );
    let local = sim.reg_mr(NodeId(0), 16 << 20, Access::REMOTE_RW, true);
    let remote = sim.reg_mr(NodeId(1), 16 << 20, Access::REMOTE_RW, true);
    for i in 0..8 {
        sim.post_send(
            NodeId(0),
            pair.a.1,
            SendWr::read(i, 64 << 10, local.key, local.addr, remote.key, remote.addr),
        )
        .unwrap();
    }
    // at any instant, outstanding ≤ 2
    loop {
        let out = sim.node(NodeId(0)).qps[pair.a.1 .0].outstanding;
        assert!(out <= 2, "outstanding={out}");
        if sim.step().is_none() {
            break;
        }
    }
    assert_eq!(sim.poll_cq(NodeId(0), cq0, 64).len(), 8);
}
