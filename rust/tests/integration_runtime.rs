//! Integration: PJRT runtime against the real AOT artifacts.
//!
//! Requires `make artifacts` to have run (the Makefile dependency ensures
//! this under `make test`); tests are skipped gracefully when absent so
//! `cargo test` alone still passes on a fresh checkout.

use rdmavisor::runtime::{Executor, Manifest};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn manifest_loads_and_names_variants() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    assert!(!m.variants.is_empty());
    for v in &m.variants {
        assert!(v.batch >= 1);
        assert!(v.seq >= 1);
        assert!(v.flops_fwd > 0);
    }
}

#[test]
fn executor_runs_all_variants() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let mut exe = Executor::load("artifacts").expect("compile artifacts");
    for name in exe.variant_names() {
        let v = exe.manifest.by_name(&name).unwrap().clone();
        let tokens: Vec<i32> = (0..v.batch * v.seq).map(|i| (i % v.vocab) as i32).collect();
        let out = exe.run(&name, &tokens).expect("execute");
        assert_eq!(out.logits.len(), v.batch * v.seq * v.vocab);
        assert!(out.logits.iter().all(|x| x.is_finite()), "{name}: non-finite logits");
    }
    assert_eq!(exe.executions as usize, exe.variant_names().len());
}

#[test]
fn executor_is_deterministic() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let mut exe = Executor::load("artifacts").unwrap();
    let name = exe.variant_names()[0].clone();
    let v = exe.manifest.by_name(&name).unwrap().clone();
    let tokens: Vec<i32> = (0..v.batch * v.seq).map(|i| ((i * 7) % v.vocab) as i32).collect();
    let a = exe.run(&name, &tokens).unwrap();
    let b = exe.run(&name, &tokens).unwrap();
    assert_eq!(a.logits, b.logits, "same input must give identical logits");
}

#[test]
fn batcher_picks_smallest_fitting_variant() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let mut exe = Executor::load("artifacts").unwrap();
    let seq = exe.manifest.variants[0].seq;
    let rows = vec![vec![1i32; seq]; 2];
    let (name, out) = exe.run_batched(&rows).unwrap();
    let v = exe.manifest.by_name(&name).unwrap();
    assert!(v.batch >= 2, "picked variant {name} too small");
    // row 0 and row 1 have identical inputs => identical logits
    let row = out.seq * out.vocab;
    assert_eq!(out.logits[..row], out.logits[row..2 * row]);
}

#[test]
fn argmax_helper_consistent() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let mut exe = Executor::load("artifacts").unwrap();
    let name = exe.variant_names()[0].clone();
    let v = exe.manifest.by_name(&name).unwrap().clone();
    let tokens: Vec<i32> = (0..v.batch * v.seq).map(|i| (i % 17) as i32).collect();
    let out = exe.run(&name, &tokens).unwrap();
    let am = out.argmax(0, v.seq - 1);
    assert!(am < v.vocab);
    // manual check
    let base = (v.seq - 1) * v.vocab;
    let manual = (0..v.vocab)
        .max_by(|&a, &b| out.logits[base + a].partial_cmp(&out.logits[base + b]).unwrap())
        .unwrap();
    assert_eq!(am, manual);
}
