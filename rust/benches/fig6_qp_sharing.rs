//! cargo bench: regenerate Fig 6 (lock-free vs locked QP sharing).
use rdmavisor::figures::{fig6, print_fig6, Budget};

fn main() {
    let rows = fig6(Budget::from_env(), rdmavisor::util::parallel::jobs_from_env());
    println!("{}", print_fig6(&rows));
    // at the lock-bound point (12 threads) the paper's ordering must hold
    if let Some(r) = rows.iter().find(|r| r.threads == 12) {
        assert!(r.locked_q6.mops < r.locked_q3.mops, "q=6 below q=3");
        assert!(r.raas.mops >= r.locked_q3.mops * 0.95, "RaaS not behind q=3");
    }
    std::fs::create_dir_all("results").ok();
    let mut s = rdmavisor::metrics::Series::new("fig6_qp_sharing", "threads", &["raas", "q3", "q6"]);
    for r in &rows { s.push(r.threads as f64, vec![r.raas.mops, r.locked_q3.mops, r.locked_q6.mops]); }
    s.write_tsv("results").ok();
}
