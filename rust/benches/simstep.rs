//! DES scheduler throughput (EXPERIMENTS.md §Perf): events/sec through
//! the timing-wheel event loop on a daemon-free QP WRITE storm — the raw
//! budget behind every figure sweep. `cargo bench --bench simstep`, or
//! `rdmavisor bench simstep` for the JSON form; quick mode via
//! `RDMAVISOR_BENCH_QUICK=1`.

use rdmavisor::fabric::time::Ns;
use rdmavisor::util::bench::Bencher;
use rdmavisor::workload::scenarios::event_storm;

fn main() {
    let mut b = Bencher::from_env();
    let quick = std::env::var("RDMAVISOR_BENCH_QUICK").is_ok();
    let (pairs, sim_ms) = if quick { (64, 2) } else { (256, 8) };

    b.bench_with_metric("sim/event_storm_events_per_sec", "meps", || {
        let t0 = std::time::Instant::now();
        let events = event_storm(pairs, 8, 4096, Ns::from_ms(sim_ms));
        events as f64 / t0.elapsed().as_secs_f64() / 1e6
    });

    // small-message storm: more events per byte, stresses queue churn
    b.bench_with_metric("sim/event_storm_256B_events_per_sec", "meps", || {
        let t0 = std::time::Instant::now();
        let events = event_storm(pairs, 8, 256, Ns::from_ms(sim_ms));
        events as f64 / t0.elapsed().as_secs_f64() / 1e6
    });

    std::fs::create_dir_all("results").ok();
    b.write_tsv("results/bench_simstep.tsv").ok();
}
