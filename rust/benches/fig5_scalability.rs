//! cargo bench: regenerate Fig 5 (scalability) and assert the paper shape.
use rdmavisor::figures::{fig5, print_fig5, Budget};

fn main() {
    let rows = fig5(Budget::from_env(), rdmavisor::util::parallel::jobs_from_env());
    println!("{}", print_fig5(&rows));
    let low = rows.iter().find(|r| r.conns <= 100).unwrap();
    let high = rows.iter().max_by_key(|r| r.conns).unwrap();
    assert!(high.naive.gbps < low.naive.gbps * 0.6, "naive collapses beyond 400 QPs");
    assert!(high.raas.gbps > low.raas.gbps * 0.9, "RaaS stays stable");
    std::fs::create_dir_all("results").ok();
    let mut s = rdmavisor::metrics::Series::new("fig5_scalability", "conns", &["naive", "raas"]);
    for r in &rows { s.push(r.conns as f64, vec![r.naive.gbps, r.raas.gbps]); }
    s.write_tsv("results").ok();
}
