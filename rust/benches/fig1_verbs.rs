//! cargo bench: regenerate Fig 1 (verbs throughput vs message size) and
//! time the harness itself. RDMAVISOR_BENCH_QUICK=1 shrinks the sweep.
use rdmavisor::figures::{fig1, print_fig1, Budget};
use rdmavisor::util::bench::Bencher;

fn main() {
    let budget = Budget::from_env();
    let rows = fig1(budget, rdmavisor::util::parallel::jobs_from_env());
    println!("{}", print_fig1(&rows));
    // paper-shape checks (who wins, where the knees are)
    let large = rows.iter().find(|r| r.msg_bytes == 1 << 20).unwrap();
    assert!((large.rc_read - large.rc_write).abs() < 2.0, "RC READ ≈ RC WRITE at 1MB");
    assert!(large.rc_write > 34.0, "1MB hits line rate");
    let small = rows.iter().find(|r| r.msg_bytes == 64).unwrap();
    assert!(small.rc_write < 10.0, "64B is overhead-bound");
    let mut b = Bencher::from_env();
    b.bench_with_metric("fig1/rc_write_64k_point", "gbps", || {
        rdmavisor::workload::scenarios::verbs_sweep_point(
            rdmavisor::fabric::types::QpTransport::Rc,
            rdmavisor::fabric::types::Verb::Write,
            64 << 10, 16, rdmavisor::fabric::time::Ns::from_ms(2),
        )
    });
    b.write_tsv("results/bench_fig1.tsv").ok();
}
