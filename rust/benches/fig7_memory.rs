//! cargo bench: regenerate Fig 7 (normalized memory vs #applications).
use rdmavisor::figures::{fig78, print_fig7, Budget};

fn main() {
    let rows = fig78(Budget::from_env(), rdmavisor::util::parallel::jobs_from_env());
    println!("{}", print_fig7(&rows));
    let last = rows.last().unwrap();
    assert!(last.naive_mem > last.apps as f64 * 0.75, "naive memory grows ~linearly");
    assert!(last.raas_mem < last.naive_mem / 2.0, "RaaS memory sublinear");
    std::fs::create_dir_all("results").ok();
    let mut s = rdmavisor::metrics::Series::new("fig7_memory", "apps", &["naive", "raas"]);
    for r in &rows { s.push(r.apps as f64, vec![r.naive_mem, r.raas_mem]); }
    s.write_tsv("results").ok();
}
