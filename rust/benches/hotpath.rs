//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf):
//! * the real lock-free SPSC ring (push/pop, cross-thread),
//! * eventfd doorbell cost,
//! * DES throughput (events/s) — the budget that makes 1000-conn sweeps
//!   run in sub-second wall time,
//! * daemon submit path (read() -> pending batch),
//! * ICM cache touch.
use std::sync::Arc;

use rdmavisor::fabric::cache::{IcmCache, IcmKey};
use rdmavisor::fabric::sim::{FabricConfig, Sim};
use rdmavisor::fabric::time::Ns;
use rdmavisor::fabric::types::NodeId;
use rdmavisor::raas::daemon::{connect_via, Daemon, DaemonConfig};
use rdmavisor::raas::shmem::{Channel, Descriptor, SpscRing};
use rdmavisor::util::bench::Bencher;
use rdmavisor::workload::scenarios::{naive_random_read, ScenarioCfg};

fn main() {
    let mut b = Bencher::from_env();

    // ---- SPSC ring, single-threaded round trip
    let ring: Arc<SpscRing<Descriptor>> = SpscRing::new(4096);
    b.bench("shmem/spsc_push_pop", || {
        ring.push(Descriptor::new(1, 2, 3, 4, 5)).unwrap();
        ring.pop().unwrap()
    });

    // ---- SPSC ring, cross-thread streaming (msgs/s metric)
    b.bench_with_metric("shmem/spsc_cross_thread_1M", "mops", || {
        let r: Arc<SpscRing<u64>> = SpscRing::new(4096);
        let n = 1_000_000u64;
        let t0 = std::time::Instant::now();
        // on a single-core host spinning just burns the timeslice; yielding
        // lets producer/consumer alternate in ring-sized batches
        let prod = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                for i in 0..n {
                    while r.push(i).is_err() {
                        std::thread::yield_now();
                    }
                }
            })
        };
        let mut seen = 0u64;
        while seen < n {
            if r.pop().is_some() {
                seen += 1;
            } else {
                std::thread::yield_now();
            }
        }
        prod.join().unwrap();
        n as f64 / t0.elapsed().as_secs_f64() / 1e6
    });

    // ---- eventfd doorbell ring+wait
    let ch = Channel::new(16).unwrap();
    b.bench("shmem/eventfd_ring_wait", || {
        ch.submit_bell.ring();
        ch.submit_bell.wait_timeout(100)
    });

    // ---- ICM cache touch (hit path)
    let mut cache = IcmCache::new(400);
    for i in 0..400u32 {
        cache.touch(IcmKey::Qpc(i));
    }
    let mut i = 0u32;
    b.bench("fabric/icm_touch_hit", || {
        i = (i + 1) % 400;
        cache.touch(IcmKey::Qpc(i))
    });

    // ---- daemon submit path (ring + selector + lease + batch append)
    {
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 2;
        fcfg.sq_depth = 1 << 20;
        let mut sim = Sim::new(fcfg);
        let mut daemons = vec![
            Daemon::start(&mut sim, NodeId(0), DaemonConfig::default()),
            Daemon::start(&mut sim, NodeId(1), DaemonConfig::default()),
        ];
        let sapp = daemons[1].register_app();
        daemons[1].listen(sapp, 1);
        let app = daemons[0].register_app();
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        let mut tag = 0u64;
        b.bench("raas/submit_read", || {
            tag += 1;
            let r = daemons[0].read(&mut sim, conn, 4096, (tag * 4096) % (1 << 20), tag);
            if tag % 1024 == 0 {
                // keep the pending batch and pool bounded
                daemons[0].pump(&mut sim);
                while sim.step().is_some() {}
                daemons[0].pump(&mut sim);
                while daemons[0].recv_zero_copy(&mut sim, app).is_some() {}
            }
            r.is_ok()
        });
    }

    // ---- whole-stack DES throughput: events/s for a 200-conn fig5 point
    b.bench_with_metric("sim/fig5_point_200conns_8ms", "sim_ms_per_wall_s", || {
        let mut cfg = ScenarioCfg::default();
        cfg.conns = 200;
        cfg.duration = Ns::from_ms(8);
        let t0 = std::time::Instant::now();
        let _ = naive_random_read(&cfg);
        8.0 / t0.elapsed().as_secs_f64() / 1e3 * 1e3
    });

    std::fs::create_dir_all("results").ok();
    b.write_tsv("results/bench_hotpath.tsv").ok();
}
