//! Daemon data-plane throughput (EXPERIMENTS.md §Perf): ops/sec through
//! one daemon's pump loop — Worker batch flush, Poller CQ drain,
//! wr_id-slab completion, inbox delivery, SRQ refill — on a closed-loop
//! READ storm. The number the dense-table/op-slab densification moves
//! (`bench simstep` isolates the fabric below it). `cargo bench --bench
//! pump`, or `rdmavisor bench pump` for the JSON form; quick mode via
//! `RDMAVISOR_BENCH_QUICK=1`.

use rdmavisor::fabric::time::Ns;
use rdmavisor::util::bench::Bencher;
use rdmavisor::workload::scenarios::pump_storm;

fn main() {
    let mut b = Bencher::from_env();
    let quick = std::env::var("RDMAVISOR_BENCH_QUICK").is_ok();
    let (conns, sim_ms) = if quick { (128, 2) } else { (512, 8) };

    b.bench_with_metric("raas/pump_storm_ops_per_sec", "mops", || {
        let t0 = std::time::Instant::now();
        let (ops, _events) = pump_storm(conns, 4096, 4, Ns::from_ms(sim_ms));
        ops as f64 / t0.elapsed().as_secs_f64() / 1e6
    });

    // small messages: more ops per byte, stresses the per-op slab and
    // inbox paths instead of the copy model
    b.bench_with_metric("raas/pump_storm_512B_ops_per_sec", "mops", || {
        let t0 = std::time::Instant::now();
        let (ops, _events) = pump_storm(conns, 512, 4, Ns::from_ms(sim_ms));
        ops as f64 / t0.elapsed().as_secs_f64() / 1e6
    });

    std::fs::create_dir_all("results").ok();
    b.write_tsv("results/bench_pump.tsv").ok();
}
