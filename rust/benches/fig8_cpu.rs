//! cargo bench: regenerate Fig 8 (normalized CPU vs #applications).
use rdmavisor::figures::{fig78, print_fig8, Budget};

fn main() {
    let rows = fig78(Budget::from_env(), rdmavisor::util::parallel::jobs_from_env());
    println!("{}", print_fig8(&rows));
    let last = rows.last().unwrap();
    assert!(last.naive_cpu > last.apps as f64 * 0.75, "naive CPU grows ~linearly (poll thread per app)");
    assert!(last.raas_cpu < last.naive_cpu / 2.0, "RaaS CPU ~flat (2 service threads)");
    std::fs::create_dir_all("results").ok();
    let mut s = rdmavisor::metrics::Series::new("fig8_cpu", "apps", &["naive", "raas"]);
    for r in &rows { s.push(r.apps as f64, vec![r.naive_cpu, r.raas_cpu]); }
    s.write_tsv("results").ok();
}
