//! Workload generation and the evaluation scenario drivers.
//!
//! * [`generator`] — seeded offset/size/key generators (uniform + zipf),
//!   open-loop arrival processes, trace recording/replay.
//! * [`scenarios`] — the paper's evaluation workloads as closed-loop
//!   drivers over the simulator: random READ fan-out for naive / locked /
//!   RaaS clients (Figs 5 & 6), the verbs-level size sweep (Fig 1), and
//!   the per-application resource scenario (Figs 7 & 8).

pub mod generator;
pub mod scenarios;
