//! Seeded workload generators: offsets, sizes, arrivals, traces.

use crate::fabric::time::Ns;
use crate::util::rng::{Rng, Zipf};

/// Access-pattern generator for remote offsets within a buffer.
#[derive(Clone, Debug)]
pub enum OffsetGen {
    /// Uniform random block-aligned offsets (the paper's "randomly read").
    Uniform { region: u64, align: u64 },
    /// Zipf-distributed block popularity (KV-style skew).
    Zipf { region: u64, align: u64, dist: Zipf },
    /// Pure sequential streaming.
    Sequential { region: u64, align: u64, cursor: u64 },
}

impl OffsetGen {
    /// Uniform block-aligned offsets over `region` bytes.
    pub fn uniform(region: u64, align: u64) -> OffsetGen {
        OffsetGen::Uniform { region, align }
    }

    /// Zipf(θ)-popular blocks over `region` bytes.
    pub fn zipf(region: u64, align: u64, theta: f64) -> OffsetGen {
        let blocks = (region / align).max(1);
        OffsetGen::Zipf { region, align, dist: Zipf::new(blocks, theta) }
    }

    /// Sequential streaming over `region` bytes.
    pub fn sequential(region: u64, align: u64) -> OffsetGen {
        OffsetGen::Sequential { region, align, cursor: 0 }
    }

    /// Next offset for an op of `len` bytes (always fits the region).
    pub fn next(&mut self, rng: &mut Rng, len: u64) -> u64 {
        match self {
            OffsetGen::Uniform { region, align } => {
                let blocks = ((*region - len.min(*region)) / *align).max(1);
                rng.gen_range(blocks) * *align
            }
            OffsetGen::Zipf { region, align, dist } => {
                let off = dist.sample(rng) * *align;
                off.min(region.saturating_sub(len))
            }
            OffsetGen::Sequential { region, align, cursor } => {
                let off = *cursor;
                *cursor = (*cursor + *align) % region.saturating_sub(len).max(1);
                off
            }
        }
    }
}

/// Message-size distribution.
#[derive(Clone, Debug)]
pub enum SizeGen {
    /// Constant size.
    Fixed(u64),
    /// Log-uniform between lo and hi (heavy small-message tail).
    LogUniform { lo: u64, hi: u64 },
    /// Bimodal: small with probability p, else large (RPC req/resp shape).
    Bimodal { small: u64, large: u64, p_small: f64 },
}

impl SizeGen {
    /// Draw the next message size.
    pub fn next(&self, rng: &mut Rng) -> u64 {
        match self {
            SizeGen::Fixed(n) => *n,
            SizeGen::LogUniform { lo, hi } => {
                let (l, h) = ((*lo as f64).ln(), (*hi as f64).ln());
                (l + rng.f64() * (h - l)).exp() as u64
            }
            SizeGen::Bimodal { small, large, p_small } => {
                if rng.chance(*p_small) {
                    *small
                } else {
                    *large
                }
            }
        }
    }
}

/// Open-loop Poisson arrivals.
#[derive(Clone, Debug)]
pub struct Arrivals {
    mean_gap_ns: f64,
    next_at: Ns,
}

impl Arrivals {
    /// Poisson arrivals at `rate_per_sec` events/second.
    pub fn poisson(rate_per_sec: f64) -> Arrivals {
        Arrivals { mean_gap_ns: 1e9 / rate_per_sec, next_at: Ns::ZERO }
    }

    /// Next arrival at or after `now`.
    pub fn next(&mut self, rng: &mut Rng, now: Ns) -> Ns {
        let gap = rng.exp(self.mean_gap_ns) as u64;
        self.next_at = Ns(self.next_at.0.max(now.0) + gap);
        self.next_at
    }
}

/// A recorded operation for trace replay.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceOp {
    /// Virtual time the op was issued.
    pub at: Ns,
    /// Connection the op ran on.
    pub conn: u32,
    /// Payload size.
    pub len: u64,
    /// Remote offset.
    pub offset: u64,
}

/// Fixed-capacity trace recorder (ring, keeps the tail).
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Recorded operations, in issue order.
    pub ops: Vec<TraceOp>,
    cap: usize,
}

impl Trace {
    /// Trace that keeps at most `cap` ops.
    pub fn with_capacity(cap: usize) -> Trace {
        Trace { ops: Vec::with_capacity(cap.min(1 << 20)), cap }
    }

    /// Record an op (dropped once the trace is full).
    pub fn record(&mut self, op: TraceOp) {
        if self.ops.len() < self.cap {
            self.ops.push(op);
        }
    }

    /// Serialize as TSV for offline analysis.
    pub fn to_tsv(&self) -> String {
        let mut s = String::from("at_ns\tconn\tlen\toffset\n");
        for op in &self.ops {
            s.push_str(&format!("{}\t{}\t{}\t{}\n", op.at.0, op.conn, op.len, op.offset));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_offsets_block_aligned_in_range() {
        let mut g = OffsetGen::uniform(1 << 20, 4096);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let off = g.next(&mut rng, 64 << 10);
            assert_eq!(off % 4096, 0);
            assert!(off + (64 << 10) <= 1 << 20);
        }
    }

    #[test]
    fn zipf_offsets_skewed() {
        let mut g = OffsetGen::zipf(1 << 20, 4096, 0.99);
        let mut rng = Rng::new(2);
        let mut first_block = 0;
        for _ in 0..1000 {
            if g.next(&mut rng, 4096) == 0 {
                first_block += 1;
            }
        }
        assert!(first_block > 50, "zipf head should repeat: {first_block}");
    }

    #[test]
    fn sequential_wraps() {
        let mut g = OffsetGen::sequential(16 << 10, 4096);
        let mut rng = Rng::new(3);
        let offs: Vec<u64> = (0..4).map(|_| g.next(&mut rng, 4096)).collect();
        assert_eq!(offs, vec![0, 4096, 8192, 0]);
    }

    #[test]
    fn size_generators_in_bounds() {
        let mut rng = Rng::new(4);
        let lu = SizeGen::LogUniform { lo: 64, hi: 65536 };
        for _ in 0..1000 {
            let s = lu.next(&mut rng);
            assert!((64..=65536).contains(&s), "{s}");
        }
        let bi = SizeGen::Bimodal { small: 128, large: 1 << 20, p_small: 0.9 };
        let smalls = (0..1000).filter(|_| bi.next(&mut rng) == 128).count();
        assert!(smalls > 800);
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let mut a = Arrivals::poisson(1_000_000.0);
        let mut rng = Rng::new(5);
        let mut last = Ns::ZERO;
        for _ in 0..100 {
            let t = a.next(&mut rng, last);
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn trace_records_and_serializes() {
        let mut t = Trace::with_capacity(2);
        t.record(TraceOp { at: Ns(1), conn: 2, len: 3, offset: 4 });
        t.record(TraceOp { at: Ns(5), conn: 6, len: 7, offset: 8 });
        t.record(TraceOp { at: Ns(9), conn: 0, len: 0, offset: 0 }); // dropped
        assert_eq!(t.ops.len(), 2);
        let tsv = t.to_tsv();
        assert!(tsv.contains("1\t2\t3\t4"));
    }
}
