//! The paper's evaluation workloads as deterministic closed-loop drivers.
//!
//! Testbed shape mirrors §3: a 4-node cluster (24 cores, 40 Gb NICs); one
//! node hosts the client stack under test and "randomly reads 64 KB data
//! from other machines". Each driver returns a [`RunStats`] row; the
//! figure harnesses sweep parameters and print the paper-shaped series.

use crate::apps::kv::{KvClient, KvLayout, KvMode, KvServer};
use crate::baselines::locked::LockedSystem;
use crate::baselines::naive::NaiveSystem;
use crate::fabric::sim::{FabricConfig, Notification, Sim};
use crate::fabric::time::{gbps, Ns};
use crate::fabric::topo::CcMode;
use crate::fabric::types::NodeId;
use crate::raas::api::Flags;
use crate::raas::daemon::{connect_via, disconnect_via, Daemon, DaemonConfig, Delivery};
use crate::raas::transport::HostLoad;
use crate::util::rng::Rng;
use crate::util::stats::Histogram;

use super::generator::{OffsetGen, SizeGen};

/// Common scenario parameters.
#[derive(Clone, Debug)]
pub struct ScenarioCfg {
    /// Logical connections (or worker threads) on the client machine.
    pub conns: usize,
    /// Applications the connections are divided among.
    pub apps: u32,
    /// Operation payload size.
    pub msg_bytes: u64,
    /// Outstanding ops per connection (closed loop window).
    pub window: u32,
    /// Virtual run length.
    pub duration: Ns,
    /// Fraction of the run treated as warmup (excluded from stats).
    pub warmup_frac: f64,
    /// Workload RNG seed (runs replay bit-identically).
    pub seed: u64,
    /// Fabric the scenario runs on.
    pub fabric: FabricConfig,
}

impl Default for ScenarioCfg {
    fn default() -> Self {
        let mut fabric = FabricConfig::default();
        fabric.sq_depth = 8192; // shared QPs carry many conns' WRs
        ScenarioCfg {
            conns: 100,
            apps: 1,
            msg_bytes: 64 << 10,
            window: 1,
            duration: Ns::from_ms(20),
            warmup_frac: 0.25,
            seed: 42,
            fabric,
        }
    }
}

/// One measured run.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    /// Delivered payload throughput, Gb/s.
    pub gbps: f64,
    /// Completed operations, millions per second.
    pub mops: f64,
    /// Operations completed inside the measured window.
    pub ops: u64,
    /// Median op latency, microseconds.
    pub p50_us: f64,
    /// 99th-percentile op latency, microseconds.
    pub p99_us: f64,
    /// Client-side memory footprint (Fig 7 input).
    pub mem_bytes: u64,
    /// Client-side cores-equivalent consumed (Fig 8 input).
    pub cpu_cores: f64,
    /// Client-NIC ICM cache hit rate over the measured window.
    pub cache_hit_rate: f64,
    /// Lock wait (locked baseline only).
    pub lock_wait_ms: f64,
}

fn servers(cfg: &ScenarioCfg) -> Vec<NodeId> {
    (1..cfg.fabric.nodes as u32).map(NodeId).collect()
}

/// Measurement window bookkeeping shared by the drivers.
struct Window {
    warmup_end: Ns,
    started: bool,
    bytes0: u64,
    ops0: u64,
    t0: Ns,
    lat: Histogram,
}

impl Window {
    fn new(cfg: &ScenarioCfg) -> Window {
        Window {
            warmup_end: Ns((cfg.duration.0 as f64 * cfg.warmup_frac) as u64),
            started: false,
            bytes0: 0,
            ops0: 0,
            t0: Ns::ZERO,
            lat: Histogram::new(),
        }
    }

    fn maybe_start(&mut self, sim: &Sim) {
        if !self.started && sim.now() >= self.warmup_end {
            self.started = true;
            // measure wire-level delivered payload, not message completions
            // (completions clump: a message's bytes cross the wire long
            // before its CQE, which biases short windows)
            self.bytes0 = sim.total_rx_data_bytes();
            self.ops0 = sim.completed_msgs;
            self.t0 = sim.now();
        }
    }

    fn record_latency(&mut self, ns: u64) {
        if self.started {
            self.lat.record(ns);
        }
    }

    fn finish(&self, sim: &Sim) -> (f64, f64, u64, f64, f64) {
        let span = sim.now().saturating_sub(self.t0);
        let bytes = sim.total_rx_data_bytes() - self.bytes0;
        let ops = sim.completed_msgs - self.ops0;
        (
            gbps(bytes, span),
            if span.0 == 0 { 0.0 } else { ops as f64 * 1e3 / span.0 as f64 },
            ops,
            self.lat.p50() as f64 / 1e3,
            self.lat.p99() as f64 / 1e3,
        )
    }
}

/// Fig 5 (naive series): one QP per connection, random 64 KB READs.
pub fn naive_random_read(cfg: &ScenarioCfg) -> RunStats {
    let mut sim = Sim::new(cfg.fabric.clone());
    let srv = servers(cfg);
    let conns_per_app = (cfg.conns as u32).div_ceil(cfg.apps);
    let mut sys = NaiveSystem::setup(
        &mut sim,
        NodeId(0),
        &srv,
        cfg.apps,
        conns_per_app,
        (cfg.msg_bytes * 4).max(256 << 10),
    );
    let n = sys.conns.len().min(cfg.conns);
    let mut rng = Rng::new(cfg.seed);
    let mut offgen = OffsetGen::uniform((cfg.msg_bytes * 3).max(256 << 10), 4096);
    let mut posted_at: Vec<Ns> = vec![Ns::ZERO; n];
    let mut win = Window::new(cfg);

    for i in 0..n {
        for _ in 0..cfg.window {
            let off = offgen.next(&mut rng, cfg.msg_bytes);
            posted_at[i] = sim.now();
            sys.post_read(&mut sim, i, cfg.msg_bytes, off);
        }
    }
    // reset cache stats after connection churn
    sim.node_mut(NodeId(0)).cache.reset_stats();

    let mut notes: Vec<Notification> = Vec::new();
    while sim.now() < cfg.duration {
        win.maybe_start(&sim);
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        let any_cqe = notes
            .iter()
            .any(|n| matches!(n, Notification::CqeReady { node, .. } if *node == NodeId(0)));
        if any_cqe {
            for idx in sys.poll(&mut sim) {
                win.record_latency(sim.now().saturating_sub(posted_at[idx]).0);
                let off = offgen.next(&mut rng, cfg.msg_bytes);
                posted_at[idx] = sim.now();
                sys.post_read(&mut sim, idx, cfg.msg_bytes, off);
            }
        }
    }

    let (gbps, mops, ops, p50, p99) = win.finish(&sim);
    RunStats {
        gbps,
        mops,
        ops,
        p50_us: p50,
        p99_us: p99,
        mem_bytes: sys.client_mem_bytes(&sim),
        cpu_cores: sys.client_cpu_cores(&sim),
        cache_hit_rate: sim.node(NodeId(0)).cache.hit_rate(),
        lock_wait_ms: 0.0,
    }
}

/// Fig 5/6 (RaaS series) + Figs 7/8 (RaaS resource scaling): shared QPs,
/// lock-free vQPN demux, WR batching.
pub fn raas_random_read(cfg: &ScenarioCfg) -> RunStats {
    raas_random_read_with_daemon(cfg, DaemonConfig::default())
}

/// RaaS run with a custom daemon config (ablation entry point).
pub fn raas_random_read_with_daemon(cfg: &ScenarioCfg, dcfg: DaemonConfig) -> RunStats {
    let mut sim = Sim::new(cfg.fabric.clone());
    let n_nodes = cfg.fabric.nodes;
    let mut daemons: Vec<Daemon> = (0..n_nodes)
        .map(|i| Daemon::start(&mut sim, NodeId(i as u32), dcfg.clone()))
        .collect();

    // server side: one service app listening per server daemon
    for d in daemons.iter_mut().skip(1) {
        let app = d.register_app();
        d.listen(app, 7000);
    }
    // client side: apps with conns spread across servers
    let mut client_apps = Vec::new();
    for _ in 0..cfg.apps {
        client_apps.push(daemons[0].register_app());
    }
    let mut conns = Vec::new();
    for i in 0..cfg.conns {
        let app = client_apps[i % client_apps.len()];
        let server = 1 + (i % (n_nodes - 1));
        let c = connect_via(&mut sim, &mut daemons, 0, app, server, 7000).unwrap();
        conns.push((c, app));
    }

    let mut rng = Rng::new(cfg.seed);
    let mut offgen = OffsetGen::uniform(64 << 20, 4096);
    let mut posted_at: std::collections::HashMap<u32, (Ns, usize)> = std::collections::HashMap::new();
    let mut win = Window::new(cfg);

    for (i, (c, _)) in conns.iter().enumerate() {
        for _ in 0..cfg.window {
            let off = offgen.next(&mut rng, cfg.msg_bytes);
            daemons[0].read(&mut sim, *c, cfg.msg_bytes, off, i as u64).unwrap();
            posted_at.insert(c.0, (sim.now(), i));
        }
    }
    daemons[0].pump(&mut sim);
    sim.node_mut(NodeId(0)).cache.reset_stats();

    let mut notes: Vec<Notification> = Vec::new();
    while sim.now() < cfg.duration {
        win.maybe_start(&sim);
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        let client_cqe = notes.iter().any(
            |n| matches!(n, Notification::CqeReady { node, .. } if *node == NodeId(0)),
        );
        if client_cqe {
            daemons[0].pump(&mut sim);
            // drain app inboxes and re-post (closed loop)
            for &app in &client_apps {
                while let Some(d) = daemons[0].recv_zero_copy(&mut sim, app) {
                    if let Delivery::OpComplete { conn, .. } = d {
                        if let Some((t, _i)) = posted_at.get(&conn.0) {
                            win.record_latency(sim.now().saturating_sub(*t).0);
                        }
                        let off = offgen.next(&mut rng, cfg.msg_bytes);
                        let _ = daemons[0].read(&mut sim, conn, cfg.msg_bytes, off, 0);
                        posted_at.insert(conn.0, (sim.now(), 0));
                    }
                }
            }
            daemons[0].pump(&mut sim);
        }
    }

    let (gbps, mops, ops, p50, p99) = win.finish(&sim);
    let snap = daemons[0].snapshot(&sim);
    RunStats {
        gbps,
        mops,
        ops,
        p50_us: p50,
        p99_us: p99,
        mem_bytes: snap.mem_bytes,
        cpu_cores: snap.cpu_cores,
        cache_hit_rate: sim.node(NodeId(0)).cache.hit_rate(),
        lock_wait_ms: 0.0,
    }
}

/// Fig 6 (locked series): FaRM-style mutex-shared QPs, q threads per QP.
pub fn locked_random_read(cfg: &ScenarioCfg, q: usize) -> RunStats {
    let mut sim = Sim::new(cfg.fabric.clone());
    let srv = servers(cfg);
    let mut sys = LockedSystem::setup(
        &mut sim,
        NodeId(0),
        &srv,
        cfg.conns,
        q,
        (cfg.msg_bytes * 4).max(256 << 10),
    );
    let mut rng = Rng::new(cfg.seed);
    let mut offgen = OffsetGen::uniform((cfg.msg_bytes * 2).max(128 << 10), 4096);
    let mut posted_at: Vec<Ns> = vec![Ns::ZERO; cfg.conns];
    let mut win = Window::new(cfg);

    // initial posts go through the lock protocol
    for t in 0..cfg.conns {
        for _ in 0..cfg.window {
            let grant = sys.acquire_for_post(sim.now(), t);
            sim.schedule(grant, t as u64);
        }
    }
    sim.node_mut(NodeId(0)).cache.reset_stats();

    let mut notes: Vec<Notification> = Vec::new();
    while sim.now() < cfg.duration {
        win.maybe_start(&sim);
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        for note in notes.drain(..) {
            match note {
                Notification::Timer { token } => {
                    let t = token as usize;
                    let off = offgen.next(&mut rng, cfg.msg_bytes);
                    posted_at[t] = sim.now();
                    sys.post_read_at(&mut sim, t, cfg.msg_bytes, off);
                }
                Notification::CqeReady { node, .. } if node == NodeId(0) => {
                    for t in sys.poll(&mut sim) {
                        win.record_latency(sim.now().saturating_sub(posted_at[t]).0);
                        let grant = sys.acquire_for_post(sim.now(), t);
                        sim.schedule(grant, t as u64);
                    }
                }
                _ => {}
            }
        }
    }

    let (gbps, mops, ops, p50, p99) = win.finish(&sim);
    RunStats {
        gbps,
        mops,
        ops,
        p50_us: p50,
        p99_us: p99,
        mem_bytes: sim.node(NodeId(0)).fabric_mem_bytes()
            + sim.node(NodeId(0)).mrs.registered_bytes,
        cpu_cores: sim.node(NodeId(0)).cpu.cores_used(sim.now()),
        cache_hit_rate: sim.node(NodeId(0)).cache.hit_rate(),
        lock_wait_ms: sys.lock_wait_ns as f64 / 1e6,
    }
}

// ------------------------------------------------- Fig 9 (scale sweep)

/// Config for the thousand-connection scale experiment (Fig 9): one
/// client daemon sending 64 B–4 KB messages over `conns` logical
/// connections fanned out across up to `max_servers` destination
/// daemons. Each destination needs its own shared RC QP, so past the
/// ICM-cache capacity the RC working set thrashes — the regime the
/// adaptive RC↔UD migration ([`crate::raas::migrate`]) exists for.
#[derive(Clone, Debug)]
pub struct ScaleCfg {
    /// Logical connections on the client machine.
    pub conns: usize,
    /// Cap on distinct destination daemons (cluster size - 1).
    pub max_servers: usize,
    /// Smallest message size drawn (log-uniform).
    pub msg_lo: u64,
    /// Largest message size drawn (log-uniform). Must not exceed the
    /// fabric MTU: `sim.completed_msgs` counts one per *wire message*,
    /// and a UD message above the MTU fragments into several, which
    /// would inflate the adaptive run's mops against the RC-only
    /// ablation. `scale_send` asserts this.
    pub msg_hi: u64,
    /// Virtual run length.
    pub duration: Ns,
    /// Fraction of the run treated as warmup (excluded from stats).
    pub warmup_frac: f64,
    /// Workload RNG seed.
    pub seed: u64,
    /// Ablation: disable migration, everything stays on RC.
    pub rc_only: bool,
    /// Simulator shard count (1 = serial; forwarded to
    /// [`FabricConfig`]`::shards`, byte-identical output for any value).
    pub shards: usize,
}

impl Default for ScaleCfg {
    fn default() -> Self {
        ScaleCfg {
            conns: 256,
            max_servers: 1024,
            msg_lo: 64,
            msg_hi: 4096,
            duration: Ns::from_ms(10),
            warmup_frac: 0.3,
            seed: 42,
            rc_only: false,
            shards: 1,
        }
    }
}

/// One measured scale-sweep point.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScaleRun {
    /// Logical connections of this point.
    pub conns: usize,
    /// Distinct destination daemons of this point.
    pub servers: usize,
    /// Delivered payload throughput, Gb/s.
    pub gbps: f64,
    /// Completed messages, millions per second.
    pub mops: f64,
    /// Messages completed inside the measured window.
    pub ops: u64,
    /// Client cores-equivalent (daemon threads + itemized work).
    pub cpu_cores: f64,
    /// Client fabric memory: QP/CQ/SRQ rings + MTT + pool high-water.
    pub fabric_mem_bytes: u64,
    /// Fraction of `send()` calls that rode the UD QP.
    pub ud_fraction: f64,
    /// Client-NIC ICM hit rate over the measured window.
    pub cache_hit_rate: f64,
    /// RC→UD migrations the client daemon performed.
    pub migrations_to_ud: u64,
    /// Destinations on RC at the end of the run.
    pub rc_dests: usize,
    /// Destinations on UD at the end of the run.
    pub ud_dests: usize,
    /// Simulator events processed over the whole run (deterministic; the
    /// wall-clock benches divide by this for events/sec).
    pub events: u64,
}

/// Client daemon config for the scale sweep: a 4 KB-slab pool deep
/// enough for `conns` outstanding small sends, a UD SQ that can hold the
/// whole closed-loop window, and migration switched per the ablation.
fn scale_client_cfg(cfg: &ScaleCfg) -> DaemonConfig {
    let mut d = DaemonConfig::default();
    d.pool_layout = vec![(4096, (2 * cfg.conns).max(2048) as u32)];
    d.recv_slot_bytes = 4096;
    d.srq_capacity = 64;
    d.srq_watermark = 16;
    d.ud_sq_depth = (2 * cfg.conns).max(8192);
    d.migration.enabled = !cfg.rc_only;
    d
}

/// Server daemon config: small per-node footprint so a 1000-server
/// cluster stays cheap to simulate.
fn scale_server_cfg() -> DaemonConfig {
    let mut d = DaemonConfig::default();
    d.pool_layout = vec![(4096, 1024)];
    d.recv_slot_bytes = 4096;
    d.srq_capacity = 512;
    d.srq_watermark = 64;
    d.ud_sq_depth = 64;
    d.service_threads = 1;
    d
}

/// Fig 9: closed-loop `send()` fan-out across `cfg.conns` connections.
/// With migration on, a destination working set past the ICM budget
/// rides the host-wide UD QP; with `rc_only`, every destination keeps
/// its shared RC QP and the client NIC thrashes its context cache (the
/// Fig-5 collapse, now at the *destination* axis).
pub fn scale_send(cfg: &ScaleCfg) -> ScaleRun {
    let servers = cfg.conns.min(cfg.max_servers).max(1);
    let mut fabric = FabricConfig::default();
    fabric.nodes = servers + 1;
    fabric.sq_depth = 1024;
    fabric.shards = cfg.shards;
    assert!(
        cfg.msg_hi <= fabric.mtu,
        "msg_hi {} > MTU {}: fragmented UD messages would be counted once \
         per fragment, skewing the adaptive-vs-rc_only mops comparison",
        cfg.msg_hi,
        fabric.mtu
    );
    let mut sim = Sim::new(fabric);

    let mut daemons: Vec<Daemon> = Vec::with_capacity(servers + 1);
    daemons.push(Daemon::start(&mut sim, NodeId(0), scale_client_cfg(cfg)));
    for s in 0..servers {
        daemons.push(Daemon::start(&mut sim, NodeId(s as u32 + 1), scale_server_cfg()));
    }
    let mut server_apps = vec![0u32; servers + 1];
    for (s, d) in daemons.iter_mut().enumerate().skip(1) {
        let app = d.register_app();
        d.listen(app, 7000);
        server_apps[s] = app;
    }
    let app = daemons[0].register_app();
    let mut conns = Vec::with_capacity(cfg.conns);
    for i in 0..cfg.conns {
        let server = 1 + i % servers;
        conns.push(connect_via(&mut sim, &mut daemons, 0, app, server, 7000).unwrap());
    }

    let mut rng = Rng::new(cfg.seed);
    let sizes = SizeGen::LogUniform { lo: cfg.msg_lo, hi: cfg.msg_hi };
    let mut win = Window::new(&ScenarioCfg {
        duration: cfg.duration,
        warmup_frac: cfg.warmup_frac,
        ..ScenarioCfg::default()
    });

    // first pump evaluates migration before the initial burst
    daemons[0].pump(&mut sim);
    for (i, c) in conns.iter().enumerate() {
        let len = sizes.next(&mut rng).clamp(cfg.msg_lo, cfg.msg_hi);
        daemons[0]
            .send(&mut sim, *c, len, Flags::default(), i as u64, HostLoad::default())
            .unwrap();
    }
    daemons[0].pump(&mut sim);
    sim.node_mut(NodeId(0)).cache.reset_stats();

    let mut server_nodes: Vec<u32> = Vec::new();
    let mut notes: Vec<Notification> = Vec::new();
    // ICM counters at window start, so the reported hit rate covers the
    // measured window only (warmup excluded, like bytes/ops)
    let mut icm0: Option<(u64, u64)> = None;
    while sim.now() < cfg.duration {
        win.maybe_start(&sim);
        if win.started && icm0.is_none() {
            let c = &sim.node(NodeId(0)).cache;
            icm0 = Some((c.hits, c.misses));
        }
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        let mut client_cqe = false;
        server_nodes.clear();
        for n in &notes {
            if let Notification::CqeReady { node, .. } = n {
                if node.0 == 0 {
                    client_cqe = true;
                } else {
                    server_nodes.push(node.0);
                }
            }
        }
        // dedup needs sorted input (Vec::dedup only removes adjacent
        // repeats); pump order across distinct servers does not affect
        // the client-side measurement
        server_nodes.sort_unstable();
        server_nodes.dedup();
        for &s in &server_nodes {
            let d = &mut daemons[s as usize];
            d.pump(&mut sim);
            while d.recv_zero_copy(&mut sim, server_apps[s as usize]).is_some() {}
        }
        if client_cqe {
            daemons[0].pump(&mut sim);
            while let Some(d) = daemons[0].recv_zero_copy(&mut sim, app) {
                if let Delivery::OpComplete { conn, .. } = d {
                    let len = sizes.next(&mut rng).clamp(cfg.msg_lo, cfg.msg_hi);
                    let _ = daemons[0].send(
                        &mut sim,
                        conn,
                        len,
                        Flags::default(),
                        0,
                        HostLoad::default(),
                    );
                }
            }
            daemons[0].pump(&mut sim);
        }
    }

    let (gbps, mops, ops, _p50, _p99) = win.finish(&sim);
    let snap = daemons[0].snapshot(&sim);
    let (rc, draining, ud) = daemons[0].migrate.state_counts();
    let cache = &sim.node(NodeId(0)).cache;
    let (h0, m0) = icm0.unwrap_or((0, 0));
    let (wh, wm) = (cache.hits - h0, cache.misses - m0);
    ScaleRun {
        conns: cfg.conns,
        servers,
        gbps,
        mops,
        ops,
        cpu_cores: snap.cpu_cores,
        fabric_mem_bytes: snap.mem_bytes,
        ud_fraction: daemons[0].ud_send_fraction(),
        cache_hit_rate: if wh + wm == 0 { 0.0 } else { wh as f64 / (wh + wm) as f64 },
        migrations_to_ud: daemons[0].migrate.to_ud,
        rc_dests: rc + draining,
        ud_dests: ud,
        events: sim.steps_processed(),
    }
}

// ------------------------------------------------ Fig 10 (chaos sweep)

/// Config for the fault-injection chaos experiment (fig 10): closed-loop
/// `send()` fan-out like [`ScaleCfg`], but over a seeded lossy fabric —
/// iid + burst frame loss, delay jitter, link-flap windows and optional
/// server restarts ([`crate::fabric::fault`]). Message sizes deliberately
/// exceed the MTU so UD-migrated traffic fragments: a lost fragment then
/// tears a hole RC would have retransmitted around, which is the
/// adaptive-vs-`--rc-only` story the figure tells.
#[derive(Clone, Debug)]
pub struct ChaosCfg {
    /// Logical connections on the client machine.
    pub conns: usize,
    /// Cap on distinct destination daemons.
    pub max_servers: usize,
    /// Smallest message size drawn (log-uniform).
    pub msg_lo: u64,
    /// Largest message size drawn (log-uniform; MAY exceed the MTU —
    /// goodput is measured as daemon-level delivered messages, so
    /// fragment counting cannot skew the comparison).
    pub msg_hi: u64,
    /// Virtual run length.
    pub duration: Ns,
    /// Fraction of the run treated as warmup (excluded from stats).
    pub warmup_frac: f64,
    /// Workload seed; the fault plan's RNG stream is split off it.
    pub seed: u64,
    /// Ablation: disable migration, everything stays on RC.
    pub rc_only: bool,
    /// Per-frame iid drop probability (0.0 + no flaps/restarts = the
    /// null plan: the fault layer is not even installed).
    pub loss: f64,
    /// Link-down windows drawn on client↔server links (1–2 ms, long
    /// enough to exhaust the RC retry budget).
    pub flaps: u32,
    /// Server soft-restarts scheduled mid-run.
    pub server_restarts: u32,
    /// Simulator shard count (1 = serial; forwarded to
    /// [`FabricConfig`]`::shards`, byte-identical output for any value).
    pub shards: usize,
}

impl Default for ChaosCfg {
    fn default() -> Self {
        ChaosCfg {
            conns: 128,
            max_servers: 16,
            msg_lo: 64,
            msg_hi: 16 << 10,
            duration: Ns::from_ms(10),
            warmup_frac: 0.25,
            seed: 42,
            rc_only: false,
            loss: 0.0,
            flaps: 0,
            server_restarts: 0,
            shards: 1,
        }
    }
}

/// One measured chaos point.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChaosRun {
    /// Logical connections of this point.
    pub conns: usize,
    /// Distinct destination daemons.
    pub servers: usize,
    /// The injected per-frame loss rate.
    pub loss: f64,
    /// Application-level goodput, Gb/s: bytes of fully delivered
    /// messages counted at the receiving daemons (wire-level rx bytes
    /// would credit fragments of messages reassembly later discards).
    pub gbps: f64,
    /// Delivered messages, millions per second.
    pub mops: f64,
    /// Messages delivered inside the measured window.
    pub ops: u64,
    /// Median successful-op latency, microseconds.
    pub p50_us: f64,
    /// 99th-percentile successful-op latency, microseconds.
    pub p99_us: f64,
    /// Fraction of `send()` calls that rode the UD QP.
    pub ud_fraction: f64,
    /// Client ops that completed in failure or were reclaimed.
    pub failed_ops: u64,
    /// RC message retransmissions (go-back-N), all nodes.
    pub retransmits: u64,
    /// RC messages that exhausted their retry budget.
    pub retry_exceeded: u64,
    /// RC data frames discarded by the responder go-back-N discipline.
    pub gbn_discards: u64,
    /// Frames the fault layer dropped (iid + burst + flap).
    pub frames_dropped: u64,
    /// Frames the fault layer jitter-delayed.
    pub frames_delayed: u64,
    /// UD partial messages discarded on a reassembly gap or sender
    /// restart, summed over the server daemons.
    pub ud_dropped: u64,
    /// UD fragments that arrived with no partial in progress.
    pub ud_orphans: u64,
    /// UD partials reclaimed by the fragment timeout.
    pub ud_expired: u64,
    /// Staging leases reclaimed without a completion, all daemons.
    pub leases_reclaimed: u64,
    /// Node soft-restarts executed.
    pub restarts: u64,
    /// RC→UD migrations the client daemon performed.
    pub migrations_to_ud: u64,
    /// Simulator events processed over the whole run.
    pub events: u64,
}

/// Build the seeded fault plan for one chaos run: flap windows and
/// restart instants are drawn from a stream split off the scenario seed
/// (never the workload stream), and only links that actually carry
/// traffic (client↔server) can flap.
fn chaos_fault_cfg(cfg: &ChaosCfg, servers: usize) -> crate::fabric::fault::FaultConfig {
    use crate::fabric::fault::{FaultConfig, Flap};
    let mut rng = Rng::new(cfg.seed ^ 0xC4A0_5FA0_0017);
    let mut flaps = Vec::new();
    for _ in 0..cfg.flaps {
        let server = 1 + rng.gen_range(servers as u64) as u32;
        // half the flaps kill the data direction, half the ACK direction
        let (src, dst) = if rng.chance(0.5) { (0u32, server) } else { (server, 0u32) };
        let lo = cfg.duration.0 / 8;
        let hi = (cfg.duration.0 * 5 / 8).max(lo + 1);
        let start = lo + rng.gen_range(hi - lo);
        let down = 1_000_000 + rng.gen_range(1_000_000); // 1–2 ms
        flaps.push(Flap {
            src: NodeId(src),
            dst: NodeId(dst),
            from: Ns(start),
            until: Ns(start + down),
        });
    }
    let mut restarts = Vec::new();
    for _ in 0..cfg.server_restarts {
        let server = 1 + rng.gen_range(servers as u64) as u32;
        let lo = cfg.duration.0 / 4;
        let hi = (cfg.duration.0 * 3 / 4).max(lo + 1);
        restarts.push((server, lo + rng.gen_range(hi - lo)));
    }
    FaultConfig {
        seed: rng.next_u64(),
        drop_p: cfg.loss,
        burst_p: if cfg.loss > 0.0 { 0.1 } else { 0.0 },
        burst_len: (4, 16),
        jitter_p: if cfg.loss > 0.0 { 0.02 } else { 0.0 },
        jitter_ns: (200, 4000),
        flaps,
        restarts,
        ..FaultConfig::default()
    }
}

/// Client daemon config for the chaos runs. The RC context budget is
/// shrunk so the 16-server destination working set overflows it and the
/// adaptive run actually rides UD — the same regime fig 9 reaches with a
/// thousand servers, at a cluster size cheap enough to sweep loss rates.
/// Fault hygiene (stale-lease reclaim) is on; it must outlast the RC
/// retry span (~1 ms) by a wide margin.
fn chaos_client_cfg(cfg: &ChaosCfg) -> DaemonConfig {
    let mut d = DaemonConfig::default();
    let slots = (2 * cfg.conns).max(1024) as u32;
    d.pool_layout = vec![(4096, slots), (16 << 10, slots)];
    d.recv_slot_bytes = 4096;
    d.srq_capacity = 64;
    d.srq_watermark = 16;
    d.ud_sq_depth = (4 * cfg.conns).max(8192);
    d.migration.enabled = !cfg.rc_only;
    d.migration.rc_share = 0.02; // budget: 8 of 400 ICM entries
    d.lease_timeout_ns = 5_000_000;
    d
}

/// Server daemon config for the chaos runs: reassembly fragment timeout
/// and lease reclaim on, small footprint.
fn chaos_server_cfg() -> DaemonConfig {
    let mut d = DaemonConfig::default();
    d.pool_layout = vec![(4096, 1024), (16 << 10, 256)];
    d.recv_slot_bytes = 4096;
    d.srq_capacity = 512;
    d.srq_watermark = 64;
    d.ud_sq_depth = 64;
    d.service_threads = 1;
    d.lease_timeout_ns = 5_000_000;
    d.reassembly_timeout_ns = 2_000_000;
    d
}

/// Fig 10: closed-loop `send()` fan-out under a seeded fault plan —
/// goodput and tail latency vs injected loss rate, adaptive RC↔UD
/// migration vs the `--rc-only` ablation. At loss 0 the plan is null and
/// this is byte-identical to the lossless simulator (no timers, no RNG,
/// no gating). Under loss, RC traffic retransmits (and exhausts its
/// retry budget inside flap windows — `retry_exceeded`), while
/// UD-migrated traffic loses fragments silently and the peer's
/// reassembler discards the partials (`ud_dropped`/`ud_orphans`).
pub fn chaos_send(cfg: &ChaosCfg) -> ChaosRun {
    let servers = cfg.conns.min(cfg.max_servers).max(1);
    let mut fabric = FabricConfig::default();
    fabric.nodes = servers + 1;
    fabric.sq_depth = 1024;
    fabric.shards = cfg.shards;
    let mut sim = Sim::new(fabric);
    // before any traffic: the go-back-N discipline and the fault gate
    // must switch on together
    sim.install_faults(chaos_fault_cfg(cfg, servers));

    let mut daemons: Vec<Daemon> = Vec::with_capacity(servers + 1);
    daemons.push(Daemon::start(&mut sim, NodeId(0), chaos_client_cfg(cfg)));
    for s in 0..servers {
        daemons.push(Daemon::start(&mut sim, NodeId(s as u32 + 1), chaos_server_cfg()));
    }
    let mut server_apps = vec![0u32; servers + 1];
    for (s, d) in daemons.iter_mut().enumerate().skip(1) {
        let app = d.register_app();
        d.listen(app, 7000);
        server_apps[s] = app;
    }
    let app = daemons[0].register_app();
    let mut conns = Vec::with_capacity(cfg.conns);
    for i in 0..cfg.conns {
        let server = 1 + i % servers;
        conns.push(connect_via(&mut sim, &mut daemons, 0, app, server, 7000).unwrap());
    }

    let mut rng = Rng::new(cfg.seed);
    let sizes = SizeGen::LogUniform { lo: cfg.msg_lo, hi: cfg.msg_hi };
    let mut win = Window::new(&ScenarioCfg {
        duration: cfg.duration,
        warmup_frac: cfg.warmup_frac,
        ..ScenarioCfg::default()
    });

    // goodput numerator: fully delivered messages at the server daemons
    let mut delivered_bytes = 0u64;
    let mut delivered_msgs = 0u64;
    let (mut win_bytes0, mut win_msgs0) = (0u64, 0u64);
    let mut win_snapped = false;
    let mut posted_at: std::collections::HashMap<u32, Ns> = std::collections::HashMap::new();

    daemons[0].pump(&mut sim);
    for (i, c) in conns.iter().enumerate() {
        let len = sizes.next(&mut rng).clamp(cfg.msg_lo, cfg.msg_hi);
        posted_at.insert(c.0, sim.now());
        let _ = daemons[0].send(&mut sim, *c, len, Flags::default(), i as u64, HostLoad::default());
    }
    daemons[0].pump(&mut sim);
    sim.node_mut(NodeId(0)).cache.reset_stats();

    // periodic heartbeat so server daemons pump even when no CQE lands —
    // a restarted server's SRQ is empty, so WITHOUT this its refill (and
    // therefore its recovery) would wait on a completion that can never
    // arrive. The live daemon busy-polls; this is the sim equivalent.
    const HEARTBEAT: u64 = u64::MAX;
    const HEARTBEAT_NS: u64 = 100_000;
    sim.schedule(Ns(HEARTBEAT_NS), HEARTBEAT);

    let mut server_nodes: Vec<u32> = Vec::new();
    let mut notes: Vec<Notification> = Vec::new();
    while sim.now() < cfg.duration {
        win.maybe_start(&sim);
        if win.started && !win_snapped {
            win_snapped = true;
            win_bytes0 = delivered_bytes;
            win_msgs0 = delivered_msgs;
        }
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        let mut client_cqe = false;
        let mut heartbeat = false;
        server_nodes.clear();
        for n in &notes {
            match n {
                Notification::CqeReady { node, .. } => {
                    if node.0 == 0 {
                        client_cqe = true;
                    } else {
                        server_nodes.push(node.0);
                    }
                }
                Notification::Timer { token } if *token == HEARTBEAT => heartbeat = true,
                _ => {}
            }
        }
        if heartbeat {
            for s in 1..=servers {
                server_nodes.push(s as u32);
            }
            sim.schedule(sim.now() + Ns(HEARTBEAT_NS), HEARTBEAT);
        }
        server_nodes.sort_unstable();
        server_nodes.dedup();
        for &s in &server_nodes {
            let d = &mut daemons[s as usize];
            d.pump(&mut sim);
            while let Some(del) = d.recv_zero_copy(&mut sim, server_apps[s as usize]) {
                if let Delivery::Message { len, .. } = del {
                    delivered_bytes += len;
                    delivered_msgs += 1;
                }
            }
        }
        if client_cqe || heartbeat {
            daemons[0].pump(&mut sim);
            while let Some(del) = daemons[0].recv_zero_copy(&mut sim, app) {
                if let Delivery::OpComplete { conn, ok, .. } = del {
                    if ok {
                        if let Some(t) = posted_at.get(&conn.0) {
                            win.record_latency(sim.now().saturating_sub(*t).0);
                        }
                    }
                    // closed loop continues through failures
                    let len = sizes.next(&mut rng).clamp(cfg.msg_lo, cfg.msg_hi);
                    posted_at.insert(conn.0, sim.now());
                    let _ = daemons[0].send(
                        &mut sim,
                        conn,
                        len,
                        Flags::default(),
                        0,
                        HostLoad::default(),
                    );
                }
            }
            daemons[0].pump(&mut sim);
        }
    }

    let span = sim.now().saturating_sub(win.t0);
    let ops = delivered_msgs - win_msgs0;
    let fstats = sim.fault_stats().unwrap_or_default();
    let (mut ud_dropped, mut ud_orphans, mut ud_expired) = (0u64, 0u64, 0u64);
    for d in daemons.iter().skip(1) {
        ud_dropped += d.reassembly.dropped;
        ud_orphans += d.reassembly.orphan_fragments;
        ud_expired += d.reassembly.expired;
    }
    ChaosRun {
        conns: cfg.conns,
        servers,
        loss: cfg.loss,
        gbps: gbps(delivered_bytes - win_bytes0, span),
        mops: if span.0 == 0 { 0.0 } else { ops as f64 * 1e3 / span.0 as f64 },
        ops,
        p50_us: win.lat.p50() as f64 / 1e3,
        p99_us: win.lat.p99() as f64 / 1e3,
        ud_fraction: daemons[0].ud_send_fraction(),
        failed_ops: daemons[0].stats.ops_failed,
        retransmits: sim.nodes().map(|n| n.retransmits).sum(),
        retry_exceeded: sim.nodes().map(|n| n.retry_exceeded).sum(),
        gbn_discards: sim.nodes().map(|n| n.gbn_discards).sum(),
        frames_dropped: fstats.frames_dropped,
        frames_delayed: fstats.frames_delayed,
        ud_dropped,
        ud_orphans,
        ud_expired,
        leases_reclaimed: daemons.iter().map(|d| d.stats.leases_reclaimed).sum(),
        restarts: sim.nodes().map(|n| n.restarts).sum(),
        migrations_to_ud: daemons[0].migrate.to_ud,
        events: sim.steps_processed(),
    }
}

// -------------------------------------------------- Fig 11 (KV storm)

/// Config for the KV-tier experiment (fig 11): thousands of closed-loop
/// clients run Zipf-popular GET/PUT rounds against fixed-slot value
/// tables sharded over the server daemons. The ablation axis is the
/// access mode — one-sided registered-window READ/WRITE (the Storm
/// repeat-get pattern + RDMAbox doorbell coalescing) vs SEND-RPC.
#[derive(Clone, Debug)]
pub struct KvCfg {
    /// Closed-loop clients on the client machine.
    pub clients: usize,
    /// Cap on distinct server daemons the table is sharded across.
    pub max_servers: usize,
    /// Virtual run length.
    pub duration: Ns,
    /// Fraction of the run treated as warmup (excluded from stats).
    pub warmup_frac: f64,
    /// Workload RNG seed (runs replay bit-identically).
    pub seed: u64,
    /// Zipf skew of the key-popularity distribution.
    pub theta: f64,
    /// Percent of rounds that are GETs (95 read-mostly, 50 write-heavy).
    pub read_pct: u32,
    /// Value-table slots per server shard.
    pub slots: u64,
    /// Bytes per table slot — the largest value class and the window's
    /// max-op bound.
    pub slot_bytes: u64,
    /// WRITEs per PUT round (the doorbell-coalescing group size).
    pub put_burst: u32,
    /// Ablation: SEND-RPC GET/PUT instead of the one-sided window path.
    pub rpc: bool,
    /// Simulator shard count (1 = serial; forwarded to
    /// [`FabricConfig`]`::shards`, byte-identical output for any value).
    pub shards: usize,
}

impl Default for KvCfg {
    fn default() -> Self {
        KvCfg {
            clients: 1024,
            max_servers: 64,
            duration: Ns::from_ms(4),
            warmup_frac: 0.25,
            seed: 42,
            theta: 0.99,
            read_pct: 95,
            slots: 512,
            slot_bytes: 128 << 10,
            put_burst: 4,
            rpc: false,
            shards: 1,
        }
    }
}

/// One measured KV-storm point.
#[derive(Clone, Copy, Debug, Default)]
pub struct KvRun {
    /// Closed-loop clients of this point.
    pub clients: usize,
    /// Server shards the table spans.
    pub servers: usize,
    /// App-level rounds (GET, or whole PUT burst) completed inside the
    /// measured window — the ops fig 11 plots.
    pub ops: u64,
    /// GET rounds issued over the full run.
    pub gets: u64,
    /// PUT values issued over the full run.
    pub puts: u64,
    /// App-level rounds, millions per second.
    pub mops: f64,
    /// Wire-delivered payload throughput, Gb/s.
    pub gbps: f64,
    /// Median round latency, microseconds.
    pub p50_us: f64,
    /// 99th-percentile round latency, microseconds.
    pub p99_us: f64,
    /// Cores-equivalent burned by the server daemons — near zero in
    /// one-sided mode (the paper's passive-server story).
    pub server_cpu_cores: f64,
    /// RPC GETs the servers answered (0 in one-sided mode).
    pub server_gets_served: u64,
    /// PUT values the servers applied (0 in one-sided mode).
    pub server_puts_applied: u64,
    /// Doorbell groups flushed by the client daemon.
    pub window_flushes: u64,
    /// WRITEs that rode an earlier WR's doorbell (saved CQEs).
    pub writes_coalesced: u64,
    /// Client ops that completed in failure.
    pub ops_failed: u64,
    /// Simulator events processed over the whole run.
    pub events: u64,
}

/// Client daemon config for the KV storm: staging classes for every
/// value size in play (4 KB covers the small classes), a recv ring able
/// to land value-sized RPC replies, and migration off — the ablation
/// must compare one-sided vs RPC on identical RC plumbing.
fn kv_client_cfg(cfg: &KvCfg) -> DaemonConfig {
    let mut d = DaemonConfig::default();
    let n = cfg.clients as u32;
    let mut pool = vec![(4096u64, (4 * n).max(1024))];
    if cfg.slot_bytes > 16 << 10 {
        pool.push((16 << 10, (4 * n).max(512)));
    }
    if cfg.slot_bytes > 4096 {
        pool.push((cfg.slot_bytes, (4 * n).max(512)));
    }
    d.pool_layout = pool;
    d.recv_slot_bytes = cfg.slot_bytes.max(16 << 10);
    d.srq_capacity = (2 * cfg.clients).max(1024);
    d.srq_watermark = (2 * cfg.clients).max(1024) / 4;
    d.migration.enabled = false;
    d
}

/// Server daemon config: the pool must cover the whole table span (the
/// clients' window registrations bound-check against it) plus RPC reply
/// staging headroom.
fn kv_server_cfg(cfg: &KvCfg) -> DaemonConfig {
    let mut d = DaemonConfig::default();
    let mut pool = vec![(4096u64, 512u32)];
    if cfg.slot_bytes > 16 << 10 {
        pool.push((16 << 10, 256));
    }
    if cfg.slot_bytes > 4096 {
        pool.push((cfg.slot_bytes, cfg.slots as u32 + 128));
    } else {
        pool[0].1 += cfg.slots as u32 + 128;
    }
    d.pool_layout = pool;
    d.recv_slot_bytes = cfg.slot_bytes.max(4096);
    d.srq_capacity = 512;
    d.srq_watermark = 64;
    d.service_threads = 1;
    d.migration.enabled = false;
    d
}

/// Fig 11: the Zipfian KV storm. Every client keeps one logical round in
/// flight (closed loop): a GET with probability `read_pct`, else a PUT
/// burst. One-sided mode registers one remote window per client up front
/// — repeat GETs are single READ RTTs and PUT bursts coalesce into one
/// doorbell group, with the server daemons fully passive; the `rpc`
/// ablation pushes the same workload through SEND request/reply and pays
/// two legs plus server CPU per GET.
pub fn kv_storm(cfg: &KvCfg) -> KvRun {
    let servers = cfg.clients.min(cfg.max_servers).max(1);
    let mut fabric = FabricConfig::default();
    fabric.nodes = servers + 1;
    fabric.sq_depth = 1024;
    fabric.shards = cfg.shards;
    let mut sim = Sim::new(fabric);

    let mode = if cfg.rpc { KvMode::Rpc } else { KvMode::OneSided };
    let layout = KvLayout { slots: cfg.slots, slot_bytes: cfg.slot_bytes };

    let mut daemons: Vec<Daemon> = Vec::with_capacity(servers + 1);
    daemons.push(Daemon::start(&mut sim, NodeId(0), kv_client_cfg(cfg)));
    for s in 0..servers {
        daemons.push(Daemon::start(&mut sim, NodeId(s as u32 + 1), kv_server_cfg(cfg)));
    }
    let mut kv_servers: Vec<KvServer> = Vec::with_capacity(servers);
    for s in 0..servers {
        let seed = cfg.seed ^ (s as u64 + 1);
        kv_servers.push(KvServer::new(&mut daemons[s + 1], 6000, layout, mode, seed));
    }
    let capp = daemons[0].register_app();
    let mut clients: Vec<KvClient> = Vec::with_capacity(cfg.clients);
    // conn vqpn → client index (vqpns are dense per daemon)
    let mut client_of: Vec<usize> = Vec::new();
    for i in 0..cfg.clients {
        let server = 1 + i % servers;
        let conn = connect_via(&mut sim, &mut daemons, 0, capp, server, 6000).unwrap();
        if conn.0 as usize >= client_of.len() {
            client_of.resize(conn.0 as usize + 1, usize::MAX);
        }
        client_of[conn.0 as usize] = i;
        let seed = cfg.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut c =
            KvClient::new(capp, conn, layout, seed, cfg.theta, mode, cfg.read_pct, cfg.put_burst);
        c.register(&mut sim, &mut daemons[0]).unwrap();
        clients.push(c);
    }

    let mut win = Window::new(&ScenarioCfg {
        duration: cfg.duration,
        warmup_frac: cfg.warmup_frac,
        ..ScenarioCfg::default()
    });
    let mut issued_at: Vec<Ns> = vec![Ns::ZERO; cfg.clients];
    // clients whose last issue hit transient pool backpressure — retried
    // on the next client pump turn so the closed loop never strands one
    let mut stalled: Vec<usize> = Vec::new();
    let mut rounds = 0u64;
    let (mut rounds0, mut win_snapped) = (0u64, false);

    // first pump flushes registration-era work before the opening burst
    daemons[0].pump(&mut sim);
    for (i, c) in clients.iter_mut().enumerate() {
        issued_at[i] = sim.now();
        if c.issue(&mut sim, &mut daemons[0]).is_err() {
            stalled.push(i);
        }
    }
    daemons[0].pump(&mut sim);
    sim.node_mut(NodeId(0)).cache.reset_stats();

    let mut server_nodes: Vec<u32> = Vec::new();
    let mut notes: Vec<Notification> = Vec::new();
    while sim.now() < cfg.duration {
        win.maybe_start(&sim);
        if win.started && !win_snapped {
            win_snapped = true;
            rounds0 = rounds;
        }
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        let mut client_cqe = false;
        server_nodes.clear();
        for n in &notes {
            if let Notification::CqeReady { node, .. } = n {
                if node.0 == 0 {
                    client_cqe = true;
                } else {
                    server_nodes.push(node.0);
                }
            }
        }
        server_nodes.sort_unstable();
        server_nodes.dedup();
        for &s in &server_nodes {
            let d = &mut daemons[s as usize];
            d.pump(&mut sim);
            kv_servers[s as usize - 1].service(&mut sim, d);
            // a service turn enqueues reply WRs; flush them now instead of
            // waiting for this server's next CQE — at low load the reply
            // IS the next traffic, so that CQE would never come
            d.pump(&mut sim);
        }
        if client_cqe {
            daemons[0].pump(&mut sim);
            if !stalled.is_empty() {
                let retry = std::mem::take(&mut stalled);
                for i in retry {
                    issued_at[i] = sim.now();
                    if clients[i].issue(&mut sim, &mut daemons[0]).is_err() {
                        stalled.push(i);
                    }
                }
            }
            while let Some(del) = daemons[0].recv_zero_copy(&mut sim, capp) {
                let conn = match &del {
                    Delivery::OpComplete { conn, .. } | Delivery::Message { conn, .. } => *conn,
                };
                let Some(&i) = client_of.get(conn.0 as usize) else { continue };
                if i == usize::MAX {
                    continue;
                }
                if clients[i].on_delivery(&del) {
                    win.record_latency(sim.now().saturating_sub(issued_at[i]).0);
                    rounds += 1;
                    issued_at[i] = sim.now();
                    if clients[i].issue(&mut sim, &mut daemons[0]).is_err() {
                        stalled.push(i);
                    }
                }
            }
            daemons[0].pump(&mut sim);
        }
    }

    let (gbps_v, _, _, p50, p99) = win.finish(&sim);
    let span = sim.now().saturating_sub(win.t0);
    let ops = rounds - rounds0;
    let mut server_cpu = 0.0;
    for s in 1..=servers {
        server_cpu += daemons[s].snapshot(&sim).cpu_cores;
    }
    KvRun {
        clients: cfg.clients,
        servers,
        ops,
        gets: clients.iter().map(|c| c.gets_issued).sum(),
        puts: clients.iter().map(|c| c.puts_issued).sum(),
        mops: if span.0 == 0 { 0.0 } else { ops as f64 * 1e3 / span.0 as f64 },
        gbps: gbps_v,
        p50_us: p50,
        p99_us: p99,
        server_cpu_cores: server_cpu,
        server_gets_served: kv_servers.iter().map(|s| s.gets_served).sum(),
        server_puts_applied: kv_servers.iter().map(|s| s.puts_applied).sum(),
        window_flushes: daemons[0].stats.window_flushes,
        writes_coalesced: daemons[0].stats.writes_coalesced,
        ops_failed: daemons[0].stats.ops_failed,
        events: sim.steps_processed(),
    }
}

// ------------------------------------------------ Fig 12 (churn storm)

/// Config for the tenant-churn experiment (fig 12): a seeded open-loop
/// arrival process registers `conns` tenants across `hosts` client
/// daemons. Most tenants go idle immediately (the multi-tenant reality
/// the elastic control plane is built for); a working set issues a first
/// READ, and a churning minority departs after a short lifetime and is
/// replaced — the regime where QP reuse pools and lazy batched leases
/// pay off. The clock of the arrival process is the *arrival index*, not
/// fabric time: a million-tenant ramp cannot fit in a ms-scale fabric
/// run, and what fig 12 measures is control-plane cost per connect
/// (`DaemonStats::ctrl_ns`), which is charged CPU, not timeline events.
#[derive(Clone, Debug)]
pub struct ChurnCfg {
    /// Total tenant arrivals — the fig-12 x axis, swept toward 10^6.
    pub conns: usize,
    /// Client daemons the arrivals round-robin across.
    pub hosts: usize,
    /// Destination daemons. Churners get the upper half of the server
    /// range and the idle mass the lower half, so a churn destination's
    /// connection count actually reaches zero (tenant locality); without
    /// the split the idle mass would pin every shared QP forever and the
    /// pool would never be exercised.
    pub max_servers: usize,
    /// Fraction of tenants that depart mid-run.
    pub churn_frac: f64,
    /// Churner lifetime in arrival counts (uniform on [1, 2·mean_life]).
    pub mean_life: usize,
    /// Fraction of tenants that issue a first READ on arrival.
    pub active_frac: f64,
    /// First-op payload.
    pub msg_bytes: u64,
    /// Workload RNG seed (runs replay bit-identically).
    pub seed: u64,
    /// Ablation: no QP pool (every reconnect is a full handshake) and
    /// eager lease establishment at connect.
    pub cold: bool,
    /// Simulator shard count (1 = serial; forwarded to
    /// [`FabricConfig`]`::shards`, byte-identical output for any value).
    pub shards: usize,
}

impl Default for ChurnCfg {
    fn default() -> Self {
        ChurnCfg {
            conns: 5_000,
            hosts: 2,
            max_servers: 16,
            churn_frac: 0.25,
            mean_life: 64,
            active_frac: 0.05,
            msg_bytes: 4096,
            seed: 42,
            cold: false,
            shards: 1,
        }
    }
}

/// One measured churn point.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChurnRun {
    /// Tenant arrivals of this point.
    pub conns: usize,
    /// Client daemons.
    pub hosts: usize,
    /// Destination daemons.
    pub servers: usize,
    /// Connection setup rate, thousands of conns/sec: arrivals divided
    /// by the busiest host's setup control time (hosts run in parallel).
    pub setup_kcps: f64,
    /// Median time-to-first-byte for the working set, microseconds:
    /// connect control cost + lazy-establishment cost + fabric RTT of
    /// the first READ.
    pub p50_ttfb_us: f64,
    /// 99th-percentile time-to-first-byte, microseconds.
    pub p99_ttfb_us: f64,
    /// Host bytes per registered vQPN at end of run — the idle-tenant
    /// footprint (client daemon memory over live connections).
    pub mem_per_vqpn: f64,
    /// Connection-table bytes per registered vQPN — the marginal cost
    /// of one more idle tenant under lazy leases.
    pub table_bytes_per_vqpn: f64,
    /// Live registered vQPNs at end of run (the idle mass).
    pub live_vqpns: u64,
    /// Full RC handshakes the client hosts performed.
    pub handshakes_full: u64,
    /// Reconnects served from the QP reuse pool (no handshake).
    pub qp_reused: u64,
    /// Shared QPs parked into the pools.
    pub qp_parked: u64,
    /// Pooled QPs destroyed (LRU bound, unrevivable halves, cold mode).
    pub qp_evicted: u64,
    /// QPs parked in the pools at end of run.
    pub pooled_qps: u64,
    /// Lease-establishment control messages (each covers a batch).
    pub lease_batches: u64,
    /// Remotes whose pool credentials were established.
    pub leases_established: u64,
    /// Remotes still deferred (never sent) at end of run.
    pub deferred_leases: u64,
    /// CQEs dropped by the epoch gate (stale tenant generation).
    pub stale_epoch_drops: u64,
    /// Tenant departures processed.
    pub disconnects: u64,
    /// First-READ completions delivered.
    pub ops_completed: u64,
    /// Ops failed (first READ torn down by its tenant's departure).
    pub ops_failed: u64,
    /// Busiest host's total control-plane time, milliseconds.
    pub ctrl_ms: f64,
    /// Simulator events processed over the whole run.
    pub events: u64,
}

/// Daemon config for the churn runs, both sides: migration off (the
/// figure isolates the control plane), pool/lazy knobs per the ablation.
/// Both endpoints must agree on pooling — a parked half is only
/// revivable if the peer parked its half too.
fn churn_daemon_cfg(cfg: &ChurnCfg) -> DaemonConfig {
    let mut d = DaemonConfig::default();
    d.migration.enabled = false;
    d.lazy_leases = !cfg.cold;
    d.qp_pool_max = if cfg.cold { 0 } else { 8 };
    d
}

/// Drain the fabric: pump every daemon, deliver client completions
/// (recording TTFB for first-READ tenants), step until the timeline is
/// empty. Bounded so a logic bug can never hang the figure harness.
fn churn_drain(
    sim: &mut Sim,
    daemons: &mut [Daemon],
    hosts: usize,
    apps: &[u32],
    pending: &mut [Vec<Option<(Ns, u64)>>],
    ttfb: &mut Histogram,
) {
    for _ in 0..100_000 {
        for d in daemons.iter_mut() {
            d.pump(sim);
        }
        for h in 0..hosts {
            while let Some(del) = daemons[h].recv_zero_copy(sim, apps[h]) {
                if let Delivery::OpComplete { conn, ok, .. } = del {
                    if let Some(slot) = pending[h].get_mut(conn.0 as usize) {
                        if let Some((t0, ctrl)) = slot.take() {
                            if ok {
                                ttfb.record(ctrl + sim.now().saturating_sub(t0).0);
                            }
                        }
                    }
                }
            }
        }
        if sim.step().is_none() {
            for d in daemons.iter_mut() {
                d.pump(sim);
            }
            if sim.pending_events() == 0 {
                break;
            }
        }
    }
}

/// Fig 12: the tenant churn storm. Warm mode (default) parks drained
/// shared QPs for reuse and defers lease establishment to first use;
/// `cold` replays the same seeded arrival tape with the pool disabled
/// and eager leases — every churner reconnect pays the full RC
/// handshake and every idle tenant pays lease state it never uses.
pub fn churn_storm(cfg: &ChurnCfg) -> ChurnRun {
    let hosts = cfg.hosts.max(1);
    let servers = cfg.max_servers.max(2);
    let mut fabric = FabricConfig::default();
    fabric.nodes = hosts + servers;
    fabric.sq_depth = 1024;
    fabric.shards = cfg.shards;
    let mut sim = Sim::new(fabric);

    let mut daemons: Vec<Daemon> = (0..hosts + servers)
        .map(|i| Daemon::start(&mut sim, NodeId(i as u32), churn_daemon_cfg(cfg)))
        .collect();
    for d in daemons.iter_mut().skip(hosts) {
        let app = d.register_app();
        d.listen(app, 7000);
    }
    let apps: Vec<u32> = (0..hosts).map(|h| daemons[h].register_app()).collect();

    let mut rng = Rng::new(cfg.seed);
    let mut offgen = OffsetGen::uniform(64 << 20, 4096);
    let mut ttfb = Histogram::new();
    // per-host: vqpn → (first-READ submit time, control ns already paid)
    let mut pending: Vec<Vec<Option<(Ns, u64)>>> = vec![Vec::new(); hosts];
    // departures bucketed by the arrival index they fire at
    let life_span = 2 * cfg.mean_life.max(1) + 2;
    let mut departs: Vec<Vec<(usize, crate::raas::vqpn::Vqpn)>> =
        vec![Vec::new(); cfg.conns + life_span];
    let mut setup_ns = vec![0u64; hosts];
    let churn_servers = (servers / 2).max(1);

    for k in 0..cfg.conns {
        let h = k % hosts;
        let churner = rng.chance(cfg.churn_frac);
        // the k==0 pacer guarantees fabric traffic even at tiny scales
        let active = rng.chance(cfg.active_frac) || k == 0;
        // tenant locality: churners live on the upper server half
        let s = if churner {
            hosts + servers - churn_servers + rng.gen_range(churn_servers as u64) as usize
        } else {
            hosts + rng.gen_range((servers - churn_servers) as u64) as usize
        };
        let ctrl0 = daemons[h].stats.ctrl_ns + daemons[s].stats.ctrl_ns;
        let conn = connect_via(&mut sim, &mut daemons, h, apps[h], s, 7000).unwrap();
        let setup = daemons[h].stats.ctrl_ns + daemons[s].stats.ctrl_ns - ctrl0;
        setup_ns[h] += setup;
        if churner {
            let life = 1 + rng.gen_range(2 * cfg.mean_life.max(1) as u64) as usize;
            departs[k + life].push((h, conn));
        }
        if active {
            let off = offgen.next(&mut rng, cfg.msg_bytes);
            let c0 = daemons[h].stats.ctrl_ns;
            if daemons[h].read(&mut sim, conn, cfg.msg_bytes, off, k as u64).is_ok() {
                let first_use = daemons[h].stats.ctrl_ns - c0;
                if conn.0 as usize >= pending[h].len() {
                    pending[h].resize(conn.0 as usize + 1, None);
                }
                pending[h][conn.0 as usize] = Some((sim.now(), setup + first_use));
            }
        }
        for (dh, dconn) in std::mem::take(&mut departs[k]) {
            if let Some(slot) = pending[dh].get_mut(dconn.0 as usize) {
                *slot = None; // the vQPN may be recycled; never misattribute
            }
            let _ = disconnect_via(&mut sim, &mut daemons, dh, dconn);
        }
        if k % 64 == 63 {
            churn_drain(&mut sim, &mut daemons, hosts, &apps, &mut pending, &mut ttfb);
        }
    }
    // late departures scheduled past the last arrival
    for k in cfg.conns..cfg.conns + life_span {
        for (dh, dconn) in std::mem::take(&mut departs[k]) {
            if let Some(slot) = pending[dh].get_mut(dconn.0 as usize) {
                *slot = None;
            }
            let _ = disconnect_via(&mut sim, &mut daemons, dh, dconn);
        }
    }
    churn_drain(&mut sim, &mut daemons, hosts, &apps, &mut pending, &mut ttfb);

    let mut live = 0u64;
    let mut mem = 0u64;
    let mut table = 0u64;
    for h in 0..hosts {
        let snap = daemons[h].snapshot(&sim);
        live += snap.conns as u64;
        mem += snap.mem_bytes;
        table += snap.conn_table_bytes;
    }
    let worst_setup = setup_ns.iter().copied().max().unwrap_or(0);
    let sum = |f: &dyn Fn(&Daemon) -> u64| daemons[..hosts].iter().map(|d| f(d)).sum::<u64>();
    ChurnRun {
        conns: cfg.conns,
        hosts,
        servers,
        setup_kcps: if worst_setup == 0 {
            0.0
        } else {
            cfg.conns as f64 / (worst_setup as f64 / 1e9) / 1e3
        },
        p50_ttfb_us: ttfb.p50() as f64 / 1e3,
        p99_ttfb_us: ttfb.p99() as f64 / 1e3,
        mem_per_vqpn: if live == 0 { 0.0 } else { mem as f64 / live as f64 },
        table_bytes_per_vqpn: if live == 0 { 0.0 } else { table as f64 / live as f64 },
        live_vqpns: live,
        handshakes_full: sum(&|d| d.stats.handshakes_full),
        qp_reused: sum(&|d| d.stats.qp_reused),
        qp_parked: sum(&|d| d.stats.qp_parked),
        qp_evicted: sum(&|d| d.stats.qp_evicted),
        pooled_qps: sum(&|d| d.pooled_qp_count() as u64),
        lease_batches: sum(&|d| d.stats.lease_batches),
        leases_established: sum(&|d| d.stats.leases_established),
        deferred_leases: sum(&|d| d.deferred_lease_count() as u64),
        stale_epoch_drops: daemons.iter().map(|d| d.stats.stale_epoch_drops).sum(),
        disconnects: sum(&|d| d.stats.conns_disconnected),
        ops_completed: sum(&|d| d.stats.ops_completed),
        ops_failed: sum(&|d| d.stats.ops_failed),
        ctrl_ms: daemons[..hosts]
            .iter()
            .map(|d| d.stats.ctrl_ns)
            .max()
            .unwrap_or(0) as f64
            / 1e6,
        events: sim.steps_processed(),
    }
}

// ------------------------------------------------ Fig 13 (incast storm)

/// Config for the Clos incast experiment (fig 13): `writers` RC writers
/// spread over the non-sink ToRs blast a single sink host through an
/// oversubscribed fat-tree ([`crate::fabric::topo`]), over a background
/// of cross-ToR elephants, while mice probe the congested spine path and
/// report flow-completion time. The sweep variable is the ToR
/// oversubscription ratio; the ablation variable is the congestion-
/// control mode.
#[derive(Clone, Copy, Debug)]
pub struct IncastCfg {
    /// Fan-in writers targeting the sink (spread over ToRs 1..).
    pub writers: usize,
    /// Hosts per ToR switch (sink is host 0 of ToR 0).
    pub hosts_per_tor: usize,
    /// ToR count; total nodes = `tors * hosts_per_tor`.
    pub tors: usize,
    /// ToR uplink oversubscription ratio (1 = full bisection).
    pub oversub: u32,
    /// Congestion-control mode under test.
    pub mode: CcMode,
    /// Incast and elephant message size.
    pub msg_bytes: u64,
    /// Outstanding WRITEs per incast writer (closed loop).
    pub window: u32,
    /// Cross-ToR background elephant flows (window 8 each).
    pub elephants: usize,
    /// Latency-probe mice (window 1, [`IncastCfg::mice_bytes`] each),
    /// writing to a non-sink ToR-0 host through the congested spine.
    pub mice: usize,
    /// Mouse message size.
    pub mice_bytes: u64,
    /// Virtual run length.
    pub duration: Ns,
    /// Simulator shard count (byte-identical output for any value).
    pub shards: usize,
    /// Optional spine-link flap `(from_ns, until_ns)`: every incast flow
    /// whose ECMP hash picked uplink 0 loses its frames inside the
    /// window (PR-4 fault stream riding the Clos fabric).
    pub spine_flap: Option<(u64, u64)>,
}

impl Default for IncastCfg {
    fn default() -> Self {
        IncastCfg {
            writers: 12,
            hosts_per_tor: 8,
            tors: 3,
            oversub: 4,
            mode: CcMode::Dcqcn,
            msg_bytes: 64 << 10,
            window: 16,
            elephants: 4,
            mice: 4,
            mice_bytes: 2 << 10,
            duration: Ns::from_ms(4),
            shards: 1,
            spine_flap: None,
        }
    }
}

/// One measured incast point.
#[derive(Clone, Copy, Debug, Default)]
pub struct IncastRun {
    /// Incast goodput at the sink, Gb/s: ACK-completed writer bytes in
    /// the measured window (unique per message — retransmitted duplicates
    /// never count).
    pub goodput_gbps: f64,
    /// Incast messages completed inside the measured window.
    pub ops: u64,
    /// Median mouse flow-completion time, microseconds.
    pub p50_fct_us: f64,
    /// 99th-percentile mouse flow-completion time, microseconds.
    pub p99_fct_us: f64,
    /// Data frames ECN-marked by congested Clos ports.
    pub ecn_marks: u64,
    /// Frames tail-dropped at full Clos ports (0 in PFC mode).
    pub switch_drops: u64,
    /// Frames pause-gated by PFC backpressure (Pfc mode only).
    pub pauses: u64,
    /// RC messages retransmitted after ACK timeout, all nodes.
    pub retransmits: u64,
    /// RC messages that exhausted their retry budget, all nodes.
    pub retry_exceeded: u64,
    /// Frames dropped by the fault layer (spine-flap windows).
    pub wire_drops: u64,
    /// Simulator events processed over the whole run.
    pub events: u64,
}

/// Fig 13: N-to-1 incast through an oversubscribed Clos fabric. Closed
/// loop at three tiers — incast writers into one sink, cross-ToR
/// elephants saturating the spine, single-message mice measuring FCT —
/// all raw RC WRITEs (no daemon layer), so the figure isolates the
/// fabric and its congestion control. Deterministic for every shard
/// count (`tests/determinism.rs` gates fig 13's byte-identity).
pub fn incast_storm(cfg: &IncastCfg) -> IncastRun {
    use crate::fabric::fault::{FaultConfig, Flap};
    use crate::fabric::mr::Access;
    use crate::fabric::topo::{ecmp_hash, pick_uplink, TopoConfig};
    use crate::fabric::types::{QpTransport, Qpn};
    use crate::fabric::verbs as fv;
    use crate::fabric::wqe::SendWr;

    assert!(cfg.tors >= 2 && cfg.hosts_per_tor >= 2, "need a sink ToR and a source ToR");
    let nodes = cfg.tors * cfg.hosts_per_tor;
    let hosts = cfg.hosts_per_tor;
    let src_pool = (cfg.tors - 1) * hosts; // nodes on ToRs 1..

    let mut topo = TopoConfig::default();
    topo.hosts_per_tor = hosts;
    topo.oversub = cfg.oversub;
    topo.mode = cfg.mode;

    let mut fabric = FabricConfig::default();
    fabric.nodes = nodes;
    fabric.shards = cfg.shards;
    fabric.max_outstanding = cfg.window.max(8) as usize;
    fabric.sq_depth = 4 * cfg.window as usize + 32;
    // deep queues (and PFC pause chains) delay ACKs far beyond the
    // lossless ETA; a tight timer would retransmit spuriously and a
    // 7-retry budget would die under sustained incast drops
    fabric.nic.retransmit_timeout_ns = 400_000;
    fabric.nic.retry_cnt = 64;
    fabric.topo = Some(topo);
    let mut sim = Sim::new(fabric);

    // one CQ + one registered region per node; actors multiplex by wr_id
    let mut cqs = Vec::with_capacity(nodes);
    let mut mrs = Vec::with_capacity(nodes);
    for n in 0..nodes {
        cqs.push(sim.create_cq(NodeId(n as u32), 1 << 16));
        mrs.push(sim.reg_mr(NodeId(n as u32), 64 << 20, Access::REMOTE_RW, true));
    }

    // actor table: incast writers, then elephants, then mice
    struct Actor {
        src: NodeId,
        dst: NodeId,
        qpn: Qpn,
        peer_qpn: Qpn,
        len: u64,
        window: u32,
        is_writer: bool,
        is_mouse: bool,
        issued_at: Ns,
    }
    let sink = NodeId(0);
    let mut actors: Vec<Actor> = Vec::new();
    for w in 0..cfg.writers {
        let src = NodeId((hosts + w % src_pool) as u32);
        let pair = fv::create_connected_pair(
            &mut sim,
            QpTransport::Rc,
            src,
            sink,
            cqs[src.0 as usize],
            cqs[src.0 as usize],
            cqs[0],
            cqs[0],
        );
        actors.push(Actor {
            src,
            dst: sink,
            qpn: pair.a.1,
            peer_qpn: pair.b.1,
            len: cfg.msg_bytes,
            window: cfg.window,
            is_writer: true,
            is_mouse: false,
            issued_at: Ns::ZERO,
        });
    }
    for e in 0..cfg.elephants {
        // cross-ToR background load, never touching the sink's ToR when
        // there are enough ToRs; directions alternate
        let (src, dst) = if cfg.tors >= 3 {
            let a = NodeId((hosts + e % hosts) as u32);
            let b = NodeId((2 * hosts + e % hosts) as u32);
            if e % 2 == 0 { (a, b) } else { (b, a) }
        } else {
            (NodeId((hosts + e % hosts) as u32), NodeId(1 + (e % (hosts - 1)) as u32))
        };
        let pair = fv::create_connected_pair(
            &mut sim,
            QpTransport::Rc,
            src,
            dst,
            cqs[src.0 as usize],
            cqs[src.0 as usize],
            cqs[dst.0 as usize],
            cqs[dst.0 as usize],
        );
        actors.push(Actor {
            src,
            dst,
            qpn: pair.a.1,
            peer_qpn: pair.b.1,
            len: cfg.msg_bytes,
            window: 8,
            is_writer: false,
            is_mouse: false,
            issued_at: Ns::ZERO,
        });
    }
    for m in 0..cfg.mice {
        // mice land on a NON-sink ToR-0 host: they share the congested
        // spine→ToR-0 path with the incast but not the sink's NIC, so
        // their FCT isolates fabric queueing
        let src = NodeId((hosts + (m + 1) % src_pool) as u32);
        let dst = NodeId(1 + (m % (hosts - 1)) as u32);
        let pair = fv::create_connected_pair(
            &mut sim,
            QpTransport::Rc,
            src,
            dst,
            cqs[src.0 as usize],
            cqs[src.0 as usize],
            cqs[dst.0 as usize],
            cqs[dst.0 as usize],
        );
        actors.push(Actor {
            src,
            dst,
            qpn: pair.a.1,
            peer_qpn: pair.b.1,
            len: cfg.mice_bytes,
            window: 1,
            is_writer: false,
            is_mouse: true,
            issued_at: Ns::ZERO,
        });
    }

    // spine-link flap: kill the flows ECMP hashed onto uplink 0 — must be
    // installed before the first event
    if let Some((from, until)) = cfg.spine_flap {
        let live = vec![true; topo.uplinks()];
        let flaps: Vec<Flap> = actors
            .iter()
            .filter(|a| a.is_writer)
            .filter(|a| pick_uplink(ecmp_hash(a.src, a.dst, a.qpn, a.peer_qpn), 0, &live) == 0)
            .map(|a| Flap { src: a.src, dst: a.dst, from: Ns(from), until: Ns(until) })
            .collect();
        if !flaps.is_empty() {
            sim.install_faults(FaultConfig { flaps, ..FaultConfig::default() });
        }
    }

    let post = |sim: &mut Sim, a: &Actor, i: usize| {
        let off = (i as u64 * cfg.msg_bytes) % (32 << 20);
        let wr = SendWr::write(
            i as u64,
            a.len,
            mrs[a.src.0 as usize].key,
            mrs[a.src.0 as usize].addr + off,
            mrs[a.dst.0 as usize].key,
            mrs[a.dst.0 as usize].addr + off,
        );
        let _ = sim.post_send(a.src, a.qpn, wr);
    };
    for i in 0..actors.len() {
        actors[i].issued_at = sim.now();
        for _ in 0..actors[i].window {
            post(&mut sim, &actors[i], i);
        }
    }

    // measurement: skip the first quarter as warmup
    let warmup = Ns(cfg.duration.0 / 4);
    let mut t0 = Ns::ZERO;
    let mut measuring = false;
    let mut goodput_bytes = 0u64;
    let mut ops = 0u64;
    let mut fct = Histogram::new();
    let mut notes: Vec<Notification> = Vec::new();
    let mut cqes: Vec<crate::fabric::wqe::Cqe> = Vec::new();
    while sim.now() < cfg.duration {
        if !measuring && sim.now() >= warmup {
            measuring = true;
            t0 = sim.now();
        }
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        for n in notes.drain(..) {
            let Notification::CqeReady { node, cqn } = n else { continue };
            cqes.clear();
            sim.poll_cq_into(node, cqn, 1024, &mut cqes);
            for c in 0..cqes.len() {
                let i = cqes[c].wr_id as usize;
                if i >= actors.len() {
                    continue;
                }
                let ok = cqes[c].status == crate::fabric::types::WcStatus::Success;
                let now = sim.now();
                if measuring && ok && actors[i].is_writer {
                    goodput_bytes += actors[i].len;
                    ops += 1;
                }
                if measuring && ok && actors[i].is_mouse {
                    fct.record(now.saturating_sub(actors[i].issued_at).0);
                }
                // repost regardless of status: the closed loop must keep
                // pressure on even through RetryExceeded flushes
                actors[i].issued_at = now;
                post(&mut sim, &actors[i], i);
            }
        }
    }

    let span = sim.now().saturating_sub(t0);
    let clos = sim.clos_stats();
    let (mut retransmits, mut retry_exceeded) = (0u64, 0u64);
    for n in sim.nodes() {
        retransmits += n.retransmits;
        retry_exceeded += n.retry_exceeded;
    }
    IncastRun {
        goodput_gbps: gbps(goodput_bytes, span),
        ops,
        p50_fct_us: fct.p50() as f64 / 1e3,
        p99_fct_us: fct.p99() as f64 / 1e3,
        ecn_marks: clos.ecn_marks,
        switch_drops: clos.switch_drops,
        pauses: clos.pauses,
        retransmits,
        retry_exceeded,
        wire_drops: sim.wire_drops(),
        events: sim.steps_processed(),
    }
}

// --------------------------------------------- Fig 14 (failover storm)

/// Config for the survivability experiment (fig 14): cross-ToR RC
/// writers and FCT mice ride an oversubscribed Clos while a spine
/// switch dies for a window and one ToR-0 uplink dies permanently — so
/// ToR 0 is fully cut during the window. A RaaS daemon tier on ToR 0
/// exercises self-healing (its QPs exhaust the fabric retry budget and
/// must be re-established), while the raw tier exercises the per-QP
/// blackhole detector and the ECMP reconvergence epoch. Flows do NOT
/// repost after a failed completion — a survivor is a flow the
/// machinery actually saved.
#[derive(Clone, Copy, Debug)]
pub struct FailoverCfg {
    /// Cross-ToR RC writers between the non-ToR-0 ToRs (half each way).
    pub writers: usize,
    /// Hosts per ToR switch.
    pub hosts_per_tor: usize,
    /// ToR count (≥ 3: ToR 0 hosts the daemon tier, ToRs 1.. the raw).
    pub tors: usize,
    /// ToR uplink oversubscription ratio.
    pub oversub: u32,
    /// Writer message size.
    pub msg_bytes: u64,
    /// Outstanding WRITEs per writer (closed loop).
    pub window: u32,
    /// Latency-probe mice (window 1) crossing the same spine tier.
    pub mice: usize,
    /// Mouse message size.
    pub mice_bytes: u64,
    /// Daemon-tier connections from the ToR-0 client (round-robin over
    /// cross-ToR server daemons).
    pub daemon_conns: usize,
    /// Daemon-tier READ size.
    pub daemon_msg_bytes: u64,
    /// Outstanding READs per daemon connection.
    pub daemon_window: usize,
    /// Survivability on: ECMP repath epochs + blackhole detector in the
    /// fabric, self-healing in the daemon. false is the fig-14 ablation
    /// — the routing mask freezes and `RetryExceeded` surfaces to apps.
    pub repath: bool,
    /// Failure window start, ns: spine 0 dies and ToR 0's uplink 1 dies
    /// permanently.
    pub fail_from: u64,
    /// Failure window end, ns: spine 0 revives (the uplink death stays).
    pub fail_until: u64,
    /// Post-failure goodput is measured from `fail_until + settle` on.
    pub settle: u64,
    /// Virtual run length.
    pub duration: Ns,
    /// Simulator shard count (byte-identical output for any value).
    pub shards: usize,
}

impl Default for FailoverCfg {
    fn default() -> Self {
        FailoverCfg {
            writers: 8,
            hosts_per_tor: 8,
            tors: 3,
            oversub: 4,
            msg_bytes: 64 << 10,
            window: 8,
            mice: 4,
            mice_bytes: 2 << 10,
            daemon_conns: 2,
            daemon_msg_bytes: 16 << 10,
            daemon_window: 4,
            repath: true,
            fail_from: 2_000_000,
            fail_until: 4_000_000,
            settle: 1_000_000,
            duration: Ns::from_ms(8),
            shards: 1,
        }
    }
}

/// Goodput-timeline bin width for [`FailoverRun::timeline_gbps`].
pub const FAILOVER_BIN_NS: u64 = 250_000;

/// One measured failover run.
#[derive(Clone, Debug, Default)]
pub struct FailoverRun {
    /// Goodput (all tiers) before the failure window, Gb/s.
    pub pre_gbps: f64,
    /// Goodput inside the failure window, Gb/s.
    pub dip_gbps: f64,
    /// Goodput after `fail_until + settle`, Gb/s — the recovery gate
    /// compares this against `pre_gbps`.
    pub post_gbps: f64,
    /// Median mouse flow-completion time across the whole run, µs.
    pub p50_fct_us: f64,
    /// 99th-percentile mouse flow-completion time, µs.
    pub p99_fct_us: f64,
    /// Blackhole-detector firings (QPs that bumped their path salt).
    pub repaths: u64,
    /// Routing-mask reconvergences applied by the fabric control plane.
    pub route_epoch: u32,
    /// Daemon QPs re-established by self-healing.
    pub qp_reestablished: u64,
    /// Virtual ns daemon ops spent parked awaiting re-establishment.
    pub heal_backoff_ns: u64,
    /// Heal cycles that exhausted their attempt budget.
    pub heal_giveups: u64,
    /// RC messages retransmitted after ACK timeout, all nodes.
    pub retransmits: u64,
    /// RC messages that exhausted their retry budget, all nodes.
    pub retry_exceeded: u64,
    /// Frames dropped at dead Clos ports.
    pub blackhole_drops: u64,
    /// Daemon-tier READs delivered `ok`.
    pub daemon_ops_ok: u64,
    /// Daemon-tier READs delivered failed (`ok: false`).
    pub daemon_ops_failed: u64,
    /// Raw-tier flows still alive at end of run (writers + mice).
    pub flows_alive: u64,
    /// Goodput per [`FAILOVER_BIN_NS`] bin, Gb/s — the fig-14 timeline.
    pub timeline_gbps: Vec<f64>,
    /// Simulator events processed over the whole run.
    pub events: u64,
}

/// Fig 14: the failover storm. See [`FailoverCfg`] for the layout; the
/// headline claims are (repath on) post-failure goodput recovering to
/// ≥ 90% of pre-failure with `repaths > 0` and `qp_reestablished > 0`,
/// and (repath off) `retry_exceeded > 0` with strictly lower
/// post-failure goodput. Deterministic for every shard count
/// (`tests/determinism.rs` gates fig 14's byte-identity).
pub fn failover_storm(cfg: &FailoverCfg) -> FailoverRun {
    use crate::fabric::fault::FaultConfig;
    use crate::fabric::mr::Access;
    use crate::fabric::topo::TopoConfig;
    use crate::fabric::types::{QpTransport, Qpn, WcStatus};
    use crate::fabric::verbs as fv;
    use crate::fabric::wqe::SendWr;
    use crate::raas::vqpn::Vqpn;

    assert!(cfg.tors >= 3, "need ToR 0 (daemon tier) plus two raw-tier ToRs");
    assert!(cfg.fail_from < cfg.fail_until && Ns(cfg.fail_until) < cfg.duration);
    let hosts = cfg.hosts_per_tor;
    let nodes = cfg.tors * hosts;

    let mut topo = TopoConfig::default();
    topo.hosts_per_tor = hosts;
    topo.oversub = cfg.oversub;
    topo.mode = CcMode::Dcqcn;
    topo.repath = cfg.repath;
    // reconvergence slower than the detector's three-timeout fuse
    // (~350µs here), so the per-QP salt escape is load-bearing and the
    // mask update is the backstop — but both well inside the ~1.2ms
    // retry budget, so no raw flow dies when repath is on
    topo.reroute_lag_ns = 400_000;

    let mut fabric = FabricConfig::default();
    fabric.nodes = nodes;
    fabric.shards = cfg.shards;
    fabric.max_outstanding = (cfg.window.max(8)) as usize;
    fabric.sq_depth = 4 * cfg.window as usize + 32;
    fabric.nic.retransmit_timeout_ns = 50_000;
    fabric.nic.retry_cnt = 5;
    fabric.topo = Some(topo);
    let mut sim = Sim::new(fabric);

    // the failure plan: spine 0 out for the window, ToR 0's uplink 1
    // gone for good — ToR 0 is completely cut inside the window, which
    // defeats the blackhole detector by design (there is no live port
    // to repath onto) and leaves daemon self-healing as ToR 0's only
    // recovery
    sim.install_faults(FaultConfig {
        uplink_deaths: vec![(0, 1, cfg.fail_from)],
        spine_windows: vec![(0, cfg.fail_from, cfg.fail_until)],
        ..FaultConfig::default()
    });

    // ---- raw tier: writers + mice between ToR 1 and ToR 2
    let mut cqs = Vec::with_capacity(nodes);
    let mut mrs = Vec::with_capacity(nodes);
    for n in 0..nodes {
        cqs.push(sim.create_cq(NodeId(n as u32), 1 << 16));
        mrs.push(sim.reg_mr(NodeId(n as u32), 64 << 20, Access::REMOTE_RW, true));
    }
    struct Flow {
        src: NodeId,
        dst: NodeId,
        qpn: Qpn,
        len: u64,
        window: u32,
        is_mouse: bool,
        alive: bool,
        issued_at: Ns,
    }
    let mut flows: Vec<Flow> = Vec::new();
    for w in 0..cfg.writers {
        let a = NodeId((hosts + w % hosts) as u32);
        let b = NodeId((2 * hosts + w % hosts) as u32);
        let (src, dst) = if w % 2 == 0 { (a, b) } else { (b, a) };
        let pair = fv::create_connected_pair(
            &mut sim,
            QpTransport::Rc,
            src,
            dst,
            cqs[src.0 as usize],
            cqs[src.0 as usize],
            cqs[dst.0 as usize],
            cqs[dst.0 as usize],
        );
        flows.push(Flow {
            src,
            dst,
            qpn: pair.a.1,
            len: cfg.msg_bytes,
            window: cfg.window,
            is_mouse: false,
            alive: true,
            issued_at: Ns::ZERO,
        });
    }
    for m in 0..cfg.mice {
        let src = NodeId((hosts + m % hosts) as u32);
        let dst = NodeId((2 * hosts + (m + 3) % hosts) as u32);
        let pair = fv::create_connected_pair(
            &mut sim,
            QpTransport::Rc,
            src,
            dst,
            cqs[src.0 as usize],
            cqs[src.0 as usize],
            cqs[dst.0 as usize],
            cqs[dst.0 as usize],
        );
        flows.push(Flow {
            src,
            dst,
            qpn: pair.a.1,
            len: cfg.mice_bytes,
            window: 1,
            is_mouse: true,
            alive: true,
            issued_at: Ns::ZERO,
        });
    }

    // ---- daemon tier: ToR-0 client healing across the cut
    let mut dcfg = DaemonConfig::default();
    dcfg.migration.enabled = false; // no UD fallback masking the dead RC path
    if cfg.repath {
        dcfg.heal_max_attempts = 6;
        // first revival lands just past the spine window; the doubled
        // retry covers a replay that dies inside it
        dcfg.heal_backoff_ns = 500_000;
        dcfg.heal_backoff_cap_ns = 800_000;
    }
    // daemon node set: client is ToR-0 host 0; servers sit mid-ToR on
    // the raw-tier ToRs (distinct hosts from the writer/mouse endpoints
    // is not required — QPNs keep the ECMP hashes distinct)
    let server_nodes: Vec<u32> =
        (0..cfg.daemon_conns.max(1)).map(|c| (hosts + (c % 2) * hosts + 4 + c / 2) as u32).collect();
    let mut daemons: Vec<Daemon> = Vec::new();
    daemons.push(Daemon::start(&mut sim, NodeId(0), dcfg.clone()));
    for &s in &server_nodes {
        daemons.push(Daemon::start(&mut sim, NodeId(s), dcfg.clone()));
    }
    let app0 = daemons[0].register_app();
    for (i, d) in daemons.iter_mut().enumerate().skip(1) {
        let app = d.register_app();
        d.listen(app, 7000 + i as u16);
    }
    struct DFlow {
        conn: Vqpn,
        alive: bool,
        issued: u64,
    }
    let mut dflows: Vec<DFlow> = Vec::new();
    for c in 0..cfg.daemon_conns {
        let server = 1 + c % server_nodes.len();
        let conn = connect_via(&mut sim, &mut daemons, 0, app0, server, 7000 + server as u16)
            .expect("daemon connect");
        dflows.push(DFlow { conn, alive: true, issued: 0 });
    }

    // ---- prime the closed loops
    let post_raw = |sim: &mut Sim, f: &Flow, i: usize| {
        let off = (i as u64 * f.len) % (32 << 20);
        let wr = SendWr::write(
            i as u64,
            f.len,
            mrs[f.src.0 as usize].key,
            mrs[f.src.0 as usize].addr + off,
            mrs[f.dst.0 as usize].key,
            mrs[f.dst.0 as usize].addr + off,
        );
        let _ = sim.post_send(f.src, f.qpn, wr);
    };
    for i in 0..flows.len() {
        flows[i].issued_at = sim.now();
        for _ in 0..flows[i].window {
            post_raw(&mut sim, &flows[i], i);
        }
    }
    for (c, df) in dflows.iter_mut().enumerate() {
        for k in 0..cfg.daemon_window {
            let off = ((c * cfg.daemon_window + k) as u64 * cfg.daemon_msg_bytes) % (32 << 20);
            if daemons[0].read(&mut sim, df.conn, cfg.daemon_msg_bytes, off, c as u64).is_ok() {
                df.issued += 1;
            }
        }
    }

    // ---- measurement phases + goodput timeline
    let warmup = Ns(cfg.fail_from / 2);
    let post_from = Ns(cfg.fail_until + cfg.settle);
    let nbins = (cfg.duration.0 / FAILOVER_BIN_NS + 1) as usize;
    let mut bins = vec![0u64; nbins];
    let (mut pre_bytes, mut dip_bytes, mut post_bytes) = (0u64, 0u64, 0u64);
    let mut fct = Histogram::new();
    let mut account = |now: Ns, bytes: u64, bins: &mut [u64]| {
        bins[((now.0 / FAILOVER_BIN_NS) as usize).min(nbins - 1)] += bytes;
        if now >= post_from {
            post_bytes += bytes;
        } else if now.0 >= cfg.fail_from && now.0 < cfg.fail_until {
            dip_bytes += bytes;
        } else if now >= warmup && now.0 < cfg.fail_from {
            pre_bytes += bytes;
        }
    };

    let mut notes: Vec<Notification> = Vec::new();
    let mut cqes: Vec<crate::fabric::wqe::Cqe> = Vec::new();
    let (mut d_ok, mut d_failed) = (0u64, 0u64);
    while sim.now() < cfg.duration {
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        for n in notes.drain(..) {
            let Notification::CqeReady { node, cqn } = n else { continue };
            if cqn != cqs[node.0 as usize] {
                // a daemon-owned CQ: its pump below drains it — polling
                // it here would steal the daemon's completions
                continue;
            }
            cqes.clear();
            sim.poll_cq_into(node, cqn, 1024, &mut cqes);
            for c in 0..cqes.len() {
                let i = cqes[c].wr_id as usize;
                if i >= flows.len() || !flows[i].alive {
                    continue;
                }
                let now = sim.now();
                if cqes[c].status == WcStatus::Success {
                    if !flows[i].is_mouse {
                        account(now, flows[i].len, &mut bins);
                    } else if now >= warmup {
                        fct.record(now.saturating_sub(flows[i].issued_at).0);
                    }
                    flows[i].issued_at = now;
                    post_raw(&mut sim, &flows[i], i);
                } else {
                    // no repost on failure: a dead flow stays dead, so
                    // post-failure goodput measures real survival
                    flows[i].alive = false;
                }
            }
        }
        // daemon tier: pump everyone, then run the client's closed loop
        for d in daemons.iter_mut() {
            d.pump(&mut sim);
        }
        let mut resubmit: Vec<(usize, bool)> = Vec::new();
        while let Some(del) = daemons[0].recv_zero_copy(&mut sim, app0) {
            let Delivery::OpComplete { conn, ok, .. } = del else { continue };
            if let Some(c) = dflows.iter().position(|df| df.conn == conn && df.alive) {
                resubmit.push((c, ok));
            }
        }
        let mut daemon_ok = 0u64;
        for (c, ok) in resubmit {
            if !ok {
                d_failed += 1;
                dflows[c].alive = false;
                continue;
            }
            d_ok += 1;
            daemon_ok += 1;
            let off = (dflows[c].issued * cfg.daemon_msg_bytes) % (32 << 20);
            if daemons[0]
                .read(&mut sim, dflows[c].conn, cfg.daemon_msg_bytes, off, c as u64)
                .is_ok()
            {
                dflows[c].issued += 1;
            }
        }
        if daemon_ok > 0 {
            account(sim.now(), daemon_ok * cfg.daemon_msg_bytes, &mut bins);
        }
    }

    let pre_span = Ns(cfg.fail_from).saturating_sub(warmup);
    let dip_span = Ns(cfg.fail_until - cfg.fail_from);
    let post_span = cfg.duration.saturating_sub(post_from);
    let clos = sim.clos_stats();
    let (mut retransmits, mut retry_exceeded) = (0u64, 0u64);
    for n in sim.nodes() {
        retransmits += n.retransmits;
        retry_exceeded += n.retry_exceeded;
    }
    let ds = &daemons[0].stats;
    FailoverRun {
        pre_gbps: gbps(pre_bytes, pre_span),
        dip_gbps: gbps(dip_bytes, dip_span),
        post_gbps: gbps(post_bytes, post_span),
        p50_fct_us: fct.p50() as f64 / 1e3,
        p99_fct_us: fct.p99() as f64 / 1e3,
        repaths: sim.repaths(),
        route_epoch: sim.route_epoch(),
        qp_reestablished: ds.qp_reestablished,
        heal_backoff_ns: ds.backoff_ns,
        heal_giveups: ds.heal_giveups,
        retransmits,
        retry_exceeded,
        blackhole_drops: clos.blackhole_drops,
        daemon_ops_ok: d_ok,
        daemon_ops_failed: d_failed,
        flows_alive: flows.iter().filter(|f| f.alive).count() as u64,
        timeline_gbps: bins
            .iter()
            .map(|&b| gbps(b, Ns(FAILOVER_BIN_NS)))
            .collect(),
        events: sim.steps_processed(),
    }
}

/// Scheduler microbench workload for `bench simstep`: `pairs` RC QPs on
/// one client streaming closed-loop WRITEs of `msg_bytes` at `window`
/// outstanding each, across the default 4-node fabric. No daemon layer —
/// this isolates the event loop + engine + port model + dense context
/// tables. Returns events processed (deterministic; callers time the
/// call and divide for events/sec).
pub fn event_storm(pairs: usize, window: u32, msg_bytes: u64, duration: Ns) -> u64 {
    event_storm_sharded(pairs, window, msg_bytes, duration, 1)
}

/// [`event_storm`] with an explicit simulator shard count — the workload,
/// seedless and closed-loop, is identical; only the execution strategy
/// changes, and the returned event count is byte-identical for any
/// `shards` (`tests/determinism.rs` gates this). `bench simstep --shards`
/// times this to measure conservative-parallel scaling.
pub fn event_storm_sharded(
    pairs: usize,
    window: u32,
    msg_bytes: u64,
    duration: Ns,
    shards: usize,
) -> u64 {
    use crate::fabric::mr::Access;
    use crate::fabric::verbs as fv;
    use crate::fabric::wqe::SendWr;

    let mut fabric = FabricConfig::default();
    fabric.max_outstanding = window as usize;
    fabric.sq_depth = 4 * window as usize + 16;
    fabric.shards = shards;
    let servers = fabric.nodes - 1;
    let mut sim = Sim::new(fabric);
    let cq0 = sim.create_cq(NodeId(0), 1 << 16);
    let local = sim.reg_mr(NodeId(0), 256 << 20, Access::REMOTE_RW, true);

    let mut qpns = Vec::with_capacity(pairs);
    let mut remotes = Vec::with_capacity(pairs);
    for i in 0..pairs {
        let server = NodeId(1 + (i % servers) as u32);
        let server_cq = sim.create_cq(server, 4096);
        let pair = fv::create_connected_pair(
            &mut sim,
            crate::fabric::types::QpTransport::Rc,
            NodeId(0),
            server,
            cq0,
            cq0,
            server_cq,
            server_cq,
        );
        let remote = sim.reg_mr(server, 16 << 20, Access::REMOTE_RW, true);
        qpns.push(pair.a.1);
        remotes.push(remote);
    }
    let post = |sim: &mut Sim, qpns: &[crate::fabric::types::Qpn], i: usize| {
        let wr = SendWr::write(
            i as u64,
            msg_bytes,
            local.key,
            local.addr + (i as u64 * msg_bytes) % (128 << 20),
            remotes[i].key,
            remotes[i].addr,
        );
        let _ = sim.post_send(NodeId(0), qpns[i], wr);
    };
    for i in 0..pairs {
        for _ in 0..window {
            post(&mut sim, &qpns, i);
        }
    }
    let mut notes: Vec<Notification> = Vec::new();
    let mut cqes: Vec<crate::fabric::wqe::Cqe> = Vec::new();
    while sim.now() < duration {
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        for n in notes.drain(..) {
            if matches!(n, Notification::CqeReady { node, .. } if node == NodeId(0)) {
                cqes.clear();
                sim.poll_cq_into(NodeId(0), cq0, 256, &mut cqes);
                for cqe in &cqes {
                    post(&mut sim, &qpns, cqe.wr_id as usize % pairs);
                }
            }
        }
    }
    sim.steps_processed()
}

/// Daemon-pump microbench workload for `bench pump`: `conns` logical
/// connections from one client daemon to one server daemon, closed-loop
/// READs of `msg_bytes` at `window` outstanding each. Unlike
/// [`event_storm`] (which has no daemon layer) this exercises exactly
/// the per-op daemon data plane — Worker batch flush, Poller CQ drain,
/// wr_id-slab completion, inbox delivery, SRQ refill — so it is the perf
/// trajectory for daemon densification work. Returns (ops completed by
/// the client daemon, simulator events); both are deterministic, callers
/// time the call and divide for ops/sec.
pub fn pump_storm(conns: usize, msg_bytes: u64, window: u32, duration: Ns) -> (u64, u64) {
    let mut fabric = FabricConfig::default();
    fabric.nodes = 2;
    fabric.sq_depth = 8192;
    let mut sim = Sim::new(fabric);
    let mut daemons = vec![
        Daemon::start(&mut sim, NodeId(0), DaemonConfig::default()),
        Daemon::start(&mut sim, NodeId(1), DaemonConfig::default()),
    ];
    let sapp = daemons[1].register_app();
    daemons[1].listen(sapp, 7000);
    let app = daemons[0].register_app();
    let mut handles = Vec::with_capacity(conns);
    for _ in 0..conns {
        handles.push(connect_via(&mut sim, &mut daemons, 0, app, 1, 7000).unwrap());
    }

    let mut rng = Rng::new(42);
    let mut offgen = OffsetGen::uniform(64 << 20, 4096);
    for (i, c) in handles.iter().enumerate() {
        for _ in 0..window {
            let off = offgen.next(&mut rng, msg_bytes);
            let _ = daemons[0].read(&mut sim, *c, msg_bytes, off, i as u64);
        }
    }
    daemons[0].pump(&mut sim);

    let mut notes: Vec<Notification> = Vec::new();
    while sim.now() < duration {
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        let client_cqe = notes
            .iter()
            .any(|n| matches!(n, Notification::CqeReady { node, .. } if node.0 == 0));
        if client_cqe {
            daemons[0].pump(&mut sim);
            while let Some(d) = daemons[0].recv_zero_copy(&mut sim, app) {
                if let Delivery::OpComplete { conn, .. } = d {
                    let off = offgen.next(&mut rng, msg_bytes);
                    let _ = daemons[0].read(&mut sim, conn, msg_bytes, off, 0);
                }
            }
            daemons[0].pump(&mut sim);
        }
    }
    (daemons[0].stats.ops_completed, sim.steps_processed())
}

/// Fig 1: verbs-level single-pair throughput sweep for one (transport,
/// verb) combination at one message size.
pub fn verbs_sweep_point(
    transport: crate::fabric::types::QpTransport,
    verb: crate::fabric::types::Verb,
    msg_bytes: u64,
    window: u32,
    duration: Ns,
) -> f64 {
    use crate::fabric::mr::Access;
    use crate::fabric::types::{QpTransport, Verb};
    use crate::fabric::verbs as fv;
    use crate::fabric::wqe::SendWr;

    let mut fabric = FabricConfig::default();
    fabric.max_outstanding = window as usize;
    fabric.sq_depth = 4 * window as usize + 16;
    let mut sim = Sim::new(fabric);
    let cq0 = sim.create_cq(NodeId(0), 65_536);
    let cq1 = sim.create_cq(NodeId(1), 65_536);

    let local = sim.reg_mr(NodeId(0), 256 << 20, Access::REMOTE_RW, true);
    let remote = sim.reg_mr(NodeId(1), 256 << 20, Access::REMOTE_RW, true);

    let make_wr = |i: u64, qpn_is_ud: Option<(NodeId, crate::fabric::types::Qpn)>| -> SendWr {
        let wr = match verb {
            Verb::Read => SendWr::read(i, msg_bytes, local.key, local.addr, remote.key, remote.addr),
            Verb::Write => SendWr::write(i, msg_bytes, local.key, local.addr, remote.key, remote.addr),
            Verb::Send => SendWr::send(i, msg_bytes, local.key, local.addr, i as u32),
        };
        match qpn_is_ud {
            Some((n, q)) => wr.to_ud(n, q),
            None => wr,
        }
    };

    let (qpn, ud_dest, recv_qpn) = if transport == QpTransport::Ud {
        let ud0 = fv::create_ud(&mut sim, NodeId(0), cq0, cq0);
        let ud1 = fv::create_ud(&mut sim, NodeId(1), cq1, cq1);
        (ud0, Some((NodeId(1), ud1)), ud1)
    } else {
        let pair = fv::create_connected_pair(
            &mut sim, transport, NodeId(0), NodeId(1), cq0, cq0, cq1, cq1,
        );
        (pair.a.1, None, pair.b.1)
    };

    // receiver WQEs for two-sided traffic
    let needs_recv = verb == Verb::Send;
    let mut recv_seq = 0u64;
    let mut replenish = |sim: &mut Sim| {
        if needs_recv {
            fv::replenish_rq(sim, NodeId(1), recv_qpn, &remote, msg_bytes.max(64), 512, &mut recv_seq);
        }
    };
    replenish(&mut sim);

    let mut next = 0u64;
    for _ in 0..window {
        sim.post_send(NodeId(0), qpn, make_wr(next, ud_dest)).unwrap();
        next += 1;
    }

    let warmup = Ns(duration.0 / 5);
    let mut started = false;
    let (mut bytes0, mut t0) = (0u64, Ns::ZERO);
    let mut notes: Vec<Notification> = Vec::new();
    while sim.now() < duration {
        if !started && sim.now() >= warmup {
            started = true;
            bytes0 = sim.total_rx_data_bytes();
            t0 = sim.now();
        }
        notes.clear();
        if !sim.step_into(&mut notes) {
            break;
        }
        let mut repost = 0;
        for n in notes.drain(..) {
            match n {
                Notification::CqeReady { node, cqn } if node == NodeId(0) && cqn == cq0 => {
                    repost += sim.poll_cq(NodeId(0), cq0, 64).len();
                }
                Notification::CqeReady { node, cqn } if node == NodeId(1) && cqn == cq1 => {
                    // receiver drains its CQ (keeps it from overflowing)
                    sim.poll_cq(NodeId(1), cq1, 64);
                    replenish(&mut sim);
                }
                _ => {}
            }
        }
        for _ in 0..repost {
            let _ = sim.post_send(NodeId(0), qpn, make_wr(next, ud_dest));
            next += 1;
        }
    }
    gbps(sim.total_rx_data_bytes() - bytes0, sim.now().saturating_sub(t0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::types::{QpTransport, Verb};

    fn quick(conns: usize) -> ScenarioCfg {
        let mut cfg = ScenarioCfg::default();
        cfg.conns = conns;
        cfg.duration = Ns::from_ms(4);
        cfg
    }

    #[test]
    fn naive_healthy_at_low_conns() {
        let st = naive_random_read(&quick(32));
        assert!(st.gbps > 30.0, "expected near line rate, got {:.1}", st.gbps);
        assert!(st.cache_hit_rate > 0.9, "cache should be hot: {}", st.cache_hit_rate);
    }

    #[test]
    fn naive_collapses_beyond_cache_capacity() {
        // needs a long window: with 800 outstanding 64 KB reads the first
        // closed-loop round alone takes ~10 ms, and the ICM-thrash regime
        // only develops once reposts are engine-gated
        let mut lo_cfg = quick(100);
        lo_cfg.duration = Ns::from_ms(30);
        lo_cfg.warmup_frac = 0.4;
        let mut hi_cfg = quick(800);
        hi_cfg.duration = Ns::from_ms(30);
        hi_cfg.warmup_frac = 0.4;
        let low = naive_random_read(&lo_cfg);
        let high = naive_random_read(&hi_cfg);
        assert!(
            high.gbps < low.gbps * 0.75,
            "800 conns ({:.1} Gb/s) must be well below 100 conns ({:.1} Gb/s)",
            high.gbps,
            low.gbps
        );
        assert!(high.cache_hit_rate < 0.7);
    }

    #[test]
    fn raas_stable_at_high_conns() {
        let low = raas_random_read(&quick(100));
        let high = raas_random_read(&quick(800));
        assert!(low.gbps > 30.0, "raas low: {:.1}", low.gbps);
        assert!(
            high.gbps > low.gbps * 0.85,
            "raas must stay stable: {:.1} vs {:.1}",
            high.gbps,
            low.gbps
        );
        assert!(high.cache_hit_rate > 0.95, "shared QPs stay cached");
    }

    #[test]
    fn locked_q6_worse_than_q3() {
        // 12 worker threads: q=6 leaves only 2 QPs, so the lock becomes the
        // bottleneck; q=3 still has 4 lock domains.
        let mut cfg = quick(12);
        cfg.msg_bytes = 512;
        cfg.window = 4;
        let q3 = locked_random_read(&cfg, 3);
        let q6 = locked_random_read(&cfg, 6);
        assert!(
            q6.mops < q3.mops,
            "q=6 ({:.2} Mops) must underperform q=3 ({:.2} Mops)",
            q6.mops,
            q3.mops
        );
        assert!(q6.lock_wait_ms > 0.0);
    }

    fn chaos_quick(loss: f64) -> ChaosCfg {
        let mut cfg = ChaosCfg::default();
        cfg.conns = 48;
        cfg.duration = Ns::from_ms(3);
        cfg.loss = loss;
        cfg
    }

    #[test]
    fn chaos_at_loss_zero_is_the_lossless_simulator() {
        // null plan: the fault layer is not even installed, so every
        // fault counter must be exactly zero and traffic must flow
        let r = chaos_send(&chaos_quick(0.0));
        assert!(r.gbps > 0.0, "no goodput at loss 0: {r:?}");
        assert!(r.ops > 0);
        assert_eq!(r.frames_dropped + r.frames_delayed, 0);
        assert_eq!(r.retransmits + r.retry_exceeded + r.gbn_discards, 0);
        assert_eq!(r.ud_dropped + r.ud_orphans + r.ud_expired, 0);
        assert_eq!(r.failed_ops + r.leases_reclaimed + r.restarts, 0);
    }

    #[test]
    fn chaos_lossy_run_retransmits_and_degrades() {
        let clean = chaos_send(&chaos_quick(0.0));
        let mut cfg = chaos_quick(0.05);
        cfg.flaps = 2;
        // adaptive: the migrated (UD) traffic pays for loss with torn
        // reassemblies, not retransmissions
        let dirty = chaos_send(&cfg);
        assert!(dirty.frames_dropped > 0, "{dirty:?}");
        assert!(
            dirty.ud_dropped + dirty.ud_orphans > 0,
            "fragmented UD messages must lose fragments: {dirty:?}"
        );
        assert!(
            dirty.gbps < clean.gbps,
            "5% loss must cost goodput: {:.2} vs {:.2}",
            dirty.gbps,
            clean.gbps
        );
        // rc-only: the connected path pays with go-back-N retransmissions
        cfg.rc_only = true;
        let rc = chaos_send(&cfg);
        assert!(rc.retransmits > 0, "RC must retransmit under loss: {rc:?}");
        assert_eq!(rc.ud_dropped + rc.ud_orphans, 0, "no UD traffic in the ablation");
    }

    fn kv_quick(clients: usize, rpc: bool) -> KvCfg {
        let mut cfg = KvCfg::default();
        cfg.clients = clients;
        cfg.max_servers = 8;
        cfg.duration = Ns::from_ms(3);
        cfg.rpc = rpc;
        cfg
    }

    #[test]
    fn kv_storm_one_sided_beats_rpc_and_bypasses_servers() {
        let os = kv_storm(&kv_quick(256, false));
        let rpc = kv_storm(&kv_quick(256, true));
        assert!(os.ops > 0, "{os:?}");
        assert!(rpc.ops > 0, "{rpc:?}");
        // the fig-11 claim: one READ RTT beats two SEND legs plus a
        // server turn, at app-level ops
        assert!(
            os.ops > rpc.ops,
            "one-sided ({}) must out-op SEND-RPC ({})",
            os.ops,
            rpc.ops
        );
        // one-sided ops never touch the server's service loop…
        assert_eq!(os.server_gets_served + os.server_puts_applied, 0, "{os:?}");
        // …and PUT bursts coalesce into doorbell groups
        assert!(os.window_flushes > 0, "{os:?}");
        assert!(os.writes_coalesced > 0, "{os:?}");
        // the RPC baseline does the opposite on every count
        assert!(rpc.server_gets_served > 0, "{rpc:?}");
        assert!(rpc.server_puts_applied > 0, "{rpc:?}");
        assert_eq!(rpc.window_flushes, 0, "{rpc:?}");
        assert!(
            rpc.server_cpu_cores > os.server_cpu_cores,
            "RPC must burn more server CPU: {:.3} vs {:.3}",
            rpc.server_cpu_cores,
            os.server_cpu_cores
        );
    }

    fn churn_quick(cold: bool) -> ChurnCfg {
        let mut cfg = ChurnCfg::default();
        cfg.conns = 2_000;
        cfg.cold = cold;
        cfg
    }

    #[test]
    fn churn_storm_reuse_and_lazy_beat_cold() {
        let warm = churn_storm(&churn_quick(false));
        let cold = churn_storm(&churn_quick(true));
        // the pool gets exercised and actually serves reconnects
        assert!(warm.qp_parked > 0, "{warm:?}");
        assert!(warm.qp_reused > 0, "{warm:?}");
        assert_eq!(cold.qp_reused, 0, "cold mode must never revive: {cold:?}");
        // every cold reconnect pays the full handshake
        assert!(
            cold.handshakes_full > warm.handshakes_full,
            "cold must handshake more: {} vs {}",
            cold.handshakes_full,
            warm.handshakes_full
        );
        // …which is the fig-12 headline: warm setup rate wins
        assert!(
            warm.setup_kcps > cold.setup_kcps,
            "reuse+lazy must beat cold setup rate: {:.1} vs {:.1} kcps",
            warm.setup_kcps,
            cold.setup_kcps
        );
        // lazy leases coalesce: never more control messages than remotes
        // established; eager pays exactly one message per establishment
        assert!(warm.lease_batches <= warm.leases_established, "{warm:?}");
        assert_eq!(cold.lease_batches, cold.leases_established, "{cold:?}");
        // the working set completed its first READs and the idle mass is
        // registered at a per-vQPN cost far below any full connection
        assert!(warm.ops_completed > 0, "{warm:?}");
        assert!(warm.live_vqpns > 1000, "{warm:?}");
        assert!(
            warm.table_bytes_per_vqpn > 0.0 && warm.table_bytes_per_vqpn < 256.0,
            "idle tenant must cost ~one table entry: {warm:?}"
        );
        // a late frame/CQE from a departed tenant never surfaces
        assert_eq!(warm.disconnects, cold.disconnects, "same seeded tape");
    }

    #[test]
    fn kv_storm_replays_identically() {
        let cfg = kv_quick(64, false);
        let a = format!("{:?}", kv_storm(&cfg));
        let b = format!("{:?}", kv_storm(&cfg));
        assert_eq!(a, b, "kv_storm must replay identically");
    }

    #[test]
    fn pump_storm_completes_ops_deterministically() {
        let a = pump_storm(64, 4096, 2, Ns::from_ms(2));
        let b = pump_storm(64, 4096, 2, Ns::from_ms(2));
        assert!(a.0 > 0, "the closed loop must complete ops: {a:?}");
        assert!(a.1 > 0);
        assert_eq!(a, b, "pump storm must replay identically");
    }

    #[test]
    fn verbs_sweep_large_msgs_hit_line_rate() {
        let g = verbs_sweep_point(QpTransport::Rc, Verb::Write, 1 << 20, 8, Ns::from_ms(4));
        assert!(g > 34.0, "RC WRITE 1MB: {g:.1} Gb/s");
    }

    #[test]
    fn verbs_sweep_small_msgs_overhead_bound() {
        let g = verbs_sweep_point(QpTransport::Rc, Verb::Write, 64, 8, Ns::from_ms(2));
        assert!(g < 10.0, "64 B writes can't reach line rate: {g:.1}");
    }

    fn incast_quick(oversub: u32, mode: CcMode) -> IncastCfg {
        let mut cfg = IncastCfg::default();
        cfg.oversub = oversub;
        cfg.mode = mode;
        cfg.writers = 8;
        cfg.elephants = 2;
        cfg.mice = 2;
        cfg.window = 8;
        cfg.duration = Ns::from_ms(2);
        cfg
    }

    #[test]
    fn incast_storm_completes_and_replays() {
        let cfg = incast_quick(4, CcMode::Dcqcn);
        let a = incast_storm(&cfg);
        let b = incast_storm(&cfg);
        assert!(a.ops > 0 && a.goodput_gbps > 0.0, "{a:?}");
        assert!(a.events > 0);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "incast must replay identically");
    }

    #[test]
    fn incast_congestion_marks_and_drops_without_cc() {
        let run = incast_storm(&incast_quick(8, CcMode::NoCc));
        assert!(run.switch_drops > 0, "deep incast into one uplink must tail-drop: {run:?}");
        assert!(run.retransmits > 0, "drops must drive go-back-N recovery: {run:?}");
    }

    #[test]
    fn incast_pfc_never_drops_at_the_switch() {
        let run = incast_storm(&incast_quick(8, CcMode::Pfc));
        assert_eq!(run.switch_drops, 0, "PFC is lossless: {run:?}");
        assert!(run.pauses > 0, "deep incast must pause somewhere: {run:?}");
    }

    #[test]
    fn incast_spine_flap_recovers_deterministically() {
        let mut cfg = incast_quick(2, CcMode::Dcqcn);
        cfg.spine_flap = Some((500_000, 900_000));
        let a = incast_storm(&cfg);
        let b = incast_storm(&cfg);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "flap run must replay identically");
        assert!(a.ops > 0, "flows must survive the flap window: {a:?}");
    }
}
