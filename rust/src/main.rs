//! rdmavisor — CLI entrypoint.
//!
//! Subcommands:
//! * `figures`   — regenerate the paper's tables/figures (`--all`,
//!   `--table1`, `--fig1`, `--fig5`, `--fig6`, `--fig7`, `--fig8`,
//!   `--send-staging`, `--batching`); `--tsv DIR` also writes TSVs.
//! * `bench`     — one scenario run with explicit knobs (conns, size, …).
//! * `serve`     — live serving smoke: load artifacts, run a batched
//!   inference workload through the RaaS channels, report latency.
//! * `init-config` — write a documented sample cluster config.
//! * `info`      — print fabric/daemon defaults and artifact status.

use rdmavisor::config;
use rdmavisor::figures::{self, Budget};
use rdmavisor::metrics::Series;
use rdmavisor::util::cli::Args;
use rdmavisor::util::logging;
use rdmavisor::workload::scenarios::{
    locked_random_read, naive_random_read, raas_random_read, ScenarioCfg,
};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse_with_subcommand(&argv);
    logging::set_level_from_str(&args.str_or("log", "info"));

    match args.subcommand.as_deref() {
        Some("figures") => figures_cmd(&args),
        Some("bench") => bench_cmd(&args),
        Some("serve") => serve_cmd(&args),
        Some("init-config") => {
            let path = args.str_or("out", "cluster.toml");
            std::fs::write(&path, config::SAMPLE).expect("write config");
            println!("wrote {path}");
        }
        Some("info") => info_cmd(),
        _ => {
            eprintln!(
                "usage: rdmavisor <figures|bench|serve|init-config|info> [--help]\n\
                 \n  figures --all | --table1 --fig1 --fig5 --fig6 --fig7 --fig8 \
                 --send-staging --batching [--quick] [--tsv DIR]\
                 \n  bench [--system raas|naive|locked] [--conns N] [--size BYTES] \
                 [--window N] [--duration-ms MS] [--q N] [--config FILE]\
                 \n  serve [--clients N] [--requests N] [--artifacts DIR]\
                 \n  init-config [--out FILE]"
            );
            std::process::exit(2);
        }
    }
}

fn budget(args: &Args) -> Budget {
    if args.flag("quick") {
        Budget::Quick
    } else {
        Budget::from_env()
    }
}

fn figures_cmd(args: &Args) {
    let b = budget(args);
    let all = args.flag("all");
    let tsv_dir = args.get("tsv").map(|s| s.to_string());
    let mut series: Vec<Series> = Vec::new();

    if all || args.flag("table1") {
        println!("{}", figures::table1());
    }
    if all || args.flag("fig1") {
        let rows = figures::fig1(b);
        println!("{}", figures::print_fig1(&rows));
        let mut s = Series::new(
            "fig1_verbs",
            "msg_bytes",
            &["rc_read", "rc_write", "uc_write", "ud_send"],
        );
        for r in &rows {
            s.push(r.msg_bytes as f64, vec![r.rc_read, r.rc_write, r.uc_write, r.ud_send]);
        }
        series.push(s);
    }
    if all || args.flag("fig5") {
        let rows = figures::fig5(b);
        println!("{}", figures::print_fig5(&rows));
        let mut s = Series::new("fig5_scalability", "conns", &["naive_gbps", "raas_gbps"]);
        for r in &rows {
            s.push(r.conns as f64, vec![r.naive.gbps, r.raas.gbps]);
        }
        series.push(s);
    }
    if all || args.flag("fig6") {
        let rows = figures::fig6(b);
        println!("{}", figures::print_fig6(&rows));
        let mut s = Series::new(
            "fig6_qp_sharing",
            "threads",
            &["raas_mops", "lock_q3_mops", "lock_q6_mops"],
        );
        for r in &rows {
            s.push(r.threads as f64, vec![r.raas.mops, r.locked_q3.mops, r.locked_q6.mops]);
        }
        series.push(s);
    }
    if all || args.flag("fig7") || args.flag("fig8") {
        let rows = figures::fig78(b);
        if all || args.flag("fig7") {
            println!("{}", figures::print_fig7(&rows));
        }
        if all || args.flag("fig8") {
            println!("{}", figures::print_fig8(&rows));
        }
        let mut s = Series::new(
            "fig78_resources",
            "apps",
            &["naive_mem", "raas_mem", "naive_cpu", "raas_cpu"],
        );
        for r in &rows {
            s.push(r.apps as f64, vec![r.naive_mem, r.raas_mem, r.naive_cpu, r.raas_cpu]);
        }
        series.push(s);
    }
    if all || args.flag("send-staging") {
        println!("{}", figures::send_staging_sweep());
    }
    if all || args.flag("batching") {
        println!("{}", figures::batching_ablation(b));
    }
    if let Some(dir) = tsv_dir {
        for s in &series {
            match s.write_tsv(&dir) {
                Ok(p) => println!("wrote {p}"),
                Err(e) => eprintln!("tsv write failed: {e}"),
            }
        }
    }
}

fn bench_cmd(args: &Args) {
    let mut cfg = match args.get("config") {
        Some(path) => config::from_file(path).expect("config").scenario,
        None => ScenarioCfg::default(),
    };
    cfg.conns = args.usize_or("conns", cfg.conns);
    cfg.apps = args.u64_or("apps", cfg.apps as u64) as u32;
    cfg.msg_bytes = args.u64_or("size", cfg.msg_bytes);
    cfg.window = args.u64_or("window", cfg.window as u64) as u32;
    cfg.duration = rdmavisor::fabric::time::Ns::from_ms(args.u64_or("duration-ms", 20));
    cfg.seed = args.u64_or("seed", cfg.seed);

    let system = args.str_or("system", "raas");
    let st = match system.as_str() {
        "naive" => naive_random_read(&cfg),
        "locked" => locked_random_read(&cfg, args.usize_or("q", 3)),
        _ => raas_random_read(&cfg),
    };
    println!(
        "{system}: conns={} size={} -> {:.2} Gb/s  {:.3} Mops  p50={:.1}µs p99={:.1}µs  \
         mem={:.1}MB cpu={:.2} cores  cache={:.1}%",
        cfg.conns,
        figures::human_size(cfg.msg_bytes),
        st.gbps,
        st.mops,
        st.p50_us,
        st.p99_us,
        st.mem_bytes as f64 / 1e6,
        st.cpu_cores,
        st.cache_hit_rate * 100.0
    );
}

fn serve_cmd(args: &Args) {
    use rdmavisor::apps::inference::InferenceEngine;
    use std::time::Instant;

    let dir = args.str_or("artifacts", "artifacts");
    let clients = args.usize_or("clients", 4);
    let requests = args.u64_or("requests", 64);

    let manifest = rdmavisor::runtime::Manifest::load(&dir)
        .expect("load artifacts (run `make artifacts` first)");
    println!(
        "variants={:?}",
        manifest.variants.iter().map(|v| v.name.clone()).collect::<Vec<_>>()
    );
    let engine = InferenceEngine::new(&dir, clients, 1024);

    let server = {
        let engine = engine.clone();
        std::thread::spawn(move || engine.serve_loop())
    };

    let t0 = Instant::now();
    let mut latencies = Vec::new();
    let mut outstanding: Vec<Vec<(u64, Instant)>> = vec![Vec::new(); clients];
    let mut done = 0u64;
    let mut next_tag = 0u64;
    let total = requests * clients as u64;
    while done < total {
        for c in 0..clients {
            if outstanding[c].len() < 4 && next_tag < total && engine.submit(c, next_tag) {
                outstanding[c].push((next_tag, Instant::now()));
                next_tag += 1;
            }
            for tag in engine.reap(c) {
                if let Some(pos) = outstanding[c].iter().position(|(t, _)| *t == tag) {
                    let (_, t) = outstanding[c].remove(pos);
                    latencies.push(t.elapsed().as_micros() as u64);
                    done += 1;
                }
            }
        }
    }
    let wall = t0.elapsed();
    engine.stop();
    let _ = server.join();

    latencies.sort_unstable();
    let p = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
    let st = engine.stats.lock().unwrap();
    println!(
        "served {} requests in {:.2?}: {:.0} req/s, p50={}µs p99={}µs, \
         mean batch={:.2}, model time {:.1}ms total",
        done,
        wall,
        done as f64 / wall.as_secs_f64(),
        p(0.5),
        p(0.99),
        st.mean_batch(),
        st.model_ns as f64 / 1e6
    );
}

fn info_cmd() {
    let f = figures::default_fabric();
    println!(
        "fabric: {} nodes × {} cores, {} Gb/s, MTU {}",
        f.nodes, f.cores_per_node, f.link_gbps, f.mtu
    );
    println!(
        "nic: icm_cache={} entries, miss={}ns, frame={}ns",
        f.nic.icm_cache_entries, f.nic.icm_miss_ns, f.nic.engine_frame_ns
    );
    match rdmavisor::runtime::Manifest::load("artifacts") {
        Ok(m) => println!("artifacts: {} variants (seed {})", m.variants.len(), m.seed),
        Err(e) => println!("artifacts: not built ({e})"),
    }
}
