//! rdmavisor — the experiment-runner CLI.
//!
//! One binary drives the whole reproduction. Subcommands:
//!
//! * `fig --id {1,5,6,7,8,9,10,11,12,13,14}` — regenerate a paper figure
//!   (9 = the RC↔UD-migration scale extension, 10 = the fault-injection
//!   chaos sweep, 11 = the one-sided KV tier, 12 = the tenant-churn
//!   setup-rate sweep, 13 = the Clos incast congestion sweep, 14 = the
//!   failover storm through a spine death) and print
//!   the series as JSON on stdout (human-readable table on stderr).
//!   `--all` runs every figure; `--quick` shrinks the
//!   sweeps; `--rc-only` restricts figures 9/10/11 to the ablation;
//!   `--cold` restricts figure 12 to the no-pool/eager-lease ablation;
//!   `--no-cc`/`--pfc` restrict figure 13 to one congestion-control
//!   ablation; `--repath-off` restricts figure 14 to the frozen-routing
//!   ablation;
//!   `--jobs N` runs the independent sweep points on N threads (0 = all
//!   cores) with byte-identical output; `--shards N` splits each
//!   figure-9–12 `Sim` into N conservatively-synchronized partitions (0 =
//!   all cores), also byte-identical; `--tsv DIR` also writes TSVs.
//! * `bench hotpath` — the hot-path microbenchmarks (SPSC ring, doorbell,
//!   ICM cache, daemon submit) with JSON results.
//! * `bench simstep [--shards N]` — raw discrete-event-scheduler
//!   throughput (events/sec) on a daemon-free QP storm; `--shards N` adds
//!   a shard-count sweep (1, 2, N) of the same storm for the
//!   conservative-parallel scaling trajectory (BENCH_PR8.json via
//!   `scripts/bench_pr8.sh`).
//! * `bench pump` — daemon data-plane throughput (ops/sec through one
//!   daemon's pump loop: batch flush, CQ drain, slab completion, SRQ
//!   refill).
//! * `bench fig9 [--out FILE] [--jobs N] [--shards N]` — wall-clock of
//!   the Fig-9 scale sweep per connection count, written as
//!   `BENCH_PR5.json` (the CI perf artifact; `bench pump` + `bench
//!   simstep` sections embedded). With `--shards N` every point also runs
//!   sharded, the output series is byte-compared against serial
//!   (`identical_series`), and the artifact defaults to `BENCH_PR8.json`.
//! * `bench kv [--out FILE] [--jobs N]` — wall-clock of the fig-11 KV
//!   sweep per client count (one-sided vs SEND-RPC), written as
//!   `BENCH_PR6.json` (the CI perf artifact for the window data plane).
//! * `bench churn [--out FILE] [--jobs N]` — wall-clock of the fig-12
//!   churn sweep per arrival count (warm vs cold), written as
//!   `BENCH_PR7.json` (the CI perf artifact for the elastic control
//!   plane).
//! * `bench incast [--out FILE] [--jobs N]` — wall-clock of the fig-13
//!   incast sweep per oversubscription factor (DCQCN vs no-CC vs PFC),
//!   written as `BENCH_PR9.json` (the CI perf artifact for the Clos
//!   congestion-control fabric).
//! * `bench failover [--out FILE] [--jobs N] [--shards N]` — wall-clock
//!   of the fig-14 failover storm (repath-on vs repath-off), written as
//!   `BENCH_PR10.json` (the CI perf artifact for the survivable fabric).
//!   With `--shards N` the repath run also executes sharded and its
//!   series is byte-compared against serial (`identical_series`).
//! * `bench` — one scenario run with explicit knobs (`--system
//!   raas|naive|locked`, `--conns`, `--size`, …), JSON result on stdout.
//! * `demo {kv,rpc,inference}` — the example applications end-to-end over
//!   the simulated fabric (inference uses real threads + the simulated
//!   model executor), JSON stats on stdout.
//! * `figures` — the legacy all-tables report (`--all`, `--table1`,
//!   `--fig1` … `--send-staging`, `--batching`).
//! * `serve` — live serving smoke: batched inference through the RaaS
//!   channels, latency report.
//! * `init-config` — write a documented sample cluster config.
//! * `info` — print fabric/daemon defaults and artifact status.

use std::time::Instant;

use rdmavisor::config;
use rdmavisor::figures::{self, Budget};
use rdmavisor::metrics::Series;
use rdmavisor::util::cli::Args;
use rdmavisor::util::jsonmini::{obj, Json};
use rdmavisor::util::logging;
use rdmavisor::util::parallel;
use rdmavisor::workload::scenarios::{
    locked_random_read, naive_random_read, raas_random_read, RunStats, ScenarioCfg,
};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse_with_subcommand(&argv);
    logging::set_level_from_str(&args.str_or("log", "info"));

    match args.subcommand.as_deref() {
        Some("fig") => fig_cmd(&args),
        Some("figures") => figures_cmd(&args),
        Some("bench") => bench_cmd(&args),
        Some("demo") => demo_cmd(&args),
        Some("serve") => serve_cmd(&args),
        Some("init-config") => {
            let path = args.str_or("out", "cluster.toml");
            std::fs::write(&path, config::SAMPLE).expect("write config");
            println!("wrote {path}");
        }
        Some("info") => info_cmd(),
        _ => {
            eprintln!(
                "usage: rdmavisor <fig|figures|bench|demo|serve|init-config|info> [--help]\n\
                 \n  fig --id 1|5|6|7|8|9|10|11|12|13|14 [--all] [--quick] [--rc-only] [--cold] [--no-cc] [--pfc] [--repath-off] [--jobs N] [--shards N] [--tsv DIR]   (JSON on stdout)\
                 \n  bench hotpath|simstep|pump [--quick] [--shards N]  (JSON on stdout)\
                 \n  bench fig9 [--quick] [--jobs N] [--shards N] [--out FILE]    (fig-9 wall clock -> BENCH_PR5.json; --shards -> BENCH_PR8.json)\
                 \n  bench kv [--quick] [--jobs N] [--out FILE]      (fig-11 wall clock -> BENCH_PR6.json)\
                 \n  bench churn [--quick] [--jobs N] [--out FILE]   (fig-12 wall clock -> BENCH_PR7.json)\
                 \n  bench incast [--quick] [--jobs N] [--out FILE]  (fig-13 wall clock -> BENCH_PR9.json)\
                 \n  bench failover [--quick] [--jobs N] [--shards N] [--out FILE]  (fig-14 wall clock -> BENCH_PR10.json)\
                 \n  bench [--system raas|naive|locked] [--conns N] [--size BYTES] \
                 [--window N] [--duration-ms MS] [--q N] [--config FILE]\
                 \n  demo kv|rpc|inference [--gets N] [--calls N] [--requests N]\
                 \n  figures --all | --table1 --fig1 --fig5 --fig6 --fig7 --fig8 --fig9 \
                 --fig10 --fig11 --fig12 --fig13 --fig14 --send-staging --batching [--quick] [--tsv DIR]\
                 \n  serve [--clients N] [--requests N] [--artifacts DIR]\
                 \n  init-config [--out FILE]"
            );
            std::process::exit(2);
        }
    }
}

fn budget(args: &Args) -> Budget {
    if args.flag("quick") {
        Budget::Quick
    } else {
        Budget::from_env()
    }
}

/// Resolve `--jobs N` (default 1 = the serial runner; 0 = all cores).
fn jobs(args: &Args) -> usize {
    parallel::effective_jobs(args.usize_or("jobs", 1))
}

/// Resolve `--shards N` (default 1 = the serial simulator; 0 = all
/// cores). The zero case is resolved here so the printed/recorded value
/// matches what the `Sim` actually ran with.
fn shards(args: &Args) -> usize {
    parallel::effective_jobs(args.usize_or("shards", 1))
}

// ---------------------------------------------------------------- JSON glue

/// JSON number that degrades NaN/inf to null (strict-JSON safe).
fn num(f: f64) -> Json {
    if f.is_finite() {
        Json::Num(f)
    } else {
        Json::Null
    }
}

fn run_stats_json(st: &RunStats) -> Json {
    obj(vec![
        ("gbps", num(st.gbps)),
        ("mops", num(st.mops)),
        ("ops", Json::Num(st.ops as f64)),
        ("p50_us", num(st.p50_us)),
        ("p99_us", num(st.p99_us)),
        ("mem_bytes", Json::Num(st.mem_bytes as f64)),
        ("cpu_cores", num(st.cpu_cores)),
        ("cache_hit_rate", num(st.cache_hit_rate)),
        ("lock_wait_ms", num(st.lock_wait_ms)),
    ])
}

// ------------------------------------------------------------------- `fig`

fn fig_cmd(args: &Args) {
    let b = budget(args);
    let jobs = jobs(args);
    let shards = shards(args);
    let mut ids: Vec<u64> = if args.flag("all") {
        vec![1, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14]
    } else {
        args.u64_list("id", &[])
    };
    // also accept bare positional ids: `rdmavisor fig 5`
    for p in &args.positional {
        if let Ok(n) = p.parse::<u64>() {
            ids.push(n);
        }
    }
    // order-preserving dedup (Vec::dedup only removes adjacent repeats)
    let mut seen = std::collections::BTreeSet::new();
    ids.retain(|id| seen.insert(*id));
    if ids.is_empty() {
        eprintln!(
            "usage: rdmavisor fig --id 1|5|6|7|8|9|10|11|12|13|14 [--all] [--quick] [--rc-only] \
             [--cold] [--no-cc] [--pfc] [--repath-off] [--jobs N] [--shards N] [--tsv DIR]"
        );
        std::process::exit(2);
    }

    let t0 = Instant::now();
    let mut series = Vec::new();
    let mut figs = Vec::new();
    let mut fig78_cache = None;
    for &id in &ids {
        // `fig --id 9|10 --rc-only` runs just the ablation series
        let (s, table) = if id == 9 && args.flag("rc-only") {
            let rows = figures::fig9_rc_only_sharded(b, jobs, shards);
            (figures::fig9_series(&rows), figures::print_fig9(&rows))
        } else if id == 10 && args.flag("rc-only") {
            let rows = figures::fig10_rc_only_sharded(b, jobs, shards);
            (figures::fig10_series(&rows), figures::print_fig10(&rows))
        } else if id == 11 && args.flag("rc-only") {
            let rows = figures::fig11_rpc_only_sharded(b, jobs, shards);
            (figures::fig11_series(&rows), figures::print_fig11(&rows))
        } else if id == 12 && args.flag("cold") {
            let rows = figures::fig12_cold_only_sharded(b, jobs, shards);
            (figures::fig12_series(&rows), figures::print_fig12(&rows))
        } else if id == 13 && args.flag("no-cc") {
            let rows = figures::fig13_no_cc_sharded(b, jobs, shards);
            (figures::fig13_series(&rows), figures::print_fig13(&rows))
        } else if id == 13 && args.flag("pfc") {
            let rows = figures::fig13_pfc_sharded(b, jobs, shards);
            (figures::fig13_series(&rows), figures::print_fig13(&rows))
        } else if id == 14 && args.flag("repath-off") {
            let rows = figures::fig14_repath_off_sharded(b, jobs, shards);
            (figures::fig14_series(&rows), figures::print_fig14(&rows))
        } else {
            match figures::run_fig_sharded(id, b, &mut fig78_cache, jobs, shards) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "unknown figure id {id}: expected 1, 5, 6, 7, 8, 9, 10, 11, 12, 13 or 14"
                    );
                    std::process::exit(2);
                }
            }
        };
        eprint!("{table}");
        let mut f = s.to_json();
        if let Json::Obj(m) = &mut f {
            m.insert("id".to_string(), Json::Num(id as f64));
        }
        figs.push(f);
        series.push(s);
    }
    if let Some(dir) = args.get("tsv") {
        for s in &series {
            match s.write_tsv(dir) {
                Ok(p) => eprintln!("wrote {p}"),
                Err(e) => eprintln!("tsv write failed: {e}"),
            }
        }
    }
    let budget_name = if b == Budget::Quick { "quick" } else { "full" };
    let doc = obj(vec![
        ("command", Json::Str("fig".into())),
        ("budget", Json::Str(budget_name.to_string())),
        ("wall_ms", num(t0.elapsed().as_secs_f64() * 1e3)),
        ("figures", Json::Arr(figs)),
    ]);
    println!("{}", doc.to_string());
}

// --------------------------------------------------------------- `figures`

fn figures_cmd(args: &Args) {
    let b = budget(args);
    let jobs = jobs(args);
    let all = args.flag("all");
    let tsv_dir = args.get("tsv").map(|s| s.to_string());
    let mut series: Vec<Series> = Vec::new();

    if all || args.flag("table1") {
        println!("{}", figures::table1());
    }
    let mut fig78_cache = None;
    for (flag, id) in [
        ("fig1", 1u64),
        ("fig5", 5),
        ("fig6", 6),
        ("fig7", 7),
        ("fig8", 8),
        ("fig9", 9),
        ("fig10", 10),
        ("fig11", 11),
        ("fig12", 12),
        ("fig13", 13),
        ("fig14", 14),
    ] {
        if all || args.flag(flag) {
            let (s, table) =
                figures::run_fig(id, b, &mut fig78_cache, jobs).expect("known figure id");
            print!("{table}");
            series.push(s);
        }
    }
    if all || args.flag("send-staging") {
        println!("{}", figures::send_staging_sweep());
    }
    if all || args.flag("batching") {
        println!("{}", figures::batching_ablation(b));
    }
    if let Some(dir) = tsv_dir {
        for s in &series {
            match s.write_tsv(&dir) {
                Ok(p) => println!("wrote {p}"),
                Err(e) => eprintln!("tsv write failed: {e}"),
            }
        }
    }
}

// ----------------------------------------------------------------- `bench`

fn bench_cmd(args: &Args) {
    match args.positional.first().map(|s| s.as_str()) {
        Some("hotpath") => return bench_hotpath(args),
        Some("simstep") => return bench_simstep(args),
        Some("pump") => return bench_pump(args),
        Some("fig9") => return bench_fig9(args),
        Some("kv") => return bench_kv(args),
        Some("churn") => return bench_churn(args),
        Some("incast") => return bench_incast(args),
        Some("failover") => return bench_failover(args),
        _ => {}
    }
    let mut cfg = match args.get("config") {
        Some(path) => config::from_file(path).expect("config").scenario,
        None => ScenarioCfg::default(),
    };
    cfg.conns = args.usize_or("conns", cfg.conns);
    cfg.apps = args.u64_or("apps", cfg.apps as u64) as u32;
    cfg.msg_bytes = args.u64_or("size", cfg.msg_bytes);
    cfg.window = args.u64_or("window", cfg.window as u64) as u32;
    cfg.duration = rdmavisor::fabric::time::Ns::from_ms(args.u64_or("duration-ms", 20));
    cfg.seed = args.u64_or("seed", cfg.seed);

    let system = args.str_or("system", "raas");
    let st = match system.as_str() {
        "naive" => naive_random_read(&cfg),
        "locked" => locked_random_read(&cfg, args.usize_or("q", 3)),
        _ => raas_random_read(&cfg),
    };
    eprintln!(
        "{system}: conns={} size={} -> {:.2} Gb/s  {:.3} Mops  p50={:.1}µs p99={:.1}µs  \
         mem={:.1}MB cpu={:.2} cores  cache={:.1}%",
        cfg.conns,
        figures::human_size(cfg.msg_bytes),
        st.gbps,
        st.mops,
        st.p50_us,
        st.p99_us,
        st.mem_bytes as f64 / 1e6,
        st.cpu_cores,
        st.cache_hit_rate * 100.0
    );
    let doc = obj(vec![
        ("command", Json::Str("bench".into())),
        ("system", Json::Str(system)),
        ("conns", Json::Num(cfg.conns as f64)),
        ("msg_bytes", Json::Num(cfg.msg_bytes as f64)),
        ("window", Json::Num(cfg.window as f64)),
        ("stats", run_stats_json(&st)),
    ]);
    println!("{}", doc.to_string());
}

fn bench_hotpath(args: &Args) {
    use rdmavisor::fabric::cache::{IcmCache, IcmKey};
    use rdmavisor::fabric::sim::{FabricConfig, Sim};
    use rdmavisor::fabric::types::NodeId;
    use rdmavisor::raas::daemon::{connect_via, Daemon, DaemonConfig};
    use rdmavisor::raas::shmem::{Channel, Descriptor, SpscRing};
    use rdmavisor::util::bench::Bencher;
    use std::sync::Arc;
    use std::time::Duration;

    let mut b = Bencher::from_env();
    if args.flag("quick") {
        b.warmup = Duration::from_millis(20);
        b.max_time = Duration::from_millis(300);
        b.min_iters = 3;
    }

    // lock-free SPSC ring, single-threaded round trip
    let ring: Arc<SpscRing<Descriptor>> = SpscRing::new(4096);
    b.bench("shmem/spsc_push_pop", || {
        ring.push(Descriptor::new(1, 2, 3, 4, 5)).unwrap();
        ring.pop().unwrap()
    });

    // doorbell ring + non-blocking wait
    let ch = Channel::new(16).unwrap();
    b.bench("shmem/doorbell_ring_wait", || {
        ch.submit_bell.ring();
        ch.submit_bell.wait_timeout(100)
    });

    // ICM cache touch (hit path)
    let mut cache = IcmCache::new(400);
    for i in 0..400u32 {
        cache.touch(IcmKey::Qpc(i));
    }
    let mut i = 0u32;
    b.bench("fabric/icm_touch_hit", || {
        i = (i + 1) % 400;
        cache.touch(IcmKey::Qpc(i))
    });

    // daemon submit path (ring + selector + lease + batch append)
    {
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 2;
        fcfg.sq_depth = 1 << 20;
        let mut sim = Sim::new(fcfg);
        let mut daemons = vec![
            Daemon::start(&mut sim, NodeId(0), DaemonConfig::default()),
            Daemon::start(&mut sim, NodeId(1), DaemonConfig::default()),
        ];
        let sapp = daemons[1].register_app();
        daemons[1].listen(sapp, 1);
        let app = daemons[0].register_app();
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        let mut tag = 0u64;
        b.bench("raas/submit_read", || {
            tag += 1;
            let r = daemons[0].read(&mut sim, conn, 4096, (tag * 4096) % (1 << 20), tag);
            if tag % 1024 == 0 {
                daemons[0].pump(&mut sim);
                while sim.step().is_some() {}
                daemons[0].pump(&mut sim);
                while daemons[0].recv_zero_copy(&mut sim, app).is_some() {}
            }
            r.is_ok()
        });
    }

    let results: Vec<Json> = b
        .results()
        .iter()
        .map(|r| {
            let mut pairs = vec![
                ("name", Json::Str(r.name.clone())),
                ("iters", Json::Num(r.iters as f64)),
                ("mean_ns", num(r.mean_ns)),
                ("p50_ns", Json::Num(r.p50_ns as f64)),
                ("p99_ns", Json::Num(r.p99_ns as f64)),
            ];
            if let Some((k, v)) = &r.metric {
                pairs.push(("metric", obj(vec![(k.as_str(), num(*v))])));
            }
            obj(pairs)
        })
        .collect();
    let doc = obj(vec![
        ("command", Json::Str("bench".into())),
        ("mode", Json::Str("hotpath".into())),
        ("results", Json::Arr(results)),
    ]);
    println!("{}", doc.to_string());
}

/// Measure raw discrete-event-scheduler throughput: a QP-fanout WRITE
/// storm with no daemon layer, so the number is the event loop + engine +
/// port model + dense context tables and nothing else. Shared by `bench
/// simstep` and the `simstep` section of `bench fig9`/BENCH_PR3.json.
fn simstep_measure(quick: bool) -> Json {
    simstep_measure_sharded(quick, 1)
}

/// [`simstep_measure`] on a `Sim` split into `n_shards` partitions: the
/// same storm, same deterministic event count, the wall clock now
/// measuring the conservative-parallel executor.
fn simstep_measure_sharded(quick: bool, n_shards: usize) -> Json {
    use rdmavisor::fabric::time::Ns;
    use rdmavisor::workload::scenarios::event_storm_sharded;

    let (pairs, window, msg, sim_ms, reps) =
        if quick { (64, 8, 4096, 2, 2) } else { (256, 8, 4096, 10, 3) };
    let mut best_eps = 0.0f64;
    let mut events = 0u64;
    // best rep's wall: events is deterministic (identical every rep), so
    // events / wall_ms == events_per_sec — mutually consistent fields
    let mut best_wall = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        events = event_storm_sharded(pairs, window, msg, Ns::from_ms(sim_ms), n_shards);
        let w = t0.elapsed().as_secs_f64().max(1e-9);
        best_wall = best_wall.min(w);
        best_eps = best_eps.max(events as f64 / w);
    }
    eprintln!(
        "simstep: {pairs} QPs × window {window} × {msg} B for {sim_ms} sim-ms \
         (shards {n_shards}) -> {events} events, best {best_eps:.0} events/s"
    );
    obj(vec![
        ("pairs", Json::Num(pairs as f64)),
        ("window", Json::Num(window as f64)),
        ("msg_bytes", Json::Num(msg as f64)),
        ("sim_ms", Json::Num(sim_ms as f64)),
        ("shards", Json::Num(n_shards as f64)),
        ("events", Json::Num(events as f64)),
        ("events_per_sec", num(best_eps)),
        ("wall_ms", num(best_wall * 1e3)),
    ])
}

/// `bench simstep` — the scheduler-throughput perf trajectory future
/// scheduler changes regress against (see [`simstep_measure`]). With
/// `--shards N` the same storm is re-timed at shard counts {1, 2, N}
/// (deduped) and the sweep rides along as `shard_sweep` — the
/// events-per-sec scaling record for the conservative-parallel executor.
fn bench_simstep(args: &Args) {
    let quick = args.flag("quick") || std::env::var("RDMAVISOR_BENCH_QUICK").is_ok();
    let result = simstep_measure(quick);
    let mut pairs = vec![
        ("command", Json::Str("bench".into())),
        ("mode", Json::Str("simstep".into())),
        ("result", result),
    ];
    if args.get("shards").is_some() {
        let n = shards(args);
        let mut counts = vec![1usize, 2, n];
        counts.sort_unstable();
        counts.dedup();
        let sweep: Vec<Json> =
            counts.into_iter().map(|c| simstep_measure_sharded(quick, c)).collect();
        pairs.push(("shard_sweep", Json::Arr(sweep)));
    }
    let doc = obj(pairs);
    println!("{}", doc.to_string());
}

/// Measure daemon data-plane throughput: ops/sec through ONE daemon's
/// pump loop (Worker batch flush → Poller CQ drain → slab completion →
/// SRQ refill) on a closed-loop READ storm. This is the number the
/// wr_id-slab/dense-table densification moves; `bench simstep` isolates
/// the fabric below it. Shared by `bench pump` and the `pump` section of
/// `bench fig9`/BENCH_PR5.json.
fn pump_measure(quick: bool) -> Json {
    use rdmavisor::fabric::time::Ns;
    use rdmavisor::workload::scenarios::pump_storm;

    let (conns, window, msg, sim_ms, reps) =
        if quick { (128, 4, 4096, 2, 2) } else { (512, 4, 4096, 10, 3) };
    let mut best_ops = 0.0f64;
    let (mut ops, mut events) = (0u64, 0u64);
    // wall_ms is the BEST rep's wall (ops and events are deterministic,
    // identical every rep), so ops / wall_ms == ops_per_sec and the
    // artifact's fields stay mutually consistent
    let mut best_wall = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = pump_storm(conns, msg, window, Ns::from_ms(sim_ms));
        ops = r.0;
        events = r.1;
        let w = t0.elapsed().as_secs_f64().max(1e-9);
        best_wall = best_wall.min(w);
        best_ops = best_ops.max(ops as f64 / w);
    }
    eprintln!(
        "pump: {conns} conns × window {window} × {msg} B for {sim_ms} sim-ms -> \
         {ops} ops ({events} events), best {best_ops:.0} ops/s"
    );
    obj(vec![
        ("conns", Json::Num(conns as f64)),
        ("window", Json::Num(window as f64)),
        ("msg_bytes", Json::Num(msg as f64)),
        ("sim_ms", Json::Num(sim_ms as f64)),
        ("ops", Json::Num(ops as f64)),
        ("events", Json::Num(events as f64)),
        ("ops_per_sec", num(best_ops)),
        ("wall_ms", num(best_wall * 1e3)),
    ])
}

/// `bench pump` — the daemon-pump perf trajectory future data-plane
/// changes regress against (see [`pump_measure`]).
fn bench_pump(args: &Args) {
    let quick = args.flag("quick") || std::env::var("RDMAVISOR_BENCH_QUICK").is_ok();
    let result = pump_measure(quick);
    let doc = obj(vec![
        ("command", Json::Str("bench".into())),
        ("mode", Json::Str("pump".into())),
        ("result", result),
    ]);
    println!("{}", doc.to_string());
}

/// `bench fig9` — wall-clock of the Fig-9 scale sweep, per connection
/// count (adaptive + rc-only, exactly the runs `fig --id 9` makes).
/// Writes the result to `--out` (default BENCH_PR5.json) so CI archives
/// a perf trajectory for future PRs to regress against. `--jobs N` runs
/// the sweep points concurrently — total wall clock drops, but the
/// per-point wall numbers then measure *contended* time, so recorded
/// trajectories should stay at the serial default.
fn bench_fig9(args: &Args) {
    use rdmavisor::workload::scenarios::scale_send;

    let b = budget(args);
    let j = jobs(args);
    let n_shards = shards(args);
    let out_path = args.str_or("out", if n_shards > 1 { "BENCH_PR8.json" } else { "BENCH_PR5.json" });
    let t_all = Instant::now();
    let measured = parallel::map_indexed(figures::fig9_conns(b), j, |_, conns| {
        let t0 = Instant::now();
        let adaptive = scale_send(&figures::fig9_cfg(conns, b, false));
        let rc_only = scale_send(&figures::fig9_cfg(conns, b, true));
        let serial_wall = t0.elapsed().as_secs_f64();
        // same two runs again on the sharded executor: the wall ratio is
        // the per-point speedup, the rows feed the byte-identity check
        let sharded = (n_shards > 1).then(|| {
            let t1 = Instant::now();
            let mut a = figures::fig9_cfg(conns, b, false);
            a.shards = n_shards;
            let mut r = figures::fig9_cfg(conns, b, true);
            r.shards = n_shards;
            (scale_send(&a), scale_send(&r), t1.elapsed().as_secs_f64())
        });
        (conns, adaptive, rc_only, serial_wall, sharded)
    });
    let mut points = Vec::new();
    let mut total_wall = 0.0f64;
    let mut total_sharded_wall = 0.0f64;
    let mut total_events = 0u64;
    let mut serial_rows = Vec::new();
    let mut sharded_rows = Vec::new();
    for (conns, adaptive, rc_only, wall, sharded) in measured {
        let events = adaptive.events + rc_only.events;
        total_wall += wall;
        total_events += events;
        let eps = events as f64 / wall.max(1e-9);
        eprintln!(
            "fig9 conns={conns:>6}: {:>9} events in {:>8.1} ms  ({:>11.0} events/s)",
            events,
            wall * 1e3,
            eps
        );
        let mut point = vec![
            ("conns", Json::Num(conns as f64)),
            ("servers", Json::Num(adaptive.servers as f64)),
            ("wall_ms", num(wall * 1e3)),
            ("events", Json::Num(events as f64)),
            ("events_per_sec", num(eps)),
            ("adaptive_gbps", num(adaptive.gbps)),
            ("rc_only_gbps", num(rc_only.gbps)),
        ];
        serial_rows.push(figures::Fig9Row { conns, adaptive: Some(adaptive), rc_only });
        if let Some((sa, sr, swall)) = sharded {
            total_sharded_wall += swall;
            eprintln!(
                "fig9 conns={conns:>6}: sharded x{n_shards} {:>8.1} ms  (speedup {:.2}x)",
                swall * 1e3,
                wall / swall.max(1e-9)
            );
            point.push(("sharded_wall_ms", num(swall * 1e3)));
            point.push(("sharded_events_per_sec", num(events as f64 / swall.max(1e-9))));
            point.push(("speedup", num(wall / swall.max(1e-9))));
            sharded_rows.push(figures::Fig9Row { conns, adaptive: Some(sa), rc_only: sr });
        }
        points.push(obj(point));
    }
    // at --jobs 1 the sum of per-point walls IS the elapsed time; at
    // jobs > 1 report the overlapped elapsed wall instead
    if j > 1 {
        total_wall = t_all.elapsed().as_secs_f64();
    }
    let budget_name = if b == Budget::Quick { "quick" } else { "full" };
    let mut doc_pairs = vec![
        ("command", Json::Str("bench".into())),
        ("mode", Json::Str("fig9".into())),
        ("budget", Json::Str(budget_name.to_string())),
        ("jobs", Json::Num(j as f64)),
        ("shards", Json::Num(n_shards as f64)),
        ("points", Json::Arr(points)),
        ("total_wall_ms", num(total_wall * 1e3)),
        ("total_events", Json::Num(total_events as f64)),
        (
            "events_per_sec",
            num(total_events as f64 / total_wall.max(1e-9)),
        ),
    ];
    if n_shards > 1 {
        // the whole point of the sharded executor is that these bytes
        // cannot differ; record the check in the artifact
        let identical = figures::fig9_series(&serial_rows).to_json().to_string()
            == figures::fig9_series(&sharded_rows).to_json().to_string()
            && figures::print_fig9(&serial_rows) == figures::print_fig9(&sharded_rows);
        doc_pairs.push(("total_sharded_wall_ms", num(total_sharded_wall * 1e3)));
        doc_pairs.push((
            "sharded_events_per_sec",
            num(total_events as f64 / total_sharded_wall.max(1e-9)),
        ));
        doc_pairs.push(("identical_series", Json::Bool(identical)));
    }
    // the daemon-pump and raw scheduler throughputs ride along so the
    // artifact is one self-contained perf record (no external JSON
    // merging)
    doc_pairs.push(("pump", pump_measure(b == Budget::Quick)));
    doc_pairs.push(("simstep", simstep_measure(b == Budget::Quick)));
    let doc = obj(doc_pairs);
    let text = doc.to_string();
    match std::fs::write(&out_path, &text) {
        Ok(()) => eprintln!("wrote {out_path}"),
        Err(e) => eprintln!("write {out_path} failed: {e}"),
    }
    println!("{text}");
}

/// `bench kv` — wall-clock of the fig-11 KV sweep per client count
/// (one-sided window + SEND-RPC at the read-mostly mix, exactly the runs
/// `fig --id 11` makes). Writes the result to `--out` (default
/// BENCH_PR6.json) so CI archives a perf trajectory for the one-sided
/// window data plane. As with `bench fig9`, recorded trajectories should
/// stay at the serial `--jobs` default.
fn bench_kv(args: &Args) {
    use rdmavisor::workload::scenarios::kv_storm;

    let b = budget(args);
    let j = jobs(args);
    let out_path = args.str_or("out", "BENCH_PR6.json");
    let t_all = Instant::now();
    let measured = parallel::map_indexed(figures::fig11_clients(b), j, |_, clients| {
        let t0 = Instant::now();
        let one_sided = kv_storm(&figures::fig11_cfg(clients, b, false, false));
        let rpc = kv_storm(&figures::fig11_cfg(clients, b, true, false));
        (clients, one_sided, rpc, t0.elapsed().as_secs_f64())
    });
    let mut points = Vec::new();
    let mut total_wall = 0.0f64;
    let (mut total_ops, mut total_events) = (0u64, 0u64);
    for (clients, one_sided, rpc, wall) in measured {
        total_wall += wall;
        total_ops += one_sided.ops + rpc.ops;
        total_events += one_sided.events + rpc.events;
        eprintln!(
            "kv clients={clients:>5}: one-sided {:.3} Mops vs rpc {:.3} Mops  \
             ({:>8.1} ms wall)",
            one_sided.mops,
            rpc.mops,
            wall * 1e3
        );
        points.push(obj(vec![
            ("clients", Json::Num(clients as f64)),
            ("servers", Json::Num(one_sided.servers as f64)),
            ("wall_ms", num(wall * 1e3)),
            ("events", Json::Num((one_sided.events + rpc.events) as f64)),
            ("onesided_mops", num(one_sided.mops)),
            ("rpc_mops", num(rpc.mops)),
            ("onesided_p99_us", num(one_sided.p99_us)),
            ("rpc_p99_us", num(rpc.p99_us)),
            ("onesided_server_cpu", num(one_sided.server_cpu_cores)),
            ("rpc_server_cpu", num(rpc.server_cpu_cores)),
            ("writes_coalesced", Json::Num(one_sided.writes_coalesced as f64)),
        ]));
    }
    // at --jobs 1 the sum of per-point walls IS the elapsed time; at
    // jobs > 1 report the overlapped elapsed wall instead
    if j > 1 {
        total_wall = t_all.elapsed().as_secs_f64();
    }
    let budget_name = if b == Budget::Quick { "quick" } else { "full" };
    let doc = obj(vec![
        ("command", Json::Str("bench".into())),
        ("mode", Json::Str("kv".into())),
        ("budget", Json::Str(budget_name.to_string())),
        ("jobs", Json::Num(j as f64)),
        ("points", Json::Arr(points)),
        ("total_wall_ms", num(total_wall * 1e3)),
        ("total_events", Json::Num(total_events as f64)),
        ("total_ops", Json::Num(total_ops as f64)),
        ("ops_per_sec", num(total_ops as f64 / total_wall.max(1e-9))),
    ]);
    let text = doc.to_string();
    match std::fs::write(&out_path, &text) {
        Ok(()) => eprintln!("wrote {out_path}"),
        Err(e) => eprintln!("write {out_path} failed: {e}"),
    }
    println!("{text}");
}

/// `bench churn` — wall-clock of the fig-12 churn sweep per arrival
/// count (warm + cold, exactly the runs `fig --id 12` makes). Writes the
/// result to `--out` (default BENCH_PR7.json) so CI archives a perf
/// trajectory for the elastic control plane. As with `bench fig9`,
/// recorded trajectories should stay at the serial `--jobs` default.
fn bench_churn(args: &Args) {
    use rdmavisor::workload::scenarios::churn_storm;

    let b = budget(args);
    let j = jobs(args);
    let out_path = args.str_or("out", "BENCH_PR7.json");
    let t_all = Instant::now();
    let measured = parallel::map_indexed(figures::fig12_conns(b), j, |_, conns| {
        let t0 = Instant::now();
        let warm = churn_storm(&figures::fig12_cfg(conns, false));
        let cold = churn_storm(&figures::fig12_cfg(conns, true));
        (conns, warm, cold, t0.elapsed().as_secs_f64())
    });
    let mut points = Vec::new();
    let mut total_wall = 0.0f64;
    let (mut total_conns, mut total_events) = (0u64, 0u64);
    for (conns, warm, cold, wall) in measured {
        total_wall += wall;
        total_conns += 2 * conns as u64;
        total_events += warm.events + cold.events;
        eprintln!(
            "churn conns={conns:>8}: warm {:.1} kcps vs cold {:.1} kcps, \
             {:.0} B/vqpn  ({:>8.1} ms wall)",
            warm.setup_kcps,
            cold.setup_kcps,
            warm.mem_per_vqpn,
            wall * 1e3
        );
        points.push(obj(vec![
            ("conns", Json::Num(conns as f64)),
            ("hosts", Json::Num(warm.hosts as f64)),
            ("servers", Json::Num(warm.servers as f64)),
            ("wall_ms", num(wall * 1e3)),
            ("events", Json::Num((warm.events + cold.events) as f64)),
            ("warm_setup_kcps", num(warm.setup_kcps)),
            ("cold_setup_kcps", num(cold.setup_kcps)),
            ("warm_p99_ttfb_us", num(warm.p99_ttfb_us)),
            ("cold_p99_ttfb_us", num(cold.p99_ttfb_us)),
            ("warm_mem_per_vqpn", num(warm.mem_per_vqpn)),
            ("cold_mem_per_vqpn", num(cold.mem_per_vqpn)),
            ("qp_reused", Json::Num(warm.qp_reused as f64)),
            ("handshakes_full", Json::Num(warm.handshakes_full as f64)),
            ("lease_batches", Json::Num(warm.lease_batches as f64)),
            ("live_vqpns", Json::Num(warm.live_vqpns as f64)),
        ]));
    }
    // at --jobs 1 the sum of per-point walls IS the elapsed time; at
    // jobs > 1 report the overlapped elapsed wall instead
    if j > 1 {
        total_wall = t_all.elapsed().as_secs_f64();
    }
    let budget_name = if b == Budget::Quick { "quick" } else { "full" };
    let doc = obj(vec![
        ("command", Json::Str("bench".into())),
        ("mode", Json::Str("churn".into())),
        ("budget", Json::Str(budget_name.to_string())),
        ("jobs", Json::Num(j as f64)),
        ("points", Json::Arr(points)),
        ("total_wall_ms", num(total_wall * 1e3)),
        ("total_events", Json::Num(total_events as f64)),
        ("total_conns", Json::Num(total_conns as f64)),
        ("conns_per_sec", num(total_conns as f64 / total_wall.max(1e-9))),
    ]);
    let text = doc.to_string();
    match std::fs::write(&out_path, &text) {
        Ok(()) => eprintln!("wrote {out_path}"),
        Err(e) => eprintln!("write {out_path} failed: {e}"),
    }
    println!("{text}");
}

/// `bench incast` — wall-clock of the fig-13 Clos incast sweep per
/// oversubscription factor (DCQCN + no-CC + PFC, exactly the runs `fig
/// --id 13` makes). Writes the result to `--out` (default
/// BENCH_PR9.json) so CI archives a perf trajectory for the congested
/// fabric. Recorded trajectories should stay at the serial `--jobs`
/// default.
fn bench_incast(args: &Args) {
    use rdmavisor::fabric::topo::CcMode;
    use rdmavisor::workload::scenarios::incast_storm;

    let b = budget(args);
    let j = jobs(args);
    let out_path = args.str_or("out", "BENCH_PR9.json");
    let t_all = Instant::now();
    let measured = parallel::map_indexed(figures::fig13_oversubs(b), j, |_, oversub| {
        let t0 = Instant::now();
        let dcqcn = incast_storm(&figures::fig13_cfg(oversub, b, CcMode::Dcqcn));
        let no_cc = incast_storm(&figures::fig13_cfg(oversub, b, CcMode::NoCc));
        let pfc = incast_storm(&figures::fig13_cfg(oversub, b, CcMode::Pfc));
        (oversub, dcqcn, no_cc, pfc, t0.elapsed().as_secs_f64())
    });
    let mut points = Vec::new();
    let mut total_wall = 0.0f64;
    let mut total_events = 0u64;
    for (oversub, dcqcn, no_cc, pfc, wall) in measured {
        total_wall += wall;
        total_events += dcqcn.events + no_cc.events + pfc.events;
        eprintln!(
            "incast oversub={oversub}: dcqcn {:.2} Gb/s vs no-cc {:.2} Gb/s vs pfc {:.2} Gb/s, \
             {} marks / {} drops  ({:>8.1} ms wall)",
            dcqcn.goodput_gbps,
            no_cc.goodput_gbps,
            pfc.goodput_gbps,
            dcqcn.ecn_marks,
            no_cc.switch_drops,
            wall * 1e3
        );
        points.push(obj(vec![
            ("oversub", Json::Num(oversub as f64)),
            ("wall_ms", num(wall * 1e3)),
            ("events", Json::Num((dcqcn.events + no_cc.events + pfc.events) as f64)),
            ("dcqcn_goodput_gbps", num(dcqcn.goodput_gbps)),
            ("nocc_goodput_gbps", num(no_cc.goodput_gbps)),
            ("pfc_goodput_gbps", num(pfc.goodput_gbps)),
            ("dcqcn_p99_fct_us", num(dcqcn.p99_fct_us)),
            ("nocc_p99_fct_us", num(no_cc.p99_fct_us)),
            ("pfc_p99_fct_us", num(pfc.p99_fct_us)),
            ("ecn_marks", Json::Num(dcqcn.ecn_marks as f64)),
            ("switch_drops", Json::Num(no_cc.switch_drops as f64)),
            ("pauses", Json::Num(pfc.pauses as f64)),
            ("retransmits", Json::Num(no_cc.retransmits as f64)),
        ]));
    }
    if j > 1 {
        total_wall = t_all.elapsed().as_secs_f64();
    }
    let budget_name = if b == Budget::Quick { "quick" } else { "full" };
    let doc = obj(vec![
        ("command", Json::Str("bench".into())),
        ("mode", Json::Str("incast".into())),
        ("budget", Json::Str(budget_name.to_string())),
        ("jobs", Json::Num(j as f64)),
        ("points", Json::Arr(points)),
        ("total_wall_ms", num(total_wall * 1e3)),
        ("total_events", Json::Num(total_events as f64)),
        ("events_per_sec", num(total_events as f64 / total_wall.max(1e-9))),
    ]);
    let text = doc.to_string();
    match std::fs::write(&out_path, &text) {
        Ok(()) => eprintln!("wrote {out_path}"),
        Err(e) => eprintln!("write {out_path} failed: {e}"),
    }
    println!("{text}");
}

/// `bench failover` — wall-clock of the fig-14 failover storm (repath-on
/// vs repath-off, exactly the runs `fig --id 14` makes). Writes the
/// result to `--out` (default BENCH_PR10.json) so CI archives a perf
/// trajectory for the survivable fabric. With `--shards N` both modes
/// also execute sharded and the fig-14 series is byte-compared against
/// serial (`identical_series`). Recorded trajectories should stay at the
/// serial `--jobs` default.
fn bench_failover(args: &Args) {
    use rdmavisor::workload::scenarios::failover_storm;

    let b = budget(args);
    let j = jobs(args);
    let n_shards = shards(args);
    let out_path = args.str_or("out", "BENCH_PR10.json");
    let t_all = Instant::now();
    let measured = parallel::map_indexed(vec![true, false], j, |_, repath| {
        let t0 = Instant::now();
        let run = failover_storm(&figures::fig14_cfg(b, repath));
        let serial_wall = t0.elapsed().as_secs_f64();
        // the same run on the sharded executor: the wall ratio is the
        // speedup, the rows feed the byte-identity check
        let sharded = (n_shards > 1).then(|| {
            let t1 = Instant::now();
            let mut cfg = figures::fig14_cfg(b, repath);
            cfg.shards = n_shards;
            (failover_storm(&cfg), t1.elapsed().as_secs_f64())
        });
        (repath, run, serial_wall, sharded)
    });
    let mut points = Vec::new();
    let mut total_wall = 0.0f64;
    let mut total_sharded_wall = 0.0f64;
    let mut total_events = 0u64;
    let mut serial_row = figures::Fig14Row { repath: None, no_repath: None };
    let mut sharded_row = figures::Fig14Row { repath: None, no_repath: None };
    for (repath, run, wall, sharded) in measured {
        total_wall += wall;
        total_events += run.events;
        let mode = if repath { "repath" } else { "no-repath" };
        eprintln!(
            "failover {mode:>9}: pre {:.2} -> dip {:.2} -> post {:.2} Gb/s, \
             {} repaths / {} heals / {} retry-exceeded  ({:>8.1} ms wall)",
            run.pre_gbps,
            run.dip_gbps,
            run.post_gbps,
            run.repaths,
            run.qp_reestablished,
            run.retry_exceeded,
            wall * 1e3
        );
        let mut point = vec![
            ("mode", Json::Str(mode.to_string())),
            ("wall_ms", num(wall * 1e3)),
            ("events", Json::Num(run.events as f64)),
            ("events_per_sec", num(run.events as f64 / wall.max(1e-9))),
            ("pre_gbps", num(run.pre_gbps)),
            ("dip_gbps", num(run.dip_gbps)),
            ("post_gbps", num(run.post_gbps)),
            ("p99_fct_us", num(run.p99_fct_us)),
            ("repaths", Json::Num(run.repaths as f64)),
            ("route_epoch", Json::Num(run.route_epoch as f64)),
            ("qp_reestablished", Json::Num(run.qp_reestablished as f64)),
            ("heal_giveups", Json::Num(run.heal_giveups as f64)),
            ("retry_exceeded", Json::Num(run.retry_exceeded as f64)),
            ("retransmits", Json::Num(run.retransmits as f64)),
            ("blackhole_drops", Json::Num(run.blackhole_drops as f64)),
            ("flows_alive", Json::Num(run.flows_alive as f64)),
        ];
        if let Some((srun, swall)) = sharded {
            total_sharded_wall += swall;
            eprintln!(
                "failover {mode:>9}: sharded x{n_shards} {:>8.1} ms  (speedup {:.2}x)",
                swall * 1e3,
                wall / swall.max(1e-9)
            );
            point.push(("sharded_wall_ms", num(swall * 1e3)));
            point.push(("speedup", num(wall / swall.max(1e-9))));
            if repath {
                sharded_row.repath = Some(srun);
            } else {
                sharded_row.no_repath = Some(srun);
            }
        }
        if repath {
            serial_row.repath = Some(run);
        } else {
            serial_row.no_repath = Some(run);
        }
        points.push(obj(point));
    }
    if j > 1 {
        total_wall = t_all.elapsed().as_secs_f64();
    }
    let budget_name = if b == Budget::Quick { "quick" } else { "full" };
    let mut doc_pairs = vec![
        ("command", Json::Str("bench".into())),
        ("mode", Json::Str("failover".into())),
        ("budget", Json::Str(budget_name.to_string())),
        ("jobs", Json::Num(j as f64)),
        ("shards", Json::Num(n_shards as f64)),
        ("points", Json::Arr(points)),
        ("total_wall_ms", num(total_wall * 1e3)),
        ("total_events", Json::Num(total_events as f64)),
        ("events_per_sec", num(total_events as f64 / total_wall.max(1e-9))),
    ];
    if n_shards > 1 {
        // the sharded executor's whole contract is that these bytes
        // cannot differ; record the check in the artifact
        let serial_rows = vec![serial_row];
        let sharded_rows = vec![sharded_row];
        let identical = figures::fig14_series(&serial_rows).to_json().to_string()
            == figures::fig14_series(&sharded_rows).to_json().to_string()
            && figures::print_fig14(&serial_rows) == figures::print_fig14(&sharded_rows);
        doc_pairs.push(("total_sharded_wall_ms", num(total_sharded_wall * 1e3)));
        doc_pairs.push(("identical_series", Json::Bool(identical)));
    }
    let doc = obj(doc_pairs);
    let text = doc.to_string();
    match std::fs::write(&out_path, &text) {
        Ok(()) => eprintln!("wrote {out_path}"),
        Err(e) => eprintln!("write {out_path} failed: {e}"),
    }
    println!("{text}");
}

// ------------------------------------------------------------------ `demo`

fn demo_cmd(args: &Args) {
    match args.positional.first().map(|s| s.as_str()) {
        Some("kv") => demo_kv(args),
        Some("rpc") => demo_rpc(args),
        Some("inference") => demo_inference(args),
        _ => {
            eprintln!("usage: rdmavisor demo kv|rpc|inference");
            std::process::exit(2);
        }
    }
}

fn two_node_cluster() -> (rdmavisor::fabric::sim::Sim, Vec<rdmavisor::raas::daemon::Daemon>) {
    use rdmavisor::fabric::sim::{FabricConfig, Sim};
    use rdmavisor::fabric::types::NodeId;
    use rdmavisor::raas::daemon::{Daemon, DaemonConfig};
    let mut fcfg = FabricConfig::default();
    fcfg.nodes = 2;
    fcfg.sq_depth = 8192;
    let mut sim = Sim::new(fcfg);
    let daemons = (0..2)
        .map(|i| Daemon::start(&mut sim, NodeId(i), DaemonConfig::default()))
        .collect();
    (sim, daemons)
}

fn demo_kv(args: &Args) {
    use rdmavisor::apps::kv::{KvClient, KvLayout, KvMode, KvServer};
    use rdmavisor::raas::daemon::{connect_via, Delivery};

    let gets = args.u64_or("gets", 512);
    let put_rounds = args.u64_or("puts", 16);
    let seed = args.u64_or("seed", 7);
    let mode = if args.flag("rpc") { KvMode::Rpc } else { KvMode::OneSided };
    let t0 = Instant::now();

    let (mut sim, mut daemons) = two_node_cluster();
    let layout = KvLayout { slots: 4096, slot_bytes: 1024 };
    let mut server = KvServer::new(&mut daemons[1], 6000, layout, mode, seed ^ 1);
    let capp = daemons[0].register_app();
    let conn = connect_via(&mut sim, &mut daemons, 0, capp, 1, 6000).unwrap();
    let mut client = KvClient::new(capp, conn, layout, seed, 0.99, mode, 95, 4);
    client.register(&mut sim, &mut daemons[0]).expect("register window");

    for _ in 0..gets {
        client.get(&mut sim, &mut daemons[0]).expect("kv get");
    }
    for _ in 0..put_rounds {
        client.put(&mut sim, &mut daemons[0]).expect("kv put");
    }
    // drive: RPC mode needs server service turns interleaved (one-sided
    // mode leaves the server idle — that is the point)
    for _ in 0..2_000_000 {
        daemons[0].pump(&mut sim);
        daemons[1].pump(&mut sim);
        server.service(&mut sim, &mut daemons[1]);
        daemons[1].pump(&mut sim);
        if sim.step().is_none() {
            daemons[0].pump(&mut sim);
            daemons[1].pump(&mut sim);
            server.service(&mut sim, &mut daemons[1]);
            daemons[1].pump(&mut sim);
            if sim.pending_events() == 0 {
                break;
            }
        }
    }
    let mut completed = 0u64;
    while let Some(d) = daemons[0].recv_zero_copy(&mut sim, capp) {
        if matches!(d, Delivery::OpComplete { .. }) {
            completed += 1;
        }
        let _ = client.on_delivery(&d);
    }

    let sim_s = sim.now().as_secs_f64();
    let mode_name = if mode == KvMode::Rpc { "rpc" } else { "one-sided" };
    let doc = obj(vec![
        ("command", Json::Str("demo".into())),
        ("app", Json::Str("kv".into())),
        ("mode", Json::Str(mode_name.into())),
        ("gets_issued", Json::Num(client.gets_issued as f64)),
        ("puts_issued", Json::Num(client.puts_issued as f64)),
        ("ops_completed", Json::Num(completed as f64)),
        ("gets_served", Json::Num(server.gets_served as f64)),
        ("puts_applied", Json::Num(server.puts_applied as f64)),
        ("window_flushes", Json::Num(daemons[0].stats.window_flushes as f64)),
        ("writes_coalesced", Json::Num(daemons[0].stats.writes_coalesced as f64)),
        ("sim_ms", num(sim_s * 1e3)),
        (
            "mops",
            num(if sim_s > 0.0 { completed as f64 / sim_s / 1e6 } else { 0.0 }),
        ),
        ("wall_ms", num(t0.elapsed().as_secs_f64() * 1e3)),
    ]);
    println!("{}", doc.to_string());
}

fn demo_rpc(args: &Args) {
    use rdmavisor::apps::rpc::{RpcClient, RpcServer};
    use rdmavisor::raas::daemon::connect_via;

    let calls = args.u64_or("calls", 256);
    let req_bytes = args.u64_or("req-bytes", 128);
    let resp_bytes = args.u64_or("resp-bytes", 256);
    let t0 = Instant::now();

    let (mut sim, mut daemons) = two_node_cluster();
    let mut server = RpcServer::new(&mut daemons[1], 5000, resp_bytes);
    let capp = daemons[0].register_app();
    let conn = connect_via(&mut sim, &mut daemons, 0, capp, 1, 5000).unwrap();
    let mut client = RpcClient::new(capp, conn, req_bytes);

    for _ in 0..calls {
        client.call(&mut sim, &mut daemons[0]).expect("rpc call");
    }
    // drive: the server must get service() turns to reply
    for _ in 0..2_000_000 {
        daemons[0].pump(&mut sim);
        server.service(&mut sim, &mut daemons[1]).expect("rpc service");
        daemons[1].pump(&mut sim);
        if sim.step().is_none() {
            daemons[0].pump(&mut sim);
            server.service(&mut sim, &mut daemons[1]).expect("rpc service");
            daemons[1].pump(&mut sim);
            if sim.pending_events() == 0 {
                break;
            }
        }
    }
    client.drain(&mut sim, &mut daemons[0]);

    let sim_s = sim.now().as_secs_f64();
    let doc = obj(vec![
        ("command", Json::Str("demo".into())),
        ("app", Json::Str("rpc".into())),
        ("calls", Json::Num(client.sent as f64)),
        ("served", Json::Num(server.served as f64)),
        ("responses", Json::Num(client.responses as f64)),
        ("sim_ms", num(sim_s * 1e3)),
        (
            "krps",
            num(if sim_s > 0.0 { client.responses as f64 / sim_s / 1e3 } else { 0.0 }),
        ),
        ("wall_ms", num(t0.elapsed().as_secs_f64() * 1e3)),
    ]);
    println!("{}", doc.to_string());
}

/// Wall-clock serving stats shared by `serve` and `demo inference`.
struct ServeRun {
    done: u64,
    wall_s: f64,
    p50_us: u64,
    p99_us: u64,
    mean_batch: f64,
    model_ms: f64,
}

fn run_serving(artifacts: &str, clients: usize, requests_per_client: u64) -> ServeRun {
    use rdmavisor::apps::inference::InferenceEngine;

    let engine = InferenceEngine::new(artifacts, clients, 1024);
    let server = {
        let engine = engine.clone();
        std::thread::spawn(move || engine.serve_loop())
    };

    let t0 = Instant::now();
    let mut latencies = Vec::new();
    let mut outstanding: Vec<Vec<(u64, Instant)>> = vec![Vec::new(); clients];
    let mut done = 0u64;
    let mut next_tag = 0u64;
    let total = requests_per_client * clients as u64;
    while done < total {
        for c in 0..clients {
            if outstanding[c].len() < 4 && next_tag < total && engine.submit(c, next_tag) {
                outstanding[c].push((next_tag, Instant::now()));
                next_tag += 1;
            }
            for tag in engine.reap(c) {
                if let Some(pos) = outstanding[c].iter().position(|(t, _)| *t == tag) {
                    let (_, t) = outstanding[c].remove(pos);
                    latencies.push(t.elapsed().as_micros() as u64);
                    done += 1;
                }
            }
        }
    }
    let wall = t0.elapsed();
    engine.stop();
    let _ = server.join();

    latencies.sort_unstable();
    let p = |q: f64| {
        if latencies.is_empty() {
            0
        } else {
            latencies[((latencies.len() - 1) as f64 * q) as usize]
        }
    };
    let st = engine.stats.lock().unwrap();
    ServeRun {
        done,
        wall_s: wall.as_secs_f64(),
        p50_us: p(0.5),
        p99_us: p(0.99),
        mean_batch: st.mean_batch(),
        model_ms: st.model_ns as f64 / 1e6,
    }
}

fn demo_inference(args: &Args) {
    let clients = args.usize_or("clients", 2);
    let requests = args.u64_or("requests", 64);
    let artifacts = args.str_or("artifacts", "artifacts");
    let r = run_serving(&artifacts, clients, requests);
    let doc = obj(vec![
        ("command", Json::Str("demo".into())),
        ("app", Json::Str("inference".into())),
        ("clients", Json::Num(clients as f64)),
        ("requests", Json::Num(r.done as f64)),
        ("rps", num(r.done as f64 / r.wall_s.max(1e-9))),
        ("p50_us", Json::Num(r.p50_us as f64)),
        ("p99_us", Json::Num(r.p99_us as f64)),
        ("mean_batch", num(r.mean_batch)),
        ("model_ms", num(r.model_ms)),
    ]);
    println!("{}", doc.to_string());
}

// ----------------------------------------------------------------- `serve`

fn serve_cmd(args: &Args) {
    let dir = args.str_or("artifacts", "artifacts");
    let clients = args.usize_or("clients", 4);
    let requests = args.u64_or("requests", 64);

    let manifest = rdmavisor::runtime::Manifest::load_or_synthetic(&dir);
    println!(
        "variants={:?}",
        manifest.variants.iter().map(|v| v.name.clone()).collect::<Vec<_>>()
    );
    let r = run_serving(&dir, clients, requests);
    println!(
        "served {} requests in {:.2}s: {:.0} req/s, p50={}µs p99={}µs, \
         mean batch={:.2}, model time {:.1}ms total",
        r.done,
        r.wall_s,
        r.done as f64 / r.wall_s.max(1e-9),
        r.p50_us,
        r.p99_us,
        r.mean_batch,
        r.model_ms
    );
}

// ------------------------------------------------------------------ `info`

fn info_cmd() {
    let f = figures::default_fabric();
    println!(
        "fabric: {} nodes × {} cores, {} Gb/s, MTU {}",
        f.nodes, f.cores_per_node, f.link_gbps, f.mtu
    );
    println!(
        "nic: icm_cache={} entries, miss={}ns, frame={}ns",
        f.nic.icm_cache_entries, f.nic.icm_miss_ns, f.nic.engine_frame_ns
    );
    match rdmavisor::runtime::Manifest::load("artifacts") {
        Ok(m) => println!("artifacts: {} variants (seed {})", m.variants.len(), m.seed),
        Err(e) => println!(
            "artifacts: not built ({e}); the simulated executor will use the \
             built-in synthetic manifest"
        ),
    }
}
