//! Deterministic fault injection: lossy links, burst loss, delay jitter,
//! link flaps, node restarts.
//!
//! The simulated fabric is lossless by construction ([`super::switchfab`]),
//! which leaves RDMA's hardest operational edges — lost frames, RC retry
//! exhaustion, UD silent drops tearing holes in reassembly — untested and
//! unreachable. A [`FaultConfig`] describes a *seeded* fault plan; the
//! simulator compiles it into a [`FaultState`] it consults once per frame
//! at delivery time (the moment the frame would be handed to the
//! destination NIC). All randomness comes from a dedicated xoshiro stream
//! forked off the plan seed, and every draw happens at a point whose order
//! is fixed by the (deterministic) event timeline — so identical seeds
//! replay identical fault timelines, bit for bit.
//!
//! ### The null-plan identity
//!
//! A plan with zero drop probability, zero jitter, no flaps and no
//! restarts is **null**: `Sim::install_faults` (see [`super::sim::Sim`])
//! refuses to install it, no RNG is ever created, no retransmission timer
//! is ever armed, and the simulator is byte-identical to one that never
//! heard of this module. `fig --id 10` at loss 0 rides this path — that
//! is the determinism gate's loss-0 clause.
//!
//! ### What each fault means
//!
//! * **iid drop** (`drop_p`) — the frame is discarded at the destination
//!   port (transmitted, then lost in the switch/wire; egress and ingress
//!   serialization already happened, which is what real loss looks like
//!   to the sender's pacing).
//! * **burst loss** (`burst_p`, `burst_len`) — an iid drop escalates into
//!   an episode: the next `burst_len`-drawn frames on that *link* are
//!   dropped too (correlated loss, the pattern that defeats naive
//!   single-retry schemes).
//! * **delay jitter** (`jitter_p`, `jitter_ns`) — the frame is held for a
//!   drawn extra delay and re-delivered (switch queueing excursions; can
//!   reorder frames, which the RC go-back-N discipline and the UD
//!   reassembler's gap-discard both have to survive).
//! * **link flap** ([`Flap`]) — a directed link drops *everything* inside
//!   a time window (cable pull / LAG rebalance). Flap windows outlasting
//!   the RC retry budget are how `RetryExceeded` completions are
//!   reliably produced.
//! * **node restart** (`restarts`) — at the given instant the node's NIC
//!   soft-restarts: engine queue, SQ/RQ/SRQ/CQ contents and in-flight
//!   requester state vanish (connection state survives — the daemon is
//!   assumed to re-establish its QPs out of band). Posted work that died
//!   silently is exactly what the daemon's stale-lease reclaim exists for.

use std::collections::HashMap;

use crate::util::rng::Rng;

use super::time::Ns;
use super::types::NodeId;

/// One directed link-down window: every frame from `src` to `dst` with a
/// delivery time in `[from, until)` is dropped.
#[derive(Clone, Copy, Debug)]
pub struct Flap {
    /// Transmitting node of the affected direction.
    pub src: NodeId,
    /// Receiving node of the affected direction.
    pub dst: NodeId,
    /// Window start (inclusive).
    pub from: Ns,
    /// Window end (exclusive).
    pub until: Ns,
}

/// A seeded fault plan. See the module docs for the semantics of each
/// knob; `..Default::default()` gives an all-zero (null) plan to build on.
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Seed of the fault layer's private RNG stream (split off the
    /// scenario seed by the caller so workload draws and fault draws
    /// never interleave).
    pub seed: u64,
    /// Per-frame iid drop probability at delivery.
    pub drop_p: f64,
    /// Probability that an iid drop starts a burst episode on its link.
    pub burst_p: f64,
    /// Burst episode length range `[lo, hi]`, in frames, drawn uniformly.
    pub burst_len: (u32, u32),
    /// Per-frame probability of extra delivery delay.
    pub jitter_p: f64,
    /// Extra delay range `[lo, hi]` ns, drawn uniformly.
    pub jitter_ns: (u64, u64),
    /// Directed link-down windows.
    pub flaps: Vec<Flap>,
    /// Node soft-restart instants: `(node id, virtual time ns)`.
    pub restarts: Vec<(u32, u64)>,
    /// Permanent ToR-uplink port deaths: `(tor, uplink, at ns)`. Applied
    /// coordinator-side at the conservative barrier (switch state is
    /// barrier-owned), so the timeline is byte-identical at every shard
    /// count. Requires a Clos topology (`FabricConfig::topo`).
    pub uplink_deaths: Vec<(u32, u32, u64)>,
    /// Whole-spine-switch failure windows: `(spine, from ns, until ns)`.
    /// Uplink `s` of every ToR dies for the window, then revives.
    pub spine_windows: Vec<(u32, u64, u64)>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            drop_p: 0.0,
            burst_p: 0.0,
            burst_len: (2, 8),
            jitter_p: 0.0,
            jitter_ns: (200, 2000),
            flaps: Vec::new(),
            restarts: Vec::new(),
            uplink_deaths: Vec::new(),
            spine_windows: Vec::new(),
        }
    }
}

impl FaultConfig {
    /// True when this plan can never perturb the timeline: installing it
    /// is a no-op and the simulator stays byte-identical to the lossless
    /// build (the loss-0 determinism clause).
    pub fn is_null(&self) -> bool {
        self.drop_p <= 0.0
            && self.jitter_p <= 0.0
            && self.flaps.is_empty()
            && self.restarts.is_empty()
            && self.uplink_deaths.is_empty()
            && self.spine_windows.is_empty()
    }
}

/// What the fault layer decided for one frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Discard the frame (it was transmitted; it never arrives).
    Drop,
    /// Hold the frame for this extra delay, then deliver it.
    Delay(Ns),
}

/// Aggregate fault counters (diagnostics + the fig-10 row).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultStats {
    /// Frames discarded, all causes.
    pub frames_dropped: u64,
    /// Of which: iid draws.
    pub drops_iid: u64,
    /// Of which: burst-episode continuations.
    pub drops_burst: u64,
    /// Of which: link-flap windows.
    pub drops_flap: u64,
    /// Frames held back by delay jitter.
    pub frames_delayed: u64,
    /// Node soft-restarts executed.
    pub restarts: u64,
}

impl FaultStats {
    /// Fold another counter block into this one. The sharded simulator
    /// keeps one [`FaultState`] per destination node and sums the forks
    /// (in node order) when asked for plan-wide totals.
    pub fn absorb(&mut self, other: &FaultStats) {
        self.frames_dropped += other.frames_dropped;
        self.drops_iid += other.drops_iid;
        self.drops_burst += other.drops_burst;
        self.drops_flap += other.drops_flap;
        self.frames_delayed += other.frames_delayed;
        self.restarts += other.restarts;
    }
}

/// The compiled, running fault plan. Owned by the simulator; consulted
/// once per frame at delivery time.
#[derive(Clone, Debug)]
pub struct FaultState {
    cfg: FaultConfig,
    rng: Rng,
    /// Remaining forced drops per directed link `(src, dst)` — the live
    /// burst episodes. Keyed access only (no iteration), so the map's
    /// order can never leak into the timeline.
    burst_left: HashMap<(u32, u32), u32>,
    /// Counters.
    pub stats: FaultStats,
}

impl FaultState {
    /// Compile a (non-null) plan. The RNG is forked from the plan seed
    /// through a domain constant so a scenario reusing its workload seed
    /// still gets an independent stream.
    pub fn new(cfg: FaultConfig) -> Self {
        let rng = Rng::new(cfg.seed ^ 0xFA11_7EC7_0000_0001);
        FaultState { cfg, rng, burst_left: HashMap::new(), stats: FaultStats::default() }
    }

    /// Compile the per-destination-node fork of a (non-null) plan. The
    /// sharded simulator consults faults where frames *land*, so each
    /// destination node owns an independent RNG stream forked off the
    /// plan seed and its node id — the draw sequence a node sees then
    /// depends only on the frames delivered *to that node*, which the
    /// conservative barriers order identically under every shard count.
    /// (This re-keys the fault timeline relative to the old single-stream
    /// simulator — a deliberate re-baseline; see DESIGN.md §13.)
    pub fn for_node(cfg: &FaultConfig, node: NodeId) -> Self {
        let lane = (node.0 as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let rng = Rng::new(cfg.seed ^ 0xFA11_7EC7_0000_0001 ^ lane);
        FaultState { cfg: cfg.clone(), rng, burst_left: HashMap::new(), stats: FaultStats::default() }
    }

    /// The plan this state was compiled from.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Link-flap check alone — no RNG involved, so it is also re-applied
    /// to jitter-*redelivered* frames (whose probabilistic draws already
    /// happened): a flap window is a property of the link at the moment
    /// of delivery, and a delayed frame landing inside one must die too.
    pub fn flap_drop(&mut self, now: Ns, src: NodeId, dst: NodeId) -> bool {
        for f in &self.cfg.flaps {
            if f.src == src && f.dst == dst && now >= f.from && now < f.until {
                self.stats.frames_dropped += 1;
                self.stats.drops_flap += 1;
                return true;
            }
        }
        false
    }

    /// Decide the fate of one frame delivered on `src → dst` at `now`.
    /// `None` means deliver normally. Draw order per frame is fixed
    /// (flap check → burst check → drop draw → jitter draw), so the
    /// stream stays aligned across replays.
    pub fn action(&mut self, now: Ns, src: NodeId, dst: NodeId) -> Option<FaultAction> {
        // 1. link-flap windows: no RNG involved
        if self.flap_drop(now, src, dst) {
            return Some(FaultAction::Drop);
        }
        // 2. live burst episode on this link
        let link = (src.0, dst.0);
        if let Some(left) = self.burst_left.get_mut(&link) {
            *left -= 1;
            if *left == 0 {
                self.burst_left.remove(&link);
            }
            self.stats.frames_dropped += 1;
            self.stats.drops_burst += 1;
            return Some(FaultAction::Drop);
        }
        // 3. iid drop, possibly escalating into a burst
        if self.cfg.drop_p > 0.0 && self.rng.chance(self.cfg.drop_p) {
            if self.cfg.burst_p > 0.0 && self.rng.chance(self.cfg.burst_p) {
                let (lo, hi) = self.cfg.burst_len;
                let len = lo + self.rng.gen_range((hi - lo + 1) as u64) as u32;
                if len > 0 {
                    self.burst_left.insert(link, len);
                }
            }
            self.stats.frames_dropped += 1;
            self.stats.drops_iid += 1;
            return Some(FaultAction::Drop);
        }
        // 4. delay jitter
        if self.cfg.jitter_p > 0.0 && self.rng.chance(self.cfg.jitter_p) {
            let (lo, hi) = self.cfg.jitter_ns;
            let extra = lo + self.rng.gen_range(hi.saturating_sub(lo).max(1));
            self.stats.frames_delayed += 1;
            return Some(FaultAction::Delay(Ns(extra)));
        }
        None
    }

    /// Record an executed node restart (the simulator performs the actual
    /// state clearing; this keeps the tally in one place).
    pub fn note_restart(&mut self) {
        self.stats.restarts += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lossy(seed: u64, p: f64) -> FaultState {
        FaultState::new(FaultConfig { seed, drop_p: p, ..FaultConfig::default() })
    }

    #[test]
    fn null_plan_detection() {
        assert!(FaultConfig::default().is_null());
        assert!(!FaultConfig { drop_p: 0.01, ..FaultConfig::default() }.is_null());
        assert!(!FaultConfig { jitter_p: 0.5, ..FaultConfig::default() }.is_null());
        let f = Flap { src: NodeId(0), dst: NodeId(1), from: Ns(0), until: Ns(1) };
        assert!(!FaultConfig { flaps: vec![f], ..FaultConfig::default() }.is_null());
        assert!(!FaultConfig { restarts: vec![(0, 5)], ..FaultConfig::default() }.is_null());
        // burst knobs alone never fire without a drop probability
        assert!(FaultConfig { burst_p: 1.0, ..FaultConfig::default() }.is_null());
        // switch-level events are real faults too
        assert!(
            !FaultConfig { uplink_deaths: vec![(0, 1, 100)], ..FaultConfig::default() }.is_null()
        );
        assert!(
            !FaultConfig { spine_windows: vec![(0, 100, 200)], ..FaultConfig::default() }.is_null()
        );
    }

    #[test]
    fn same_seed_same_fault_timeline() {
        let mut a = lossy(7, 0.3);
        let mut b = lossy(7, 0.3);
        for i in 0..10_000u64 {
            let t = Ns(i * 100);
            assert_eq!(
                a.action(t, NodeId(0), NodeId(1)),
                b.action(t, NodeId(0), NodeId(1)),
                "diverged at frame {i}"
            );
        }
        assert_eq!(a.stats.frames_dropped, b.stats.frames_dropped);
    }

    #[test]
    fn drop_rate_tracks_probability() {
        let mut s = lossy(3, 0.1);
        let n = 50_000u64;
        for i in 0..n {
            let _ = s.action(Ns(i), NodeId(0), NodeId(1));
        }
        let rate = s.stats.frames_dropped as f64 / n as f64;
        assert!((rate - 0.1).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn flap_window_drops_only_its_link_and_time() {
        let mut s = FaultState::new(FaultConfig {
            seed: 1,
            flaps: vec![Flap { src: NodeId(0), dst: NodeId(1), from: Ns(100), until: Ns(200) }],
            ..FaultConfig::default()
        });
        assert_eq!(s.action(Ns(99), NodeId(0), NodeId(1)), None);
        assert_eq!(s.action(Ns(100), NodeId(0), NodeId(1)), Some(FaultAction::Drop));
        assert_eq!(s.action(Ns(199), NodeId(0), NodeId(1)), Some(FaultAction::Drop));
        assert_eq!(s.action(Ns(200), NodeId(0), NodeId(1)), None);
        // the reverse direction is unaffected
        assert_eq!(s.action(Ns(150), NodeId(1), NodeId(0)), None);
        assert_eq!(s.stats.drops_flap, 2);
    }

    #[test]
    fn bursts_drop_consecutive_frames_on_one_link() {
        let mut s = FaultState::new(FaultConfig {
            seed: 11,
            drop_p: 0.05,
            burst_p: 1.0,
            burst_len: (3, 3),
            ..FaultConfig::default()
        });
        // drive until an iid drop starts a burst, then the next 3 frames
        // on that link must drop while the other link is untouched
        let mut i = 0u64;
        loop {
            i += 1;
            assert!(i < 10_000, "no drop in 10k frames at p=0.05?");
            if s.action(Ns(i), NodeId(0), NodeId(1)) == Some(FaultAction::Drop) {
                break;
            }
        }
        // the episode is per-link: a frame on another link may take its
        // own iid draw, but never a burst continuation
        let mut other = s.clone();
        let _ = other.action(Ns(i + 1), NodeId(2), NodeId(3));
        assert_eq!(other.stats.drops_burst, 0, "burst leaked to another link");
        for k in 0..3 {
            assert_eq!(
                s.action(Ns(i + 1 + k), NodeId(0), NodeId(1)),
                Some(FaultAction::Drop),
                "burst frame {k} not dropped"
            );
        }
        assert_eq!(s.stats.drops_burst, 3);
    }

    #[test]
    fn per_node_forks_are_deterministic_and_independent() {
        let cfg = FaultConfig { seed: 9, drop_p: 0.2, ..FaultConfig::default() };
        // same fork → same stream
        let mut a = FaultState::for_node(&cfg, NodeId(3));
        let mut b = FaultState::for_node(&cfg, NodeId(3));
        for i in 0..5_000u64 {
            assert_eq!(
                a.action(Ns(i), NodeId(0), NodeId(3)),
                b.action(Ns(i), NodeId(0), NodeId(3)),
                "fork replay diverged at {i}"
            );
        }
        // different forks → different streams (overwhelmingly likely at
        // p=0.2 over 5000 draws; equality would mean the lane mix failed)
        let mut c = FaultState::for_node(&cfg, NodeId(4));
        let mut same = true;
        let mut a2 = FaultState::for_node(&cfg, NodeId(3));
        for i in 0..5_000u64 {
            if a2.action(Ns(i), NodeId(0), NodeId(3)) != c.action(Ns(i), NodeId(0), NodeId(4)) {
                same = false;
                break;
            }
        }
        assert!(!same, "node forks produced identical fault streams");
    }

    #[test]
    fn jitter_delays_within_range() {
        let mut s = FaultState::new(FaultConfig {
            seed: 5,
            jitter_p: 1.0,
            jitter_ns: (100, 400),
            ..FaultConfig::default()
        });
        for i in 0..1000u64 {
            match s.action(Ns(i), NodeId(0), NodeId(1)) {
                Some(FaultAction::Delay(d)) => {
                    assert!((100..=400).contains(&d.0), "delay {d} out of range")
                }
                other => panic!("expected delay, got {other:?}"),
            }
        }
        assert_eq!(s.stats.frames_delayed, 1000);
    }
}
