//! Memory-region registration: lkey/rkey table, page-table (MTT) entries,
//! huge-page support and protection checks.
//!
//! The MTT entry count matters twice: it is charged to the memory ledger
//! (Fig 7) and each MTT cache line competes for the NIC ICM cache with QP
//! contexts ([`super::cache`]) — registering with huge pages divides the
//! entry count by 512, the real-world trick the paper cites from FaRM [8].

use std::collections::BTreeMap;

use super::types::Mrkey;

/// Base page size (one MTT entry per 4 KiB without huge pages).
pub const PAGE_4K: u64 = 4 << 10;
/// Huge page size (one MTT entry per 2 MiB).
pub const PAGE_HUGE_2M: u64 = 2 << 20;

/// Access flags for a registered region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Access {
    /// Local writes (recv landing) allowed.
    pub local_write: bool,
    /// Remote RDMA READ allowed.
    pub remote_read: bool,
    /// Remote RDMA WRITE allowed.
    pub remote_write: bool,
}

impl Access {
    /// Local read/write only; no remote access.
    pub const LOCAL_ONLY: Access =
        Access { local_write: true, remote_read: false, remote_write: false };
    /// Remote READ + WRITE allowed (the pool default).
    pub const REMOTE_RW: Access =
        Access { local_write: true, remote_read: true, remote_write: true };
    /// Remote READ only.
    pub const REMOTE_RO: Access =
        Access { local_write: true, remote_read: true, remote_write: false };
}

/// One registered memory region.
#[derive(Clone, Debug)]
pub struct MemoryRegion {
    /// The region's lkey/rkey.
    pub key: Mrkey,
    /// Base address in the node's flat virtual space.
    pub addr: u64,
    /// Registered length in bytes.
    pub len: u64,
    /// Permission flags checked on every remote op.
    pub access: Access,
    /// Registered with 2 MiB pages (512× fewer MTT entries).
    pub huge_pages: bool,
    /// Page-table entries this region pins (ICM pressure input).
    pub mtt_entries: u64,
}

impl MemoryRegion {
    /// Does `[addr, addr+len)` fall entirely inside this region?
    pub fn contains(&self, addr: u64, len: u64) -> bool {
        addr >= self.addr && addr.saturating_add(len) <= self.addr + self.len
    }
}

/// Per-node MR table. Addresses are a flat per-node virtual space managed by
/// a bump allocator (the simulator never stores payload bytes, only extents).
#[derive(Debug, Default)]
pub struct MrTable {
    regions: BTreeMap<u32, MemoryRegion>,
    next_key: u32,
    next_addr: u64,
    /// total registered bytes (memory ledger input)
    pub registered_bytes: u64,
    /// total MTT entries (memory ledger + ICM cache pressure input)
    pub total_mtt_entries: u64,
}

impl MrTable {
    /// Empty table with a fresh key/address allocator.
    pub fn new() -> Self {
        MrTable { regions: BTreeMap::new(), next_key: 1, next_addr: 0x1000, ..Default::default() }
    }

    /// Register `len` bytes; returns the region (address assigned by the
    /// allocator). `huge_pages` controls MTT granularity.
    pub fn register(&mut self, len: u64, access: Access, huge_pages: bool) -> MemoryRegion {
        let page = if huge_pages { PAGE_HUGE_2M } else { PAGE_4K };
        let mtt_entries = len.div_ceil(page).max(1);
        let key = Mrkey(self.next_key);
        self.next_key += 1;
        let addr = self.next_addr;
        // keep regions page-aligned and non-adjacent to catch off-by-one bugs
        self.next_addr += len.div_ceil(page) * page + page;
        let mr = MemoryRegion { key, addr, len, access, huge_pages, mtt_entries };
        self.registered_bytes += len;
        self.total_mtt_entries += mtt_entries;
        self.regions.insert(key.0, mr.clone());
        mr
    }

    /// Remove a region; false if the key is unknown.
    pub fn deregister(&mut self, key: Mrkey) -> bool {
        if let Some(mr) = self.regions.remove(&key.0) {
            self.registered_bytes -= mr.len;
            self.total_mtt_entries -= mr.mtt_entries;
            true
        } else {
            false
        }
    }

    /// Look a region up by key.
    pub fn get(&self, key: Mrkey) -> Option<&MemoryRegion> {
        self.regions.get(&key.0)
    }

    /// Validate a local buffer reference (lkey).
    pub fn check_local(&self, key: Mrkey, addr: u64, len: u64) -> bool {
        self.get(key).map(|mr| mr.contains(addr, len)).unwrap_or(false)
    }

    /// Validate a remote access (rkey + permission for the op).
    pub fn check_remote(&self, key: Mrkey, addr: u64, len: u64, write: bool) -> bool {
        match self.get(key) {
            None => false,
            Some(mr) => {
                let perm = if write { mr.access.remote_write } else { mr.access.remote_read };
                perm && mr.contains(addr, len)
            }
        }
    }

    /// Which MTT cache block an address falls in (for ICM cache keys).
    pub fn mtt_block(&self, key: Mrkey, addr: u64) -> Option<u64> {
        self.get(key).map(|mr| {
            let page = if mr.huge_pages { PAGE_HUGE_2M } else { PAGE_4K };
            (addr - mr.addr) / page
        })
    }

    /// Number of live regions.
    pub fn region_count(&self) -> usize {
        self.regions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_assigns_disjoint_regions() {
        let mut t = MrTable::new();
        let a = t.register(1 << 20, Access::REMOTE_RW, false);
        let b = t.register(1 << 20, Access::REMOTE_RW, false);
        assert_ne!(a.key, b.key);
        assert!(a.addr + a.len <= b.addr);
    }

    #[test]
    fn huge_pages_reduce_mtt_512x() {
        let mut t = MrTable::new();
        let small = t.register(1 << 30, Access::REMOTE_RW, false);
        let huge = t.register(1 << 30, Access::REMOTE_RW, true);
        assert_eq!(small.mtt_entries, (1 << 30) / PAGE_4K);
        assert_eq!(huge.mtt_entries, (1 << 30) / PAGE_HUGE_2M);
        assert_eq!(small.mtt_entries / huge.mtt_entries, 512);
    }

    #[test]
    fn protection_checks() {
        let mut t = MrTable::new();
        let ro = t.register(4096, Access::REMOTE_RO, false);
        assert!(t.check_remote(ro.key, ro.addr, 4096, false));
        assert!(!t.check_remote(ro.key, ro.addr, 4096, true)); // write to RO
        assert!(!t.check_remote(ro.key, ro.addr + 1, 4096, false)); // 1 past end
        assert!(!t.check_remote(Mrkey(999), ro.addr, 16, false)); // bad rkey
    }

    #[test]
    fn local_check() {
        let mut t = MrTable::new();
        let mr = t.register(8192, Access::LOCAL_ONLY, false);
        assert!(t.check_local(mr.key, mr.addr + 4096, 4096));
        assert!(!t.check_local(mr.key, mr.addr + 4097, 4096));
    }

    #[test]
    fn ledger_tracks_registration() {
        let mut t = MrTable::new();
        let mr = t.register(1 << 20, Access::REMOTE_RW, false);
        assert_eq!(t.registered_bytes, 1 << 20);
        assert!(t.total_mtt_entries > 0);
        assert!(t.deregister(mr.key));
        assert_eq!(t.registered_bytes, 0);
        assert_eq!(t.total_mtt_entries, 0);
        assert!(!t.deregister(mr.key));
    }

    #[test]
    fn mtt_block_granularity() {
        let mut t = MrTable::new();
        let mr = t.register(1 << 22, Access::REMOTE_RW, false);
        assert_eq!(t.mtt_block(mr.key, mr.addr), Some(0));
        assert_eq!(t.mtt_block(mr.key, mr.addr + PAGE_4K), Some(1));
        let hp = t.register(1 << 22, Access::REMOTE_RW, true);
        assert_eq!(t.mtt_block(hp.key, hp.addr + PAGE_4K), Some(0)); // same huge page
    }
}
