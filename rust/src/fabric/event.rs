//! Discrete-event queue with deterministic FIFO tie-breaking.
//!
//! Implemented as a **hierarchical timing wheel** (64-slot levels, 1 ns
//! finest granularity) with a sorted overflow level for events beyond the
//! wheel span. Replaces the original `BinaryHeap`: pushes and pops are
//! O(1) amortized instead of O(log n) sift operations over ~100-byte
//! entries, which is what made the event loop the bottleneck of the
//! thousand-connection sweeps.
//!
//! ### Exact order equivalence
//!
//! Pop order is **identical** to the heap it replaced: ascending event
//! time, FIFO (ascending sequence number) within the same instant. Three
//! structural invariants make this exact, not approximate:
//!
//! * the finest level has 1 ns slots, so every entry in a level-0 slot
//!   shares one exact timestamp and FIFO falls out of seq order;
//! * every slot (and overflow bucket) keeps its entries sorted by seq —
//!   inserts scan from the back, so in-order pushes stay O(1) while a
//!   [`EventQueue::push_at_seq`] replay with a previously reserved seq
//!   lands in its original position;
//! * level *l* holds only times within the cursor's level-(*l*+1) block,
//!   so all level-*l* entries precede all level-(*l*+1) entries and the
//!   earliest event is always in the lowest occupied level's lowest
//!   occupied slot (or, with an empty wheel, the overflow's first bucket).
//!
//! The seq counter is the same push-ordered counter the heap used;
//! [`EventQueue::reserve_seqs`] lets a caller claim a contiguous block up
//! front and replay it later (the simulator's coalesced frame streams),
//! which preserves the exact order an eager push-per-frame would have had.

use std::collections::{BTreeMap, VecDeque};

use super::time::Ns;

/// log2 of the slot count per wheel level.
const SLOT_BITS: u32 = 6;
/// Slots per level.
const SLOTS: usize = 1 << SLOT_BITS;
/// Wheel levels; spans `2^(SLOT_BITS*LEVELS)` ns ≈ 1.07 s at 6×5.
const LEVELS: usize = 5;
/// Total bits of time the wheel covers; beyond this is the overflow level.
const WHEEL_BITS: u32 = SLOT_BITS * LEVELS as u32;

struct Entry<E> {
    at: u64,
    seq: u64,
    event: E,
}

/// Pending-event queue of a simulation.
pub struct EventQueue<E> {
    /// Cursor: the time of the last popped event (all earlier times are
    /// fully drained). Slot membership is computed relative to this.
    horizon: u64,
    /// `LEVELS × SLOTS` slot deques, level-major.
    levels: Vec<VecDeque<Entry<E>>>,
    /// Per-level occupancy bitmap (bit i = slot i non-empty).
    occ: [u64; LEVELS],
    /// Sorted overflow level: time → seq-ordered entries, for events
    /// beyond the wheel span. Migrated into the wheel block-wise.
    overflow: BTreeMap<u64, VecDeque<Entry<E>>>,
    len: usize,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue.
    pub fn new() -> Self {
        let mut levels = Vec::with_capacity(LEVELS * SLOTS);
        levels.resize_with(LEVELS * SLOTS, VecDeque::new);
        EventQueue {
            horizon: 0,
            levels,
            occ: [0; LEVELS],
            overflow: BTreeMap::new(),
            len: 0,
            seq: 0,
        }
    }

    /// Schedule `event` at absolute time `at` with the next sequence
    /// number (FIFO within an instant).
    pub fn push(&mut self, at: Ns, event: E) {
        let seq = self.seq;
        self.seq += 1;
        self.push_at_seq(at, seq, event);
    }

    /// Claim `n` consecutive sequence numbers and return the first.
    ///
    /// A caller that would otherwise push `n` events back-to-back can
    /// reserve their seqs up front and replay them one at a time via
    /// [`EventQueue::push_at_seq`]; pop order is identical to the eager
    /// pushes (the simulator's coalesced multi-frame message streams).
    pub fn reserve_seqs(&mut self, n: u64) -> u64 {
        let s = self.seq;
        self.seq += n;
        s
    }

    /// Schedule `event` at `at` under an explicitly reserved sequence
    /// number (see [`EventQueue::reserve_seqs`]). `at` must not precede
    /// the last popped event — that is a caller bug (debug assert);
    /// release builds clamp to it as a safety net so the wheel's slot
    /// invariants cannot be corrupted.
    pub fn push_at_seq(&mut self, at: Ns, seq: u64, event: E) {
        debug_assert!(at.0 >= self.horizon, "push into the drained past");
        let t = at.0.max(self.horizon);
        let e = Entry { at: t, seq, event };
        self.len += 1;
        if (t ^ self.horizon) >> WHEEL_BITS != 0 {
            // beyond the wheel span: sorted overflow level
            let d = self.overflow.entry(t).or_default();
            let mut i = d.len();
            while i > 0 && d[i - 1].seq > seq {
                i -= 1;
            }
            d.insert(i, e);
        } else {
            self.wheel_insert(e);
        }
    }

    /// Place an in-span entry in the correct level/slot, keeping the slot
    /// seq-sorted (in-order pushes append in O(1)).
    fn wheel_insert(&mut self, e: Entry<E>) {
        let x = e.at ^ self.horizon;
        let lvl = if x == 0 {
            0
        } else {
            (63 - x.leading_zeros()) as usize / SLOT_BITS as usize
        };
        debug_assert!(lvl < LEVELS);
        let idx = ((e.at >> (SLOT_BITS * lvl as u32)) & (SLOTS as u64 - 1)) as usize;
        let d = &mut self.levels[lvl * SLOTS + idx];
        let mut i = d.len();
        while i > 0 && d[i - 1].seq > e.seq {
            i -= 1;
        }
        d.insert(i, e);
        self.occ[lvl] |= 1u64 << idx;
    }

    /// Remove and return the earliest event (FIFO within an instant).
    pub fn pop(&mut self) -> Option<(Ns, E)> {
        if self.len == 0 {
            return None;
        }
        loop {
            // Level 0: 1 ns slots — every entry in the slot shares one
            // exact timestamp, and the deque is seq-sorted.
            if self.occ[0] != 0 {
                let idx = self.occ[0].trailing_zeros() as usize;
                let d = &mut self.levels[idx];
                let e = d.pop_front().expect("occupied level-0 slot");
                if d.is_empty() {
                    self.occ[0] &= !(1u64 << idx);
                }
                self.horizon = e.at;
                self.len -= 1;
                return Some((Ns(e.at), e.event));
            }
            // Cascade the lowest occupied slot of the lowest non-empty
            // level down: advance the cursor to that slot's range start
            // and re-insert its entries (they land in strictly lower
            // levels, so this terminates).
            let mut cascaded = false;
            for lvl in 1..LEVELS {
                if self.occ[lvl] == 0 {
                    continue;
                }
                let idx = self.occ[lvl].trailing_zeros() as usize;
                let mut d = std::mem::take(&mut self.levels[lvl * SLOTS + idx]);
                self.occ[lvl] &= !(1u64 << idx);
                let span = SLOT_BITS * (lvl as u32 + 1);
                let base = (self.horizon >> span) << span;
                self.horizon = base | ((idx as u64) << (SLOT_BITS * lvl as u32));
                for e in d.drain(..) {
                    self.wheel_insert(e);
                }
                // hand the (now empty) deque's capacity back to the slot
                self.levels[lvl * SLOTS + idx] = d;
                cascaded = true;
                break;
            }
            if cascaded {
                continue;
            }
            // Wheel empty: migrate the earliest overflow block in.
            let (&t0, _) = self
                .overflow
                .iter()
                .next()
                .expect("len > 0 with empty wheel and empty overflow");
            self.horizon = t0;
            let block = t0 >> WHEEL_BITS;
            loop {
                let Some((&t, _)) = self.overflow.iter().next() else { break };
                if t >> WHEEL_BITS != block {
                    break;
                }
                let d = self.overflow.remove(&t).expect("present key");
                for e in d {
                    self.wheel_insert(e);
                }
            }
        }
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<Ns> {
        if self.len == 0 {
            return None;
        }
        for lvl in 0..LEVELS {
            if self.occ[lvl] == 0 {
                continue;
            }
            let idx = self.occ[lvl].trailing_zeros() as usize;
            let d = &self.levels[lvl * SLOTS + idx];
            return if lvl == 0 {
                // one exact timestamp per level-0 slot
                d.front().map(|e| Ns(e.at))
            } else {
                // coarser slots mix timestamps (seq-sorted): scan for min
                d.iter().map(|e| e.at).min().map(Ns)
            };
        }
        self.overflow.keys().next().copied().map(Ns)
    }

    /// Pending events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the timeline is drained.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(Ns(30), "c");
        q.push(Ns(10), "a");
        q.push(Ns(20), "b");
        assert_eq!(q.pop(), Some((Ns(10), "a")));
        assert_eq!(q.pop(), Some((Ns(20), "b")));
        assert_eq!(q.pop(), Some((Ns(30), "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_within_same_instant() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(Ns(5), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((Ns(5), i)));
        }
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(Ns(10), 1);
        q.push(Ns(5), 0);
        assert_eq!(q.pop(), Some((Ns(5), 0)));
        q.push(Ns(7), 2);
        assert_eq!(q.pop(), Some((Ns(7), 2)));
        assert_eq!(q.pop(), Some((Ns(10), 1)));
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(Ns(42), ());
        assert_eq!(q.peek_time(), Some(Ns(42)));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn peek_across_levels_and_overflow() {
        let mut q = EventQueue::new();
        q.push(Ns(1 << 40), "overflow");
        assert_eq!(q.peek_time(), Some(Ns(1 << 40)));
        q.push(Ns(70_000), "level2");
        assert_eq!(q.peek_time(), Some(Ns(70_000)));
        q.push(Ns(3), "level0");
        assert_eq!(q.peek_time(), Some(Ns(3)));
        assert_eq!(q.pop(), Some((Ns(3), "level0")));
        assert_eq!(q.pop(), Some((Ns(70_000), "level2")));
        assert_eq!(q.pop(), Some((Ns(1 << 40), "overflow")));
        assert!(q.is_empty());
    }

    #[test]
    fn far_future_overflow_entries_pop_in_order() {
        let mut q = EventQueue::new();
        // several distinct overflow blocks plus near-term wheel entries
        q.push(Ns(5 << 30), 4u32);
        q.push(Ns((1 << 30) + 7), 2);
        q.push(Ns(12), 0);
        q.push(Ns((1 << 30) + 7), 3); // same overflow instant: FIFO
        q.push(Ns(900), 1);
        q.push(Ns(9 << 35), 5);
        let mut got = Vec::new();
        while let Some((_, e)) = q.pop() {
            got.push(e);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
    }

    // ------------------------------------------------ reference equivalence

    /// The exact structure this wheel replaced: a BinaryHeap ordered by
    /// (time asc, seq asc).
    struct RefEntry {
        at: u64,
        seq: u64,
        id: u64,
    }
    impl PartialEq for RefEntry {
        fn eq(&self, other: &Self) -> bool {
            self.at == other.at && self.seq == other.seq
        }
    }
    impl Eq for RefEntry {}
    impl PartialOrd for RefEntry {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for RefEntry {
        fn cmp(&self, other: &Self) -> Ordering {
            other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
        }
    }

    /// Property: under a random interleaved push/pop workload — including
    /// same-instant bursts, far-future overflow entries and reserved-seq
    /// stream replays — the wheel pops byte-identically to the reference
    /// heap.
    #[test]
    fn property_matches_reference_heap() {
        for seed in 0..12u64 {
            let mut rng = Rng::new(0xE_u64.wrapping_mul(seed).wrapping_add(seed + 1));
            let mut wheel: EventQueue<u64> = EventQueue::new();
            let mut heap: BinaryHeap<RefEntry> = BinaryHeap::new();
            let mut ref_seq = 0u64;
            // reserved-seq streams the wheel replays lazily: id -> (next
            // push index, times, base seq). The reference pushed all of a
            // stream's entries eagerly at reservation time.
            let mut streams: std::collections::HashMap<u64, (usize, Vec<u64>, u64)> =
                std::collections::HashMap::new();
            let mut clock = 0u64; // mirrors the sim: pushes never precede
            let mut next_id = 0u64; // the last popped time
            let mut popped = 0u64;
            // ids: plain events are (id << 8) | 0xFF; stream frame k of
            // stream s is (s << 8) | k with k < 6 — disjoint low bytes, so
            // the pop-side resolver can tell them apart.
            let plain_id = |next_id: &mut u64| {
                let id = (*next_id << 8) | 0xFF;
                *next_id += 1;
                id
            };

            for _ in 0..4000 {
                match rng.gen_range(100) {
                    // plain push, near horizon
                    0..=39 => {
                        let at = clock + rng.gen_range(1 << 14);
                        let id = plain_id(&mut next_id);
                        heap.push(RefEntry { at, seq: ref_seq, id });
                        ref_seq += 1;
                        wheel.push(Ns(at), id);
                    }
                    // same-instant burst
                    40..=54 => {
                        let at = clock + rng.gen_range(1 << 10);
                        for _ in 0..rng.usize_in(2, 40) {
                            let id = plain_id(&mut next_id);
                            heap.push(RefEntry { at, seq: ref_seq, id });
                            ref_seq += 1;
                            wheel.push(Ns(at), id);
                        }
                    }
                    // far-future (overflow level) push
                    55..=62 => {
                        let at = clock + (1 << WHEEL_BITS) + rng.gen_range(1 << 40);
                        let id = plain_id(&mut next_id);
                        heap.push(RefEntry { at, seq: ref_seq, id });
                        ref_seq += 1;
                        wheel.push(Ns(at), id);
                    }
                    // stream reservation: the reference pushes all n
                    // frames now; the wheel pushes only the first and
                    // replays the rest on pop with the reserved seqs
                    63..=74 => {
                        let n = rng.usize_in(2, 6);
                        let mut at = clock + 1 + rng.gen_range(1 << 12);
                        let mut times = Vec::with_capacity(n);
                        for _ in 0..n {
                            times.push(at);
                            at += 1 + rng.gen_range(1 << 8);
                        }
                        let base = ref_seq;
                        for (k, &t) in times.iter().enumerate() {
                            heap.push(RefEntry {
                                at: t,
                                seq: base + k as u64,
                                id: (next_id << 8) | k as u64,
                            });
                        }
                        ref_seq += n as u64;
                        assert_eq!(wheel.reserve_seqs(n as u64), base);
                        wheel.push_at_seq(Ns(times[0]), base, next_id << 8);
                        streams.insert(next_id, (0, times, base));
                        next_id += 1;
                    }
                    // pop and compare
                    _ => {
                        let w = wheel.pop();
                        let h = heap.pop();
                        match (w, h) {
                            (None, None) => {}
                            (Some((wt, wid)), Some(r)) => {
                                popped += 1;
                                clock = clock.max(wt.0);
                                assert_eq!(wt.0, r.at, "time diverged (seed {seed})");
                                // resolve stream frames to their ref id
                                let sid = wid >> 8;
                                let resolved = match streams.get_mut(&sid) {
                                    Some((k, times, base)) if (wid & 0xFF) == *k as u64 => {
                                        let id = (sid << 8) | *k as u64;
                                        *k += 1;
                                        if *k < times.len() {
                                            wheel.push_at_seq(
                                                Ns(times[*k]),
                                                *base + *k as u64,
                                                (sid << 8) | *k as u64,
                                            );
                                        }
                                        id
                                    }
                                    _ => wid,
                                };
                                assert_eq!(resolved, r.id, "order diverged (seed {seed})");
                            }
                            (w, h) => panic!(
                                "length diverged (seed {seed}): wheel={:?} heap={:?}",
                                w.map(|x| x.0),
                                h.map(|x| x.at)
                            ),
                        }
                    }
                }
            }
            // drain both completely
            loop {
                let w = wheel.pop();
                let h = heap.pop();
                match (w, h) {
                    (None, None) => break,
                    (Some((wt, wid)), Some(r)) => {
                        popped += 1;
                        assert_eq!(wt.0, r.at, "drain time diverged (seed {seed})");
                        let sid = wid >> 8;
                        let resolved = match streams.get_mut(&sid) {
                            Some((k, times, base)) if (wid & 0xFF) == *k as u64 => {
                                let id = (sid << 8) | *k as u64;
                                *k += 1;
                                if *k < times.len() {
                                    wheel.push_at_seq(
                                        Ns(times[*k]),
                                        *base + *k as u64,
                                        (sid << 8) | *k as u64,
                                    );
                                }
                                id
                            }
                            _ => wid,
                        };
                        assert_eq!(resolved, r.id, "drain order diverged (seed {seed})");
                    }
                    _ => panic!("drain length diverged (seed {seed})"),
                }
            }
            assert!(popped > 100, "workload too small to mean anything");
        }
    }
}
