//! Discrete-event queue with deterministic FIFO tie-breaking.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::Ns;

struct Entry<E> {
    at: Ns,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: earlier time first; FIFO within the same instant.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Pending-event queue of a simulation.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `event` at absolute time `at`.
    pub fn push(&mut self, at: Ns, event: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at, seq, event });
    }

    /// Remove and return the earliest event (FIFO within an instant).
    pub fn pop(&mut self) -> Option<(Ns, E)> {
        self.heap.pop().map(|e| (e.at, e.event))
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<Ns> {
        self.heap.peek().map(|e| e.at)
    }

    /// Pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when the timeline is drained.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(Ns(30), "c");
        q.push(Ns(10), "a");
        q.push(Ns(20), "b");
        assert_eq!(q.pop(), Some((Ns(10), "a")));
        assert_eq!(q.pop(), Some((Ns(20), "b")));
        assert_eq!(q.pop(), Some((Ns(30), "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_within_same_instant() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(Ns(5), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((Ns(5), i)));
        }
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(Ns(10), 1);
        q.push(Ns(5), 0);
        assert_eq!(q.pop(), Some((Ns(5), 0)));
        q.push(Ns(7), 2);
        assert_eq!(q.pop(), Some((Ns(7), 2)));
        assert_eq!(q.pop(), Some((Ns(10), 1)));
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(Ns(42), ());
        assert_eq!(q.peek_time(), Some(Ns(42)));
        assert_eq!(q.len(), 1);
    }
}
