//! Network: per-node full-duplex ports through a non-blocking switch.
//!
//! Each node has one egress and one ingress port at line rate. A frame
//! leaves the source when its egress port is free (serialization at
//! `link_gbps`), crosses the switch (fixed propagation + switching delay),
//! and is delivered when the destination's ingress port has absorbed it.
//! For a 4-node cluster with a single ToR this is exact; per-port queues
//! give us backpressure and fan-in contention (3 readers hitting one
//! responder node share that node's egress on the response path — visible
//! in Fig 5's plateau). Installing [`crate::fabric::topo::TopoConfig`]
//! (`FabricConfig::topo`) replaces the single non-blocking switch with a
//! multi-switch fat-tree/Clos built from the same [`Port`] primitive —
//! oversubscribed uplinks, ECN/DCQCN, PFC pause gating (DESIGN.md §14).

use super::time::{wire_time, Ns};
use super::types::NodeId;

/// Per-frame wire overhead on RoCEv2: Eth(14+4) + IPv4(20) + UDP(8) +
/// BTH(12) + ICRC(4) + preamble/IFG(20) = 82 B. We fold it into each frame.
pub const FRAME_OVERHEAD_BYTES: u64 = 82;

/// Per-port switch buffering before PFC pauses the senders (shared by
/// [`Fabric::new`] and the sharded simulator's egress-side PFC gate so
/// both stages of the split wire model agree on the threshold).
pub const SWITCH_BUFFER_BYTES: u64 = 256 << 10;

/// One direction of a port: models serialization as a busy-until horizon.
#[derive(Clone, Debug, Default)]
pub struct Port {
    busy_until: Ns,
    /// Wire bytes through this port (incl. per-frame overhead).
    pub bytes: u64,
    /// Frames through this port.
    pub frames: u64,
    /// Frames the fault layer discarded after this port absorbed them
    /// (injected loss — see [`crate::fabric::fault`]; always 0 on the
    /// lossless fabric).
    pub dropped: u64,
}

impl Port {
    /// Occupy the port for `duration` starting no earlier than `earliest`;
    /// returns the completion time. Public because the sharded simulator
    /// drives its shard-owned egress ports directly (the ingress half
    /// stays behind [`Fabric::absorb_frame`]).
    pub fn occupy(&mut self, earliest: Ns, duration: Ns, wire_bytes: u64) -> Ns {
        let start = self.busy_until.max(earliest);
        let done = start + duration;
        self.busy_until = done;
        self.bytes += wire_bytes;
        self.frames += 1;
        done
    }

    /// When the port finishes serializing its current backlog.
    pub fn busy_until(&self) -> Ns {
        self.busy_until
    }

    /// Utilization of this port over `[0, horizon]`.
    pub fn utilization(&self, horizon: Ns, gbps: f64) -> f64 {
        if horizon.0 == 0 {
            return 0.0;
        }
        (wire_time(self.bytes, gbps).0 as f64 / horizon.0 as f64).min(1.0)
    }
}

/// The cluster network: per-node ingress/egress ports + fixed latency.
#[derive(Debug)]
pub struct Fabric {
    /// Per-port line rate.
    pub gbps: f64,
    /// Maximum frame payload.
    pub mtu: u64,
    /// Propagation + switch latency, one way.
    pub base_latency: Ns,
    /// Per-port switch buffering before PFC pauses the senders. RoCE
    /// fabrics run lossless: once a destination port's queue exceeds this,
    /// upstream transmitters pause (modeled as delayed egress start).
    pub switch_buffer_bytes: u64,
    egress: Vec<Port>,
    ingress: Vec<Port>,
}

impl Fabric {
    /// Build a fabric of `nodes` ports at `gbps` line rate.
    pub fn new(nodes: usize, gbps: f64, mtu: u64, base_latency: Ns) -> Self {
        Fabric {
            gbps,
            mtu,
            base_latency,
            switch_buffer_bytes: SWITCH_BUFFER_BYTES,
            egress: vec![Port::default(); nodes],
            ingress: vec![Port::default(); nodes],
        }
    }

    /// Send one frame of `payload_bytes` from `src` to `dst` starting no
    /// earlier than `now`; returns the delivery (last-bit-in) time at `dst`.
    ///
    /// First bit leaves `src` when its egress port frees up; it reaches the
    /// destination `base_latency` later (cut-through switch); the ingress
    /// port then absorbs the frame at line rate, queueing behind any fan-in
    /// traffic already arriving there.
    pub fn send_frame(&mut self, now: Ns, src: NodeId, dst: NodeId, payload_bytes: u64) -> Ns {
        debug_assert!(payload_bytes <= self.mtu, "frame exceeds MTU");
        let wire_bytes = payload_bytes + FRAME_OVERHEAD_BYTES;
        let frame_time = wire_time(wire_bytes, self.gbps);
        // PFC backpressure: if the destination port's queue is more than
        // `switch_buffer_bytes` deep (in time: its busy horizon is that far
        // ahead of now), the source is paused until it drains below the
        // threshold. This is what makes 3:1 fan-in actually slow the
        // responders down instead of queueing unboundedly in the switch.
        let buffer_time = wire_time(self.switch_buffer_bytes, self.gbps);
        let pfc_gate = self.ingress[dst.0 as usize]
            .busy_until()
            .saturating_sub(buffer_time + self.base_latency);
        let tx_start = self.egress[src.0 as usize].busy_until().max(now).max(pfc_gate);
        self.egress[src.0 as usize].occupy(tx_start, frame_time, wire_bytes);
        let first_bit_at_dst = tx_start + self.base_latency;
        self.ingress[dst.0 as usize].occupy(first_bit_at_dst, frame_time, wire_bytes)
    }

    /// Number of MTU-sized frames a `len`-byte message needs (Table 1's
    /// framing note; a 0-byte message still takes one header frame).
    #[inline]
    pub fn frame_count(&self, len: u64) -> u64 {
        len.div_ceil(self.mtu).max(1)
    }

    /// Payload bytes of frame `i` of an `n`-frame, `len`-byte message:
    /// full MTU frames followed by the remainder. With
    /// [`Fabric::frame_count`] this replaces the per-message `Vec` the
    /// old `frames_for` allocated on the issue hot path.
    #[inline]
    pub fn frame_bytes(&self, len: u64, i: u64, n: u64) -> u64 {
        if i + 1 < n {
            self.mtu
        } else {
            len - (n - 1) * self.mtu
        }
    }

    /// Split a message into MTU-sized frames (allocating convenience form
    /// of [`Fabric::frame_count`] + [`Fabric::frame_bytes`]; tests and
    /// cold paths only).
    pub fn frames_for(&self, len: u64) -> Vec<u64> {
        let n = self.frame_count(len);
        (0..n).map(|i| self.frame_bytes(len, i, n)).collect()
    }

    /// Absorb one staged frame at `dst`'s ingress port: the frame's first
    /// bit reaches the port at `link_at` (already paid for egress
    /// serialization + switch latency on the source side); the port then
    /// takes it in at line rate behind any fan-in backlog. Returns the
    /// delivery (last-bit-in) time. This is the ingress half of
    /// [`Fabric::send_frame`], split out so the sharded simulator can run
    /// the egress half inside the owning shard and this half at the
    /// conservative barrier, in one global deterministic frame order.
    pub fn absorb_frame(&mut self, link_at: Ns, dst: NodeId, payload_bytes: u64) -> Ns {
        debug_assert!(payload_bytes <= self.mtu, "frame exceeds MTU");
        let wire_bytes = payload_bytes + FRAME_OVERHEAD_BYTES;
        let frame_time = wire_time(wire_bytes, self.gbps);
        self.ingress[dst.0 as usize].occupy(link_at, frame_time, wire_bytes)
    }

    /// Copy every ingress port's busy horizon into `out` (index = node
    /// id). Refreshed into each shard at every barrier: the shards' PFC
    /// gates read this snapshot instead of racing on the live ports.
    pub fn ingress_snapshot_into(&self, out: &mut Vec<Ns>) {
        out.clear();
        out.extend(self.ingress.iter().map(|p| p.busy_until()));
    }

    /// Record an injected-loss discard at `dst`'s ingress port. The frame
    /// already occupied both ports (it was transmitted and then lost in
    /// the switch/wire), so only the drop tally changes — the sender's
    /// pacing saw a normal transmission, as it would on real hardware.
    pub fn note_drop(&mut self, dst: NodeId) {
        self.ingress[dst.0 as usize].dropped += 1;
    }

    /// This node's egress-port counters.
    pub fn egress_stats(&self, node: NodeId) -> &Port {
        &self.egress[node.0 as usize]
    }

    /// When this node's egress port frees up (engine backpressure input).
    pub fn egress_busy_until(&self, node: NodeId) -> Ns {
        self.egress[node.0 as usize].busy_until()
    }

    /// This node's ingress-port counters.
    pub fn ingress_stats(&self, node: NodeId) -> &Port {
        &self.ingress[node.0 as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fab() -> Fabric {
        Fabric::new(4, 40.0, 4096, Ns(1000))
    }

    #[test]
    fn frame_latency_includes_serialization_and_prop() {
        let mut f = fab();
        let t = f.send_frame(Ns(0), NodeId(0), NodeId(1), 4096);
        // ~ (4096+82)*8/40 ns tx + 1000 ns prop + rx absorption
        assert!(t.0 > 1000 + 835, "t={t}");
        assert!(t.0 < 4000, "t={t}");
    }

    #[test]
    fn egress_serializes_back_to_back() {
        let mut f = fab();
        let t1 = f.send_frame(Ns(0), NodeId(0), NodeId(1), 4096);
        let t2 = f.send_frame(Ns(0), NodeId(0), NodeId(1), 4096);
        let gap = t2.0 - t1.0;
        let frame_ns = wire_time(4096 + FRAME_OVERHEAD_BYTES, 40.0).0;
        assert!((gap as i64 - frame_ns as i64).unsigned_abs() <= 2, "gap={gap}");
    }

    #[test]
    fn ingress_fanin_contention() {
        // two sources to one sink: deliveries can't overlap at the sink port
        let mut f = fab();
        let a = f.send_frame(Ns(0), NodeId(0), NodeId(2), 4096);
        let b = f.send_frame(Ns(0), NodeId(1), NodeId(2), 4096);
        let frame_ns = wire_time(4096 + FRAME_OVERHEAD_BYTES, 40.0).0;
        assert!(
            (b.0 as i64 - a.0 as i64).unsigned_abs() >= frame_ns - 2,
            "a={a} b={b}"
        );
    }

    #[test]
    fn distinct_destinations_dont_contend_at_ingress() {
        let mut f = fab();
        let a = f.send_frame(Ns(0), NodeId(0), NodeId(1), 4096);
        // different egress AND ingress => same timing as a alone
        let b = f.send_frame(Ns(0), NodeId(2), NodeId(3), 4096);
        assert_eq!(a.0, b.0);
    }

    #[test]
    fn framing_mtu() {
        let f = fab();
        assert_eq!(f.frames_for(4096), vec![4096]);
        assert_eq!(f.frames_for(10000), vec![4096, 4096, 1808]);
        assert_eq!(f.frames_for(0), vec![0]);
        assert_eq!(f.frames_for(64 << 10).len(), 16);
    }

    #[test]
    fn sustained_rate_is_line_rate() {
        let mut f = fab();
        let n = 1000u64;
        let mut last = Ns(0);
        for _ in 0..n {
            last = f.send_frame(Ns(0), NodeId(0), NodeId(1), 4096);
        }
        let goodput = super::super::time::gbps(4096 * n, last);
        // payload goodput slightly below 40G due to per-frame overhead
        assert!(goodput > 38.0 && goodput < 40.0, "goodput={goodput}");
    }
}
