//! The RNIC's on-chip context cache (ICM cache).
//!
//! ConnectX-class NICs keep QP contexts, CQ contexts and MTT (address
//! translation) entries in host memory and cache a small working set on
//! chip. Every WQE/frame the NIC processes must find its QP context (and
//! the MTT blocks it touches) in this cache; a miss stalls the processing
//! pipeline for a PCIe round-trip. **This cache is the mechanism behind
//! Fig 5**: with one QP per connection, >~400 active QPs thrash the cache
//! and aggregate throughput collapses; with RDMAvisor's shared QPs the
//! working set is a handful of contexts and the hit rate stays ~100%.
//!
//! Implemented as an O(1) LRU (intrusive doubly-linked list over a slab +
//! hash index) so simulating millions of frames stays cheap.

use std::collections::HashMap;

/// Cache key: one cachable ICM object.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IcmKey {
    /// QP context, by QPN.
    Qpc(u32),
    /// CQ context, by CQN.
    Cqc(u32),
    /// MTT block: (mr key, block index).
    Mtt(u32, u64),
}

const NIL: u32 = u32::MAX;

struct Slot {
    key: IcmKey,
    prev: u32,
    next: u32,
}

/// Fixed-capacity LRU set of ICM objects with hit/miss accounting.
pub struct IcmCache {
    index: HashMap<IcmKey, u32>,
    slots: Vec<Slot>,
    free: Vec<u32>,
    head: u32, // most-recently used
    tail: u32, // least-recently used
    capacity: usize,
    /// Lookups that found their entry resident.
    pub hits: u64,
    /// Lookups that had to install (and maybe evict).
    pub misses: u64,
    /// LRU entries displaced by installs.
    pub evictions: u64,
}

impl IcmCache {
    /// Create an empty cache holding at most `capacity` entries.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        IcmCache {
            index: HashMap::with_capacity(capacity * 2),
            slots: Vec::with_capacity(capacity),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            capacity,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Maximum resident entries.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Currently resident entries.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Touch `key`: returns true on hit; on miss, installs it (evicting the
    /// LRU entry if full) and returns false. One call = one ICM lookup.
    pub fn touch(&mut self, key: IcmKey) -> bool {
        if let Some(&slot) = self.index.get(&key) {
            self.hits += 1;
            self.move_to_front(slot);
            return true;
        }
        self.misses += 1;
        self.install(key);
        false
    }

    /// Does the cache currently hold `key` (no accounting, no reordering)?
    pub fn contains(&self, key: &IcmKey) -> bool {
        self.index.contains_key(key)
    }

    /// Invalidate (QP destroy / MR dereg).
    pub fn invalidate(&mut self, key: &IcmKey) {
        if let Some(slot) = self.index.remove(key) {
            self.unlink(slot);
            self.free.push(slot);
        }
    }

    /// Hits / (hits + misses) since the last [`IcmCache::reset_stats`].
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Zero the hit/miss/eviction counters (contents preserved).
    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.evictions = 0;
    }

    fn install(&mut self, key: IcmKey) {
        if self.index.len() >= self.capacity {
            // evict LRU
            let victim = self.tail;
            debug_assert_ne!(victim, NIL);
            let vkey = self.slots[victim as usize].key;
            self.index.remove(&vkey);
            self.unlink(victim);
            self.free.push(victim);
            self.evictions += 1;
        }
        let slot = match self.free.pop() {
            Some(s) => {
                self.slots[s as usize].key = key;
                s
            }
            None => {
                self.slots.push(Slot { key, prev: NIL, next: NIL });
                (self.slots.len() - 1) as u32
            }
        };
        self.index.insert(key, slot);
        self.link_front(slot);
    }

    fn unlink(&mut self, slot: u32) {
        let (prev, next) = {
            let s = &self.slots[slot as usize];
            (s.prev, s.next)
        };
        if prev != NIL {
            self.slots[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn link_front(&mut self, slot: u32) {
        let s = &mut self.slots[slot as usize];
        s.prev = NIL;
        s.next = self.head;
        if self.head != NIL {
            self.slots[self.head as usize].prev = slot;
        }
        self.head = slot;
        if self.tail == NIL {
            self.tail = slot;
        }
    }

    fn move_to_front(&mut self, slot: u32) {
        if self.head == slot {
            return;
        }
        self.unlink(slot);
        self.link_front(slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_within_capacity() {
        let mut c = IcmCache::new(4);
        for i in 0..4 {
            assert!(!c.touch(IcmKey::Qpc(i))); // cold misses
        }
        for i in 0..4 {
            assert!(c.touch(IcmKey::Qpc(i))); // all hot
        }
        assert_eq!(c.hits, 4);
        assert_eq!(c.misses, 4);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = IcmCache::new(2);
        c.touch(IcmKey::Qpc(1));
        c.touch(IcmKey::Qpc(2));
        c.touch(IcmKey::Qpc(1)); // 2 is now LRU
        c.touch(IcmKey::Qpc(3)); // evicts 2
        assert!(c.contains(&IcmKey::Qpc(1)));
        assert!(!c.contains(&IcmKey::Qpc(2)));
        assert!(c.contains(&IcmKey::Qpc(3)));
        assert_eq!(c.evictions, 1);
    }

    #[test]
    fn round_robin_beyond_capacity_thrashes() {
        // The Fig 5 mechanism: N+1 QPs round-robin over an N-entry cache
        // => ~0% hit rate with LRU.
        let mut c = IcmCache::new(100);
        for round in 0..10 {
            for q in 0..101u32 {
                let hit = c.touch(IcmKey::Qpc(q));
                if round > 0 {
                    assert!(!hit, "round {round} qp {q} unexpectedly hit");
                }
            }
        }
        assert!(c.hit_rate() < 0.01);
    }

    #[test]
    fn shared_qps_stay_hot_under_same_load() {
        // RaaS working set: 3 QPs in a 400-entry cache => ~100% hits.
        let mut c = IcmCache::new(400);
        for _ in 0..1000 {
            for q in 0..3u32 {
                c.touch(IcmKey::Qpc(q));
            }
        }
        assert!(c.hit_rate() > 0.99);
    }

    #[test]
    fn mixed_key_types_coexist() {
        let mut c = IcmCache::new(10);
        c.touch(IcmKey::Qpc(1));
        c.touch(IcmKey::Cqc(1));
        c.touch(IcmKey::Mtt(1, 0));
        assert_eq!(c.len(), 3);
        assert!(c.contains(&IcmKey::Qpc(1)));
        assert!(c.contains(&IcmKey::Cqc(1)));
    }

    #[test]
    fn invalidate_removes() {
        let mut c = IcmCache::new(4);
        c.touch(IcmKey::Qpc(1));
        c.invalidate(&IcmKey::Qpc(1));
        assert!(!c.contains(&IcmKey::Qpc(1)));
        assert_eq!(c.len(), 0);
        // reuse of freed slot
        c.touch(IcmKey::Qpc(2));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn stats_reset() {
        let mut c = IcmCache::new(2);
        c.touch(IcmKey::Qpc(1));
        c.reset_stats();
        assert_eq!(c.hits + c.misses + c.evictions, 0);
        assert_eq!(c.len(), 1); // contents preserved
    }
}
