//! Virtual CPU accounting: per-node core ledgers, busy-poll threads, and a
//! mutex contention model.
//!
//! Two distinct roles:
//!
//! 1. **Accounting** (Figs 7/8): every software action in the simulation
//!    charges cycles to a node's ledger; dedicated busy-poll threads charge
//!    a whole core for their lifetime. `cores_used()` converts the ledger
//!    to "cores-equivalent", the unit the paper normalizes to.
//! 2. **Contention** (Fig 6): the FaRM-style baseline serializes QP posts
//!    through a [`MutexModel`]; acquisition cost grows with the number of
//!    contending threads (cache-line bouncing), and holders serialize, so
//!    aggregate post rate degrades as q grows — exactly Fig 6's effect.

use super::time::Ns;

/// Per-node CPU ledger.
#[derive(Clone, Debug)]
pub struct CpuLedger {
    /// Physical cores on the node.
    pub cores: u32,
    /// Accumulated busy nanoseconds from discrete work items.
    pub busy_ns: u64,
    /// Number of dedicated busy-polling threads (each pins a core).
    pub polling_threads: u32,
    /// Work-item counters by class (diagnostics).
    pub post_ops: u64,
    /// poll_cq calls charged.
    pub poll_ops: u64,
    /// Bytes copied by charged memcpys.
    pub memcpy_bytes: u64,
}

impl CpuLedger {
    /// Fresh ledger for a node with `cores` cores.
    pub fn new(cores: u32) -> Self {
        CpuLedger {
            cores,
            busy_ns: 0,
            polling_threads: 0,
            post_ops: 0,
            poll_ops: 0,
            memcpy_bytes: 0,
        }
    }

    /// Charge `ns` of CPU work.
    pub fn charge(&mut self, ns: u64) {
        self.busy_ns += ns;
    }

    /// Charge a post_send/post_recv driver call.
    pub fn charge_post(&mut self, ns: u64) {
        self.post_ops += 1;
        self.charge(ns);
    }

    /// Charge a poll_cq driver call.
    pub fn charge_poll(&mut self, ns: u64) {
        self.poll_ops += 1;
        self.charge(ns);
    }

    /// memcpy at ~`bytes_per_ns` (default models ~10 GB/s single-core copy).
    pub fn charge_memcpy(&mut self, bytes: u64, bytes_per_ns: f64) {
        self.memcpy_bytes += bytes;
        self.charge((bytes as f64 / bytes_per_ns).ceil() as u64);
    }

    /// Cores-equivalent consumed over `[0, horizon]`: dedicated polling
    /// threads count fully; itemized work converts via busy time.
    pub fn cores_used(&self, horizon: Ns) -> f64 {
        let itemized = if horizon.0 == 0 {
            0.0
        } else {
            self.busy_ns as f64 / horizon.0 as f64
        };
        self.polling_threads as f64 + itemized
    }
}

/// Mutex contention model (Fig 6 baseline).
///
/// Cost model, calibrated to published lock microbenchmarks:
/// * uncontended acquire+release: ~25 ns,
/// * each additional contending thread adds ~150 ns of coherence traffic
///   (lock cache line bouncing between cores + handoff under contention —
///   see the MCS/futex handoff numbers in the locking literature),
/// * holders serialize: the lock is a single-server queue.
#[derive(Clone, Debug)]
pub struct MutexModel {
    /// Uncontended acquire+release cost.
    pub uncontended_ns: u64,
    /// Added coherence cost per extra contending thread.
    pub per_contender_ns: u64,
    /// Single-server horizon: next time the lock is free.
    free_at: Ns,
    /// Lifetime acquisitions.
    pub acquisitions: u64,
    /// Total time acquirers spent queued behind the lock.
    pub contended_ns_total: u64,
}

impl Default for MutexModel {
    fn default() -> Self {
        MutexModel {
            uncontended_ns: 25,
            per_contender_ns: 150,
            free_at: Ns(0),
            acquisitions: 0,
            contended_ns_total: 0,
        }
    }
}

impl MutexModel {
    /// Model with the default calibrated costs.
    pub fn new() -> Self {
        Self::default()
    }

    /// A thread arrives at `now` wanting the lock for `hold_ns` of work,
    /// with `q` threads total sharing this lock. Returns (start, end) of the
    /// critical section.
    pub fn acquire(&mut self, now: Ns, hold_ns: u64, q: usize) -> (Ns, Ns) {
        let overhead = self.uncontended_ns + self.per_contender_ns * (q.saturating_sub(1)) as u64;
        let start = self.free_at.max(now);
        self.contended_ns_total += start.0.saturating_sub(now.0);
        let end = start + Ns(overhead + hold_ns);
        self.free_at = end;
        self.acquisitions += 1;
        (start, end)
    }

    /// Effective service time per critical section for q contenders.
    pub fn service_ns(&self, hold_ns: u64, q: usize) -> u64 {
        self.uncontended_ns + self.per_contender_ns * (q.saturating_sub(1)) as u64 + hold_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let mut l = CpuLedger::new(24);
        l.charge_post(100);
        l.charge_poll(50);
        l.charge_memcpy(10_000, 10.0);
        assert_eq!(l.busy_ns, 100 + 50 + 1000);
        assert_eq!(l.post_ops, 1);
        assert_eq!(l.poll_ops, 1);
    }

    #[test]
    fn cores_used_counts_pollers() {
        let mut l = CpuLedger::new(24);
        l.polling_threads = 3;
        l.charge(500_000_000); // 0.5 core-seconds
        let used = l.cores_used(Ns(1_000_000_000));
        assert!((used - 3.5).abs() < 1e-9, "used={used}");
    }

    #[test]
    fn mutex_serializes() {
        let mut m = MutexModel::new();
        // two threads arrive simultaneously; second waits for first
        let (s1, e1) = m.acquire(Ns(0), 100, 2);
        let (s2, _e2) = m.acquire(Ns(0), 100, 2);
        assert_eq!(s1, Ns(0));
        assert_eq!(s2, e1);
        assert!(m.contended_ns_total > 0);
    }

    #[test]
    fn contention_grows_with_q() {
        let m = MutexModel::new();
        let s3 = m.service_ns(100, 3);
        let s6 = m.service_ns(100, 6);
        assert!(s6 > s3, "q=6 must be slower per op than q=3");
        // aggregate rate through the lock is 1/service regardless of q;
        // q only inflates service time => q=6 aggregate < q=3 aggregate.
        let rate3 = 1e9 / s3 as f64;
        let rate6 = 1e9 / s6 as f64;
        assert!(rate6 < rate3);
    }

    #[test]
    fn uncontended_fast_path() {
        let mut m = MutexModel::new();
        let (s, e) = m.acquire(Ns(1000), 50, 1);
        assert_eq!(s, Ns(1000));
        assert_eq!(e.0, 1000 + 25 + 50);
    }
}
