//! Simulated RDMA fabric — the substrate substituting for the paper's
//! 4-node ConnectX-3 RoCE testbed (see DESIGN.md §Hardware gate).
//!
//! A deterministic discrete-event simulator with nanosecond virtual time.
//! The model captures exactly the mechanisms the paper's evaluation
//! exercises:
//!
//! * **RNIC engine** ([`nic`]) — WQE fetch/processing with per-WQE and
//!   per-frame costs, doorbell batching, DMA, ACK generation.
//! * **QP-context (ICM) cache** ([`cache`]) — the finite on-NIC cache whose
//!   thrashing beyond ~400 QPs causes Fig 5's throughput collapse.
//! * **Transports** ([`qp`]) — RC / UC / UD with the capability matrix of
//!   Table 1 enforced (UC: no READ; UD: max message = MTU).
//! * **Links** ([`switchfab`]) — 40 Gb/s full-duplex ports, MTU framing,
//!   per-frame wire overhead, propagation; a non-blocking switch — or,
//!   with [`topo`] installed, a multi-switch fat-tree/Clos with
//!   oversubscribed uplinks, ECN/DCQCN congestion control, and a PFC
//!   pause ablation.
//! * **Verbs** ([`verbs`]) — an ibverbs-like façade (`post_send`,
//!   `post_recv`, `poll_cq`, …) the RaaS layer and baselines are written
//!   against, exactly as the real prototype is written against libibverbs.
//! * **CPU ledger** ([`cpu`]) — virtual per-core accounting including a
//!   mutex contention model (Fig 6) and busy-poll thread costs (Fig 8).
//!
//! Everything is seeded and replayable; two runs with the same config
//! produce bit-identical results.

pub mod time;
pub mod event;
pub mod fault;
pub mod types;
pub mod mr;
pub mod wqe;
pub mod cq;
pub mod srq;
pub mod qp;
pub mod cache;
pub mod switchfab;
pub mod topo;
pub mod cpu;
pub mod nic;
mod shard;
pub mod sim;
pub mod verbs;

pub use sim::{FabricConfig, Sim};
pub use topo::{CcMode, TopoConfig};
pub use types::{NodeId, QpTransport, Verb};
