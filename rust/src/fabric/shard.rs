//! One simulator shard: a node partition with its own timing wheel.
//!
//! The sharded simulator ([`super::sim::Sim`]) partitions the cluster's
//! nodes round-robin over `P` shards ([`NodeId::shard_of`]). Each shard
//! owns the full NIC engine state of its nodes — QPs, CQs, SRQs, ICM
//! cache, engine queue, requester bookkeeping — plus its nodes' **egress**
//! ports and a per-destination-node fork of the fault plan. A shard
//! advances its own wheel through one conservative window
//! (`[start, start+W)`, `W = switch_latency_ns.max(1)`) at a time via
//! [`Shard::run_window`], completely independently of its peers.
//!
//! Everything that would touch another node crosses the shard boundary as
//! **staged data**, never as a direct mutation:
//!
//! * data/ACK/NAK frames → [`StagedFrame`]s in [`Shard::out_wire`],
//!   absorbed into the destination's ingress port (coordinator-owned) at
//!   the next barrier and pushed into the destination shard's wheel;
//! * RC retry-exhaustion sequence resyncs → [`Resync`]s in
//!   [`Shard::out_resync`], applied (as a `max`) at the next barrier;
//! * driver notifications → `(time, node, note)` triples in
//!   [`Shard::out_notes`], merged by `(time, node)` at the barrier.
//!
//! Lookahead safety: a frame staged at shard-local time `t` has
//! `link_at >= t + switch_latency_ns`, i.e. at or after the end of the
//! current window — so no event a shard executes inside a window can
//! depend on anything any other shard does in that same window. That is
//! the whole conservative-PDES argument; DESIGN.md §13 spells it out.

use std::collections::{HashMap, HashSet, VecDeque};

use super::cache::{IcmCache, IcmKey};
use super::cpu::CpuLedger;
use super::cq::Cq;
use super::event::EventQueue;
use super::fault::{FaultAction, FaultConfig, FaultState, FaultStats};
use super::mr::MrTable;
use super::nic::{Frame, FrameKind, WorkItem, CTRL_FRAME_BYTES};
use super::qp::{PostError, Qp};
use super::sim::{FabricConfig, Notification};
use super::srq::Srq;
use super::switchfab::{Port, FRAME_OVERHEAD_BYTES, SWITCH_BUFFER_BYTES};
use super::time::{wire_time, Ns};
use super::topo::{ecmp_hash, pick_uplink, CcMode};
use super::types::{Cqn, DenseTable, NodeId, QpTransport, Qpn, Srqn, Verb, WcStatus};
use super::wqe::{Cqe, CqeKind, RecvWr, SendWr};

/// Events on one shard's timeline. Node-local by construction: every
/// variant names (or carries a frame addressed to) a node this shard owns.
pub enum Event {
    /// The NIC engine should check its work queue.
    EngineCheck(NodeId),
    /// A frame's last bit arrived at its destination ingress port.
    FrameDelivered(Frame),
    /// A CQE becomes visible to the driver.
    CqeDeliver {
        /// Node owning the CQ.
        node: NodeId,
        /// The CQ.
        cqn: Cqn,
        /// The entry.
        cqe: Cqe,
    },
    /// RNR backoff expired: repost the message at the head of the SQ.
    RetrySend {
        /// Requester node.
        node: NodeId,
        /// Requester QP.
        qpn: Qpn,
        /// The message to repost.
        wr: SendWr,
    },
    /// Driver-scheduled timer (lock-grant wakeups, open-loop arrivals…).
    /// Always routed to shard 0 so its pop order is shard-count-invariant.
    AppTimer {
        /// Opaque driver token.
        token: u64,
    },
    /// A frame held back by injected delay jitter lands here; it already
    /// passed the fault gate and must not be re-drawn.
    FrameRedelivered(Frame),
    /// RC requester ACK timeout for `(msg_id, attempt)` — armed only
    /// under an installed fault plan. Stale timers (message acked, or a
    /// newer attempt in flight) no-op.
    AckTimeout {
        /// Requester node.
        node: NodeId,
        /// Requester QP.
        qpn: Qpn,
        /// The in-flight message.
        msg_id: u64,
        /// Attempt the timer was armed under.
        attempt: u32,
    },
    /// Fault-plan node soft-restart.
    NodeRestart {
        /// The restarting node.
        node: NodeId,
    },
    /// DCQCN pacer expiry: the QP's inter-message gap elapsed; try to
    /// issue again. Only ever scheduled when a Clos topology with DCQCN
    /// is installed ([`super::topo`]), so single-switch traces are
    /// byte-identical with or without this variant existing.
    CcPace {
        /// Paced requester node.
        node: NodeId,
        /// Paced QP.
        qpn: Qpn,
    },
}

impl Event {
    /// `(node, kind)` trace key: the node whose state the event mutates
    /// (timers use node 0 — they live on shard 0) and a stable per-variant
    /// discriminant. The merged `(time, node, kind)` pop trace is the
    /// shard-count-invariance witness the determinism proptest compares.
    fn trace_key(&self) -> (u32, u8) {
        match self {
            Event::EngineCheck(n) => (n.0, 0),
            Event::FrameDelivered(f) => (f.dst.0, 1),
            Event::CqeDeliver { node, .. } => (node.0, 2),
            Event::RetrySend { node, .. } => (node.0, 3),
            Event::AppTimer { .. } => (0, 4),
            Event::FrameRedelivered(f) => (f.dst.0, 5),
            Event::AckTimeout { node, .. } => (node.0, 6),
            Event::NodeRestart { node } => (node.0, 7),
            Event::CcPace { node, .. } => (node.0, 8),
        }
    }
}

/// A frame that left its source shard and awaits barrier absorption into
/// the destination ingress port. `(link_at, frame.src, emit)` is a total
/// order: per source node `link_at` never decreases (egress serialization)
/// and `emit` strictly increases, so the coordinator's merge is
/// deterministic under every shard count.
pub struct StagedFrame {
    /// First-bit-at-destination time (`tx_start + switch_latency`).
    pub link_at: Ns,
    /// Per-source-node emission counter (tie-break within one `link_at`).
    pub emit: u64,
    /// The frame itself (`frame.src`/`frame.dst` carry the endpoints).
    pub frame: Frame,
}

/// A staged RC sequence resync: after a requester exhausts its retry
/// budget, the responder's `expected_msg_seq` is advanced (as a `max`, so
/// application order cannot matter) past every issued sequence. Crosses
/// the barrier like a frame because the peer may live on another shard.
pub struct Resync {
    /// Shard-local time the retry budget died.
    pub at: Ns,
    /// Requester node (sort tie-break).
    pub src: NodeId,
    /// Per-source-node emission counter (shared with frames).
    pub emit: u64,
    /// Responder node.
    pub peer: NodeId,
    /// Responder QP.
    pub peer_qpn: Qpn,
    /// The requester's next unissued sequence.
    pub next_seq: u64,
}

/// Per-message requester-side bookkeeping (ACK matching, RNR retry,
/// go-back-N retransmission).
struct InFlight {
    wr: SendWr,
    qpn: Qpn,
    /// Go-back-N sequence assigned at first issue; retransmissions reuse
    /// it (the responder's dedup key).
    msg_seq: u64,
    /// Transmissions so far minus one. An [`Event::AckTimeout`] only acts
    /// when its recorded attempt still matches.
    attempt: u32,
    /// Fault mode, READs only: which response-frame indices have arrived
    /// (bitmap for responses of <= 64 frames, plain count above that) —
    /// the last response frame only completes the READ when the response
    /// arrived with no holes.
    resp_seen: u64,
}

/// One machine.
pub struct NodeState {
    /// This node's id.
    pub id: NodeId,
    /// Queue pairs, dense-indexed by QPN.
    pub qps: DenseTable<Qp>,
    /// Completion queues, dense-indexed by CQN.
    pub cqs: DenseTable<Cq>,
    /// Shared receive queues, dense-indexed by SRQN.
    pub srqs: DenseTable<Srq>,
    /// Registered memory regions.
    pub mrs: MrTable,
    /// The NIC's on-chip context cache (Fig 5's mechanism).
    pub cache: IcmCache,
    /// Per-node CPU accounting.
    pub cpu: CpuLedger,
    engine_busy_until: Ns,
    engine_queue: VecDeque<WorkItem>,
    engine_scheduled: bool,
    next_msg_id: u64,
    /// Requester-side in-flight messages keyed by msg_id.
    inflight: HashMap<u64, InFlight>,
    /// Responder-side recv WQE held from first to last frame of a message,
    /// keyed by (src node, src qpn, msg id).
    pending_recv: HashMap<(u32, u32, u64), RecvWr>,
    /// Fault mode only: data frames of a multi-frame RC message seen so
    /// far, keyed like `pending_recv`. The last frame only completes the
    /// message when every frame of one attempt arrived — a lost MIDDLE
    /// frame must not ACK a message with a hole in it.
    rc_frames_seen: HashMap<(u32, u32, u64), u64>,
    /// Messages dropped mid-flight (RNR/protection) — suppress completion.
    dropped_msgs: HashSet<(u32, u32, u64)>,
    /// Counters.
    pub protection_errors: u64,
    /// RNR NAKs this node's NIC generated.
    pub rnr_naks_sent: u64,
    /// RC message retransmissions this node's NIC performed (requester
    /// side; go-back-N under an installed fault plan).
    pub retransmits: u64,
    /// RC messages that exhausted their retry budget and completed with
    /// [`WcStatus::RetryExceeded`].
    pub retry_exceeded: u64,
    /// RC data frames discarded by the responder's go-back-N discipline
    /// (sequence ahead of the expected one — an earlier message is lost).
    pub gbn_discards: u64,
    /// RC last-frames that arrived with earlier frames of their attempt
    /// missing: the message was NOT delivered or ACKed (the requester
    /// retransmits the whole message instead).
    pub rc_incomplete_msgs: u64,
    /// Duplicate RC messages re-ACKed without re-delivery (the original
    /// ACK was lost; exactly-once delivery held).
    pub gbn_dup_acks: u64,
    /// Fault-plan soft-restarts executed on this node.
    pub restarts: u64,
    /// Payload bytes of data-bearing frames processed by this NIC's rx
    /// path — the smooth wire-level goodput counter the scenario drivers
    /// measure (message-completion counters clump and bias short windows).
    pub rx_data_bytes: u64,
    /// Frames that arrived addressed to a destroyed QP and died at the
    /// NIC (tenant-isolation counter for the QP reuse pool).
    pub frames_to_destroyed: u64,
    /// Blackhole-detector firings on this node's QPs: `blackhole_k`
    /// consecutive ACK timeouts on one QP re-salted its ECMP pick
    /// (DESIGN.md §15). Zero unless a Clos topology with `repath` is on.
    pub repaths: u64,
}

impl NodeState {
    pub(crate) fn new(id: NodeId, cfg: &FabricConfig) -> Self {
        NodeState {
            id,
            qps: DenseTable::new(),
            cqs: DenseTable::new(),
            srqs: DenseTable::new(),
            mrs: MrTable::new(),
            cache: IcmCache::new(cfg.nic.icm_cache_entries),
            cpu: CpuLedger::new(cfg.cores_per_node),
            engine_busy_until: Ns::ZERO,
            engine_queue: VecDeque::new(),
            engine_scheduled: false,
            next_msg_id: 1,
            inflight: HashMap::new(),
            pending_recv: HashMap::new(),
            rc_frames_seen: HashMap::new(),
            dropped_msgs: HashSet::new(),
            protection_errors: 0,
            rnr_naks_sent: 0,
            retransmits: 0,
            retry_exceeded: 0,
            gbn_discards: 0,
            rc_incomplete_msgs: 0,
            gbn_dup_acks: 0,
            restarts: 0,
            rx_data_bytes: 0,
            frames_to_destroyed: 0,
            repaths: 0,
        }
    }

    /// Engine work-queue depth (diagnostics).
    pub fn engine_queue_len(&self) -> usize {
        self.engine_queue.len()
    }

    /// Total fabric-level memory charged to this node (ledger for Fig 7):
    /// QP rings + contexts, CQ rings, SRQ rings, registered regions' MTT.
    pub fn fabric_mem_bytes(&self) -> u64 {
        let qp: u64 = self.qps.iter().map(|q| q.mem_bytes()).sum();
        let cq: u64 = self.cqs.iter().map(|c| c.mem_bytes()).sum();
        let srq: u64 = self.srqs.iter().map(|s| s.mem_bytes()).sum();
        let mtt = self.mrs.total_mtt_entries * 8; // 8 B per MTT entry
        qp + cq + srq + mtt
    }
}

/// One shard: a node partition, its timing wheel, its egress ports, and
/// the staging buffers the coordinator drains at every barrier.
pub struct Shard {
    /// This shard's index in `0..nshards`.
    pub id: usize,
    nshards: usize,
    /// Owned copy of the cluster config (makes `run_window` self-contained
    /// so the worker pool can run shards without borrowing the `Sim`).
    cfg: FabricConfig,
    clock: Ns,
    events: EventQueue<Event>,
    /// Local node state, indexed by `NodeId::shard_local`.
    nodes: Vec<NodeState>,
    /// Egress ports of the local nodes (same local indexing).
    egress: Vec<Port>,
    /// Barrier snapshot of EVERY node's ingress busy horizon (global
    /// indexing) — the PFC gate input; refreshed by the coordinator.
    ingress_snap: Vec<Ns>,
    /// Barrier snapshot of every Clos ToR-uplink port's busy horizon
    /// (`tor * uplinks + u` indexing, mirroring [`super::topo::Clos`]).
    /// Empty unless a topology in [`CcMode::Pfc`] is installed — the
    /// host-side pause gate that chains switch backpressure down to the
    /// sending NIC. Refreshed by the coordinator at every barrier.
    uplink_snap: Vec<Ns>,
    /// Barrier snapshot of the Clos routing mask ([`super::topo::Clos::route_live`],
    /// same `tor * uplinks + u` indexing): which uplinks the converged
    /// route tables still use. Shards consult it so host-side path picks
    /// (the PFC uplink gate) agree with the switch's own rendezvous pick.
    /// Empty until a topology is installed; refreshed by the coordinator
    /// only when the route epoch changes.
    route_live: Vec<bool>,
    /// Per-local-node fault-plan forks (None entries without a plan).
    faults: Vec<Option<FaultState>>,
    faults_on: bool,
    /// Per-local-node emission counters (frame/resync staging tie-break).
    emit_seq: Vec<u64>,
    /// Events this shard has popped.
    pub steps: u64,
    /// Completed payload bytes (data verbs) on this shard's nodes.
    pub completed_bytes: u64,
    /// Completed data messages on this shard's nodes.
    pub completed_msgs: u64,
    /// Frames the fault layer discarded on this shard's nodes.
    pub wire_drops: u64,
    /// Staged outbound frames, drained by the coordinator at the barrier.
    pub out_wire: Vec<StagedFrame>,
    /// Staged RC sequence resyncs, drained at the barrier.
    pub out_resync: Vec<Resync>,
    /// Buffered driver notifications `(event time, node, note)`, merged
    /// by `(time, node)` at the barrier.
    pub out_notes: Vec<(Ns, NodeId, Notification)>,
    /// Optional `(time, node, kind)` pop trace (determinism proptest).
    trace: Option<Vec<(u64, u32, u8)>>,
}

impl Shard {
    /// Build shard `id` of `nshards` for `cfg`: owns every node with
    /// `node % nshards == id`, quiescent at virtual time zero.
    pub fn new(id: usize, nshards: usize, cfg: &FabricConfig) -> Self {
        let locals: Vec<NodeId> = (0..cfg.nodes as u32)
            .map(NodeId)
            .filter(|n| n.shard_of(nshards) == id)
            .collect();
        let nodes: Vec<NodeState> = locals.iter().map(|&n| NodeState::new(n, cfg)).collect();
        Shard {
            id,
            nshards,
            cfg: cfg.clone(),
            clock: Ns::ZERO,
            events: EventQueue::new(),
            egress: vec![Port::default(); nodes.len()],
            faults: (0..nodes.len()).map(|_| None).collect(),
            emit_seq: vec![0; nodes.len()],
            ingress_snap: vec![Ns::ZERO; cfg.nodes],
            uplink_snap: Vec::new(),
            route_live: match cfg.topo {
                Some(t) => vec![true; t.tors * t.uplinks()],
                None => Vec::new(),
            },
            nodes,
            // a Clos fabric drops frames at full ports (tail-drop in the
            // Dcqcn/NoCc modes), so the RC reliability machinery — go-
            // back-N sequencing, ACK timers, retransmission — must be
            // armed even without a fault plan. The fault FORKS stay None
            // (no probabilistic draws); deliver_frame skips them safely.
            faults_on: cfg.topo.is_some(),
            steps: 0,
            completed_bytes: 0,
            completed_msgs: 0,
            wire_drops: 0,
            out_wire: Vec::new(),
            out_resync: Vec::new(),
            out_notes: Vec::new(),
            trace: None,
        }
    }

    #[inline]
    fn li(&self, node: NodeId) -> usize {
        debug_assert_eq!(node.shard_of(self.nshards), self.id, "foreign node");
        node.shard_local(self.nshards)
    }

    /// State of a node this shard owns.
    pub fn node(&self, id: NodeId) -> &NodeState {
        &self.nodes[self.li(id)]
    }

    /// State of a node this shard owns, mutably.
    pub fn node_mut(&mut self, id: NodeId) -> &mut NodeState {
        let i = self.li(id);
        &mut self.nodes[i]
    }

    /// The shard's local nodes, in local (striped) order.
    pub fn local_nodes(&self) -> impl Iterator<Item = &NodeState> {
        self.nodes.iter()
    }

    /// Earliest pending event on this shard's wheel.
    pub fn peek(&self) -> Option<Ns> {
        self.events.peek_time()
    }

    /// Events pending on this shard's wheel.
    pub fn wheel_len(&self) -> usize {
        self.events.len()
    }

    /// Advance the shard clock to a barrier/deadline without running
    /// anything (the coordinator keeps every shard's clock at the global
    /// boundary so driver calls between windows see consistent time).
    pub fn sync_clock(&mut self, t: Ns) {
        self.clock = self.clock.max(t);
    }

    /// Enable/disable the `(time, node, kind)` pop trace.
    pub fn set_trace(&mut self, on: bool) {
        self.trace = if on { Some(Vec::new()) } else { None };
    }

    /// Drain this shard's pop trace into `out`.
    pub fn drain_trace_into(&mut self, out: &mut Vec<(u64, u32, u8)>) {
        if let Some(t) = self.trace.as_mut() {
            out.append(t);
        }
    }

    /// Refresh the barrier snapshot of every ingress port's busy horizon.
    pub fn set_ingress_snap(&mut self, snap: &[Ns]) {
        self.ingress_snap.clear();
        self.ingress_snap.extend_from_slice(snap);
    }

    /// Refresh the barrier snapshot of every Clos ToR-uplink port's busy
    /// horizon (PFC mode only — see [`Shard::stage_frame`]'s uplink gate).
    pub fn set_uplink_snap(&mut self, snap: &[Ns]) {
        self.uplink_snap.clear();
        self.uplink_snap.extend_from_slice(snap);
    }

    /// Refresh the barrier snapshot of the Clos routing mask. Called by
    /// the coordinator whenever [`super::topo::Clos::reconverge`] bumps
    /// the route epoch, so every shard count sees the same mask at the
    /// same barrier.
    pub fn set_route_live(&mut self, live: &[bool]) {
        self.route_live.clear();
        self.route_live.extend_from_slice(live);
    }

    /// Push an absorbed cross-shard frame at its delivery time. The
    /// coordinator calls this in global `(link_at, src, emit)` order, so
    /// same-instant deliveries pop in that order on every shard count.
    pub fn push_frame(&mut self, deliver: Ns, frame: Frame) {
        self.events.push(deliver, Event::FrameDelivered(frame));
    }

    /// Schedule a driver timer (shard 0 only — see [`Event::AppTimer`]).
    pub fn push_timer(&mut self, at: Ns, token: u64) {
        debug_assert_eq!(self.id, 0, "timers live on shard 0");
        self.events.push(at, Event::AppTimer { token });
    }

    /// Schedule a fault-plan soft-restart of a local node.
    pub fn push_restart(&mut self, at: Ns, node: NodeId) {
        debug_assert_eq!(node.shard_of(self.nshards), self.id);
        self.events.push(at, Event::NodeRestart { node });
    }

    /// Apply a barrier-delivered RC sequence resync (max-merge, so the
    /// application order of same-window resyncs cannot matter).
    pub fn apply_resync(&mut self, peer: NodeId, peer_qpn: Qpn, next_seq: u64) {
        if let Some(pq) = self.node_mut(peer).qps.get_mut(peer_qpn.0) {
            pq.expected_msg_seq = pq.expected_msg_seq.max(next_seq);
        }
    }

    /// Install the per-local-node fault-plan forks and the fault gate.
    pub fn install_fault_forks(&mut self, cfg: &FaultConfig) {
        for (i, slot) in self.faults.iter_mut().enumerate() {
            let node = self.nodes[i].id;
            *slot = Some(FaultState::for_node(cfg, node));
        }
        self.faults_on = true;
    }

    /// Fold this shard's fault counters (local-node order) into `into`.
    pub fn fold_fault_stats(&self, into: &mut FaultStats) {
        for f in self.faults.iter().flatten() {
            into.absorb(&f.stats);
        }
    }

    // ------------------------------------------------------------ window

    /// Run every event strictly before `end`, then park the clock at the
    /// barrier. Cross-shard effects land in the staging buffers; the
    /// lookahead bound guarantees nothing staged here is consumable
    /// before `end` (see the module docs).
    pub fn run_window(&mut self, end: Ns) {
        while let Some(t) = self.events.peek_time() {
            if t >= end {
                break;
            }
            let (at, ev) = self.events.pop().expect("peeked event");
            debug_assert!(at >= self.clock, "time went backwards");
            self.clock = at;
            self.steps += 1;
            if let Some(tr) = self.trace.as_mut() {
                let (node, kind) = ev.trace_key();
                tr.push((at.0, node, kind));
            }
            match ev {
                Event::EngineCheck(node) => self.on_engine_check(node),
                Event::FrameDelivered(frame) => self.deliver_frame(frame, true),
                Event::FrameRedelivered(frame) => self.deliver_frame(frame, false),
                Event::CqeDeliver { node, cqn, cqe } => {
                    let pushed = match self.node_mut(node).cqs.get_mut(cqn.0) {
                        Some(cq) => {
                            cq.push(cqe);
                            true
                        }
                        None => false,
                    };
                    if pushed {
                        self.out_notes.push((at, node, Notification::CqeReady { node, cqn }));
                    }
                }
                Event::RetrySend { node, qpn, wr } => {
                    // RNR retry: put the message back at the head of the SQ.
                    if let Some(qp) = self.node_mut(node).qps.get_mut(qpn.0) {
                        qp.sq.push_front(wr);
                    }
                    self.rearm_issue(node, qpn);
                }
                Event::AppTimer { token } => {
                    self.out_notes.push((at, NodeId(0), Notification::Timer { token }));
                }
                Event::AckTimeout { node, qpn, msg_id, attempt } => {
                    self.on_ack_timeout(node, qpn, msg_id, attempt)
                }
                Event::NodeRestart { node } => self.on_node_restart(node),
                Event::CcPace { node, qpn } => self.rearm_issue(node, qpn),
            }
        }
        self.clock = end;
    }

    // ---------------------------------------------------- wire staging

    /// Number of MTU-sized frames a `len`-byte message needs.
    #[inline]
    fn frame_count(&self, len: u64) -> u64 {
        len.div_ceil(self.cfg.mtu).max(1)
    }

    /// Payload bytes of frame `i` of an `n`-frame, `len`-byte message.
    #[inline]
    fn frame_bytes(&self, len: u64, i: u64, n: u64) -> u64 {
        if i + 1 < n {
            self.cfg.mtu
        } else {
            len - (n - 1) * self.cfg.mtu
        }
    }

    /// Egress half of the split wire model: occupy the source's (shard-
    /// owned) egress port no earlier than `earliest`, gated by the PFC
    /// snapshot of the destination's ingress backlog, and stage the frame
    /// with its first-bit-at-destination time. Returns that `link_at`;
    /// the ingress half happens at the barrier ([`StagedFrame`]).
    fn stage_frame(&mut self, earliest: Ns, frame: Frame) -> Ns {
        debug_assert!(frame.bytes <= self.cfg.mtu, "frame exceeds MTU");
        let wire_bytes = frame.bytes + FRAME_OVERHEAD_BYTES;
        let frame_time = wire_time(wire_bytes, self.cfg.link_gbps);
        let base = Ns(self.cfg.switch_latency_ns);
        // PFC backpressure against the barrier snapshot: within a window
        // the true ingress horizon can only grow by what this window's
        // frames add AFTER the snapshot — those arrive next window, so
        // gating on the snapshot is exact for everything already absorbed.
        let buffer_time = wire_time(SWITCH_BUFFER_BYTES, self.cfg.link_gbps);
        let mut pfc_gate =
            self.ingress_snap[frame.dst.0 as usize].saturating_sub(buffer_time + base);
        // Clos PFC mode: the first-hop pause chains down to the host NIC.
        // Gate on the barrier snapshot of the ToR-uplink port this frame's
        // rendezvous pick selects — same window-exactness argument as
        // above (the uplink horizon only grows by frames absorbed AFTER
        // the snapshot, which arrive next window). Deterministic: both
        // snapshots (busy horizons and routing mask) are barrier-side
        // facts and the pick is pure. Dead ports snapshot as idle and the
        // mask excludes them once converged, so a paused flow can never
        // wait forever on a port that will never drain (DESIGN.md §15).
        if let Some(t) = self.cfg.topo {
            if t.mode == CcMode::Pfc && !self.uplink_snap.is_empty() {
                let hosts = t.hosts_per_tor.max(1);
                let src_tor = frame.src.0 as usize / hosts;
                let dst_tor = frame.dst.0 as usize / hosts;
                if src_tor != dst_tor {
                    let uplinks = t.uplinks();
                    let hash = ecmp_hash(frame.src, frame.dst, frame.src_qpn, frame.dst_qpn);
                    let live = &self.route_live[src_tor * uplinks..][..uplinks];
                    let u = pick_uplink(hash, frame.path_salt, live);
                    if let Some(&busy) = self.uplink_snap.get(src_tor * uplinks + u) {
                        pfc_gate = pfc_gate.max(busy.saturating_sub(buffer_time + base));
                    }
                }
            }
        }
        let i = self.li(frame.src);
        let tx_start = self.egress[i].busy_until().max(earliest).max(pfc_gate);
        self.egress[i].occupy(tx_start, frame_time, wire_bytes);
        let link_at = tx_start + base;
        let emit = self.emit_seq[i];
        self.emit_seq[i] += 1;
        self.out_wire.push(StagedFrame { link_at, emit, frame });
        link_at
    }

    /// Estimated delivery time of a frame whose first bit lands at
    /// `link_at`: one ingress serialization later, assuming no fan-in
    /// backlog. Used for requester-side ACK-timeout ETAs only (a source-
    /// local estimate — the true ingress time is a barrier-side fact).
    fn est_deliver(&self, link_at: Ns, bytes: u64) -> Ns {
        link_at + wire_time(bytes + FRAME_OVERHEAD_BYTES, self.cfg.link_gbps)
    }

    /// Engine backpressure: extra stall (ns) before the engine can hand the
    /// next frame to the egress port, given the tx FIFO depth.
    fn tx_stall(&self, node: NodeId, at: Ns) -> u64 {
        let fifo = Ns(self.cfg.nic.tx_fifo_frames
            * wire_time(self.cfg.mtu + FRAME_OVERHEAD_BYTES, self.cfg.link_gbps).0);
        let backlog = self.egress[self.li(node)].busy_until().saturating_sub(at);
        backlog.saturating_sub(fifo).0
    }

    /// ICM cache touch: returns the stall cost (0 on hit).
    fn icm_touch(&mut self, node: NodeId, key: IcmKey) -> u64 {
        let miss_ns = self.cfg.nic.icm_miss_ns;
        if self.node_mut(node).cache.touch(key) {
            0
        } else {
            miss_ns
        }
    }

    // ----------------------------------------------------- driver calls

    /// Post a send WR and ring the doorbell. Charges driver CPU.
    pub fn post_send(&mut self, node: NodeId, qpn: Qpn, wr: SendWr) -> Result<(), PostError> {
        let mtu = self.cfg.mtu;
        let post_cpu = self.cfg.post_cpu_ns;
        let n = self.node_mut(node);
        n.cpu.charge_post(post_cpu);
        let qp = n.qps.get_mut(qpn.0).ok_or(PostError::BadState(super::qp::QpState::Error))?;
        qp.post_send(wr, mtu)?;
        self.ring_doorbell(node, qpn);
        Ok(())
    }

    /// Post a chain of WRs with ONE doorbell (WR batching).
    pub fn post_send_batch(
        &mut self,
        node: NodeId,
        qpn: Qpn,
        wrs: Vec<SendWr>,
    ) -> Result<usize, PostError> {
        let mtu = self.cfg.mtu;
        let post_cpu = self.cfg.post_cpu_ns;
        let n = self.node_mut(node);
        // one syscall-ish driver cost + small per-WR marshalling cost
        n.cpu.charge_post(post_cpu + 30 * wrs.len() as u64);
        let qp = n.qps.get_mut(qpn.0).ok_or(PostError::BadState(super::qp::QpState::Error))?;
        let mut accepted = 0;
        for wr in wrs {
            match qp.post_send(wr, mtu) {
                Ok(()) => accepted += 1,
                Err(e) => {
                    if accepted == 0 {
                        return Err(e);
                    }
                    break;
                }
            }
        }
        self.ring_doorbell(node, qpn);
        Ok(accepted)
    }

    /// Post a receive WR on a QP's private RQ. Charges driver CPU.
    pub fn post_recv(&mut self, node: NodeId, qpn: Qpn, wr: RecvWr) -> Result<(), PostError> {
        let post_cpu = self.cfg.post_cpu_ns;
        let n = self.node_mut(node);
        n.cpu.charge_post(post_cpu);
        n.qps
            .get_mut(qpn.0)
            .ok_or(PostError::BadState(super::qp::QpState::Error))?
            .post_recv(wr)
    }

    /// Post a receive WR on an SRQ; false when full. Charges driver CPU.
    pub fn post_srq_recv(&mut self, node: NodeId, srqn: Srqn, wr: RecvWr) -> bool {
        let post_cpu = self.cfg.post_cpu_ns;
        let n = self.node_mut(node);
        n.cpu.charge_post(post_cpu);
        n.srqs.get_mut(srqn.0).map(|s| s.post(wr)).unwrap_or(false)
    }

    /// Poll up to `max` CQEs into `out` (appended); returns the count.
    /// Charges poller CPU.
    pub fn poll_cq_into(&mut self, node: NodeId, cqn: Cqn, max: usize, out: &mut Vec<Cqe>) -> usize {
        let (poll_cpu, per_cqe) = (self.cfg.poll_cpu_ns, self.cfg.per_cqe_cpu_ns);
        let n = self.node_mut(node);
        let got = match n.cqs.get_mut(cqn.0) {
            Some(cq) => cq.poll_into(max, out),
            None => 0,
        };
        n.cpu.charge_poll(poll_cpu + per_cqe * got as u64);
        got
    }

    // -------------------------------------------------------------- engine

    fn ring_doorbell(&mut self, node: NodeId, qpn: Qpn) {
        let nic_doorbell = self.cfg.nic.doorbell_ns;
        let clock = self.clock;
        let n = self.node_mut(node);
        let Some(qp) = n.qps.get_mut(qpn.0) else { return };
        if !qp.issue_armed {
            qp.issue_armed = true;
            n.engine_queue.push_back(WorkItem::IssueFromQp(qpn));
            // doorbell MMIO handling occupies the engine briefly
            n.engine_busy_until = n.engine_busy_until.max(clock) + Ns(nic_doorbell);
            self.kick_engine(node);
        }
    }

    fn kick_engine(&mut self, node: NodeId) {
        let clock = self.clock;
        let n = self.node_mut(node);
        if !n.engine_scheduled && !n.engine_queue.is_empty() {
            n.engine_scheduled = true;
            let at = n.engine_busy_until.max(clock);
            self.events.push(at, Event::EngineCheck(node));
        }
    }

    /// Re-arm a QP's issue item after a completion freed window space.
    fn rearm_issue(&mut self, node: NodeId, qpn: Qpn) {
        let n = self.node_mut(node);
        let Some(qp) = n.qps.get_mut(qpn.0) else { return };
        if qp.can_issue() && !qp.issue_armed {
            qp.issue_armed = true;
            n.engine_queue.push_back(WorkItem::IssueFromQp(qpn));
            self.kick_engine(node);
        }
    }

    fn on_engine_check(&mut self, node: NodeId) {
        {
            let clock = self.clock;
            let n = self.node_mut(node);
            n.engine_scheduled = false;
            if clock < n.engine_busy_until {
                // engine still busy (doorbell bumped the horizon): re-check.
                self.kick_engine(node);
                return;
            }
        }
        let item = match self.node_mut(node).engine_queue.pop_front() {
            Some(i) => i,
            None => return,
        };
        let cost = self.process_item(node, item);
        let clock = self.clock;
        let n = self.node_mut(node);
        n.engine_busy_until = clock + Ns(cost);
        self.kick_engine(node);
    }

    /// Execute one engine work item; returns engine occupancy in ns.
    fn process_item(&mut self, node: NodeId, item: WorkItem) -> u64 {
        match item {
            WorkItem::IssueFromQp(qpn) => self.issue_from_qp(node, qpn),
            WorkItem::RxFrame(frame) => self.rx_frame(node, frame),
            WorkItem::ReadRespond {
                requester,
                requester_qpn,
                responder_qpn,
                msg_id,
                len,
                wr_id,
                idx,
                path_salt,
            } => self.read_respond(
                node,
                requester,
                requester_qpn,
                responder_qpn,
                msg_id,
                len,
                wr_id,
                idx,
                path_salt,
            ),
            WorkItem::Retransmit { qpn, msg_id } => self.retransmit_msg(node, qpn, msg_id),
        }
    }

    // -------------------------------------------------- requester-side tx

    /// Issue ONE message from this QP's send queue, then re-enqueue the
    /// issue item. Every frame of a multi-frame message stages eagerly
    /// (port state advances at issue time, exactly like the retransmit
    /// path) — the barrier absorbs them in global order.
    fn issue_from_qp(&mut self, node: NodeId, qpn: Qpn) -> u64 {
        let nic = self.cfg.nic;
        let cc = self.cfg.topo.filter(|t| t.mode == CcMode::Dcqcn);

        // DCQCN pacing gate: advance the lazy rate-recovery clock, then —
        // if this QP's inter-message gap has not elapsed — park the issue
        // until the pacer expires, WITHOUT popping the WR or mutating any
        // window state. A duplicate [`Event::CcPace`] (a completion can
        // re-arm the QP before the pacer fires) is a harmless no-op.
        let paced = {
            let clock = self.clock;
            let n = self.node_mut(node);
            let qp = match n.qps.get_mut(qpn.0) {
                Some(qp) => qp,
                None => return 0,
            };
            qp.issue_armed = false;
            if !qp.can_issue() {
                return 0; // window-blocked; re-armed on completion
            }
            if cc.is_some() && qp.transport == QpTransport::Rc {
                if let Some(t) = cc {
                    qp.cc_advance(clock, t.cc_recovery_ns, t.cc_ai_frac);
                }
                if clock < qp.cc_paced_until {
                    Some(qp.cc_paced_until)
                } else {
                    None
                }
            } else {
                None
            }
        };
        if let Some(at) = paced {
            self.events.push(at, Event::CcPace { node, qpn });
            return 0;
        }

        // Pull the next WR (`can_issue` held above; nothing ran since).
        let (wr, peer, transport, msg_seq, path_salt) = {
            let n = self.node_mut(node);
            let qp = n.qps.get_mut(qpn.0).expect("checked above");
            let wr = qp.sq.pop_front().unwrap();
            let peer = match qp.transport {
                QpTransport::Ud => wr.ud_dest,
                _ => qp.peer,
            };
            let msg_seq = if qp.transport == QpTransport::Rc {
                qp.outstanding += 1;
                let s = qp.next_msg_seq;
                qp.next_msg_seq += 1;
                s
            } else {
                0
            };
            (wr, peer, qp.transport, msg_seq, qp.path_salt)
        };
        let (peer_node, peer_qpn) = match peer {
            Some(p) => p,
            None => return nic.engine_wqe_ns, // unroutable; swallow
        };

        let mut cost = nic.engine_wqe_ns + nic.dma_setup_ns;
        cost += self.icm_touch(node, IcmKey::Qpc(qpn.0));
        // local buffer translation (MTT) once per message
        if let Some(block) = self.node(node).mrs.mtt_block(wr.lkey, wr.laddr) {
            cost += self.icm_touch(node, IcmKey::Mtt(wr.lkey.0, block));
        }

        let msg_id = {
            let n = self.node_mut(node);
            let id = n.next_msg_id;
            n.next_msg_id += 1;
            id
        };

        // DCQCN pacer charge input: this message's ideal wire occupancy
        // (payload + per-frame overhead at line rate). READs charge their
        // response size — the bytes they pull through the fabric.
        let pace_wire_ns = if cc.is_some() && transport == QpTransport::Rc {
            let payload = wr.len.max(1);
            let frames = self.frame_count(payload);
            wire_time(payload + frames * FRAME_OVERHEAD_BYTES, self.cfg.link_gbps).0
        } else {
            0
        };

        match wr.verb {
            Verb::Read => {
                // header-only request; the responder streams the data back.
                let frame = Frame {
                    kind: FrameKind::ReadReq,
                    src: node,
                    dst: peer_node,
                    dst_qpn: peer_qpn,
                    src_qpn: qpn,
                    transport,
                    msg_id,
                    msg_seq,
                    frame_idx: 0,
                    bytes: CTRL_FRAME_BYTES,
                    msg_len: wr.len,
                    is_first: true,
                    is_last: true,
                    wr_id: wr.wr_id,
                    imm: None,
                    rkey: wr.rkey,
                    raddr: wr.raddr,
                    ecn: false,
                    path_salt,
                };
                cost += nic.engine_frame_ns;
                let link_at = self.stage_frame(self.clock + Ns(cost), frame);
                let eta = self.est_deliver(link_at, frame.bytes) + self.read_response_eta(wr.len);
                self.node_mut(node)
                    .inflight
                    .insert(msg_id, InFlight { wr, qpn, msg_seq, attempt: 0, resp_seen: 0 });
                self.arm_rc_timer(node, qpn, msg_id, 0, eta);
            }
            Verb::Write | Verb::Send => {
                let kind = if wr.verb == Verb::Write {
                    FrameKind::WriteData
                } else {
                    FrameKind::SendData
                };
                let payload_len = wr.len.max(1);
                let total = self.frame_count(payload_len);
                let template = Frame {
                    kind,
                    src: node,
                    dst: peer_node,
                    dst_qpn: peer_qpn,
                    src_qpn: qpn,
                    transport,
                    msg_id,
                    msg_seq,
                    frame_idx: 0, // set per frame below
                    bytes: 0,     // set per frame below
                    msg_len: wr.len,
                    is_first: false,
                    is_last: false,
                    wr_id: wr.wr_id,
                    imm: wr.imm_data,
                    rkey: wr.rkey,
                    raddr: wr.raddr,
                    ecn: false,
                    path_salt,
                };
                let mut handoff = self.clock + Ns(cost);
                let mut last_link = self.clock;
                let mut last_bytes = 0;
                for i in 0..total {
                    cost += nic.engine_frame_ns;
                    handoff += Ns(nic.engine_frame_ns);
                    // tx FIFO backpressure (see read_respond)
                    let stall = self.tx_stall(node, handoff);
                    cost += stall;
                    handoff += Ns(stall);
                    let mut frame = template;
                    frame.frame_idx = i;
                    frame.bytes = self.frame_bytes(payload_len, i, total);
                    frame.is_first = i == 0;
                    frame.is_last = i + 1 == total;
                    last_bytes = frame.bytes;
                    last_link = self.stage_frame(handoff, frame);
                }
                match transport {
                    QpTransport::Rc => {
                        // completion on ACK
                        let done = self.est_deliver(last_link, last_bytes);
                        self.node_mut(node)
                            .inflight
                            .insert(msg_id, InFlight { wr, qpn, msg_seq, attempt: 0, resp_seen: 0 });
                        self.arm_rc_timer(node, qpn, msg_id, 0, done);
                    }
                    QpTransport::Uc | QpTransport::Ud => {
                        // local completion once the message is on the wire
                        if wr.signaled {
                            let send_cq = self.node(node).qps[qpn.0].send_cq;
                            let cqe = Cqe {
                                wr_id: wr.wr_id,
                                kind: CqeKind::SendDone(wr.verb),
                                status: WcStatus::Success,
                                len: wr.len,
                                imm_data: None,
                                qpn,
                                src: None,
                            };
                            let at = self.clock + Ns(cost + nic.cqe_delay_ns);
                            let cqc = self.icm_touch(node, IcmKey::Cqc(send_cq.0));
                            cost += cqc;
                            self.events
                                .push(at + Ns(cqc), Event::CqeDeliver { node, cqn: send_cq, cqe });
                            self.node_mut(node).qps.get_mut(qpn.0).unwrap().completed += 1;
                        }
                    }
                }
            }
        }

        // DCQCN pacer charge: this QP's NEXT message may not issue before
        // this one's wire time, stretched by the current rate cut, has
        // elapsed. Message-granularity rate limiting on the QP itself —
        // never dead time on the shared egress port, so co-located QPs
        // pace independently (no head-of-line blocking between tenants).
        if pace_wire_ns > 0 {
            let clock = self.clock;
            if let Some(qp) = self.node_mut(node).qps.get_mut(qpn.0) {
                let gap = (pace_wire_ns as f64 / qp.cc_rate.max(1e-6)) as u64;
                qp.cc_paced_until = qp.cc_paced_until.max(clock) + Ns(gap);
            }
        }

        // round-robin: more WQEs pending? re-arm at the tail.
        self.rearm_issue(node, qpn);
        cost
    }

    // -------------------------------------------------- responder-side

    /// Stream ONE frame of a READ response per engine pass; re-enqueue the
    /// job until done. This interleaves concurrent responses frame-by-frame
    /// (the access pattern that thrashes the requester's ICM cache).
    #[allow(clippy::too_many_arguments)]
    fn read_respond(
        &mut self,
        node: NodeId,
        requester: NodeId,
        requester_qpn: Qpn,
        responder_qpn: Qpn,
        msg_id: u64,
        remaining: u64,
        wr_id: u64,
        idx: u64,
        path_salt: u32,
    ) -> u64 {
        let nic = self.cfg.nic;
        let mtu = self.cfg.mtu;
        // note: `remaining` is re-encoded in `len` across re-enqueues, so
        // msg_len on response frames tracks bytes-left; completion uses the
        // requester's in-flight record for the true length.
        let total_len = remaining;
        let bytes = remaining.min(mtu);
        let left = remaining - bytes;
        let mut cost = nic.engine_frame_ns;
        cost += self.icm_touch(node, IcmKey::Qpc(responder_qpn.0));
        // wire backpressure: stall until the tx FIFO has room — this paces
        // response streaming to line rate so concurrent responses interleave
        cost += self.tx_stall(node, self.clock + Ns(cost));

        let frame = Frame {
            kind: FrameKind::ReadResp,
            src: node,
            dst: requester,
            dst_qpn: requester_qpn,
            src_qpn: responder_qpn,
            transport: QpTransport::Rc,
            msg_id,
            msg_seq: 0,
            frame_idx: idx,
            bytes,
            msg_len: total_len,
            is_first: false,
            is_last: left == 0,
            wr_id,
            imm: None,
            rkey: None,
            raddr: 0,
            ecn: false,
            path_salt,
        };
        self.stage_frame(self.clock + Ns(cost), frame);

        if left > 0 {
            self.node_mut(node).engine_queue.push_back(WorkItem::ReadRespond {
                requester,
                requester_qpn,
                responder_qpn,
                msg_id,
                len: left,
                wr_id,
                idx: idx + 1,
                path_salt,
            });
        }
        cost
    }

    // ---------------------------------------------------------- rx path

    /// Hand a frame to its destination NIC. `check_faults` is false only
    /// for re-deliveries of jitter-delayed frames, which already passed
    /// the gate — every frame consults the fault plan exactly once, so
    /// the RNG stream stays aligned across replays.
    fn deliver_frame(&mut self, frame: Frame, check_faults: bool) {
        if self.faults_on {
            let clock = self.clock;
            let i = self.li(frame.dst);
            if check_faults {
                if let Some(f) = self.faults[i].as_mut() {
                    match f.action(clock, frame.src, frame.dst) {
                        Some(FaultAction::Drop) => {
                            // transmitted, then lost in the switch/wire:
                            // both ports already serialized it, only the
                            // delivery (and goodput) is suppressed
                            self.wire_drops += 1;
                            return;
                        }
                        Some(FaultAction::Delay(extra)) => {
                            let at = clock + extra;
                            self.events.push(at, Event::FrameRedelivered(frame));
                            return;
                        }
                        None => {}
                    }
                }
            } else if let Some(f) = self.faults[i].as_mut() {
                // jitter-redelivered frame: its probabilistic draws already
                // happened, but a flap window is a property of the link at
                // delivery time — a delayed frame landing inside one dies
                if f.flap_drop(clock, frame.src, frame.dst) {
                    self.wire_drops += 1;
                    return;
                }
            }
        }
        let dst = frame.dst;
        if frame.kind.carries_data() {
            // wire-level goodput counter: counted at delivery, not at engine
            // processing (the engine can burst-drain backlog and overshoot)
            self.node_mut(dst).rx_data_bytes += frame.bytes;
        }
        self.node_mut(dst).engine_queue.push_back(WorkItem::RxFrame(frame));
        self.kick_engine(dst);
    }

    fn rx_frame(&mut self, node: NodeId, frame: Frame) -> u64 {
        let nic = self.cfg.nic;
        let mut cost = nic.engine_frame_ns;
        // every frame needs the QP context — THE Fig 5 mechanism.
        cost += self.icm_touch(node, IcmKey::Qpc(frame.dst_qpn.0));

        // a frame addressed to a destroyed QP (torn down by the control
        // plane while stragglers were still in flight) dies at the NIC:
        // no delivery, no ACK, no CQE — a prior tenant's late traffic can
        // never surface once its QP is gone
        if self.node(node).qps.get(frame.dst_qpn.0).map(|q| q.destroyed).unwrap_or(false) {
            self.node_mut(node).frames_to_destroyed += 1;
            return cost;
        }

        match frame.kind {
            FrameKind::ReadReq => {
                // go-back-N: a READ request occupies a slot in its QP's
                // ordered message stream like any other RC message. Ahead
                // of the expected sequence → discard (an earlier message
                // is missing; the requester retransmits in order). Behind
                // it → a duplicate request whose response was lost:
                // re-execute (idempotent; the requester dedups by msg_id).
                if self.faults_on {
                    let expected = self
                        .node(node)
                        .qps
                        .get(frame.dst_qpn.0)
                        .map(|q| q.expected_msg_seq)
                        .unwrap_or(0);
                    if frame.msg_seq > expected {
                        self.node_mut(node).gbn_discards += 1;
                        return cost;
                    }
                    self.gbn_advance(node, &frame);
                }
                // validate remote access then start streaming the response
                let ok = frame
                    .rkey
                    .map(|k| self.node(node).mrs.check_remote(k, frame.raddr, frame.msg_len, false))
                    .unwrap_or(false);
                if !ok {
                    self.node_mut(node).protection_errors += 1;
                    // NAK → requester completes in error
                    self.send_nak(node, &frame);
                    return cost;
                }
                if let Some(rk) = frame.rkey {
                    if let Some(block) = self.node(node).mrs.mtt_block(rk, frame.raddr) {
                        cost += self.icm_touch(node, IcmKey::Mtt(rk.0, block));
                    }
                }
                self.node_mut(node).engine_queue.push_back(WorkItem::ReadRespond {
                    requester: frame.src,
                    requester_qpn: frame.src_qpn,
                    responder_qpn: frame.dst_qpn,
                    msg_id: frame.msg_id,
                    len: frame.msg_len,
                    wr_id: frame.wr_id,
                    idx: 0,
                    path_salt: frame.path_salt,
                });
            }
            FrameKind::ReadResp => {
                // under faults, the last frame only completes the READ
                // when every response frame actually arrived
                let complete = self.read_resp_complete(node, &frame);
                if frame.is_last && complete {
                    cost += self.complete_read(node, &frame);
                }
            }
            FrameKind::WriteData => {
                cost += self.rx_write_data(node, &frame);
            }
            FrameKind::SendData => {
                cost += self.rx_send_data(node, &frame);
            }
            FrameKind::Ack => {
                cost += self.rx_ack(node, &frame);
            }
            FrameKind::Nak => {
                // remote-error NAK from the responder: complete the
                // in-flight message at this requester in error
                self.complete_requester_error(node, frame.msg_id, WcStatus::RemoteAccessError);
            }
            FrameKind::RnrNak => {
                let key = frame.msg_id;
                if self.faults_on {
                    // fault mode: retransmit IN PLACE after the backoff —
                    // same msg_id and msg_seq, through the ACK-timeout
                    // machinery (counts against the retry budget). A
                    // re-post with a fresh sequence would leave a hole
                    // the responder's go-back-N discipline waits on
                    // forever.
                    let armed = self.node(node).inflight.get(&key).map(|inf| (inf.qpn, inf.attempt));
                    if let Some((qpn, attempt)) = armed {
                        self.events.push(
                            self.clock + Ns(nic.rnr_retry_ns),
                            Event::AckTimeout { node, qpn, msg_id: key, attempt },
                        );
                    }
                } else if let Some(inf) = self.node_mut(node).inflight.remove(&key) {
                    // lossless mode: retry the whole message after backoff
                    // by re-posting it at the head of the SQ (it re-issues
                    // with a fresh msg_id — fine when nothing is gated on
                    // sequence numbers)
                    if let Some(qp) = self.node_mut(node).qps.get_mut(inf.qpn.0) {
                        qp.outstanding = qp.outstanding.saturating_sub(1);
                    }
                    self.events.push(
                        self.clock + Ns(nic.rnr_retry_ns),
                        Event::RetrySend { node, qpn: inf.qpn, wr: inf.wr },
                    );
                }
            }
        }
        cost
    }

    fn rx_write_data(&mut self, node: NodeId, frame: &Frame) -> u64 {
        let nic = self.cfg.nic;
        let mut cost = 0;
        let (gcost, proceed) = self.gbn_admit(node, frame);
        if !proceed {
            return gcost;
        }
        let attempt_complete = self.rc_attempt_complete(node, frame);
        let key = (frame.src.0, frame.src_qpn.0, frame.msg_id);
        if frame.is_first {
            let ok = frame
                .rkey
                .map(|k| self.node(node).mrs.check_remote(k, frame.raddr, frame.msg_len, true))
                .unwrap_or(false);
            if !ok {
                self.node_mut(node).protection_errors += 1;
                self.node_mut(node).dropped_msgs.insert(key);
            } else if let Some(rk) = frame.rkey {
                if let Some(block) = self.node(node).mrs.mtt_block(rk, frame.raddr) {
                    cost += self.icm_touch(node, IcmKey::Mtt(rk.0, block));
                }
            }
        }
        if frame.is_last {
            let dropped = self.node_mut(node).dropped_msgs.remove(&key);
            if dropped {
                // protection error: the requester completes in error, so
                // this message's go-back-N slot is closed for good
                self.gbn_advance(node, frame);
                if frame.transport == QpTransport::Rc {
                    self.send_nak(node, frame);
                }
                return cost;
            }
            if !attempt_complete {
                // a non-terminal frame of this attempt was lost: no
                // delivery, no ACK, no sequence advance — the requester's
                // timer retransmits the whole message
                return cost;
            }
            // write-with-imm consumes a receive WQE and raises a CQE
            if frame.imm.is_some() {
                if let Some((recv_cq, wr)) = self.consume_recv_wqe(node, frame) {
                    let cqe = Cqe {
                        wr_id: wr.map(|w| w.wr_id).unwrap_or(0),
                        kind: CqeKind::RecvRdmaWithImm,
                        status: WcStatus::Success,
                        len: frame.msg_len,
                        imm_data: frame.imm,
                        qpn: frame.dst_qpn,
                        src: Some((frame.src, frame.src_qpn)),
                    };
                    cost += self.icm_touch(node, IcmKey::Cqc(recv_cq.0));
                    self.events.push(
                        self.clock + Ns(cost + nic.cqe_delay_ns),
                        Event::CqeDeliver { node, cqn: recv_cq, cqe },
                    );
                } else {
                    // RNR on write-with-imm (no recv WQE)
                    self.send_rnr_nak(node, frame);
                    return cost;
                }
            }
            if frame.transport == QpTransport::Rc {
                self.gbn_advance(node, frame);
                cost += self.send_ack(node, frame);
            } else {
                // UC: delivered without ACK — count at the receiver
                self.completed_bytes += frame.msg_len;
                self.completed_msgs += 1;
            }
        }
        cost
    }

    fn rx_send_data(&mut self, node: NodeId, frame: &Frame) -> u64 {
        let nic = self.cfg.nic;
        let mut cost = 0;
        let (gcost, proceed) = self.gbn_admit(node, frame);
        if !proceed {
            return gcost;
        }
        let attempt_complete = self.rc_attempt_complete(node, frame);
        let key = (frame.src.0, frame.src_qpn.0, frame.msg_id);
        if frame.is_first {
            // retransmitted first frames must be idempotent: clear any
            // stale drop marker from a prior attempt, and never consume a
            // second recv WQE for a message already mid-assembly
            let already = if self.faults_on {
                self.node_mut(node).dropped_msgs.remove(&key);
                // WQE already held from a prior attempt? then skip consume
                self.node(node).pending_recv.contains_key(&key)
            } else {
                false
            };
            if !already {
                match self.consume_recv_wqe_wr(node, frame) {
                    Some(wr) => {
                        // local buffer translation for the landing buffer
                        if let Some(block) = self.node(node).mrs.mtt_block(wr.lkey, wr.laddr) {
                            cost += self.icm_touch(node, IcmKey::Mtt(wr.lkey.0, block));
                        }
                        self.node_mut(node).pending_recv.insert(key, wr);
                    }
                    None => {
                        self.node_mut(node).dropped_msgs.insert(key);
                        if frame.transport == QpTransport::Rc {
                            self.send_rnr_nak(node, frame);
                        }
                        // UC/UD: silent drop
                    }
                }
            }
        }
        if frame.is_last {
            if self.node_mut(node).dropped_msgs.remove(&key) {
                return cost;
            }
            if !attempt_complete {
                // hole in this attempt (a middle frame was lost): keep
                // the held recv WQE and wait for the retransmission
                return cost;
            }
            let wr = match self.node_mut(node).pending_recv.remove(&key) {
                Some(wr) => wr,
                None => return cost, // first frame never consumed (shouldn't happen)
            };
            let recv_cq = self
                .node(node)
                .qps
                .get(frame.dst_qpn.0)
                .map(|qp| qp.recv_cq)
                .unwrap_or(Cqn(0));
            let cqe = Cqe {
                wr_id: wr.wr_id,
                kind: CqeKind::Recv,
                status: WcStatus::Success,
                len: frame.msg_len,
                imm_data: frame.imm,
                qpn: frame.dst_qpn,
                src: Some((frame.src, frame.src_qpn)),
            };
            cost += self.icm_touch(node, IcmKey::Cqc(recv_cq.0));
            self.events.push(
                self.clock + Ns(cost + nic.cqe_delay_ns),
                Event::CqeDeliver { node, cqn: recv_cq, cqe },
            );
            if frame.transport == QpTransport::Rc {
                self.gbn_advance(node, frame);
                cost += self.send_ack(node, frame);
            } else {
                // UC/UD: delivered without ACK — count at the receiver
                self.completed_bytes += frame.msg_len;
                self.completed_msgs += 1;
            }
        }
        cost
    }

    /// Consume a recv WQE (SRQ if attached, else private RQ); returns the
    /// recv CQ and the WR if one was available.
    fn consume_recv_wqe(&mut self, node: NodeId, frame: &Frame) -> Option<(Cqn, Option<RecvWr>)> {
        let (srq, recv_cq) = {
            let qp = self.node(node).qps.get(frame.dst_qpn.0)?;
            (qp.srq, qp.recv_cq)
        };
        let wr = match srq {
            Some(srqn) => self.node_mut(node).srqs.get_mut(srqn.0)?.consume(),
            None => {
                let qp = self.node_mut(node).qps.get_mut(frame.dst_qpn.0)?;
                qp.rq.pop_front()
            }
        };
        wr.map(|w| (recv_cq, Some(w)))
    }

    fn consume_recv_wqe_wr(&mut self, node: NodeId, frame: &Frame) -> Option<RecvWr> {
        self.consume_recv_wqe(node, frame).and_then(|(_, wr)| wr)
    }

    fn send_ack(&mut self, node: NodeId, frame: &Frame) -> u64 {
        let nic = self.cfg.nic;
        let cost = nic.engine_frame_ns;
        let ack = Frame {
            kind: FrameKind::Ack,
            src: node,
            dst: frame.src,
            dst_qpn: frame.src_qpn,
            src_qpn: frame.dst_qpn,
            transport: QpTransport::Rc,
            msg_id: frame.msg_id,
            msg_seq: frame.msg_seq,
            frame_idx: 0,
            bytes: CTRL_FRAME_BYTES,
            msg_len: frame.msg_len,
            is_first: true,
            is_last: true,
            wr_id: frame.wr_id,
            imm: None,
            rkey: None,
            raddr: 0,
            // CNP echo: the last data frame's congestion mark rides the
            // message's ACK back to the requester's DCQCN rate limiter
            ecn: frame.ecn,
            // salt echo: the ACK retraces the (possibly repathed) pick so
            // a requester that escaped a dead uplink hears back on a
            // live reverse path too
            path_salt: frame.path_salt,
        };
        self.stage_frame(self.clock + Ns(cost), ack);
        cost
    }

    fn send_rnr_nak(&mut self, node: NodeId, frame: &Frame) {
        self.node_mut(node).rnr_naks_sent += 1;
        let nak = Frame {
            kind: FrameKind::RnrNak,
            src: node,
            dst: frame.src,
            dst_qpn: frame.src_qpn,
            src_qpn: frame.dst_qpn,
            transport: QpTransport::Rc,
            msg_id: frame.msg_id,
            msg_seq: frame.msg_seq,
            frame_idx: 0,
            bytes: CTRL_FRAME_BYTES,
            msg_len: frame.msg_len,
            is_first: true,
            is_last: true,
            wr_id: frame.wr_id,
            imm: None,
            rkey: None,
            raddr: 0,
            ecn: false,
            path_salt: frame.path_salt,
        };
        self.stage_frame(self.clock, nak);
    }

    /// Remote-error NAK (protection/rkey failure at the responder): the
    /// requester completes the message with `RemoteAccessError` when this
    /// frame lands. Replaces the old simulator's direct requester-state
    /// mutation — a shard may never touch another shard's nodes.
    fn send_nak(&mut self, node: NodeId, frame: &Frame) {
        let nak = Frame {
            kind: FrameKind::Nak,
            src: node,
            dst: frame.src,
            dst_qpn: frame.src_qpn,
            src_qpn: frame.dst_qpn,
            transport: QpTransport::Rc,
            msg_id: frame.msg_id,
            msg_seq: frame.msg_seq,
            frame_idx: 0,
            bytes: CTRL_FRAME_BYTES,
            msg_len: frame.msg_len,
            is_first: true,
            is_last: true,
            wr_id: frame.wr_id,
            imm: None,
            rkey: None,
            raddr: 0,
            ecn: false,
            path_salt: frame.path_salt,
        };
        self.stage_frame(self.clock, nak);
    }

    /// ACK received at the requester: complete the in-flight RC message.
    /// An ECN-echoing ACK is the CNP — it cuts the QP's DCQCN rate here.
    fn rx_ack(&mut self, node: NodeId, frame: &Frame) -> u64 {
        let nic = self.cfg.nic;
        let cc = self.cfg.topo.filter(|t| t.mode == CcMode::Dcqcn);
        let mut cost = 0;
        let inf = match self.node_mut(node).inflight.remove(&frame.msg_id) {
            Some(i) => i,
            None => return 0, // duplicate/stale ack
        };
        let (send_cq, signaled) = {
            let clock = self.clock;
            let qp = self.node_mut(node).qps.get_mut(inf.qpn.0).unwrap();
            qp.outstanding = qp.outstanding.saturating_sub(1);
            qp.completed += 1;
            // the path delivered: the blackhole detector's evidence resets
            qp.timeout_streak = 0;
            if frame.ecn {
                if let Some(t) = cc {
                    // settle any recovery earned so far, then cut
                    // (coalesced: at most one cut per cc_cnp_gap_ns)
                    qp.cc_advance(clock, t.cc_recovery_ns, t.cc_ai_frac);
                    qp.cc_on_cnp(clock, t.cc_alpha, t.cc_min_rate, t.cc_cnp_gap_ns);
                }
            }
            (qp.send_cq, inf.wr.signaled)
        };
        self.completed_bytes += inf.wr.len;
        self.completed_msgs += 1;
        if signaled {
            let cqe = Cqe {
                wr_id: inf.wr.wr_id,
                kind: CqeKind::SendDone(inf.wr.verb),
                status: WcStatus::Success,
                len: inf.wr.len,
                imm_data: None,
                qpn: inf.qpn,
                src: None,
            };
            cost += self.icm_touch(node, IcmKey::Cqc(send_cq.0));
            self.events.push(
                self.clock + Ns(cost + nic.cqe_delay_ns),
                Event::CqeDeliver { node, cqn: send_cq, cqe },
            );
        }
        self.rearm_issue(node, inf.qpn);
        cost
    }

    /// Last READ response frame landed: complete at the requester.
    fn complete_read(&mut self, node: NodeId, frame: &Frame) -> u64 {
        let nic = self.cfg.nic;
        let mut cost = 0;
        let inf = match self.node_mut(node).inflight.remove(&frame.msg_id) {
            Some(i) => i,
            None => return 0,
        };
        let send_cq = {
            let qp = self.node_mut(node).qps.get_mut(inf.qpn.0).unwrap();
            qp.outstanding = qp.outstanding.saturating_sub(1);
            qp.completed += 1;
            qp.timeout_streak = 0;
            qp.send_cq
        };
        self.completed_bytes += inf.wr.len;
        self.completed_msgs += 1;
        if inf.wr.signaled {
            let cqe = Cqe {
                wr_id: inf.wr.wr_id,
                kind: CqeKind::SendDone(Verb::Read),
                status: WcStatus::Success,
                len: inf.wr.len,
                imm_data: None,
                qpn: inf.qpn,
                src: None,
            };
            cost += self.icm_touch(node, IcmKey::Cqc(send_cq.0));
            self.events.push(
                self.clock + Ns(cost + nic.cqe_delay_ns),
                Event::CqeDeliver { node, cqn: send_cq, cqe },
            );
        }
        self.rearm_issue(node, inf.qpn);
        cost
    }

    /// Requester-side error completion, fired by an incoming remote-error
    /// NAK ([`FrameKind::Nak`]) addressed to this node.
    fn complete_requester_error(&mut self, node: NodeId, msg_id: u64, status: WcStatus) {
        let inf = match self.node_mut(node).inflight.remove(&msg_id) {
            Some(i) => i,
            None => return, // duplicate/stale NAK
        };
        let send_cq = {
            let qp = self.node_mut(node).qps.get_mut(inf.qpn.0).unwrap();
            qp.outstanding = qp.outstanding.saturating_sub(1);
            qp.send_cq
        };
        let cqe = Cqe {
            wr_id: inf.wr.wr_id,
            kind: CqeKind::SendDone(inf.wr.verb),
            status,
            len: 0,
            imm_data: None,
            qpn: inf.qpn,
            src: None,
        };
        let at = self.clock + Ns(self.cfg.nic.cqe_delay_ns);
        self.events.push(at, Event::CqeDeliver { node, cqn: send_cq, cqe });
        self.rearm_issue(node, inf.qpn);
    }

    // -------------------------------------- fault layer: RC go-back-N

    /// Responder-side go-back-N admission for an RC data frame: `(extra
    /// cost, may proceed)`. Dormant (always admit) without a fault plan —
    /// on the lossless fabric frames cannot arrive out of sequence.
    fn gbn_admit(&mut self, node: NodeId, frame: &Frame) -> (u64, bool) {
        if !self.faults_on || frame.transport != QpTransport::Rc {
            return (0, true);
        }
        let expected = self
            .node(node)
            .qps
            .get(frame.dst_qpn.0)
            .map(|q| q.expected_msg_seq)
            .unwrap_or(0);
        if frame.msg_seq > expected {
            // an earlier message is missing: discard; the requester
            // retransmits everything from the hole, in order
            self.node_mut(node).gbn_discards += 1;
            return (0, false);
        }
        if frame.msg_seq < expected {
            // duplicate of a message this QP already consumed — its ACK
            // was evidently lost. Re-ACK the last frame so the requester
            // can complete; NEVER re-deliver (exactly-once).
            let mut cost = 0;
            if frame.is_last {
                self.node_mut(node).gbn_dup_acks += 1;
                cost += self.send_ack(node, frame);
            }
            return (cost, false);
        }
        (0, true)
    }

    /// An accepted RC message closed its go-back-N slot: the QP expects
    /// the next sequence. No-op without a fault plan (counters would be
    /// meaningless there — the lossless RNR path re-issues under fresh
    /// sequences).
    fn gbn_advance(&mut self, node: NodeId, frame: &Frame) {
        if !self.faults_on || frame.transport != QpTransport::Rc {
            return;
        }
        if let Some(qp) = self.node_mut(node).qps.get_mut(frame.dst_qpn.0) {
            qp.expected_msg_seq = qp.expected_msg_seq.max(frame.msg_seq + 1);
        }
    }

    /// Fault mode, RC multi-frame data messages: record one *admitted*
    /// frame (call after [`Shard::gbn_admit`]) and, on the last frame,
    /// report whether the message arrived with no holes — a lost MIDDLE
    /// frame must not let the last frame deliver/ACK a message missing
    /// bytes. Coverage is a per-index bitmap for messages of ≤ 64 frames
    /// (every workload here; dropped duplicates stay idempotent) and a
    /// plain frame count above that. The tracker is consumed on the last
    /// frame either way; an incomplete attempt leaves the requester's
    /// timer to retransmit the whole message.
    fn rc_attempt_complete(&mut self, node: NodeId, frame: &Frame) -> bool {
        if !self.faults_on || frame.transport != QpTransport::Rc {
            return true;
        }
        let total = self.frame_count(frame.msg_len.max(1));
        if total <= 1 {
            return true;
        }
        let key = (frame.src.0, frame.src_qpn.0, frame.msg_id);
        let n = self.node_mut(node);
        let seen = {
            let e = n.rc_frames_seen.entry(key).or_insert(0);
            if total <= 64 {
                *e |= 1u64 << frame.frame_idx.min(63);
            } else {
                *e += 1;
            }
            *e
        };
        if !frame.is_last {
            return true;
        }
        n.rc_frames_seen.remove(&key);
        let complete = if total <= 64 {
            let mask = if total == 64 { u64::MAX } else { (1u64 << total) - 1 };
            seen & mask == mask
        } else {
            seen >= total
        };
        if !complete {
            n.rc_incomplete_msgs += 1;
        }
        complete
    }

    /// Fault mode: record one ReadResp frame against its in-flight READ;
    /// on the last frame, true iff the response arrived complete (same
    /// bitmap/count scheme as [`Shard::rc_attempt_complete`], accumulated
    /// in the in-flight entry so duplicate response streams union up).
    fn read_resp_complete(&mut self, node: NodeId, frame: &Frame) -> bool {
        if !self.faults_on {
            return true;
        }
        let len = match self.node(node).inflight.get(&frame.msg_id) {
            Some(inf) => inf.wr.len.max(1),
            None => return true, // stale duplicate; complete_read will no-op
        };
        let total = self.frame_count(len);
        if total <= 1 {
            return true;
        }
        let n = self.node_mut(node);
        let complete = {
            let inf = n.inflight.get_mut(&frame.msg_id).expect("checked above");
            if total <= 64 {
                inf.resp_seen |= 1u64 << frame.frame_idx.min(63);
            } else {
                inf.resp_seen += 1;
            }
            if !frame.is_last {
                return true;
            }
            if total <= 64 {
                let mask = if total == 64 { u64::MAX } else { (1u64 << total) - 1 };
                inf.resp_seen & mask == mask
            } else {
                inf.resp_seen >= total
            }
        };
        if !complete {
            n.rc_incomplete_msgs += 1;
        }
        complete
    }

    /// Schedule the ACK timeout for `attempt` of an in-flight RC message.
    /// `expected_done` is when its last frame lands (for READs: when the
    /// response should have finished streaming); the margin backs off
    /// exponentially per attempt, capped at 8×. Dormant without faults.
    fn arm_rc_timer(&mut self, node: NodeId, qpn: Qpn, msg_id: u64, attempt: u32, expected_done: Ns) {
        if !self.faults_on {
            return;
        }
        let margin = self.cfg.nic.retransmit_timeout_ns << attempt.min(3);
        let at = expected_done + Ns(2 * self.cfg.switch_latency_ns + margin);
        self.events.push(at, Event::AckTimeout { node, qpn, msg_id, attempt });
    }

    /// Rough time for a READ response of `len` bytes to stream back:
    /// serialization of payload + per-frame overhead, responder engine
    /// touches, one-way propagation.
    fn read_response_eta(&self, len: u64) -> Ns {
        let payload = len.max(1);
        let frames = self.frame_count(payload);
        let wire = wire_time(payload + frames * FRAME_OVERHEAD_BYTES, self.cfg.link_gbps);
        Ns(wire.0 + frames * self.cfg.nic.engine_frame_ns + self.cfg.switch_latency_ns)
    }

    /// An ACK timeout fired. Acts only when the message is still in
    /// flight under the same attempt (otherwise it was acked, completed,
    /// superseded by a newer attempt, or its node restarted).
    fn on_ack_timeout(&mut self, node: NodeId, qpn: Qpn, msg_id: u64, attempt: u32) {
        let retry_cnt = self.cfg.nic.retry_cnt;
        {
            let n = self.node_mut(node);
            match n.inflight.get(&msg_id) {
                Some(inf) if inf.attempt == attempt => {}
                _ => return,
            }
        }
        if attempt >= retry_cnt {
            self.complete_retry_exceeded(node, msg_id);
            return;
        }
        // Blackhole detector (DESIGN.md §15): `blackhole_k` consecutive
        // ACK timeouts on one QP — with zero successful completions in
        // between — are read as "this ECMP pick leads into a dead port",
        // not as congestion. Re-salt the QP's rendezvous pick BEFORE the
        // retransmission below stages its frames, so the retry budget is
        // spent probing paths instead of hammering one blackhole until
        // RetryExceeded. The streak resets on every delivered ACK / READ
        // completion ([`Shard::rx_ack`], [`Shard::complete_read`]).
        if let Some(t) = self.cfg.topo {
            if t.repath && t.blackhole_k > 0 {
                let n = self.node_mut(node);
                let fired = match n.qps.get_mut(qpn.0) {
                    Some(qp) => {
                        qp.timeout_streak += 1;
                        if qp.timeout_streak >= t.blackhole_k {
                            qp.path_salt += 1;
                            qp.timeout_streak = 0;
                            true
                        } else {
                            false
                        }
                    }
                    None => false,
                };
                if fired {
                    n.repaths += 1;
                }
            }
        }
        // bump the attempt NOW, not when the engine gets to the work item:
        // a second timer armed under the same attempt (the RNR path arms
        // one alongside the issue-time timer) must see the mismatch and
        // no-op instead of double-retransmitting and burning the budget
        if let Some(inf) = self.node_mut(node).inflight.get_mut(&msg_id) {
            inf.attempt += 1;
        }
        // retransmission is engine work like everything else
        self.node_mut(node).engine_queue.push_back(WorkItem::Retransmit { qpn, msg_id });
        self.kick_engine(node);
    }

    /// Re-emit every frame of a timed-out RC message — go-back-N at
    /// message granularity, same msg_id and msg_seq as the original
    /// transmission so the responder can deduplicate. Returns engine
    /// occupancy.
    fn retransmit_msg(&mut self, node: NodeId, qpn: Qpn, msg_id: u64) -> u64 {
        let nic = self.cfg.nic;
        let (wr, msg_seq, attempt) = {
            // the attempt was already bumped by the timeout that queued
            // this work item — read, don't re-bump
            let Some(inf) = self.node(node).inflight.get(&msg_id) else { return 0 };
            (inf.wr.clone(), inf.msg_seq, inf.attempt)
        };
        let Some((peer_node, peer_qpn)) = self.node(node).qps.get(qpn.0).and_then(|q| q.peer)
        else {
            return 0;
        };
        // read the CURRENT salt, not the one the original transmission
        // used: if the blackhole detector re-salted this QP, every frame
        // of this attempt takes the escaped path
        let path_salt = self.node(node).qps.get(qpn.0).map_or(0, |q| q.path_salt);
        self.node_mut(node).retransmits += 1;
        let mut cost = nic.engine_wqe_ns;
        cost += self.icm_touch(node, IcmKey::Qpc(qpn.0));

        match wr.verb {
            Verb::Read => {
                let frame = Frame {
                    kind: FrameKind::ReadReq,
                    src: node,
                    dst: peer_node,
                    dst_qpn: peer_qpn,
                    src_qpn: qpn,
                    transport: QpTransport::Rc,
                    msg_id,
                    msg_seq,
                    frame_idx: 0,
                    bytes: CTRL_FRAME_BYTES,
                    msg_len: wr.len,
                    is_first: true,
                    is_last: true,
                    wr_id: wr.wr_id,
                    imm: None,
                    rkey: wr.rkey,
                    raddr: wr.raddr,
                    ecn: false,
                    path_salt,
                };
                cost += nic.engine_frame_ns;
                let link_at = self.stage_frame(self.clock + Ns(cost), frame);
                let eta = self.est_deliver(link_at, frame.bytes) + self.read_response_eta(wr.len);
                self.arm_rc_timer(node, qpn, msg_id, attempt, eta);
            }
            Verb::Write | Verb::Send => {
                let kind = if wr.verb == Verb::Write {
                    FrameKind::WriteData
                } else {
                    FrameKind::SendData
                };
                let payload = wr.len.max(1);
                let total = self.frame_count(payload);
                let mut handoff = self.clock + Ns(cost);
                let mut last_link = self.clock;
                let mut last_bytes = 0;
                for i in 0..total {
                    cost += nic.engine_frame_ns;
                    handoff += Ns(nic.engine_frame_ns);
                    let stall = self.tx_stall(node, handoff);
                    cost += stall;
                    handoff += Ns(stall);
                    let bytes = self.frame_bytes(payload, i, total);
                    let frame = Frame {
                        kind,
                        src: node,
                        dst: peer_node,
                        dst_qpn: peer_qpn,
                        src_qpn: qpn,
                        transport: QpTransport::Rc,
                        msg_id,
                        msg_seq,
                        frame_idx: i,
                        bytes,
                        msg_len: wr.len,
                        is_first: i == 0,
                        is_last: i + 1 == total,
                        wr_id: wr.wr_id,
                        imm: wr.imm_data,
                        rkey: wr.rkey,
                        raddr: wr.raddr,
                        ecn: false,
                        path_salt,
                    };
                    last_bytes = bytes;
                    last_link = self.stage_frame(handoff, frame);
                }
                self.arm_rc_timer(node, qpn, msg_id, attempt, self.est_deliver(last_link, last_bytes));
            }
        }
        cost
    }

    /// The retry budget ran out. Real RC transitions the QP to Error and
    /// FLUSHES every outstanding WR — modeled here by completing every
    /// in-flight message of the QP with [`WcStatus::RetryExceeded`]. The
    /// responder's expected sequence is then resynced to the requester's
    /// next issue via a staged [`Resync`] (the out-of-band
    /// re-establishment a daemon performs after a fatal retry): without
    /// both, one dead message would make the responder discard everything
    /// after it forever, and a partial resync could dup-ACK a message
    /// that was never delivered.
    fn complete_retry_exceeded(&mut self, node: NodeId, msg_id: u64) {
        let qpn = match self.node(node).inflight.get(&msg_id) {
            Some(inf) => inf.qpn,
            None => return,
        };
        // flush in ascending msg_id order — never HashMap order
        let mut ids: Vec<u64> = self
            .node(node)
            .inflight
            .iter()
            .filter(|(_, inf)| inf.qpn == qpn)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        for id in ids {
            let inf = self.node_mut(node).inflight.remove(&id).expect("collected id");
            let send_cq = {
                let n = self.node_mut(node);
                n.retry_exceeded += 1;
                let qp = n.qps.get_mut(qpn.0).expect("qp of in-flight msg");
                qp.outstanding = qp.outstanding.saturating_sub(1);
                qp.send_cq
            };
            let cqe = Cqe {
                wr_id: inf.wr.wr_id,
                kind: CqeKind::SendDone(inf.wr.verb),
                status: WcStatus::RetryExceeded,
                len: 0,
                imm_data: None,
                qpn,
                src: None,
            };
            let at = self.clock + Ns(self.cfg.nic.cqe_delay_ns);
            self.events.push(at, Event::CqeDeliver { node, cqn: send_cq, cqe });
        }
        // resync the responder past every issued (now dead or delivered)
        // sequence so post-recovery traffic is accepted again — staged,
        // because the peer may live on another shard; post-recovery frames
        // have link_at at or after the next barrier, so the max-merge
        // lands before anything that depends on it
        let (next_seq, peer) = {
            let qp = self.node(node).qps.get(qpn.0).expect("qp exists");
            (qp.next_msg_seq, qp.peer)
        };
        if let Some((peer_node, peer_qpn)) = peer {
            let i = self.li(node);
            let emit = self.emit_seq[i];
            self.emit_seq[i] += 1;
            self.out_resync.push(Resync {
                at: self.clock,
                src: node,
                emit,
                peer: peer_node,
                peer_qpn,
                next_seq,
            });
        }
        self.rearm_issue(node, qpn);
    }

    /// Fault-plan node soft-restart: queued engine work, SQ/RQ/SRQ/CQ
    /// contents and requester in-flight state vanish; connection state
    /// (peer bindings, go-back-N counters) survives so peers recover by
    /// retransmission. Work that died without a completion is what the
    /// daemon's stale-lease reclaim exists for.
    fn on_node_restart(&mut self, node: NodeId) {
        let i = self.li(node);
        if let Some(f) = self.faults[i].as_mut() {
            f.note_restart();
        }
        let n = self.node_mut(node);
        n.restarts += 1;
        n.engine_queue.clear();
        n.inflight.clear();
        n.pending_recv.clear();
        n.rc_frames_seen.clear();
        n.dropped_msgs.clear();
        for qp in n.qps.iter_mut() {
            qp.reset_soft();
        }
        for srq in n.srqs.iter_mut() {
            srq.clear();
        }
        for cq in n.cqs.iter_mut() {
            cq.clear();
        }
    }
}
