//! Multi-switch fat-tree/Clos topology with congestion control.
//!
//! The single-switch fabric ([`super::switchfab`]) models every node one
//! hop from every other, which makes incast fan-in and oversubscribed
//! uplinks — the regime where production RDMA actually dies — physically
//! unrepresentable. This module adds a two-tier leaf/spine Clos on *top*
//! of the per-node host ports: nodes attach to ToR switches in groups of
//! [`TopoConfig::hosts_per_tor`]; each ToR has `hosts_per_tor / oversub`
//! uplinks, one to each spine; a frame whose destination sits under a
//! different ToR crosses ToR-uplink → spine-downlink before reaching the
//! destination's host ingress port. Same-ToR traffic keeps the old
//! single-hop timing exactly.
//!
//! ### Determinism
//!
//! All Clos port state is owned by the *coordinator* ([`super::sim::Sim`])
//! and mutated only inside the conservative barrier, where staged frames
//! are already processed in one global `(link_at, src, emit)` total order
//! that is independent of the shard count. Path selection is ECMP by a
//! pure [`ecmp_hash`] of `(src, dst, src_qpn, dst_qpn)` — one path per QP
//! pair, so a QP's frames never reorder and the go-back-N discipline is
//! untouched. Cross-switch hops only ever *add* latency after the staged
//! `link_at`, so the shard lookahead bound (frames staged at local time
//! `t` arrive no earlier than `t + switch_latency`) still holds and shard
//! partitioning stays byte-identical to the serial schedule.
//!
//! ### Congestion control ([`CcMode`])
//!
//! * **`Dcqcn`** — each Clos port has a finite buffer
//!   ([`TopoConfig::buffer_bytes`]); a data frame that finds more than
//!   [`TopoConfig::ecn_threshold_bytes`] of backlog is ECN-marked, the
//!   responder echoes the mark on its ACK (the CNP), and the requester QP
//!   cuts its sending rate, recovering by additive then hyper increase on
//!   a timer (the DCQCN-flavored limiter in [`super::qp::Qp`]). Frames
//!   beyond the buffer are tail-dropped and recovered by the PR-4 RC
//!   retransmission machinery.
//! * **`NoCc`** — same finite buffers and drops, no marking reaction:
//!   the congestion-collapse ablation.
//! * **`Pfc`** — lossless instead: a port whose *downstream* queue
//!   exceeds the buffer pauses (its service start is pushed back), the
//!   pause chains hop by hop toward the hosts, and head-of-line blocking
//!   emerges naturally from FIFO port service. No drops, no marks.
//!
//! ### Failure survival (DESIGN.md §15)
//!
//! Switch-level faults ([`super::fault::FaultConfig::uplink_deaths`] /
//! `spine_windows`) mark uplink ports dead ([`Clos::kill_uplink`] /
//! [`Clos::kill_spine`]). Path selection is *rendezvous* (highest-random-
//! weight) hashing over the live-port mask ([`pick_uplink`]): killing or
//! reviving one port only moves the flows whose argmax that port was —
//! every other flow keeps its path, so failure reconvergence never
//! reorders healthy QPs. The mask itself lags the failure by
//! [`TopoConfig::reroute_lag_ns`] (control-plane reconvergence); until it
//! catches up, frames picked onto a dead port drop at the uplink
//! ([`ClosStats::blackhole_drops`]) and the PR-4 go-back-N machinery
//! recovers them. Each mask change bumps [`Clos::route_epoch`]. Endpoints
//! escape faster than the fabric reconverges via the per-QP blackhole
//! detector (K consecutive ack-timeouts bump the QP's `path_salt`, which
//! reseeds the rendezvous pick — see `shard.rs`).

use super::switchfab::{Port, FRAME_OVERHEAD_BYTES};
use super::time::{wire_time, Ns};
use super::types::{NodeId, Qpn};

/// Congestion-control regime for the Clos fabric (fig 13's ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CcMode {
    /// ECN marking above threshold + per-QP DCQCN rate limiter; tail-drop
    /// above the buffer (recovered by RC retransmission).
    Dcqcn,
    /// Finite buffers and tail-drop with *no* rate reaction: the
    /// congestion-collapse baseline.
    NoCc,
    /// Priority-flow-control ablation: lossless chained pauses instead of
    /// drops/marks; HOL blocking is the cost.
    Pfc,
}

/// Clos topology + congestion-control parameters. `None` in
/// [`super::sim::FabricConfig::topo`] keeps the single-switch fabric and
/// every pre-existing figure byte-identical.
#[derive(Clone, Copy, Debug)]
pub struct TopoConfig {
    /// Hosts attached to each ToR switch (nodes are assigned to ToRs in
    /// id order: ToR `t` owns nodes `[t*hosts_per_tor, (t+1)*hosts_per_tor)`).
    pub hosts_per_tor: usize,
    /// Oversubscription ratio: each ToR gets `hosts_per_tor / oversub`
    /// uplinks (min 1), one per spine. 1 = full bisection.
    pub oversub: u32,
    /// Congestion-control regime.
    pub mode: CcMode,
    /// Per-hop propagation + switching delay between switch tiers.
    pub hop_latency_ns: u64,
    /// ECN marking threshold per Clos port (bytes of queued backlog).
    pub ecn_threshold_bytes: u64,
    /// Finite per-port buffer: tail-drop point in `Dcqcn`/`NoCc`, pause
    /// threshold in `Pfc`.
    pub buffer_bytes: u64,
    /// DCQCN rate-cut factor: `rate *= 1 - alpha` per accepted CNP.
    pub cc_alpha: f64,
    /// DCQCN rate floor, as a fraction of line rate.
    pub cc_min_rate: f64,
    /// DCQCN additive-increase step per recovery period (fraction of line
    /// rate); after five additive steps the step doubles per period
    /// (hyper increase).
    pub cc_ai_frac: f64,
    /// DCQCN rate-recovery timer period.
    pub cc_recovery_ns: u64,
    /// CNP coalescing: a QP cuts at most once per this interval.
    pub cc_cnp_gap_ns: u64,
    /// Failure reconvergence: when true, the ECMP live mask excludes dead
    /// uplinks (after [`TopoConfig::reroute_lag_ns`]) and the endpoint
    /// blackhole detector is armed. False = the fig-14 ablation: flows
    /// stay pinned to their original path forever.
    pub repath: bool,
    /// Blackhole detector threshold: a QP that sees this many
    /// *consecutive* ack-timeouts bumps its path salt and retransmits on
    /// a fresh rendezvous pick, before the retry budget burns out.
    /// 0 disables the detector.
    pub blackhole_k: u32,
    /// Delay between a port dying and the routing mask excluding it — the
    /// fabric's control-plane reconvergence time. Kept long relative to
    /// the RC retransmit timeout so the per-QP detector is what saves
    /// in-flight flows (the paper's service-layer pitch), with mask
    /// reconvergence as the slow backstop for future flows.
    pub reroute_lag_ns: u64,
}

impl Default for TopoConfig {
    fn default() -> Self {
        TopoConfig {
            hosts_per_tor: 8,
            oversub: 1,
            mode: CcMode::Dcqcn,
            hop_latency_ns: 500,
            ecn_threshold_bytes: 64 << 10,
            buffer_bytes: 256 << 10,
            cc_alpha: 0.5,
            cc_min_rate: 1.0 / 32.0,
            cc_ai_frac: 1.0 / 16.0,
            cc_recovery_ns: 55_000,
            cc_cnp_gap_ns: 50_000,
            repath: true,
            blackhole_k: 3,
            reroute_lag_ns: 200_000,
        }
    }
}

impl TopoConfig {
    /// Uplinks per ToR (= spine count): `hosts_per_tor / oversub`, min 1.
    pub fn uplinks(&self) -> usize {
        (self.hosts_per_tor / (self.oversub.max(1) as usize)).max(1)
    }

    /// True when the DCQCN rate limiter should react to echoed marks.
    pub fn dcqcn(&self) -> bool {
        self.mode == CcMode::Dcqcn
    }
}

/// Stable ECMP path hash (splitmix64 finalizer over the packed flow key).
/// Pure function of the QP pair, so a flow sticks to one uplink/spine for
/// its lifetime — no intra-QP reordering, and the same path on every
/// shard count and every replay.
pub fn ecmp_hash(src: NodeId, dst: NodeId, src_qpn: Qpn, dst_qpn: Qpn) -> u64 {
    let mut z = ((src.0 as u64) << 48)
        ^ ((dst.0 as u64) << 32)
        ^ ((src_qpn.0 as u64) << 16)
        ^ (dst_qpn.0 as u64);
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Rendezvous (highest-random-weight) uplink pick for one flow: among the
/// ports marked live, take the one whose per-(flow, salt, port) weight is
/// largest. Stability is the point — killing or reviving a port only
/// moves the flows whose argmax that port was, so ECMP reconvergence
/// after a failure never touches a healthy flow's path (no reordering,
/// no spurious go-back-N). `salt` reseeds the weights: the endpoint
/// blackhole detector bumps a QP's salt to escape a dead path before the
/// routing mask has reconverged. Pure function, so every shard count and
/// every replay picks identically. Falls back to `hash % len` over *all*
/// ports when nothing is live (the frame then blackhole-drops at the
/// dead uplink — a totally cut ToR stays cut).
pub fn pick_uplink(hash: u64, salt: u32, live: &[bool]) -> usize {
    let n = live.len().max(1);
    let key = hash ^ (salt as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let mut best: Option<(u64, usize)> = None;
    for (u, &ok) in live.iter().enumerate() {
        if !ok {
            continue;
        }
        // splitmix64 finalizer over (flow key, port): independent weight
        // per port, so the argmax is uniform and per-port-stable
        let mut z = key ^ (u as u64 + 1).wrapping_mul(0xd6e8_feb8_6659_fd93);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        if best.map(|(w, _)| z > w).unwrap_or(true) {
            best = Some((z, u));
        }
    }
    match best {
        Some((_, u)) => u,
        None => (key % n as u64) as usize,
    }
}

/// Aggregate Clos counters (fig-13 columns).
#[derive(Clone, Copy, Debug, Default)]
pub struct ClosStats {
    /// Data frames ECN-marked at any Clos port or the destination ingress.
    pub ecn_marks: u64,
    /// Frames tail-dropped at a full Clos port (`Dcqcn`/`NoCc` only).
    pub switch_drops: u64,
    /// Pause events: a frame whose port service was pushed back by a
    /// congested downstream queue (`Pfc` only).
    pub pauses: u64,
    /// Frames that picked a dead uplink (before the routing mask
    /// reconverged, or on a totally cut ToR) and vanished into it.
    pub blackhole_drops: u64,
}

/// Coordinator-owned Clos switch state: one [`Port`] per ToR uplink and
/// per spine downlink. Mutated only at the conservative barrier, in the
/// global staged-frame order.
#[derive(Debug)]
pub struct Clos {
    /// The topology + CC parameters this fabric was built from.
    pub topo: TopoConfig,
    tors: usize,
    uplinks: usize,
    gbps: f64,
    hop_latency: Ns,
    /// ECN threshold converted to backlog time at line rate.
    ecn_threshold: Ns,
    /// Buffer depth converted to backlog time at line rate.
    buffer: Ns,
    /// ToR uplink ports, indexed `[tor * uplinks + u]`; uplink `u` of
    /// every ToR lands on spine `u`.
    tor_up: Vec<Port>,
    /// Spine downlink ports, indexed `[spine * tors + dst_tor]`.
    spine_down: Vec<Port>,
    /// Death refcount per uplink port (same indexing as `tor_up`): a
    /// permanent uplink death and a spine failure window can overlap on
    /// one port, so revival decrements instead of clearing. `> 0` = the
    /// port eats frames *now* (physical truth).
    up_dead: Vec<u8>,
    /// ECMP selection mask (same indexing): what the *routing* believes
    /// is alive. Lags `up_dead` by the reconvergence delay — the window
    /// where in-flight flows blackhole and the endpoint detector earns
    /// its keep. All-true when `TopoConfig::repath` is off.
    route_live: Vec<bool>,
    /// Bumped on every `route_live` change (the repath epoch: replays and
    /// the determinism suite gate on it).
    route_epoch: u32,
    /// Aggregate marking/drop/pause counters.
    pub stats: ClosStats,
}

/// What the Clos decided for one staged frame.
pub enum ClosVerdict {
    /// Frame reaches the destination's host ingress at this time (first
    /// bit); the `bool` is true when a Clos hop ECN-marked it.
    Deliver(Ns, bool),
    /// Frame tail-dropped at a full Clos port.
    Drop,
}

impl Clos {
    /// Build the Clos for `nodes` hosts. Spine count = uplinks per ToR.
    pub fn new(nodes: usize, gbps: f64, topo: TopoConfig) -> Self {
        let hosts = topo.hosts_per_tor.max(1);
        let tors = nodes.div_ceil(hosts).max(1);
        let uplinks = topo.uplinks();
        Clos {
            topo,
            tors,
            uplinks,
            gbps,
            hop_latency: Ns(topo.hop_latency_ns),
            ecn_threshold: wire_time(topo.ecn_threshold_bytes, gbps),
            buffer: wire_time(topo.buffer_bytes, gbps),
            tor_up: vec![Port::default(); tors * uplinks],
            spine_down: vec![Port::default(); tors * uplinks],
            up_dead: vec![0; tors * uplinks],
            route_live: vec![true; tors * uplinks],
            route_epoch: 0,
            stats: ClosStats::default(),
        }
    }

    /// ToR switch owning this node.
    pub fn tor_of(&self, n: NodeId) -> usize {
        (n.0 as usize / self.topo.hosts_per_tor.max(1)).min(self.tors - 1)
    }

    /// Number of ToR switches.
    pub fn tors(&self) -> usize {
        self.tors
    }

    /// Uplinks per ToR (= spine count).
    pub fn uplinks(&self) -> usize {
        self.uplinks
    }

    /// ECMP uplink/spine index for an unsalted flow under the current
    /// routing mask (same on every shard count; see [`pick_uplink`]).
    pub fn path_of(&self, src: NodeId, dst: NodeId, src_qpn: Qpn, dst_qpn: Qpn) -> usize {
        let st = self.tor_of(src);
        pick_uplink(
            ecmp_hash(src, dst, src_qpn, dst_qpn),
            0,
            &self.route_live[st * self.uplinks..][..self.uplinks],
        )
    }

    /// Kill one ToR uplink port (refcounted: overlapping spine windows
    /// and permanent deaths stack). Takes effect on the *data* plane
    /// immediately; the routing mask follows at the next
    /// [`Clos::reconverge`].
    pub fn kill_uplink(&mut self, tor: usize, u: usize) {
        if tor < self.tors && u < self.uplinks {
            let i = tor * self.uplinks + u;
            self.up_dead[i] = self.up_dead[i].saturating_add(1);
        }
    }

    /// Undo one [`Clos::kill_uplink`] on a port.
    pub fn revive_uplink(&mut self, tor: usize, u: usize) {
        if tor < self.tors && u < self.uplinks {
            let i = tor * self.uplinks + u;
            self.up_dead[i] = self.up_dead[i].saturating_sub(1);
        }
    }

    /// Whole-spine failure: uplink `s` of every ToR dies (spine `s` is
    /// only reachable through those ports, so this cuts the switch out
    /// of the fabric entirely).
    pub fn kill_spine(&mut self, s: usize) {
        for t in 0..self.tors {
            self.kill_uplink(t, s);
        }
    }

    /// Spine `s` comes back.
    pub fn revive_spine(&mut self, s: usize) {
        for t in 0..self.tors {
            self.revive_uplink(t, s);
        }
    }

    /// Routing reconvergence: fold the current death state into the ECMP
    /// selection mask; bumps the repath epoch and returns true when the
    /// mask actually changed. No-op (mask stays all-true) when
    /// `TopoConfig::repath` is off — the fig-14 ablation.
    pub fn reconverge(&mut self) -> bool {
        if !self.topo.repath {
            return false;
        }
        let mut changed = false;
        for i in 0..self.up_dead.len() {
            let live = self.up_dead[i] == 0;
            if self.route_live[i] != live {
                self.route_live[i] = live;
                changed = true;
            }
        }
        if changed {
            self.route_epoch += 1;
        }
        changed
    }

    /// Current repath epoch (0 until the first reconvergence).
    pub fn route_epoch(&self) -> u32 {
        self.route_epoch
    }

    /// The full ECMP selection mask, indexed `[tor * uplinks + u]`
    /// (snapshotted into each shard at the barrier for the PFC gate's
    /// path pick).
    pub fn route_live(&self) -> &[bool] {
        &self.route_live
    }

    /// True when this uplink port currently eats frames.
    pub fn uplink_dead(&self, tor: usize, u: usize) -> bool {
        tor < self.tors && u < self.uplinks && self.up_dead[tor * self.uplinks + u] > 0
    }

    /// ECN threshold as backlog time at line rate (the destination-ingress
    /// marking check in the coordinator uses the same constant).
    pub fn ecn_threshold(&self) -> Ns {
        self.ecn_threshold
    }

    /// Buffer depth as backlog time at line rate.
    pub fn buffer(&self) -> Ns {
        self.buffer
    }

    /// Snapshot every ToR-uplink port's busy horizon into `out`
    /// (index = `tor * uplinks + u`). Refreshed into each shard at every
    /// barrier so the PFC host-egress gate can see uplink congestion
    /// without racing on the live ports.
    pub fn uplink_snapshot_into(&self, out: &mut Vec<Ns>) {
        out.clear();
        // a dead port's horizon is frozen at its moment of death; letting
        // the PFC gate keep pausing on it would deadlock senders forever,
        // so dead ports snapshot as idle (their frames die at the uplink
        // instead — see `route`)
        out.extend(
            self.tor_up
                .iter()
                .zip(self.up_dead.iter())
                .map(|(p, &d)| if d > 0 { Ns::ZERO } else { p.busy_until() }),
        );
    }

    /// Route one cross-ToR frame through uplink + spine, in the global
    /// staged-frame order. `link_at` is the first bit arriving at the
    /// source ToR (the shard already paid host egress + switch latency);
    /// `salt` is the sending QP's path salt (0 until its blackhole
    /// detector fires); `dst_ingress_busy` is the destination host-ingress
    /// horizon, used by the PFC chain's last gate. Same-ToR frames must
    /// not be routed here.
    ///
    /// Returns where/whether the frame reaches the destination ingress;
    /// `carries_data` gates ECN marking (marking an ACK would fabricate a
    /// CNP at a node that never sent data).
    #[allow(clippy::too_many_arguments)]
    pub fn route(
        &mut self,
        link_at: Ns,
        src: NodeId,
        dst: NodeId,
        src_qpn: Qpn,
        dst_qpn: Qpn,
        salt: u32,
        payload_bytes: u64,
        carries_data: bool,
        dst_ingress_busy: Ns,
    ) -> ClosVerdict {
        let wire_bytes = payload_bytes + FRAME_OVERHEAD_BYTES;
        let frame_time = wire_time(wire_bytes, self.gbps);
        let st = self.tor_of(src);
        let dt = self.tor_of(dst);
        let u = pick_uplink(
            ecmp_hash(src, dst, src_qpn, dst_qpn),
            salt,
            &self.route_live[st * self.uplinks..][..self.uplinks],
        );
        // dead port (mask not yet reconverged, or the ToR is totally
        // cut): the frame vanishes at the uplink; go-back-N recovers it
        if self.up_dead[st * self.uplinks + u] > 0 {
            self.stats.blackhole_drops += 1;
            return ClosVerdict::Drop;
        }
        let mut marked = false;

        // --- hop 1: source ToR uplink `u` (lands on spine `u`) ---
        let down_busy = self.spine_down[u * self.tors + dt].busy_until();
        let up = &mut self.tor_up[st * self.uplinks + u];
        let mut earliest = link_at;
        match self.topo.mode {
            CcMode::Pfc => {
                // Pause: don't start serializing while the downstream
                // spine queue is more than a buffer ahead.
                let gate = down_busy.saturating_sub(self.buffer + self.hop_latency);
                if gate > earliest {
                    earliest = gate;
                    self.stats.pauses += 1;
                }
            }
            CcMode::Dcqcn | CcMode::NoCc => {
                let backlog = up.busy_until().saturating_sub(link_at);
                if backlog > self.buffer {
                    self.stats.switch_drops += 1;
                    return ClosVerdict::Drop;
                }
                if carries_data && backlog > self.ecn_threshold {
                    marked = true;
                }
            }
        }
        let up_done = up.occupy(earliest, frame_time, wire_bytes);
        let at_spine = up_done + self.hop_latency;

        // --- hop 2: spine `u` downlink to the destination ToR ---
        let down = &mut self.spine_down[u * self.tors + dt];
        let mut earliest = at_spine;
        match self.topo.mode {
            CcMode::Pfc => {
                let gate = dst_ingress_busy.saturating_sub(self.buffer + self.hop_latency);
                if gate > earliest {
                    earliest = gate;
                    self.stats.pauses += 1;
                }
            }
            CcMode::Dcqcn | CcMode::NoCc => {
                let backlog = down.busy_until().saturating_sub(at_spine);
                if backlog > self.buffer {
                    self.stats.switch_drops += 1;
                    return ClosVerdict::Drop;
                }
                if carries_data && backlog > self.ecn_threshold {
                    marked = true;
                }
            }
        }
        let down_done = down.occupy(earliest, frame_time, wire_bytes);
        if marked {
            self.stats.ecn_marks += 1;
        }
        ClosVerdict::Deliver(down_done + self.hop_latency, marked)
    }

    /// Record a destination-ingress ECN mark (the coordinator checks the
    /// host ingress backlog itself; the counter lives here so fig 13 sees
    /// one total).
    pub fn note_ingress_mark(&mut self) {
        self.stats.ecn_marks += 1;
    }

    /// Record a destination-ingress tail-drop.
    pub fn note_ingress_drop(&mut self) {
        self.stats.switch_drops += 1;
    }

    /// Aggregate utilization of all ToR uplink ports over `[0, horizon]`.
    pub fn uplink_utilization(&self, horizon: Ns) -> f64 {
        if self.tor_up.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .tor_up
            .iter()
            .map(|p| p.utilization(horizon, self.gbps))
            .sum();
        sum / self.tor_up.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(oversub: u32, mode: CcMode) -> TopoConfig {
        TopoConfig {
            oversub,
            mode,
            ..TopoConfig::default()
        }
    }

    #[test]
    fn uplink_count_follows_oversubscription() {
        assert_eq!(topo(1, CcMode::Dcqcn).uplinks(), 8);
        assert_eq!(topo(2, CcMode::Dcqcn).uplinks(), 4);
        assert_eq!(topo(8, CcMode::Dcqcn).uplinks(), 1);
        assert_eq!(topo(64, CcMode::Dcqcn).uplinks(), 1);
    }

    #[test]
    fn ecmp_is_stable_and_spreads() {
        let h = |s: u32, q: u32| ecmp_hash(NodeId(s), NodeId(0), Qpn(q), Qpn(1));
        assert_eq!(h(8, 3), h(8, 3), "pure function");
        // 64 distinct flows should not all collapse onto one value
        let mut seen = std::collections::HashSet::new();
        for s in 0..8 {
            for q in 0..8 {
                seen.insert(h(8 + s, 100 + q) % 8);
            }
        }
        assert!(seen.len() >= 4, "ECMP spread too narrow: {seen:?}");
    }

    #[test]
    fn same_path_routes_serialize_cross_tor() {
        let mut c = Clos::new(24, 40.0, topo(8, CcMode::NoCc));
        assert_eq!(c.uplinks(), 1);
        let d1 = match c.route(Ns(0), NodeId(8), NodeId(0), Qpn(1), Qpn(2), 0, 4096, true, Ns(0)) {
            ClosVerdict::Deliver(t, _) => t,
            ClosVerdict::Drop => panic!("dropped"),
        };
        let d2 = match c.route(Ns(0), NodeId(9), NodeId(1), Qpn(1), Qpn(2), 0, 4096, true, Ns(0)) {
            ClosVerdict::Deliver(t, _) => t,
            ClosVerdict::Drop => panic!("dropped"),
        };
        // both frames share ToR-1's single uplink: second serializes behind
        let frame = wire_time(4096 + FRAME_OVERHEAD_BYTES, 40.0);
        assert!(d2 >= d1 + frame, "d1={d1} d2={d2}");
    }

    #[test]
    fn full_port_tail_drops_and_marks_before_that() {
        let cfg = topo(8, CcMode::NoCc);
        let mut c = Clos::new(24, 40.0, cfg);
        let mut dropped = false;
        let mut marked = false;
        for i in 0..400 {
            match c.route(
                Ns(0),
                NodeId(8),
                NodeId(0),
                Qpn(1),
                Qpn(2),
                0,
                4096,
                true,
                Ns(0),
            ) {
                ClosVerdict::Deliver(_, m) => marked |= m,
                ClosVerdict::Drop => {
                    dropped = true;
                    assert!(i > 10, "dropped way too early at frame {i}");
                    break;
                }
            }
        }
        assert!(marked, "no ECN mark before the buffer filled");
        assert!(dropped, "queue never hit the finite buffer");
        assert!(c.stats.ecn_marks > 0 && c.stats.switch_drops > 0);
    }

    #[test]
    fn pfc_pauses_instead_of_dropping() {
        let mut c = Clos::new(24, 40.0, topo(8, CcMode::Pfc));
        for _ in 0..400 {
            match c.route(
                Ns(0),
                NodeId(8),
                NodeId(0),
                Qpn(1),
                Qpn(2),
                0,
                4096,
                true,
                Ns(0),
            ) {
                ClosVerdict::Deliver(..) => {}
                ClosVerdict::Drop => panic!("PFC must be lossless"),
            }
        }
        assert_eq!(c.stats.switch_drops, 0);
        assert_eq!(c.stats.ecn_marks, 0, "PFC ablation does not mark");
    }

    #[test]
    fn rendezvous_pick_is_stable_under_port_death() {
        // killing one port must only move the flows that used it
        let all = vec![true; 4];
        let mut masked = all.clone();
        masked[2] = false;
        let mut moved = 0;
        for f in 0..256u64 {
            let h = ecmp_hash(NodeId(8), NodeId(0), Qpn(f as u32), Qpn(1));
            let before = pick_uplink(h, 0, &all);
            let after = pick_uplink(h, 0, &masked);
            if before != 2 {
                assert_eq!(before, after, "healthy flow {f} moved");
            } else {
                assert_ne!(after, 2, "flow {f} still on the dead port");
                moved += 1;
            }
        }
        assert!(moved > 0, "no flow ever used port 2");
    }

    #[test]
    fn salt_escapes_a_port_and_spreads() {
        // bumping the salt reshuffles the pick — within a few bumps every
        // flow escapes any single port even with the mask unconverged
        let all = vec![true; 2];
        for f in 0..64u64 {
            let h = ecmp_hash(NodeId(8), NodeId(0), Qpn(f as u32), Qpn(1));
            let first = pick_uplink(h, 0, &all);
            let escaped = (1..=8u32).any(|s| pick_uplink(h, s, &all) != first);
            assert!(escaped, "flow {f} pinned across 8 salts");
        }
    }

    #[test]
    fn kill_reconverge_and_epoch() {
        let mut c = Clos::new(24, 40.0, topo(4, CcMode::Dcqcn));
        assert_eq!(c.uplinks(), 2);
        assert_eq!(c.route_epoch(), 0);
        c.kill_uplink(0, 1);
        // data plane dies immediately, routing mask lags until reconverge
        assert!(c.uplink_dead(0, 1));
        assert!(c.route_live()[1]);
        assert!(c.reconverge());
        assert_eq!(c.route_epoch(), 1);
        assert!(!c.route_live()[1]);
        // idempotent: nothing changed, no epoch bump
        assert!(!c.reconverge());
        assert_eq!(c.route_epoch(), 1);
        // overlapping spine window on the same port: refcounted
        c.kill_spine(1);
        c.revive_spine(1);
        assert!(c.uplink_dead(0, 1), "permanent death must survive the window");
        c.revive_uplink(0, 1);
        assert!(c.reconverge());
        assert_eq!(c.route_epoch(), 2);
        assert!(c.route_live()[1]);
    }

    #[test]
    fn repath_off_mask_never_moves() {
        let mut cfg = topo(4, CcMode::Dcqcn);
        cfg.repath = false;
        let mut c = Clos::new(24, 40.0, cfg);
        c.kill_spine(0);
        assert!(!c.reconverge());
        assert_eq!(c.route_epoch(), 0);
        assert!(c.route_live().iter().all(|&l| l), "ablation mask must stay full");
        // frames picked onto the dead spine blackhole instead
        let mut holes = 0;
        for q in 0..32u32 {
            if let ClosVerdict::Drop =
                c.route(Ns(0), NodeId(8), NodeId(0), Qpn(q), Qpn(1), 0, 4096, true, Ns(0))
            {
                holes += 1;
            }
        }
        assert!(holes > 0, "no flow hashed onto the dead spine");
        assert_eq!(c.stats.blackhole_drops, holes);
        assert_eq!(c.stats.switch_drops, 0, "blackholes are not congestion drops");
    }

    #[test]
    fn dead_port_snapshots_idle() {
        let mut c = Clos::new(24, 40.0, topo(8, CcMode::Pfc));
        // pile traffic onto ToR 1's single uplink, then kill it
        for q in 0..64u32 {
            let _ = c.route(Ns(0), NodeId(8), NodeId(0), Qpn(q), Qpn(1), 0, 4096, true, Ns(0));
        }
        let mut snap = Vec::new();
        c.uplink_snapshot_into(&mut snap);
        assert!(snap[1].0 > 0, "uplink had backlog");
        c.kill_uplink(1, 0);
        c.uplink_snapshot_into(&mut snap);
        assert_eq!(snap[1], Ns::ZERO, "dead port must not pause senders on a frozen horizon");
    }
}
