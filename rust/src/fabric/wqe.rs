//! Work queue elements (WQEs) and completion queue elements (CQEs).
//!
//! The `wr_id` and `imm_data` fields are the paper's vQPN carriers (Fig 4):
//! RDMAvisor stamps the virtual QPN of a logical connection into `wr_id` for
//! one-sided verbs (visible only to the initiator's CQE) and into `imm_data`
//! for two-sided verbs (travels on the wire to the responder's CQE).

use super::types::{Mrkey, NodeId, Qpn, Verb, WcStatus};

/// A send work request, as submitted via `post_send`. `Copy`: extents,
/// keys, and ids only — no owned payload — so the daemon can retain the
/// posted WR for self-healing replay at zero heap cost.
#[derive(Clone, Copy, Debug)]
pub struct SendWr {
    /// Opaque 64-bit id returned in the initiator's CQE. RDMAvisor packs the
    /// vQPN into the low 32 bits (Fig 4).
    pub wr_id: u64,
    /// Operation to perform.
    pub verb: Verb,
    /// Payload length in bytes (the simulator tracks extents, not bytes).
    pub len: u64,
    /// Local buffer (lkey + offset within the region).
    pub lkey: Mrkey,
    /// Local buffer address.
    pub laddr: u64,
    /// Remote buffer for one-sided verbs (ignored for SEND).
    pub rkey: Option<Mrkey>,
    /// Remote buffer address (one-sided verbs).
    pub raddr: u64,
    /// 4-byte immediate travelling with the message (SEND / WRITE-with-imm);
    /// RDMAvisor's vQPN carrier for two-sided traffic.
    pub imm_data: Option<u32>,
    /// UD only: destination address handle (node + remote QPN).
    pub ud_dest: Option<(NodeId, Qpn)>,
    /// Suppress the local completion (unsignaled WR) — halves CQE traffic
    /// on the RaaS hot path for WRITEs that the protocol acks elsewhere.
    pub signaled: bool,
}

impl SendWr {
    /// A SEND with immediate data.
    pub fn send(wr_id: u64, len: u64, lkey: Mrkey, laddr: u64, imm: u32) -> SendWr {
        SendWr {
            wr_id,
            verb: Verb::Send,
            len,
            lkey,
            laddr,
            rkey: None,
            raddr: 0,
            imm_data: Some(imm),
            ud_dest: None,
            signaled: true,
        }
    }

    /// A one-sided WRITE.
    pub fn write(
        wr_id: u64,
        len: u64,
        lkey: Mrkey,
        laddr: u64,
        rkey: Mrkey,
        raddr: u64,
    ) -> SendWr {
        SendWr {
            wr_id,
            verb: Verb::Write,
            len,
            lkey,
            laddr,
            rkey: Some(rkey),
            raddr,
            imm_data: None,
            ud_dest: None,
            signaled: true,
        }
    }

    /// A one-sided READ.
    pub fn read(
        wr_id: u64,
        len: u64,
        lkey: Mrkey,
        laddr: u64,
        rkey: Mrkey,
        raddr: u64,
    ) -> SendWr {
        SendWr {
            wr_id,
            verb: Verb::Read,
            len,
            lkey,
            laddr,
            rkey: Some(rkey),
            raddr,
            imm_data: None,
            ud_dest: None,
            signaled: true,
        }
    }

    /// Attach immediate data (WRITE-with-imm / SEND).
    pub fn with_imm(mut self, imm: u32) -> SendWr {
        self.imm_data = Some(imm);
        self
    }

    /// Suppress the local completion.
    pub fn unsignaled(mut self) -> SendWr {
        self.signaled = false;
        self
    }

    /// Address a UD datagram (per-WR address handle).
    pub fn to_ud(mut self, node: NodeId, qpn: Qpn) -> SendWr {
        self.ud_dest = Some((node, qpn));
        self
    }
}

/// A receive work request (posted to an RQ or SRQ).
#[derive(Clone, Debug)]
pub struct RecvWr {
    /// Returned in the responder-side CQE on consumption.
    pub wr_id: u64,
    /// Landing buffer's local key.
    pub lkey: Mrkey,
    /// Landing buffer address.
    pub laddr: u64,
    /// Landing buffer capacity.
    pub len: u64,
}

/// Which side/op a completion describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CqeKind {
    /// Initiator-side completion of a send-queue WR.
    SendDone(Verb),
    /// Responder-side completion of a consumed receive WQE (SEND arrived).
    Recv,
    /// Responder-side completion for WRITE-with-imm (consumes an RQ WQE).
    RecvRdmaWithImm,
}

/// A completion queue element.
#[derive(Clone, Debug)]
pub struct Cqe {
    /// The originating WR's id (vQPN carrier for one-sided verbs).
    pub wr_id: u64,
    /// Which side/op this completion describes.
    pub kind: CqeKind,
    /// Success or the failure class.
    pub status: WcStatus,
    /// Bytes transferred.
    pub len: u64,
    /// Immediate data, if the message carried one (vQPN for two-sided).
    pub imm_data: Option<u32>,
    /// Local QP this completion belongs to.
    pub qpn: Qpn,
    /// For Recv completions on UD: the source (node, qpn).
    pub src: Option<(NodeId, Qpn)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_set_fields() {
        let wr = SendWr::write(7, 64 << 10, Mrkey(1), 0x1000, Mrkey(2), 0x2000);
        assert_eq!(wr.verb, Verb::Write);
        assert_eq!(wr.rkey, Some(Mrkey(2)));
        assert!(wr.signaled);
        let wr = wr.with_imm(0xDEAD).unsignaled();
        assert_eq!(wr.imm_data, Some(0xDEAD));
        assert!(!wr.signaled);
    }

    #[test]
    fn send_carries_imm() {
        let wr = SendWr::send(1, 128, Mrkey(1), 0, 42);
        assert_eq!(wr.imm_data, Some(42));
        assert_eq!(wr.verb, Verb::Send);
        assert!(wr.rkey.is_none());
    }

    #[test]
    fn ud_dest() {
        let wr = SendWr::send(1, 128, Mrkey(1), 0, 0).to_ud(NodeId(2), Qpn(9));
        assert_eq!(wr.ud_dest, Some((NodeId(2), Qpn(9))));
    }

    #[test]
    fn wr_id_carries_32bit_vqpn() {
        // Fig 4: vQPN rides in the low 32 bits of wr_id
        let vqpn: u32 = 0xABCD_1234;
        let wr = SendWr::read(vqpn as u64, 4096, Mrkey(1), 0, Mrkey(2), 0);
        assert_eq!(wr.wr_id as u32, vqpn);
    }
}
