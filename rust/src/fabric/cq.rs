//! Completion queues.

use std::collections::VecDeque;

use super::types::Cqn;
use super::wqe::Cqe;

/// A completion queue with bounded capacity; overflow is recorded (real
/// RNICs raise a fatal async event — we latch a flag and count drops).
#[derive(Debug)]
pub struct Cq {
    /// This CQ's id on its node.
    pub cqn: Cqn,
    queue: VecDeque<Cqe>,
    capacity: usize,
    /// Latched on the first overflow (fatal on real RNICs).
    pub overflowed: bool,
    /// CQEs dropped by overflow.
    pub dropped: u64,
    /// Lifetime count of CQEs pushed (metrics).
    pub total: u64,
}

impl Cq {
    /// Create a CQ with `capacity` entries.
    pub fn new(cqn: Cqn, capacity: usize) -> Self {
        Cq {
            cqn,
            queue: VecDeque::with_capacity(capacity.min(1024)),
            capacity,
            overflowed: false,
            dropped: 0,
            total: 0,
        }
    }

    /// NIC-side push.
    pub fn push(&mut self, cqe: Cqe) {
        if self.queue.len() >= self.capacity {
            self.overflowed = true;
            self.dropped += 1;
            return;
        }
        self.total += 1;
        self.queue.push_back(cqe);
    }

    /// Consumer-side poll of up to `n` completions.
    pub fn poll(&mut self, n: usize) -> Vec<Cqe> {
        let k = n.min(self.queue.len());
        self.queue.drain(..k).collect()
    }

    /// Consumer-side poll of up to `n` completions into a caller-provided
    /// buffer (appended; the caller clears). Returns how many were
    /// appended — the zero-alloc twin of [`Cq::poll`] for the pollers
    /// that run once per simulated event.
    pub fn poll_into(&mut self, n: usize, out: &mut Vec<Cqe>) -> usize {
        let k = n.min(self.queue.len());
        out.extend(self.queue.drain(..k));
        k
    }

    /// Drop every unpolled completion (node soft-restart): work that
    /// finished but was never observed is gone, which is why the daemon
    /// needs its stale-lease reclaim.
    pub fn clear(&mut self) {
        self.queue.clear();
    }

    /// Completions waiting to be polled.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when no completions are pending.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Memory footprint of this CQ (ledger input): entries × CQE size.
    pub fn mem_bytes(&self) -> u64 {
        (self.capacity as u64) * CQE_BYTES
    }
}

/// Hardware CQE size (ConnectX family: 64 B).
pub const CQE_BYTES: u64 = 64;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::types::{Qpn, WcStatus};
    use crate::fabric::wqe::CqeKind;

    fn cqe(wr_id: u64) -> Cqe {
        Cqe {
            wr_id,
            kind: CqeKind::Recv,
            status: WcStatus::Success,
            len: 0,
            imm_data: None,
            qpn: Qpn(1),
            src: None,
        }
    }

    #[test]
    fn fifo_order() {
        let mut cq = Cq::new(Cqn(0), 16);
        for i in 0..5 {
            cq.push(cqe(i));
        }
        let got = cq.poll(3);
        assert_eq!(got.iter().map(|c| c.wr_id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(cq.len(), 2);
    }

    #[test]
    fn poll_more_than_present() {
        let mut cq = Cq::new(Cqn(0), 16);
        cq.push(cqe(1));
        assert_eq!(cq.poll(10).len(), 1);
        assert!(cq.poll(10).is_empty());
    }

    #[test]
    fn overflow_latches_and_drops() {
        let mut cq = Cq::new(Cqn(0), 2);
        cq.push(cqe(1));
        cq.push(cqe(2));
        cq.push(cqe(3));
        assert!(cq.overflowed);
        assert_eq!(cq.dropped, 1);
        assert_eq!(cq.len(), 2);
    }

    #[test]
    fn mem_accounting() {
        let cq = Cq::new(Cqn(0), 1024);
        assert_eq!(cq.mem_bytes(), 1024 * 64);
    }
}
