//! ibverbs-style convenience layer over [`Sim`].
//!
//! The RaaS daemon and both baselines are written against this façade the
//! same way the real RDMAvisor prototype is written against libibverbs.
//! It adds: connected-QP-pair setup in one call, UD endpoint setup, recv
//! buffer/WQE replenishing helpers, and the Table-1 capability probe used
//! by the conformance tests and `figures --table1`.

use super::mr::{Access, MemoryRegion};
use super::qp::PostError;
use super::sim::Sim;
use super::types::{max_msg_size, supports, Cqn, NodeId, QpTransport, Qpn, Srqn, Verb};
use super::wqe::{RecvWr, SendWr};

/// A fully-connected (RTS↔RTS) QP pair.
#[derive(Clone, Copy, Debug)]
pub struct QpPair {
    /// End A: (node, QPN).
    pub a: (NodeId, Qpn),
    /// End B: (node, QPN).
    pub b: (NodeId, Qpn),
}

/// Create CQs + QPs on both ends and connect them (RC/UC).
pub fn create_connected_pair(
    sim: &mut Sim,
    transport: QpTransport,
    a: NodeId,
    b: NodeId,
    a_send_cq: Cqn,
    a_recv_cq: Cqn,
    b_send_cq: Cqn,
    b_recv_cq: Cqn,
) -> QpPair {
    assert_ne!(transport, QpTransport::Ud, "UD is connectionless; use create_ud");
    let qa = sim.create_qp(a, transport, a_send_cq, a_recv_cq);
    let qb = sim.create_qp(b, transport, b_send_cq, b_recv_cq);
    sim.connect(a, qa, b, qb);
    QpPair { a: (a, qa), b: (b, qb) }
}

/// Create and activate a UD endpoint.
pub fn create_ud(sim: &mut Sim, node: NodeId, send_cq: Cqn, recv_cq: Cqn) -> Qpn {
    let qpn = sim.create_qp(node, QpTransport::Ud, send_cq, recv_cq);
    sim.activate_ud(node, qpn);
    qpn
}

/// Keep `target` receive WQEs posted on a private RQ, drawing buffers from
/// `mr` in fixed `slot` strides. Returns how many were posted.
pub fn replenish_rq(
    sim: &mut Sim,
    node: NodeId,
    qpn: Qpn,
    mr: &MemoryRegion,
    slot_bytes: u64,
    target: usize,
    next_wr_id: &mut u64,
) -> usize {
    let mut posted = 0;
    loop {
        let cur = sim.node(node).qps.get(qpn.0).map(|q| q.rq.len()).unwrap_or(0);
        if cur >= target {
            break;
        }
        let slot = (*next_wr_id as u64) % (mr.len / slot_bytes).max(1);
        let wr = RecvWr {
            wr_id: *next_wr_id,
            lkey: mr.key,
            laddr: mr.addr + slot * slot_bytes,
            len: slot_bytes,
        };
        *next_wr_id += 1;
        if sim.post_recv(node, qpn, wr).is_err() {
            break;
        }
        posted += 1;
    }
    posted
}

/// Keep `target` receive WQEs posted on an SRQ.
pub fn replenish_srq(
    sim: &mut Sim,
    node: NodeId,
    srqn: Srqn,
    mr: &MemoryRegion,
    slot_bytes: u64,
    target: usize,
    next_wr_id: &mut u64,
) -> usize {
    let mut posted = 0;
    loop {
        let cur = sim.node(node).srqs.get(srqn.0).map(|s| s.posted()).unwrap_or(0);
        if cur >= target {
            break;
        }
        let slot = *next_wr_id % (mr.len / slot_bytes).max(1);
        let wr = RecvWr {
            wr_id: *next_wr_id,
            lkey: mr.key,
            laddr: mr.addr + slot * slot_bytes,
            len: slot_bytes,
        };
        *next_wr_id += 1;
        if !sim.post_srq_recv(node, srqn, wr) {
            break;
        }
        posted += 1;
    }
    posted
}

/// One row of the Table-1 capability probe.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CapabilityRow {
    /// The probed transport.
    pub transport: QpTransport,
    /// Two-sided SEND/RECV supported.
    pub send_recv: bool,
    /// One-sided WRITE supported.
    pub write: bool,
    /// One-sided READ supported.
    pub read: bool,
    /// Maximum message size on this transport.
    pub max_msg: u64,
}

/// Probe the simulator's enforced capability matrix (must equal Table 1).
pub fn capability_matrix(mtu: u64) -> Vec<CapabilityRow> {
    [QpTransport::Rc, QpTransport::Uc, QpTransport::Ud]
        .into_iter()
        .map(|t| CapabilityRow {
            transport: t,
            send_recv: supports(t, Verb::Send),
            write: supports(t, Verb::Write),
            read: supports(t, Verb::Read),
            max_msg: max_msg_size(t, mtu),
        })
        .collect()
}

/// Convenience: post a send and panic with context on validation failure
/// (test/example use).
pub fn must_post(sim: &mut Sim, node: NodeId, qpn: Qpn, wr: SendWr) {
    if let Err(e) = sim.post_send(node, qpn, wr) {
        panic!("post_send failed on {node}/{qpn:?}: {e}");
    }
}

/// Register a remote-accessible buffer with huge pages (the default for
/// all systems in this reproduction, as the paper's implementation does).
pub fn reg_buffer(sim: &mut Sim, node: NodeId, len: u64) -> MemoryRegion {
    sim.reg_mr(node, len, Access::REMOTE_RW, true)
}

/// Validation error re-export for API users.
pub type VerbsError = PostError;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::sim::FabricConfig;

    #[test]
    fn capability_matrix_matches_table1() {
        let rows = capability_matrix(4096);
        let rc = &rows[0];
        assert!(rc.send_recv && rc.write && rc.read);
        assert_eq!(rc.max_msg, 1 << 30);
        let uc = &rows[1];
        assert!(uc.send_recv && uc.write && !uc.read);
        assert_eq!(uc.max_msg, 1 << 30);
        let ud = &rows[2];
        assert!(ud.send_recv && !ud.write && !ud.read);
        assert_eq!(ud.max_msg, 4096);
    }

    #[test]
    fn connected_pair_reaches_rts() {
        let mut sim = Sim::new(FabricConfig::default());
        let cq0 = sim.create_cq(NodeId(0), 64);
        let cq1 = sim.create_cq(NodeId(1), 64);
        let pair = create_connected_pair(
            &mut sim,
            QpTransport::Rc,
            NodeId(0),
            NodeId(1),
            cq0,
            cq0,
            cq1,
            cq1,
        );
        let qp = &sim.node(NodeId(0)).qps[pair.a.1 .0];
        assert_eq!(qp.state, crate::fabric::qp::QpState::Rts);
        assert_eq!(qp.peer, Some((NodeId(1), pair.b.1)));
    }

    #[test]
    fn replenish_fills_to_target() {
        let mut sim = Sim::new(FabricConfig::default());
        let cq = sim.create_cq(NodeId(0), 64);
        let qpn = sim.create_qp(NodeId(0), QpTransport::Rc, cq, cq);
        sim.node_mut(NodeId(0)).qps.get_mut(qpn.0).unwrap().to_rtr();
        let mr = reg_buffer(&mut sim, NodeId(0), 1 << 20);
        let mut next = 0;
        let posted = replenish_rq(&mut sim, NodeId(0), qpn, &mr, 4096, 32, &mut next);
        assert_eq!(posted, 32);
        // idempotent: already at target
        let posted2 = replenish_rq(&mut sim, NodeId(0), qpn, &mr, 4096, 32, &mut next);
        assert_eq!(posted2, 0);
    }

    #[test]
    fn srq_replenish() {
        let mut sim = Sim::new(FabricConfig::default());
        let srqn = sim.create_srq(NodeId(0), 128, 8);
        let mr = reg_buffer(&mut sim, NodeId(0), 1 << 20);
        let mut next = 0;
        assert_eq!(replenish_srq(&mut sim, NodeId(0), srqn, &mr, 4096, 64, &mut next), 64);
        assert_eq!(sim.node(NodeId(0)).srqs[srqn.0].posted(), 64);
    }
}
