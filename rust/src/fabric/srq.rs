//! Shared receive queues.
//!
//! An SRQ pools receive WQEs across many QPs; §1.2 of the paper extends the
//! idea across *applications*: RDMAvisor posts one host-wide SRQ per NIC so
//! every application's two-sided traffic draws from one buffer pool. The
//! starvation watermark models the paper's "data sink consumer may be
//! unaware that the RQ is starving" concern — consumers can query it.

use std::collections::VecDeque;

use super::types::Srqn;
use super::wqe::RecvWr;

/// Hardware receive WQE size (ConnectX family: 16 B per SGE slot, one SGE).
pub const RECV_WQE_BYTES: u64 = 16;

/// A shared receive queue: a pool of receive WQEs many QPs draw from.
#[derive(Debug)]
pub struct Srq {
    /// This SRQ's id on its node.
    pub srqn: Srqn,
    queue: VecDeque<RecvWr>,
    capacity: usize,
    /// Below this many posted WQEs the SRQ reports "starving" (limit event).
    pub watermark: usize,
    /// Lifetime counters.
    pub consumed: u64,
    /// Times a consume left the queue below the watermark.
    pub starved_events: u64,
    /// Incoming SENDs that found no WQE (-> RNR at the requester).
    pub rnr_drops: u64,
}

impl Srq {
    /// Create an empty SRQ with `capacity` slots and a starvation `watermark`.
    pub fn new(srqn: Srqn, capacity: usize, watermark: usize) -> Self {
        Srq {
            srqn,
            queue: VecDeque::new(),
            capacity,
            watermark,
            consumed: 0,
            starved_events: 0,
            rnr_drops: 0,
        }
    }

    /// Post a receive WQE; returns false if the SRQ is full.
    pub fn post(&mut self, wr: RecvWr) -> bool {
        if self.queue.len() >= self.capacity {
            return false;
        }
        self.queue.push_back(wr);
        true
    }

    /// NIC consumes one WQE for an arriving SEND; None => RNR.
    pub fn consume(&mut self) -> Option<RecvWr> {
        match self.queue.pop_front() {
            Some(wr) => {
                self.consumed += 1;
                if self.queue.len() < self.watermark {
                    self.starved_events += 1;
                }
                Some(wr)
            }
            None => {
                self.rnr_drops += 1;
                None
            }
        }
    }

    /// Receive WQEs currently posted.
    pub fn posted(&self) -> usize {
        self.queue.len()
    }

    /// Drop every posted WQE (node soft-restart). The owning daemon's
    /// next pump refills from its pool, exactly like a daemon process
    /// coming back up.
    pub fn clear(&mut self) {
        self.queue.clear();
    }

    /// True when posted WQEs are below the watermark (limit event).
    pub fn is_starving(&self) -> bool {
        self.queue.len() < self.watermark
    }

    /// Memory footprint (ledger): capacity × WQE size (the WQE ring is
    /// allocated up front by the provider).
    pub fn mem_bytes(&self) -> u64 {
        self.capacity as u64 * RECV_WQE_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::types::Mrkey;

    fn wr(id: u64) -> RecvWr {
        RecvWr { wr_id: id, lkey: Mrkey(1), laddr: 0, len: 4096 }
    }

    #[test]
    fn post_consume_fifo() {
        let mut s = Srq::new(Srqn(0), 8, 2);
        assert!(s.post(wr(1)));
        assert!(s.post(wr(2)));
        assert_eq!(s.consume().unwrap().wr_id, 1);
        assert_eq!(s.consume().unwrap().wr_id, 2);
        assert_eq!(s.consumed, 2);
    }

    #[test]
    fn rnr_when_empty() {
        let mut s = Srq::new(Srqn(0), 8, 0);
        assert!(s.consume().is_none());
        assert_eq!(s.rnr_drops, 1);
    }

    #[test]
    fn capacity_bound() {
        let mut s = Srq::new(Srqn(0), 2, 0);
        assert!(s.post(wr(1)));
        assert!(s.post(wr(2)));
        assert!(!s.post(wr(3)));
        assert_eq!(s.posted(), 2);
    }

    #[test]
    fn starvation_watermark() {
        let mut s = Srq::new(Srqn(0), 8, 3);
        for i in 0..4 {
            s.post(wr(i));
        }
        assert!(!s.is_starving());
        s.consume();
        s.consume();
        assert!(s.is_starving());
        assert!(s.starved_events > 0);
    }

    #[test]
    fn mem_bytes() {
        let s = Srq::new(Srqn(0), 1024, 16);
        assert_eq!(s.mem_bytes(), 1024 * RECV_WQE_BYTES);
    }
}
