//! The discrete-event simulator: a sharded coordinator over per-partition
//! engines.
//!
//! Drivers (workload generators, the RaaS daemon, baselines) interact with
//! the sim through the verbs-style API (`create_qp`, `post_send`,
//! `poll_cq`, …) and advance virtual time by calling [`Sim::step`] (or the
//! zero-alloc [`Sim::step_into`]), which processes one **conservative
//! window** of events and reports completion notifications. Everything is
//! deterministic: same calls + same seeds ⇒ identical timelines, for every
//! shard count.
//!
//! ### Sharded execution model (DESIGN.md §13)
//!
//! The cluster's nodes are partitioned round-robin over
//! [`FabricConfig::shards`] shards ([`super::shard::Shard`]), each owning
//! its nodes' full NIC state, egress ports, and its own timing wheel.
//! [`Sim::step_into`] is the barrier loop:
//!
//! 1. apply last window's staged RC sequence resyncs;
//! 2. find the global minimum pending time `t` (shard wheels + staged
//!    wire) and derive the window `[start, start + W)` containing it,
//!    with `W = switch_latency_ns.max(1)`;
//! 3. absorb every staged frame with `link_at < end` into its
//!    destination's ingress port — in global `(link_at, src, emit)`
//!    order, a total order — and push the deliveries into the owning
//!    shards' wheels;
//! 4. refresh each shard's ingress-busy snapshot (the PFC gate input);
//! 5. run all shards through the window — in parallel on a persistent
//!    worker pool when `shards > 1`, directly on the calling thread when
//!    `shards == 1`;
//! 6. merge staged outputs deterministically (sort frames/resyncs by
//!    their total orders, notifications by `(time, node)` stably) and
//!    advance the clock to the barrier.
//!
//! Conservative safety: a frame staged inside a window cannot have
//! `link_at` before that window's end (lookahead = switch latency), so no
//! shard can ever need another shard's same-window output. That makes the
//! parallel run **byte-identical** to `shards = 1` — gated by
//! `tests/determinism.rs`.
//!
//! ### Engine model
//!
//! Each NIC has one processing engine that serially executes
//! [`super::nic::WorkItem`]s with costs from [`NicConfig`]. Multi-frame
//! messages are emitted **one frame per work item** on the responder's
//! READ path, re-enqueuing the remainder at the tail — so concurrent
//! responses interleave frame-by-frame exactly like a real RNIC's
//! processing units, which is what makes the receiver's ICM cache thrash
//! under high QP counts (Fig 5's mechanism). The engine itself lives in
//! [`super::shard`]; this module is the coordinator plus the public API.

use super::cq::Cq;
use super::fault::{FaultConfig, FaultStats};
use super::mr::{Access, MemoryRegion};
use super::nic::NicConfig;
use super::qp::{PostError, Qp};
use super::shard::{Resync, Shard, StagedFrame};
use super::srq::Srq;
use super::switchfab::Fabric;
use super::time::Ns;
use super::topo::{CcMode, Clos, ClosStats, ClosVerdict, TopoConfig};
use super::types::{Cqn, NodeId, QpTransport, Qpn, Srqn};
use super::wqe::{Cqe, RecvWr, SendWr};
use crate::util::parallel::{effective_jobs, OwnedPool};

pub use super::shard::NodeState;

/// Whole-fabric configuration.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    /// Machines in the cluster.
    pub nodes: usize,
    /// CPU cores per machine.
    pub cores_per_node: u32,
    /// Per-port line rate.
    pub link_gbps: f64,
    /// Maximum frame payload.
    pub mtu: u64,
    /// One-way propagation + switch latency — also the sharded
    /// simulator's conservative lookahead (window width).
    pub switch_latency_ns: u64,
    /// RNIC engine cost/capacity model.
    pub nic: NicConfig,
    /// Default queue depths.
    pub sq_depth: usize,
    /// Default receive-queue depth.
    pub rq_depth: usize,
    /// RC requester window (outstanding messages per QP).
    pub max_outstanding: usize,
    /// CPU cost of a post_send/post_recv call (driver side).
    pub post_cpu_ns: u64,
    /// CPU cost of a poll_cq call + per-CQE handling.
    pub poll_cpu_ns: u64,
    /// CPU cost per CQE handled after a poll.
    pub per_cqe_cpu_ns: u64,
    /// Simulator node partitions run in parallel (1 = serial, the
    /// default; 0 = one per available core). Clamped to the node count.
    /// Output is byte-identical for every value; `> 1` requires
    /// `switch_latency_ns > 0` (the lookahead bound).
    pub shards: usize,
    /// Multi-switch Clos topology + congestion control ([`super::topo`]).
    /// `None` (the default) keeps the single-switch fabric and every
    /// pre-existing figure byte-identical. When set, the RC
    /// retransmission machinery is armed (Clos ports tail-drop when full)
    /// and cross-ToR frames pay uplink + spine hops at the barrier.
    pub topo: Option<TopoConfig>,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            nodes: 4,
            cores_per_node: 24,
            link_gbps: 40.0,
            mtu: 4096,
            switch_latency_ns: 1000,
            nic: NicConfig::default(),
            sq_depth: 256,
            rq_depth: 256,
            max_outstanding: 16,
            post_cpu_ns: 150,
            poll_cpu_ns: 80,
            per_cqe_cpu_ns: 50,
            shards: 1,
            topo: None,
        }
    }
}

/// What [`Sim::step`] reports back to the driver.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Notification {
    /// A CQE landed in (node, cqn) — the driver should poll it.
    CqeReady {
        /// Node owning the CQ.
        node: NodeId,
        /// The CQ with a fresh entry.
        cqn: Cqn,
    },
    /// A timer scheduled via [`Sim::schedule`] fired.
    Timer {
        /// The token passed to [`Sim::schedule`].
        token: u64,
    },
}

/// A scheduled switch-level topology fault (DESIGN.md §15), built from
/// [`FaultConfig::uplink_deaths`] / [`FaultConfig::spine_windows`] and
/// applied to the coordinator-owned [`Clos`] at window barriers — before
/// any of that window's frames are absorbed. Window boundaries are
/// shard-count-invariant, so failure/recovery instants land identically
/// under every shard count (quantized to the barrier, ≤ one window).
#[derive(Clone, Copy, Debug)]
enum TopoEvent {
    /// Permanent death of one ToR uplink port.
    UplinkDown { tor: u32, u: u32 },
    /// Whole-spine failure window opens.
    SpineDown(u32),
    /// Whole-spine failure window closes.
    SpineUp(u32),
    /// Control-plane reconvergence: fold the physical truth into the
    /// routing mask ([`Clos::reconverge`]), `reroute_lag_ns` after the
    /// change it reacts to. Not scheduled when `topo.repath` is off.
    Reconverge,
}

/// The simulator: shard coordinator + verbs API.
pub struct Sim {
    /// The configuration the fabric was built from.
    pub cfg: FabricConfig,
    clock: Ns,
    /// Conservative window width (`switch_latency_ns.max(1)`).
    window: u64,
    nshards: usize,
    shards: Vec<Shard>,
    /// Coordinator-owned network state: every node's **ingress** port
    /// (egress ports live in the shards). Frames absorbed at barriers.
    fabric: Fabric,
    /// Coordinator-owned Clos switch tiers (uplink/spine ports, ECN/drop
    /// counters); `None` on the single-switch fabric. Mutated only inside
    /// [`Sim::absorb_wire`]'s global frame order — deterministic for
    /// every shard count.
    clos: Option<Clos>,
    /// Persistent worker pool, spawned lazily on the first parallel
    /// window (never for `shards == 1`).
    pool: Option<OwnedPool<Shard>>,
    /// Staged frames not yet absorbed, sorted by `(link_at, src, emit)`.
    pending_wire: Vec<StagedFrame>,
    /// Staged RC sequence resyncs, applied at the next barrier.
    pending_resync: Vec<Resync>,
    /// Scratch: this window's notifications, merged by `(time, node)`.
    note_buf: Vec<(Ns, NodeId, Notification)>,
    /// Scratch: ingress busy-horizon snapshot (index = node id).
    snap_buf: Vec<Ns>,
    /// Scratch: ToR-uplink busy-horizon snapshot (PFC mode only).
    up_snap_buf: Vec<Ns>,
    /// Scheduled switch-level faults, sorted by `(time, kind rank)`;
    /// `topo_cursor` marks how far the barriers have applied them.
    topo_events: Vec<(Ns, u8, TopoEvent)>,
    topo_cursor: usize,
    /// Completed payload bytes (data verbs), for quick aggregate throughput.
    pub completed_bytes: u64,
    /// Completed data messages (companion counter).
    pub completed_msgs: u64,
    steps: u64,
    faults_on: bool,
}

impl Sim {
    /// Build a quiescent cluster at virtual time zero, panicking on an
    /// invalid sharding request (see [`Sim::try_new`]).
    pub fn new(cfg: FabricConfig) -> Self {
        Sim::try_new(cfg).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Build a quiescent cluster at virtual time zero.
    ///
    /// Rejects `shards > 1` with `switch_latency_ns == 0`: conservative
    /// parallel execution uses the switch latency as its lookahead bound,
    /// and zero lookahead would degenerate every window to a single event
    /// — serial execution with barrier overhead on top.
    pub fn try_new(cfg: FabricConfig) -> Result<Self, String> {
        let requested = if cfg.shards == 0 { effective_jobs(0) } else { cfg.shards };
        let nshards = requested.clamp(1, cfg.nodes.max(1));
        if nshards > 1 && cfg.switch_latency_ns == 0 {
            return Err(format!(
                "shards = {nshards} requires switch_latency_ns > 0: conservative parallel \
                 execution uses the switch latency as its lookahead bound, and zero lookahead \
                 degenerates to serial execution — run with shards = 1 instead"
            ));
        }
        if let Some(t) = &cfg.topo {
            if t.hosts_per_tor == 0 {
                return Err("topo.hosts_per_tor must be > 0".into());
            }
        }
        let fabric = Fabric::new(cfg.nodes, cfg.link_gbps, cfg.mtu, Ns(cfg.switch_latency_ns));
        let clos = cfg.topo.map(|t| Clos::new(cfg.nodes, cfg.link_gbps, t));
        let shards = (0..nshards).map(|i| Shard::new(i, nshards, &cfg)).collect();
        Ok(Sim {
            window: cfg.switch_latency_ns.max(1),
            snap_buf: vec![Ns::ZERO; cfg.nodes],
            cfg,
            clock: Ns::ZERO,
            nshards,
            shards,
            fabric,
            clos,
            pool: None,
            pending_wire: Vec::new(),
            pending_resync: Vec::new(),
            note_buf: Vec::new(),
            up_snap_buf: Vec::new(),
            topo_events: Vec::new(),
            topo_cursor: 0,
            completed_bytes: 0,
            completed_msgs: 0,
            steps: 0,
            faults_on: false,
        })
    }

    /// Install a seeded fault plan ([`super::fault`]). A null plan (zero
    /// rates, no flaps, no restarts) installs nothing, which is the
    /// loss-0 byte-identity guarantee. Must be called before any traffic
    /// is driven: the RC go-back-N discipline assumes sequence counters
    /// and the fault gate switch on together. Each node gets an
    /// independent deterministic fork of the plan's RNG
    /// ([`super::fault::FaultState::for_node`]), so fault draws are a
    /// function of the destination node alone — invariant under sharding.
    pub fn install_faults(&mut self, cfg: FaultConfig) {
        if cfg.is_null() {
            return;
        }
        assert!(
            self.steps == 0 && self.shards.iter().all(|s| s.wheel_len() == 0),
            "install_faults must run before the first event"
        );
        for &(node, at) in &cfg.restarts {
            debug_assert!((node as usize) < self.cfg.nodes, "restart of unknown node");
            let nid = NodeId(node);
            let s = nid.shard_of(self.nshards);
            self.shards[s].push_restart(Ns(at).max(self.clock), nid);
        }
        for sh in &mut self.shards {
            sh.install_fault_forks(&cfg);
        }
        // Switch-level faults need a Clos to act on; on the single-switch
        // fabric they are inert (the plan still arms the RC machinery).
        if self.clos.is_some() && (!cfg.uplink_deaths.is_empty() || !cfg.spine_windows.is_empty()) {
            let (repath, lag) = self
                .cfg
                .topo
                .map(|t| (t.repath, t.reroute_lag_ns))
                .unwrap_or((false, 0));
            let mut ev: Vec<(Ns, u8, TopoEvent)> = Vec::new();
            for &(tor, u, at) in &cfg.uplink_deaths {
                ev.push((Ns(at), 1, TopoEvent::UplinkDown { tor, u }));
                if repath {
                    ev.push((Ns(at + lag), 3, TopoEvent::Reconverge));
                }
            }
            for &(s, from, until) in &cfg.spine_windows {
                debug_assert!(from < until, "empty spine window");
                ev.push((Ns(from), 2, TopoEvent::SpineDown(s)));
                ev.push((Ns(until), 0, TopoEvent::SpineUp(s)));
                if repath {
                    ev.push((Ns(from + lag), 3, TopoEvent::Reconverge));
                    ev.push((Ns(until + lag), 3, TopoEvent::Reconverge));
                }
            }
            // stable sort: same-instant ties resolve in config order —
            // revive before kill before reconverge, so a window closing
            // exactly when another opens never leaves a phantom death
            ev.sort_by_key(|&(at, rank, _)| (at.0, rank));
            self.topo_events = ev;
        }
        self.faults_on = true;
    }

    /// Is a (non-null) fault plan installed?
    pub fn faults_active(&self) -> bool {
        self.faults_on
    }

    /// Snapshot of the fault layer's counters, summed over the per-node
    /// forks (None without a plan).
    pub fn fault_stats(&self) -> Option<FaultStats> {
        if !self.faults_on {
            return None;
        }
        let mut total = FaultStats::default();
        for sh in &self.shards {
            sh.fold_fault_stats(&mut total);
        }
        Some(total)
    }

    /// Current virtual time (barrier-aligned: the end of the last
    /// processed window, or the deadline of the last `run_until`).
    pub fn now(&self) -> Ns {
        self.clock
    }

    /// Events processed since construction (the DES throughput metric the
    /// `bench simstep` / `bench fig9` targets report). Summed over shards;
    /// invariant across shard counts.
    pub fn steps_processed(&self) -> u64 {
        self.steps
    }

    /// Number of node partitions executing in parallel.
    pub fn shard_count(&self) -> usize {
        self.nshards
    }

    /// A node's state.
    pub fn node(&self, id: NodeId) -> &NodeState {
        self.shards[id.shard_of(self.nshards)].node(id)
    }

    /// A node's state, mutably.
    pub fn node_mut(&mut self, id: NodeId) -> &mut NodeState {
        self.shards[id.shard_of(self.nshards)].node_mut(id)
    }

    /// Every node's state, in node-id order (replaces direct access to
    /// the pre-sharding `nodes` vector).
    pub fn nodes(&self) -> impl Iterator<Item = &NodeState> + '_ {
        (0..self.cfg.nodes as u32).map(move |i| self.node(NodeId(i)))
    }

    // ------------------------------------------------------------ verbs API

    /// Create a completion queue on `node`.
    pub fn create_cq(&mut self, node: NodeId, capacity: usize) -> Cqn {
        let n = self.node_mut(node);
        let cqn = Cqn(n.cqs.next_id());
        n.cqs.insert(Cq::new(cqn, capacity));
        cqn
    }

    /// Create a shared receive queue on `node`.
    pub fn create_srq(&mut self, node: NodeId, capacity: usize, watermark: usize) -> Srqn {
        let n = self.node_mut(node);
        let srqn = Srqn(n.srqs.next_id());
        n.srqs.insert(Srq::new(srqn, capacity, watermark));
        srqn
    }

    /// Create a QP on `node` (Reset state; connect/activate it next).
    pub fn create_qp(
        &mut self,
        node: NodeId,
        transport: QpTransport,
        send_cq: Cqn,
        recv_cq: Cqn,
    ) -> Qpn {
        let (sq, rq, win) = (self.cfg.sq_depth, self.cfg.rq_depth, self.cfg.max_outstanding);
        let n = self.node_mut(node);
        let qpn = Qpn(n.qps.next_id());
        n.qps.insert(Qp::new(qpn, transport, send_cq, recv_cq, sq, rq, win));
        qpn
    }

    /// Point a QP's receive side at an SRQ.
    pub fn attach_srq(&mut self, node: NodeId, qpn: Qpn, srqn: Srqn) {
        let n = self.node_mut(node);
        n.qps.get_mut(qpn.0).expect("no such qp").srq = Some(srqn);
    }

    /// Resize a QP's send-queue capacity after creation (e.g. the RaaS
    /// daemon's host-wide UD QP, which multiplexes every migrated
    /// destination and needs a far deeper SQ than the per-peer default).
    pub fn set_sq_depth(&mut self, node: NodeId, qpn: Qpn, depth: usize) {
        let n = self.node_mut(node);
        n.qps.get_mut(qpn.0).expect("no such qp").sq_depth = depth;
    }

    /// Destroy a QP: rings and on-NIC context are freed (its
    /// [`NodeState::fabric_mem_bytes`] contribution drops to zero) and any
    /// frame still in flight toward it dies at the destination NIC. The
    /// dense id table keeps the slot so QPNs stay stable; callers are
    /// expected to destroy only quiesced QPs (no outstanding messages) —
    /// the RaaS control plane drains before it parks or evicts.
    pub fn destroy_qp(&mut self, node: NodeId, qpn: Qpn) {
        let n = self.node_mut(node);
        n.qps.get_mut(qpn.0).expect("no such qp").destroy();
    }

    /// Register a memory region on `node`.
    pub fn reg_mr(&mut self, node: NodeId, len: u64, access: Access, huge: bool) -> MemoryRegion {
        self.node_mut(node).mrs.register(len, access, huge)
    }

    /// Transition both QPs to RTS, bound to each other (RC/UC connect).
    pub fn connect(&mut self, a: NodeId, a_qpn: Qpn, b: NodeId, b_qpn: Qpn) {
        {
            let qp = self.node_mut(a).qps.get_mut(a_qpn.0).expect("no qp a");
            qp.to_rtr();
            qp.to_rts(Some((b, b_qpn)));
        }
        {
            let qp = self.node_mut(b).qps.get_mut(b_qpn.0).expect("no qp b");
            qp.to_rtr();
            qp.to_rts(Some((a, a_qpn)));
        }
    }

    /// Bring a UD QP up (no peer binding).
    pub fn activate_ud(&mut self, node: NodeId, qpn: Qpn) {
        let qp = self.node_mut(node).qps.get_mut(qpn.0).expect("no qp");
        debug_assert_eq!(qp.transport, QpTransport::Ud);
        qp.to_rtr();
        qp.to_rts(None);
    }

    /// Post a send WR and ring the doorbell. Charges driver CPU.
    pub fn post_send(&mut self, node: NodeId, qpn: Qpn, wr: SendWr) -> Result<(), PostError> {
        let s = node.shard_of(self.nshards);
        self.shards[s].post_send(node, qpn, wr)
    }

    /// Post a chain of WRs with ONE doorbell (WR batching — §2.3's
    /// "sharing QP promotes the probability of batching WRs").
    pub fn post_send_batch(
        &mut self,
        node: NodeId,
        qpn: Qpn,
        wrs: Vec<SendWr>,
    ) -> Result<usize, PostError> {
        let s = node.shard_of(self.nshards);
        self.shards[s].post_send_batch(node, qpn, wrs)
    }

    /// Post a receive WR on a QP's private RQ. Charges driver CPU.
    pub fn post_recv(&mut self, node: NodeId, qpn: Qpn, wr: RecvWr) -> Result<(), PostError> {
        let s = node.shard_of(self.nshards);
        self.shards[s].post_recv(node, qpn, wr)
    }

    /// Post a receive WR on an SRQ; false when full. Charges driver CPU.
    pub fn post_srq_recv(&mut self, node: NodeId, srqn: Srqn, wr: RecvWr) -> bool {
        let s = node.shard_of(self.nshards);
        self.shards[s].post_srq_recv(node, srqn, wr)
    }

    /// Free send-queue slots on a QP (drivers use this to size batches).
    pub fn sq_free(&self, node: NodeId, qpn: Qpn) -> usize {
        self.node(node)
            .qps
            .get(qpn.0)
            .map(|qp| qp.sq_depth.saturating_sub(qp.sq.len()))
            .unwrap_or(0)
    }

    /// Poll up to `max` CQEs; charges poller CPU.
    pub fn poll_cq(&mut self, node: NodeId, cqn: Cqn, max: usize) -> Vec<Cqe> {
        let mut out = Vec::new();
        self.poll_cq_into(node, cqn, max, &mut out);
        out
    }

    /// Poll up to `max` CQEs into a caller-provided buffer (appended; the
    /// caller clears between polls). Returns how many were appended.
    /// Charges poller CPU — the zero-alloc form the hot pollers use.
    pub fn poll_cq_into(
        &mut self,
        node: NodeId,
        cqn: Cqn,
        max: usize,
        out: &mut Vec<Cqe>,
    ) -> usize {
        let s = node.shard_of(self.nshards);
        self.shards[s].poll_cq_into(node, cqn, max, out)
    }

    // ---------------------------------------------------------- event loop

    /// Process one conservative window of events; returns notifications,
    /// or None when the timeline is exhausted. Allocating convenience
    /// form of [`Sim::step_into`].
    pub fn step(&mut self) -> Option<Vec<Notification>> {
        let mut notes = Vec::new();
        if self.step_into(&mut notes) {
            Some(notes)
        } else {
            None
        }
    }

    /// Process one conservative window `[start, start + W)` containing
    /// the earliest pending event, **appending** its notifications to
    /// `notes` (the caller clears between steps and reuses the buffer —
    /// zero-alloc in steady state). Returns false when the timeline is
    /// exhausted. See the module docs for the barrier protocol.
    pub fn step_into(&mut self, notes: &mut Vec<Notification>) -> bool {
        self.apply_resyncs();
        let Some(t) = self.next_event_time() else { return false };
        let w = self.window;
        let end = Ns(t.0 / w * w + w);
        // switch-level faults apply BEFORE absorption, so every frame of
        // this window routes against the same topology — on every shard
        // count (the barrier grid is shard-count-invariant)
        self.apply_topo_events(end);
        self.absorb_wire(end);
        self.refresh_snaps();
        self.run_shards(end);
        self.collect(notes);
        self.clock = end;
        true
    }

    /// Earliest pending virtual time: shard wheels + staged wire (resyncs
    /// are not events — they piggyback on the window that follows them).
    fn next_event_time(&self) -> Option<Ns> {
        let mut t = self.pending_wire.first().map(|f| f.link_at);
        for sh in &self.shards {
            if let Some(p) = sh.peek() {
                t = Some(match t {
                    Some(cur) => cur.min(p),
                    None => p,
                });
            }
        }
        t
    }

    /// Apply every scheduled switch-level fault with `at < end` to the
    /// coordinator-owned Clos, then — if a reconvergence changed the
    /// routing mask — push the fresh mask to every shard (their host-side
    /// path picks must agree with the switch's own rendezvous pick).
    fn apply_topo_events(&mut self, end: Ns) {
        if self.topo_cursor >= self.topo_events.len() {
            return;
        }
        let Some(clos) = self.clos.as_mut() else {
            self.topo_cursor = self.topo_events.len();
            return;
        };
        let mut remasked = false;
        while let Some(&(at, _, ev)) = self.topo_events.get(self.topo_cursor) {
            if at >= end {
                break;
            }
            self.topo_cursor += 1;
            match ev {
                TopoEvent::UplinkDown { tor, u } => clos.kill_uplink(tor as usize, u as usize),
                TopoEvent::SpineDown(s) => clos.kill_spine(s as usize),
                TopoEvent::SpineUp(s) => clos.revive_spine(s as usize),
                TopoEvent::Reconverge => remasked |= clos.reconverge(),
            }
        }
        if remasked {
            let live = clos.route_live().to_vec();
            for sh in &mut self.shards {
                sh.set_route_live(&live);
            }
        }
    }

    /// Apply last window's staged RC sequence resyncs (already sorted by
    /// `(at, src, emit)`; applied as a max, so order is belt-and-braces).
    fn apply_resyncs(&mut self) {
        for r in self.pending_resync.drain(..) {
            let s = r.peer.shard_of(self.nshards);
            self.shards[s].apply_resync(r.peer, r.peer_qpn, r.next_seq);
        }
    }

    /// Absorb every staged frame with `link_at < end` into its
    /// destination's ingress port, in global `(link_at, src, emit)` order
    /// (`pending_wire` is kept sorted by [`Sim::collect`]), and push the
    /// deliveries into the owning shards' wheels.
    ///
    /// With a Clos topology installed, a cross-ToR frame first crosses
    /// its ECMP uplink + spine ports here (tail-drop / ECN-mark / pause
    /// per [`CcMode`]), then the destination ingress applies the same
    /// finite-buffer discipline. All of it happens in the one global
    /// frame order, so the Clos state evolves identically for every
    /// shard count; hops only ever push delivery *later* than the staged
    /// `link_at`, so the conservative lookahead bound is untouched.
    fn absorb_wire(&mut self, end: Ns) {
        let cut = self.pending_wire.partition_point(|f| f.link_at < end);
        if cut == 0 {
            return;
        }
        for sf in self.pending_wire.drain(..cut) {
            let mut frame = sf.frame;
            let mut at = sf.link_at;
            if let Some(clos) = self.clos.as_mut() {
                if clos.tor_of(frame.src) != clos.tor_of(frame.dst) {
                    let dst_busy = self.fabric.ingress_stats(frame.dst).busy_until();
                    match clos.route(
                        at,
                        frame.src,
                        frame.dst,
                        frame.src_qpn,
                        frame.dst_qpn,
                        frame.path_salt,
                        frame.bytes,
                        frame.kind.carries_data(),
                        dst_busy,
                    ) {
                        ClosVerdict::Deliver(t, marked) => {
                            at = t;
                            frame.ecn |= marked;
                        }
                        ClosVerdict::Drop => continue,
                    }
                }
                // The destination host-ingress port is a queue too: same
                // finite buffer + ECN threshold (the true incast hot spot).
                if clos.topo.mode != CcMode::Pfc {
                    let backlog =
                        self.fabric.ingress_stats(frame.dst).busy_until().saturating_sub(at);
                    if backlog > clos.buffer() {
                        clos.note_ingress_drop();
                        continue;
                    }
                    if frame.kind.carries_data() && !frame.ecn && backlog > clos.ecn_threshold() {
                        frame.ecn = true;
                        clos.note_ingress_mark();
                    }
                }
            }
            let deliver = self.fabric.absorb_frame(at, frame.dst, frame.bytes);
            let s = frame.dst.shard_of(self.nshards);
            self.shards[s].push_frame(deliver, frame);
        }
    }

    /// Refresh every shard's snapshot of the ingress busy horizons (the
    /// egress-side PFC gate input) — after absorption, so this window's
    /// deliveries are visible to this window's transmitters.
    fn refresh_snaps(&mut self) {
        self.fabric.ingress_snapshot_into(&mut self.snap_buf);
        for sh in &mut self.shards {
            sh.set_ingress_snap(&self.snap_buf);
        }
        // PFC chains all the way to the hosts: shards gate cross-ToR
        // egress on a barrier-refreshed snapshot of their ToR's uplink
        // horizons (deterministic — same staleness on every shard count).
        if let Some(clos) = &self.clos {
            if clos.topo.mode == CcMode::Pfc {
                clos.uplink_snapshot_into(&mut self.up_snap_buf);
                for sh in &mut self.shards {
                    sh.set_uplink_snap(&self.up_snap_buf);
                }
            }
        }
    }

    /// Run every shard through `[.., end)`. Serial (`shards == 1`) runs
    /// on the calling thread — no pool, no channel hops, the exact
    /// single-threaded path. Parallel scatters to the persistent pool.
    fn run_shards(&mut self, end: Ns) {
        if self.nshards == 1 {
            self.shards[0].run_window(end);
            return;
        }
        let workers = self.nshards.min(effective_jobs(0));
        let pool = self.pool.get_or_insert_with(|| OwnedPool::new(workers));
        let shards = std::mem::take(&mut self.shards);
        self.shards = pool.scatter(shards, move |s| s.run_window(end));
    }

    /// Merge the window's staged outputs: frames and resyncs into the
    /// pending queues (re-sorted by their total orders), notifications
    /// stably sorted by `(time, node)` — per-node subsequences are
    /// shard-count-invariant, so this merged order is too. Aggregate
    /// counters are refreshed as sums over shards (commutative).
    fn collect(&mut self, notes: &mut Vec<Notification>) {
        for sh in &mut self.shards {
            self.pending_wire.append(&mut sh.out_wire);
            self.pending_resync.append(&mut sh.out_resync);
            self.note_buf.append(&mut sh.out_notes);
        }
        self.pending_wire.sort_by_key(|f| (f.link_at.0, f.frame.src.0, f.emit));
        self.pending_resync.sort_by_key(|r| (r.at.0, r.src.0, r.emit));
        self.note_buf.sort_by_key(|&(t, n, _)| (t.0, n.0)); // stable
        notes.extend(self.note_buf.drain(..).map(|(_, _, note)| note));
        self.completed_bytes = self.shards.iter().map(|s| s.completed_bytes).sum();
        self.completed_msgs = self.shards.iter().map(|s| s.completed_msgs).sum();
        self.steps = self.shards.iter().map(|s| s.steps).sum();
    }

    /// Schedule a driver timer at absolute time `at` (clamped to now).
    /// Timers live on shard 0, so their firing order is shard-count
    /// invariant by construction.
    pub fn schedule(&mut self, at: Ns, token: u64) {
        let at = at.max(self.clock);
        self.shards[0].push_timer(at, token);
    }

    /// Run until the event queue drains or `deadline` passes; collect all
    /// notifications. Allocating convenience form of
    /// [`Sim::run_until_into`].
    pub fn run_until(&mut self, deadline: Ns) -> Vec<Notification> {
        let mut out = Vec::new();
        self.run_until_into(deadline, &mut out);
        out
    }

    /// Run until the event queue drains or `deadline` passes, appending
    /// notifications to `out` (caller-owned buffer — the zero-alloc form).
    /// Window-quantized: events sharing the deadline's window are
    /// processed with it (up to one window past the deadline), and the
    /// clock parks at `max(deadline, last window end)` on every shard.
    pub fn run_until_into(&mut self, deadline: Ns, out: &mut Vec<Notification>) {
        loop {
            match self.next_event_time() {
                Some(t) if t <= deadline => {
                    self.step_into(out);
                }
                _ => break,
            }
        }
        self.clock = self.clock.max(deadline);
        let c = self.clock;
        for sh in &mut self.shards {
            sh.sync_clock(c);
        }
    }

    /// Drain every pending event (quiescence).
    pub fn run_to_quiescence(&mut self) -> Vec<Notification> {
        let mut out = Vec::new();
        while self.step_into(&mut out) {}
        out
    }

    /// Events still on the timeline (shard wheels + staged frames).
    pub fn pending_events(&self) -> usize {
        self.shards.iter().map(|s| s.wheel_len()).sum::<usize>() + self.pending_wire.len()
    }

    /// Total data payload delivered across all NICs (see
    /// [`NodeState::rx_data_bytes`]).
    pub fn total_rx_data_bytes(&self) -> u64 {
        self.nodes().map(|n| n.rx_data_bytes).sum()
    }

    /// Frames discarded by the fault layer (injected wire loss), summed
    /// over shards.
    pub fn wire_drops(&self) -> u64 {
        self.shards.iter().map(|s| s.wire_drops).sum()
    }

    /// Clos congestion counters (ECN marks, tail-drops, pauses); all-zero
    /// on the single-switch fabric.
    pub fn clos_stats(&self) -> ClosStats {
        self.clos.as_ref().map(|c| c.stats).unwrap_or_default()
    }

    /// The Clos switch tiers, when a topology is installed.
    pub fn clos(&self) -> Option<&Clos> {
        self.clos.as_ref()
    }

    /// Blackhole-detector firings summed over every node (see
    /// [`NodeState::repaths`]). Zero without a repathing Clos.
    pub fn repaths(&self) -> u64 {
        self.nodes().map(|n| n.repaths).sum()
    }

    /// The Clos routing-mask epoch: bumped by each reconvergence that
    /// actually changed the mask. 0 on the single-switch fabric.
    pub fn route_epoch(&self) -> u32 {
        self.clos.as_ref().map(|c| c.route_epoch()).unwrap_or(0)
    }

    /// Enable/disable the `(time, node, kind)` event pop trace on every
    /// shard (the determinism property test's witness).
    pub fn set_trace(&mut self, on: bool) {
        for sh in &mut self.shards {
            sh.set_trace(on);
        }
    }

    /// Drain the merged pop trace, stably sorted by `(time, node)` — the
    /// order that is invariant across shard counts (same-instant events
    /// of *different* nodes commute; same-node order is preserved).
    pub fn take_trace(&mut self) -> Vec<(u64, u32, u8)> {
        let mut out = Vec::new();
        for sh in &mut self.shards {
            sh.drain_trace_into(&mut out);
        }
        out.sort_by_key(|&(t, n, _)| (t, n)); // stable
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::types::Verb;
    use crate::fabric::verbs;

    #[test]
    fn zero_latency_rejects_multiple_shards() {
        let cfg = FabricConfig { switch_latency_ns: 0, shards: 4, ..FabricConfig::default() };
        let err = Sim::try_new(cfg).unwrap_err();
        assert!(err.contains("switch_latency_ns > 0"), "unexpected message: {err}");
        assert!(err.contains("shards = 4"), "unexpected message: {err}");
    }

    #[test]
    fn zero_latency_serial_is_accepted() {
        let cfg = FabricConfig { switch_latency_ns: 0, shards: 1, ..FabricConfig::default() };
        let sim = Sim::try_new(cfg).expect("serial zero-latency is valid");
        assert_eq!(sim.shard_count(), 1);
    }

    #[test]
    #[should_panic(expected = "switch_latency_ns > 0")]
    fn zero_latency_panics_through_new() {
        let _ = Sim::new(FabricConfig {
            switch_latency_ns: 0,
            shards: 2,
            ..FabricConfig::default()
        });
    }

    #[test]
    fn shards_clamp_to_node_count_and_zero_means_cores() {
        let sim = Sim::new(FabricConfig { nodes: 2, shards: 16, ..FabricConfig::default() });
        assert_eq!(sim.shard_count(), 2);
        let sim = Sim::new(FabricConfig { nodes: 4, shards: 0, ..FabricConfig::default() });
        assert!(sim.shard_count() >= 1 && sim.shard_count() <= 4);
    }

    /// Drive an all-to-all RC SEND/RECV ring and return every observable:
    /// counters, final clock, and the merged pop trace.
    fn drive(shards: usize) -> (u64, u64, u64, u64, Vec<(u64, u32, u8)>) {
        let mut sim = Sim::new(FabricConfig { shards, ..FabricConfig::default() });
        sim.set_trace(true);
        let nodes = sim.cfg.nodes as u32;
        let mut cqs = Vec::new();
        let mut mrs = Vec::new();
        for i in 0..nodes {
            cqs.push(sim.create_cq(NodeId(i), 1024));
            mrs.push(verbs::reg_buffer(&mut sim, NodeId(i), 1 << 20));
        }
        // ring of RC pairs: i -> (i+1) % nodes, 8 sends each
        for i in 0..nodes {
            let j = (i + 1) % nodes;
            let pair = verbs::create_connected_pair(
                &mut sim,
                QpTransport::Rc,
                NodeId(i),
                NodeId(j),
                cqs[i as usize],
                cqs[i as usize],
                cqs[j as usize],
                cqs[j as usize],
            );
            let mut next = 0;
            verbs::replenish_rq(&mut sim, NodeId(j), pair.b.1, &mrs[j as usize], 8192, 16, &mut next);
            for k in 0..8u64 {
                let wr = SendWr {
                    wr_id: u64::from(i) * 100 + k,
                    verb: Verb::Send,
                    len: 6000, // two frames
                    lkey: mrs[i as usize].key,
                    laddr: mrs[i as usize].addr,
                    rkey: None,
                    raddr: 0,
                    imm_data: Some(k as u32),
                    ud_dest: None,
                    signaled: true,
                };
                sim.post_send(NodeId(i), pair.a.1, wr).expect("post");
            }
        }
        sim.run_to_quiescence();
        (
            sim.completed_msgs,
            sim.completed_bytes,
            sim.steps_processed(),
            sim.now().0,
            sim.take_trace(),
        )
    }

    #[test]
    fn sharded_ring_matches_serial() {
        let serial = drive(1);
        assert_eq!(serial.0, 32, "8 msgs on each of 4 ring edges");
        for shards in [2, 4] {
            let sharded = drive(shards);
            assert_eq!(serial.0, sharded.0, "completed_msgs, shards={shards}");
            assert_eq!(serial.1, sharded.1, "completed_bytes, shards={shards}");
            assert_eq!(serial.2, sharded.2, "steps, shards={shards}");
            assert_eq!(serial.3, sharded.3, "final clock, shards={shards}");
            assert_eq!(serial.4, sharded.4, "pop trace, shards={shards}");
        }
    }

    #[test]
    fn run_until_parks_every_clock_at_the_deadline() {
        let mut sim = Sim::new(FabricConfig { shards: 2, ..FabricConfig::default() });
        let notes = sim.run_until(Ns(50_000));
        assert!(notes.is_empty());
        assert_eq!(sim.now(), Ns(50_000));
        sim.schedule(Ns(60_000), 7);
        let notes = sim.run_until(Ns(70_000));
        assert_eq!(notes, vec![Notification::Timer { token: 7 }]);
        assert!(sim.now() >= Ns(70_000));
    }
}
