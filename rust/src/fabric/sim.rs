//! The discrete-event simulator: nodes, NIC engines, the event loop.
//!
//! Drivers (workload generators, the RaaS daemon, baselines) interact with
//! the sim through the verbs-style API (`create_qp`, `post_send`,
//! `poll_cq`, …) and advance virtual time by calling [`Sim::step`] (or the
//! zero-alloc [`Sim::step_into`]), which processes one event and reports
//! completion notifications. Everything is deterministic: same calls +
//! same seeds ⇒ identical timelines.
//!
//! ### Engine model
//!
//! Each NIC has one processing engine that serially executes
//! [`WorkItem`]s with costs from [`NicConfig`]. Multi-frame messages are
//! emitted **one frame per work item**, re-enqueuing the remainder at the
//! tail — so concurrent messages interleave frame-by-frame exactly like a
//! real RNIC's processing units, which is what makes the receiver's ICM
//! cache thrash under high QP counts (Fig 5's mechanism).
//!
//! ### Hot-path layout
//!
//! The event queue is a hierarchical timing wheel ([`super::event`]);
//! QPs/CQs/SRQs live in dense id-indexed vectors ([`DenseTable`]) so the
//! per-frame context lookups are an index, not a hash; frames are `Copy`;
//! and a requester-side multi-frame message occupies **one** pooled
//! in-queue event that replays each frame at its precomputed delivery
//! time under a reserved seq block — byte-identical pop order to the
//! push-per-frame it replaces, at a fraction of the queue traffic.

use std::collections::{HashMap, VecDeque};

use super::cache::{IcmCache, IcmKey};
use super::cq::Cq;
use super::cpu::CpuLedger;
use super::event::EventQueue;
use super::fault::{FaultAction, FaultConfig, FaultState, FaultStats};
use super::mr::{Access, MemoryRegion, MrTable};
use super::nic::{Frame, FrameKind, NicConfig, WorkItem, CTRL_FRAME_BYTES};
use super::qp::{PostError, Qp};
use super::srq::Srq;
use super::switchfab::Fabric;
use super::time::Ns;
use super::types::{Cqn, DenseTable, NodeId, QpTransport, Qpn, Srqn, Verb, WcStatus};
use super::wqe::{Cqe, CqeKind, RecvWr, SendWr};

/// Whole-fabric configuration.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    /// Machines in the cluster.
    pub nodes: usize,
    /// CPU cores per machine.
    pub cores_per_node: u32,
    /// Per-port line rate.
    pub link_gbps: f64,
    /// Maximum frame payload.
    pub mtu: u64,
    /// One-way propagation + switch latency.
    pub switch_latency_ns: u64,
    /// RNIC engine cost/capacity model.
    pub nic: NicConfig,
    /// Default queue depths.
    pub sq_depth: usize,
    /// Default receive-queue depth.
    pub rq_depth: usize,
    /// RC requester window (outstanding messages per QP).
    pub max_outstanding: usize,
    /// CPU cost of a post_send/post_recv call (driver side).
    pub post_cpu_ns: u64,
    /// CPU cost of a poll_cq call + per-CQE handling.
    pub poll_cpu_ns: u64,
    /// CPU cost per CQE handled after a poll.
    pub per_cqe_cpu_ns: u64,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            nodes: 4,
            cores_per_node: 24,
            link_gbps: 40.0,
            mtu: 4096,
            switch_latency_ns: 1000,
            nic: NicConfig::default(),
            sq_depth: 256,
            rq_depth: 256,
            max_outstanding: 16,
            post_cpu_ns: 150,
            poll_cpu_ns: 80,
            per_cqe_cpu_ns: 50,
        }
    }
}

/// Events on the simulator's timeline.
enum Event {
    EngineCheck(NodeId),
    FrameDelivered(Frame),
    /// One frame of a coalesced multi-frame message stream (see
    /// [`FrameStreamState`]): replays `FrameDelivered` semantics at each
    /// precomputed delivery time while keeping a single event in-queue.
    FrameStream { stream: u32 },
    CqeDeliver { node: NodeId, cqn: Cqn, cqe: Cqe },
    RetrySend { node: NodeId, qpn: Qpn, wr: SendWr },
    /// Driver-scheduled timer (lock-grant wakeups, open-loop arrivals…).
    AppTimer { token: u64 },
    /// A frame held back by injected delay jitter lands here; it already
    /// passed the fault gate and must not be re-drawn.
    FrameRedelivered(Frame),
    /// RC requester ACK timeout for `(msg_id, attempt)` — armed only
    /// under an installed fault plan. Stale timers (message acked, or a
    /// newer attempt in flight) no-op.
    AckTimeout { node: NodeId, qpn: Qpn, msg_id: u64, attempt: u32 },
    /// Fault-plan node soft-restart.
    NodeRestart { node: NodeId },
}

/// Requester-side multi-frame message in flight: the template frame plus
/// the delivery schedule computed eagerly at issue time (port state is
/// mutated then, so the times are fixed). Pooled in [`Sim::streams`] and
/// reused — steady-state zero allocation. The seq block reserved at issue
/// makes the replayed pops byte-identical to eager per-frame pushes.
struct FrameStreamState {
    template: Frame,
    /// `wr.len.max(1)` — what the frames were sized from.
    payload_len: u64,
    deliveries: Vec<Ns>,
    next: u64,
    base_seq: u64,
}

/// What [`Sim::step`] reports back to the driver.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Notification {
    /// A CQE landed in (node, cqn) — the driver should poll it.
    CqeReady { node: NodeId, cqn: Cqn },
    /// A timer scheduled via [`Sim::schedule`] fired.
    Timer { token: u64 },
}

/// Per-message requester-side bookkeeping (ACK matching, RNR retry,
/// go-back-N retransmission).
struct InFlight {
    wr: SendWr,
    qpn: Qpn,
    /// Go-back-N sequence assigned at first issue; retransmissions reuse
    /// it (the responder's dedup key).
    msg_seq: u64,
    /// Transmissions so far minus one. An [`Event::AckTimeout`] only acts
    /// when its recorded attempt still matches.
    attempt: u32,
    /// Fault mode, READs only: which response-frame indices have arrived
    /// (bitmap for responses of <= 64 frames, plain count above that) —
    /// the last response frame only completes the READ when the response
    /// arrived with no holes.
    resp_seen: u64,
}

/// One machine.
pub struct NodeState {
    /// This node's id.
    pub id: NodeId,
    /// Queue pairs, dense-indexed by QPN.
    pub qps: DenseTable<Qp>,
    /// Completion queues, dense-indexed by CQN.
    pub cqs: DenseTable<Cq>,
    /// Shared receive queues, dense-indexed by SRQN.
    pub srqs: DenseTable<Srq>,
    /// Registered memory regions.
    pub mrs: MrTable,
    /// The NIC's on-chip context cache (Fig 5's mechanism).
    pub cache: IcmCache,
    /// Per-node CPU accounting.
    pub cpu: CpuLedger,
    engine_busy_until: Ns,
    engine_queue: VecDeque<WorkItem>,
    engine_scheduled: bool,
    next_msg_id: u64,
    /// Requester-side in-flight messages keyed by msg_id.
    inflight: HashMap<u64, InFlight>,
    /// Responder-side recv WQE held from first to last frame of a message,
    /// keyed by (src node, src qpn, msg id).
    pending_recv: HashMap<(u32, u32, u64), RecvWr>,
    /// Fault mode only: data frames of a multi-frame RC message seen so
    /// far, keyed like `pending_recv`. The last frame only completes the
    /// message when every frame of one attempt arrived — a lost MIDDLE
    /// frame must not ACK a message with a hole in it.
    rc_frames_seen: HashMap<(u32, u32, u64), u64>,
    /// Messages dropped mid-flight (RNR/protection) — suppress completion.
    dropped_msgs: std::collections::HashSet<(u32, u32, u64)>,
    /// Counters.
    pub protection_errors: u64,
    /// RNR NAKs this node's NIC generated.
    pub rnr_naks_sent: u64,
    /// RC message retransmissions this node's NIC performed (requester
    /// side; go-back-N under an installed fault plan).
    pub retransmits: u64,
    /// RC messages that exhausted their retry budget and completed with
    /// [`WcStatus::RetryExceeded`].
    pub retry_exceeded: u64,
    /// RC data frames discarded by the responder's go-back-N discipline
    /// (sequence ahead of the expected one — an earlier message is lost).
    pub gbn_discards: u64,
    /// RC last-frames that arrived with earlier frames of their attempt
    /// missing: the message was NOT delivered or ACKed (the requester
    /// retransmits the whole message instead).
    pub rc_incomplete_msgs: u64,
    /// Duplicate RC messages re-ACKed without re-delivery (the original
    /// ACK was lost; exactly-once delivery held).
    pub gbn_dup_acks: u64,
    /// Fault-plan soft-restarts executed on this node.
    pub restarts: u64,
    /// Payload bytes of data-bearing frames processed by this NIC's rx
    /// path — the smooth wire-level goodput counter the scenario drivers
    /// measure (message-completion counters clump and bias short windows).
    pub rx_data_bytes: u64,
    /// Frames that arrived addressed to a destroyed QP and died at the
    /// NIC (tenant-isolation counter for the QP reuse pool).
    pub frames_to_destroyed: u64,
}

impl NodeState {
    fn new(id: NodeId, cfg: &FabricConfig) -> Self {
        NodeState {
            id,
            qps: DenseTable::new(),
            cqs: DenseTable::new(),
            srqs: DenseTable::new(),
            mrs: MrTable::new(),
            cache: IcmCache::new(cfg.nic.icm_cache_entries),
            cpu: CpuLedger::new(cfg.cores_per_node),
            engine_busy_until: Ns::ZERO,
            engine_queue: VecDeque::new(),
            engine_scheduled: false,
            next_msg_id: 1,
            inflight: HashMap::new(),
            pending_recv: HashMap::new(),
            rc_frames_seen: HashMap::new(),
            dropped_msgs: std::collections::HashSet::new(),
            protection_errors: 0,
            rnr_naks_sent: 0,
            retransmits: 0,
            retry_exceeded: 0,
            gbn_discards: 0,
            rc_incomplete_msgs: 0,
            gbn_dup_acks: 0,
            restarts: 0,
            rx_data_bytes: 0,
            frames_to_destroyed: 0,
        }
    }

    /// Engine work-queue depth (diagnostics).
    pub fn engine_queue_len(&self) -> usize {
        self.engine_queue.len()
    }

    /// Total fabric-level memory charged to this node (ledger for Fig 7):
    /// QP rings + contexts, CQ rings, SRQ rings, registered regions' MTT.
    pub fn fabric_mem_bytes(&self) -> u64 {
        let qp: u64 = self.qps.iter().map(|q| q.mem_bytes()).sum();
        let cq: u64 = self.cqs.iter().map(|c| c.mem_bytes()).sum();
        let srq: u64 = self.srqs.iter().map(|s| s.mem_bytes()).sum();
        let mtt = self.mrs.total_mtt_entries * 8; // 8 B per MTT entry
        qp + cq + srq + mtt
    }
}

/// The simulator.
pub struct Sim {
    /// The configuration the fabric was built from.
    pub cfg: FabricConfig,
    clock: Ns,
    events: EventQueue<Event>,
    /// Per-machine state, indexed by [`NodeId`].
    pub nodes: Vec<NodeState>,
    /// The switch + ports.
    pub fabric: Fabric,
    /// Completed payload bytes (data verbs), for quick aggregate throughput.
    pub completed_bytes: u64,
    /// Completed data messages (companion counter).
    pub completed_msgs: u64,
    steps: u64,
    /// Pooled multi-frame message streams (slab + free list).
    streams: Vec<FrameStreamState>,
    free_streams: Vec<u32>,
    /// Installed fault plan, if any. `None` (the default, and the result
    /// of installing a null plan) keeps every fault hook dormant: no RNG,
    /// no retransmission timers, no go-back-N gating — the lossless
    /// simulator, byte for byte.
    faults: Option<FaultState>,
}

impl Sim {
    /// Build a quiescent cluster at virtual time zero.
    pub fn new(cfg: FabricConfig) -> Self {
        let fabric = Fabric::new(cfg.nodes, cfg.link_gbps, cfg.mtu, Ns(cfg.switch_latency_ns));
        let nodes = (0..cfg.nodes)
            .map(|i| NodeState::new(NodeId(i as u32), &cfg))
            .collect();
        Sim {
            cfg,
            clock: Ns::ZERO,
            events: EventQueue::new(),
            nodes,
            fabric,
            completed_bytes: 0,
            completed_msgs: 0,
            steps: 0,
            streams: Vec::new(),
            free_streams: Vec::new(),
            faults: None,
        }
    }

    /// Install a seeded fault plan ([`super::fault`]). A null plan (zero
    /// rates, no flaps, no restarts) installs nothing, which is the
    /// loss-0 byte-identity guarantee. Must be called before any traffic
    /// is driven: the RC go-back-N discipline assumes sequence counters
    /// and the fault gate switch on together.
    pub fn install_faults(&mut self, cfg: FaultConfig) {
        if cfg.is_null() {
            return;
        }
        assert!(
            self.steps == 0 && self.events.is_empty(),
            "install_faults must run before the first event"
        );
        for &(node, at) in &cfg.restarts {
            debug_assert!((node as usize) < self.nodes.len(), "restart of unknown node");
            self.events
                .push(Ns(at).max(self.clock), Event::NodeRestart { node: NodeId(node) });
        }
        self.faults = Some(FaultState::new(cfg));
    }

    /// Is a (non-null) fault plan installed?
    pub fn faults_active(&self) -> bool {
        self.faults.is_some()
    }

    /// Snapshot of the fault layer's counters (None without a plan).
    pub fn fault_stats(&self) -> Option<FaultStats> {
        self.faults.as_ref().map(|f| f.stats)
    }

    /// Current virtual time.
    pub fn now(&self) -> Ns {
        self.clock
    }

    /// Events processed since construction (the DES throughput metric the
    /// `bench simstep` / `bench fig9` targets report).
    pub fn steps_processed(&self) -> u64 {
        self.steps
    }

    /// A node's state.
    pub fn node(&self, id: NodeId) -> &NodeState {
        &self.nodes[id.0 as usize]
    }

    /// A node's state, mutably.
    pub fn node_mut(&mut self, id: NodeId) -> &mut NodeState {
        &mut self.nodes[id.0 as usize]
    }

    // ------------------------------------------------------------ verbs API

    /// Create a completion queue on `node`.
    pub fn create_cq(&mut self, node: NodeId, capacity: usize) -> Cqn {
        let n = self.node_mut(node);
        let cqn = Cqn(n.cqs.next_id());
        n.cqs.insert(Cq::new(cqn, capacity));
        cqn
    }

    /// Create a shared receive queue on `node`.
    pub fn create_srq(&mut self, node: NodeId, capacity: usize, watermark: usize) -> Srqn {
        let n = self.node_mut(node);
        let srqn = Srqn(n.srqs.next_id());
        n.srqs.insert(Srq::new(srqn, capacity, watermark));
        srqn
    }

    /// Create a QP on `node` (Reset state; connect/activate it next).
    pub fn create_qp(
        &mut self,
        node: NodeId,
        transport: QpTransport,
        send_cq: Cqn,
        recv_cq: Cqn,
    ) -> Qpn {
        let (sq, rq, win) = (self.cfg.sq_depth, self.cfg.rq_depth, self.cfg.max_outstanding);
        let n = self.node_mut(node);
        let qpn = Qpn(n.qps.next_id());
        n.qps.insert(Qp::new(qpn, transport, send_cq, recv_cq, sq, rq, win));
        qpn
    }

    /// Point a QP's receive side at an SRQ.
    pub fn attach_srq(&mut self, node: NodeId, qpn: Qpn, srqn: Srqn) {
        let n = self.node_mut(node);
        n.qps.get_mut(qpn.0).expect("no such qp").srq = Some(srqn);
    }

    /// Resize a QP's send-queue capacity after creation (e.g. the RaaS
    /// daemon's host-wide UD QP, which multiplexes every migrated
    /// destination and needs a far deeper SQ than the per-peer default).
    pub fn set_sq_depth(&mut self, node: NodeId, qpn: Qpn, depth: usize) {
        let n = self.node_mut(node);
        n.qps.get_mut(qpn.0).expect("no such qp").sq_depth = depth;
    }

    /// Destroy a QP: rings and on-NIC context are freed (its
    /// [`NodeState::fabric_mem_bytes`] contribution drops to zero) and any
    /// frame still in flight toward it dies at the destination NIC. The
    /// dense id table keeps the slot so QPNs stay stable; callers are
    /// expected to destroy only quiesced QPs (no outstanding messages) —
    /// the RaaS control plane drains before it parks or evicts.
    pub fn destroy_qp(&mut self, node: NodeId, qpn: Qpn) {
        let n = self.node_mut(node);
        n.qps.get_mut(qpn.0).expect("no such qp").destroy();
    }

    /// Register a memory region on `node`.
    pub fn reg_mr(&mut self, node: NodeId, len: u64, access: Access, huge: bool) -> MemoryRegion {
        self.node_mut(node).mrs.register(len, access, huge)
    }

    /// Transition both QPs to RTS, bound to each other (RC/UC connect).
    pub fn connect(&mut self, a: NodeId, a_qpn: Qpn, b: NodeId, b_qpn: Qpn) {
        {
            let qp = self.node_mut(a).qps.get_mut(a_qpn.0).expect("no qp a");
            qp.to_rtr();
            qp.to_rts(Some((b, b_qpn)));
        }
        {
            let qp = self.node_mut(b).qps.get_mut(b_qpn.0).expect("no qp b");
            qp.to_rtr();
            qp.to_rts(Some((a, a_qpn)));
        }
    }

    /// Bring a UD QP up (no peer binding).
    pub fn activate_ud(&mut self, node: NodeId, qpn: Qpn) {
        let qp = self.node_mut(node).qps.get_mut(qpn.0).expect("no qp");
        debug_assert_eq!(qp.transport, QpTransport::Ud);
        qp.to_rtr();
        qp.to_rts(None);
    }

    /// Post a send WR and ring the doorbell. Charges driver CPU.
    pub fn post_send(&mut self, node: NodeId, qpn: Qpn, wr: SendWr) -> Result<(), PostError> {
        let mtu = self.cfg.mtu;
        let post_cpu = self.cfg.post_cpu_ns;
        let n = self.node_mut(node);
        n.cpu.charge_post(post_cpu);
        let qp = n.qps.get_mut(qpn.0).ok_or(PostError::BadState(super::qp::QpState::Error))?;
        qp.post_send(wr, mtu)?;
        self.ring_doorbell(node, qpn);
        Ok(())
    }

    /// Post a chain of WRs with ONE doorbell (WR batching — §2.3's
    /// "sharing QP promotes the probability of batching WRs").
    pub fn post_send_batch(
        &mut self,
        node: NodeId,
        qpn: Qpn,
        wrs: Vec<SendWr>,
    ) -> Result<usize, PostError> {
        let mtu = self.cfg.mtu;
        let post_cpu = self.cfg.post_cpu_ns;
        let n = self.node_mut(node);
        // one syscall-ish driver cost + small per-WR marshalling cost
        n.cpu.charge_post(post_cpu + 30 * wrs.len() as u64);
        let qp = n.qps.get_mut(qpn.0).ok_or(PostError::BadState(super::qp::QpState::Error))?;
        let mut accepted = 0;
        for wr in wrs {
            match qp.post_send(wr, mtu) {
                Ok(()) => accepted += 1,
                Err(e) => {
                    if accepted == 0 {
                        return Err(e);
                    }
                    break;
                }
            }
        }
        self.ring_doorbell(node, qpn);
        Ok(accepted)
    }

    /// Post a receive WR on a QP's private RQ. Charges driver CPU.
    pub fn post_recv(&mut self, node: NodeId, qpn: Qpn, wr: RecvWr) -> Result<(), PostError> {
        let post_cpu = self.cfg.post_cpu_ns;
        let n = self.node_mut(node);
        n.cpu.charge_post(post_cpu);
        n.qps
            .get_mut(qpn.0)
            .ok_or(PostError::BadState(super::qp::QpState::Error))?
            .post_recv(wr)
    }

    /// Post a receive WR on an SRQ; false when full. Charges driver CPU.
    pub fn post_srq_recv(&mut self, node: NodeId, srqn: Srqn, wr: RecvWr) -> bool {
        let post_cpu = self.cfg.post_cpu_ns;
        let n = self.node_mut(node);
        n.cpu.charge_post(post_cpu);
        n.srqs.get_mut(srqn.0).map(|s| s.post(wr)).unwrap_or(false)
    }

    /// Free send-queue slots on a QP (drivers use this to size batches).
    pub fn sq_free(&self, node: NodeId, qpn: Qpn) -> usize {
        self.node(node)
            .qps
            .get(qpn.0)
            .map(|qp| qp.sq_depth.saturating_sub(qp.sq.len()))
            .unwrap_or(0)
    }

    /// Poll up to `max` CQEs; charges poller CPU.
    pub fn poll_cq(&mut self, node: NodeId, cqn: Cqn, max: usize) -> Vec<Cqe> {
        let mut out = Vec::new();
        self.poll_cq_into(node, cqn, max, &mut out);
        out
    }

    /// Poll up to `max` CQEs into a caller-provided buffer (appended; the
    /// caller clears between polls). Returns how many were appended.
    /// Charges poller CPU — the zero-alloc form the hot pollers use.
    pub fn poll_cq_into(
        &mut self,
        node: NodeId,
        cqn: Cqn,
        max: usize,
        out: &mut Vec<Cqe>,
    ) -> usize {
        let (poll_cpu, per_cqe) = (self.cfg.poll_cpu_ns, self.cfg.per_cqe_cpu_ns);
        let n = self.node_mut(node);
        let got = match n.cqs.get_mut(cqn.0) {
            Some(cq) => cq.poll_into(max, out),
            None => 0,
        };
        n.cpu.charge_poll(poll_cpu + per_cqe * got as u64);
        got
    }

    // -------------------------------------------------------------- engine

    fn ring_doorbell(&mut self, node: NodeId, qpn: Qpn) {
        let nic_doorbell = self.cfg.nic.doorbell_ns;
        let clock = self.clock;
        let n = self.node_mut(node);
        let Some(qp) = n.qps.get_mut(qpn.0) else { return };
        if !qp.issue_armed {
            qp.issue_armed = true;
            n.engine_queue.push_back(WorkItem::IssueFromQp(qpn));
            // doorbell MMIO handling occupies the engine briefly
            n.engine_busy_until = n.engine_busy_until.max(clock) + Ns(nic_doorbell);
            self.kick_engine(node);
        }
    }

    fn kick_engine(&mut self, node: NodeId) {
        let clock = self.clock;
        let n = self.node_mut(node);
        if !n.engine_scheduled && !n.engine_queue.is_empty() {
            n.engine_scheduled = true;
            let at = n.engine_busy_until.max(clock);
            self.events.push(at, Event::EngineCheck(node));
        }
    }

    /// Re-arm a QP's issue item after a completion freed window space.
    fn rearm_issue(&mut self, node: NodeId, qpn: Qpn) {
        let n = self.node_mut(node);
        let Some(qp) = n.qps.get_mut(qpn.0) else { return };
        if qp.can_issue() && !qp.issue_armed {
            qp.issue_armed = true;
            n.engine_queue.push_back(WorkItem::IssueFromQp(qpn));
            self.kick_engine(node);
        }
    }

    // ---------------------------------------------------------- event loop

    /// Process one event; returns notifications, or None when the timeline
    /// is exhausted. Allocating convenience form of [`Sim::step_into`].
    pub fn step(&mut self) -> Option<Vec<Notification>> {
        let mut notes = Vec::new();
        if self.step_into(&mut notes) {
            Some(notes)
        } else {
            None
        }
    }

    /// Process one event, **appending** notifications to `notes` (the
    /// caller clears between steps and reuses the buffer — zero-alloc in
    /// steady state). Returns false when the timeline is exhausted.
    pub fn step_into(&mut self, notes: &mut Vec<Notification>) -> bool {
        let Some((at, ev)) = self.events.pop() else { return false };
        debug_assert!(at >= self.clock, "time went backwards");
        self.clock = at;
        self.steps += 1;
        match ev {
            Event::EngineCheck(node) => self.on_engine_check(node),
            Event::FrameDelivered(frame) => self.deliver_frame(frame, true),
            Event::FrameRedelivered(frame) => self.deliver_frame(frame, false),
            Event::FrameStream { stream } => {
                let frame = self.next_stream_frame(stream);
                self.deliver_frame(frame, true);
            }
            Event::CqeDeliver { node, cqn, cqe } => {
                if let Some(cq) = self.node_mut(node).cqs.get_mut(cqn.0) {
                    cq.push(cqe);
                    notes.push(Notification::CqeReady { node, cqn });
                }
            }
            Event::RetrySend { node, qpn, wr } => {
                // RNR retry: put the message back at the head of the SQ.
                if let Some(qp) = self.node_mut(node).qps.get_mut(qpn.0) {
                    qp.sq.push_front(wr);
                }
                self.rearm_issue(node, qpn);
            }
            Event::AppTimer { token } => notes.push(Notification::Timer { token }),
            Event::AckTimeout { node, qpn, msg_id, attempt } => {
                self.on_ack_timeout(node, qpn, msg_id, attempt)
            }
            Event::NodeRestart { node } => self.on_node_restart(node),
        }
        true
    }

    /// Schedule a driver timer at absolute time `at` (clamped to now).
    pub fn schedule(&mut self, at: Ns, token: u64) {
        self.events.push(at.max(self.clock), Event::AppTimer { token });
    }

    /// Run until the event queue drains or `deadline` passes; collect all
    /// notifications.
    pub fn run_until(&mut self, deadline: Ns) -> Vec<Notification> {
        let mut out = Vec::new();
        while let Some(t) = self.events.peek_time() {
            if t > deadline {
                break;
            }
            self.step_into(&mut out);
        }
        self.clock = self.clock.max(deadline);
        out
    }

    /// Drain every pending event (quiescence).
    pub fn run_to_quiescence(&mut self) -> Vec<Notification> {
        let mut out = Vec::new();
        while self.step_into(&mut out) {}
        out
    }

    /// Events still on the timeline.
    pub fn pending_events(&self) -> usize {
        self.events.len()
    }

    /// Total data payload delivered across all NICs (see
    /// [`NodeState::rx_data_bytes`]).
    pub fn total_rx_data_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.rx_data_bytes).sum()
    }

    fn on_engine_check(&mut self, node: NodeId) {
        {
            let clock = self.clock;
            let n = self.node_mut(node);
            n.engine_scheduled = false;
            if clock < n.engine_busy_until {
                // engine still busy (doorbell bumped the horizon): re-check.
                self.kick_engine(node);
                return;
            }
        }
        let item = match self.node_mut(node).engine_queue.pop_front() {
            Some(i) => i,
            None => return,
        };
        let cost = self.process_item(node, item);
        let clock = self.clock;
        let n = self.node_mut(node);
        n.engine_busy_until = clock + Ns(cost);
        self.kick_engine(node);
    }

    /// Execute one engine work item; returns engine occupancy in ns.
    fn process_item(&mut self, node: NodeId, item: WorkItem) -> u64 {
        match item {
            WorkItem::IssueFromQp(qpn) => self.issue_from_qp(node, qpn),
            WorkItem::RxFrame(frame) => self.rx_frame(node, frame),
            WorkItem::ReadRespond {
                requester,
                requester_qpn,
                responder_qpn,
                msg_id,
                len,
                wr_id,
                idx,
            } => self
                .read_respond(node, requester, requester_qpn, responder_qpn, msg_id, len, wr_id, idx),
            WorkItem::Retransmit { qpn, msg_id } => self.retransmit_msg(node, qpn, msg_id),
        }
    }

    /// Engine backpressure: extra stall (ns) before the engine can hand the
    /// next frame to the egress port, given the tx FIFO depth.
    fn tx_stall(&self, node: NodeId, at: Ns) -> u64 {
        let fifo = Ns(self.cfg.nic.tx_fifo_frames
            * super::time::wire_time(self.cfg.mtu + super::switchfab::FRAME_OVERHEAD_BYTES, self.cfg.link_gbps).0);
        let backlog = self.fabric.egress_busy_until(node).saturating_sub(at);
        backlog.saturating_sub(fifo).0
    }

    /// ICM cache touch: returns the stall cost (0 on hit).
    fn icm_touch(&mut self, node: NodeId, key: IcmKey) -> u64 {
        let miss_ns = self.cfg.nic.icm_miss_ns;
        if self.node_mut(node).cache.touch(key) {
            0
        } else {
            miss_ns
        }
    }

    // ----------------------------------------------------- frame streams

    /// Pool a new stream slot (reusing a freed one when available).
    fn alloc_stream(&mut self, template: Frame, payload_len: u64, base_seq: u64) -> u32 {
        match self.free_streams.pop() {
            Some(h) => {
                let st = &mut self.streams[h as usize];
                debug_assert!(st.deliveries.is_empty());
                st.template = template;
                st.payload_len = payload_len;
                st.next = 0;
                st.base_seq = base_seq;
                h
            }
            None => {
                self.streams.push(FrameStreamState {
                    template,
                    payload_len,
                    deliveries: Vec::new(),
                    next: 0,
                    base_seq,
                });
                (self.streams.len() - 1) as u32
            }
        }
    }

    /// Materialize the stream's next frame; re-arm the stream's single
    /// in-queue event at the following delivery time (with its reserved
    /// seq) or retire the slot to the free list.
    fn next_stream_frame(&mut self, handle: u32) -> Frame {
        let (frame, next, base_seq, next_at) = {
            let st = &mut self.streams[handle as usize];
            let n = st.deliveries.len() as u64;
            let i = st.next;
            debug_assert!(i < n);
            let mut frame = st.template;
            frame.is_first = i == 0;
            frame.is_last = i + 1 == n;
            frame.frame_idx = i;
            // same sizing the delivery schedule was computed from
            frame.bytes = self.fabric.frame_bytes(st.payload_len, i, n);
            st.next += 1;
            let next_at =
                if st.next < n { Some(st.deliveries[st.next as usize]) } else { None };
            (frame, st.next, st.base_seq, next_at)
        };
        match next_at {
            Some(at) => {
                self.events
                    .push_at_seq(at, base_seq + next, Event::FrameStream { stream: handle });
            }
            None => {
                self.streams[handle as usize].deliveries.clear();
                self.free_streams.push(handle);
            }
        }
        frame
    }

    // -------------------------------------------------- requester-side tx

    /// Issue ONE message from this QP's send queue, then re-enqueue the
    /// issue item (frame-level fairness is provided by message streaming —
    /// large messages stream via `TxContinue`-style re-enqueue below).
    fn issue_from_qp(&mut self, node: NodeId, qpn: Qpn) -> u64 {
        let nic = self.cfg.nic;

        // Pull the next WR if the window allows.
        let (wr, peer, transport, msg_seq) = {
            let n = self.node_mut(node);
            let qp = match n.qps.get_mut(qpn.0) {
                Some(qp) => qp,
                None => return 0,
            };
            qp.issue_armed = false;
            if !qp.can_issue() {
                return 0; // window-blocked; re-armed on completion
            }
            let wr = qp.sq.pop_front().unwrap();
            let peer = match qp.transport {
                QpTransport::Ud => wr.ud_dest,
                _ => qp.peer,
            };
            let msg_seq = if qp.transport == QpTransport::Rc {
                qp.outstanding += 1;
                let s = qp.next_msg_seq;
                qp.next_msg_seq += 1;
                s
            } else {
                0
            };
            (wr, peer, qp.transport, msg_seq)
        };
        let (peer_node, peer_qpn) = match peer {
            Some(p) => p,
            None => return nic.engine_wqe_ns, // unroutable; swallow
        };

        let mut cost = nic.engine_wqe_ns + nic.dma_setup_ns;
        cost += self.icm_touch(node, IcmKey::Qpc(qpn.0));
        // local buffer translation (MTT) once per message
        if let Some(block) = self.node(node).mrs.mtt_block(wr.lkey, wr.laddr) {
            cost += self.icm_touch(node, IcmKey::Mtt(wr.lkey.0, block));
        }

        let msg_id = {
            let n = self.node_mut(node);
            let id = n.next_msg_id;
            n.next_msg_id += 1;
            id
        };

        match wr.verb {
            Verb::Read => {
                // header-only request; the responder streams the data back.
                let frame = Frame {
                    kind: FrameKind::ReadReq,
                    src: node,
                    dst: peer_node,
                    dst_qpn: peer_qpn,
                    src_qpn: qpn,
                    transport,
                    msg_id,
                    msg_seq,
                    frame_idx: 0,
                    bytes: CTRL_FRAME_BYTES,
                    msg_len: wr.len,
                    is_first: true,
                    is_last: true,
                    wr_id: wr.wr_id,
                    imm: None,
                    rkey: wr.rkey,
                    raddr: wr.raddr,
                };
                cost += nic.engine_frame_ns;
                let deliver = self.fabric.send_frame(self.clock + Ns(cost), node, peer_node, frame.bytes);
                self.events.push(deliver, Event::FrameDelivered(frame));
                let eta = deliver + self.read_response_eta(wr.len);
                self.node_mut(node)
                    .inflight
                    .insert(msg_id, InFlight { wr, qpn, msg_seq, attempt: 0, resp_seen: 0 });
                self.arm_rc_timer(node, qpn, msg_id, 0, eta);
            }
            Verb::Write | Verb::Send => {
                let kind = if wr.verb == Verb::Write {
                    FrameKind::WriteData
                } else {
                    FrameKind::SendData
                };
                let payload_len = wr.len.max(1);
                let total = self.fabric.frame_count(payload_len);
                let template = Frame {
                    kind,
                    src: node,
                    dst: peer_node,
                    dst_qpn: peer_qpn,
                    src_qpn: qpn,
                    transport,
                    msg_id,
                    msg_seq,
                    frame_idx: 0, // set per frame (stream replay / single)
                    bytes: 0, // set per frame
                    msg_len: wr.len,
                    is_first: false,
                    is_last: false,
                    wr_id: wr.wr_id,
                    imm: wr.imm_data,
                    rkey: wr.rkey,
                    raddr: wr.raddr,
                };
                let mut handoff = self.clock + Ns(cost);
                let last_deliver;
                if total == 1 {
                    cost += nic.engine_frame_ns;
                    handoff += Ns(nic.engine_frame_ns);
                    let stall = self.tx_stall(node, handoff);
                    cost += stall;
                    handoff += Ns(stall);
                    let mut frame = template;
                    frame.bytes = payload_len;
                    frame.is_first = true;
                    frame.is_last = true;
                    let deliver = self.fabric.send_frame(handoff, node, peer_node, frame.bytes);
                    self.events.push(deliver, Event::FrameDelivered(frame));
                    last_deliver = deliver;
                } else {
                    // Coalesced stream: reserve the seq block the eager
                    // per-frame pushes would have used, compute every
                    // delivery time now (port state must advance at issue
                    // time), and keep ONE event in-queue that replays them.
                    let base_seq = self.events.reserve_seqs(total);
                    let handle = self.alloc_stream(template, payload_len, base_seq);
                    for i in 0..total {
                        cost += nic.engine_frame_ns;
                        handoff += Ns(nic.engine_frame_ns);
                        // tx FIFO backpressure (see read_respond)
                        let stall = self.tx_stall(node, handoff);
                        cost += stall;
                        handoff += Ns(stall);
                        let bytes = self.fabric.frame_bytes(payload_len, i, total);
                        let deliver = self.fabric.send_frame(handoff, node, peer_node, bytes);
                        self.streams[handle as usize].deliveries.push(deliver);
                    }
                    let first_at = self.streams[handle as usize].deliveries[0];
                    last_deliver = *self.streams[handle as usize].deliveries.last().unwrap();
                    self.events
                        .push_at_seq(first_at, base_seq, Event::FrameStream { stream: handle });
                }
                match transport {
                    QpTransport::Rc => {
                        // completion on ACK
                        self.node_mut(node)
                            .inflight
                            .insert(msg_id, InFlight { wr, qpn, msg_seq, attempt: 0, resp_seen: 0 });
                        self.arm_rc_timer(node, qpn, msg_id, 0, last_deliver);
                    }
                    QpTransport::Uc | QpTransport::Ud => {
                        // local completion once the message is on the wire
                        if wr.signaled {
                            let send_cq = self.node(node).qps[qpn.0].send_cq;
                            let cqe = Cqe {
                                wr_id: wr.wr_id,
                                kind: CqeKind::SendDone(wr.verb),
                                status: WcStatus::Success,
                                len: wr.len,
                                imm_data: None,
                                qpn,
                                src: None,
                            };
                            let at = self.clock + Ns(cost + nic.cqe_delay_ns);
                            let cqc = self.icm_touch(node, IcmKey::Cqc(send_cq.0));
                            cost += cqc;
                            self.events.push(at + Ns(cqc), Event::CqeDeliver { node, cqn: send_cq, cqe });
                            self.node_mut(node).qps.get_mut(qpn.0).unwrap().completed += 1;
                        }
                    }
                }
            }
        }

        // round-robin: more WQEs pending? re-arm at the tail.
        self.rearm_issue(node, qpn);
        cost
    }

    // -------------------------------------------------- responder-side

    /// Stream ONE frame of a READ response per engine pass; re-enqueue the
    /// job until done. This interleaves concurrent responses frame-by-frame
    /// (the access pattern that thrashes the requester's ICM cache).
    #[allow(clippy::too_many_arguments)]
    fn read_respond(
        &mut self,
        node: NodeId,
        requester: NodeId,
        requester_qpn: Qpn,
        responder_qpn: Qpn,
        msg_id: u64,
        remaining: u64,
        wr_id: u64,
        idx: u64,
    ) -> u64 {
        let nic = self.cfg.nic;
        let mtu = self.cfg.mtu;
        // note: `remaining` is re-encoded in `len` across re-enqueues, so
        // msg_len on response frames tracks bytes-left; completion uses the
        // requester's in-flight record for the true length.
        let total_len = remaining; // note: we re-encode remaining in `len`
        let bytes = remaining.min(mtu);
        let left = remaining - bytes;
        let mut cost = nic.engine_frame_ns;
        cost += self.icm_touch(node, IcmKey::Qpc(responder_qpn.0));
        // wire backpressure: stall until the tx FIFO has room — this paces
        // response streaming to line rate so concurrent responses interleave
        cost += self.tx_stall(node, self.clock + Ns(cost));

        let frame = Frame {
            kind: FrameKind::ReadResp,
            src: node,
            dst: requester,
            dst_qpn: requester_qpn,
            src_qpn: responder_qpn,
            transport: QpTransport::Rc,
            msg_id,
            msg_seq: 0,
            frame_idx: idx,
            bytes,
            msg_len: total_len,
            is_first: false,
            is_last: left == 0,
            wr_id,
            imm: None,
            rkey: None,
            raddr: 0,
        };
        let deliver = self.fabric.send_frame(self.clock + Ns(cost), node, requester, bytes);
        self.events.push(deliver, Event::FrameDelivered(frame));

        if left > 0 {
            self.node_mut(node).engine_queue.push_back(WorkItem::ReadRespond {
                requester,
                requester_qpn,
                responder_qpn,
                msg_id,
                len: left,
                wr_id,
                idx: idx + 1,
            });
        }
        cost
    }

    // ---------------------------------------------------------- rx path

    /// Hand a frame to its destination NIC. `check_faults` is false only
    /// for re-deliveries of jitter-delayed frames, which already passed
    /// the gate — every frame consults the fault plan exactly once, so
    /// the RNG stream stays aligned across replays.
    fn deliver_frame(&mut self, frame: Frame, check_faults: bool) {
        if check_faults {
            if let Some(f) = self.faults.as_mut() {
                match f.action(self.clock, frame.src, frame.dst) {
                    Some(FaultAction::Drop) => {
                        // transmitted, then lost in the switch/wire: both
                        // ports already serialized it, only delivery (and
                        // the goodput counter) is suppressed
                        self.fabric.note_drop(frame.dst);
                        return;
                    }
                    Some(FaultAction::Delay(extra)) => {
                        let at = self.clock + extra;
                        self.events.push(at, Event::FrameRedelivered(frame));
                        return;
                    }
                    None => {}
                }
            }
        } else if let Some(f) = self.faults.as_mut() {
            // jitter-redelivered frame: its probabilistic draws already
            // happened, but a flap window is a property of the link at
            // delivery time — a delayed frame landing inside one dies too
            if f.flap_drop(self.clock, frame.src, frame.dst) {
                self.fabric.note_drop(frame.dst);
                return;
            }
        }
        let dst = frame.dst;
        if frame.kind.carries_data() {
            // wire-level goodput counter: counted at delivery, not at engine
            // processing (the engine can burst-drain backlog and overshoot)
            self.node_mut(dst).rx_data_bytes += frame.bytes;
        }
        self.node_mut(dst).engine_queue.push_back(WorkItem::RxFrame(frame));
        self.kick_engine(dst);
    }

    fn rx_frame(&mut self, node: NodeId, frame: Frame) -> u64 {
        let nic = self.cfg.nic;
        let mut cost = nic.engine_frame_ns;
        // every frame needs the QP context — THE Fig 5 mechanism.
        cost += self.icm_touch(node, IcmKey::Qpc(frame.dst_qpn.0));

        // a frame addressed to a destroyed QP (torn down by the control
        // plane while stragglers were still in flight) dies at the NIC:
        // no delivery, no ACK, no CQE — a prior tenant's late traffic can
        // never surface once its QP is gone
        if self.node(node).qps.get(frame.dst_qpn.0).map(|q| q.destroyed).unwrap_or(false) {
            self.node_mut(node).frames_to_destroyed += 1;
            return cost;
        }

        match frame.kind {
            FrameKind::ReadReq => {
                // go-back-N: a READ request occupies a slot in its QP's
                // ordered message stream like any other RC message. Ahead
                // of the expected sequence → discard (an earlier message
                // is missing; the requester retransmits in order). Behind
                // it → a duplicate request whose response was lost:
                // re-execute (idempotent; the requester dedups by msg_id).
                if self.faults.is_some() {
                    let expected = self
                        .node(node)
                        .qps
                        .get(frame.dst_qpn.0)
                        .map(|q| q.expected_msg_seq)
                        .unwrap_or(0);
                    if frame.msg_seq > expected {
                        self.node_mut(node).gbn_discards += 1;
                        return cost;
                    }
                    self.gbn_advance(node, &frame);
                }
                // validate remote access then start streaming the response
                let ok = frame
                    .rkey
                    .map(|k| self.node(node).mrs.check_remote(k, frame.raddr, frame.msg_len, false))
                    .unwrap_or(false);
                if !ok {
                    self.node_mut(node).protection_errors += 1;
                    // NAK → requester completes in error
                    self.complete_requester_error(frame, WcStatus::RemoteAccessError);
                    return cost;
                }
                if let Some(rk) = frame.rkey {
                    if let Some(block) = self.node(node).mrs.mtt_block(rk, frame.raddr) {
                        cost += self.icm_touch(node, IcmKey::Mtt(rk.0, block));
                    }
                }
                self.node_mut(node).engine_queue.push_back(WorkItem::ReadRespond {
                    requester: frame.src,
                    requester_qpn: frame.src_qpn,
                    responder_qpn: frame.dst_qpn,
                    msg_id: frame.msg_id,
                    len: frame.msg_len,
                    wr_id: frame.wr_id,
                    idx: 0,
                });
            }
            FrameKind::ReadResp => {
                // under faults, the last frame only completes the READ
                // when every response frame actually arrived
                let complete = self.read_resp_complete(node, &frame);
                if frame.is_last && complete {
                    cost += self.complete_read(node, &frame);
                }
            }
            FrameKind::WriteData => {
                cost += self.rx_write_data(node, &frame);
            }
            FrameKind::SendData => {
                cost += self.rx_send_data(node, &frame);
            }
            FrameKind::Ack => {
                cost += self.rx_ack(node, &frame);
            }
            FrameKind::RnrNak => {
                let key = frame.msg_id;
                if self.faults.is_some() {
                    // fault mode: retransmit IN PLACE after the backoff —
                    // same msg_id and msg_seq, through the ACK-timeout
                    // machinery (counts against the retry budget). A
                    // re-post with a fresh sequence would leave a hole
                    // the responder's go-back-N discipline waits on
                    // forever.
                    let n = self.node_mut(node);
                    if let Some(inf) = n.inflight.get(&key) {
                        let (qpn, attempt) = (inf.qpn, inf.attempt);
                        self.events.push(
                            self.clock + Ns(nic.rnr_retry_ns),
                            Event::AckTimeout { node, qpn, msg_id: key, attempt },
                        );
                    }
                } else if let Some(inf) = self.node_mut(node).inflight.remove(&key) {
                    // lossless mode: retry the whole message after backoff
                    // by re-posting it at the head of the SQ (it re-issues
                    // with a fresh msg_id — fine when nothing is gated on
                    // sequence numbers)
                    if let Some(qp) = self.node_mut(node).qps.get_mut(inf.qpn.0) {
                        qp.outstanding = qp.outstanding.saturating_sub(1);
                    }
                    self.events.push(
                        self.clock + Ns(nic.rnr_retry_ns),
                        Event::RetrySend { node, qpn: inf.qpn, wr: inf.wr },
                    );
                }
            }
        }
        cost
    }

    fn rx_write_data(&mut self, node: NodeId, frame: &Frame) -> u64 {
        let nic = self.cfg.nic;
        let mut cost = 0;
        let (gcost, proceed) = self.gbn_admit(node, frame);
        if !proceed {
            return gcost;
        }
        let attempt_complete = self.rc_attempt_complete(node, frame);
        let key = (frame.src.0, frame.src_qpn.0, frame.msg_id);
        if frame.is_first {
            let ok = frame
                .rkey
                .map(|k| self.node(node).mrs.check_remote(k, frame.raddr, frame.msg_len, true))
                .unwrap_or(false);
            if !ok {
                self.node_mut(node).protection_errors += 1;
                self.node_mut(node).dropped_msgs.insert(key);
            } else if let Some(rk) = frame.rkey {
                if let Some(block) = self.node(node).mrs.mtt_block(rk, frame.raddr) {
                    cost += self.icm_touch(node, IcmKey::Mtt(rk.0, block));
                }
            }
        }
        if frame.is_last {
            let dropped = self.node_mut(node).dropped_msgs.remove(&key);
            if dropped {
                // protection error: the requester completes in error, so
                // this message's go-back-N slot is closed for good
                self.gbn_advance(node, frame);
                if frame.transport == QpTransport::Rc {
                    self.complete_requester_error(*frame, WcStatus::RemoteAccessError);
                }
                return cost;
            }
            if !attempt_complete {
                // a non-terminal frame of this attempt was lost: no
                // delivery, no ACK, no sequence advance — the requester's
                // timer retransmits the whole message
                return cost;
            }
            // write-with-imm consumes a receive WQE and raises a CQE
            if frame.imm.is_some() {
                if let Some((recv_cq, wr)) = self.consume_recv_wqe(node, frame) {
                    let cqe = Cqe {
                        wr_id: wr.map(|w| w.wr_id).unwrap_or(0),
                        kind: CqeKind::RecvRdmaWithImm,
                        status: WcStatus::Success,
                        len: frame.msg_len,
                        imm_data: frame.imm,
                        qpn: frame.dst_qpn,
                        src: Some((frame.src, frame.src_qpn)),
                    };
                    cost += self.icm_touch(node, IcmKey::Cqc(recv_cq.0));
                    self.events.push(
                        self.clock + Ns(cost + nic.cqe_delay_ns),
                        Event::CqeDeliver { node, cqn: recv_cq, cqe },
                    );
                } else {
                    // RNR on write-with-imm (no recv WQE)
                    self.send_rnr_nak(node, frame);
                    return cost;
                }
            }
            if frame.transport == QpTransport::Rc {
                self.gbn_advance(node, frame);
                cost += self.send_ack(node, frame);
            } else {
                // UC: delivered without ACK — count at the receiver
                self.completed_bytes += frame.msg_len;
                self.completed_msgs += 1;
            }
        }
        cost
    }

    fn rx_send_data(&mut self, node: NodeId, frame: &Frame) -> u64 {
        let nic = self.cfg.nic;
        let mut cost = 0;
        let (gcost, proceed) = self.gbn_admit(node, frame);
        if !proceed {
            return gcost;
        }
        let attempt_complete = self.rc_attempt_complete(node, frame);
        let key = (frame.src.0, frame.src_qpn.0, frame.msg_id);
        if frame.is_first {
            // retransmitted first frames must be idempotent: clear any
            // stale drop marker from a prior attempt, and never consume a
            // second recv WQE for a message already mid-assembly
            let already = if self.faults.is_some() {
                self.node_mut(node).dropped_msgs.remove(&key);
                // WQE already held from a prior attempt? then skip consume
                self.node(node).pending_recv.contains_key(&key)
            } else {
                false
            };
            if !already {
                match self.consume_recv_wqe_wr(node, frame) {
                    Some(wr) => {
                        // local buffer translation for the landing buffer
                        if let Some(block) = self.node(node).mrs.mtt_block(wr.lkey, wr.laddr) {
                            cost += self.icm_touch(node, IcmKey::Mtt(wr.lkey.0, block));
                        }
                        self.node_mut(node).pending_recv.insert(key, wr);
                    }
                    None => {
                        self.node_mut(node).dropped_msgs.insert(key);
                        if frame.transport == QpTransport::Rc {
                            self.send_rnr_nak(node, frame);
                        }
                        // UC/UD: silent drop
                    }
                }
            }
        }
        if frame.is_last {
            if self.node_mut(node).dropped_msgs.remove(&key) {
                return cost;
            }
            if !attempt_complete {
                // hole in this attempt (a middle frame was lost): keep
                // the held recv WQE and wait for the retransmission
                return cost;
            }
            let wr = match self.node_mut(node).pending_recv.remove(&key) {
                Some(wr) => wr,
                None => return cost, // first frame never consumed (shouldn't happen)
            };
            let recv_cq = self
                .node(node)
                .qps
                .get(frame.dst_qpn.0)
                .map(|qp| qp.recv_cq)
                .unwrap_or(Cqn(0));
            let cqe = Cqe {
                wr_id: wr.wr_id,
                kind: CqeKind::Recv,
                status: WcStatus::Success,
                len: frame.msg_len,
                imm_data: frame.imm,
                qpn: frame.dst_qpn,
                src: Some((frame.src, frame.src_qpn)),
            };
            cost += self.icm_touch(node, IcmKey::Cqc(recv_cq.0));
            self.events.push(
                self.clock + Ns(cost + nic.cqe_delay_ns),
                Event::CqeDeliver { node, cqn: recv_cq, cqe },
            );
            if frame.transport == QpTransport::Rc {
                self.gbn_advance(node, frame);
                cost += self.send_ack(node, frame);
            } else {
                // UC/UD: delivered without ACK — count at the receiver
                self.completed_bytes += frame.msg_len;
                self.completed_msgs += 1;
            }
        }
        cost
    }

    /// Consume a recv WQE (SRQ if attached, else private RQ); returns the
    /// recv CQ and the WR if one was available.
    fn consume_recv_wqe(&mut self, node: NodeId, frame: &Frame) -> Option<(Cqn, Option<RecvWr>)> {
        let (srq, recv_cq) = {
            let qp = self.node(node).qps.get(frame.dst_qpn.0)?;
            (qp.srq, qp.recv_cq)
        };
        let wr = match srq {
            Some(srqn) => self.node_mut(node).srqs.get_mut(srqn.0)?.consume(),
            None => {
                let qp = self.node_mut(node).qps.get_mut(frame.dst_qpn.0)?;
                qp.rq.pop_front()
            }
        };
        wr.map(|w| (recv_cq, Some(w)))
    }

    fn consume_recv_wqe_wr(&mut self, node: NodeId, frame: &Frame) -> Option<RecvWr> {
        self.consume_recv_wqe(node, frame).and_then(|(_, wr)| wr)
    }

    fn send_ack(&mut self, node: NodeId, frame: &Frame) -> u64 {
        let nic = self.cfg.nic;
        let cost = nic.engine_frame_ns;
        let ack = Frame {
            kind: FrameKind::Ack,
            src: node,
            dst: frame.src,
            dst_qpn: frame.src_qpn,
            src_qpn: frame.dst_qpn,
            transport: QpTransport::Rc,
            msg_id: frame.msg_id,
            msg_seq: frame.msg_seq,
            frame_idx: 0,
            bytes: CTRL_FRAME_BYTES,
            msg_len: frame.msg_len,
            is_first: true,
            is_last: true,
            wr_id: frame.wr_id,
            imm: None,
            rkey: None,
            raddr: 0,
        };
        let deliver = self.fabric.send_frame(self.clock + Ns(cost), node, frame.src, ack.bytes);
        self.events.push(deliver, Event::FrameDelivered(ack));
        cost
    }

    fn send_rnr_nak(&mut self, node: NodeId, frame: &Frame) {
        self.node_mut(node).rnr_naks_sent += 1;
        let nak = Frame {
            kind: FrameKind::RnrNak,
            src: node,
            dst: frame.src,
            dst_qpn: frame.src_qpn,
            src_qpn: frame.dst_qpn,
            transport: QpTransport::Rc,
            msg_id: frame.msg_id,
            msg_seq: frame.msg_seq,
            frame_idx: 0,
            bytes: CTRL_FRAME_BYTES,
            msg_len: frame.msg_len,
            is_first: true,
            is_last: true,
            wr_id: frame.wr_id,
            imm: None,
            rkey: None,
            raddr: 0,
        };
        let deliver = self.fabric.send_frame(self.clock, node, frame.src, nak.bytes);
        self.events.push(deliver, Event::FrameDelivered(nak));
    }

    /// ACK received at the requester: complete the in-flight RC message.
    fn rx_ack(&mut self, node: NodeId, frame: &Frame) -> u64 {
        let nic = self.cfg.nic;
        let mut cost = 0;
        let inf = match self.node_mut(node).inflight.remove(&frame.msg_id) {
            Some(i) => i,
            None => return 0, // duplicate/stale ack
        };
        let (send_cq, signaled) = {
            let qp = self.node_mut(node).qps.get_mut(inf.qpn.0).unwrap();
            qp.outstanding = qp.outstanding.saturating_sub(1);
            qp.completed += 1;
            (qp.send_cq, inf.wr.signaled)
        };
        self.completed_bytes += inf.wr.len;
        self.completed_msgs += 1;
        if signaled {
            let cqe = Cqe {
                wr_id: inf.wr.wr_id,
                kind: CqeKind::SendDone(inf.wr.verb),
                status: WcStatus::Success,
                len: inf.wr.len,
                imm_data: None,
                qpn: inf.qpn,
                src: None,
            };
            cost += self.icm_touch(node, IcmKey::Cqc(send_cq.0));
            self.events.push(
                self.clock + Ns(cost + nic.cqe_delay_ns),
                Event::CqeDeliver { node, cqn: send_cq, cqe },
            );
        }
        self.rearm_issue(node, inf.qpn);
        cost
    }

    /// Last READ response frame landed: complete at the requester.
    fn complete_read(&mut self, node: NodeId, frame: &Frame) -> u64 {
        let nic = self.cfg.nic;
        let mut cost = 0;
        let inf = match self.node_mut(node).inflight.remove(&frame.msg_id) {
            Some(i) => i,
            None => return 0,
        };
        let send_cq = {
            let qp = self.node_mut(node).qps.get_mut(inf.qpn.0).unwrap();
            qp.outstanding = qp.outstanding.saturating_sub(1);
            qp.completed += 1;
            qp.send_cq
        };
        self.completed_bytes += inf.wr.len;
        self.completed_msgs += 1;
        if inf.wr.signaled {
            let cqe = Cqe {
                wr_id: inf.wr.wr_id,
                kind: CqeKind::SendDone(Verb::Read),
                status: WcStatus::Success,
                len: inf.wr.len,
                imm_data: None,
                qpn: inf.qpn,
                src: None,
            };
            cost += self.icm_touch(node, IcmKey::Cqc(send_cq.0));
            self.events.push(
                self.clock + Ns(cost + nic.cqe_delay_ns),
                Event::CqeDeliver { node, cqn: send_cq, cqe },
            );
        }
        self.rearm_issue(node, inf.qpn);
        cost
    }

    /// Requester-side error completion (protection/NAK). Takes the frame
    /// by value — `Frame` is `Copy`, no clone on this path.
    fn complete_requester_error(&mut self, frame: Frame, status: WcStatus) {
        let node = frame.src;
        let inf = match self.node_mut(node).inflight.remove(&frame.msg_id) {
            Some(i) => i,
            None => return,
        };
        let send_cq = {
            let qp = self.node_mut(node).qps.get_mut(inf.qpn.0).unwrap();
            qp.outstanding = qp.outstanding.saturating_sub(1);
            qp.send_cq
        };
        let cqe = Cqe {
            wr_id: inf.wr.wr_id,
            kind: CqeKind::SendDone(inf.wr.verb),
            status,
            len: 0,
            imm_data: None,
            qpn: inf.qpn,
            src: None,
        };
        let at = self.clock + Ns(self.cfg.nic.cqe_delay_ns);
        self.events.push(at, Event::CqeDeliver { node, cqn: send_cq, cqe });
        self.rearm_issue(node, inf.qpn);
    }

    // -------------------------------------- fault layer: RC go-back-N

    /// Responder-side go-back-N admission for an RC data frame: `(extra
    /// cost, may proceed)`. Dormant (always admit) without a fault plan —
    /// on the lossless fabric frames cannot arrive out of sequence.
    fn gbn_admit(&mut self, node: NodeId, frame: &Frame) -> (u64, bool) {
        if self.faults.is_none() || frame.transport != QpTransport::Rc {
            return (0, true);
        }
        let expected = self
            .node(node)
            .qps
            .get(frame.dst_qpn.0)
            .map(|q| q.expected_msg_seq)
            .unwrap_or(0);
        if frame.msg_seq > expected {
            // an earlier message is missing: discard; the requester
            // retransmits everything from the hole, in order
            self.node_mut(node).gbn_discards += 1;
            return (0, false);
        }
        if frame.msg_seq < expected {
            // duplicate of a message this QP already consumed — its ACK
            // was evidently lost. Re-ACK the last frame so the requester
            // can complete; NEVER re-deliver (exactly-once).
            let mut cost = 0;
            if frame.is_last {
                self.node_mut(node).gbn_dup_acks += 1;
                cost += self.send_ack(node, frame);
            }
            return (cost, false);
        }
        (0, true)
    }

    /// An accepted RC message closed its go-back-N slot: the QP expects
    /// the next sequence. No-op without a fault plan (counters would be
    /// meaningless there — the lossless RNR path re-issues under fresh
    /// sequences).
    fn gbn_advance(&mut self, node: NodeId, frame: &Frame) {
        if self.faults.is_none() || frame.transport != QpTransport::Rc {
            return;
        }
        if let Some(qp) = self.node_mut(node).qps.get_mut(frame.dst_qpn.0) {
            qp.expected_msg_seq = qp.expected_msg_seq.max(frame.msg_seq + 1);
        }
    }

    /// Fault mode, RC multi-frame data messages: record one *admitted*
    /// frame (call after [`Sim::gbn_admit`]) and, on the last frame,
    /// report whether the message arrived with no holes — a lost MIDDLE
    /// frame must not let the last frame deliver/ACK a message missing
    /// bytes. Coverage is a per-index bitmap for messages of ≤ 64 frames
    /// (every workload here; dropped duplicates stay idempotent) and a
    /// plain frame count above that. The tracker is consumed on the last
    /// frame either way; an incomplete attempt leaves the requester's
    /// timer to retransmit the whole message.
    fn rc_attempt_complete(&mut self, node: NodeId, frame: &Frame) -> bool {
        if self.faults.is_none() || frame.transport != QpTransport::Rc {
            return true;
        }
        let total = self.fabric.frame_count(frame.msg_len.max(1));
        if total <= 1 {
            return true;
        }
        let key = (frame.src.0, frame.src_qpn.0, frame.msg_id);
        let n = self.node_mut(node);
        let seen = {
            let e = n.rc_frames_seen.entry(key).or_insert(0);
            if total <= 64 {
                *e |= 1u64 << frame.frame_idx.min(63);
            } else {
                *e += 1;
            }
            *e
        };
        if !frame.is_last {
            return true;
        }
        n.rc_frames_seen.remove(&key);
        let complete = if total <= 64 {
            let mask = if total == 64 { u64::MAX } else { (1u64 << total) - 1 };
            seen & mask == mask
        } else {
            seen >= total
        };
        if !complete {
            n.rc_incomplete_msgs += 1;
        }
        complete
    }

    /// Fault mode: record one ReadResp frame against its in-flight READ;
    /// on the last frame, true iff the response arrived complete (same
    /// bitmap/count scheme as [`Sim::rc_attempt_complete`], accumulated
    /// in the in-flight entry so duplicate response streams union up).
    fn read_resp_complete(&mut self, node: NodeId, frame: &Frame) -> bool {
        if self.faults.is_none() {
            return true;
        }
        let len = match self.node(node).inflight.get(&frame.msg_id) {
            Some(inf) => inf.wr.len.max(1),
            None => return true, // stale duplicate; complete_read will no-op
        };
        let total = self.fabric.frame_count(len);
        if total <= 1 {
            return true;
        }
        let n = self.node_mut(node);
        let complete = {
            let inf = n.inflight.get_mut(&frame.msg_id).expect("checked above");
            if total <= 64 {
                inf.resp_seen |= 1u64 << frame.frame_idx.min(63);
            } else {
                inf.resp_seen += 1;
            }
            if !frame.is_last {
                return true;
            }
            if total <= 64 {
                let mask = if total == 64 { u64::MAX } else { (1u64 << total) - 1 };
                inf.resp_seen & mask == mask
            } else {
                inf.resp_seen >= total
            }
        };
        if !complete {
            n.rc_incomplete_msgs += 1;
        }
        complete
    }

    /// Schedule the ACK timeout for `attempt` of an in-flight RC message.
    /// `expected_done` is when its last frame lands (for READs: when the
    /// response should have finished streaming); the margin backs off
    /// exponentially per attempt, capped at 8×. Dormant without faults.
    fn arm_rc_timer(&mut self, node: NodeId, qpn: Qpn, msg_id: u64, attempt: u32, expected_done: Ns) {
        if self.faults.is_none() {
            return;
        }
        let margin = self.cfg.nic.retransmit_timeout_ns << attempt.min(3);
        let at = expected_done + Ns(2 * self.cfg.switch_latency_ns + margin);
        self.events.push(at, Event::AckTimeout { node, qpn, msg_id, attempt });
    }

    /// Rough time for a READ response of `len` bytes to stream back:
    /// serialization of payload + per-frame overhead, responder engine
    /// touches, one-way propagation.
    fn read_response_eta(&self, len: u64) -> Ns {
        let payload = len.max(1);
        let frames = self.fabric.frame_count(payload);
        let wire = super::time::wire_time(
            payload + frames * super::switchfab::FRAME_OVERHEAD_BYTES,
            self.cfg.link_gbps,
        );
        Ns(wire.0 + frames * self.cfg.nic.engine_frame_ns + self.cfg.switch_latency_ns)
    }

    /// An ACK timeout fired. Acts only when the message is still in
    /// flight under the same attempt (otherwise it was acked, completed,
    /// superseded by a newer attempt, or its node restarted).
    fn on_ack_timeout(&mut self, node: NodeId, qpn: Qpn, msg_id: u64, attempt: u32) {
        let retry_cnt = self.cfg.nic.retry_cnt;
        {
            let n = self.node_mut(node);
            match n.inflight.get(&msg_id) {
                Some(inf) if inf.attempt == attempt => {}
                _ => return,
            }
        }
        if attempt >= retry_cnt {
            self.complete_retry_exceeded(node, msg_id);
            return;
        }
        // bump the attempt NOW, not when the engine gets to the work item:
        // a second timer armed under the same attempt (the RNR path arms
        // one alongside the issue-time timer) must see the mismatch and
        // no-op instead of double-retransmitting and burning the budget
        if let Some(inf) = self.node_mut(node).inflight.get_mut(&msg_id) {
            inf.attempt += 1;
        }
        // retransmission is engine work like everything else
        self.node_mut(node).engine_queue.push_back(WorkItem::Retransmit { qpn, msg_id });
        self.kick_engine(node);
    }

    /// Re-emit every frame of a timed-out RC message — go-back-N at
    /// message granularity, same msg_id and msg_seq as the original
    /// transmission so the responder can deduplicate. Returns engine
    /// occupancy.
    fn retransmit_msg(&mut self, node: NodeId, qpn: Qpn, msg_id: u64) -> u64 {
        let nic = self.cfg.nic;
        let (wr, msg_seq, attempt) = {
            // the attempt was already bumped by the timeout that queued
            // this work item — read, don't re-bump
            let Some(inf) = self.node(node).inflight.get(&msg_id) else { return 0 };
            (inf.wr.clone(), inf.msg_seq, inf.attempt)
        };
        let Some((peer_node, peer_qpn)) = self.node(node).qps.get(qpn.0).and_then(|q| q.peer)
        else {
            return 0;
        };
        self.node_mut(node).retransmits += 1;
        let mut cost = nic.engine_wqe_ns;
        cost += self.icm_touch(node, IcmKey::Qpc(qpn.0));

        match wr.verb {
            Verb::Read => {
                let frame = Frame {
                    kind: FrameKind::ReadReq,
                    src: node,
                    dst: peer_node,
                    dst_qpn: peer_qpn,
                    src_qpn: qpn,
                    transport: QpTransport::Rc,
                    msg_id,
                    msg_seq,
                    frame_idx: 0,
                    bytes: CTRL_FRAME_BYTES,
                    msg_len: wr.len,
                    is_first: true,
                    is_last: true,
                    wr_id: wr.wr_id,
                    imm: None,
                    rkey: wr.rkey,
                    raddr: wr.raddr,
                };
                cost += nic.engine_frame_ns;
                let deliver =
                    self.fabric.send_frame(self.clock + Ns(cost), node, peer_node, frame.bytes);
                self.events.push(deliver, Event::FrameDelivered(frame));
                let eta = deliver + self.read_response_eta(wr.len);
                self.arm_rc_timer(node, qpn, msg_id, attempt, eta);
            }
            Verb::Write | Verb::Send => {
                let kind = if wr.verb == Verb::Write {
                    FrameKind::WriteData
                } else {
                    FrameKind::SendData
                };
                let payload = wr.len.max(1);
                let total = self.fabric.frame_count(payload);
                let mut handoff = self.clock + Ns(cost);
                let mut last = self.clock;
                // retransmissions are rare: eager per-frame pushes, no
                // stream coalescing
                for i in 0..total {
                    cost += nic.engine_frame_ns;
                    handoff += Ns(nic.engine_frame_ns);
                    let stall = self.tx_stall(node, handoff);
                    cost += stall;
                    handoff += Ns(stall);
                    let bytes = self.fabric.frame_bytes(payload, i, total);
                    let frame = Frame {
                        kind,
                        src: node,
                        dst: peer_node,
                        dst_qpn: peer_qpn,
                        src_qpn: qpn,
                        transport: QpTransport::Rc,
                        msg_id,
                        msg_seq,
                        frame_idx: i,
                        bytes,
                        msg_len: wr.len,
                        is_first: i == 0,
                        is_last: i + 1 == total,
                        wr_id: wr.wr_id,
                        imm: wr.imm_data,
                        rkey: wr.rkey,
                        raddr: wr.raddr,
                    };
                    last = self.fabric.send_frame(handoff, node, peer_node, bytes);
                    self.events.push(last, Event::FrameDelivered(frame));
                }
                self.arm_rc_timer(node, qpn, msg_id, attempt, last);
            }
        }
        cost
    }

    /// The retry budget ran out. Real RC transitions the QP to Error and
    /// FLUSHES every outstanding WR — modeled here by completing every
    /// in-flight message of the QP with [`WcStatus::RetryExceeded`]. The
    /// responder's expected sequence is then resynced to the requester's
    /// next issue (the out-of-band re-establishment a daemon performs
    /// after a fatal retry): without both, one dead message would make
    /// the responder discard everything after it forever, and a
    /// partial resync could dup-ACK a message that was never delivered.
    fn complete_retry_exceeded(&mut self, node: NodeId, msg_id: u64) {
        let qpn = match self.node(node).inflight.get(&msg_id) {
            Some(inf) => inf.qpn,
            None => return,
        };
        // flush in ascending msg_id order — never HashMap order
        let mut ids: Vec<u64> = self
            .node(node)
            .inflight
            .iter()
            .filter(|(_, inf)| inf.qpn == qpn)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        for id in ids {
            let inf = self.node_mut(node).inflight.remove(&id).expect("collected id");
            let send_cq = {
                let n = self.node_mut(node);
                n.retry_exceeded += 1;
                let qp = n.qps.get_mut(qpn.0).expect("qp of in-flight msg");
                qp.outstanding = qp.outstanding.saturating_sub(1);
                qp.send_cq
            };
            let cqe = Cqe {
                wr_id: inf.wr.wr_id,
                kind: CqeKind::SendDone(inf.wr.verb),
                status: WcStatus::RetryExceeded,
                len: 0,
                imm_data: None,
                qpn,
                src: None,
            };
            let at = self.clock + Ns(self.cfg.nic.cqe_delay_ns);
            self.events.push(at, Event::CqeDeliver { node, cqn: send_cq, cqe });
        }
        // resync the responder past every issued (now dead or delivered)
        // sequence so post-recovery traffic is accepted again
        let (next_seq, peer) = {
            let qp = self.node(node).qps.get(qpn.0).expect("qp exists");
            (qp.next_msg_seq, qp.peer)
        };
        if let Some((peer_node, peer_qpn)) = peer {
            if let Some(pq) = self.node_mut(peer_node).qps.get_mut(peer_qpn.0) {
                pq.expected_msg_seq = pq.expected_msg_seq.max(next_seq);
            }
        }
        self.rearm_issue(node, qpn);
    }

    /// Fault-plan node soft-restart: queued engine work, SQ/RQ/SRQ/CQ
    /// contents and requester in-flight state vanish; connection state
    /// (peer bindings, go-back-N counters) survives so peers recover by
    /// retransmission. Work that died without a completion is what the
    /// daemon's stale-lease reclaim exists for.
    fn on_node_restart(&mut self, node: NodeId) {
        if let Some(f) = self.faults.as_mut() {
            f.note_restart();
        }
        let n = self.node_mut(node);
        n.restarts += 1;
        n.engine_queue.clear();
        n.inflight.clear();
        n.pending_recv.clear();
        n.rc_frames_seen.clear();
        n.dropped_msgs.clear();
        for qp in n.qps.iter_mut() {
            qp.reset_soft();
        }
        for srq in n.srqs.iter_mut() {
            srq.clear();
        }
        for cq in n.cqs.iter_mut() {
            cq.clear();
        }
    }
}
