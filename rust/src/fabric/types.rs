//! Core identifiers, transports, opcodes and the Table-1 capability matrix.

use std::fmt;

/// A physical machine in the cluster.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u32);

impl NodeId {
    /// Which of `shards` simulator shards owns this node (round-robin
    /// partition, `node % shards`). Round-robin beats contiguous ranges
    /// here because scenario drivers cluster servers at low ids and
    /// clients above them — striping spreads both roles over all shards.
    #[inline]
    pub fn shard_of(self, shards: usize) -> usize {
        self.0 as usize % shards.max(1)
    }

    /// This node's index within its owning shard's dense local arrays
    /// (`node / shards`; the inverse of the round-robin stripe).
    #[inline]
    pub fn shard_local(self, shards: usize) -> usize {
        self.0 as usize / shards.max(1)
    }
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Queue-pair number, unique per node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Qpn(pub u32);

/// Completion-queue id, unique per node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Cqn(pub u32);

/// Shared-receive-queue id, unique per node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Srqn(pub u32);

/// Memory-region key (both lkey and rkey in this simulator).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Mrkey(pub u32);

/// RDMA transport service types (paper Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QpTransport {
    /// Reliable Connection: acked, ordered, SEND/WRITE/READ, ≤1 GB messages.
    Rc,
    /// Unreliable Connection: unacked, SEND/WRITE only, ≤1 GB messages.
    Uc,
    /// Unreliable Datagram: unacked, SEND only, ≤MTU messages, one QP may
    /// address many remote QPs.
    Ud,
}

impl fmt::Display for QpTransport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QpTransport::Rc => write!(f, "RC"),
            QpTransport::Uc => write!(f, "UC"),
            QpTransport::Ud => write!(f, "UD"),
        }
    }
}

/// Verb opcodes used by work requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Verb {
    /// Two-sided send (channel semantics); consumes a remote RQ/SRQ WQE.
    Send,
    /// One-sided RDMA WRITE (optionally with immediate data).
    Write,
    /// One-sided RDMA READ.
    Read,
}

impl fmt::Display for Verb {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Verb::Send => write!(f, "SEND"),
            Verb::Write => write!(f, "WRITE"),
            Verb::Read => write!(f, "READ"),
        }
    }
}

/// Maximum message size for connected transports (Table 1: 1 GB).
pub const MAX_CONNECTED_MSG: u64 = 1 << 30;

/// Table 1: does `transport` support `verb`?
pub fn supports(transport: QpTransport, verb: Verb) -> bool {
    matches!(
        (transport, verb),
        (QpTransport::Rc, _)
            | (QpTransport::Uc, Verb::Send)
            | (QpTransport::Uc, Verb::Write)
            | (QpTransport::Ud, Verb::Send)
    )
}

/// Table 1: maximum message size for `transport` given the fabric MTU.
pub fn max_msg_size(transport: QpTransport, mtu: u64) -> u64 {
    match transport {
        QpTransport::Rc | QpTransport::Uc => MAX_CONNECTED_MSG,
        QpTransport::Ud => mtu,
    }
}

/// Dense id-indexed object table.
///
/// QPNs/CQNs/SRQNs are allocated sequentially from 1 and objects are
/// never destroyed mid-run, so the per-node object tables are plain
/// vectors indexed by `id - 1` instead of hash maps — the per-frame
/// QP/CQ/SRQ lookups on the simulator's hot path become a bounds check
/// and an add, with no hashing and no pointer chase.
#[derive(Debug)]
pub struct DenseTable<T> {
    items: Vec<T>,
}

impl<T> Default for DenseTable<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> DenseTable<T> {
    /// Empty table.
    pub fn new() -> Self {
        DenseTable { items: Vec::new() }
    }

    /// The id the next [`DenseTable::insert`] will assign (ids start at 1;
    /// 0 is reserved as a null id).
    pub fn next_id(&self) -> u32 {
        self.items.len() as u32 + 1
    }

    /// Append an object; returns its id.
    pub fn insert(&mut self, item: T) -> u32 {
        self.items.push(item);
        self.items.len() as u32
    }

    /// Look up by id (None for 0 or out of range).
    #[inline]
    pub fn get(&self, id: u32) -> Option<&T> {
        self.items.get((id.wrapping_sub(1)) as usize)
    }

    /// Mutable lookup by id.
    #[inline]
    pub fn get_mut(&mut self, id: u32) -> Option<&mut T> {
        self.items.get_mut((id.wrapping_sub(1)) as usize)
    }

    /// Objects stored.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when no object was ever inserted.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Iterate the objects in id order.
    pub fn iter(&self) -> std::slice::Iter<'_, T> {
        self.items.iter()
    }

    /// Iterate the objects mutably in id order (node restarts sweep every
    /// QP/SRQ/CQ of a node).
    pub fn iter_mut(&mut self) -> std::slice::IterMut<'_, T> {
        self.items.iter_mut()
    }
}

impl<T> std::ops::Index<u32> for DenseTable<T> {
    type Output = T;
    fn index(&self, id: u32) -> &T {
        self.get(id).expect("no object with this id")
    }
}

/// Dense id-keyed map for externally assigned small ids.
///
/// The daemon's per-remote state (shared QPs, peer pool credentials,
/// pending WR batches, migration entries) and per-vQPN state (UD message
/// tags, reassembly partials) are keyed by node ids / vQPNs, which are
/// small and dense but — unlike [`DenseTable`] ids — assigned by the
/// caller and insertable in any order. `IdMap` stores them in a
/// `Vec<Option<T>>` indexed directly by the id: lookups on the per-op
/// data plane are one bounds check, no hashing, and iteration is always
/// in ascending id order, so nothing about the backing store can leak
/// into the deterministic event timeline.
#[derive(Clone, Debug)]
pub struct IdMap<T> {
    slots: Vec<Option<T>>,
    live: usize,
}

impl<T> Default for IdMap<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> IdMap<T> {
    /// Empty map.
    pub fn new() -> Self {
        IdMap { slots: Vec::new(), live: 0 }
    }

    /// Insert (or replace) the entry for `id`, growing the backing
    /// vector as needed; returns the previous value, if any.
    pub fn insert(&mut self, id: u32, value: T) -> Option<T> {
        let idx = id as usize;
        if idx >= self.slots.len() {
            self.slots.resize_with(idx + 1, || None);
        }
        let old = self.slots[idx].replace(value);
        if old.is_none() {
            self.live += 1;
        }
        old
    }

    /// Look up by id.
    #[inline]
    pub fn get(&self, id: u32) -> Option<&T> {
        self.slots.get(id as usize).and_then(|s| s.as_ref())
    }

    /// Mutable lookup by id.
    #[inline]
    pub fn get_mut(&mut self, id: u32) -> Option<&mut T> {
        self.slots.get_mut(id as usize).and_then(|s| s.as_mut())
    }

    /// Mutable access to `id`, inserting `T::default()` when vacant.
    pub fn entry_or_default(&mut self, id: u32) -> &mut T
    where
        T: Default,
    {
        let idx = id as usize;
        if idx >= self.slots.len() {
            self.slots.resize_with(idx + 1, || None);
        }
        if self.slots[idx].is_none() {
            self.slots[idx] = Some(T::default());
            self.live += 1;
        }
        self.slots[idx].as_mut().expect("just populated")
    }

    /// Remove and return the entry for `id`.
    pub fn remove(&mut self, id: u32) -> Option<T> {
        let old = self.slots.get_mut(id as usize).and_then(|s| s.take());
        if old.is_some() {
            self.live -= 1;
        }
        old
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.live
    }

    /// True when no entry is present.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Iterate `(id, &value)` in ascending id order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &T)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|v| (i as u32, v)))
    }

    /// Iterate `(id, &mut value)` in ascending id order.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = (u32, &mut T)> {
        self.slots
            .iter_mut()
            .enumerate()
            .filter_map(|(i, s)| s.as_mut().map(|v| (i as u32, v)))
    }

    /// Keep only the entries for which `f` returns true (ascending id
    /// order); returns how many were dropped.
    pub fn retain(&mut self, mut f: impl FnMut(u32, &T) -> bool) -> usize {
        let mut dropped = 0;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Some(v) = slot {
                if !f(i as u32, v) {
                    *slot = None;
                    dropped += 1;
                }
            }
        }
        self.live -= dropped;
        dropped
    }
}

/// Completion status codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WcStatus {
    /// Operation completed successfully.
    Success,
    /// RQ/SRQ had no posted WQE for an incoming SEND.
    RnrRetryExceeded,
    /// RC transport retry budget exhausted (ACK never arrived within
    /// `retry_cnt` retransmissions — lost peer or flapping link).
    RetryExceeded,
    /// Access outside a registered region / bad rkey.
    RemoteAccessError,
    /// Message exceeded the transport's max size.
    InvalidLength,
    /// Local protection error (bad lkey).
    LocalProtectionError,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_capability_matrix() {
        use QpTransport::*;
        use Verb::*;
        // RC: everything
        assert!(supports(Rc, Send) && supports(Rc, Write) && supports(Rc, Read));
        // UC: no READ
        assert!(supports(Uc, Send) && supports(Uc, Write));
        assert!(!supports(Uc, Read));
        // UD: SEND only
        assert!(supports(Ud, Send));
        assert!(!supports(Ud, Write) && !supports(Ud, Read));
    }

    #[test]
    fn table1_max_sizes() {
        let mtu = 4096;
        assert_eq!(max_msg_size(QpTransport::Rc, mtu), 1 << 30);
        assert_eq!(max_msg_size(QpTransport::Uc, mtu), 1 << 30);
        assert_eq!(max_msg_size(QpTransport::Ud, mtu), 4096);
    }

    #[test]
    fn dense_table_ids_from_one() {
        let mut t: DenseTable<&str> = DenseTable::new();
        assert!(t.is_empty());
        assert_eq!(t.next_id(), 1);
        assert_eq!(t.insert("a"), 1);
        assert_eq!(t.insert("b"), 2);
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(0), None, "0 is the null id");
        assert_eq!(t.get(1), Some(&"a"));
        assert_eq!(t[2], "b");
        assert_eq!(t.get(3), None);
        *t.get_mut(1).unwrap() = "c";
        assert_eq!(t.iter().copied().collect::<Vec<_>>(), vec!["c", "b"]);
    }

    #[test]
    fn id_map_basics() {
        let mut m: IdMap<&str> = IdMap::new();
        assert!(m.is_empty());
        assert_eq!(m.insert(5, "a"), None);
        assert_eq!(m.insert(1, "b"), None);
        assert_eq!(m.insert(5, "c"), Some("a"));
        assert_eq!(m.len(), 2);
        assert_eq!(m.get(5), Some(&"c"));
        assert_eq!(m.get(0), None);
        assert_eq!(m.get(99), None);
        // iteration is ascending-id, never insertion order
        let ids: Vec<u32> = m.iter().map(|(i, _)| i).collect();
        assert_eq!(ids, vec![1, 5]);
        assert_eq!(m.remove(1), Some("b"));
        assert_eq!(m.remove(1), None);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn id_map_entry_and_retain() {
        let mut m: IdMap<Vec<u32>> = IdMap::new();
        m.entry_or_default(3).push(7);
        m.entry_or_default(3).push(8);
        m.entry_or_default(0).push(1);
        assert_eq!(m.get(3), Some(&vec![7, 8]));
        assert_eq!(m.len(), 2);
        let dropped = m.retain(|id, _| id != 3);
        assert_eq!(dropped, 1);
        assert_eq!(m.len(), 1);
        assert_eq!(m.get(3), None);
    }

    #[test]
    fn display_forms() {
        assert_eq!(format!("{}", QpTransport::Rc), "RC");
        assert_eq!(format!("{}", Verb::Read), "READ");
        assert_eq!(format!("{}", NodeId(3)), "n3");
    }
}
