//! Core identifiers, transports, opcodes and the Table-1 capability matrix.

use std::fmt;

/// A physical machine in the cluster.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Queue-pair number, unique per node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Qpn(pub u32);

/// Completion-queue id, unique per node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Cqn(pub u32);

/// Shared-receive-queue id, unique per node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Srqn(pub u32);

/// Memory-region key (both lkey and rkey in this simulator).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Mrkey(pub u32);

/// RDMA transport service types (paper Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QpTransport {
    /// Reliable Connection: acked, ordered, SEND/WRITE/READ, ≤1 GB messages.
    Rc,
    /// Unreliable Connection: unacked, SEND/WRITE only, ≤1 GB messages.
    Uc,
    /// Unreliable Datagram: unacked, SEND only, ≤MTU messages, one QP may
    /// address many remote QPs.
    Ud,
}

impl fmt::Display for QpTransport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QpTransport::Rc => write!(f, "RC"),
            QpTransport::Uc => write!(f, "UC"),
            QpTransport::Ud => write!(f, "UD"),
        }
    }
}

/// Verb opcodes used by work requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Verb {
    /// Two-sided send (channel semantics); consumes a remote RQ/SRQ WQE.
    Send,
    /// One-sided RDMA WRITE (optionally with immediate data).
    Write,
    /// One-sided RDMA READ.
    Read,
}

impl fmt::Display for Verb {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Verb::Send => write!(f, "SEND"),
            Verb::Write => write!(f, "WRITE"),
            Verb::Read => write!(f, "READ"),
        }
    }
}

/// Maximum message size for connected transports (Table 1: 1 GB).
pub const MAX_CONNECTED_MSG: u64 = 1 << 30;

/// Table 1: does `transport` support `verb`?
pub fn supports(transport: QpTransport, verb: Verb) -> bool {
    matches!(
        (transport, verb),
        (QpTransport::Rc, _)
            | (QpTransport::Uc, Verb::Send)
            | (QpTransport::Uc, Verb::Write)
            | (QpTransport::Ud, Verb::Send)
    )
}

/// Table 1: maximum message size for `transport` given the fabric MTU.
pub fn max_msg_size(transport: QpTransport, mtu: u64) -> u64 {
    match transport {
        QpTransport::Rc | QpTransport::Uc => MAX_CONNECTED_MSG,
        QpTransport::Ud => mtu,
    }
}

/// Completion status codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WcStatus {
    /// Operation completed successfully.
    Success,
    /// RQ/SRQ had no posted WQE for an incoming SEND.
    RnrRetryExceeded,
    /// Access outside a registered region / bad rkey.
    RemoteAccessError,
    /// Message exceeded the transport's max size.
    InvalidLength,
    /// Local protection error (bad lkey).
    LocalProtectionError,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_capability_matrix() {
        use QpTransport::*;
        use Verb::*;
        // RC: everything
        assert!(supports(Rc, Send) && supports(Rc, Write) && supports(Rc, Read));
        // UC: no READ
        assert!(supports(Uc, Send) && supports(Uc, Write));
        assert!(!supports(Uc, Read));
        // UD: SEND only
        assert!(supports(Ud, Send));
        assert!(!supports(Ud, Write) && !supports(Ud, Read));
    }

    #[test]
    fn table1_max_sizes() {
        let mtu = 4096;
        assert_eq!(max_msg_size(QpTransport::Rc, mtu), 1 << 30);
        assert_eq!(max_msg_size(QpTransport::Uc, mtu), 1 << 30);
        assert_eq!(max_msg_size(QpTransport::Ud, mtu), 4096);
    }

    #[test]
    fn display_forms() {
        assert_eq!(format!("{}", QpTransport::Rc), "RC");
        assert_eq!(format!("{}", Verb::Read), "READ");
        assert_eq!(format!("{}", NodeId(3)), "n3");
    }
}
