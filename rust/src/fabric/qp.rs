//! Queue pairs: state machine, send/receive queues, transport rules.
//!
//! Enforces the Table-1 capability matrix at post time (UC rejects READ,
//! UD rejects anything over MTU, …) and models the RC requester's
//! outstanding-window so reads pipeline realistically.

use std::collections::VecDeque;

use super::srq::RECV_WQE_BYTES;
use super::time::Ns;
use super::types::{max_msg_size, supports, NodeId, QpTransport, Qpn, Srqn, Cqn};
use super::wqe::{RecvWr, SendWr};

/// Hardware send WQE size (ConnectX family: 64 B typical with one SGE).
pub const SEND_WQE_BYTES: u64 = 64;
/// On-NIC QP context size (QPC ~ 256 B in ConnectX parts).
pub const QP_CONTEXT_BYTES: u64 = 256;

/// QP state machine (subset: the states the verbs path exercises).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QpState {
    /// Freshly created; not usable yet.
    Reset,
    /// Initialized (access rights set).
    Init,
    /// Ready To Receive.
    Rtr,
    /// Ready To Send (fully connected).
    Rts,
    /// Fatal error; all posts rejected.
    Error,
}

/// Errors surfaced by post-time validation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PostError {
    /// QP is not in a postable state.
    BadState(QpState),
    /// Verb not in the transport's Table-1 row.
    UnsupportedVerb(QpTransport),
    /// Message exceeds the transport's maximum size.
    TooLong { len: u64, max: u64 },
    /// Send queue at capacity.
    SqFull,
    /// Receive queue at capacity (or the QP uses an SRQ).
    RqFull,
    /// UD send without an address handle.
    MissingUdDest,
    /// One-sided verb without an rkey.
    MissingRemoteKey,
}

impl std::fmt::Display for PostError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PostError::BadState(s) => write!(f, "QP not ready (state {s:?})"),
            PostError::UnsupportedVerb(t) => write!(f, "verb unsupported on {t}"),
            PostError::TooLong { len, max } => write!(f, "message {len} B > max {max} B"),
            PostError::SqFull => write!(f, "send queue full"),
            PostError::RqFull => write!(f, "receive queue full"),
            PostError::MissingUdDest => write!(f, "UD send without address handle"),
            PostError::MissingRemoteKey => write!(f, "one-sided verb without rkey"),
        }
    }
}

/// A queue pair.
#[derive(Debug)]
pub struct Qp {
    /// This QP's number on its node.
    pub qpn: Qpn,
    /// Service type (RC/UC/UD).
    pub transport: QpTransport,
    /// Current state-machine state.
    pub state: QpState,
    /// Connected peer (RC/UC); UD resolves per-WR address handles.
    pub peer: Option<(NodeId, Qpn)>,
    /// Completion queue for send-side CQEs.
    pub send_cq: Cqn,
    /// Completion queue for recv-side CQEs.
    pub recv_cq: Cqn,
    /// Receive WQEs come from the SRQ if set, else the private RQ.
    pub srq: Option<Srqn>,
    /// Send queue (WQEs awaiting NIC issue).
    pub sq: VecDeque<SendWr>,
    /// Private receive queue (unused when an SRQ is attached).
    pub rq: VecDeque<RecvWr>,
    /// Send-queue capacity.
    pub sq_depth: usize,
    /// Receive-queue capacity.
    pub rq_depth: usize,
    /// RC requester window: max outstanding (un-acked / un-responded) msgs.
    pub max_outstanding: usize,
    /// Currently un-acked / un-responded messages.
    pub outstanding: usize,
    /// An `IssueFromQp` work item is queued on the engine for this QP
    /// (doorbell coalescing — replaces the per-node hash set of armed
    /// QPNs with a flag in the dense QP slot).
    pub issue_armed: bool,
    /// Requester-side RC go-back-N: sequence the next issued message gets
    /// (assigned at first issue, reused on retransmission). Advances in
    /// issue order, which is SQ order.
    pub next_msg_seq: u64,
    /// Responder-side RC go-back-N: the only message sequence this QP
    /// accepts next. Lower = duplicate (re-ACK, don't re-deliver);
    /// higher = discard (the requester will retransmit in order).
    pub expected_msg_seq: u64,
    /// Lifetime counters (metrics / tests).
    pub posted_send: u64,
    /// Lifetime receive WRs posted.
    pub posted_recv: u64,
    /// Lifetime send-side completions.
    pub completed: u64,
    /// Torn down via [`crate::fabric::sim::Sim::destroy_qp`]. The dense
    /// table never reuses the slot (ids stay stable), but a destroyed QP
    /// accounts zero memory, rejects posts, and the engine/fabric drop
    /// anything addressed to it.
    pub destroyed: bool,
    /// DCQCN sending rate, as a fraction of line rate. Only consulted
    /// when the Clos fabric runs in `Dcqcn` mode ([`crate::fabric::topo`]);
    /// 1.0 (line rate) otherwise and at rest.
    pub cc_rate: f64,
    /// DCQCN pacer horizon: the NIC may not *issue* the next message from
    /// this SQ before this instant (message-level pacing — egress-port
    /// serialization is left untouched so co-located QPs don't HOL-block).
    pub cc_paced_until: Ns,
    /// Last instant the lazy additive/hyper rate recovery was applied.
    pub cc_last_update: Ns,
    /// Last accepted rate cut (CNP coalescing gate).
    pub cc_last_cut: Ns,
    /// ECMP path salt: stamped on every frame this QP originates, folded
    /// into the Clos rendezvous pick. Bumped by the blackhole detector
    /// (see `shard.rs`) to move the flow off a dead path before the retry
    /// budget burns out. Never reset — the flow stays on its escape path.
    pub path_salt: u32,
    /// Consecutive ack-timeouts since the last successful completion on
    /// this QP (the blackhole detector's evidence counter).
    pub timeout_streak: u32,
}

impl Qp {
    /// Create a QP in the Reset state.
    pub fn new(
        qpn: Qpn,
        transport: QpTransport,
        send_cq: Cqn,
        recv_cq: Cqn,
        sq_depth: usize,
        rq_depth: usize,
        max_outstanding: usize,
    ) -> Self {
        Qp {
            qpn,
            transport,
            state: QpState::Reset,
            peer: None,
            send_cq,
            recv_cq,
            srq: None,
            sq: VecDeque::new(),
            rq: VecDeque::new(),
            sq_depth,
            rq_depth,
            max_outstanding,
            outstanding: 0,
            issue_armed: false,
            next_msg_seq: 0,
            expected_msg_seq: 0,
            posted_send: 0,
            posted_recv: 0,
            completed: 0,
            destroyed: false,
            cc_rate: 1.0,
            cc_paced_until: Ns::ZERO,
            cc_last_update: Ns::ZERO,
            cc_last_cut: Ns::ZERO,
            path_salt: 0,
            timeout_streak: 0,
        }
    }

    /// INIT → RTR (responder resources ready).
    pub fn to_rtr(&mut self) {
        debug_assert!(matches!(self.state, QpState::Reset | QpState::Init));
        self.state = QpState::Rtr;
    }

    /// RTR → RTS, binding the peer for connected transports.
    pub fn to_rts(&mut self, peer: Option<(NodeId, Qpn)>) {
        self.state = QpState::Rts;
        if self.transport != QpTransport::Ud {
            debug_assert!(peer.is_some(), "connected transport requires a peer");
        }
        self.peer = peer;
    }

    /// Validate + enqueue a send WR (does not start NIC processing — the
    /// [`super::nic`] engine pulls from the SQ).
    pub fn post_send(&mut self, wr: SendWr, mtu: u64) -> Result<(), PostError> {
        if self.state != QpState::Rts {
            return Err(PostError::BadState(self.state));
        }
        if !supports(self.transport, wr.verb) {
            return Err(PostError::UnsupportedVerb(self.transport));
        }
        let max = max_msg_size(self.transport, mtu);
        if wr.len > max {
            return Err(PostError::TooLong { len: wr.len, max });
        }
        if self.sq.len() >= self.sq_depth {
            return Err(PostError::SqFull);
        }
        if self.transport == QpTransport::Ud && wr.ud_dest.is_none() {
            return Err(PostError::MissingUdDest);
        }
        if matches!(wr.verb, super::types::Verb::Write | super::types::Verb::Read)
            && wr.rkey.is_none()
        {
            return Err(PostError::MissingRemoteKey);
        }
        self.posted_send += 1;
        self.sq.push_back(wr);
        Ok(())
    }

    /// Validate + enqueue a receive WR on the private RQ.
    pub fn post_recv(&mut self, wr: RecvWr) -> Result<(), PostError> {
        if matches!(self.state, QpState::Reset | QpState::Error) {
            return Err(PostError::BadState(self.state));
        }
        if self.srq.is_some() {
            // Verbs spec: QPs attached to an SRQ must not post to the RQ.
            return Err(PostError::RqFull);
        }
        if self.rq.len() >= self.rq_depth {
            return Err(PostError::RqFull);
        }
        self.posted_recv += 1;
        self.rq.push_back(wr);
        Ok(())
    }

    /// Can the NIC start another message from this SQ (RC window check)?
    pub fn can_issue(&self) -> bool {
        !self.sq.is_empty()
            && (self.transport != QpTransport::Rc || self.outstanding < self.max_outstanding)
    }

    /// Lazily apply the DCQCN rate-recovery timer up to `now`: one
    /// additive step of `ai_frac` per elapsed `recovery_ns` period for the
    /// first five periods since the last cut, doubling per period beyond
    /// that (hyper increase), clamped to line rate. Closed form — no
    /// per-period events, so an idle QP costs nothing.
    pub fn cc_advance(&mut self, now: Ns, recovery_ns: u64, ai_frac: f64) {
        if recovery_ns == 0 || now <= self.cc_last_update || self.cc_rate >= 1.0 {
            if now > self.cc_last_update {
                self.cc_last_update = now;
            }
            return;
        }
        let steps = (now.0 - self.cc_last_update.0) / recovery_ns;
        if steps == 0 {
            return;
        }
        let add = if steps <= 5 {
            ai_frac * steps as f64
        } else {
            // 5 additive steps, then 2, 4, 8, ... per step:
            // 5 + sum_{i=1}^{steps-5} 2^i = 3 + 2^(steps-4)
            ai_frac * (3.0 + 2f64.powi((steps - 4).min(32) as i32))
        };
        self.cc_rate = (self.cc_rate + add).min(1.0);
        self.cc_last_update = Ns(self.cc_last_update.0 + steps * recovery_ns);
    }

    /// React to an echoed ECN mark (the CNP): multiplicative rate cut,
    /// coalesced to at most one cut per `cnp_gap_ns`. Returns true when
    /// the cut was taken.
    pub fn cc_on_cnp(&mut self, now: Ns, alpha: f64, min_rate: f64, cnp_gap_ns: u64) -> bool {
        if self.cc_last_cut.0 != 0 && now.0.saturating_sub(self.cc_last_cut.0) < cnp_gap_ns {
            return false;
        }
        self.cc_rate = (self.cc_rate * (1.0 - alpha)).max(min_rate);
        self.cc_last_cut = now;
        self.cc_last_update = now;
        true
    }

    /// Node soft-restart ([`crate::fabric::fault`]): queued-but-unissued
    /// work and the requester window vanish; connection state (peer
    /// binding, RTS, go-back-N sequence counters) survives — the daemon
    /// is assumed to re-establish its QPs out of band, and keeping the
    /// sequence counters is what lets in-flight peers recover by
    /// retransmission instead of deadlocking the accept discipline.
    pub fn reset_soft(&mut self) {
        self.sq.clear();
        self.rq.clear();
        self.outstanding = 0;
        self.issue_armed = false;
        self.cc_rate = 1.0;
        self.cc_paced_until = Ns::ZERO;
        self.cc_last_update = Ns::ZERO;
        self.cc_last_cut = Ns::ZERO;
        // the detector's evidence resets with the NIC; the path salt is
        // link state, not NIC state, so the flow keeps its escape path
        self.timeout_streak = 0;
    }

    /// Tear the QP down: rings freed, context deallocated, peer binding
    /// severed. The slot stays in the dense table (ids are stable) but
    /// every later touch — posts, frame delivery, memory accounting —
    /// treats it as gone.
    pub fn destroy(&mut self) {
        self.destroyed = true;
        self.state = QpState::Error;
        self.peer = None;
        self.sq.clear();
        self.rq.clear();
        self.outstanding = 0;
        self.issue_armed = false;
    }

    /// Memory footprint of the QP (ledger): SQ+RQ rings + on-NIC context.
    /// Destroyed QPs have released their rings and QPC — zero bytes.
    pub fn mem_bytes(&self) -> u64 {
        if self.destroyed {
            return 0;
        }
        self.sq_depth as u64 * SEND_WQE_BYTES
            + self.rq_depth as u64 * RECV_WQE_BYTES
            + QP_CONTEXT_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::types::{Mrkey, Verb};

    fn mk(t: QpTransport) -> Qp {
        let mut qp = Qp::new(Qpn(1), t, Cqn(0), Cqn(0), 128, 128, 16);
        qp.to_rtr();
        qp.to_rts(if t == QpTransport::Ud { None } else { Some((NodeId(1), Qpn(2))) });
        qp
    }

    fn send(len: u64) -> SendWr {
        SendWr::send(1, len, Mrkey(1), 0, 0)
    }

    #[test]
    fn rc_accepts_all_verbs() {
        let mut qp = mk(QpTransport::Rc);
        assert!(qp.post_send(send(1024), 4096).is_ok());
        assert!(qp
            .post_send(SendWr::write(1, 1024, Mrkey(1), 0, Mrkey(2), 0), 4096)
            .is_ok());
        assert!(qp
            .post_send(SendWr::read(1, 1024, Mrkey(1), 0, Mrkey(2), 0), 4096)
            .is_ok());
    }

    #[test]
    fn uc_rejects_read() {
        let mut qp = mk(QpTransport::Uc);
        let err = qp
            .post_send(SendWr::read(1, 1024, Mrkey(1), 0, Mrkey(2), 0), 4096)
            .unwrap_err();
        assert_eq!(err, PostError::UnsupportedVerb(QpTransport::Uc));
    }

    #[test]
    fn ud_rejects_over_mtu_and_needs_ah() {
        let mut qp = mk(QpTransport::Ud);
        let err = qp.post_send(send(8192).to_ud(NodeId(1), Qpn(2)), 4096).unwrap_err();
        assert!(matches!(err, PostError::TooLong { .. }));
        let err = qp.post_send(send(1024), 4096).unwrap_err();
        assert_eq!(err, PostError::MissingUdDest);
        assert!(qp.post_send(send(1024).to_ud(NodeId(1), Qpn(2)), 4096).is_ok());
    }

    #[test]
    fn connected_max_1gb() {
        let mut qp = mk(QpTransport::Rc);
        assert!(qp
            .post_send(SendWr::write(1, 1 << 30, Mrkey(1), 0, Mrkey(2), 0), 4096)
            .is_ok());
        assert!(matches!(
            qp.post_send(SendWr::write(1, (1 << 30) + 1, Mrkey(1), 0, Mrkey(2), 0), 4096),
            Err(PostError::TooLong { .. })
        ));
    }

    #[test]
    fn post_requires_rts() {
        let mut qp = Qp::new(Qpn(1), QpTransport::Rc, Cqn(0), Cqn(0), 8, 8, 4);
        assert!(matches!(qp.post_send(send(64), 4096), Err(PostError::BadState(_))));
    }

    #[test]
    fn sq_depth_enforced() {
        let mut qp = mk(QpTransport::Rc);
        qp.sq_depth = 2;
        assert!(qp.post_send(send(64), 4096).is_ok());
        assert!(qp.post_send(send(64), 4096).is_ok());
        assert_eq!(qp.post_send(send(64), 4096), Err(PostError::SqFull));
    }

    #[test]
    fn srq_attached_rejects_rq_post() {
        let mut qp = mk(QpTransport::Rc);
        qp.srq = Some(Srqn(0));
        let wr = RecvWr { wr_id: 1, lkey: Mrkey(1), laddr: 0, len: 64 };
        assert!(qp.post_recv(wr).is_err());
    }

    #[test]
    fn rc_window_gates_issue() {
        let mut qp = mk(QpTransport::Rc);
        qp.max_outstanding = 1;
        qp.post_send(send(64), 4096).unwrap();
        qp.post_send(send(64), 4096).unwrap();
        assert!(qp.can_issue());
        qp.outstanding = 1;
        assert!(!qp.can_issue());
    }

    #[test]
    fn one_sided_requires_rkey() {
        let mut qp = mk(QpTransport::Rc);
        let mut wr = SendWr::write(1, 64, Mrkey(1), 0, Mrkey(2), 0);
        wr.rkey = None;
        assert_eq!(qp.post_send(wr, 4096), Err(PostError::MissingRemoteKey));
    }

    #[test]
    fn mem_footprint() {
        let qp = Qp::new(Qpn(1), QpTransport::Rc, Cqn(0), Cqn(0), 128, 128, 16);
        assert_eq!(qp.mem_bytes(), 128 * 64 + 128 * 16 + 256);
    }

    #[test]
    fn dcqcn_cut_recovers_additively_then_hyper() {
        let mut qp = mk(QpTransport::Rc);
        assert!(qp.cc_on_cnp(Ns(1000), 0.5, 1.0 / 32.0, 50_000));
        assert!((qp.cc_rate - 0.5).abs() < 1e-12);
        // coalescing: a second CNP inside the gap is ignored
        assert!(!qp.cc_on_cnp(Ns(2000), 0.5, 1.0 / 32.0, 50_000));
        assert!((qp.cc_rate - 0.5).abs() < 1e-12);
        // 3 recovery periods later: 3 additive steps of 1/16
        qp.cc_advance(Ns(1000 + 3 * 55_000), 55_000, 1.0 / 16.0);
        assert!((qp.cc_rate - (0.5 + 3.0 / 16.0)).abs() < 1e-12);
        // far in the future the hyper phase clamps to line rate
        qp.cc_advance(Ns(10_000_000), 55_000, 1.0 / 16.0);
        assert!((qp.cc_rate - 1.0).abs() < 1e-12);
        // floor is respected
        for i in 0..20 {
            qp.cc_on_cnp(Ns(20_000_000 + i * 60_000), 0.5, 1.0 / 32.0, 50_000);
        }
        assert!(qp.cc_rate >= 1.0 / 32.0 - 1e-12);
    }
}
