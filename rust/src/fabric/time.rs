//! Virtual time: nanosecond clock and rate arithmetic.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point (or span) in virtual time, in nanoseconds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Ns(pub u64);

impl Ns {
    /// Time zero.
    pub const ZERO: Ns = Ns(0);

    /// Microseconds → [`Ns`].
    pub fn from_us(us: u64) -> Ns {
        Ns(us * 1_000)
    }

    /// Milliseconds → [`Ns`].
    pub fn from_ms(ms: u64) -> Ns {
        Ns(ms * 1_000_000)
    }

    /// Seconds (f64) → [`Ns`].
    pub fn from_secs_f64(s: f64) -> Ns {
        Ns((s * 1e9) as u64)
    }

    /// This span in seconds.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    /// This span in microseconds.
    pub fn as_us_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }

    /// Later of the two instants.
    pub fn max(self, other: Ns) -> Ns {
        Ns(self.0.max(other.0))
    }

    /// `self - other`, clamped at zero.
    pub fn saturating_sub(self, other: Ns) -> Ns {
        Ns(self.0.saturating_sub(other.0))
    }
}

impl Add for Ns {
    type Output = Ns;
    fn add(self, rhs: Ns) -> Ns {
        Ns(self.0 + rhs.0)
    }
}

impl AddAssign for Ns {
    fn add_assign(&mut self, rhs: Ns) {
        self.0 += rhs.0;
    }
}

impl Sub for Ns {
    type Output = Ns;
    fn sub(self, rhs: Ns) -> Ns {
        Ns(self.0 - rhs.0)
    }
}

impl fmt::Display for Ns {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 < 1_000 {
            write!(f, "{}ns", self.0)
        } else if self.0 < 1_000_000 {
            write!(f, "{:.2}µs", self.0 as f64 / 1e3)
        } else if self.0 < 1_000_000_000 {
            write!(f, "{:.2}ms", self.0 as f64 / 1e6)
        } else {
            write!(f, "{:.3}s", self.0 as f64 / 1e9)
        }
    }
}

/// Time to serialize `bytes` at `gbps` gigabits per second.
pub fn wire_time(bytes: u64, gbps: f64) -> Ns {
    // ns = bytes*8 / (gbps * 1e9) * 1e9 = bytes*8 / gbps
    Ns((bytes as f64 * 8.0 / gbps).ceil() as u64)
}

/// Throughput in Gb/s for `bytes` over `elapsed`.
pub fn gbps(bytes: u64, elapsed: Ns) -> f64 {
    if elapsed.0 == 0 {
        return 0.0;
    }
    bytes as f64 * 8.0 / elapsed.0 as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_40g() {
        // 4096 B at 40 Gb/s = 819.2 ns
        assert_eq!(wire_time(4096, 40.0), Ns(820));
        // 1 GB at 40 Gb/s = 0.2 s
        let t = wire_time(1_000_000_000, 40.0);
        assert!((t.as_secs_f64() - 0.2).abs() < 1e-6);
    }

    #[test]
    fn gbps_roundtrip() {
        let t = wire_time(1_000_000, 40.0);
        let g = gbps(1_000_000, t);
        assert!((g - 40.0).abs() < 0.1, "g={g}");
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", Ns(500)), "500ns");
        assert_eq!(format!("{}", Ns(2_500)), "2.50µs");
        assert_eq!(format!("{}", Ns(3_000_000)), "3.00ms");
    }

    #[test]
    fn arithmetic() {
        assert_eq!(Ns(5) + Ns(7), Ns(12));
        assert_eq!(Ns(9) - Ns(4), Ns(5));
        assert_eq!(Ns(3).max(Ns(8)), Ns(8));
        assert_eq!(Ns(3).saturating_sub(Ns(8)), Ns(0));
    }
}
