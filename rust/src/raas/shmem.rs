//! Lock-free app↔daemon shared-memory channel (§2.3).
//!
//! "Applications write send-requests to shared memory, use eventfd to
//! notify RDMAvisor, and read the same eventfd to get the send result" —
//! the producer/consumer design that keeps the whole submit path in user
//! space with zero locks.
//!
//! This module is the **real** implementation (used by the live serving
//! example and the hot-path benches): a cache-padded SPSC ring over a boxed
//! slice with acquire/release atomics, plus a [`Doorbell`] with a busy-poll
//! fast path. On a real deployment the doorbell is a Linux `eventfd(2)`;
//! the offline build has no `libc`, so it is modeled with the identical
//! counter semantics over `Mutex`+`Condvar` (8-byte write to ring, read
//! resets — same contract, same cost class: one syscall-ish wakeup). The
//! simulator charges the [`ShmCosts`] constants for the same operations in
//! virtual time.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Cost constants the DES charges for ring ops (measured on this machine by
/// `benches/hotpath.rs`; see EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug)]
pub struct ShmCosts {
    /// Producer-side ring push.
    pub ring_push_ns: u64,
    /// Consumer-side ring pop.
    pub ring_pop_ns: u64,
    /// eventfd write+read pair when the consumer was asleep.
    pub doorbell_ns: u64,
}

impl Default for ShmCosts {
    fn default() -> Self {
        ShmCosts { ring_push_ns: 25, ring_pop_ns: 20, doorbell_ns: 700 }
    }
}

#[repr(align(64))]
struct CachePadded<T>(T);

/// A fixed-size 64-byte request descriptor — what actually crosses the
/// app/daemon boundary (payloads stay in the registered pool).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Descriptor {
    /// Logical connection (vQPN) the request targets.
    pub conn: u32,
    /// Operation code (app-defined; e.g. submit vs completion).
    pub opcode: u32,
    /// Payload length in the registered pool.
    pub len: u64,
    /// Payload address in the registered pool.
    pub addr: u64,
    /// Opaque tag echoed back in the completion.
    pub user_tag: u64,
    /// Fig-3 FLAGS bits for this request.
    pub flags: u32,
    /// Completion status (0 = success).
    pub status: u32,
    /// Padding up to the 64-byte descriptor size.
    pub _pad: [u64; 3],
}

impl Descriptor {
    /// Descriptor with zeroed flags/status.
    pub fn new(conn: u32, opcode: u32, len: u64, addr: u64, tag: u64) -> Self {
        Descriptor {
            conn,
            opcode,
            len,
            addr,
            user_tag: tag,
            flags: 0,
            status: 0,
            _pad: [0; 3],
        }
    }
}

/// Single-producer single-consumer lock-free ring.
///
/// Invariants (property-tested in `tests/proptest_invariants.rs`):
/// * every pushed descriptor is popped exactly once, in FIFO order,
/// * push fails (backpressure) iff the ring holds `capacity` items,
/// * no data race: producer writes a slot strictly before publishing via
///   the tail store (Release), consumer reads after the head load (Acquire).
pub struct SpscRing<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    mask: u64,
    head: CachePadded<AtomicU64>, // consumer cursor
    tail: CachePadded<AtomicU64>, // producer cursor
    /// Producer-private cache of `head`: reloaded only when the ring looks
    /// full. Avoids a cross-core cache-line read on every push (§Perf: this
    /// took the cross-thread stream from 0.5 M msg/s to >10 M msg/s).
    head_cache: CachePadded<UnsafeCell<u64>>,
    /// Consumer-private cache of `tail`, symmetric.
    tail_cache: CachePadded<UnsafeCell<u64>>,
}

unsafe impl<T: Send> Send for SpscRing<T> {}
unsafe impl<T: Send> Sync for SpscRing<T> {}

impl<T> SpscRing<T> {
    /// `capacity` must be a power of two.
    pub fn new(capacity: usize) -> Arc<Self> {
        assert!(capacity.is_power_of_two() && capacity >= 2);
        let buf = (0..capacity)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Arc::new(SpscRing {
            buf,
            mask: capacity as u64 - 1,
            head: CachePadded(AtomicU64::new(0)),
            tail: CachePadded(AtomicU64::new(0)),
            head_cache: CachePadded(UnsafeCell::new(0)),
            tail_cache: CachePadded(UnsafeCell::new(0)),
        })
    }

    /// Ring capacity (a power of two).
    pub fn capacity(&self) -> usize {
        self.mask as usize + 1
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        let t = self.tail.0.load(Ordering::Acquire);
        let h = self.head.0.load(Ordering::Acquire);
        (t - h) as usize
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Producer side. Returns the value back on a full ring.
    pub fn push(&self, value: T) -> Result<(), T> {
        let tail = self.tail.0.load(Ordering::Relaxed);
        // fast path: use the cached head (producer-private; no coherence
        // traffic). Only reload the real head when the ring looks full.
        let head_cache = self.head_cache.0.get();
        let mut head = unsafe { *head_cache };
        if tail - head >= self.buf.len() as u64 {
            head = self.head.0.load(Ordering::Acquire);
            unsafe { *head_cache = head };
            if tail - head >= self.buf.len() as u64 {
                return Err(value);
            }
        }
        unsafe {
            (*self.buf[(tail & self.mask) as usize].get()).write(value);
        }
        self.tail.0.store(tail + 1, Ordering::Release);
        Ok(())
    }

    /// Consumer side.
    pub fn pop(&self) -> Option<T> {
        let head = self.head.0.load(Ordering::Relaxed);
        let tail_cache = self.tail_cache.0.get();
        let mut tail = unsafe { *tail_cache };
        if head == tail {
            tail = self.tail.0.load(Ordering::Acquire);
            unsafe { *tail_cache = tail };
            if head == tail {
                return None;
            }
        }
        let value = unsafe { (*self.buf[(head & self.mask) as usize].get()).assume_init_read() };
        self.head.0.store(head + 1, Ordering::Release);
        Some(value)
    }

    /// Consumer: drain up to `max` items into `out` (one cursor publish —
    /// the worker's batch-drain fast path).
    pub fn pop_batch(&self, out: &mut Vec<T>, max: usize) -> usize {
        let head = self.head.0.load(Ordering::Relaxed);
        let tail = self.tail.0.load(Ordering::Acquire);
        unsafe { *self.tail_cache.0.get() = tail };
        let n = ((tail - head) as usize).min(max);
        for i in 0..n {
            out.push(unsafe {
                (*self.buf[((head + i as u64) & self.mask) as usize].get()).assume_init_read()
            });
        }
        if n > 0 {
            self.head.0.store(head + n as u64, Ordering::Release);
        }
        n
    }
}

impl<T> Drop for SpscRing<T> {
    fn drop(&mut self) {
        // drain any unconsumed items so their Drop runs
        while self.pop().is_some() {}
    }
}

/// Doorbell with eventfd counter semantics: the producer `ring()`s when the
/// consumer may be asleep; the consumer `wait()`s when it has spun long
/// enough without work. A read resets the counter, exactly like reading a
/// non-semaphore eventfd.
pub struct Doorbell {
    count: Mutex<u64>,
    rung: Condvar,
}

impl Doorbell {
    /// Create an unrung doorbell. (`io::Result` kept for API compatibility
    /// with the eventfd-backed deployment build, which can fail on fd
    /// exhaustion; this implementation is infallible.)
    pub fn new() -> std::io::Result<Doorbell> {
        Ok(Doorbell { count: Mutex::new(0), rung: Condvar::new() })
    }

    /// Producer-side notify (the 8-byte eventfd write).
    pub fn ring(&self) {
        let mut c = self.count.lock().unwrap();
        *c += 1;
        self.rung.notify_one();
    }

    /// Consumer-side block until rung (reads & resets the counter).
    pub fn wait(&self) {
        let mut c = self.count.lock().unwrap();
        while *c == 0 {
            c = self.rung.wait(c).unwrap();
        }
        *c = 0;
    }

    /// Poll with a timeout in milliseconds; true if rung (counter reset).
    /// A non-positive timeout is a pure non-blocking poll.
    pub fn wait_timeout(&self, timeout_ms: i32) -> bool {
        let mut c = self.count.lock().unwrap();
        if *c > 0 {
            *c = 0;
            return true;
        }
        if timeout_ms <= 0 {
            return false;
        }
        let deadline = Duration::from_millis(timeout_ms as u64);
        let (mut c, _timed_out) = self
            .rung
            .wait_timeout_while(c, deadline, |c| *c == 0)
            .unwrap();
        if *c > 0 {
            *c = 0;
            true
        } else {
            false
        }
    }
}

/// One app↔daemon session channel: submit ring, completion ring, doorbells.
pub struct Channel {
    /// App → daemon request ring.
    pub submit: Arc<SpscRing<Descriptor>>,
    /// Daemon → app completion ring.
    pub complete: Arc<SpscRing<Descriptor>>,
    /// Rung by the app after pushing a request.
    pub submit_bell: Doorbell,
    /// Rung by the daemon after pushing a completion.
    pub complete_bell: Doorbell,
}

impl Channel {
    /// Channel with two `depth`-deep rings and their doorbells.
    pub fn new(depth: usize) -> std::io::Result<Channel> {
        Ok(Channel {
            submit: SpscRing::new(depth),
            complete: SpscRing::new(depth),
            submit_bell: Doorbell::new()?,
            complete_bell: Doorbell::new()?,
        })
    }

    /// Shared-memory footprint of this channel (Fig 7 input).
    pub fn mem_bytes(&self) -> u64 {
        (self.submit.capacity() + self.complete.capacity()) as u64
            * std::mem::size_of::<Descriptor>() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn descriptor_is_64_bytes() {
        assert_eq!(std::mem::size_of::<Descriptor>(), 64);
    }

    #[test]
    fn fifo_single_thread() {
        let r = SpscRing::new(8);
        for i in 0..8u64 {
            r.push(i).unwrap();
        }
        assert!(r.push(99).is_err(), "full ring must reject");
        for i in 0..8u64 {
            assert_eq!(r.pop(), Some(i));
        }
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn pop_batch_drains_in_order() {
        let r = SpscRing::new(16);
        for i in 0..10u64 {
            r.push(i).unwrap();
        }
        let mut out = Vec::new();
        assert_eq!(r.pop_batch(&mut out, 4), 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(r.pop_batch(&mut out, 100), 6);
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn cross_thread_transfer_exact() {
        let r: Arc<SpscRing<u64>> = SpscRing::new(1024);
        let n = 200_000u64;
        let prod = {
            let r = Arc::clone(&r);
            thread::spawn(move || {
                for i in 0..n {
                    loop {
                        if r.push(i).is_ok() {
                            break;
                        }
                        std::hint::spin_loop();
                    }
                }
            })
        };
        let mut expect = 0u64;
        let mut sum = 0u64;
        while expect < n {
            if let Some(v) = r.pop() {
                assert_eq!(v, expect, "FIFO order violated");
                sum = sum.wrapping_add(v);
                expect += 1;
            } else {
                std::hint::spin_loop();
            }
        }
        prod.join().unwrap();
        assert_eq!(sum, n * (n - 1) / 2);
    }

    #[test]
    fn doorbell_wakes_waiter() {
        let c = Channel::new(16).unwrap();
        assert!(!c.submit_bell.wait_timeout(0), "not rung yet");
        c.submit_bell.ring();
        assert!(c.submit_bell.wait_timeout(100));
        assert!(!c.submit_bell.wait_timeout(0), "counter reset after read");
    }

    #[test]
    fn doorbell_cross_thread() {
        let c = std::sync::Arc::new(Channel::new(16).unwrap());
        let c2 = std::sync::Arc::clone(&c);
        let t = thread::spawn(move || {
            c2.submit.push(Descriptor::new(1, 2, 3, 4, 5)).unwrap();
            c2.submit_bell.ring();
        });
        assert!(c.submit_bell.wait_timeout(2000), "doorbell must wake us");
        let d = c.submit.pop().unwrap();
        assert_eq!(d.conn, 1);
        assert_eq!(d.user_tag, 5);
        t.join().unwrap();
    }

    #[test]
    fn channel_memory_accounting() {
        let c = Channel::new(4096).unwrap();
        assert_eq!(c.mem_bytes(), 2 * 4096 * 64);
    }

    #[test]
    fn drop_with_items_is_safe() {
        let r = SpscRing::new(8);
        r.push(String::from("leak-check")).unwrap();
        drop(r); // must drop the unconsumed String
    }
}
