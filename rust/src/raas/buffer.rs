//! Registered buffer pools: slab classes over huge-page memory regions.
//!
//! §1.2's third challenge: per-application buffer fleets waste memory. The
//! daemon owns ONE pool per NIC, registered once with huge pages, carved
//! into power-of-two slab classes; every application's staging and receive
//! buffers come from it. Pool occupancy feeds Fig 7 and the adaptive
//! selector's memory-pressure input.
//!
//! Also implements the send-side staging policy from Frey & Alonso [9]
//! (§2.2): small payloads are **memcpy**'d into the pre-registered pool,
//! large payloads are **memreg**'d in place (register-on-the-fly), because
//! copy cost scales with size while registration cost is ~flat. The
//! crossover is measured by the `--send-staging` ablation.

use crate::fabric::mr::{Access, MemoryRegion};
use crate::fabric::sim::Sim;
use crate::fabric::types::NodeId;

/// One outstanding buffer lease.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lease {
    /// Slab class index.
    pub class: usize,
    /// Slot within the class.
    pub slot: u32,
    /// Address within the pool MR.
    pub addr: u64,
    /// Usable bytes (the slot size, ≥ the requested length).
    pub len: u64,
}

/// A slab class: fixed-size slots with a free list.
#[derive(Debug)]
struct SlabClass {
    slot_bytes: u64,
    base: u64,
    free: Vec<u32>,
    /// One bit per slot, set while leased: O(1) double-free/double-lease
    /// detection (replaces the O(n) `free.contains` scan `release` used
    /// to run under debug asserts).
    leased: Vec<u64>,
    total: u32,
    /// High-water mark of simultaneously leased slots.
    pub hwm: u32,
}

impl SlabClass {
    #[inline]
    fn leased_bit(&self, slot: u32) -> bool {
        self.leased[(slot >> 6) as usize] & (1u64 << (slot & 63)) != 0
    }

    #[inline]
    fn set_leased(&mut self, slot: u32, on: bool) {
        let w = (slot >> 6) as usize;
        let b = 1u64 << (slot & 63);
        if on {
            self.leased[w] |= b;
        } else {
            self.leased[w] &= !b;
        }
    }
}

/// The daemon's registered buffer pool.
#[derive(Debug)]
pub struct BufferPool {
    /// The one huge-page MR backing every slab class.
    pub mr: MemoryRegion,
    classes: Vec<SlabClass>,
    /// size→class table indexed by `len.next_power_of_two()`'s exponent:
    /// `class_by_pow2[k]` is the smallest class whose slots hold `2^k`
    /// bytes. Every lease used to linear-scan the class list; with the
    /// (power-of-two) layouts the daemons actually run, the scan is now
    /// one shift + one index. Non-power-of-two layouts fall back to the
    /// scan so the smallest-fitting-class semantics stay exact.
    class_by_pow2: Vec<Option<usize>>,
    /// True when every class size is a power of two (table usable).
    pow2_layout: bool,
    /// Bytes currently leased out.
    pub leased_bytes: u64,
    /// Lifetime successful leases.
    pub lease_ops: u64,
    /// Lease attempts that found every class empty.
    pub exhausted: u64,
}

/// Slab layout: (slot size, slot count). Sized for thousands of in-flight
/// 64 KB operations plus small-message staging.
pub const DEFAULT_LAYOUT: &[(u64, u32)] = &[
    (4 << 10, 4096),   // 16 MB of 4K slots
    (64 << 10, 2048),  // 128 MB of 64K slots
    (1 << 20, 64),     // 64 MB of 1M slots
];

impl BufferPool {
    /// Carve a pool out of one huge-page MR on `node`.
    pub fn new(sim: &mut Sim, node: NodeId, layout: &[(u64, u32)]) -> Self {
        let total: u64 = layout.iter().map(|(s, c)| s * *c as u64).sum();
        let mr = sim.reg_mr(node, total, Access::REMOTE_RW, true);
        let mut classes = Vec::new();
        let mut base = mr.addr;
        for &(slot_bytes, count) in layout {
            classes.push(SlabClass {
                slot_bytes,
                base,
                free: (0..count).rev().collect(),
                leased: vec![0; count.div_ceil(64) as usize],
                total: count,
                hwm: 0,
            });
            base += slot_bytes * count as u64;
        }
        let pow2_layout = classes.iter().all(|c| c.slot_bytes.is_power_of_two());
        let max_k = classes
            .iter()
            .map(|c| c.slot_bytes.next_power_of_two().trailing_zeros() as usize)
            .max()
            .unwrap_or(0);
        let class_by_pow2 = (0..=max_k)
            .map(|k| classes.iter().position(|c| c.slot_bytes >= 1u64 << k))
            .collect();
        BufferPool {
            mr,
            classes,
            class_by_pow2,
            pow2_layout,
            leased_bytes: 0,
            lease_ops: 0,
            exhausted: 0,
        }
    }

    /// Smallest class that fits `len`: a shift + table index for the
    /// power-of-two layouts the daemons run (every `len` in the bucket
    /// `(2^(k-1), 2^k]` fits exactly the classes that fit `2^k` when all
    /// class sizes are powers of two), a linear scan otherwise.
    fn class_for(&self, len: u64) -> Option<usize> {
        if self.pow2_layout {
            let k = len.max(1).next_power_of_two().trailing_zeros() as usize;
            return *self.class_by_pow2.get(k)?;
        }
        self.classes.iter().position(|c| c.slot_bytes >= len)
    }

    /// Lease a buffer ≥ `len` bytes.
    pub fn lease(&mut self, len: u64) -> Option<Lease> {
        let ci = self.class_for(len)?;
        // try the exact class, then spill upward
        for class in ci..self.classes.len() {
            let c = &mut self.classes[class];
            if let Some(slot) = c.free.pop() {
                debug_assert!(!c.leased_bit(slot), "slot leased while on the free list");
                c.set_leased(slot, true);
                let used = c.total - c.free.len() as u32;
                c.hwm = c.hwm.max(used);
                self.leased_bytes += c.slot_bytes;
                self.lease_ops += 1;
                return Some(Lease {
                    class,
                    slot,
                    addr: c.base + slot as u64 * c.slot_bytes,
                    len: c.slot_bytes,
                });
            }
        }
        self.exhausted += 1;
        None
    }

    /// Return a lease to its slab class. Double frees are caught by the
    /// per-slot leased bitmap in O(1) (the old debug assert scanned the
    /// whole free list).
    pub fn release(&mut self, lease: Lease) {
        let c = &mut self.classes[lease.class];
        debug_assert!(lease.slot < c.total);
        debug_assert!(c.leased_bit(lease.slot), "double free");
        c.set_leased(lease.slot, false);
        c.free.push(lease.slot);
        self.leased_bytes -= c.slot_bytes;
    }

    /// Pool bytes currently leased / total (the selector's `mem` input).
    pub fn pressure(&self) -> f64 {
        self.leased_bytes as f64 / self.mr.len as f64
    }

    /// Memory actually *touched* (high-water): what Fig 7 charges RaaS for,
    /// since untouched pool pages stay unbacked.
    pub fn hwm_bytes(&self) -> u64 {
        self.classes.iter().map(|c| c.hwm as u64 * c.slot_bytes).sum()
    }

    /// Total pool size (the registered MR length).
    pub fn total_bytes(&self) -> u64 {
        self.mr.len
    }
}

/// Send-staging policy [9]: memcpy below the crossover, memreg above.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Staging {
    /// Copy into the pre-registered pool (cost ∝ len).
    Memcpy,
    /// Register the caller's buffer on the fly (flat cost, ~µs).
    Memreg,
}

/// Cost model for the staging decision; values from [9]-era hardware,
/// exposed for the ablation bench.
#[derive(Clone, Copy, Debug)]
pub struct StagingCosts {
    /// Single-core copy bandwidth, bytes per ns (~10 GB/s).
    pub memcpy_bytes_per_ns: f64,
    /// Flat cost of ibv_reg_mr + invalidation, ns.
    pub memreg_ns: u64,
}

impl Default for StagingCosts {
    fn default() -> Self {
        StagingCosts { memcpy_bytes_per_ns: 10.0, memreg_ns: 15_000 }
    }
}

impl StagingCosts {
    /// Cost of copying `len` bytes into the pool.
    pub fn memcpy_ns(&self, len: u64) -> u64 {
        (len as f64 / self.memcpy_bytes_per_ns).ceil() as u64
    }

    /// The size at which registering beats copying.
    pub fn crossover_bytes(&self) -> u64 {
        (self.memreg_ns as f64 * self.memcpy_bytes_per_ns) as u64
    }

    /// Pick the cheaper staging strategy for `len` bytes.
    pub fn choose(&self, len: u64) -> Staging {
        if len < self.crossover_bytes() {
            Staging::Memcpy
        } else {
            Staging::Memreg
        }
    }

    /// Cost of the given staging strategy for `len` bytes.
    pub fn cost_ns(&self, staging: Staging, len: u64) -> u64 {
        match staging {
            Staging::Memcpy => self.memcpy_ns(len),
            Staging::Memreg => self.memreg_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::sim::FabricConfig;

    fn pool() -> (Sim, BufferPool) {
        let mut sim = Sim::new(FabricConfig::default());
        let layout = [(4096u64, 8u32), (65536, 4)];
        let p = BufferPool::new(&mut sim, NodeId(0), &layout);
        (sim, p)
    }

    #[test]
    fn lease_picks_smallest_fitting_class() {
        let (_s, mut p) = pool();
        let a = p.lease(100).unwrap();
        assert_eq!(a.len, 4096);
        let b = p.lease(5000).unwrap();
        assert_eq!(b.len, 65536);
    }

    #[test]
    fn lease_release_roundtrip() {
        let (_s, mut p) = pool();
        let before = p.leased_bytes;
        let l = p.lease(4096).unwrap();
        assert_eq!(p.leased_bytes, before + 4096);
        p.release(l);
        assert_eq!(p.leased_bytes, before);
    }

    #[test]
    fn exhaustion_spills_then_fails() {
        let (_s, mut p) = pool();
        let mut leases = Vec::new();
        for _ in 0..8 {
            leases.push(p.lease(4096).unwrap());
        }
        // 4K class empty: spills into 64K class
        let spilled = p.lease(4096).unwrap();
        assert_eq!(spilled.len, 65536);
        for _ in 0..3 {
            leases.push(p.lease(65536).unwrap());
        }
        assert!(p.lease(4096).is_none(), "everything exhausted");
        assert_eq!(p.exhausted, 1);
    }

    #[test]
    fn distinct_addresses_within_mr() {
        let (_s, mut p) = pool();
        let a = p.lease(4096).unwrap();
        let b = p.lease(4096).unwrap();
        assert_ne!(a.addr, b.addr);
        assert!(p.mr.contains(a.addr, a.len));
        assert!(p.mr.contains(b.addr, b.len));
    }

    #[test]
    fn hwm_tracks_touched_not_total() {
        let (_s, mut p) = pool();
        let l1 = p.lease(4096).unwrap();
        let l2 = p.lease(4096).unwrap();
        p.release(l1);
        p.release(l2);
        assert_eq!(p.hwm_bytes(), 2 * 4096);
        assert!(p.hwm_bytes() < p.total_bytes());
    }

    #[test]
    fn class_table_matches_smallest_fit() {
        // pow2 layout: the shift+index table path
        let (_s, mut p) = pool();
        assert_eq!(p.lease(1).unwrap().len, 4096);
        assert_eq!(p.lease(4096).unwrap().len, 4096);
        assert_eq!(p.lease(4097).unwrap().len, 65536);
        assert_eq!(p.lease(65536).unwrap().len, 65536);
        assert!(p.lease(65537).is_none(), "beyond the largest class");
        // non-pow2 layout: exact smallest-fit via the scan fallback
        let mut sim = Sim::new(FabricConfig::default());
        let mut q = BufferPool::new(&mut sim, NodeId(0), &[(6000, 2), (10000, 2)]);
        assert_eq!(q.lease(5000).unwrap().len, 6000);
        assert_eq!(q.lease(6001).unwrap().len, 10000);
        assert!(q.lease(10001).is_none());
    }

    #[test]
    fn staging_crossover_matches_model() {
        let c = StagingCosts::default();
        // 10 GB/s copy vs 15 µs reg => crossover at 150 KB
        assert_eq!(c.crossover_bytes(), 150_000);
        assert_eq!(c.choose(4096), Staging::Memcpy);
        assert_eq!(c.choose(1 << 20), Staging::Memreg);
        assert!(c.cost_ns(Staging::Memcpy, 4096) < c.cost_ns(Staging::Memreg, 4096));
        assert!(c.cost_ns(Staging::Memcpy, 10 << 20) > c.cost_ns(Staging::Memreg, 10 << 20));
    }
}
