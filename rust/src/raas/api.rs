//! The socket-like RaaS interface (paper Fig 3).
//!
//! ```text
//! int connect(Target* t, int FLAGS);
//! int listen(Target* t, int FLAGS);
//! int accept(int fd, int FLAGS);
//! int send(int fd, void* buf, int len, int FLAGS);
//! int recv(int fd, void* buf, int len, int FLAGS);
//! int recv_zero_copy(int fd, void** buf_addr, int len, int FLAGS);
//! int disconnect(int fd);
//! ```
//!
//! Normal users call `send`/`recv` and let RDMAvisor pick the RDMA
//! operation; knowledgeable users pin one with `FLAGS` (e.g. `RC | WRITE`).
//!
//! The whole socket-like flow against a two-node simulated cluster:
//!
//! ```
//! use rdmavisor::fabric::sim::{FabricConfig, Sim};
//! use rdmavisor::fabric::types::NodeId;
//! use rdmavisor::raas::api::{Flags, Target};
//! use rdmavisor::raas::daemon::{connect_target, Daemon, DaemonConfig, Delivery};
//! use rdmavisor::raas::transport::HostLoad;
//!
//! let mut sim = Sim::new(FabricConfig::default());
//! let mut daemons: Vec<Daemon> = (0..2)
//!     .map(|i| Daemon::start(&mut sim, NodeId(i), DaemonConfig::default()))
//!     .collect();
//!
//! // server side: listen(Target, FLAGS) binds a port, accept() pops conns
//! let server_app = daemons[1].register_app();
//! daemons[1].listen(server_app, 7000);
//!
//! // client side: connect(Target, FLAGS) — the IPv4 host byte names node 1
//! let client_app = daemons[0].register_app();
//! let conn = connect_target(
//!     &mut sim, &mut daemons, 0, client_app,
//!     Target::Ipv4([10, 0, 0, 1], 7000), 7000,
//! ).unwrap();
//! let server_conn = daemons[1].accept(server_app, 7000).unwrap();
//!
//! // send(fd, buf, 256, 0): FLAGS=0 lets the daemon pick the verb —
//! // 256 B is small, so it rides two-sided SEND over the shared RC QP
//! daemons[0]
//!     .send(&mut sim, conn, 256, Flags::default(), 1, HostLoad::default())
//!     .unwrap();
//!
//! // drive the simulated fabric until the timeline drains
//! for _ in 0..100_000 {
//!     for d in daemons.iter_mut() { d.pump(&mut sim); }
//!     if sim.step().is_none() {
//!         for d in daemons.iter_mut() { d.pump(&mut sim); }
//!         if sim.pending_events() == 0 { break; }
//!     }
//! }
//!
//! // recv(fd, ...) on the server: the message arrived on its conn
//! let delivery = daemons[1].recv(&mut sim, server_app).unwrap();
//! assert!(matches!(delivery, Delivery::Message { len: 256, .. }));
//!
//! // disconnect(fd): the vQPN is quarantined and the shared RC QP is
//! // parked for reuse by the next tenant targeting the same node (§12)
//! rdmavisor::raas::daemon::disconnect_via(&mut sim, &mut daemons, 0, conn).unwrap();
//! # let _ = server_conn;
//! ```

use crate::fabric::types::{NodeId, QpTransport, Verb};

/// The FLAGS bitset of Fig 3. `0` (`Flags::default()`) means "let the
/// daemon decide" — RC transport, verb chosen adaptively.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Flags(pub u32);

impl Flags {
    /// Pin the Reliable Connection transport.
    pub const RC: Flags = Flags(1 << 0);
    /// Pin the Unreliable Connection transport.
    pub const UC: Flags = Flags(1 << 1);
    /// Pin the Unreliable Datagram transport.
    pub const UD: Flags = Flags(1 << 2);
    /// Pin the two-sided SEND verb.
    pub const SEND: Flags = Flags(1 << 3);
    /// Pin the one-sided WRITE verb.
    pub const WRITE: Flags = Flags(1 << 4);
    /// Pin the one-sided READ verb.
    pub const READ: Flags = Flags(1 << 5);
    /// recv-side: deliver in place from the registered pool (no copy-out).
    pub const ZERO_COPY: Flags = Flags(1 << 6);
    /// send-side: block until remotely acknowledged (default is async).
    pub const SYNC: Flags = Flags(1 << 7);

    /// Are all of `other`'s bits set?
    #[inline]
    pub fn contains(self, other: Flags) -> bool {
        self.0 & other.0 == other.0
    }

    /// Explicit transport, if the user pinned one.
    pub fn transport(self) -> Option<QpTransport> {
        if self.contains(Flags::RC) {
            Some(QpTransport::Rc)
        } else if self.contains(Flags::UC) {
            Some(QpTransport::Uc)
        } else if self.contains(Flags::UD) {
            Some(QpTransport::Ud)
        } else {
            None
        }
    }

    /// Explicit verb, if the user pinned one.
    pub fn verb(self) -> Option<Verb> {
        if self.contains(Flags::SEND) {
            Some(Verb::Send)
        } else if self.contains(Flags::WRITE) {
            Some(Verb::Write)
        } else if self.contains(Flags::READ) {
            Some(Verb::Read)
        } else {
            None
        }
    }
}

impl std::ops::BitOr for Flags {
    type Output = Flags;
    fn bitor(self, rhs: Flags) -> Flags {
        Flags(self.0 | rhs.0)
    }
}

/// Unified host addressing (§2.2): IPv4, IPv6, or native RDMA GID/LID.
/// In the simulated cluster every form resolves to a [`NodeId`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Target {
    /// IPv4 address + port.
    Ipv4([u8; 4], u16),
    /// IPv6 address + port.
    Ipv6([u16; 8], u16),
    /// RoCE global id (we carry just the low 64 bits in the simulator).
    Gid(u64),
    /// InfiniBand local id.
    Lid(u16),
    /// Direct simulator node reference.
    Node(NodeId),
}

impl Target {
    /// Resolve to a simulator node. The convention used throughout the
    /// reproduction: the host part of any address form is the node index.
    pub fn resolve(&self) -> NodeId {
        match self {
            Target::Ipv4(octets, _) => NodeId(octets[3] as u32),
            Target::Ipv6(groups, _) => NodeId(groups[7] as u32),
            Target::Gid(g) => NodeId((*g & 0xFFFF_FFFF) as u32),
            Target::Lid(l) => NodeId(*l as u32),
            Target::Node(n) => *n,
        }
    }
}

/// Errors surfaced by the RaaS API.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RaasError {
    /// The vQPN does not name a live connection.
    UnknownConnection,
    /// The connection was closed by either side.
    ConnectionClosed,
    /// User pinned an (op, transport) combo Table 1 forbids.
    UnsupportedCombination(QpTransport, Verb),
    /// Message too large for the pinned transport.
    TooLong { len: u64, max: u64 },
    /// No registered buffer space available.
    PoolExhausted,
    /// The window token does not name a live registered window (wrong
    /// slot, stale generation, or the window was released/reclaimed).
    StaleWindow,
    /// Nothing to receive (non-blocking recv).
    WouldBlock,
    /// An error surfaced by the fabric layer.
    Fabric(String),
}

impl std::fmt::Display for RaasError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RaasError::UnknownConnection => write!(f, "unknown connection"),
            RaasError::ConnectionClosed => write!(f, "connection closed"),
            RaasError::UnsupportedCombination(t, v) => {
                write!(f, "{v} not supported on {t} (Table 1)")
            }
            RaasError::TooLong { len, max } => write!(f, "len {len} > max {max}"),
            RaasError::PoolExhausted => write!(f, "registered buffer pool exhausted"),
            RaasError::StaleWindow => write!(f, "stale or released window token"),
            RaasError::WouldBlock => write!(f, "would block"),
            RaasError::Fabric(s) => write!(f, "fabric: {s}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_compose_like_the_paper_example() {
        // the paper's example: RC|WRITE pins reliable-connected RDMA WRITE
        let f = Flags::RC | Flags::WRITE;
        assert_eq!(f.transport(), Some(QpTransport::Rc));
        assert_eq!(f.verb(), Some(Verb::Write));
        assert!(!f.contains(Flags::ZERO_COPY));
    }

    #[test]
    fn default_flags_delegate_to_daemon() {
        let f = Flags::default();
        assert_eq!(f.transport(), None);
        assert_eq!(f.verb(), None);
    }

    #[test]
    fn targets_resolve_uniformly() {
        assert_eq!(Target::Ipv4([10, 0, 0, 2], 7000).resolve(), NodeId(2));
        assert_eq!(Target::Lid(3).resolve(), NodeId(3));
        assert_eq!(Target::Gid(0x1).resolve(), NodeId(1));
        assert_eq!(Target::Node(NodeId(0)).resolve(), NodeId(0));
        let mut groups = [0u16; 8];
        groups[7] = 2;
        assert_eq!(Target::Ipv6(groups, 1).resolve(), NodeId(2));
    }

    #[test]
    fn flag_precedence_order() {
        // if multiple transports are set (user error), highest priority wins
        let f = Flags::RC | Flags::UD;
        assert_eq!(f.transport(), Some(QpTransport::Rc));
    }
}
