//! The socket-like RaaS interface (paper Fig 3).
//!
//! ```text
//! int connect(Target* t, int FLAGS);
//! int listen(Target* t, int FLAGS);
//! int accept(int fd, int FLAGS);
//! int send(int fd, void* buf, int len, int FLAGS);
//! int recv(int fd, void* buf, int len, int FLAGS);
//! int recv_zero_copy(int fd, void** buf_addr, int len, int FLAGS);
//! ```
//!
//! Normal users call `send`/`recv` and let RDMAvisor pick the RDMA
//! operation; knowledgeable users pin one with `FLAGS` (e.g. `RC | WRITE`).

use crate::fabric::types::{NodeId, QpTransport, Verb};

/// The FLAGS bitset of Fig 3. `0` (`Flags::default()`) means "let the
/// daemon decide" — RC transport, verb chosen adaptively.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Flags(pub u32);

impl Flags {
    pub const RC: Flags = Flags(1 << 0);
    pub const UC: Flags = Flags(1 << 1);
    pub const UD: Flags = Flags(1 << 2);
    pub const SEND: Flags = Flags(1 << 3);
    pub const WRITE: Flags = Flags(1 << 4);
    pub const READ: Flags = Flags(1 << 5);
    /// recv-side: deliver in place from the registered pool (no copy-out).
    pub const ZERO_COPY: Flags = Flags(1 << 6);
    /// send-side: block until remotely acknowledged (default is async).
    pub const SYNC: Flags = Flags(1 << 7);

    #[inline]
    pub fn contains(self, other: Flags) -> bool {
        self.0 & other.0 == other.0
    }

    /// Explicit transport, if the user pinned one.
    pub fn transport(self) -> Option<QpTransport> {
        if self.contains(Flags::RC) {
            Some(QpTransport::Rc)
        } else if self.contains(Flags::UC) {
            Some(QpTransport::Uc)
        } else if self.contains(Flags::UD) {
            Some(QpTransport::Ud)
        } else {
            None
        }
    }

    /// Explicit verb, if the user pinned one.
    pub fn verb(self) -> Option<Verb> {
        if self.contains(Flags::SEND) {
            Some(Verb::Send)
        } else if self.contains(Flags::WRITE) {
            Some(Verb::Write)
        } else if self.contains(Flags::READ) {
            Some(Verb::Read)
        } else {
            None
        }
    }
}

impl std::ops::BitOr for Flags {
    type Output = Flags;
    fn bitor(self, rhs: Flags) -> Flags {
        Flags(self.0 | rhs.0)
    }
}

/// Unified host addressing (§2.2): IPv4, IPv6, or native RDMA GID/LID.
/// In the simulated cluster every form resolves to a [`NodeId`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Target {
    Ipv4([u8; 4], u16),
    Ipv6([u16; 8], u16),
    /// RoCE global id (we carry just the low 64 bits in the simulator).
    Gid(u64),
    /// InfiniBand local id.
    Lid(u16),
    /// Direct simulator node reference.
    Node(NodeId),
}

impl Target {
    /// Resolve to a simulator node. The convention used throughout the
    /// reproduction: the host part of any address form is the node index.
    pub fn resolve(&self) -> NodeId {
        match self {
            Target::Ipv4(octets, _) => NodeId(octets[3] as u32),
            Target::Ipv6(groups, _) => NodeId(groups[7] as u32),
            Target::Gid(g) => NodeId((*g & 0xFFFF_FFFF) as u32),
            Target::Lid(l) => NodeId(*l as u32),
            Target::Node(n) => *n,
        }
    }
}

/// Errors surfaced by the RaaS API.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RaasError {
    UnknownConnection,
    ConnectionClosed,
    /// User pinned an (op, transport) combo Table 1 forbids.
    UnsupportedCombination(QpTransport, Verb),
    /// Message too large for the pinned transport.
    TooLong { len: u64, max: u64 },
    /// No registered buffer space available.
    PoolExhausted,
    /// Nothing to receive (non-blocking recv).
    WouldBlock,
    Fabric(String),
}

impl std::fmt::Display for RaasError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RaasError::UnknownConnection => write!(f, "unknown connection"),
            RaasError::ConnectionClosed => write!(f, "connection closed"),
            RaasError::UnsupportedCombination(t, v) => {
                write!(f, "{v} not supported on {t} (Table 1)")
            }
            RaasError::TooLong { len, max } => write!(f, "len {len} > max {max}"),
            RaasError::PoolExhausted => write!(f, "registered buffer pool exhausted"),
            RaasError::WouldBlock => write!(f, "would block"),
            RaasError::Fabric(s) => write!(f, "fabric: {s}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_compose_like_the_paper_example() {
        // the paper's example: RC|WRITE pins reliable-connected RDMA WRITE
        let f = Flags::RC | Flags::WRITE;
        assert_eq!(f.transport(), Some(QpTransport::Rc));
        assert_eq!(f.verb(), Some(Verb::Write));
        assert!(!f.contains(Flags::ZERO_COPY));
    }

    #[test]
    fn default_flags_delegate_to_daemon() {
        let f = Flags::default();
        assert_eq!(f.transport(), None);
        assert_eq!(f.verb(), None);
    }

    #[test]
    fn targets_resolve_uniformly() {
        assert_eq!(Target::Ipv4([10, 0, 0, 2], 7000).resolve(), NodeId(2));
        assert_eq!(Target::Lid(3).resolve(), NodeId(3));
        assert_eq!(Target::Gid(0x1).resolve(), NodeId(1));
        assert_eq!(Target::Node(NodeId(0)).resolve(), NodeId(0));
        let mut groups = [0u16; 8];
        groups[7] = 2;
        assert_eq!(Target::Ipv6(groups, 1).resolve(), NodeId(2));
    }

    #[test]
    fn flag_precedence_order() {
        // if multiple transports are set (user error), highest priority wins
        let f = Flags::RC | Flags::UD;
        assert_eq!(f.transport(), Some(QpTransport::Rc));
    }
}
