//! Adaptive RC ↔ UD transport migration (the abstract's headline claim).
//!
//! RC is the right default — ordered, acked, SRQ-attachable — but every RC
//! connection pins a QP context in the NIC's ICM cache, and past a few
//! hundred *destinations* the context working set thrashes (Fig 5's
//! mechanism, [`crate::fabric::cache`]). UD has the opposite shape: one
//! host-wide QP addresses every peer, so its context cost is O(1) in the
//! cluster size — at the price of SEND-only verbs and an MTU message cap.
//!
//! The [`TransportManager`] holds a per-destination state machine
//!
//! ```text
//!            pressure ≥ enter_ud
//!    Rc ───────────────────────────▶ DrainingToUd
//!     ▲                                   │ in-flight RC WRs = 0
//!     │ pressure ≤ exit_ud                │ (or the drain deadline)
//!     └────────────────────────────────  Ud
//! ```
//!
//! driven by two telemetry signals the daemon samples each pump:
//!
//! * **active-QP-count pressure** — destinations this daemon talks to
//!   against the share of the ICM cache budgeted to RC contexts. Within
//!   the budget every destination keeps RC; once the working set
//!   overflows it, the set migrates (each destination via its own state
//!   machine — see [`TransportManager::pressure`] for why the signal is
//!   host-global rather than per-rank). The signal is *structural* (it
//!   counts destinations, not QPs currently in RC), so fully migrating
//!   does not collapse the signal and re-trigger the reverse flip — that
//!   is what makes the hysteresis band flap-free.
//! * **ICM hit rate** — observed thrash. When the windowed hit rate drops
//!   below [`MigrationConfig::thrash_hit_rate`] the pressure is doubled,
//!   migrating harder than the structural estimate alone would (the
//!   estimate cannot see MTT/CQC competition); the boost latches and only
//!   releases well above the threshold *while everything runs on RC* —
//!   see [`TransportManager::observe_hit_rate`] for why releasing on the
//!   post-migration recovery would limit-cycle.
//!
//! Migration is per destination and **lossless**: a destination leaving RC
//! first drains — new sends stay on RC (preserving per-connection message
//! order across the transition) while in-flight RC WRs run to completion
//! on the shared QP — and only once the last completes does traffic flip
//! to UD. Sustained pipelined traffic could hold the in-flight count above
//! zero forever, so the drain is bounded by
//! [`MigrationConfig::drain_max_ns`]; past the deadline the flip is forced
//! and ordering across it becomes best-effort (datagram semantics — no
//! completion is ever lost). Because UD is MTU-capped, the daemon
//! fragments large messages with a per-vQPN fragment header packed into
//! `imm_data` ([`pack_ud_imm`]: vqpn:20 | msg-tag:6 | seq:5 | last:1)
//! and the peer's Poller reassembles ([`Reassembler`]) before delivery;
//! under an injected fault plan lost fragments surface as reassembly
//! gap-discards, orphans, and fragment-timeout expiries.
//!
//! User pins always win: `Flags::RC` keeps a destination on RC at any
//! pressure, `Flags::UD` rides datagrams even when the cache is cold, and
//! explicit one-sided `read`/`write` calls stay on RC (Table 1: UD cannot
//! carry them).

use crate::fabric::time::Ns;
use crate::fabric::types::IdMap;

use super::vqpn::Vqpn;

/// Bits of `imm_data` carrying the destination vQPN of a UD fragment.
pub const UD_IMM_VQPN_BITS: u32 = 20;
/// Bits of `imm_data` carrying the message id (mod-64 tag). Without it,
/// a lost tail + lost head could splice fragments of two *different*
/// messages into one "successful" reassembly whenever the sequence
/// numbers happened to line up — a silently corrupted delivery. The tag
/// makes adjacent-message aliasing detectable (a 64-message wraparound
/// coincidence with an uninterrupted stale partial is the only residue).
pub const UD_IMM_MSG_BITS: u32 = 6;
/// Bits of `imm_data` carrying the fragment sequence number.
pub const UD_IMM_SEQ_BITS: u32 = 5;
/// Largest vQPN addressable through the UD fragment header.
pub const UD_MAX_VQPN: u32 = (1 << UD_IMM_VQPN_BITS) - 1;
/// Message-id modulus of the UD fragment header.
pub const UD_MSG_MOD: u32 = 1 << UD_IMM_MSG_BITS;
/// Largest fragment count of one UD-migrated message.
pub const UD_MAX_FRAGS: u64 = 1 << UD_IMM_SEQ_BITS;

/// Largest message the UD segmentation layer can carry at `mtu`
/// (32 fragments — 128 KB at a 4 KB MTU; larger unpinned messages keep
/// the connected path, which carries up to 1 GB).
pub fn ud_max_msg_bytes(mtu: u64) -> u64 {
    UD_MAX_FRAGS * mtu
}

/// Pack the UD fragment header into a 4-byte immediate: destination vQPN
/// in the low [`UD_IMM_VQPN_BITS`], the mod-64 message id above it, the
/// fragment sequence above that, last-flag in the top bit. Panics
/// (debug) if a field overflows its lane.
#[inline]
pub fn pack_ud_imm(vqpn: Vqpn, msg: u8, seq: u16, last: bool) -> u32 {
    debug_assert!(vqpn.0 <= UD_MAX_VQPN, "vQPN {} exceeds UD header lane", vqpn.0);
    debug_assert!((msg as u32) < UD_MSG_MOD, "message id {msg} exceeds header lane");
    debug_assert!((seq as u64) < UD_MAX_FRAGS, "fragment seq {seq} exceeds header lane");
    vqpn.0
        | ((msg as u32) << UD_IMM_VQPN_BITS)
        | ((seq as u32) << (UD_IMM_VQPN_BITS + UD_IMM_MSG_BITS))
        | ((last as u32) << 31)
}

/// Unpack a UD fragment header: (destination vQPN, message id, fragment
/// seq, last?).
#[inline]
pub fn unpack_ud_imm(imm: u32) -> (Vqpn, u8, u16, bool) {
    let vqpn = Vqpn(imm & UD_MAX_VQPN);
    let msg = ((imm >> UD_IMM_VQPN_BITS) & (UD_MSG_MOD - 1)) as u8;
    let seq = ((imm >> (UD_IMM_VQPN_BITS + UD_IMM_MSG_BITS)) & (UD_MAX_FRAGS as u32 - 1)) as u16;
    let last = imm >> 31 == 1;
    (vqpn, msg, seq, last)
}

/// Where one destination's unpinned two-sided traffic currently rides.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DestState {
    /// Connected mode: the shared RC QP to this destination.
    Rc,
    /// Migration to UD decided; new sends stay on RC (order-preserving)
    /// while in-flight RC WRs drain. Promotes to [`DestState::Ud`] when
    /// the last completes or the drain deadline passes.
    DrainingToUd,
    /// Datagram mode: the host-wide UD QP.
    Ud,
}

/// Tunables of the migration policy.
#[derive(Clone, Copy, Debug)]
pub struct MigrationConfig {
    /// Master switch (`false` = the `--rc-only` ablation).
    pub enabled: bool,
    /// Fraction of the NIC's ICM cache budgeted to RC QP contexts (the
    /// rest is left for CQ contexts and MTT blocks).
    pub rc_share: f64,
    /// A destination migrates to UD when its pressure reaches this.
    pub enter_ud: f64,
    /// A UD destination returns to RC when its pressure falls to this.
    /// Must be below [`MigrationConfig::enter_ud`]; the gap is the
    /// hysteresis band in which no transition fires.
    pub exit_ud: f64,
    /// Windowed ICM hit rate below which observed thrash doubles the
    /// structural pressure estimate.
    pub thrash_hit_rate: f64,
    /// Virtual-time cadence (ns) at which the daemon samples telemetry and
    /// re-evaluates destination states.
    pub sample_ns: u64,
    /// Longest a destination may sit in [`DestState::DrainingToUd`]
    /// before the flip is forced. While draining, new sends stay on RC to
    /// preserve per-connection ordering across the transition — but under
    /// sustained closed-loop traffic the in-flight count may never reach
    /// zero, so past this deadline the destination flips anyway (ordering
    /// across the flip becomes best-effort, which is datagram semantics;
    /// no completion is ever lost).
    pub drain_max_ns: u64,
}

impl Default for MigrationConfig {
    fn default() -> Self {
        MigrationConfig {
            enabled: true,
            rc_share: 0.5,
            enter_ud: 1.0,
            exit_ud: 0.7,
            thrash_hit_rate: 0.5,
            sample_ns: 100_000,
            drain_max_ns: 1_000_000,
        }
    }
}

/// The pure hysteresis decision: next state for a destination at
/// `pressure`, given its current state. Monotone in `pressure` (higher
/// pressure never moves *toward* RC) and identity inside the
/// `(exit_ud, enter_ud)` band — both properties are pinned by
/// `tests/proptest_invariants.rs`.
pub fn decide(state: DestState, pressure: f64, cfg: &MigrationConfig) -> DestState {
    match state {
        DestState::Rc if pressure >= cfg.enter_ud => DestState::DrainingToUd,
        DestState::DrainingToUd if pressure <= cfg.exit_ud => DestState::Rc,
        DestState::Ud if pressure <= cfg.exit_ud => DestState::Rc,
        s => s,
    }
}

/// Per-destination migration state.
#[derive(Clone, Copy, Debug)]
pub struct DestEntry {
    /// Current transport state.
    pub state: DestState,
    /// First-use registration order (diagnostics; the count of registered
    /// destinations is the pressure signal).
    pub rank: u32,
    /// RC WRs submitted to this destination and not yet completed.
    pub inflight_rc: u64,
    /// When the current drain started (None outside
    /// [`DestState::DrainingToUd`]).
    pub draining_since: Option<Ns>,
}

/// The daemon's per-destination transport ledger and migration engine.
#[derive(Clone, Debug, Default)]
pub struct TransportManager {
    /// Policy knobs this manager runs with.
    pub cfg: MigrationConfig,
    /// Per-destination entries, node-id-indexed ([`IdMap`]): the per-op
    /// drain bookkeeping (`on_rc_submitted`/`on_rc_completed`) is a
    /// bounds check, not a tree walk; iteration stays ascending-id like
    /// the `BTreeMap` this replaced, so evaluation order is unchanged.
    dests: IdMap<DestEntry>,
    next_rank: u32,
    /// Latched observed-thrash flag (second hysteresis band).
    thrash: bool,
    /// Lifetime RC→UD migrations initiated.
    pub to_ud: u64,
    /// Lifetime UD→RC returns.
    pub to_rc: u64,
}

impl TransportManager {
    /// Manager with the given policy and no known destinations.
    pub fn new(cfg: MigrationConfig) -> Self {
        TransportManager {
            cfg,
            dests: IdMap::new(),
            next_rank: 0,
            thrash: false,
            to_ud: 0,
            to_rc: 0,
        }
    }

    /// Register a destination at first connect (idempotent). New
    /// destinations start on RC — the optimistic default — and migrate on
    /// the next [`TransportManager::evaluate`] if they land past the
    /// budget.
    pub fn register_dest(&mut self, remote: u32) {
        if self.dests.get(remote).is_none() {
            let rank = self.next_rank;
            self.next_rank += 1;
            self.dests.insert(
                remote,
                DestEntry { state: DestState::Rc, rank, inflight_rc: 0, draining_since: None },
            );
        }
    }

    /// Drop a destination whose last connection closed (tenant churn).
    /// The entry leaves the pressure signal immediately; a later
    /// reconnect re-registers it fresh on RC. No-op for unknown remotes,
    /// so unregister/register interleavings are always safe.
    pub fn unregister_dest(&mut self, remote: u32) {
        self.dests.remove(remote);
    }

    /// The structural working-set pressure against an ICM cache of
    /// `capacity` entries: `n` destinations need `n` resident RC
    /// contexts, which overflows the budget exactly when
    /// `(n - 1) / budget ≥ 1` — so at `enter_ud = 1.0` up to `budget`
    /// destinations stay connected and the knee sits one past it.
    /// Observed thrash doubles the estimate.
    ///
    /// The signal is deliberately host-global rather than per-rank: the
    /// NIC engine arbitrates issue slots per QP, so keeping a "head" of
    /// RC QPs hot while a tail shares one UD QP would hand the UD side
    /// ~1/(RC QPs) of the issue bandwidth and starve most connections.
    /// Migrating the whole working set once it overflows keeps
    /// per-connection fairness through the UD SQ's FIFO. Migration is
    /// still executed per destination: each drains independently and
    /// user-pinned traffic keeps individual destinations connected.
    pub fn pressure(&self, capacity: usize) -> f64 {
        let budget = (capacity as f64 * self.cfg.rc_share).max(1.0);
        let boost = if self.thrash { 2.0 } else { 1.0 };
        // live destinations, not lifetime registrations: under tenant
        // churn departed destinations unregister, and counting ghosts
        // would ratchet the pressure signal upward forever
        self.dests.len().saturating_sub(1) as f64 * boost / budget
    }

    /// Feed the windowed ICM hit rate (None when the window had too few
    /// lookups to be meaningful). Latches the thrash boost below
    /// [`MigrationConfig::thrash_hit_rate`]; releases it only once the
    /// rate recovers well above the threshold **and every destination is
    /// back on RC**. A recovered hit rate while destinations ride UD is
    /// the *expected outcome* of migrating, not evidence that RC is safe
    /// again — releasing on it would un-migrate the set, re-create the
    /// thrash, and limit-cycle through the drain machinery. So once the
    /// boost migrates a working set, it stays migrated until the
    /// *structural* pressure shrinks enough for the boosted value to pass
    /// `exit_ud` (destinations closing), which is a real change in load.
    pub fn observe_hit_rate(&mut self, hit_rate: Option<f64>) {
        if let Some(r) = hit_rate {
            if r < self.cfg.thrash_hit_rate {
                self.thrash = true;
            } else if r > self.cfg.thrash_hit_rate + 0.25
                && self.dests.iter().all(|(_, e)| e.state == DestState::Rc)
            {
                self.thrash = false;
            }
        }
    }

    /// Re-run [`decide`] for every destination against the current
    /// host-global pressure at virtual time `now`. `capacity` is the
    /// NIC's ICM cache entry count. Draining destinations promote to UD
    /// when their in-flight RC count reaches zero or their drain exceeds
    /// [`MigrationConfig::drain_max_ns`] (bounded wait — see that knob).
    pub fn evaluate(&mut self, capacity: usize, now: Ns) {
        if !self.cfg.enabled {
            return;
        }
        let pressure = self.pressure(capacity);
        for (_, e) in self.dests.iter_mut() {
            let next = decide(e.state, pressure, &self.cfg);
            if next != e.state {
                match (e.state, next) {
                    (DestState::Rc, DestState::DrainingToUd) => {
                        self.to_ud += 1;
                        e.draining_since = Some(now);
                    }
                    (DestState::Ud, DestState::Rc) => self.to_rc += 1,
                    // a cancelled drain is not a completed migration
                    (DestState::DrainingToUd, DestState::Rc) => {
                        self.to_ud -= 1;
                        e.draining_since = None;
                    }
                    _ => {}
                }
                e.state = next;
            }
            if e.state == DestState::DrainingToUd {
                let expired = e
                    .draining_since
                    .map(|t| now.saturating_sub(t).0 >= self.cfg.drain_max_ns)
                    .unwrap_or(true);
                // an idle destination needs no drain phase; a stuck one is
                // force-flipped at the deadline
                if e.inflight_rc == 0 || expired {
                    e.state = DestState::Ud;
                    e.draining_since = None;
                }
            }
        }
    }

    /// The transport state governing new unpinned traffic to `remote`.
    /// Unknown destinations (or a disabled manager) report RC.
    pub fn state_of(&self, remote: u32) -> DestState {
        if !self.cfg.enabled {
            return DestState::Rc;
        }
        self.dests.get(remote).map(|e| e.state).unwrap_or(DestState::Rc)
    }

    /// Account an RC WR submitted toward `remote` (drain bookkeeping).
    pub fn on_rc_submitted(&mut self, remote: u32) {
        if let Some(e) = self.dests.get_mut(remote) {
            e.inflight_rc += 1;
        }
    }

    /// Account an RC completion from `remote`; promotes a fully drained
    /// destination to UD.
    pub fn on_rc_completed(&mut self, remote: u32) {
        if let Some(e) = self.dests.get_mut(remote) {
            e.inflight_rc = e.inflight_rc.saturating_sub(1);
            if e.state == DestState::DrainingToUd && e.inflight_rc == 0 {
                e.state = DestState::Ud;
                e.draining_since = None;
            }
        }
    }

    /// Destinations currently in each state: (rc, draining, ud).
    pub fn state_counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for (_, e) in self.dests.iter() {
            match e.state {
                DestState::Rc => c.0 += 1,
                DestState::DrainingToUd => c.1 += 1,
                DestState::Ud => c.2 += 1,
            }
        }
        c
    }

    /// Known destinations.
    pub fn dest_count(&self) -> usize {
        self.dests.len()
    }

    /// Is the thrash boost currently latched?
    pub fn thrash_latched(&self) -> bool {
        self.thrash
    }

    /// Inspect one destination's entry (tests/diagnostics).
    pub fn dest(&self, remote: u32) -> Option<&DestEntry> {
        self.dests.get(remote)
    }
}

/// In-flight reassembly of one fragmented UD message.
#[derive(Clone, Copy, Debug)]
struct Partial {
    /// The mod-64 message tag every fragment must match.
    msg_id: u8,
    next_seq: u16,
    bytes: u64,
    /// When the latest fragment arrived (virtual time) — the fragment
    /// timeout's clock.
    last_frag_at: Ns,
}

/// Poller-side reassembly of fragmented UD messages, keyed by the local
/// vQPN the fragments address. Fragments of one message arrive in order
/// on the lossless simulated fabric; under an injected fault plan
/// fragments can be dropped, delayed out of order, or never followed by
/// their tail. A sequence gap discards the partial message — datagram
/// semantics — and a partial whose fragments stop arriving is reclaimed
/// by [`Reassembler::expire_stale`] (the Poller calls it every pump), so
/// a dropped LAST fragment cannot pin reassembly state forever.
#[derive(Clone, Debug, Default)]
pub struct Reassembler {
    /// Open partials, vQPN-indexed ([`IdMap`]): the per-fragment accept
    /// path on the Poller is an array index, and `expire_stale` sweeps
    /// in ascending-vQPN order (deterministic by construction, not by
    /// argument).
    partial: IdMap<Partial>,
    /// Messages fully reassembled and delivered.
    pub completed: u64,
    /// Partial messages discarded on a sequence gap or restart.
    pub dropped: u64,
    /// Fragments with no partial in progress (the message's FIRST
    /// fragment was lost, so every later fragment arrives orphaned —
    /// an N-fragment message lost this way shows up as N−1 orphans, not
    /// as a `dropped` increment).
    pub orphan_fragments: u64,
    /// Partial messages reclaimed by the fragment timeout (tail lost and
    /// the connection went quiet — no later fragment ever exposed the
    /// gap).
    pub expired: u64,
}

impl Reassembler {
    /// Fresh reassembler with zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Accept one fragment at virtual time `now`; returns the total
    /// message length when the fragment completes its message. A
    /// fragment whose message tag does not match the open partial kills
    /// the partial (gap semantics) — the tag is what stops a lost tail +
    /// lost head from splicing two messages together.
    pub fn accept(
        &mut self,
        vqpn: Vqpn,
        msg: u8,
        seq: u16,
        last: bool,
        len: u64,
        now: Ns,
    ) -> Option<u64> {
        if seq == 0 {
            if self.partial.remove(vqpn.0).is_some() {
                // a new message started before the previous one finished
                // (sender restart, or the previous tail was lost)
                self.dropped += 1;
            }
            if last {
                self.completed += 1;
                return Some(len);
            }
            self.partial
                .insert(vqpn.0, Partial { msg_id: msg, next_seq: 1, bytes: len, last_frag_at: now });
            return None;
        }
        match self.partial.get_mut(vqpn.0) {
            Some(p) if p.msg_id == msg && p.next_seq == seq => {
                p.bytes += len;
                p.last_frag_at = now;
                if last {
                    let total = p.bytes;
                    self.partial.remove(vqpn.0);
                    self.completed += 1;
                    Some(total)
                } else {
                    p.next_seq += 1;
                    None
                }
            }
            _ => {
                // gap, tag mismatch, or orphan fragment: drop any partial
                if self.partial.remove(vqpn.0).is_some() {
                    self.dropped += 1;
                } else {
                    self.orphan_fragments += 1;
                }
                None
            }
        }
    }

    /// Reclaim partials whose latest fragment is older than `timeout`
    /// (0 disables). Returns how many were expired. The sweep runs in
    /// ascending-vQPN order (and is pure bookkeeping anyway — it touches
    /// no simulator state), so nothing about the backing store can leak
    /// into the event timeline.
    pub fn expire_stale(&mut self, now: Ns, timeout: Ns) -> u64 {
        if timeout.0 == 0 || self.partial.is_empty() {
            return 0;
        }
        let expired =
            self.partial.retain(|_, p| now.saturating_sub(p.last_frag_at) < timeout) as u64;
        self.expired += expired;
        expired
    }

    /// Messages currently mid-reassembly.
    pub fn in_progress(&self) -> usize {
        self.partial.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MigrationConfig {
        MigrationConfig::default()
    }

    #[test]
    fn imm_header_roundtrips() {
        for &(v, m, s, l) in &[
            (0u32, 0u8, 0u16, true),
            (7, 3, 3, false),
            (UD_MAX_VQPN, 63, 31, true),
        ] {
            let imm = pack_ud_imm(Vqpn(v), m, s, l);
            assert_eq!(unpack_ud_imm(imm), (Vqpn(v), m, s, l));
        }
    }

    #[test]
    fn stale_partial_never_splices_onto_the_next_message() {
        // message A loses its tail, message B loses its head: without
        // the message tag, B's surviving fragment 1 would have continued
        // A's partial and "completed" a spliced message
        let mut r = Reassembler::new();
        let v = Vqpn(6);
        assert_eq!(r.accept(v, 0, 0, false, 4096, Ns(0)), None); // A frag 0
        // A frag 1 (last) lost; B frag 0 lost; B frag 1 (last) arrives
        assert_eq!(r.accept(v, 1, 1, true, 100, Ns(1)), None, "tag mismatch must not complete");
        assert_eq!(r.completed, 0);
        assert_eq!(r.dropped, 1, "A's partial is discarded");
    }

    #[test]
    fn decide_has_hysteresis_band() {
        let c = cfg();
        // inside the band nothing moves
        for &s in &[DestState::Rc, DestState::Ud, DestState::DrainingToUd] {
            assert_eq!(decide(s, 0.85, &c), s);
        }
        // at/above enter_ud RC starts draining; at/below exit_ud UD returns
        assert_eq!(decide(DestState::Rc, 1.0, &c), DestState::DrainingToUd);
        assert_eq!(decide(DestState::Ud, 0.7, &c), DestState::Rc);
        assert_eq!(decide(DestState::Ud, 1.5, &c), DestState::Ud);
    }

    #[test]
    fn working_set_within_budget_stays_rc() {
        let mut tm = TransportManager::new(cfg());
        // 400-entry cache, rc_share 0.5 => budget 200 RC destinations
        for r in 0..200u32 {
            tm.register_dest(r);
        }
        tm.evaluate(400, Ns::ZERO);
        // 200 destinations fit the budget exactly: pressure 199/200 < 1
        assert_eq!(tm.state_counts(), (200, 0, 0));
        assert_eq!(tm.to_ud, 0);
    }

    #[test]
    fn overflowing_working_set_migrates_to_ud() {
        let mut tm = TransportManager::new(cfg());
        for r in 0..300u32 {
            tm.register_dest(r);
        }
        tm.evaluate(400, Ns::ZERO);
        // 300 destinations: pressure 299/200 ≈ 1.5 ≥ enter_ud — the whole
        // working set migrates (idle destinations promote straight to Ud)
        assert_eq!(tm.state_of(0), DestState::Ud);
        assert_eq!(tm.state_of(299), DestState::Ud);
        assert_eq!(tm.state_counts(), (0, 0, 300));
        assert_eq!(tm.to_ud, 300);
    }

    #[test]
    fn draining_waits_for_inflight_rc() {
        let mut tm = TransportManager::new(cfg());
        for r in 0..250u32 {
            tm.register_dest(r);
        }
        // destination 249 has traffic in flight when the flip is decided
        tm.on_rc_submitted(249);
        tm.on_rc_submitted(249);
        tm.evaluate(400, Ns::ZERO);
        assert_eq!(tm.state_of(249), DestState::DrainingToUd);
        assert_eq!(tm.state_of(0), DestState::Ud, "idle dests flip immediately");
        tm.on_rc_completed(249);
        assert_eq!(tm.state_of(249), DestState::DrainingToUd, "one WR still out");
        tm.on_rc_completed(249);
        assert_eq!(tm.state_of(249), DestState::Ud, "drained => datagram mode");
    }

    #[test]
    fn stuck_drain_is_forced_at_the_deadline() {
        let mut tm = TransportManager::new(cfg());
        for r in 0..250u32 {
            tm.register_dest(r);
        }
        // sustained traffic: destination 3 never reaches zero in flight
        tm.on_rc_submitted(3);
        tm.evaluate(400, Ns::ZERO);
        assert_eq!(tm.state_of(3), DestState::DrainingToUd);
        // before the deadline the drain holds…
        tm.evaluate(400, Ns(cfg().drain_max_ns - 1));
        assert_eq!(tm.state_of(3), DestState::DrainingToUd);
        // …at the deadline the flip is forced (bounded wait)
        tm.evaluate(400, Ns(cfg().drain_max_ns));
        assert_eq!(tm.state_of(3), DestState::Ud);
        // the straggler RC completion is still accounted, not lost
        tm.on_rc_completed(3);
        assert_eq!(tm.dest(3).unwrap().inflight_rc, 0);
    }

    #[test]
    fn thrash_boost_migration_is_sticky_no_limit_cycle() {
        let mut tm = TransportManager::new(cfg());
        for r in 0..120u32 {
            tm.register_dest(r);
        }
        tm.evaluate(400, Ns::ZERO);
        assert_eq!(tm.state_counts().2, 0, "120 dests fit a 200 budget");
        // observed thrash doubles the pressure to 1.19 ≥ enter_ud
        tm.observe_hit_rate(Some(0.2));
        tm.evaluate(400, Ns::ZERO);
        assert_eq!(tm.state_counts().2, 120);
        // the migration cured the thrash — but a recovered hit rate while
        // the set rides UD must NOT release the latch (it would
        // un-migrate, re-thrash, and limit-cycle)
        tm.observe_hit_rate(Some(0.95));
        assert!(tm.thrash_latched());
        tm.evaluate(400, Ns::ZERO);
        assert_eq!(tm.state_counts().2, 120, "no flap back to RC");
        assert_eq!(tm.to_rc, 0);
    }

    #[test]
    fn thrash_latch_releases_once_back_on_rc() {
        let mut tm = TransportManager::new(cfg());
        // 60 dests: even boosted pressure 59×2/200 = 0.59 stays under
        // enter_ud, so a transient thrash migrates nothing
        for r in 0..60u32 {
            tm.register_dest(r);
        }
        tm.observe_hit_rate(Some(0.2));
        tm.evaluate(400, Ns::ZERO);
        assert_eq!(tm.state_counts(), (60, 0, 0));
        assert!(tm.thrash_latched());
        // recovering just above the threshold keeps the latch…
        tm.observe_hit_rate(Some(0.6));
        assert!(tm.thrash_latched());
        // …well above it, with everything on RC, releases it
        tm.observe_hit_rate(Some(0.9));
        assert!(!tm.thrash_latched());
    }

    #[test]
    fn disabled_manager_reports_rc() {
        let mut c = cfg();
        c.enabled = false;
        let mut tm = TransportManager::new(c);
        for r in 0..1000u32 {
            tm.register_dest(r);
        }
        tm.evaluate(400, Ns::ZERO);
        assert_eq!(tm.state_of(999), DestState::Rc);
        assert_eq!(tm.to_ud, 0);
    }

    #[test]
    fn reassembler_joins_in_order_fragments() {
        let mut r = Reassembler::new();
        let v = Vqpn(5);
        assert_eq!(r.accept(v, 0, 0, false, 4096, Ns(10)), None);
        assert_eq!(r.accept(v, 0, 1, false, 4096, Ns(20)), None);
        assert_eq!(r.accept(v, 0, 2, true, 1000, Ns(30)), Some(9192));
        assert_eq!(r.completed, 1);
        assert_eq!(r.in_progress(), 0);
    }

    #[test]
    fn reassembler_single_fragment_fast_path() {
        let mut r = Reassembler::new();
        assert_eq!(r.accept(Vqpn(1), 0, 0, true, 512, Ns(0)), Some(512));
        assert_eq!(r.in_progress(), 0);
    }

    #[test]
    fn reassembler_drops_on_gap() {
        let mut r = Reassembler::new();
        let v = Vqpn(9);
        assert_eq!(r.accept(v, 0, 0, false, 4096, Ns(0)), None);
        // fragment 1 lost; fragment 2 arrives => partial dropped
        assert_eq!(r.accept(v, 0, 2, true, 4096, Ns(1)), None);
        assert_eq!(r.dropped, 1);
        // a fresh message still reassembles
        assert_eq!(r.accept(v, 1, 0, true, 64, Ns(2)), Some(64));
    }

    #[test]
    fn reassembler_drops_on_duplicate_fragment() {
        // a jitter-reordered duplicate is indistinguishable from a gap:
        // the partial is discarded, never double-counted into the total
        let mut r = Reassembler::new();
        let v = Vqpn(4);
        assert_eq!(r.accept(v, 0, 0, false, 4096, Ns(0)), None);
        assert_eq!(r.accept(v, 0, 1, false, 4096, Ns(1)), None);
        assert_eq!(r.accept(v, 0, 1, false, 4096, Ns(2)), None, "duplicate of frag 1");
        assert_eq!(r.dropped, 1);
        assert_eq!(r.in_progress(), 0);
        // the (now orphaned) tail is counted as such
        assert_eq!(r.accept(v, 0, 2, true, 100, Ns(3)), None);
        assert_eq!(r.orphan_fragments, 1);
        assert_eq!(r.completed, 0);
    }

    #[test]
    fn reassembler_restart_mid_message() {
        // sender restarts mid-train: a fresh seq-0 supersedes the stale
        // partial (counted dropped) and the new message reassembles
        let mut r = Reassembler::new();
        let v = Vqpn(7);
        assert_eq!(r.accept(v, 0, 0, false, 4096, Ns(0)), None);
        assert_eq!(r.accept(v, 0, 1, false, 4096, Ns(1)), None);
        assert_eq!(r.accept(v, 1, 0, false, 2048, Ns(2)), None, "restarted message");
        assert_eq!(r.dropped, 1);
        assert_eq!(r.accept(v, 1, 1, true, 100, Ns(3)), Some(2148));
        assert_eq!(r.completed, 1);
        assert_eq!(r.in_progress(), 0);
    }

    #[test]
    fn reassembler_fragment_timeout_reclaims_stale_partials() {
        let mut r = Reassembler::new();
        assert_eq!(r.accept(Vqpn(1), 0, 0, false, 4096, Ns(1_000)), None); // tail never arrives
        assert_eq!(r.accept(Vqpn(2), 0, 0, false, 4096, Ns(900_000)), None); // still fresh
        assert_eq!(r.in_progress(), 2);
        // before the timeout nothing expires
        assert_eq!(r.expire_stale(Ns(500_000), Ns(1_000_000)), 0);
        assert_eq!(r.expire_stale(Ns(1_200_000), Ns(1_000_000)), 1);
        assert_eq!(r.expired, 1);
        assert_eq!(r.in_progress(), 1, "fresh partial survives");
        // timeout 0 disables expiry entirely
        assert_eq!(r.expire_stale(Ns(u64::MAX / 2), Ns(0)), 0);
        assert_eq!(r.in_progress(), 1);
        // a late tail for the expired message is an orphan, not a crash
        assert_eq!(r.accept(Vqpn(1), 0, 1, true, 64, Ns(1_300_000)), None);
        assert_eq!(r.orphan_fragments, 1);
    }

    #[test]
    fn reassembler_interleaves_across_connections() {
        let mut r = Reassembler::new();
        assert_eq!(r.accept(Vqpn(1), 0, 0, false, 4096, Ns(0)), None);
        assert_eq!(r.accept(Vqpn(2), 0, 0, false, 4096, Ns(1)), None);
        assert_eq!(r.accept(Vqpn(2), 0, 1, true, 100, Ns(2)), Some(4196));
        assert_eq!(r.accept(Vqpn(1), 0, 1, true, 200, Ns(3)), Some(4296));
    }

    #[test]
    fn ud_max_msg_scales_with_mtu() {
        assert_eq!(ud_max_msg_bytes(4096), 32 * 4096);
    }
}
