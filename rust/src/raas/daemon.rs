//! The RDMAvisor daemon (Fig 2): Worker + Poller over the simulated fabric.
//!
//! One daemon per machine owns: one shared RC QP per remote node, one
//! host-wide SRQ, one registered buffer pool, one send CQ + one recv CQ,
//! and the vQPN connection table. Applications talk to it through
//! shared-memory rings ([`super::shmem`]); in the simulator the ring/
//! doorbell costs are charged in virtual time via [`ShmCosts`].
//!
//! Data path (all lock-free):
//! * app `send/read/write` → ring push → **Worker** drains, builds WRs
//!   (vQPN stamped per Fig 4), and posts them **in batches** to the shared
//!   QP (one doorbell per batch — §2.3's WR-batching win);
//! * **Poller** drains both CQs, demuxes by vQPN (`wr_id` for one-sided,
//!   `imm_data` for two-sided), releases staging leases, replenishes the
//!   SRQ, and delivers results to the owning app's completion ring.
//!
//! Alongside the per-remote shared RC QPs the daemon owns **one host-wide
//! UD QP**: destinations whose RC contexts would thrash the NIC's ICM
//! cache are migrated onto it by the [`super::migrate::TransportManager`]
//! (telemetry-driven, hysteretic, drained before the flip). UD is
//! SEND-only and MTU-capped, so migrated messages are fragmented with a
//! per-vQPN sequence header in `imm_data` and reassembled by the peer's
//! Poller before delivery.
//!
//! For repeat access to remote data structures the daemon offers
//! **registered windows** ([`Daemon::register_window`]): one standing
//! staging lease covers a span of the peer pool, and subsequent
//! [`Daemon::window_read`] / [`Daemon::window_write`] calls skip the
//! per-op lease machinery entirely (the Storm argument: one-sided READs
//! beat RPC once the setup cost is amortized). Window WRITEs are
//! doorbell-coalesced RDMAbox-style — consecutive WRITEs through one
//! window post as a single batch whose tail WR alone is signaled, so N
//! small PUTs cost one doorbell and one CQE. DESIGN.md §11.
//!
//! The data plane is **lookup- and allocation-free per op** (PR 5, the
//! daemon-side twin of PR 3's fabric densification): per-remote state
//! (shared QPs, peer pools, pending batches) lives in node-id-indexed
//! [`IdMap`]s, per-app inboxes in an app-id-indexed `Vec`, and every
//! in-flight op in the wr_id-addressed [`OpSlab`] — `pump()` completes
//! an op with two array indexes (slab slot, conn table) and zero
//! hashing. DESIGN.md §10 has the wr_id encoding.

use std::collections::VecDeque;

use crate::fabric::sim::Sim;
use crate::fabric::time::Ns;
use crate::fabric::types::{Cqn, IdMap, NodeId, QpTransport, Qpn, Srqn, Verb, WcStatus};
use crate::fabric::wqe::{Cqe, SendWr};

use super::api::{Flags, RaasError, Target};
use super::buffer::{BufferPool, Lease, Staging, StagingCosts, DEFAULT_LAYOUT};
use super::migrate::{
    pack_ud_imm, ud_max_msg_bytes, unpack_ud_imm, DestState, MigrationConfig, Reassembler,
    TransportManager,
};
use super::opslab::{untracked_wr_id, OpSlab};
use super::shmem::ShmCosts;
use super::telemetry::Telemetry;
use super::transport::{HostLoad, Selector, SelectorConfig};
use super::vqpn::{ConnTable, Vqpn};

/// Daemon tunables.
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// SRQ depth + refill watermark (host-wide, shared by all apps — §1.2).
    pub srq_capacity: usize,
    /// Refill the SRQ when posted WQEs drop below this.
    pub srq_watermark: usize,
    /// Receive slot size drawn from the pool for SRQ WQEs.
    pub recv_slot_bytes: u64,
    /// Max WRs posted per doorbell (Worker batch size).
    pub batch_max: usize,
    /// Daemon service threads (Worker + Poller) — busy-poll cores.
    pub service_threads: u32,
    /// Ring/doorbell cost constants charged in virtual time.
    pub shm: ShmCosts,
    /// Send-side memcpy-vs-memreg cost model.
    pub staging: StagingCosts,
    /// Adaptive transport-selection tunables.
    pub selector: SelectorConfig,
    /// Pool slab layout.
    pub pool_layout: Vec<(u64, u32)>,
    /// Per-WR build cost on the Worker (translate request → WQE).
    pub wr_build_ns: u64,
    /// Per-CQE demux cost on the Poller (vQPN lookup + ring push).
    pub demux_ns: u64,
    /// RC↔UD migration policy (see [`super::migrate`]).
    pub migration: MigrationConfig,
    /// Send-queue depth of the host-wide UD QP. It multiplexes every
    /// migrated destination, so it needs far more slots than the
    /// per-peer fabric default.
    pub ud_sq_depth: usize,
    /// Stale-lease reclaim horizon (0 = disabled, the default). When an
    /// op's completion never arrives — a node restart cleared the SQ or
    /// CQ under it — the Poller releases its staging lease after this
    /// long and reports the op failed, instead of leaking pool slots
    /// forever. Must comfortably exceed the RC retry span; only fault
    /// scenarios enable it, so fault-free daemons are bit-identical to
    /// before it existed.
    pub lease_timeout_ns: u64,
    /// UD reassembly fragment timeout (0 = disabled, the default): a
    /// partial message whose fragments stop arriving for this long is
    /// discarded ([`Reassembler::expire_stale`]). Enabled by fault
    /// scenarios, where a dropped LAST fragment would otherwise pin the
    /// partial until the next message on that vQPN.
    pub reassembly_timeout_ns: u64,
    /// Parked-QP reuse pool bound (PR 7 tentpole): when the last vQPN to
    /// a remote closes and the shared RC QP drains, the QP is parked
    /// instead of destroyed; the next connect to the same remote revives
    /// it for `qp_reuse_ns` instead of a full `handshake_ns`. 0 disables
    /// parking (the fig-12 `--cold` ablation) — drained QPs are
    /// destroyed immediately.
    pub qp_pool_max: usize,
    /// Defer the pool-credential/lease exchange from connect to first
    /// use: `connect` returns after vQPN registration alone, so an idle
    /// tenant costs only its connection-table entry. Deferred remotes are
    /// established in batches of up to `lease_batch_max` per control
    /// message. Off by default (eager, the pre-PR-7 behavior).
    pub lazy_leases: bool,
    /// Max deferred lease establishments coalesced into one control
    /// message (the RDMAbox request-merging argument applied to
    /// control-plane verbs).
    pub lease_batch_max: usize,
    /// Control-plane cost of a full RC handshake: QP-pair create,
    /// INIT→RTR→RTS transitions, and the QPN exchange round-trip.
    pub handshake_ns: u64,
    /// Control-plane cost of reviving a parked QP pair — bookkeeping and
    /// an epoch bump, no wire round-trip.
    pub qp_reuse_ns: u64,
    /// Control-plane cost of one lease-establishment control message
    /// (flat per message, so batching amortizes it).
    pub lease_establish_ns: u64,
    /// Daemon self-healing (DESIGN.md §15): when an op on a shared RC QP
    /// completes with `RetryExceeded`, pull the QP out of service, hold
    /// the failed ops, and re-establish after a capped exponential
    /// backoff instead of reporting `ok: false` immediately. This bounds
    /// the re-establishment attempts per heal cycle; 0 disables healing
    /// (the default — fault-free traces stay byte-identical).
    pub heal_max_attempts: u32,
    /// First re-establishment backoff; doubles per failed attempt.
    pub heal_backoff_ns: u64,
    /// Ceiling on the doubled backoff.
    pub heal_backoff_cap_ns: u64,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            srq_capacity: 4096,
            srq_watermark: 256,
            recv_slot_bytes: 64 << 10,
            batch_max: 32,
            service_threads: 2,
            shm: ShmCosts::default(),
            staging: StagingCosts::default(),
            selector: SelectorConfig::default(),
            pool_layout: DEFAULT_LAYOUT.to_vec(),
            wr_build_ns: 60,
            demux_ns: 40,
            migration: MigrationConfig::default(),
            ud_sq_depth: 8192,
            lease_timeout_ns: 0,
            reassembly_timeout_ns: 0,
            qp_pool_max: 8,
            lazy_leases: false,
            lease_batch_max: 16,
            handshake_ns: 12_000,
            qp_reuse_ns: 900,
            lease_establish_ns: 2_500,
            heal_max_attempts: 0,
            heal_backoff_ns: 50_000,
            heal_backoff_cap_ns: 800_000,
        }
    }
}

/// What the Poller delivers into an app's completion ring.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Delivery {
    /// A send/read/write this app issued finished.
    OpComplete { conn: Vqpn, tag: u64, len: u64, ok: bool },
    /// A two-sided message arrived on this connection.
    Message { conn: Vqpn, len: u64, zero_copy: bool },
}

/// Aggregate daemon statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct DaemonStats {
    /// send/read/write calls accepted.
    pub ops_submitted: u64,
    /// Initiator-side completions delivered.
    pub ops_completed: u64,
    /// Two-sided messages delivered to apps.
    pub msgs_delivered: u64,
    /// Doorbells rung (WR batches posted).
    pub batches_posted: u64,
    /// WRs posted across all batches.
    pub wrs_posted: u64,
    /// Payload bytes of successful completions.
    pub bytes_completed: u64,
    /// Sends staged by copying into the pool.
    pub send_staged_memcpy: u64,
    /// Sends staged by register-on-the-fly.
    pub send_staged_memreg: u64,
    /// `send()` calls routed over a shared RC QP.
    pub sent_rc: u64,
    /// `send()` calls routed over the host-wide UD QP (migrated or pinned).
    pub sent_ud: u64,
    /// UD fragments emitted by the segmentation layer.
    pub ud_fragments: u64,
    /// Ops whose completion reported failure (RC retry exhaustion,
    /// protection errors) or whose lease had to be reclaimed.
    pub ops_failed: u64,
    /// Staging leases released by the stale-lease reclaim instead of a
    /// completion (their CQE never arrived — e.g. a node restart cleared
    /// the queues under the op).
    pub leases_reclaimed: u64,
    /// Remote windows registered (`register_window`).
    pub windows_registered: u64,
    /// Remote windows released by their owner (`release_window`).
    pub windows_released: u64,
    /// Remote windows force-reclaimed by the idle-window sweep (the
    /// owning client restarted and never released the token).
    pub windows_reclaimed: u64,
    /// READ/WRITE ops issued through a registered window.
    pub window_ops: u64,
    /// Doorbell flushes of coalesced window-WRITE groups.
    pub window_flushes: u64,
    /// Window WRITEs that shared another WRITE's doorbell + CQE (group
    /// size minus one, summed — the RDMAbox merging win).
    pub writes_coalesced: u64,
    /// Connections torn down via `disconnect`.
    pub conns_disconnected: u64,
    /// Full RC handshakes performed at connect (a QP pair was created).
    pub handshakes_full: u64,
    /// Shared QPs parked into the reuse pool after their remote drained.
    pub qp_parked: u64,
    /// Parked QPs revived by a later connect — the handshake skipped.
    pub qp_reused: u64,
    /// Parked QPs actually destroyed: LRU bound, an unrevivable
    /// one-sided leftover, or the pool-disabled cold path.
    pub qp_evicted: u64,
    /// Lease-establishment control messages sent (eager connects and
    /// lazy batches alike).
    pub lease_batches: u64,
    /// Per-remote credential/lease sets established.
    pub leases_established: u64,
    /// Send CQEs dropped by the epoch gate: stamped under a previous
    /// tenant generation of a since-reused QP.
    pub stale_epoch_drops: u64,
    /// Control-plane nanoseconds consumed (connect, disconnect, lease
    /// establishment) — the fig-12 setup-rate denominator.
    pub ctrl_ns: u64,
    /// Shared QPs re-established by the self-healing loop after a
    /// `RetryExceeded` park (DESIGN.md §15).
    pub qp_reestablished: u64,
    /// Virtual nanoseconds ops spent parked waiting for re-establishment
    /// (summed across heal cycles — the recovery-lag numerator).
    pub backoff_ns: u64,
    /// Heal cycles abandoned after `heal_max_attempts` re-establishments
    /// all died again; only then do the stashed ops fail with `ok: false`.
    pub heal_giveups: u64,
}

/// Info about a peer daemon's pool we can one-sidedly address.
#[derive(Clone, Copy, Debug)]
struct RemotePool {
    rkey: crate::fabric::types::Mrkey,
    base: u64,
    len: u64,
}

/// A shared QP parked for reuse after its remote's last vQPN closed
/// (PR 7 tentpole). The pair stays connected in the fabric; revival is
/// pure bookkeeping.
#[derive(Clone, Copy, Debug)]
struct ParkedQp {
    remote: u32,
    qpn: Qpn,
    /// Park-order LRU stamp (monotonic, virtual-time-free — parking
    /// order alone decides eviction, which keeps it deterministic).
    stamp: u64,
}

/// Peer credentials exchanged at connect but, under lazy leases, not yet
/// installed: the tenant pays for them at first use, not at connect.
#[derive(Clone, Copy, Debug)]
struct OfferedCreds {
    pool: RemotePool,
    ud: Qpn,
}

/// Everything the Poller needs to finish one in-flight op, stored in the
/// wr_id-addressed [`OpSlab`] (one slab entry per signaled WR).
#[derive(Clone, Copy, Debug)]
struct InflightOp {
    /// The staging lease held open until the completion arrives.
    lease: Lease,
    /// Deliver-to-app copy required (non-zero-copy READ landing).
    deliver_copy: bool,
    /// When the op was submitted — the stale-lease reclaim's clock.
    opened_at: Ns,
    /// Remote node when the op rides a shared RC QP (the migration
    /// engine's drain ledger); None on the UD path.
    rc_remote: Option<u32>,
    /// Logical message length of a fragmented UD send — the wire CQE
    /// only carries the last fragment's length.
    ud_msg_len: Option<u64>,
    /// Window slot when the op went through a registered window: its
    /// lease belongs to the window (NOT released per-op) and completion
    /// decrements the window's in-flight count.
    window: Option<u32>,
    /// Coalesced-WRITE group: the signaled tail WR of a doorbell-batched
    /// window-WRITE flush carries the whole group's (tag, len) pairs in
    /// `Daemon::wgroups[g]` — one CQE fans out into one OpComplete per
    /// logical WRITE.
    wgroup: Option<u32>,
    /// QP epoch of `rc_remote` at submit time. The Poller drops any CQE
    /// whose stamp predates the remote's current epoch (bumped when the
    /// shared QP parks), so a revived QP can never deliver a previous
    /// tenant's completion — DESIGN.md §12. 0 on the UD path (the
    /// host-wide UD QP is never parked).
    epoch: u32,
    /// The WR as posted, kept only when self-healing is enabled
    /// (DESIGN.md §15): a `RetryExceeded` completion stashes the op and
    /// this WR replays verbatim (new wr_id) once the QP re-establishes.
    /// None on window/UD ops — those have their own recovery stories.
    wr: Option<SendWr>,
}

/// One remote undergoing daemon self-healing (DESIGN.md §15): its shared
/// QP hit `RetryExceeded`, was pulled out of `shared_qps` (pausing new
/// posts — batches queue in `pending`), and re-establishes after a
/// capped exponential backoff; the failed ops wait in `replay` and
/// repost through the revived QP. The QP is held here rather than the
/// LRU reuse pool, where an eviction mid-heal would destroy the only
/// path back.
#[derive(Clone, Debug)]
struct HealState {
    /// The remote whose shared QP is being healed.
    remote: u32,
    /// The parked QP, out of `shared_qps` while `parked`.
    qpn: Qpn,
    /// Re-establishments already tried this cycle (give-up threshold is
    /// `heal_max_attempts`).
    attempts: u32,
    /// No re-establishment before this virtual time.
    next_at: Ns,
    /// When the current park began (feeds `DaemonStats::backoff_ns`).
    parked_at: Ns,
    /// Parked (waiting out the backoff) vs probing (re-established and
    /// waiting for the first successful completion to conclude the heal).
    parked: bool,
    /// Failed ops awaiting replay, in CQE order. Their old slab slots
    /// were generation-bumped at completion, so replay mints fresh
    /// wr_ids; their leases and epochs ride along untouched.
    replay: Vec<(Vqpn, InflightOp)>,
}

/// Handle a client holds on a registered remote window: an opaque
/// (slot, generation) pair. The generation check makes tokens single-use
/// across release/reclaim — an op through a released window fails with
/// [`RaasError::StaleWindow`] instead of touching a recycled slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowToken {
    slot: u32,
    gen: u32,
}

/// Live state of one registered window.
#[derive(Clone, Debug)]
struct WindowEntry {
    /// Owning connection (completion routing + restart reclaim).
    conn: Vqpn,
    /// Remote node the window addresses (resolved once at register).
    remote: u32,
    /// Offset of the window inside the peer pool.
    remote_base: u64,
    /// Window span in bytes.
    span: u64,
    /// The ONE standing staging lease every op through the window shares
    /// (the whole point: repeat ops skip the per-op lease machinery).
    lease: Lease,
    /// Ops in flight through this window (defers teardown).
    inflight: u32,
    /// Last submit through the window — the restart-reclaim clock.
    last_used: Ns,
    /// Owner called `release_window`; slot is freed once drained.
    closed: bool,
    /// Pending coalesced WRITEs awaiting the next doorbell.
    wbatch: Vec<SendWr>,
    /// (user tag, len) of each pending WRITE, in submit order.
    wtags: Vec<(u64, u64)>,
}

/// Slot in the window table: generation survives the entry so stale
/// tokens stay detectable after reuse.
#[derive(Clone, Debug, Default)]
struct WindowSlot {
    gen: u32,
    entry: Option<WindowEntry>,
}

/// The per-machine RDMAvisor daemon.
pub struct Daemon {
    /// The machine this daemon owns.
    pub node: NodeId,
    /// Tunables the daemon was started with.
    pub cfg: DaemonConfig,
    /// vQPN allocator + completion-routing index.
    pub conns: ConnTable,
    /// The host-wide registered buffer pool.
    pub pool: BufferPool,
    /// CPU/memory ledger + load snapshots.
    pub telemetry: Telemetry,
    /// Adaptive transport/verb selector.
    pub selector: Selector,
    /// RC↔UD migration engine (per-destination states + hysteresis).
    pub migrate: TransportManager,
    /// Poller-side reassembly of fragmented UD messages.
    pub reassembly: Reassembler,
    /// Aggregate data-path counters.
    pub stats: DaemonStats,
    send_cq: Cqn,
    recv_cq: Cqn,
    srq: Srqn,
    /// The host-wide UD QP every migrated destination shares.
    ud_qp: Qpn,
    /// remote node -> shared QP to it (THE §2.3 structure), node-indexed.
    shared_qps: IdMap<Qpn>,
    /// remote node -> its daemon's UD QPN (exchanged at connect).
    remote_ud: IdMap<Qpn>,
    /// remote node -> its daemon's pool credentials (one-sided verbs).
    remote_pools: IdMap<RemotePool>,
    /// Worker-side pending WR batches, per remote node. Flush order is
    /// carried by `dirty_remotes` (submission order), never by map
    /// iteration — and `IdMap` iteration is id-ordered anyway, so no
    /// backing-store order can leak into the event timeline.
    pending: IdMap<Vec<SendWr>>,
    /// Worker-side pending UD fragments (one batch, one QP).
    ud_pending: Vec<SendWr>,
    /// Remotes whose batch went non-empty since the last pump, in
    /// submission order (so pump flushes O(dirty), not O(all remotes)).
    dirty_remotes: Vec<u32>,
    /// Every in-flight op (staging lease, drain ledger, UD logical
    /// length), addressed by the slot+generation packed into its wr_id —
    /// the Poller's zero-hash completion path. The generation check also
    /// drops completions that limp in after the stale-lease reclaim
    /// already reported the op failed, so the app sees exactly ONE
    /// OpComplete per op.
    ops: OpSlab<InflightOp>,
    /// Per-connection mod-64 UD message tag (the anti-splicing id every
    /// fragment of one message carries — see [`pack_ud_imm`]),
    /// vQPN-indexed.
    ud_msg_counter: IdMap<u8>,
    /// Last ICM sample: (virtual time, hits, misses); None before the
    /// first pump.
    icm_sample: Option<(Ns, u64, u64)>,
    /// Per-app completion inboxes (stand-in for the completion rings),
    /// indexed by the sequential app id.
    inboxes: Vec<VecDeque<Delivery>>,
    /// Listening "ports" (control plane): (port, owning app); last
    /// `listen` on a port wins.
    listeners: Vec<(u16, u32)>,
    /// Accepted-but-not-yet-claimed connections per (app, port)
    /// (control plane; linear scan over the few live listeners).
    accept_queues: Vec<((u32, u16), VecDeque<Vqpn>)>,
    srq_wr_seq: u64,
    /// Poller scratch buffer reused across pumps (zero-alloc CQ drain).
    cqe_buf: Vec<Cqe>,
    /// Registered remote windows, slot-indexed (tokens carry the slot).
    windows: Vec<WindowSlot>,
    /// Free window slots (LIFO reuse keeps the table dense).
    window_free: Vec<u32>,
    /// Windows whose WRITE batch went non-empty since the last pump, in
    /// submission order (pump flushes O(dirty), mirroring
    /// `dirty_remotes`).
    dirty_windows: Vec<u32>,
    /// Coalesced-WRITE group tag tables: `wgroups[g]` holds the (tag,
    /// len) pairs the group's single signaled CQE fans out into.
    wgroups: Vec<Vec<(u64, u64)>>,
    /// Free wgroup slots (LIFO reuse keeps the table dense).
    wgroup_free: Vec<u32>,
    /// Parked shared QPs awaiting a same-destination reconnect, bounded
    /// by `cfg.qp_pool_max` (LRU-evicted with a real destroy).
    qp_pool: Vec<ParkedQp>,
    /// Monotonic park counter — the reuse pool's LRU clock.
    park_seq: u64,
    /// Per-remote QP generation, bumped when the shared QP to that
    /// remote parks. Ops are stamped with the epoch current at submit;
    /// the Poller's epoch gate drops any CQE stamped under an earlier
    /// generation (DESIGN.md §12), node-indexed.
    qp_epoch: IdMap<u32>,
    /// Remotes whose last vQPN closed, awaiting drain (zero in-flight
    /// RC WRs, empty pending batch) before their shared QP parks —
    /// submission order, swept each pump.
    parting: Vec<u32>,
    /// Remotes under self-healing after a `RetryExceeded` (DESIGN.md
    /// §15), failure order. Empty whenever `cfg.heal_max_attempts == 0`.
    heals: Vec<HealState>,
    /// Lazy mode: peer credentials offered at connect but not yet
    /// established, node-indexed.
    offered_creds: IdMap<OfferedCreds>,
    /// Lazy mode: deferred remotes in offer order — establishment
    /// batches drain from the front (FIFO keeps the migration engine's
    /// registration ranks deterministic).
    lease_backlog: Vec<u32>,
}

impl Daemon {
    /// Bring the daemon up on `node`: CQs, SRQ (pre-filled), buffer pool,
    /// and the host-wide UD QP (created up front — its context cost is
    /// O(1) regardless of how many destinations later migrate onto it).
    pub fn start(sim: &mut Sim, node: NodeId, cfg: DaemonConfig) -> Daemon {
        let send_cq = sim.create_cq(node, 65_536);
        let recv_cq = sim.create_cq(node, 65_536);
        let srq = sim.create_srq(node, cfg.srq_capacity, cfg.srq_watermark);
        let ud_qp = sim.create_qp(node, QpTransport::Ud, send_cq, recv_cq);
        sim.activate_ud(node, ud_qp);
        sim.attach_srq(node, ud_qp, srq);
        sim.set_sq_depth(node, ud_qp, cfg.ud_sq_depth);
        let mut pool = BufferPool::new(sim, node, &cfg.pool_layout);
        let mut srq_wr_seq = 0;
        // pre-post the SRQ from the pool
        Self::fill_srq(sim, node, srq, &mut pool, &cfg, &mut srq_wr_seq);
        let telemetry = Telemetry::new(cfg.service_threads);
        sim.node_mut(node).cpu.polling_threads += cfg.service_threads;
        Daemon {
            node,
            selector: Selector::new(cfg.selector.clone()),
            migrate: TransportManager::new(cfg.migration),
            reassembly: Reassembler::new(),
            conns: ConnTable::new(),
            pool,
            telemetry,
            stats: DaemonStats::default(),
            send_cq,
            recv_cq,
            srq,
            ud_qp,
            shared_qps: IdMap::new(),
            remote_ud: IdMap::new(),
            remote_pools: IdMap::new(),
            pending: IdMap::new(),
            ud_pending: Vec::new(),
            dirty_remotes: Vec::new(),
            ops: OpSlab::new(),
            ud_msg_counter: IdMap::new(),
            icm_sample: None,
            inboxes: Vec::new(),
            listeners: Vec::new(),
            accept_queues: Vec::new(),
            srq_wr_seq,
            cqe_buf: Vec::new(),
            windows: Vec::new(),
            window_free: Vec::new(),
            dirty_windows: Vec::new(),
            wgroups: Vec::new(),
            wgroup_free: Vec::new(),
            qp_pool: Vec::new(),
            park_seq: 0,
            qp_epoch: IdMap::new(),
            parting: Vec::new(),
            heals: Vec::new(),
            offered_creds: IdMap::new(),
            lease_backlog: Vec::new(),
            cfg,
        }
    }

    fn fill_srq(
        sim: &mut Sim,
        node: NodeId,
        srq: Srqn,
        pool: &mut BufferPool,
        cfg: &DaemonConfig,
        seq: &mut u64,
    ) {
        loop {
            let posted = sim.node(node).srqs[srq.0].posted();
            if posted >= cfg.srq_capacity {
                break;
            }
            let lease = match pool.lease(cfg.recv_slot_bytes) {
                Some(l) => l,
                None => break,
            };
            let wr = crate::fabric::wqe::RecvWr {
                wr_id: *seq,
                lkey: pool.mr.key,
                laddr: lease.addr,
                len: lease.len,
            };
            *seq += 1;
            if !sim.post_srq_recv(node, srq, wr) {
                pool.release(lease);
                break;
            }
            // SRQ recv leases are recycled in place on delivery; we release
            // immediately so pool pressure reflects in-flight ops, while
            // hwm_bytes still charges the touched slots (Fig 7).
            pool.release(lease);
        }
    }

    /// Register an application session (rings + eventfds accounted).
    pub fn register_app(&mut self) -> u32 {
        let app = self.telemetry.add_session();
        self.inbox_mut(app);
        app
    }

    /// The app's completion inbox, growing the table to cover `app`.
    fn inbox_mut(&mut self, app: u32) -> &mut VecDeque<Delivery> {
        let idx = app as usize;
        if idx >= self.inboxes.len() {
            self.inboxes.resize_with(idx + 1, VecDeque::new);
        }
        &mut self.inboxes[idx]
    }

    /// `listen(Target, FLAGS)` — Fig 3. Binds a port to an app.
    pub fn listen(&mut self, app: u32, port: u16) {
        match self.listeners.iter_mut().find(|(p, _)| *p == port) {
            Some(entry) => entry.1 = app,
            None => self.listeners.push((port, app)),
        }
        self.accept_queue_mut(app, port);
    }

    /// The accept queue for `(app, port)`, created on first use.
    fn accept_queue_mut(&mut self, app: u32, port: u16) -> &mut VecDeque<Vqpn> {
        if let Some(i) = self.accept_queues.iter().position(|(k, _)| *k == (app, port)) {
            return &mut self.accept_queues[i].1;
        }
        self.accept_queues.push(((app, port), VecDeque::new()));
        &mut self.accept_queues.last_mut().expect("just pushed").1
    }

    /// `accept(fd, FLAGS)` — Fig 3. Non-blocking: pops an accepted conn.
    pub fn accept(&mut self, app: u32, port: u16) -> Option<Vqpn> {
        self.accept_queues
            .iter_mut()
            .find(|(k, _)| *k == (app, port))?
            .1
            .pop_front()
    }

    /// The daemon's current load snapshot (what it advertises to peers).
    pub fn load(&self, sim: &Sim) -> HostLoad {
        let mut l = self.telemetry.load(sim.now(), sim.cfg.cores_per_node);
        l.mem = self.pool.pressure();
        l
    }

    // --------------------------------------- elastic control plane (PR 7)

    /// Charge control-plane work: the host core pays in virtual time and
    /// the fig-12 setup-rate ledger records it. Kept out of the daemon's
    /// service-thread telemetry so the data-plane selector never sees
    /// control churn as load.
    fn charge_ctrl(&mut self, sim: &mut Sim, ns: u64) {
        sim.node_mut(self.node).cpu.charge(ns);
        self.stats.ctrl_ns += ns;
    }

    /// Current QP epoch for `remote` (bumped each time its shared QP
    /// parks; 0 before the first park).
    fn epoch_of(&self, remote: u32) -> u32 {
        self.qp_epoch.get(remote).copied().unwrap_or(0)
    }

    /// Lazy-lease establishment: install the deferred pool credentials
    /// for `remote` — plus up to `lease_batch_max - 1` more backlogged
    /// remotes riding the same control message (coalesced control verbs,
    /// the RDMAbox merging argument). Establishment is atomic per batch:
    /// every remote in it lands fully (pool + UD + migration
    /// registration) or the call fails before touching any ledger —
    /// there is no partial state for a fault to observe. No-op when the
    /// credentials are already live; eager daemons never reach the
    /// deferred path.
    fn ensure_creds(&mut self, sim: &mut Sim, remote: u32) -> Result<(), RaasError> {
        if self.remote_pools.get(remote).is_some() {
            return Ok(());
        }
        if self.offered_creds.get(remote).is_none() {
            return Err(RaasError::UnknownConnection);
        }
        // one flat-cost control message covers the whole batch
        self.charge_ctrl(sim, self.cfg.lease_establish_ns);
        self.stats.lease_batches += 1;
        let cap = self.cfg.lease_batch_max.max(1);
        let mut batch = Vec::with_capacity(cap);
        batch.push(remote);
        self.lease_backlog.retain(|&r| r != remote);
        while batch.len() < cap && !self.lease_backlog.is_empty() {
            batch.push(self.lease_backlog.remove(0));
        }
        for r in batch {
            let creds = self.offered_creds.remove(r).expect("backlogged remote has an offer");
            self.remote_pools.insert(r, creds.pool);
            self.remote_ud.insert(r, creds.ud);
            self.migrate.register_dest(r);
            self.stats.leases_established += 1;
        }
        Ok(())
    }

    /// `disconnect(fd)` — tear down one logical connection (PR 7
    /// tentpole). The vQPN is quarantined (not recycled) until its
    /// remote's shared QP drains; every op still in flight through the
    /// connection is fail-fasted exactly like the stale-lease reclaim,
    /// so its late CQE misses the slab and is dropped; windows the
    /// connection owns are force-released; never-posted WRs bound to it
    /// are dropped from the pending batch. When the last vQPN to a
    /// remote closes, the remote queues for parking: once drained, its
    /// shared QP enters the reuse pool (or is destroyed under the cold
    /// ablation) and its credentials are torn down.
    pub fn disconnect(&mut self, sim: &mut Sim, conn: Vqpn) -> Result<(), RaasError> {
        let remote = match self.conns.lookup(conn) {
            Some(e) => e.remote,
            None => return Err(RaasError::ConnectionClosed),
        };
        self.charge_ctrl(sim, self.cfg.shm.ring_push_ns);
        // fail-fast in-flight ops submitted through this connection
        let doomed: Vec<u64> = self
            .ops
            .iter()
            .filter(|(id, _)| crate::raas::vqpn::unpack_vqpn(*id) == conn)
            .map(|(id, _)| id)
            .collect();
        for wr_id in doomed {
            self.fail_op(wr_id, false);
        }
        // force-release windows the connection owns (pending coalesced
        // WRITEs fail: never posted, so they cannot complete twice)
        for slot in 0..self.windows.len() as u32 {
            let owned = self.windows[slot as usize]
                .entry
                .as_ref()
                .is_some_and(|w| w.conn == conn);
            if owned {
                self.fail_window(slot);
            }
        }
        // drop never-posted WRs bound to this connection (their slab
        // entries are already gone)
        if let Some(batch) = self.pending.get_mut(remote.0) {
            batch.retain(|wr| crate::raas::vqpn::unpack_vqpn(wr.wr_id) != conn);
        }
        // purge unclaimed accepts handing out this vQPN
        for (_, q) in self.accept_queues.iter_mut() {
            q.retain(|&v| v != conn);
        }
        self.conns.close_quarantined(conn).expect("checked live");
        self.stats.conns_disconnected += 1;
        if self.conns.conns_to(remote) == 0 && !self.parting.contains(&remote.0) {
            self.parting.push(remote.0);
        }
        Ok(())
    }

    /// Parking sweep: a remote whose last vQPN closed parks its shared
    /// QP once fully drained — zero in-flight RC WRs in the migration
    /// ledger and an empty pending batch. Draining first means a parked
    /// (or destroyed) QP has no WR whose CQE could still surface, and
    /// the remote's quarantined vQPNs become safe to recycle: no frame
    /// stamped with them remains in the fabric.
    fn sweep_parting(&mut self, sim: &mut Sim) {
        if self.parting.is_empty() {
            return;
        }
        let parting = std::mem::take(&mut self.parting);
        for r in parting {
            if self.conns.conns_to(NodeId(r)) > 0 {
                // a new tenant connected before the drain finished: the
                // remote stays live (its quarantined vQPNs wait for the
                // next full drain)
                continue;
            }
            let drained = self.migrate.dest(r).map_or(true, |d| d.inflight_rc == 0)
                && self.pending.get(r).map_or(true, |b| b.is_empty());
            if !drained {
                self.parting.push(r);
                continue;
            }
            self.park_remote(sim, r);
        }
    }

    /// Park (or, cold, destroy) the drained shared QP to `r` and tear
    /// down the remote's per-destination state. The epoch bump happens
    /// here — past this point any CQE or frame stamped under the old
    /// epoch is provably a previous tenant's.
    fn park_remote(&mut self, sim: &mut Sim, r: u32) {
        self.conns.release_quarantined(NodeId(r));
        self.migrate.unregister_dest(r);
        self.remote_pools.remove(r);
        self.remote_ud.remove(r);
        self.offered_creds.remove(r);
        self.lease_backlog.retain(|&x| x != r);
        self.pending.remove(r);
        let Some(qpn) = self.shared_qps.remove(r) else { return };
        *self.qp_epoch.entry_or_default(r) += 1;
        if self.cfg.qp_pool_max == 0 {
            sim.destroy_qp(self.node, qpn);
            self.stats.qp_evicted += 1;
            return;
        }
        self.park_seq += 1;
        self.qp_pool.push(ParkedQp { remote: r, qpn, stamp: self.park_seq });
        self.stats.qp_parked += 1;
        if self.qp_pool.len() > self.cfg.qp_pool_max {
            // LRU: the smallest stamp goes (stamps are unique, so the
            // victim is deterministic)
            let lru = self
                .qp_pool
                .iter()
                .enumerate()
                .min_by_key(|(_, p)| p.stamp)
                .map(|(i, _)| i)
                .expect("non-empty pool");
            let victim = self.qp_pool.swap_remove(lru);
            sim.destroy_qp(self.node, victim.qpn);
            self.stats.qp_evicted += 1;
        }
    }

    /// Pull the parked QP for `remote` out of the reuse pool, if any.
    fn take_parked(&mut self, remote: u32) -> Option<Qpn> {
        let i = self.qp_pool.iter().position(|p| p.remote == remote)?;
        Some(self.qp_pool.swap_remove(i).qpn)
    }

    /// Parked QPs currently in the reuse pool.
    pub fn pooled_qp_count(&self) -> usize {
        self.qp_pool.len()
    }

    /// Ops currently tracked in the in-flight slab (tests/diagnostics).
    pub fn inflight_ops(&self) -> usize {
        self.ops.len()
    }

    /// Are `remote`'s pool credentials established (eagerly at connect,
    /// or lazily at first use)? Deferred and parted remotes answer no.
    /// The credential ledger is all-or-nothing per remote by
    /// construction; the debug assert keeps that honest.
    pub fn creds_established(&self, remote: u32) -> bool {
        debug_assert_eq!(
            self.remote_pools.get(remote).is_some(),
            self.remote_ud.get(remote).is_some(),
            "partial credential ledger for remote {r}",
            r = remote
        );
        self.remote_pools.get(remote).is_some()
    }

    /// Remotes whose credentials are offered but still deferred (lazy
    /// backlog length).
    pub fn deferred_lease_count(&self) -> usize {
        self.lease_backlog.len()
    }

    /// Current QP epoch for `remote` (tests/diagnostics).
    pub fn epoch(&self, remote: u32) -> u32 {
        self.epoch_of(remote)
    }

    // ------------------------------------------------------- data plane

    /// App-side submit cost (ring push + possible doorbell), charged to the
    /// app's core on the sim node.
    fn charge_submit(&mut self, sim: &mut Sim) {
        let c = self.cfg.shm.ring_push_ns + self.cfg.shm.doorbell_ns / 8; // amortized doorbell
        sim.node_mut(self.node).cpu.charge(c);
        self.stats.ops_submitted += 1;
        self.telemetry.ops_submitted += 1;
    }

    /// One-sided READ of `len` bytes from the peer pool at `remote_offset`
    /// (the Fig 5/6 workload primitive). Returns the user tag.
    pub fn read(
        &mut self,
        sim: &mut Sim,
        conn: Vqpn,
        len: u64,
        remote_offset: u64,
        tag: u64,
    ) -> Result<u64, RaasError> {
        self.one_sided(sim, conn, Verb::Read, len, remote_offset, tag, Flags::default())
    }

    /// One-sided WRITE.
    pub fn write(
        &mut self,
        sim: &mut Sim,
        conn: Vqpn,
        len: u64,
        remote_offset: u64,
        tag: u64,
    ) -> Result<u64, RaasError> {
        self.one_sided(sim, conn, Verb::Write, len, remote_offset, tag, Flags::default())
    }

    fn one_sided(
        &mut self,
        sim: &mut Sim,
        conn: Vqpn,
        verb: Verb,
        len: u64,
        remote_offset: u64,
        tag: u64,
        _flags: Flags,
    ) -> Result<u64, RaasError> {
        self.charge_submit(sim);
        let entry = self.conns.lookup(conn).ok_or(RaasError::UnknownConnection)?;
        let remote = entry.remote;
        self.ensure_creds(sim, remote.0)?;
        let rp = *self
            .remote_pools
            .get(remote.0)
            .ok_or(RaasError::UnknownConnection)?;
        if remote_offset + len > rp.len {
            return Err(RaasError::TooLong { len, max: rp.len - remote_offset });
        }
        let lease = self.pool.lease(len).ok_or(RaasError::PoolExhausted)?;
        let epoch = self.epoch_of(remote.0);
        // reads land in the lease; deliver (copy) unless app opted zero-copy
        let wr_id = self.ops.insert(
            conn,
            InflightOp {
                lease,
                deliver_copy: verb == Verb::Read,
                opened_at: sim.now(),
                rc_remote: Some(remote.0),
                ud_msg_len: None,
                window: None,
                wgroup: None,
                epoch,
                wr: None,
            },
        );
        let wr = match verb {
            Verb::Read => SendWr::read(wr_id, len, self.pool.mr.key, lease.addr, rp.rkey, rp.base + remote_offset),
            Verb::Write => SendWr::write(wr_id, len, self.pool.mr.key, lease.addr, rp.rkey, rp.base + remote_offset),
            Verb::Send => unreachable!(),
        };
        // the WR is built after `insert` (it needs the wr_id), so the
        // heal stash is back-filled through the slab
        if self.cfg.heal_max_attempts > 0 {
            if let Some(op) = self.ops.get_mut(wr_id) {
                op.wr = Some(wr);
            }
        }
        self.enqueue_wr(sim, remote, wr, tag)?;
        Ok(tag)
    }

    // ------------------------------------------------- registered windows

    /// Register a remote window: `[remote_offset, remote_offset + span)`
    /// of `conn`'s peer pool, with ops through it capped at `max_op`
    /// bytes. ONE staging lease of `max_op` bytes is taken here and held
    /// for the window's lifetime — every subsequent READ/WRITE through
    /// the returned token reuses it, skipping the per-op lease/release
    /// round that dominates the small-op submit path (the Storm
    /// repeat-access argument). Registration is control-plane work: it
    /// charges CPU but does not count as a data-plane op.
    pub fn register_window(
        &mut self,
        sim: &mut Sim,
        conn: Vqpn,
        remote_offset: u64,
        span: u64,
        max_op: u64,
    ) -> Result<WindowToken, RaasError> {
        let c = self.cfg.shm.ring_push_ns + self.cfg.shm.doorbell_ns / 8;
        sim.node_mut(self.node).cpu.charge(c);
        let entry = self.conns.lookup(conn).ok_or(RaasError::UnknownConnection)?;
        let remote = entry.remote;
        self.ensure_creds(sim, remote.0)?;
        let rp = *self
            .remote_pools
            .get(remote.0)
            .ok_or(RaasError::UnknownConnection)?;
        if remote_offset + span > rp.len {
            return Err(RaasError::TooLong { len: span, max: rp.len.saturating_sub(remote_offset) });
        }
        if max_op == 0 || max_op > span {
            return Err(RaasError::TooLong { len: max_op, max: span });
        }
        let lease = self.pool.lease(max_op).ok_or(RaasError::PoolExhausted)?;
        let slot = match self.window_free.pop() {
            Some(s) => s,
            None => {
                self.windows.push(WindowSlot::default());
                (self.windows.len() - 1) as u32
            }
        };
        let gen = self.windows[slot as usize].gen;
        self.windows[slot as usize].entry = Some(WindowEntry {
            conn,
            remote: remote.0,
            remote_base: remote_offset,
            span,
            lease,
            inflight: 0,
            last_used: sim.now(),
            closed: false,
            wbatch: Vec::new(),
            wtags: Vec::new(),
        });
        self.stats.windows_registered += 1;
        Ok(WindowToken { slot, gen })
    }

    /// Is `win` a live, open window on this daemon?
    pub fn check_window(&self, win: WindowToken) -> Result<(), RaasError> {
        match self.windows.get(win.slot as usize) {
            Some(s) if s.gen == win.gen => match &s.entry {
                Some(w) if !w.closed => Ok(()),
                _ => Err(RaasError::StaleWindow),
            },
            _ => Err(RaasError::StaleWindow),
        }
    }

    /// Copy the scalars an op needs out of a checked-live window entry.
    fn window_params(&self, slot: u32) -> (Vqpn, u32, u64, u64, Lease) {
        let w = self.windows[slot as usize].entry.as_ref().expect("checked live");
        (w.conn, w.remote, w.remote_base, w.span, w.lease)
    }

    /// One-sided READ of `len` bytes at `offset` inside a registered
    /// window — the repeat-get primitive. No per-op lease: the payload
    /// lands in the window's standing lease (the simulator tracks
    /// extents, so concurrent reads sharing the slot cost nothing).
    pub fn window_read(
        &mut self,
        sim: &mut Sim,
        win: WindowToken,
        len: u64,
        offset: u64,
        tag: u64,
    ) -> Result<u64, RaasError> {
        self.charge_submit(sim);
        self.check_window(win)?;
        let (conn, remote, remote_base, span, lease) = self.window_params(win.slot);
        if offset + len > span {
            return Err(RaasError::TooLong { len, max: span.saturating_sub(offset) });
        }
        if len > lease.len {
            return Err(RaasError::TooLong { len, max: lease.len });
        }
        let rp = *self
            .remote_pools
            .get(remote)
            .ok_or(RaasError::UnknownConnection)?;
        let epoch = self.epoch_of(remote);
        let wr_id = self.ops.insert(
            conn,
            InflightOp {
                lease,
                deliver_copy: true,
                opened_at: sim.now(),
                rc_remote: Some(remote),
                ud_msg_len: None,
                window: Some(win.slot),
                wgroup: None,
                epoch,
                wr: None,
            },
        );
        let wr = SendWr::read(
            wr_id,
            len,
            self.pool.mr.key,
            lease.addr,
            rp.rkey,
            rp.base + remote_base + offset,
        );
        self.enqueue_wr(sim, NodeId(remote), wr, tag)?;
        let w = self.windows[win.slot as usize].entry.as_mut().expect("checked live");
        w.inflight += 1;
        w.last_used = sim.now();
        self.stats.window_ops += 1;
        Ok(tag)
    }

    /// One-sided WRITE of `len` bytes at `offset` inside a registered
    /// window. WRITEs are **doorbell-coalesced** (RDMAbox-style request
    /// merging): each call appends an *unsignaled* WR to the window's
    /// pending group; the group posts as one batch whose tail WR alone is
    /// signaled, so N WRITEs cost one doorbell and one CQE. The flush
    /// happens at `batch_max`, on the next `pump`, or explicitly via
    /// [`Daemon::window_flush`]. No immediate data travels: the WRITE is
    /// truly one-sided — the responder consumes no recv WQE and raises no
    /// CQE (the remote app polls the window memory, KV-style).
    pub fn window_write(
        &mut self,
        sim: &mut Sim,
        win: WindowToken,
        len: u64,
        offset: u64,
        tag: u64,
    ) -> Result<u64, RaasError> {
        self.charge_submit(sim);
        self.check_window(win)?;
        let (conn, remote, remote_base, span, lease) = self.window_params(win.slot);
        if offset + len > span {
            return Err(RaasError::TooLong { len, max: span.saturating_sub(offset) });
        }
        if len > lease.len {
            return Err(RaasError::TooLong { len, max: lease.len });
        }
        let rp = *self
            .remote_pools
            .get(remote)
            .ok_or(RaasError::UnknownConnection)?;
        let wr = SendWr::write(
            untracked_wr_id(conn),
            len,
            self.pool.mr.key,
            lease.addr,
            rp.rkey,
            rp.base + remote_base + offset,
        )
        .unsignaled();
        self.telemetry.charge(self.cfg.shm.ring_pop_ns + self.cfg.wr_build_ns);
        let (was_empty, batch_len) = {
            let w = self.windows[win.slot as usize].entry.as_mut().expect("checked live");
            let was_empty = w.wbatch.is_empty();
            w.wbatch.push(wr);
            w.wtags.push((tag, len));
            w.inflight += 1;
            w.last_used = sim.now();
            (was_empty, w.wbatch.len())
        };
        if was_empty {
            self.dirty_windows.push(win.slot);
        }
        self.stats.window_ops += 1;
        if batch_len >= self.cfg.batch_max {
            self.flush_window(sim, win.slot)?;
        }
        Ok(tag)
    }

    /// Explicitly flush a window's pending coalesced WRITEs (one doorbell
    /// group). Closed-loop clients call this after a PUT burst.
    pub fn window_flush(&mut self, sim: &mut Sim, win: WindowToken) -> Result<(), RaasError> {
        self.check_window(win)?;
        self.flush_window(sim, win.slot)
    }

    /// Release a registered window: pending WRITEs are flushed first
    /// (accepted ops complete exactly once), the token is invalidated
    /// immediately, and the standing lease returns to the pool once the
    /// last in-flight op drains.
    pub fn release_window(&mut self, sim: &mut Sim, win: WindowToken) -> Result<(), RaasError> {
        self.check_window(win)?;
        self.flush_window(sim, win.slot)?;
        let done = {
            let w = self.windows[win.slot as usize].entry.as_mut().expect("checked live");
            w.closed = true;
            w.inflight == 0 && w.wbatch.is_empty()
        };
        self.stats.windows_released += 1;
        if done {
            self.free_window(win.slot);
        }
        Ok(())
    }

    /// Live (registered, unreleased) windows on this daemon.
    pub fn window_count(&self) -> usize {
        self.windows.iter().filter(|s| s.entry.is_some()).count()
    }

    /// Post a window's pending WRITE group to the per-remote batch: ONE
    /// slab entry (and ONE drain-ledger submit) for the whole group, the
    /// tail WR re-stamped signaled with the slab wr_id — on the ordered
    /// RC QP its completion implies every earlier unsignaled WRITE in the
    /// group also completed.
    fn flush_window(&mut self, sim: &mut Sim, slot: u32) -> Result<(), RaasError> {
        let (conn, remote, lease, mut wrs, tags) = {
            let Some(w) = self.windows.get_mut(slot as usize).and_then(|s| s.entry.as_mut())
            else {
                return Ok(());
            };
            if w.wbatch.is_empty() {
                return Ok(());
            }
            (
                w.conn,
                w.remote,
                w.lease,
                std::mem::take(&mut w.wbatch),
                std::mem::take(&mut w.wtags),
            )
        };
        let n = tags.len() as u64;
        let g = match self.wgroup_free.pop() {
            Some(g) => {
                self.wgroups[g as usize] = tags;
                g
            }
            None => {
                self.wgroups.push(tags);
                (self.wgroups.len() - 1) as u32
            }
        };
        let epoch = self.epoch_of(remote);
        let wr_id = self.ops.insert(
            conn,
            InflightOp {
                lease,
                deliver_copy: false,
                opened_at: sim.now(),
                rc_remote: Some(remote),
                ud_msg_len: None,
                window: Some(slot),
                wgroup: Some(g),
                epoch,
                wr: None,
            },
        );
        let tail = wrs.last_mut().expect("non-empty group");
        tail.wr_id = wr_id;
        tail.signaled = true;
        self.stats.window_flushes += 1;
        self.stats.writes_coalesced += n - 1;
        self.migrate.on_rc_submitted(remote);
        let batch = self.pending.entry_or_default(remote);
        if batch.is_empty() {
            self.dirty_remotes.push(remote);
        }
        batch.extend(wrs);
        if batch.len() >= self.cfg.batch_max {
            self.flush_remote(sim, NodeId(remote))?;
        }
        Ok(())
    }

    /// Return a drained window slot to the pool: release the standing
    /// lease, bump the generation (stale-token detection), recycle.
    fn free_window(&mut self, slot: u32) {
        if let Some(w) = self.windows[slot as usize].entry.take() {
            self.pool.release(w.lease);
            let s = &mut self.windows[slot as usize];
            s.gen = s.gen.wrapping_add(1);
            self.window_free.push(slot);
        }
    }

    /// `n` ops through window `slot` finished; free the slot if its owner
    /// already released it and nothing remains in flight.
    fn window_op_done(&mut self, slot: u32, n: u32) {
        let done = {
            let Some(w) = self.windows.get_mut(slot as usize).and_then(|s| s.entry.as_mut())
            else {
                return;
            };
            w.inflight = w.inflight.saturating_sub(n);
            w.closed && w.inflight == 0 && w.wbatch.is_empty()
        };
        if done {
            self.free_window(slot);
        }
    }

    /// Force-release windows whose owner went away without calling
    /// `release_window` (a client restart): any window idle past the
    /// lease-timeout horizon with nothing in flight gets its standing
    /// lease back and its token invalidated. Shares the fault-hygiene
    /// gate (`lease_timeout_ns == 0` disables it), so fault-free runs
    /// never pay for the sweep. In-flight ops first age out through
    /// [`Daemon::reclaim_stale_leases`], which drains `inflight` here.
    fn reclaim_stale_windows(&mut self, sim: &Sim) {
        if self.cfg.lease_timeout_ns == 0 || self.windows.is_empty() {
            return;
        }
        let now = sim.now();
        let timeout = Ns(self.cfg.lease_timeout_ns);
        for slot in 0..self.windows.len() as u32 {
            let idle = {
                let Some(w) = self.windows[slot as usize].entry.as_ref() else { continue };
                w.inflight == 0
                    && w.wbatch.is_empty()
                    && now.saturating_sub(w.last_used) >= timeout
            };
            if idle {
                self.free_window(slot);
                self.stats.windows_reclaimed += 1;
            }
        }
    }

    /// `send(fd, buf, len, FLAGS)` — Fig 3. Adaptive path: small → SEND,
    /// large → WRITE(+imm) per the selector; `FLAGS` pins components.
    /// Destinations the [`TransportManager`] has migrated (and unpinned
    /// `Flags::UD` traffic) ride the host-wide UD QP instead, fragmented
    /// at the MTU.
    pub fn send(
        &mut self,
        sim: &mut Sim,
        conn: Vqpn,
        len: u64,
        flags: Flags,
        tag: u64,
        remote_load: HostLoad,
    ) -> Result<Verb, RaasError> {
        self.charge_submit(sim);
        let entry = self.conns.lookup(conn).ok_or(RaasError::UnknownConnection)?;
        let (remote, peer_vqpn) = (entry.remote, entry.peer_vqpn);
        // first use establishes any lazily deferred leases (and thereby
        // registers the destination with the migration engine)
        self.ensure_creds(sim, remote.0)?;
        let local_load = self.load(sim);
        let mtu = sim.cfg.mtu;
        // only fully migrated destinations route new sends onto UD; a
        // draining destination keeps RC so per-connection order holds
        // across the transition (see [`super::migrate`])
        let prefer_ud = self.migrate.state_of(remote.0) == DestState::Ud;
        let choice =
            self.selector
                .choose_adaptive(len, flags, local_load, remote_load, mtu, prefer_ud)?;
        if choice.transport == QpTransport::Ud {
            return self.send_ud(sim, conn, remote, peer_vqpn, len);
        }

        let lease = self.stage_payload(sim, len)?;

        let epoch = self.epoch_of(remote.0);
        let wr_id = self.ops.insert(
            conn,
            InflightOp {
                lease,
                deliver_copy: false,
                opened_at: sim.now(),
                rc_remote: Some(remote.0),
                ud_msg_len: None,
                window: None,
                wgroup: None,
                epoch,
                wr: None,
            },
        );
        // `send` pushes data: a READ preference from the selector (local
        // host busier than remote) degrades to WRITE — pull-mode is only
        // available through the explicit `read` entry point.
        let verb = if choice.verb == Verb::Read { Verb::Write } else { choice.verb };
        let wr = match verb {
            Verb::Send => {
                // two-sided: vQPN rides in imm_data (Fig 4)
                SendWr::send(wr_id, len, self.pool.mr.key, lease.addr, peer_vqpn.0)
            }
            Verb::Write => {
                // large adaptive sends become WRITE-with-imm into the peer's
                // pool so the peer still gets a consumer notification
                let rp = match self.remote_pools.get(remote.0) {
                    Some(rp) => *rp,
                    None => {
                        let op = self.ops.take(wr_id).expect("just inserted");
                        self.pool.release(op.lease);
                        return Err(RaasError::UnknownConnection);
                    }
                };
                let lease_off = lease.addr - self.pool.mr.addr;
                let dst = lease_off % rp.len.max(1);
                SendWr::write(wr_id, len, self.pool.mr.key, lease.addr, rp.rkey, rp.base + dst)
                    .with_imm(peer_vqpn.0)
            }
            Verb::Read => unreachable!("degraded above"),
        };
        if self.cfg.heal_max_attempts > 0 {
            if let Some(op) = self.ops.get_mut(wr_id) {
                op.wr = Some(wr);
            }
        }
        self.stats.sent_rc += 1;
        self.enqueue_wr(sim, remote, wr, tag)?;
        Ok(verb)
    }

    /// Stage an outgoing payload into the registered pool: pick the
    /// memcpy-vs-memreg strategy [9], charge its CPU cost, and lease a
    /// slot (shared by the RC and UD send paths).
    fn stage_payload(&mut self, sim: &mut Sim, len: u64) -> Result<Lease, RaasError> {
        let staging = self.cfg.staging.choose(len);
        let cost = self.cfg.staging.cost_ns(staging, len);
        sim.node_mut(self.node).cpu.charge(cost);
        match staging {
            Staging::Memcpy => self.stats.send_staged_memcpy += 1,
            Staging::Memreg => self.stats.send_staged_memreg += 1,
        }
        self.pool.lease(len.max(1)).ok_or(RaasError::PoolExhausted)
    }

    /// Datagram-mode send: fragment at the MTU, stamp each fragment with
    /// the per-vQPN sequence header ([`pack_ud_imm`]), post the chain to
    /// the host-wide UD QP. Only the last fragment is signaled, so the
    /// initiator sees exactly one completion (and the one staging lease is
    /// released) per logical message.
    fn send_ud(
        &mut self,
        sim: &mut Sim,
        conn: Vqpn,
        remote: NodeId,
        peer_vqpn: Vqpn,
        len: u64,
    ) -> Result<Verb, RaasError> {
        let mtu = sim.cfg.mtu;
        let max = ud_max_msg_bytes(mtu);
        if len > max {
            return Err(RaasError::TooLong { len, max });
        }
        let ud_peer = *self
            .remote_ud
            .get(remote.0)
            .ok_or(RaasError::UnknownConnection)?;

        let lease = self.stage_payload(sim, len)?;

        let nfrags = len.div_ceil(mtu).max(1);
        // mod-64 message tag: lets the peer's reassembler reject a
        // fragment train spliced across two messages after losses
        let msg_tag = {
            let c = self.ud_msg_counter.entry_or_default(conn.0);
            let tag = *c;
            *c = (*c + 1) % super::migrate::UD_MSG_MOD as u8;
            tag
        };
        // one slab entry per logical message, stamped on the signaled
        // LAST fragment; unsignaled fragments never produce a CQE, so
        // they carry the untracked (null-slot) wr_id form
        let last_wr_id = self.ops.insert(
            conn,
            InflightOp {
                lease,
                deliver_copy: false,
                opened_at: sim.now(),
                rc_remote: None,
                ud_msg_len: if nfrags > 1 { Some(len) } else { None },
                window: None,
                wgroup: None,
                epoch: 0, // the host-wide UD QP is never parked
                wr: None,
            },
        );
        for k in 0..nfrags {
            let last = k == nfrags - 1;
            let frag_len = if last { len - k * mtu } else { mtu };
            let wr_id = if last { last_wr_id } else { untracked_wr_id(conn) };
            let imm = pack_ud_imm(peer_vqpn, msg_tag, k as u16, last);
            let mut wr =
                SendWr::send(wr_id, frag_len, self.pool.mr.key, lease.addr + k * mtu, imm)
                    .to_ud(remote, ud_peer);
            if !last {
                wr = wr.unsignaled();
            }
            self.telemetry.charge(self.cfg.shm.ring_pop_ns + self.cfg.wr_build_ns);
            self.ud_pending.push(wr);
        }
        self.stats.sent_ud += 1;
        self.stats.ud_fragments += nfrags;
        if self.ud_pending.len() >= self.cfg.batch_max {
            self.flush_ud(sim)?;
        }
        Ok(Verb::Send)
    }

    /// Flush the pending UD fragment batch — one doorbell, bounded by the
    /// UD QP's free SQ slots (leftovers stay pending: daemon-side
    /// backpressure, same as the RC batches).
    fn flush_ud(&mut self, sim: &mut Sim) -> Result<(), RaasError> {
        if self.ud_pending.is_empty() {
            return Ok(());
        }
        let free = sim.sq_free(self.node, self.ud_qp);
        if free == 0 {
            return Ok(());
        }
        let take = self.ud_pending.len().min(free);
        let wrs: Vec<SendWr> = self.ud_pending.drain(..take).collect();
        self.stats.batches_posted += 1;
        self.stats.wrs_posted += wrs.len() as u64;
        sim.post_send_batch(self.node, self.ud_qp, wrs)
            .map_err(|e| RaasError::Fabric(e.to_string()))?;
        Ok(())
    }

    /// Worker-side: append to the per-remote batch; flush at batch_max.
    /// All WRs through here ride a shared RC QP, so they are accounted as
    /// in-flight RC work for the migration engine's drain bookkeeping
    /// (the per-op remote also lives in the op's slab entry).
    fn enqueue_wr(
        &mut self,
        sim: &mut Sim,
        remote: NodeId,
        wr: SendWr,
        _tag: u64,
    ) -> Result<(), RaasError> {
        self.telemetry.charge(self.cfg.shm.ring_pop_ns + self.cfg.wr_build_ns);
        self.migrate.on_rc_submitted(remote.0);
        // a healing remote has no entry in `shared_qps` while parked, so
        // the inline flush would error out the submit: queue instead —
        // the batch drains once the heal re-establishes the QP
        let healing = self.is_healing(remote.0);
        let batch = self.pending.entry_or_default(remote.0);
        if batch.is_empty() {
            self.dirty_remotes.push(remote.0);
        }
        batch.push(wr);
        if batch.len() >= self.cfg.batch_max && !healing {
            self.flush_remote(sim, remote)?;
        }
        Ok(())
    }

    fn flush_remote(&mut self, sim: &mut Sim, remote: NodeId) -> Result<(), RaasError> {
        let qpn = match self.shared_qps.get(remote.0) {
            Some(q) => *q,
            None => return Err(RaasError::UnknownConnection),
        };
        // never overrun the SQ: post what fits, keep the rest pending
        // (the Worker retries on the next pump — daemon-side backpressure)
        let free = sim.sq_free(self.node, qpn);
        let Some(batch) = self.pending.get_mut(remote.0) else {
            return Ok(());
        };
        if batch.is_empty() || free == 0 {
            return Ok(());
        }
        let take = batch.len().min(free);
        let wrs: Vec<SendWr> = batch.drain(..take).collect();
        let n = wrs.len() as u64;
        self.stats.batches_posted += 1;
        self.stats.wrs_posted += n;
        sim.post_send_batch(self.node, qpn, wrs)
            .map_err(|e| RaasError::Fabric(e.to_string()))?;
        Ok(())
    }

    /// One Worker+Poller iteration: flush batches, drain CQs, deliver.
    /// Drivers call this each loop turn (it is what the daemon's service
    /// threads do continuously in the live implementation).
    pub fn pump(&mut self, sim: &mut Sim) {
        // self-healing first: a due re-establishment puts the QP back in
        // `shared_qps` and splices its replay WRs at the FRONT of the
        // pending batch, so the flush loop below posts them this pump
        self.heal_pump(sim);
        // Worker: coalesced window-WRITE groups first — their doorbell
        // flush appends to the per-remote batches the next loop posts
        // (submission order, like everything below)
        let wslots = std::mem::take(&mut self.dirty_windows);
        for s in wslots {
            let _ = self.flush_window(sim, s);
        }
        // flush batches that received WRs since the last pump
        // (submission order — deterministic); a batch the SQ couldn't
        // absorb stays dirty for the next pump
        let remotes = std::mem::take(&mut self.dirty_remotes);
        for r in remotes {
            let _ = self.flush_remote(sim, NodeId(r));
            if self.pending.get(r).is_some_and(|b| !b.is_empty()) {
                self.dirty_remotes.push(r);
            }
        }
        let _ = self.flush_ud(sim);
        // Poller: drain both CQs through the reusable scratch buffer (the
        // buffer is moved out while CQE handlers run, then handed back —
        // no allocation once it reaches its high-water capacity)
        let mut buf = std::mem::take(&mut self.cqe_buf);
        // send-side completions
        loop {
            buf.clear();
            if sim.poll_cq_into(self.node, self.send_cq, 64, &mut buf) == 0 {
                break;
            }
            for cqe in buf.drain(..) {
                self.on_send_cqe(sim, cqe);
            }
        }
        // receive-side (two-sided arrivals)
        loop {
            buf.clear();
            if sim.poll_cq_into(self.node, self.recv_cq, 64, &mut buf) == 0 {
                break;
            }
            for cqe in buf.drain(..) {
                self.on_recv_cqe(sim, cqe);
            }
        }
        self.cqe_buf = buf;
        // fault hygiene: stale reassembly partials and orphaned leases
        // (both disabled at timeout 0 — the fault-free default)
        self.reassembly
            .expire_stale(sim.now(), Ns(self.cfg.reassembly_timeout_ns));
        self.reclaim_stale_leases(sim);
        self.reclaim_stale_windows(sim);
        // park drained remotes whose last vQPN closed (PR 7)
        self.sweep_parting(sim);
        // SRQ refill
        Self::fill_srq(sim, self.node, self.srq, &mut self.pool, &self.cfg, &mut self.srq_wr_seq);
        self.telemetry.pool_pressure = self.pool.pressure();
        // migration signals: sample the NIC cache, re-evaluate destinations
        self.sample_migration(sim);
    }

    /// Release staging leases whose completion never came (the op's CQE
    /// died with a node restart, or the fabric lost it beyond recovery),
    /// reporting the op failed to its app so closed loops keep moving.
    /// The slab iterates in slot order — a fixed, deterministic inbox
    /// delivery order. Taking the op bumps its slot generation, so a
    /// completion that limps in later misses the slab and is dropped.
    fn reclaim_stale_leases(&mut self, sim: &mut Sim) {
        if self.cfg.lease_timeout_ns == 0 || self.ops.is_empty() {
            return;
        }
        let now = sim.now();
        let timeout = Ns(self.cfg.lease_timeout_ns);
        let stale: Vec<u64> = self
            .ops
            .iter()
            .filter(|(_, op)| now.saturating_sub(op.opened_at) >= timeout)
            .map(|(id, _)| id)
            .collect();
        for wr_id in stale {
            self.fail_op(wr_id, true);
        }
    }

    /// Fail one in-flight op without a completion: take it from the slab
    /// (bumping the slot generation, so its late CQE — if one ever
    /// arrives — is dropped), keep the migration drain ledger honest,
    /// release or route its lease, and report `ok: false` to the owning
    /// app. Shared by the stale-lease reclaim (`reclaim` counts the
    /// lease as reclaimed) and the disconnect fail-fast path.
    fn fail_op(&mut self, wr_id: u64, reclaim: bool) {
        let Some(op) = self.ops.take(wr_id) else { return };
        // keep the migration drain ledger honest: the RC WR is gone
        if let Some(remote) = op.rc_remote {
            self.migrate.on_rc_completed(remote);
        }
        let vqpn = crate::raas::vqpn::unpack_vqpn(wr_id);
        let app = self.conns.lookup(vqpn).map(|e| e.app);
        if let Some(slot) = op.window {
            // the lease belongs to the window, so nothing is released
            // here (and `leases_reclaimed` does not count): report
            // each logical op failed and let the window drain —
            // `reclaim_stale_windows` frees abandoned slots later
            if let Some(g) = op.wgroup {
                let tags = std::mem::take(&mut self.wgroups[g as usize]);
                self.wgroup_free.push(g);
                for &(tag, _wlen) in &tags {
                    self.stats.ops_failed += 1;
                    self.telemetry.ops_failed += 1;
                    if let Some(app) = app {
                        self.telemetry.charge(self.cfg.shm.ring_push_ns);
                        self.inbox_mut(app).push_back(Delivery::OpComplete {
                            conn: vqpn,
                            tag,
                            len: 0,
                            ok: false,
                        });
                    }
                }
                self.window_op_done(slot, tags.len() as u32);
            } else {
                self.stats.ops_failed += 1;
                self.telemetry.ops_failed += 1;
                if let Some(app) = app {
                    self.telemetry.charge(self.cfg.shm.ring_push_ns);
                    self.inbox_mut(app).push_back(Delivery::OpComplete {
                        conn: vqpn,
                        tag: wr_id,
                        len: 0,
                        ok: false,
                    });
                }
                self.window_op_done(slot, 1);
            }
            return;
        }
        self.pool.release(op.lease);
        if reclaim {
            self.stats.leases_reclaimed += 1;
        }
        self.stats.ops_failed += 1;
        self.telemetry.ops_failed += 1;
        if let Some(app) = app {
            self.telemetry.charge(self.cfg.shm.ring_push_ns);
            self.inbox_mut(app).push_back(Delivery::OpComplete {
                conn: vqpn,
                tag: wr_id,
                len: 0,
                ok: false,
            });
        }
    }

    // ------------------------------------------------------ self-healing

    /// Is `remote`'s shared QP currently parked by a heal cycle?
    fn is_healing(&self, remote: u32) -> bool {
        self.heals.iter().any(|h| h.remote == remote && h.parked)
    }

    /// Remotes currently in a heal cycle, parked or probing (test hook).
    pub fn heals_active(&self) -> usize {
        self.heals.len()
    }

    /// Backoff before re-establishment attempt `attempts`: doubles per
    /// attempt, capped at `heal_backoff_cap_ns`.
    fn heal_backoff(&self, attempts: u32) -> u64 {
        (self.cfg.heal_backoff_ns << attempts.min(16)).min(self.cfg.heal_backoff_cap_ns)
    }

    /// `RetryExceeded` intercept: move the op (already taken from the
    /// slab) into the heal ledger instead of failing it. Returns the op
    /// back when healing does not apply — disabled, a window op (windows
    /// have their own teardown story), a WR-less op, or a remote with no
    /// shared QP left to park — and the caller surfaces the plain
    /// `ok: false`. None means the op was consumed: stashed for replay,
    /// or settled by a give-up.
    fn try_stash_heal(
        &mut self,
        sim: &mut Sim,
        wr_id: u64,
        op: InflightOp,
    ) -> Option<InflightOp> {
        if self.cfg.heal_max_attempts == 0 || op.window.is_some() || op.wr.is_none() {
            return Some(op);
        }
        let Some(remote) = op.rc_remote else { return Some(op) };
        let now = sim.now();
        let vqpn = crate::raas::vqpn::unpack_vqpn(wr_id);
        let Some(i) = self.heals.iter().position(|h| h.remote == remote) else {
            // first failure of this cycle: pull the QP out of service —
            // NOT into the LRU reuse pool, where an eviction mid-heal
            // would destroy the only path back, and with NO epoch bump:
            // sibling RetryExceeded CQEs from the same flushed batch
            // must still pass the epoch gate to land here
            let Some(qpn) = self.shared_qps.remove(remote) else {
                return Some(op);
            };
            // the WR is off the wire either way; replay re-submits it
            self.migrate.on_rc_completed(remote);
            let backoff = self.heal_backoff(0);
            self.heals.push(HealState {
                remote,
                qpn,
                attempts: 0,
                next_at: now + Ns(backoff),
                parked_at: now,
                parked: true,
                replay: vec![(vqpn, op)],
            });
            return None;
        };
        self.migrate.on_rc_completed(remote);
        if self.heals[i].parked {
            // sibling failure from the same flushed batch
            self.heals[i].replay.push((vqpn, op));
            return None;
        }
        // the re-established QP died again: re-park with a doubled
        // backoff, or give up once the attempt budget is spent
        let attempts = self.heals[i].attempts + 1;
        if attempts >= self.cfg.heal_max_attempts {
            let h = self.heals.remove(i);
            self.stats.heal_giveups += 1;
            // only NOW does the failure surface (`ok: false`); the QP
            // stays in service, so a later RetryExceeded starts a fresh
            // cycle rather than wedging the remote forever
            self.fail_healed_op(vqpn, op);
            for (v, o) in h.replay {
                self.fail_healed_op(v, o);
            }
            return None;
        }
        let backoff = self.heal_backoff(attempts);
        self.shared_qps.remove(remote);
        let h = &mut self.heals[i];
        h.attempts = attempts;
        h.parked = true;
        h.parked_at = now;
        h.next_at = now + Ns(backoff);
        h.replay.push((vqpn, op));
        None
    }

    /// Surface one heal-stashed op as failed. Its slab slot is long gone
    /// and its drain-ledger entry was settled at stash time, so — unlike
    /// [`Daemon::fail_op`] — only the lease release and the app delivery
    /// happen here.
    fn fail_healed_op(&mut self, vqpn: Vqpn, op: InflightOp) {
        self.pool.release(op.lease);
        self.stats.ops_failed += 1;
        self.telemetry.ops_failed += 1;
        let tag = op.wr.map_or(0, |w| w.wr_id);
        if let Some(entry) = self.conns.lookup(vqpn) {
            let app = entry.app;
            self.telemetry.charge(self.cfg.shm.ring_push_ns);
            self.inbox_mut(app).push_back(Delivery::OpComplete {
                conn: vqpn,
                tag,
                len: 0,
                ok: false,
            });
        }
    }

    /// A successful RC completion for `remote`: a heal in its probing
    /// phase concludes — the re-established path carries traffic again.
    fn heal_concluded(&mut self, remote: u32) {
        if self.heals.is_empty() {
            return;
        }
        if let Some(i) = self.heals.iter().position(|h| h.remote == remote && !h.parked) {
            self.heals.remove(i);
        }
    }

    /// Worker pre-step: re-establish healing QPs whose backoff expired
    /// (failure order — deterministic).
    fn heal_pump(&mut self, sim: &mut Sim) {
        if self.heals.is_empty() {
            return;
        }
        let now = sim.now();
        let due: Vec<u32> = self
            .heals
            .iter()
            .filter(|h| h.parked && now >= h.next_at)
            .map(|h| h.remote)
            .collect();
        for remote in due {
            self.revive_healed(sim, remote);
        }
    }

    /// Put a healed QP back in service and queue its replay. The pair
    /// never left the fabric, so revival is the same bookkeeping as a
    /// reuse-pool hit (PR 7) and is priced as one.
    fn revive_healed(&mut self, sim: &mut Sim, remote: u32) {
        let now = sim.now();
        let Some(i) = self.heals.iter().position(|h| h.remote == remote) else {
            return;
        };
        let (qpn, replay, parked_at) = {
            let h = &mut self.heals[i];
            h.parked = false;
            (h.qpn, std::mem::take(&mut h.replay), h.parked_at)
        };
        self.shared_qps.insert(remote, qpn);
        self.charge_ctrl(sim, self.cfg.qp_reuse_ns);
        self.stats.qp_reestablished += 1;
        self.stats.backoff_ns += now.saturating_sub(parked_at).0;
        let mut wrs: Vec<SendWr> = Vec::with_capacity(replay.len());
        for (vqpn, mut op) in replay {
            // fresh slab entry (the old slot's generation was bumped
            // when the RetryExceeded CQE took it), fresh stale-lease
            // clock; the lease and epoch stamp ride along untouched
            op.opened_at = now;
            let mut wr = op.wr.expect("heal-stashed ops carry their WR");
            let id = self.ops.insert(vqpn, op);
            wr.wr_id = id;
            if let Some(stored) = self.ops.get_mut(id) {
                stored.wr = Some(wr);
            }
            self.telemetry.charge(self.cfg.wr_build_ns);
            self.migrate.on_rc_submitted(remote);
            wrs.push(wr);
        }
        if !wrs.is_empty() {
            let batch = self.pending.entry_or_default(remote);
            if batch.is_empty() {
                self.dirty_remotes.push(remote);
            }
            // replay goes ahead of anything queued during the park
            batch.splice(0..0, wrs);
        }
    }

    /// Force-release a window at disconnect: pending (never-posted)
    /// coalesced WRITEs fail, the token is invalidated, and the standing
    /// lease returns once nothing remains in flight — the disconnect op
    /// sweep has already drained the window's slab entries.
    fn fail_window(&mut self, slot: u32) {
        let (conn, tags, inflight) = {
            let Some(w) = self.windows.get_mut(slot as usize).and_then(|s| s.entry.as_mut())
            else {
                return;
            };
            w.closed = true;
            w.wbatch.clear();
            (w.conn, std::mem::take(&mut w.wtags), w.inflight)
        };
        let app = self.conns.lookup(conn).map(|e| e.app);
        for (tag, _wlen) in tags {
            self.stats.ops_failed += 1;
            self.telemetry.ops_failed += 1;
            if let Some(app) = app {
                self.telemetry.charge(self.cfg.shm.ring_push_ns);
                self.inbox_mut(app).push_back(Delivery::OpComplete {
                    conn,
                    tag,
                    len: 0,
                    ok: false,
                });
            }
        }
        self.stats.windows_released += 1;
        if inflight == 0 {
            self.free_window(slot);
        }
    }

    /// Fold the NIC's ICM counters into telemetry at the configured
    /// cadence and let the migration engine re-evaluate every
    /// destination. The very first pump evaluates immediately (structural
    /// pressure needs no observation window), so a freshly connected
    /// thousand-destination daemon migrates its tail before flooding the
    /// cache rather than after.
    fn sample_migration(&mut self, sim: &Sim) {
        self.telemetry.active_qps = self.shared_qps.len() as u32 + 1;
        if !self.cfg.migration.enabled {
            return;
        }
        let now = sim.now();
        let cache = &sim.node(self.node).cache;
        let capacity = sim.cfg.nic.icm_cache_entries;
        match self.icm_sample {
            None => {
                self.migrate.evaluate(capacity, now);
                self.icm_sample = Some((now, cache.hits, cache.misses));
            }
            Some((t0, h0, m0)) => {
                if cache.hits < h0 || cache.misses < m0 {
                    // someone reset the cache stats: rebase the window
                    self.icm_sample = Some((now, cache.hits, cache.misses));
                    return;
                }
                if now.saturating_sub(t0).0 < self.cfg.migration.sample_ns {
                    return;
                }
                let rate = self.telemetry.sample_icm(cache.hits - h0, cache.misses - m0);
                self.migrate.observe_hit_rate(rate);
                self.migrate.evaluate(capacity, now);
                self.icm_sample = Some((now, cache.hits, cache.misses));
            }
        }
    }

    /// The Poller's per-completion hot path: ONE slab index resolves the
    /// op (lease, drain ledger, UD logical length, late-completion dedup
    /// via the generation check) and ONE conn-table index routes the
    /// delivery — zero hashing, zero allocation.
    fn on_send_cqe(&mut self, sim: &mut Sim, cqe: Cqe) {
        self.telemetry.charge(self.cfg.demux_ns);
        let Some(op) = self.ops.take(cqe.wr_id) else {
            // stale generation / vacated slot: the stale-lease reclaim
            // already reported this op failed and released its lease;
            // drop the late completion so the app never sees two
            // OpCompletes for one op
            return;
        };
        if let Some(remote) = op.rc_remote {
            if op.epoch != self.epoch_of(remote) {
                // stamped under a previous tenant generation of a
                // since-parked (possibly revived) QP: the epoch gate
                // guarantees cross-tenant isolation even if the op
                // somehow outlived its disconnect sweep. The drain
                // ledger was settled when the op was failed, so no
                // double decrement here.
                self.stats.stale_epoch_drops += 1;
                if op.window.is_none() {
                    self.pool.release(op.lease);
                }
                if let Some(g) = op.wgroup {
                    self.wgroups[g as usize].clear();
                    self.wgroup_free.push(g);
                }
                return;
            }
        }
        let op = if cqe.status == WcStatus::RetryExceeded {
            // self-healing (DESIGN.md §15): instead of surfacing the
            // retry exhaustion, park the shared QP and stash the op for
            // replay through the re-established pair
            match self.try_stash_heal(sim, cqe.wr_id, op) {
                Some(op) => op, // not healable: fall through to ok:false
                None => return, // stashed (or settled by a heal give-up)
            }
        } else {
            op
        };
        if let Some(slot) = op.window {
            return self.on_window_cqe(sim, cqe, op, slot);
        }
        let vqpn = crate::raas::vqpn::unpack_vqpn(cqe.wr_id);
        let ok = cqe.status == WcStatus::Success;
        // a fragmented UD message's CQE carries only the last fragment's
        // length; report the logical message length to the app
        let len = op.ud_msg_len.unwrap_or(cqe.len);
        if let Some(remote) = op.rc_remote {
            self.migrate.on_rc_completed(remote);
            if ok {
                self.heal_concluded(remote);
            }
        }
        if op.deliver_copy && ok {
            // copy read payload out to the app's private buffer
            sim.node_mut(self.node).cpu.charge_memcpy(cqe.len, 10.0);
        }
        self.pool.release(op.lease);
        self.stats.ops_completed += 1;
        self.telemetry.ops_completed += 1;
        if ok {
            self.stats.bytes_completed += len;
        } else {
            self.stats.ops_failed += 1;
            self.telemetry.ops_failed += 1;
        }
        if let Some(entry) = self.conns.lookup(vqpn) {
            let app = entry.app;
            self.telemetry.charge(self.cfg.shm.ring_push_ns);
            self.inbox_mut(app).push_back(Delivery::OpComplete {
                conn: vqpn,
                tag: cqe.wr_id,
                len,
                ok,
            });
        }
    }

    /// Window-op completion: the standing lease stays with the window
    /// (nothing to release per-op). A coalesced-WRITE group's single CQE
    /// fans out into one OpComplete per logical WRITE, stamped with the
    /// user tags recorded at submit; a window READ completes like a plain
    /// read minus the lease release. Either way the window's in-flight
    /// count drops, which may finish a deferred teardown.
    fn on_window_cqe(&mut self, sim: &mut Sim, cqe: Cqe, op: InflightOp, slot: u32) {
        let vqpn = crate::raas::vqpn::unpack_vqpn(cqe.wr_id);
        let ok = cqe.status == WcStatus::Success;
        if let Some(remote) = op.rc_remote {
            self.migrate.on_rc_completed(remote);
            if ok {
                self.heal_concluded(remote);
            }
        }
        let app = self.conns.lookup(vqpn).map(|e| e.app);
        if let Some(g) = op.wgroup {
            let tags = std::mem::take(&mut self.wgroups[g as usize]);
            self.wgroup_free.push(g);
            for &(tag, wlen) in &tags {
                self.stats.ops_completed += 1;
                self.telemetry.ops_completed += 1;
                if ok {
                    self.stats.bytes_completed += wlen;
                } else {
                    self.stats.ops_failed += 1;
                    self.telemetry.ops_failed += 1;
                }
                if let Some(app) = app {
                    self.telemetry.charge(self.cfg.shm.ring_push_ns);
                    self.inbox_mut(app).push_back(Delivery::OpComplete {
                        conn: vqpn,
                        tag,
                        len: wlen,
                        ok,
                    });
                }
            }
            self.window_op_done(slot, tags.len() as u32);
        } else {
            if op.deliver_copy && ok {
                sim.node_mut(self.node).cpu.charge_memcpy(cqe.len, 10.0);
            }
            self.stats.ops_completed += 1;
            self.telemetry.ops_completed += 1;
            if ok {
                self.stats.bytes_completed += cqe.len;
            } else {
                self.stats.ops_failed += 1;
                self.telemetry.ops_failed += 1;
            }
            if let Some(app) = app {
                self.telemetry.charge(self.cfg.shm.ring_push_ns);
                self.inbox_mut(app).push_back(Delivery::OpComplete {
                    conn: vqpn,
                    tag: cqe.wr_id,
                    len: cqe.len,
                    ok,
                });
            }
            self.window_op_done(slot, 1);
        }
    }

    fn on_recv_cqe(&mut self, sim: &mut Sim, cqe: Cqe) {
        self.telemetry.charge(self.cfg.demux_ns);
        let Some(imm) = cqe.imm_data else { return };
        // UD arrivals land on the host-wide UD QP; their imm carries the
        // fragment header, not a bare vQPN — reassemble before delivery.
        let vqpn = if cqe.qpn == self.ud_qp {
            let (vqpn, msg, seq, last) = unpack_ud_imm(imm);
            match self.reassembly.accept(vqpn, msg, seq, last, cqe.len, sim.now()) {
                Some(total) => return self.deliver_message(sim, vqpn, total),
                None => return, // mid-message fragment (or datagram drop)
            }
        } else {
            Vqpn(imm)
        };
        self.deliver_message(sim, vqpn, cqe.len)
    }

    /// Route a fully received two-sided message to its owning app's
    /// completion ring.
    fn deliver_message(&mut self, sim: &mut Sim, vqpn: Vqpn, len: u64) {
        let Some(entry) = self.conns.lookup(vqpn) else { return };
        let app = entry.app;
        // deliver: default path copies out of the shared pool; zero-copy
        // apps read in place (recv_zero_copy — Fig 3)
        self.stats.msgs_delivered += 1;
        self.telemetry.charge(self.cfg.shm.ring_push_ns);
        self.inbox_mut(app).push_back(Delivery::Message {
            conn: vqpn,
            len,
            zero_copy: false,
        });
        let _ = sim; // copy cost charged at recv()/recv_zero_copy()
    }

    /// `recv(fd, buf, len, FLAGS)` — pops the next delivery for `app`,
    /// charging the copy-out.
    pub fn recv(&mut self, sim: &mut Sim, app: u32) -> Option<Delivery> {
        let d = self.inboxes.get_mut(app as usize)?.pop_front()?;
        sim.node_mut(self.node).cpu.charge(self.cfg.shm.ring_pop_ns);
        if let Delivery::Message { len, .. } = d {
            sim.node_mut(self.node).cpu.charge_memcpy(len, 10.0);
        }
        Some(d)
    }

    /// `recv_zero_copy(fd, &buf_addr, len, FLAGS)` — no copy-out; the app
    /// reads the registered buffer in place (Fig 3's blocking-mode path).
    pub fn recv_zero_copy(&mut self, sim: &mut Sim, app: u32) -> Option<Delivery> {
        let mut d = self.inboxes.get_mut(app as usize)?.pop_front()?;
        sim.node_mut(self.node).cpu.charge(self.cfg.shm.ring_pop_ns);
        if let Delivery::Message { ref mut zero_copy, .. } = d {
            *zero_copy = true;
        }
        Some(d)
    }

    /// Pending deliveries for an app (diagnostics).
    pub fn inbox_len(&self, app: u32) -> usize {
        self.inboxes.get(app as usize).map(|q| q.len()).unwrap_or(0)
    }

    /// Shared QPs this daemon holds (one per active remote node).
    pub fn shared_qp_count(&self) -> usize {
        self.shared_qps.len()
    }

    /// The host-wide UD QP every migrated destination shares.
    pub fn ud_qpn(&self) -> Qpn {
        self.ud_qp
    }

    /// Fraction of `send()` calls that rode the UD QP (0 when idle).
    pub fn ud_send_fraction(&self) -> f64 {
        let total = self.stats.sent_rc + self.stats.sent_ud;
        if total == 0 {
            0.0
        } else {
            self.stats.sent_ud as f64 / total as f64
        }
    }

    /// Rolled-up resource usage (Figs 7/8/12).
    pub fn snapshot(&self, sim: &Sim) -> super::telemetry::ResourceSnapshot {
        let node = sim.node(self.node);
        let conn_table_bytes = self.conns.table_mem_bytes();
        super::telemetry::ResourceSnapshot {
            mem_bytes: self.telemetry.ring_bytes()
                + self.pool.hwm_bytes()
                + node.fabric_mem_bytes()
                + conn_table_bytes,
            conn_table_bytes,
            cpu_cores: self.telemetry.cpu_cores(sim.now())
                + node.cpu.busy_ns as f64 / sim.now().0.max(1) as f64,
            apps: self.telemetry.sessions.len() as u32,
            conns: self.conns.active() as u32,
            shared_qps: self.shared_qps.len() as u32,
        }
    }
}

/// Control plane: open a logical connection from `daemons[a]` (app
/// `a_app`) to the listener on `port` at `daemons[b]`. Reuses the shared
/// QP between the two machines if it exists, else creates it (§2.3).
/// Mirrors `connect()` + `accept()` of Fig 3 for the in-sim deployment.
pub fn connect_via(
    sim: &mut Sim,
    daemons: &mut [Daemon],
    a: usize,
    a_app: u32,
    b: usize,
    port: u16,
) -> Result<Vqpn, RaasError> {
    assert_ne!(a, b, "loopback connections don't need RDMA");
    // split borrows
    let (da, db) = if a < b {
        let (l, r) = daemons.split_at_mut(b);
        (&mut l[a], &mut r[0])
    } else {
        let (l, r) = daemons.split_at_mut(a);
        (&mut r[0], &mut l[b])
    };

    let b_app = db
        .listeners
        .iter()
        .find(|(p, _)| *p == port)
        .map(|&(_, app)| app)
        .ok_or(RaasError::UnknownConnection)?;

    // shared QP pair between the machines, created once — or revived
    // from both sides' reuse pools when the pair churned recently
    // (PR 7 tentpole: the pooled path skips the full RC handshake)
    if da.shared_qps.get(db.node.0).is_none() {
        match (da.take_parked(db.node.0), db.take_parked(da.node.0)) {
            (Some(qa), Some(qb)) => {
                // revival is pure bookkeeping: the pair is still
                // connected in the fabric, and the park-time epoch bump
                // already fenced off the previous tenants' completions
                da.shared_qps.insert(db.node.0, qa);
                db.shared_qps.insert(da.node.0, qb);
                da.stats.qp_reused += 1;
                db.stats.qp_reused += 1;
                da.charge_ctrl(sim, da.cfg.qp_reuse_ns);
                db.charge_ctrl(sim, db.cfg.qp_reuse_ns);
            }
            (pa, pb) => {
                // a one-sided leftover cannot be revived (its peer half
                // is gone): destroy it and do the full handshake
                if let Some(q) = pa {
                    sim.destroy_qp(da.node, q);
                    da.stats.qp_evicted += 1;
                }
                if let Some(q) = pb {
                    sim.destroy_qp(db.node, q);
                    db.stats.qp_evicted += 1;
                }
                let qa = sim.create_qp(
                    da.node,
                    crate::fabric::types::QpTransport::Rc,
                    da.send_cq,
                    da.recv_cq,
                );
                let qb = sim.create_qp(
                    db.node,
                    crate::fabric::types::QpTransport::Rc,
                    db.send_cq,
                    db.recv_cq,
                );
                sim.connect(da.node, qa, db.node, qb);
                sim.attach_srq(da.node, qa, da.srq);
                sim.attach_srq(db.node, qb, db.srq);
                da.shared_qps.insert(db.node.0, qa);
                // an asymmetric teardown (faults) can leave `db` holding
                // a half-pair whose peer is gone: replace and destroy it
                if let Some(old) = db.shared_qps.insert(da.node.0, qb) {
                    sim.destroy_qp(db.node, old);
                    db.stats.qp_evicted += 1;
                }
                da.stats.handshakes_full += 1;
                db.stats.handshakes_full += 1;
                da.charge_ctrl(sim, da.cfg.handshake_ns);
                db.charge_ctrl(sim, db.cfg.handshake_ns);
            }
        }
        // credential/lease exchange (pool addressing + UD QPN +
        // migration registration): eager daemons install now, lazy
        // daemons stash the offer and pay at first use
        offer_creds(sim, da, db);
        offer_creds(sim, db, da);
    }

    // allocate the vQPN pair — under lazy leases this registration is
    // the ENTIRE marginal cost of an idle tenant
    da.charge_ctrl(sim, da.cfg.shm.ring_push_ns);
    db.charge_ctrl(sim, db.cfg.shm.ring_push_ns);
    let va = da.conns.open(a_app, db.node, Vqpn(0));
    let vb = db.conns.open(b_app, da.node, va);
    da.conns.set_peer(va, vb);
    db.accept_queue_mut(b_app, port).push_back(vb);
    db.inbox_mut(b_app);
    Ok(va)
}

/// Hand `from`'s pool/UD credentials to `to`. Eager daemons install and
/// register the destination immediately (one lease-establishment control
/// message); lazy daemons stash the offer in the deferred backlog, to be
/// established — batched — on first use ([`Daemon::ensure_creds`]).
fn offer_creds(sim: &mut Sim, to: &mut Daemon, from: &Daemon) {
    let creds = OfferedCreds {
        pool: RemotePool {
            rkey: from.pool.mr.key,
            base: from.pool.mr.addr,
            len: from.pool.mr.len,
        },
        ud: from.ud_qp,
    };
    if to.cfg.lazy_leases {
        if to.offered_creds.get(from.node.0).is_none() {
            to.offered_creds.insert(from.node.0, creds);
            to.lease_backlog.push(from.node.0);
        }
        return;
    }
    to.remote_pools.insert(from.node.0, creds.pool);
    to.remote_ud.insert(from.node.0, creds.ud);
    to.migrate.register_dest(from.node.0);
    to.stats.lease_batches += 1;
    to.stats.leases_established += 1;
    to.charge_ctrl(sim, to.cfg.lease_establish_ns);
}

/// Tear down a logical connection end-to-end (the `disconnect(fd)` of
/// Fig 3 for the in-sim deployment): both daemons fail-fast their
/// in-flight ops, quarantine their vQPNs, and queue the shared QP for
/// parking once their side drains.
pub fn disconnect_via(
    sim: &mut Sim,
    daemons: &mut [Daemon],
    a: usize,
    conn: Vqpn,
) -> Result<(), RaasError> {
    let (remote, peer) = {
        let e = daemons[a]
            .conns
            .lookup(conn)
            .ok_or(RaasError::ConnectionClosed)?;
        (e.remote, e.peer_vqpn)
    };
    daemons[a].disconnect(sim, conn)?;
    let b = daemons
        .iter()
        .position(|d| d.node == remote)
        .ok_or(RaasError::UnknownConnection)?;
    // the peer half may already be gone (e.g. its daemon restarted)
    let _ = daemons[b].disconnect(sim, peer);
    Ok(())
}

/// Resolve a [`Target`] then connect (the public `connect(Target*, FLAGS)`
/// form of Fig 3).
pub fn connect_target(
    sim: &mut Sim,
    daemons: &mut [Daemon],
    a: usize,
    a_app: u32,
    target: Target,
    port: u16,
) -> Result<Vqpn, RaasError> {
    let node = target.resolve();
    let b = daemons
        .iter()
        .position(|d| d.node == node)
        .ok_or(RaasError::UnknownConnection)?;
    connect_via(sim, daemons, a, a_app, b, port)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::sim::FabricConfig;

    fn cluster(n: usize) -> (Sim, Vec<Daemon>) {
        let mut cfg = FabricConfig::default();
        cfg.nodes = n;
        let mut sim = Sim::new(cfg);
        let daemons = (0..n)
            .map(|i| Daemon::start(&mut sim, NodeId(i as u32), DaemonConfig::default()))
            .collect();
        (sim, daemons)
    }

    fn pump_all(sim: &mut Sim, daemons: &mut [Daemon]) {
        // drive until quiescent: alternate sim progress and daemon pumps
        for _ in 0..10_000 {
            for d in daemons.iter_mut() {
                d.pump(sim);
            }
            if sim.step().is_none() {
                // one more pump round to drain freshly-landed CQEs
                for d in daemons.iter_mut() {
                    d.pump(sim);
                }
                if sim.pending_events() == 0 {
                    return;
                }
            }
        }
        panic!("did not quiesce");
    }

    #[test]
    fn connect_creates_one_shared_qp_per_remote() {
        let (mut sim, mut daemons) = cluster(3);
        let app = daemons[0].register_app();
        let sapp = daemons[1].register_app();
        daemons[1].listen(sapp, 7000);
        let sapp2 = daemons[2].register_app();
        daemons[2].listen(sapp2, 7000);

        for _ in 0..50 {
            connect_via(&mut sim, &mut daemons, 0, app, 1, 7000).unwrap();
        }
        for _ in 0..50 {
            connect_via(&mut sim, &mut daemons, 0, app, 2, 7000).unwrap();
        }
        assert_eq!(daemons[0].conns.active(), 100);
        assert_eq!(daemons[0].shared_qp_count(), 2, "one QP per remote node");
        // 2 shared RC QPs + the daemon's host-wide UD QP
        assert_eq!(sim.node(NodeId(0)).qps.len(), 3);
    }

    #[test]
    fn accept_pairs_with_connect() {
        let (mut sim, mut daemons) = cluster(2);
        let c_app = daemons[0].register_app();
        let s_app = daemons[1].register_app();
        daemons[1].listen(s_app, 9000);
        let va = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 9000).unwrap();
        let vb = daemons[1].accept(s_app, 9000).expect("accept should yield");
        assert_eq!(daemons[0].conns.lookup(va).unwrap().peer_vqpn, vb);
        assert_eq!(daemons[1].conns.lookup(vb).unwrap().peer_vqpn, va);
        assert!(daemons[1].accept(s_app, 9000).is_none());
    }

    #[test]
    fn read_completes_and_releases_lease() {
        let (mut sim, mut daemons) = cluster(2);
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

        daemons[0].read(&mut sim, conn, 64 << 10, 0, 42).unwrap();
        pump_all(&mut sim, &mut daemons);

        let d = daemons[0].recv(&mut sim, app).expect("completion delivered");
        match d {
            Delivery::OpComplete { conn: c, len, ok, .. } => {
                assert_eq!(c, conn);
                assert_eq!(len, 64 << 10);
                assert!(ok);
            }
            _ => panic!("unexpected delivery {d:?}"),
        }
        assert_eq!(daemons[0].pool.leased_bytes, 0, "lease released");
        assert_eq!(daemons[0].stats.ops_completed, 1);
    }

    #[test]
    fn small_send_arrives_as_message_with_vqpn_routing() {
        let (mut sim, mut daemons) = cluster(2);
        let c_app = daemons[0].register_app();
        let s_app = daemons[1].register_app();
        daemons[1].listen(s_app, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();
        let peer = daemons[0].conns.lookup(conn).unwrap().peer_vqpn;

        let verb = daemons[0]
            .send(&mut sim, conn, 512, Flags::default(), 7, HostLoad::default())
            .unwrap();
        assert_eq!(verb, Verb::Send, "small message → two-sided SEND");
        pump_all(&mut sim, &mut daemons);

        let d = daemons[1].recv(&mut sim, s_app).expect("message delivered");
        assert_eq!(d, Delivery::Message { conn: peer, len: 512, zero_copy: false });
        // sender's completion arrived too
        assert!(daemons[0].recv(&mut sim, c_app).is_some());
    }

    #[test]
    fn large_send_uses_write_with_imm() {
        let (mut sim, mut daemons) = cluster(2);
        let c_app = daemons[0].register_app();
        let s_app = daemons[1].register_app();
        daemons[1].listen(s_app, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();

        let verb = daemons[0]
            .send(&mut sim, conn, 256 << 10, Flags::default(), 7, HostLoad::default())
            .unwrap();
        assert_eq!(verb, Verb::Write, "large message → one-sided WRITE");
        pump_all(&mut sim, &mut daemons);
        let d = daemons[1].recv(&mut sim, s_app).unwrap();
        assert!(matches!(d, Delivery::Message { len, .. } if len == 256 << 10));
    }

    #[test]
    fn zero_copy_recv_skips_copy_cost() {
        let (mut sim, mut daemons) = cluster(2);
        let c_app = daemons[0].register_app();
        let s_app = daemons[1].register_app();
        daemons[1].listen(s_app, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();

        daemons[0]
            .send(&mut sim, conn, 2048, Flags::default(), 1, HostLoad::default())
            .unwrap();
        pump_all(&mut sim, &mut daemons);
        let before = sim.node(NodeId(1)).cpu.memcpy_bytes;
        let d = daemons[1].recv_zero_copy(&mut sim, s_app).unwrap();
        assert!(matches!(d, Delivery::Message { zero_copy: true, .. }));
        assert_eq!(sim.node(NodeId(1)).cpu.memcpy_bytes, before, "no copy-out");
    }

    #[test]
    fn batching_coalesces_doorbells() {
        let (mut sim, mut daemons) = cluster(2);
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

        for i in 0..64 {
            daemons[0].read(&mut sim, conn, 4096, (i * 4096) as u64, i).unwrap();
        }
        daemons[0].pump(&mut sim);
        // 64 WRs, batch_max=32 → at most a handful of doorbells
        assert!(daemons[0].stats.batches_posted <= 4, "batches={}", daemons[0].stats.batches_posted);
        assert_eq!(daemons[0].stats.wrs_posted, 64);
        pump_all(&mut sim, &mut daemons);
        assert_eq!(daemons[0].stats.ops_completed, 64);
    }

    #[test]
    fn snapshot_counts_resources() {
        let (mut sim, mut daemons) = cluster(2);
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        for _ in 0..10 {
            connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        }
        let snap = daemons[0].snapshot(&sim);
        assert_eq!(snap.apps, 1);
        assert_eq!(snap.conns, 10);
        assert_eq!(snap.shared_qps, 1);
        assert!(snap.mem_bytes > 0);
    }

    #[test]
    fn pinned_ud_send_arrives_via_datagram_qp() {
        let (mut sim, mut daemons) = cluster(2);
        let c_app = daemons[0].register_app();
        let s_app = daemons[1].register_app();
        daemons[1].listen(s_app, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();
        let peer = daemons[0].conns.lookup(conn).unwrap().peer_vqpn;

        let verb = daemons[0]
            .send(&mut sim, conn, 512, Flags::UD, 7, HostLoad::default())
            .unwrap();
        assert_eq!(verb, Verb::Send);
        assert_eq!(daemons[0].stats.sent_ud, 1);
        assert_eq!(daemons[0].stats.ud_fragments, 1);
        pump_all(&mut sim, &mut daemons);

        let d = daemons[1].recv(&mut sim, s_app).expect("message delivered");
        assert_eq!(d, Delivery::Message { conn: peer, len: 512, zero_copy: false });
        // sender got exactly one completion and released its lease
        assert!(daemons[0].recv(&mut sim, c_app).is_some());
        assert_eq!(daemons[0].pool.leased_bytes, 0);
        // the datagram rode the UD QP, not the shared RC QP
        let ud = daemons[0].ud_qpn();
        assert_eq!(sim.node(NodeId(0)).qps[ud.0].posted_send, 1);
    }

    #[test]
    fn oversize_ud_send_is_fragmented_and_reassembled() {
        let (mut sim, mut daemons) = cluster(2);
        let c_app = daemons[0].register_app();
        let s_app = daemons[1].register_app();
        daemons[1].listen(s_app, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();

        // 64 KB over a 4 KB MTU => 16 UD fragments, one logical message
        daemons[0]
            .send(&mut sim, conn, 64 << 10, Flags::UD, 7, HostLoad::default())
            .unwrap();
        assert_eq!(daemons[0].stats.ud_fragments, 16);
        pump_all(&mut sim, &mut daemons);

        let d = daemons[1].recv(&mut sim, s_app).expect("reassembled message");
        assert!(matches!(d, Delivery::Message { len, .. } if len == 64 << 10));
        assert_eq!(daemons[1].reassembly.completed, 1);
        assert_eq!(daemons[1].reassembly.dropped, 0);
        // exactly one initiator completion, reporting the LOGICAL length
        // (the wire CQE only carries the last fragment's 4 KB)
        assert_eq!(daemons[0].stats.ops_completed, 1);
        let c = daemons[0].recv(&mut sim, c_app).expect("initiator completion");
        assert!(
            matches!(c, Delivery::OpComplete { len, ok: true, .. } if len == 64 << 10),
            "{c:?}"
        );
        assert_eq!(daemons[0].stats.bytes_completed, 64 << 10);
        assert_eq!(daemons[0].pool.leased_bytes, 0);
    }

    #[test]
    fn ud_send_beyond_segmentation_limit_rejected() {
        let (mut sim, mut daemons) = cluster(2);
        let c_app = daemons[0].register_app();
        let s_app = daemons[1].register_app();
        daemons[1].listen(s_app, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, c_app, 1, 1).unwrap();
        let too_big = crate::raas::migrate::ud_max_msg_bytes(sim.cfg.mtu) + 1;
        let err = daemons[0]
            .send(&mut sim, conn, too_big, Flags::UD, 0, HostLoad::default())
            .unwrap_err();
        assert!(matches!(err, RaasError::TooLong { .. }));
    }

    #[test]
    fn migration_under_pressure_rides_ud_and_honors_rc_pin() {
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 5;
        let mut sim = Sim::new(fcfg);
        let mut dcfg = DaemonConfig::default();
        // 400-entry cache × 0.005 => RC budget 2: four destinations put
        // the working-set pressure at 3/2 = 1.5 ≥ enter_ud, so the whole
        // set migrates on the first evaluation
        dcfg.migration.rc_share = 0.005;
        let mut daemons: Vec<Daemon> = (0..5)
            .map(|i| {
                let cfg = if i == 0 { dcfg.clone() } else { DaemonConfig::default() };
                Daemon::start(&mut sim, NodeId(i as u32), cfg)
            })
            .collect();
        let app = daemons[0].register_app();
        let mut conns = Vec::new();
        for s in 1..5 {
            let sapp = daemons[s].register_app();
            daemons[s].listen(sapp, 1);
            conns.push(connect_via(&mut sim, &mut daemons, 0, app, s, 1).unwrap());
        }
        // first pump evaluates structural pressure immediately
        daemons[0].pump(&mut sim);
        use crate::raas::migrate::DestState;
        for remote in 1..5u32 {
            assert_eq!(daemons[0].migrate.state_of(remote), DestState::Ud);
        }
        assert_eq!(daemons[0].migrate.to_ud, 4);

        // unpinned sends to migrated destinations ride UD…
        daemons[0]
            .send(&mut sim, conns[2], 256, Flags::default(), 0, HostLoad::default())
            .unwrap();
        daemons[0]
            .send(&mut sim, conns[0], 256, Flags::default(), 0, HostLoad::default())
            .unwrap();
        assert_eq!(daemons[0].stats.sent_ud, 2);
        // …but an RC pin to a migrated destination is still honored
        daemons[0]
            .send(&mut sim, conns[2], 256, Flags::RC, 0, HostLoad::default())
            .unwrap();
        assert_eq!(daemons[0].stats.sent_rc, 1);

        pump_all(&mut sim, &mut daemons);
        assert_eq!(daemons[0].stats.ops_completed, 3, "no completion lost");
        assert_eq!(daemons[0].pool.leased_bytes, 0);
    }

    #[test]
    fn draining_destination_flips_after_inflight_completes() {
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 3;
        let mut sim = Sim::new(fcfg);
        let mut dcfg = DaemonConfig::default();
        // budget 2: two destinations are safe structurally (pressure 0.5)
        // but flip once the observed-thrash boost doubles it to 1.0
        dcfg.migration.rc_share = 0.005;
        let mut daemons = vec![
            Daemon::start(&mut sim, NodeId(0), dcfg),
            Daemon::start(&mut sim, NodeId(1), DaemonConfig::default()),
            Daemon::start(&mut sim, NodeId(2), DaemonConfig::default()),
        ];
        let app = daemons[0].register_app();
        let s1 = daemons[1].register_app();
        daemons[1].listen(s1, 1);
        let c1 = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        let s2 = daemons[2].register_app();
        daemons[2].listen(s2, 1);
        let _c2 = connect_via(&mut sim, &mut daemons, 0, app, 2, 1).unwrap();

        // put RC traffic in flight to node 1 BEFORE the pressure rises
        daemons[0]
            .send(&mut sim, c1, 256, Flags::default(), 0, HostLoad::default())
            .unwrap();
        daemons[0].pump(&mut sim); // evaluates: pressure 0.5 => stay Rc
        use crate::raas::migrate::DestState;
        assert_eq!(daemons[0].migrate.state_of(1), DestState::Rc);

        // observed thrash doubles the pressure: 1×2/2 = 1.0 ≥ enter_ud,
        // but the in-flight RC WR holds the drain open
        daemons[0].migrate.observe_hit_rate(Some(0.0));
        daemons[0].migrate.evaluate(sim.cfg.nic.icm_cache_entries, sim.now());
        assert_eq!(
            daemons[0].migrate.state_of(1),
            DestState::DrainingToUd,
            "in-flight RC WR holds the drain open"
        );
        // completing the WR promotes the destination to Ud
        pump_all(&mut sim, &mut daemons);
        assert_eq!(daemons[0].migrate.state_of(1), DestState::Ud);
    }

    #[test]
    fn pool_exhaustion_reported() {
        // dedicated cluster with a tiny pool on node 0
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 2;
        let mut sim = Sim::new(fcfg);
        let mut cfg0 = DaemonConfig::default();
        cfg0.pool_layout = vec![(64 << 10, 4)];
        cfg0.srq_capacity = 2;
        let mut daemons = vec![
            Daemon::start(&mut sim, NodeId(0), cfg0),
            Daemon::start(&mut sim, NodeId(1), DaemonConfig::default()),
        ];
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        let mut got_exhausted = false;
        for i in 0..10 {
            match daemons[0].read(&mut sim, conn, 64 << 10, 0, i) {
                Err(RaasError::PoolExhausted) => {
                    got_exhausted = true;
                    break;
                }
                Ok(_) => {}
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(got_exhausted, "tiny pool must exhaust");
    }

    #[test]
    fn window_reads_reuse_one_standing_lease() {
        let (mut sim, mut daemons) = cluster(2);
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

        let win = daemons[0]
            .register_window(&mut sim, conn, 0, 1 << 20, 4096)
            .unwrap();
        assert_eq!(daemons[0].stats.windows_registered, 1);
        let standing = daemons[0].pool.leased_bytes;
        assert_eq!(standing, 4096, "one lease of the max-op class");

        for i in 0..32u64 {
            daemons[0].window_read(&mut sim, win, 4096, i * 4096, i).unwrap();
        }
        // repeat reads took NO additional leases
        assert_eq!(daemons[0].pool.leased_bytes, standing);
        pump_all(&mut sim, &mut daemons);
        assert_eq!(daemons[0].stats.ops_completed, 32);
        assert_eq!(daemons[0].stats.window_ops, 32);
        let mut got = 0;
        while let Some(d) = daemons[0].recv_zero_copy(&mut sim, app) {
            assert!(matches!(d, Delivery::OpComplete { ok: true, len: 4096, .. }), "{d:?}");
            got += 1;
        }
        assert_eq!(got, 32);
        // the standing lease outlives the ops, and release returns it
        assert_eq!(daemons[0].pool.leased_bytes, standing);
        daemons[0].release_window(&mut sim, win).unwrap();
        assert_eq!(daemons[0].pool.leased_bytes, 0);
        assert_eq!(daemons[0].window_count(), 0);
    }

    #[test]
    fn window_writes_coalesce_into_one_signaled_cqe() {
        let (mut sim, mut daemons) = cluster(2);
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

        let win = daemons[0]
            .register_window(&mut sim, conn, 0, 1 << 20, 4096)
            .unwrap();
        for i in 0..8u64 {
            daemons[0].window_write(&mut sim, win, 512, i * 4096, 100 + i).unwrap();
        }
        daemons[0].window_flush(&mut sim, win).unwrap();
        pump_all(&mut sim, &mut daemons);

        // one doorbell group, one signaled tail: 7 WRITEs shared the CQE
        assert_eq!(daemons[0].stats.window_flushes, 1);
        assert_eq!(daemons[0].stats.writes_coalesced, 7);
        assert_eq!(daemons[0].stats.wrs_posted, 8);
        assert_eq!(daemons[0].stats.ops_completed, 8, "one OpComplete per WRITE");
        // fan-out carries the user tags, in submit order
        let mut tags = Vec::new();
        while let Some(d) = daemons[0].recv_zero_copy(&mut sim, app) {
            match d {
                Delivery::OpComplete { tag, len: 512, ok: true, .. } => tags.push(tag),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(tags, (100..108).collect::<Vec<u64>>());
        // truly one-sided: the responder daemon saw NO message
        assert_eq!(daemons[1].stats.msgs_delivered, 0);
        assert_eq!(daemons[1].inbox_len(s), 0);
    }

    #[test]
    fn stale_window_tokens_fail_cleanly() {
        let (mut sim, mut daemons) = cluster(2);
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

        let win = daemons[0]
            .register_window(&mut sim, conn, 0, 64 << 10, 4096)
            .unwrap();
        daemons[0].release_window(&mut sim, win).unwrap();
        assert_eq!(
            daemons[0].window_read(&mut sim, win, 4096, 0, 0),
            Err(RaasError::StaleWindow)
        );
        assert_eq!(
            daemons[0].window_write(&mut sim, win, 4096, 0, 0),
            Err(RaasError::StaleWindow)
        );
        // a recycled slot gets a new generation: the old token stays dead
        let win2 = daemons[0]
            .register_window(&mut sim, conn, 0, 64 << 10, 4096)
            .unwrap();
        assert_eq!(daemons[0].window_read(&mut sim, win, 4096, 0, 0), Err(RaasError::StaleWindow));
        // and a never-issued token is rejected too
        let bogus = WindowToken { slot: 99, gen: 0 };
        assert_eq!(daemons[0].check_window(bogus), Err(RaasError::StaleWindow));
        daemons[0].release_window(&mut sim, win2).unwrap();
        assert_eq!(daemons[0].pool.leased_bytes, 0);
    }

    #[test]
    fn release_with_inflight_ops_defers_lease_return() {
        let (mut sim, mut daemons) = cluster(2);
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

        let win = daemons[0]
            .register_window(&mut sim, conn, 0, 1 << 20, 4096)
            .unwrap();
        daemons[0].window_read(&mut sim, win, 4096, 0, 1).unwrap();
        daemons[0].window_write(&mut sim, win, 256, 8192, 2).unwrap();
        daemons[0].release_window(&mut sim, win).unwrap();
        // token dead immediately, lease held until the ops drain
        assert_eq!(daemons[0].window_read(&mut sim, win, 4096, 0, 3), Err(RaasError::StaleWindow));
        assert!(daemons[0].pool.leased_bytes > 0, "lease deferred while in flight");
        pump_all(&mut sim, &mut daemons);
        assert_eq!(daemons[0].stats.ops_completed, 2, "accepted ops complete exactly once");
        assert_eq!(daemons[0].pool.leased_bytes, 0, "drain returned the lease");
        assert_eq!(daemons[0].window_count(), 0);
    }

    #[test]
    fn disconnect_parks_and_reconnect_reuses_qp() {
        let (mut sim, mut daemons) = cluster(2);
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        assert_eq!(daemons[0].stats.handshakes_full, 1);
        let qps_before = sim.node(NodeId(0)).qps.len();

        disconnect_via(&mut sim, &mut daemons, 0, conn).unwrap();
        // nothing was in flight, so the first pump drains and parks
        daemons[0].pump(&mut sim);
        daemons[1].pump(&mut sim);
        assert_eq!(daemons[0].pooled_qp_count(), 1);
        assert_eq!(daemons[1].pooled_qp_count(), 1);
        assert_eq!(daemons[0].stats.qp_parked, 1);
        assert_eq!(daemons[0].shared_qp_count(), 0);
        assert_eq!(daemons[0].conns.active(), 0);
        assert_eq!(daemons[0].conns.quarantined(), 0, "park releases the quarantine");
        assert!(!daemons[0].creds_established(1), "parking tears leases down");
        assert_eq!(daemons[0].epoch(1), 1, "park bumps the epoch");

        // reconnect: revival is bookkeeping — no new fabric QP
        let conn2 = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        assert_eq!(daemons[0].stats.qp_reused, 1);
        assert_eq!(daemons[0].stats.handshakes_full, 1, "handshake skipped");
        assert_eq!(daemons[0].pooled_qp_count(), 0);
        assert_eq!(sim.node(NodeId(0)).qps.len(), qps_before, "no QP created");

        // the revived QP carries traffic for the new tenant
        daemons[0]
            .send(&mut sim, conn2, 512, Flags::default(), 7, HostLoad::default())
            .unwrap();
        pump_all(&mut sim, &mut daemons);
        assert_eq!(daemons[0].stats.ops_completed, 1);
        assert_eq!(daemons[1].stats.msgs_delivered, 1);
        assert_eq!(daemons[0].stats.stale_epoch_drops, 0);
    }

    #[test]
    fn cold_mode_destroys_instead_of_parking() {
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 2;
        let mut sim = Sim::new(fcfg);
        let mut cfg = DaemonConfig::default();
        cfg.qp_pool_max = 0; // the fig-12 --cold ablation
        let mut daemons = vec![
            Daemon::start(&mut sim, NodeId(0), cfg.clone()),
            Daemon::start(&mut sim, NodeId(1), cfg),
        ];
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        let qps_before = sim.node(NodeId(0)).qps.len();

        disconnect_via(&mut sim, &mut daemons, 0, conn).unwrap();
        daemons[0].pump(&mut sim);
        daemons[1].pump(&mut sim);
        assert_eq!(daemons[0].pooled_qp_count(), 0);
        assert_eq!(daemons[0].stats.qp_parked, 0);
        assert_eq!(daemons[0].stats.qp_evicted, 1, "cold path destroys");

        // reconnect pays the full handshake again, with a fresh QP
        connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        assert_eq!(daemons[0].stats.handshakes_full, 2);
        assert_eq!(daemons[0].stats.qp_reused, 0);
        assert_eq!(sim.node(NodeId(0)).qps.len(), qps_before + 1);
    }

    #[test]
    fn qp_pool_bound_evicts_lru() {
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 4;
        let mut sim = Sim::new(fcfg);
        let mut ccfg = DaemonConfig::default();
        ccfg.qp_pool_max = 2;
        let mut daemons = vec![Daemon::start(&mut sim, NodeId(0), ccfg)];
        for i in 1..4u32 {
            daemons.push(Daemon::start(&mut sim, NodeId(i), DaemonConfig::default()));
        }
        let app = daemons[0].register_app();
        let mut conns = Vec::new();
        for s in 1..4 {
            let sapp = daemons[s].register_app();
            daemons[s].listen(sapp, 1);
            conns.push(connect_via(&mut sim, &mut daemons, 0, app, s, 1).unwrap());
        }
        for &c in &conns {
            disconnect_via(&mut sim, &mut daemons, 0, c).unwrap();
        }
        for d in daemons.iter_mut() {
            d.pump(&mut sim);
        }
        // three parks into a 2-slot pool: the LRU victim (remote 1,
        // parked first) was destroyed
        assert_eq!(daemons[0].stats.qp_parked, 3);
        assert_eq!(daemons[0].stats.qp_evicted, 1);
        assert_eq!(daemons[0].pooled_qp_count(), 2);

        // remote 3 revives from the pool; remote 1 must re-handshake
        // (and the server's now-unrevivable half is destroyed)
        connect_via(&mut sim, &mut daemons, 0, app, 3, 1).unwrap();
        assert_eq!(daemons[0].stats.qp_reused, 1);
        connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();
        assert_eq!(daemons[0].stats.handshakes_full, 4);
        assert_eq!(daemons[0].stats.qp_reused, 1);
        assert_eq!(daemons[1].stats.qp_evicted, 1, "stranded server half destroyed");
    }

    #[test]
    fn lazy_leases_defer_and_batch_establishment() {
        let mut fcfg = FabricConfig::default();
        fcfg.nodes = 3;
        let mut sim = Sim::new(fcfg);
        let mut ccfg = DaemonConfig::default();
        ccfg.lazy_leases = true;
        let mut daemons = vec![Daemon::start(&mut sim, NodeId(0), ccfg)];
        for i in 1..3u32 {
            daemons.push(Daemon::start(&mut sim, NodeId(i), DaemonConfig::default()));
        }
        let app = daemons[0].register_app();
        let mut conns = Vec::new();
        for s in 1..3 {
            let sapp = daemons[s].register_app();
            daemons[s].listen(sapp, 1);
            conns.push(connect_via(&mut sim, &mut daemons, 0, app, s, 1).unwrap());
        }
        // connect registered vQPNs only: no credentials, no migration
        // registration, no lease control messages
        assert!(!daemons[0].creds_established(1));
        assert!(!daemons[0].creds_established(2));
        assert_eq!(daemons[0].deferred_lease_count(), 2);
        assert_eq!(daemons[0].stats.lease_batches, 0);
        assert_eq!(daemons[0].migrate.state_counts(), (0, 0, 0));

        // first use establishes BOTH deferred remotes in one batched
        // control message (lease_batch_max = 16 covers them)
        daemons[0].read(&mut sim, conns[0], 4096, 0, 1).unwrap();
        assert!(daemons[0].creds_established(1));
        assert!(daemons[0].creds_established(2));
        assert_eq!(daemons[0].deferred_lease_count(), 0);
        assert_eq!(daemons[0].stats.lease_batches, 1);
        assert_eq!(daemons[0].stats.leases_established, 2);
        assert_eq!(daemons[0].migrate.state_counts(), (2, 0, 0));

        pump_all(&mut sim, &mut daemons);
        assert_eq!(daemons[0].stats.ops_completed, 1);
        assert_eq!(daemons[0].pool.leased_bytes, 0);
    }

    #[test]
    fn disconnect_fail_fasts_inflight_ops() {
        let (mut sim, mut daemons) = cluster(2);
        let app = daemons[0].register_app();
        let s = daemons[1].register_app();
        daemons[1].listen(s, 1);
        let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

        // submit a read but disconnect before anything is posted
        daemons[0].read(&mut sim, conn, 4096, 0, 42).unwrap();
        assert_eq!(daemons[0].inflight_ops(), 1);
        disconnect_via(&mut sim, &mut daemons, 0, conn).unwrap();
        assert_eq!(daemons[0].inflight_ops(), 0, "op fail-fasted");
        assert_eq!(daemons[0].pool.leased_bytes, 0, "lease released");
        assert_eq!(daemons[0].stats.ops_failed, 1);
        let d = daemons[0].recv(&mut sim, app).expect("failure delivered");
        assert!(matches!(d, Delivery::OpComplete { ok: false, .. }), "{d:?}");
        assert_eq!(daemons[0].conns.quarantined(), 1, "vQPN held until drain");

        pump_all(&mut sim, &mut daemons);
        assert_eq!(daemons[0].conns.quarantined(), 0);
        assert_eq!(daemons[0].pooled_qp_count(), 1, "drained QP parked");
        assert_eq!(daemons[0].stats.ops_completed, 0, "no ghost completion");
        assert!(daemons[0].recv(&mut sim, app).is_none(), "exactly one delivery");
    }
}
