//! Virtual QPNs: the lock-free QP-sharing demultiplexer (§2.3, Fig 4).
//!
//! All logical connections targeting the same remote node share one RC QP.
//! Each connection gets a 4-byte **vQPN**; RDMAvisor stamps it into the
//! `wr_id` field of one-sided WRs (returned in the initiator's CQE) and
//! into `imm_data` for two-sided WRs (travels to the responder's CQE).
//! Completion routing is then a single array lookup — no locks anywhere on
//! the path.

use std::collections::HashMap;

use crate::fabric::types::NodeId;

/// A virtual queue pair number — identifies one logical connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Vqpn(pub u32);

/// Handle applications hold for a logical connection.
pub type ConnId = Vqpn;

/// Pack (vqpn, op-sequence) into a 64-bit wr_id: vQPN in the low 32 bits
/// exactly as Fig 4 shows, sequence in the high bits for dedup/debugging.
#[inline]
pub fn pack_wr_id(vqpn: Vqpn, seq: u32) -> u64 {
    ((seq as u64) << 32) | vqpn.0 as u64
}

/// Extract the vQPN from a completion's wr_id.
#[inline]
pub fn unpack_vqpn(wr_id: u64) -> Vqpn {
    Vqpn(wr_id as u32)
}

/// Extract the op sequence number from a completion's wr_id.
#[inline]
pub fn unpack_seq(wr_id: u64) -> u32 {
    (wr_id >> 32) as u32
}

/// State of one logical connection.
#[derive(Clone, Debug)]
pub struct ConnEntry {
    /// This connection's own vQPN.
    pub vqpn: Vqpn,
    /// Owning application (session) on this host.
    pub app: u32,
    /// Remote machine this connection targets.
    pub remote: NodeId,
    /// Peer's vQPN for this connection (stamped into imm_data so the peer's
    /// Poller can route two-sided deliveries).
    pub peer_vqpn: Vqpn,
    /// Set once the connection is closed.
    pub closed: bool,
}

/// The connection table: vQPN allocator + routing index.
///
/// Dense `Vec` storage so the Poller's demux is one bounds-checked index —
/// the hot path the paper makes lock-free.
#[derive(Debug, Default)]
pub struct ConnTable {
    entries: Vec<Option<ConnEntry>>,
    free: Vec<u32>,
    /// vQPNs closed via [`ConnTable::close_quarantined`], held out of the
    /// free list (with the remote they pointed at) until the daemon
    /// declares that remote's shared QP drained. A quarantined vQPN can
    /// never be re-issued while a frame stamped with it may still be in
    /// flight — the recycled-vQPN half of the tenant-isolation argument
    /// (DESIGN.md §12).
    quarantine: Vec<(u32, u32)>,
    /// Connections per remote node (drives shared-QP reuse stats).
    per_remote: HashMap<u32, u32>,
    /// Lifetime opens.
    pub opened: u64,
    /// Lifetime closes.
    pub closed: u64,
}

impl ConnTable {
    /// Empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a vQPN for a new connection. vQPNs are recycled after close
    /// (the 4-byte space must last the daemon's lifetime).
    pub fn open(&mut self, app: u32, remote: NodeId, peer_vqpn: Vqpn) -> Vqpn {
        self.opened += 1;
        *self.per_remote.entry(remote.0).or_insert(0) += 1;
        match self.free.pop() {
            Some(idx) => {
                let vqpn = Vqpn(idx);
                self.entries[idx as usize] =
                    Some(ConnEntry { vqpn, app, remote, peer_vqpn, closed: false });
                vqpn
            }
            None => {
                let vqpn = Vqpn(self.entries.len() as u32);
                self.entries.push(Some(ConnEntry {
                    vqpn,
                    app,
                    remote,
                    peer_vqpn,
                    closed: false,
                }));
                vqpn
            }
        }
    }

    /// Bind the peer's vQPN once the control-plane handshake returns it.
    pub fn set_peer(&mut self, vqpn: Vqpn, peer: Vqpn) {
        if let Some(Some(e)) = self.entries.get_mut(vqpn.0 as usize) {
            e.peer_vqpn = peer;
        }
    }

    /// Close a connection; false if it was not live. The vQPN is recycled.
    pub fn close(&mut self, vqpn: Vqpn) -> bool {
        match self.entries.get_mut(vqpn.0 as usize) {
            Some(slot @ Some(_)) => {
                let e = slot.take().unwrap();
                self.closed += 1;
                if let Some(c) = self.per_remote.get_mut(&e.remote.0) {
                    *c -= 1;
                }
                self.free.push(vqpn.0);
                true
            }
            _ => false,
        }
    }

    /// Close a connection like [`ConnTable::close`], but quarantine the
    /// vQPN instead of recycling it immediately: the entry is gone (demux
    /// misses route to drop), yet the number cannot be re-issued until
    /// [`ConnTable::release_quarantined`] declares its remote drained.
    pub fn close_quarantined(&mut self, vqpn: Vqpn) -> Option<NodeId> {
        match self.entries.get_mut(vqpn.0 as usize) {
            Some(slot @ Some(_)) => {
                let e = slot.take().unwrap();
                self.closed += 1;
                if let Some(c) = self.per_remote.get_mut(&e.remote.0) {
                    *c -= 1;
                }
                self.quarantine.push((vqpn.0, e.remote.0));
                Some(e.remote)
            }
            _ => None,
        }
    }

    /// Return every quarantined vQPN that pointed at `remote` to the free
    /// list (the daemon calls this once the remote's shared QP has no
    /// in-flight WRs and no pending batch). Returns how many were freed.
    pub fn release_quarantined(&mut self, remote: NodeId) -> usize {
        let before = self.quarantine.len();
        // order-preserving sweep keeps later free.pop() recycling
        // deterministic across runs
        let mut kept = Vec::with_capacity(before);
        for (v, r) in self.quarantine.drain(..) {
            if r == remote.0 {
                self.free.push(v);
            } else {
                kept.push((v, r));
            }
        }
        self.quarantine = kept;
        before - self.quarantine.len()
    }

    /// vQPNs currently quarantined (awaiting their remote's drain).
    pub fn quarantined(&self) -> usize {
        self.quarantine.len()
    }

    /// Host memory the table itself occupies: the entry array plus the
    /// free/quarantine lists. This is the entire per-registered-vQPN cost
    /// of an idle tenant under lazy leases — the fig-12 memory metric.
    pub fn table_mem_bytes(&self) -> u64 {
        (self.entries.capacity() * std::mem::size_of::<Option<ConnEntry>>()
            + self.free.capacity() * std::mem::size_of::<u32>()
            + self.quarantine.capacity() * std::mem::size_of::<(u32, u32)>()) as u64
    }

    /// The Poller's demux: O(1).
    #[inline]
    pub fn lookup(&self, vqpn: Vqpn) -> Option<&ConnEntry> {
        self.entries.get(vqpn.0 as usize).and_then(|e| e.as_ref())
    }

    /// Live connections.
    pub fn active(&self) -> usize {
        (self.opened - self.closed) as usize
    }

    /// Live connections targeting `remote`.
    pub fn conns_to(&self, remote: NodeId) -> u32 {
        self.per_remote.get(&remote.0).copied().unwrap_or(0)
    }

    /// Distinct remote nodes with ≥1 connection = number of shared QPs the
    /// daemon needs (the whole point of §2.3).
    pub fn active_remotes(&self) -> usize {
        self.per_remote.values().filter(|&&c| c > 0).count()
    }

    /// Iterate over live connections.
    pub fn iter(&self) -> impl Iterator<Item = &ConnEntry> {
        self.entries.iter().filter_map(|e| e.as_ref())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wr_id_roundtrip() {
        let id = pack_wr_id(Vqpn(0xDEAD_BEEF), 7);
        assert_eq!(unpack_vqpn(id), Vqpn(0xDEAD_BEEF));
        assert_eq!(unpack_seq(id), 7);
    }

    #[test]
    fn open_assigns_unique_vqpns() {
        let mut t = ConnTable::new();
        let a = t.open(1, NodeId(1), Vqpn(0));
        let b = t.open(1, NodeId(2), Vqpn(0));
        let c = t.open(2, NodeId(1), Vqpn(0));
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_eq!(t.active(), 3);
    }

    #[test]
    fn close_recycles_vqpn() {
        let mut t = ConnTable::new();
        let a = t.open(1, NodeId(1), Vqpn(0));
        assert!(t.close(a));
        assert!(!t.close(a), "double close must fail");
        let b = t.open(1, NodeId(1), Vqpn(0));
        assert_eq!(a, b, "vqpn must be recycled");
        assert_eq!(t.active(), 1);
    }

    #[test]
    fn lookup_routes_by_vqpn() {
        let mut t = ConnTable::new();
        let a = t.open(3, NodeId(2), Vqpn(77));
        let e = t.lookup(a).unwrap();
        assert_eq!(e.app, 3);
        assert_eq!(e.remote, NodeId(2));
        assert_eq!(e.peer_vqpn, Vqpn(77));
        assert!(t.lookup(Vqpn(999)).is_none());
    }

    #[test]
    fn shared_qp_count_tracks_distinct_remotes() {
        let mut t = ConnTable::new();
        for _ in 0..100 {
            t.open(1, NodeId(1), Vqpn(0));
        }
        for _ in 0..50 {
            t.open(1, NodeId(2), Vqpn(0));
        }
        // 150 logical connections, but only 2 shared QPs needed
        assert_eq!(t.active(), 150);
        assert_eq!(t.active_remotes(), 2);
        assert_eq!(t.conns_to(NodeId(1)), 100);
    }

    #[test]
    fn set_peer_updates() {
        let mut t = ConnTable::new();
        let a = t.open(1, NodeId(1), Vqpn(0));
        t.set_peer(a, Vqpn(42));
        assert_eq!(t.lookup(a).unwrap().peer_vqpn, Vqpn(42));
    }

    #[test]
    fn quarantined_vqpn_is_not_recycled_until_release() {
        let mut t = ConnTable::new();
        let a = t.open(1, NodeId(1), Vqpn(0));
        assert_eq!(t.close_quarantined(a), Some(NodeId(1)));
        assert!(t.lookup(a).is_none(), "closed entry must not route");
        assert_eq!(t.quarantined(), 1);
        let b = t.open(1, NodeId(1), Vqpn(0));
        assert_ne!(a, b, "quarantined vqpn must not be re-issued");
        assert_eq!(t.release_quarantined(NodeId(1)), 1);
        assert_eq!(t.quarantined(), 0);
        t.close(b);
        let c = t.open(1, NodeId(1), Vqpn(0));
        // free list is LIFO: b was recycled after the release put a back
        assert_eq!(c, b);
        t.close(c);
        let d = t.open(1, NodeId(1), Vqpn(0));
        let e = t.open(1, NodeId(1), Vqpn(0));
        assert_eq!(d, c);
        assert_eq!(e, a, "released vqpn re-enters the allocator");
    }

    #[test]
    fn release_only_frees_the_drained_remote() {
        let mut t = ConnTable::new();
        let a = t.open(1, NodeId(1), Vqpn(0));
        let b = t.open(1, NodeId(2), Vqpn(0));
        t.close_quarantined(a);
        t.close_quarantined(b);
        assert_eq!(t.quarantined(), 2);
        assert_eq!(t.release_quarantined(NodeId(2)), 1);
        assert_eq!(t.quarantined(), 1);
        assert_eq!(t.release_quarantined(NodeId(2)), 0);
        assert_eq!(t.release_quarantined(NodeId(1)), 1);
    }

    #[test]
    fn double_close_quarantined_fails() {
        let mut t = ConnTable::new();
        let a = t.open(1, NodeId(1), Vqpn(0));
        assert!(t.close_quarantined(a).is_some());
        assert!(t.close_quarantined(a).is_none());
        assert!(!t.close(a));
        assert_eq!(t.active(), 0);
    }
}
