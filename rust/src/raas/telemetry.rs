//! The daemon's CPU/memory ledger (Figs 7 & 8) and the load snapshots the
//! adaptive selector consumes (§2.2).
//!
//! Everything here is *measured from the actual structures*: registered
//! bytes come from the MR table, ring bytes from the sessions that exist,
//! thread counts from the threads the daemon actually runs. Nothing is a
//! fudge constant.

use crate::fabric::time::Ns;

use super::transport::HostLoad;

/// Per-application session resources (one app talking to the daemon).
#[derive(Clone, Debug)]
pub struct SessionResources {
    /// Submit + completion ring bytes (shared memory with the app).
    pub ring_bytes: u64,
    /// eventfd pair — kernel object, counted as a constant overhead.
    pub eventfd_bytes: u64,
}

impl Default for SessionResources {
    fn default() -> Self {
        // 2 rings × 4096 slots × 64 B descriptors + 2 eventfds
        SessionResources { ring_bytes: 2 * 4096 * 64, eventfd_bytes: 2 * 128 }
    }
}

/// Rolled-up daemon resource usage at one instant.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResourceSnapshot {
    /// Bytes: rings + pool HWM + fabric objects (QPs/CQs/SRQ/MTT).
    pub mem_bytes: u64,
    /// Cores-equivalent: daemon threads + itemized work.
    pub cpu_cores: f64,
    /// Registered application sessions.
    pub apps: u32,
    /// Live logical connections.
    pub conns: u32,
    /// Shared QPs (one per active remote node).
    pub shared_qps: u32,
    /// Connection-table bytes (entry array + free/quarantine lists) —
    /// under lazy leases this is the *entire* per-registered-vQPN cost of
    /// an idle tenant, the fig-12 memory metric.
    pub conn_table_bytes: u64,
}

/// The daemon's accounting state.
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    /// Per-app session resources.
    pub sessions: Vec<SessionResources>,
    /// Daemon service threads that busy-poll (Worker + Poller).
    pub service_threads: u32,
    /// Itemized CPU charged by daemon work (ring ops, WR builds, demux).
    pub busy_ns: u64,
    /// Observation window start (for utilization).
    pub window_start: Ns,
    /// Decision inputs maintained incrementally.
    pub pool_pressure: f64,
    /// Data-plane ops accepted.
    pub ops_submitted: u64,
    /// Initiator-side completions delivered.
    pub ops_completed: u64,
    /// Ops that completed in failure or were reclaimed without a
    /// completion (fault runs; always 0 on the lossless fabric).
    pub ops_failed: u64,
    /// Windowed ICM-cache hit rate sampled from the local NIC (input to
    /// the RC↔UD migration policy — [`super::migrate`]). 1.0 until the
    /// first window with enough lookups.
    pub icm_hit_rate: f64,
    /// QPs this daemon holds open on the local NIC (shared RC QPs + the
    /// host-wide UD QP) — the migration policy's structural signal.
    pub active_qps: u32,
}

/// Minimum ICM lookups in a sampling window before the hit rate is
/// considered meaningful.
pub const ICM_SAMPLE_MIN_LOOKUPS: u64 = 64;

impl Telemetry {
    /// Ledger for a daemon running `service_threads` busy-poll threads.
    pub fn new(service_threads: u32) -> Self {
        Telemetry { service_threads, icm_hit_rate: 1.0, ..Default::default() }
    }

    /// Fold one ICM sampling window (`hits`/`misses` deltas over the
    /// window) into the ledger; windows with fewer than
    /// [`ICM_SAMPLE_MIN_LOOKUPS`] lookups are discarded as noise. Returns
    /// the window's rate when it was accepted.
    pub fn sample_icm(&mut self, hits: u64, misses: u64) -> Option<f64> {
        let total = hits + misses;
        if total < ICM_SAMPLE_MIN_LOOKUPS {
            return None;
        }
        let rate = hits as f64 / total as f64;
        self.icm_hit_rate = rate;
        Some(rate)
    }

    /// Account a new app session; returns its id.
    pub fn add_session(&mut self) -> u32 {
        self.sessions.push(SessionResources::default());
        self.sessions.len() as u32 - 1
    }

    /// Charge `ns` of itemized daemon work.
    pub fn charge(&mut self, ns: u64) {
        self.busy_ns += ns;
    }

    /// Shared-memory bytes across all sessions (rings + eventfds).
    pub fn ring_bytes(&self) -> u64 {
        self.sessions.iter().map(|s| s.ring_bytes + s.eventfd_bytes).sum()
    }

    /// Cores-equivalent over `[window_start, now]`.
    pub fn cpu_cores(&self, now: Ns) -> f64 {
        let span = now.saturating_sub(self.window_start).0.max(1);
        self.service_threads as f64 + self.busy_ns as f64 / span as f64
    }

    /// The selector's local-load input. CPU utilization needs a minimum
    /// observation window (1 ms) before it is meaningful; early in a run we
    /// report only the fixed service-thread load.
    pub fn load(&self, now: Ns, total_cores: u32) -> HostLoad {
        let span = now.saturating_sub(self.window_start);
        let cpu_cores = if span.0 < 1_000_000 {
            self.service_threads as f64
        } else {
            self.cpu_cores(now)
        };
        HostLoad {
            cpu: (cpu_cores / total_cores.max(1) as f64).min(1.0),
            mem: self.pool_pressure,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sessions_add_ring_memory() {
        let mut t = Telemetry::new(2);
        assert_eq!(t.ring_bytes(), 0);
        t.add_session();
        t.add_session();
        assert_eq!(t.ring_bytes(), 2 * (2 * 4096 * 64 + 256));
    }

    #[test]
    fn cpu_counts_threads_plus_items() {
        let mut t = Telemetry::new(2);
        t.charge(500_000); // 0.5 ms of itemized work
        let cores = t.cpu_cores(Ns(1_000_000)); // over 1 ms
        assert!((cores - 2.5).abs() < 1e-9, "cores={cores}");
    }

    #[test]
    fn icm_window_ignores_tiny_samples() {
        let mut t = Telemetry::new(2);
        assert!((t.icm_hit_rate - 1.0).abs() < 1e-12, "optimistic before data");
        assert_eq!(t.sample_icm(3, 2), None, "5 lookups is noise");
        assert!((t.icm_hit_rate - 1.0).abs() < 1e-12);
        assert_eq!(t.sample_icm(25, 75), Some(0.25));
        assert!((t.icm_hit_rate - 0.25).abs() < 1e-12);
    }

    #[test]
    fn load_normalizes_by_core_count() {
        let mut t = Telemetry::new(6);
        t.pool_pressure = 0.4;
        let load = t.load(Ns(1_000_000), 24);
        assert!((load.cpu - 0.25).abs() < 1e-9);
        assert!((load.mem - 0.4).abs() < 1e-9);
    }
}
