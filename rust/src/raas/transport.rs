//! Adaptive transport & verb selection (§2.2).
//!
//! RDMAvisor mitigates the "no one-size-fits-all" problem: normal users
//! call `send(fd, buf, len, 0)` and the daemon picks the RDMA operation:
//!
//! * **small messages** → two-sided SEND/RECV (lower latency at small
//!   sizes; the SRQ supplies buffers; no rendezvous needed),
//! * **large messages** → one-sided WRITE (or READ on the pull side),
//!   which bypasses the remote CPU,
//! * **WRITE vs READ** — chosen from the *current CPU and memory pressure
//!   at both end-hosts*, measured by the daemons: pushing (WRITE) costs
//!   initiator CPU, pulling (READ) costs responder NIC+memory bandwidth;
//!   the selector steers work toward the less-loaded side,
//! * **UC never chosen by default**: UC QPs cannot attach to an SRQ [1],
//!   which would wreck the shared-buffer design — RC is the connected
//!   default (§2.1), and our microbench (Fig 1) shows RC WRITE ≈ UC WRITE.
//!
//! Knowledgeable users override everything with `Flags` (e.g. `RC|WRITE`).

use crate::fabric::types::{supports, QpTransport, Verb};

use super::api::{Flags, RaasError};

/// Host-load snapshot the selector consumes (produced by [`super::telemetry`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct HostLoad {
    /// CPU utilization in [0, 1] (cores busy / cores total).
    pub cpu: f64,
    /// Registered-memory pressure in [0, 1] (pool in use / pool size).
    pub mem: f64,
}

/// Tunables for the adaptive policy.
#[derive(Clone, Debug)]
pub struct SelectorConfig {
    /// At or below this size, two-sided SEND wins (inline-able, one DMA).
    pub small_msg_bytes: u64,
    /// Hysteresis band around the threshold to avoid flapping.
    pub hysteresis: f64,
    /// Load difference needed before we flip WRITE→READ or back.
    pub load_margin: f64,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        SelectorConfig { small_msg_bytes: 4096, hysteresis: 0.25, load_margin: 0.15 }
    }
}

/// The decision for one message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Choice {
    /// Transport to use.
    pub transport: QpTransport,
    /// Verb to use.
    pub verb: Verb,
}

/// Stateful per-connection selector (keeps hysteresis state).
#[derive(Clone, Debug)]
pub struct Selector {
    cfg: SelectorConfig,
    /// Last size-class decision (true = small/SEND side), for hysteresis.
    last_small: Option<bool>,
    /// Decision counters (exported to metrics/ablation).
    pub chose_send: u64,
    /// Times one-sided WRITE was chosen.
    pub chose_write: u64,
    /// Times one-sided READ was chosen.
    pub chose_read: u64,
}

impl Selector {
    /// Selector with fresh hysteresis state and zeroed counters.
    pub fn new(cfg: SelectorConfig) -> Self {
        Selector { cfg, last_small: None, chose_send: 0, chose_write: 0, chose_read: 0 }
    }

    /// Pick (transport, verb) for a message of `len` bytes given both ends'
    /// load. `flags` pins any component the user specified; combinations
    /// that violate Table 1 are rejected.
    pub fn choose(
        &mut self,
        len: u64,
        flags: Flags,
        local: HostLoad,
        remote: HostLoad,
        mtu: u64,
    ) -> Result<Choice, RaasError> {
        self.choose_adaptive(len, flags, local, remote, mtu, false)
    }

    /// [`Selector::choose`] with the migration engine's input: when
    /// `prefer_ud` is set (the destination has migrated to datagram mode —
    /// [`super::migrate`]) and the user pinned nothing that contradicts
    /// it, the choice is UD SEND; the daemon's segmentation layer lifts
    /// the MTU cap. User pins always win: a pinned transport or a pinned
    /// one-sided verb keeps the connected path regardless of pressure.
    pub fn choose_adaptive(
        &mut self,
        len: u64,
        flags: Flags,
        local: HostLoad,
        remote: HostLoad,
        mtu: u64,
        prefer_ud: bool,
    ) -> Result<Choice, RaasError> {
        // ---- user-pinned components win
        let pinned_t = flags.transport();
        let pinned_v = flags.verb();
        if let (Some(t), Some(v)) = (pinned_t, pinned_v) {
            if !supports(t, v) {
                return Err(RaasError::UnsupportedCombination(t, v));
            }
            self.count(v);
            return Ok(Choice { transport: t, verb: v });
        }

        // ---- datagram mode: a pinned UD transport, or a migrated
        // destination with no contradicting pin, rides UD SEND (the only
        // verb Table 1 allows there; size is handled by segmentation).
        // Migration must stay transparent, so unpinned messages beyond
        // the segmentation cap keep the connected path (RC carries up to
        // 1 GB) instead of surfacing an error the app never caused — only
        // an explicit `Flags::UD` pin is allowed to hit the UD limit.
        if pinned_t == Some(QpTransport::Ud)
            || (pinned_t.is_none()
                && prefer_ud
                && len <= super::migrate::ud_max_msg_bytes(mtu)
                && matches!(pinned_v, None | Some(Verb::Send)))
        {
            // keep the size-class hysteresis state advancing so a later
            // return to RC resumes from a consistent classification
            let _ = self.size_class(len);
            self.count(Verb::Send);
            return Ok(Choice { transport: QpTransport::Ud, verb: Verb::Send });
        }

        // ---- size class with hysteresis
        let small = self.size_class(len);

        // a pinned verb constrains the size-class default
        let verb = match pinned_v {
            Some(v) => v,
            None if small => Verb::Send,
            None => {
                // large: one-sided; WRITE by default, READ when the local
                // host is markedly busier than the remote (push the DMA
                // work to the idler side — §2.2's CPU-aware selection).
                if local.cpu > remote.cpu + self.cfg.load_margin
                    || local.mem > remote.mem + self.cfg.load_margin
                {
                    Verb::Read
                } else {
                    Verb::Write
                }
            }
        };

        // ---- transport: RC unless pinned (UC has no SRQ; UD only fits
        // sub-MTU sends)
        let transport = match pinned_t {
            Some(t) => t,
            None => {
                if verb == Verb::Send && len <= mtu && small && remote.cpu < 0.9 {
                    // tiny datagrams could ride UD, but RC keeps ordering and
                    // the SRQ; stay RC per §2.1 unless the user pins UD.
                    QpTransport::Rc
                } else {
                    QpTransport::Rc
                }
            }
        };

        if !supports(transport, verb) {
            return Err(RaasError::UnsupportedCombination(transport, verb));
        }
        self.count(verb);
        Ok(Choice { transport, verb })
    }

    fn size_class(&mut self, len: u64) -> bool {
        let t = self.cfg.small_msg_bytes as f64;
        let small = match self.last_small {
            // hysteresis: once large, need to drop below t*(1-h) to flip
            Some(true) => (len as f64) <= t * (1.0 + self.cfg.hysteresis),
            Some(false) => (len as f64) < t * (1.0 - self.cfg.hysteresis),
            None => len <= self.cfg.small_msg_bytes,
        };
        self.last_small = Some(small);
        small
    }

    fn count(&mut self, v: Verb) {
        match v {
            Verb::Send => self.chose_send += 1,
            Verb::Write => self.chose_write += 1,
            Verb::Read => self.chose_read += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sel() -> Selector {
        Selector::new(SelectorConfig::default())
    }

    fn idle() -> HostLoad {
        HostLoad { cpu: 0.1, mem: 0.1 }
    }

    #[test]
    fn small_messages_use_send() {
        let c = sel().choose(256, Flags::default(), idle(), idle(), 4096).unwrap();
        assert_eq!(c.verb, Verb::Send);
        assert_eq!(c.transport, QpTransport::Rc);
    }

    #[test]
    fn large_messages_use_write_when_idle() {
        let c = sel().choose(64 << 10, Flags::default(), idle(), idle(), 4096).unwrap();
        assert_eq!(c.verb, Verb::Write);
        assert_eq!(c.transport, QpTransport::Rc);
    }

    #[test]
    fn busy_local_host_prefers_read() {
        let busy = HostLoad { cpu: 0.9, mem: 0.2 };
        let c = sel().choose(64 << 10, Flags::default(), busy, idle(), 4096).unwrap();
        assert_eq!(c.verb, Verb::Read, "pull from the idle side");
    }

    #[test]
    fn memory_pressure_also_flips_to_read() {
        let squeezed = HostLoad { cpu: 0.1, mem: 0.9 };
        let c = sel().choose(64 << 10, Flags::default(), squeezed, idle(), 4096).unwrap();
        assert_eq!(c.verb, Verb::Read);
    }

    #[test]
    fn user_pin_overrides_policy() {
        let c = sel()
            .choose(64, Flags::RC | Flags::WRITE, idle(), idle(), 4096)
            .unwrap();
        assert_eq!(c.verb, Verb::Write, "pin beats the small-msg default");
    }

    #[test]
    fn illegal_pin_rejected_by_table1() {
        let err = sel()
            .choose(64, Flags::UC | Flags::READ, idle(), idle(), 4096)
            .unwrap_err();
        assert_eq!(
            err,
            RaasError::UnsupportedCombination(QpTransport::Uc, Verb::Read)
        );
        let err = sel()
            .choose(64, Flags::UD | Flags::WRITE, idle(), idle(), 4096)
            .unwrap_err();
        assert!(matches!(err, RaasError::UnsupportedCombination(..)));
    }

    #[test]
    fn hysteresis_prevents_flapping() {
        let mut s = sel();
        // establish "small"
        assert_eq!(s.choose(4096, Flags::default(), idle(), idle(), 4096).unwrap().verb, Verb::Send);
        // slightly over the threshold stays small inside the band
        assert_eq!(s.choose(4608, Flags::default(), idle(), idle(), 4096).unwrap().verb, Verb::Send);
        // far over flips to large
        assert_eq!(s.choose(64 << 10, Flags::default(), idle(), idle(), 4096).unwrap().verb, Verb::Write);
        // slightly under the threshold stays large inside the band
        assert_eq!(s.choose(4000, Flags::default(), idle(), idle(), 4096).unwrap().verb, Verb::Write);
        // far under flips back
        assert_eq!(s.choose(64, Flags::default(), idle(), idle(), 4096).unwrap().verb, Verb::Send);
    }

    #[test]
    fn pinned_ud_without_verb_forces_send() {
        // the only Table-1-legal verb on UD; the daemon's segmentation
        // layer carries sizes past the MTU
        let c = sel().choose(64 << 10, Flags::UD, idle(), idle(), 4096).unwrap();
        assert_eq!(c.transport, QpTransport::Ud);
        assert_eq!(c.verb, Verb::Send);
    }

    #[test]
    fn migrated_destination_rides_ud() {
        let c = sel()
            .choose_adaptive(256, Flags::default(), idle(), idle(), 4096, true)
            .unwrap();
        assert_eq!(c.transport, QpTransport::Ud);
        assert_eq!(c.verb, Verb::Send);
    }

    #[test]
    fn migration_preference_spares_messages_beyond_ud_cap() {
        // unpinned 16 MB > the 128 KB UD segmentation cap at 4 KB MTU:
        // migration must stay transparent, so the connected path carries it
        let c = sel()
            .choose_adaptive(16 << 20, Flags::default(), idle(), idle(), 4096, true)
            .unwrap();
        assert_eq!(c.transport, QpTransport::Rc);
        assert_eq!(c.verb, Verb::Write);
    }

    #[test]
    fn verb_pin_beats_migration_preference() {
        // a pinned one-sided verb cannot ride UD: the connected path wins
        let c = sel()
            .choose_adaptive(256, Flags::WRITE, idle(), idle(), 4096, true)
            .unwrap();
        assert_eq!(c.verb, Verb::Write);
        assert_ne!(c.transport, QpTransport::Ud);
        // a pinned RC transport also beats the preference
        let c = sel()
            .choose_adaptive(256, Flags::RC, idle(), idle(), 4096, true)
            .unwrap();
        assert_eq!(c.transport, QpTransport::Rc);
    }

    #[test]
    fn decision_counters_accumulate() {
        let mut s = sel();
        s.choose(64, Flags::default(), idle(), idle(), 4096).unwrap();
        s.choose(64 << 10, Flags::default(), idle(), idle(), 4096).unwrap();
        assert_eq!(s.chose_send, 1);
        assert_eq!(s.chose_write, 1);
    }
}
