//! The daemon's in-flight op slab: wr_id-addressed, zero-hash completion.
//!
//! Before this module the daemon tracked every in-flight op in three
//! wr_id-keyed `HashMap`s (`open_leases`, `rc_inflight_remote`,
//! `ud_msg_len`) plus a `HashSet` of reclaimed wr_ids — four hash
//! lookups on the Poller's per-completion path. The slab replaces all
//! of them: an op's slot index and a generation counter are packed
//! **into the wr_id itself**, so completing an op is two array indexes
//! (slab slot, then the vQPN connection table) and zero hashing or
//! allocation (Storm's lookup-free dataplane argument — see PAPERS.md).
//!
//! ## wr_id encoding
//!
//! ```text
//!   63      52 51          32 31             0
//!  +----------+--------------+----------------+
//!  | gen (12) | slot+1 (20)  |   vQPN (32)    |
//!  +----------+--------------+----------------+
//! ```
//!
//! * The vQPN keeps the low 32 bits exactly as Fig 4 prescribes (and as
//!   [`super::vqpn::unpack_vqpn`] expects) — completion routing still
//!   reads it straight out of the CQE.
//! * `slot+1` addresses the slab; the all-zeros field is the **null
//!   slot** used by WRs that never produce a CQE (unsignaled UD
//!   fragments), so "untracked" is representable without a map.
//! * `gen` is the slot's generation, bumped on every release. A CQE
//!   that limps in after the stale-lease reclaim freed its op carries a
//!   stale generation and misses the slab — exactly the late-completion
//!   dedup the old `reclaimed_wr_ids` hash set performed, now for free.
//!   (The 12-bit counter wraps at 4096; a false match would need one
//!   slot to be recycled 4096 times while a single CQE is in flight,
//!   orders of magnitude beyond the simulator's retry horizons.)

use crate::raas::vqpn::Vqpn;

/// Bits of the wr_id carrying `slot + 1` (≈1M concurrent ops).
pub const SLOT_BITS: u32 = 20;
/// Bits of the wr_id carrying the slot generation.
pub const GEN_BITS: u32 = 12;
/// Most ops a slab can hold live at once (one wr_id slot field is the
/// reserved null).
pub const MAX_LIVE_OPS: usize = (1 << SLOT_BITS) - 1;
const SLOT_MASK: u64 = (1 << SLOT_BITS) - 1;
const GEN_MASK: u16 = (1 << GEN_BITS) - 1;

/// Pack `(slot, gen, vqpn)` into a wr_id. `slot` must be below
/// [`MAX_LIVE_OPS`] and `gen` below `1 << `[`GEN_BITS`].
#[inline]
pub fn pack_op_wr_id(slot: u32, gen: u16, vqpn: Vqpn) -> u64 {
    debug_assert!((slot as usize) < MAX_LIVE_OPS);
    debug_assert!(gen <= GEN_MASK);
    ((gen as u64) << (32 + SLOT_BITS)) | (((slot as u64) + 1) << 32) | vqpn.0 as u64
}

/// A wr_id carrying only a vQPN (null slot): the form stamped on WRs
/// that never complete (unsignaled UD fragments).
#[inline]
pub fn untracked_wr_id(vqpn: Vqpn) -> u64 {
    vqpn.0 as u64
}

/// Extract the slab slot from a wr_id (None for the null slot).
#[inline]
pub fn unpack_op_slot(wr_id: u64) -> Option<u32> {
    (((wr_id >> 32) & SLOT_MASK) as u32).checked_sub(1)
}

/// Extract the slot generation from a wr_id.
#[inline]
pub fn unpack_op_gen(wr_id: u64) -> u16 {
    ((wr_id >> (32 + SLOT_BITS)) as u16) & GEN_MASK
}

#[derive(Clone, Debug)]
struct Slot<T> {
    gen: u16,
    vqpn: Vqpn,
    val: Option<T>,
}

/// Generational slab of in-flight ops addressed by the wr_ids it mints.
///
/// `insert` returns the wr_id to stamp on the WR; `take` (the completion
/// path) resolves a CQE's wr_id in O(1) and rejects stale generations.
/// Freed slots are recycled LIFO, so the backing vector's length is the
/// high-water mark of concurrent ops, not the lifetime count.
#[derive(Clone, Debug, Default)]
pub struct OpSlab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    live: usize,
}

impl<T> OpSlab<T> {
    /// Empty slab.
    pub fn new() -> Self {
        OpSlab { slots: Vec::new(), free: Vec::new(), live: 0 }
    }

    /// Track a new op for `vqpn`; returns the wr_id carrying its slot.
    pub fn insert(&mut self, vqpn: Vqpn, val: T) -> u64 {
        let slot = match self.free.pop() {
            Some(s) => {
                let sl = &mut self.slots[s as usize];
                sl.vqpn = vqpn;
                sl.val = Some(val);
                s
            }
            None => {
                assert!(
                    self.slots.len() < MAX_LIVE_OPS,
                    "op slab full: {} concurrent in-flight ops",
                    MAX_LIVE_OPS
                );
                self.slots.push(Slot { gen: 0, vqpn, val: Some(val) });
                (self.slots.len() - 1) as u32
            }
        };
        self.live += 1;
        pack_op_wr_id(slot, self.slots[slot as usize].gen, vqpn)
    }

    /// Resolve a live op by its wr_id (None for null slot, stale
    /// generation, vQPN mismatch, or a freed slot).
    #[inline]
    pub fn get(&self, wr_id: u64) -> Option<&T> {
        let s = unpack_op_slot(wr_id)?;
        let slot = self.slots.get(s as usize)?;
        if slot.gen != unpack_op_gen(wr_id) || slot.vqpn.0 != wr_id as u32 {
            return None;
        }
        slot.val.as_ref()
    }

    /// Resolve a live op mutably (same validity rules as [`OpSlab::get`]).
    #[inline]
    pub fn get_mut(&mut self, wr_id: u64) -> Option<&mut T> {
        let s = unpack_op_slot(wr_id)?;
        let slot = self.slots.get_mut(s as usize)?;
        if slot.gen != unpack_op_gen(wr_id) || slot.vqpn.0 != wr_id as u32 {
            return None;
        }
        slot.val.as_mut()
    }

    /// Complete an op: remove and return it, bumping the slot generation
    /// so any later CQE carrying this wr_id dies here.
    pub fn take(&mut self, wr_id: u64) -> Option<T> {
        let s = unpack_op_slot(wr_id)?;
        let slot = self.slots.get_mut(s as usize)?;
        if slot.gen != unpack_op_gen(wr_id) || slot.vqpn.0 != wr_id as u32 {
            return None;
        }
        let val = slot.val.take()?;
        slot.gen = (slot.gen + 1) & GEN_MASK;
        self.free.push(s);
        self.live -= 1;
        Some(val)
    }

    /// Live ops.
    pub fn len(&self) -> usize {
        self.live
    }

    /// True when no op is in flight.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Iterate live ops as `(wr_id, &op)` in ascending slot order — a
    /// deterministic order for the stale-lease reclaim, never hash order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, &T)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| {
            s.val
                .as_ref()
                .map(|v| (pack_op_wr_id(i as u32, s.gen, s.vqpn), v))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wr_id_fields_roundtrip() {
        let id = pack_op_wr_id(12345, 0x9AB, Vqpn(0xDEAD_BEEF));
        assert_eq!(unpack_op_slot(id), Some(12345));
        assert_eq!(unpack_op_gen(id), 0x9AB);
        assert_eq!(crate::raas::vqpn::unpack_vqpn(id), Vqpn(0xDEAD_BEEF));
    }

    #[test]
    fn null_slot_is_untracked() {
        let id = untracked_wr_id(Vqpn(77));
        assert_eq!(unpack_op_slot(id), None);
        let slab: OpSlab<u8> = OpSlab::new();
        assert!(slab.get(id).is_none());
    }

    #[test]
    fn insert_take_roundtrip() {
        let mut slab = OpSlab::new();
        let a = slab.insert(Vqpn(1), "a");
        let b = slab.insert(Vqpn(2), "b");
        assert_ne!(a, b);
        assert_eq!(slab.len(), 2);
        assert_eq!(slab.get(a), Some(&"a"));
        assert_eq!(slab.take(b), Some("b"));
        assert_eq!(slab.take(b), None, "double take must miss");
        assert_eq!(slab.take(a), Some("a"));
        assert!(slab.is_empty());
    }

    #[test]
    fn stale_generation_is_rejected() {
        let mut slab = OpSlab::new();
        let old = slab.insert(Vqpn(9), 1u32);
        assert_eq!(slab.take(old), Some(1));
        // the slot is recycled with a bumped generation: the old wr_id
        // must not resolve to the new op
        let new = slab.insert(Vqpn(9), 2u32);
        assert_ne!(old, new);
        assert!(slab.get(old).is_none());
        assert_eq!(slab.take(old), None);
        assert_eq!(slab.take(new), Some(2));
    }

    #[test]
    fn iter_is_slot_ordered() {
        let mut slab = OpSlab::new();
        let ids: Vec<u64> = (0..5).map(|i| slab.insert(Vqpn(i), i)).collect();
        slab.take(ids[2]);
        let live: Vec<u32> = slab.iter().map(|(_, &v)| v).collect();
        assert_eq!(live, vec![0, 1, 3, 4]);
        for (wr_id, &v) in slab.iter() {
            assert_eq!(slab.get(wr_id), Some(&v), "iterated wr_id resolves");
        }
    }
}
