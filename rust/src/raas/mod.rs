//! RaaS — RDMA as a Service. The paper's system contribution.
//!
//! RDMAvisor runs as one daemon per machine, owning every RDMA resource on
//! the host and exposing a socket-like API to all applications:
//!
//! * [`api`] — `connect/listen/accept/send/recv/recv_zero_copy` + `Flags`
//!   (Fig 3), with `Target` encapsulating IPv4/IPv6/GID/LID addressing.
//! * [`vqpn`] — virtual QPNs: all logical connections to the same remote
//!   node share one RC QP; the vQPN travels in `wr_id` (one-sided) or
//!   `imm_data` (two-sided) and the Poller demultiplexes completions
//!   (Figs 2 & 4, §2.3).
//! * [`shmem`] — the lock-free app↔daemon channel: real SPSC rings with
//!   eventfd doorbells (used on the live serving path), plus the cost
//!   model constants the simulator charges for them.
//! * [`transport`] — adaptive transport/verb selection from message size
//!   and end-host CPU/memory telemetry (§2.2), overridable via `Flags`.
//! * [`migrate`] — per-destination RC↔UD transport migration: the daemon
//!   tracks ICM-cache pressure and moves overflowing destination working
//!   sets onto one host-wide UD QP (hysteretic, order-preserving bounded
//!   drain, MTU fragmentation/reassembly).
//! * [`buffer`] — registered send/recv buffer pools with slab classes,
//!   huge-page registration, and the memcpy-vs-memreg staging policy [9].
//! * [`opslab`] — the in-flight op slab: slot + generation packed into
//!   the wr_id, so the Poller completes an op with two array indexes and
//!   zero hashing.
//! * [`daemon`] — the Worker/Poller engine over the simulated fabric:
//!   WR batching per shared QP, host-wide SRQ, per-app session state.
//! * [`telemetry`] — the CPU/memory ledger behind Figs 7/8 and the
//!   adaptive selector's inputs.

pub mod api;
pub mod vqpn;
pub mod shmem;
pub mod transport;
pub mod migrate;
pub mod buffer;
pub mod daemon;
pub mod opslab;
pub mod telemetry;

pub use api::{Flags, Target};
pub use daemon::{Daemon, DaemonConfig};
pub use migrate::{DestState, MigrationConfig, TransportManager};
pub use vqpn::{ConnId, Vqpn};
